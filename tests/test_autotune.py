"""Per-layer plan autotuner + plan cache (repro.engine.autotune, §7).

Covers: the exact chunked-f32 integer substrate, candidate enumeration
(cost-model-pruned tile_w picks, the interpret guard), tune-on-miss
persistence, pure cache hits (no re-measurement AND no jit retrace),
cache-key sensitivity (dtype / geometry / device kind), corrupt- and
stale-cache degradation, the never-slower winner rule, heterogeneous
ModelPlans (tuned + explicit layer_substrates), model-level bit-identity
of tuned vs default plans, and the --tuning CLI mapping.
"""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CNN_SMOKES
from repro.engine import (ExecutionPolicy, plan_conv_layer, plan_model,
                          run_conv2d, tune_conv_layer, tune_model)
from repro.engine import autotune
from repro.kernels import ref

INT8_KW = dict(stride=1, padding=1, groups=1, relu=True, has_bias=False,
               requant_kind="mult_shift", in_sz=1, w_sz=1, out_sz=1)
INT8_ARGS = ((12, 16), 8, 3, 8)


@pytest.fixture
def plan_cache(tmp_path, monkeypatch):
    """Isolated plan-cache dir; engine caches reset around the test."""
    monkeypatch.setenv("REPRO_TUNED_PLANS_DIR", str(tmp_path))
    autotune.reset_cache()
    yield tmp_path
    autotune.reset_cache()


def _fast_measure(monkeypatch, scripted=None, counter=None):
    """Deterministic measurement: real outputs (identity gate stays
    honest), scripted per-substrate timings, optional call counting."""
    real = autotune._measure_plan

    def fake(plan, *, in_sz, warmup=1, reps=5, batch=1):
        if counter is not None:
            counter.append(plan.substrate)
        us, out = real(plan, in_sz=in_sz, warmup=0, reps=1, batch=batch)
        if scripted is not None:
            us = scripted[plan.substrate]
        return us, out

    monkeypatch.setattr(autotune, "_measure_plan", fake)
    return fake


# ---------------------------------------------------------------------------
# the f32exact substrate (the schedule move the tuner finds on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("stride,pad,groups", [(1, 1, 1), (2, 0, 1),
                                               (1, 2, 2)])
def test_conv2d_exact_f32_bitwise(stride, pad, groups):
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (2, 13, 15, 8), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 8 // groups, 8),
                           -127, 127, jnp.int8)
    got = ref.conv2d_exact_f32(x, w, stride=stride, padding=pad,
                               groups=groups)
    want = ref.conv2d_ref(x, w, stride=stride, padding=pad, groups=groups)
    assert got.dtype == want.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_conv2d_exact_f32_worst_case_magnitudes():
    """Adversarial extremes: all-255 x, all +/-127 w — the exactness
    argument must hold at the bound, not just for random data."""
    x = jnp.full((1, 9, 9, 64), 255, jnp.uint8)
    w = jnp.where((jnp.arange(3 * 3 * 64 * 8) % 2).reshape(3, 3, 64, 8) > 0,
                  127, -127).astype(jnp.int8)
    np.testing.assert_array_equal(
        np.asarray(ref.conv2d_exact_f32(x, w, padding=1)),
        np.asarray(ref.conv2d_ref(x, w, padding=1)))


def test_conv2d_exact_f32_float_delegates_to_oracle():
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 8, 8, 4))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 4))
    np.testing.assert_array_equal(
        np.asarray(ref.conv2d_exact_f32(x, w)),
        np.asarray(ref.conv2d_ref(x, w)))
    # mixed int activations / float weights: no exactness budget either —
    # must delegate, not crash on jnp.iinfo(float)
    xi = jax.random.randint(key, (1, 8, 8, 4), 0, 255, jnp.uint8)
    np.testing.assert_array_equal(
        np.asarray(ref.conv2d_exact_f32(xi, w)),
        np.asarray(ref.conv2d_ref(xi, w)))


def test_f32exact_substrate_through_dispatch():
    """run_conv2d on an f32exact plan == oracle plan, bit-identically,
    including the fused requant epilogue."""
    key = jax.random.PRNGKey(2)
    x = jax.random.randint(key, (1, 10, 10, 8), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 8, 8),
                           -127, 127, jnp.int8)
    rq = (jnp.full((8,), 16384, jnp.int32), jnp.full((8,), 20, jnp.int32))
    outs = {}
    for sub in ("oracle", "f32exact"):
        lp = plan_conv_layer((10, 10), 8, 3, 8, relu=True,
                             requant_kind="mult_shift", in_sz=1, w_sz=1,
                             out_sz=1,
                             policy=ExecutionPolicy(substrate=sub))
        outs[sub] = np.asarray(run_conv2d(lp, x, w, None, rq))
    assert outs["oracle"].dtype == outs["f32exact"].dtype == np.uint8
    np.testing.assert_array_equal(outs["oracle"], outs["f32exact"])


# ---------------------------------------------------------------------------
# candidate enumeration
# ---------------------------------------------------------------------------


def test_candidate_policies_int8_cpu():
    """Off-TPU integer layers search oracle vs f32exact; float layers have
    only the default; interpret is never searched."""
    cands = autotune.candidate_policies((16, 64), 16, 3, 16, in_sz=1)
    assert [c.substrate for c in cands] == ["oracle", "f32exact"]
    assert all(c.tuning == "off" for c in cands)
    fl = autotune.candidate_policies((16, 64), 16, 3, 16, in_sz=4)
    assert [c.substrate for c in fl] == ["oracle"]
    interp = autotune.candidate_policies(
        (16, 64), 16, 3, 16, in_sz=1,
        policy=ExecutionPolicy(substrate="interpret"))
    assert [c.substrate for c in interp] == ["interpret"]


def test_candidate_policies_pallas_sweep():
    """With the Pallas kernel available the schedule knobs get a
    one-factor-at-a-time sweep; tile_w picks are cost-model pruned."""
    cands = autotune.candidate_policies(
        (96, 512), 64, 3, 64, in_sz=4, include_pallas=True)
    pallas = [c for c in cands if c.substrate == "pallas"]
    assert pallas, "pallas candidates missing"
    tws = {c.tile_w for c in pallas}
    assert None in tws            # the auto-pick is always a candidate
    ths = {c.tile_h for c in pallas}
    assert len(ths) > 1           # tile_h swept
    # distinct policies only
    assert len(cands) == len(set(cands))


def test_tile_w_candidates_budget_pruned():
    """Shrinking the budget prunes the wide picks; survivors are 8-aligned
    (or the full width) and satisfy the halo floor."""
    kw = dict(stride=1, padding=1, groups=1, tile_h=8, block_c=64,
              block_f=64, in_sz=4, w_sz=4, out_sz=4)
    wide = autotune.tile_w_candidates((96, 512), 64, 3, 64,
                                      vmem_budget=1 << 40, **kw)
    assert wide[0] is None and 512 in wide
    tight = autotune.tile_w_candidates((96, 512), 64, 3, 64,
                                       vmem_budget=4 << 20, **kw)
    assert 512 not in tight
    for tw in tight:
        if tw is not None:
            assert tw % 8 == 0 or tw == 512
            assert tw >= 2      # halo floor: ceil((K - S) / S) = 2
    tiny = autotune.tile_w_candidates((96, 512), 64, 3, 64,
                                      vmem_budget=1, **kw)
    assert tiny == [None]       # nothing fits: leave it to pick_tile_w


# ---------------------------------------------------------------------------
# the plan cache: persist, hit, key sensitivity, degradation
# ---------------------------------------------------------------------------


def test_tune_on_miss_persists_and_applies(plan_cache, monkeypatch):
    calls = []
    _fast_measure(monkeypatch, counter=calls)
    lp = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                         policy=ExecutionPolicy(tuning="auto"))
    assert calls, "auto tuning must measure on a miss"
    assert lp.tuned
    assert os.path.exists(autotune.cache_path())
    data = json.load(open(autotune.cache_path()))
    assert data["version"] == autotune.PLAN_CACHE_VERSION
    [(key, entry)] = list(data["plans"].items())
    assert key == autotune.layer_key(*INT8_ARGS, emulate_hw=False,
                                     **INT8_KW)
    assert entry["schedule"]["substrate"] == lp.substrate


def test_second_lookup_is_pure_cache_hit(plan_cache, monkeypatch):
    calls = []
    _fast_measure(monkeypatch, counter=calls)
    plan_conv_layer(*INT8_ARGS, **INT8_KW,
                    policy=ExecutionPolicy(tuning="auto"))
    n_tune = len(calls)
    assert n_tune >= 2
    # simulate a fresh process: drop every in-memory cache, keep the file
    autotune.reset_cache()
    lp = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                         policy=ExecutionPolicy(tuning="auto"))
    assert len(calls) == n_tune, "cache hit must not re-measure"
    assert lp.tuned
    # and a cached-mode lookup is identical
    autotune.reset_cache()
    lp2 = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                          policy=ExecutionPolicy(tuning="cached"))
    assert lp2 == lp and len(calls) == n_tune


def test_cache_hit_does_not_retrace(plan_cache, monkeypatch):
    """Plans rebuilt from the persisted cache are value-equal, so a jit
    closed over them as a static argument must hit the trace cache."""
    _fast_measure(monkeypatch)
    traces = []

    def run(x, w, rq0, rq1, *, plan):
        traces.append(1)
        from repro.engine import execute
        return execute.run_conv2d(plan, x, w, None, (rq0, rq1))

    run2 = jax.jit(run, static_argnames=("plan",))
    key = jax.random.PRNGKey(0)
    x = jax.random.randint(key, (1, 12, 16, 8), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 8, 8),
                           -127, 127, jnp.int8)
    rq = (jnp.full((8,), 16384, jnp.int32), jnp.full((8,), 20, jnp.int32))
    p1 = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                         policy=ExecutionPolicy(tuning="auto"))
    o1 = run2(x, w, *rq, plan=p1)
    autotune.reset_cache()   # fresh process: plan rebuilt from the file
    p2 = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                         policy=ExecutionPolicy(tuning="cached"))
    assert p2 is not p1 and p2 == p1
    o2 = run2(x, w, *rq, plan=p2)
    assert len(traces) == 1, "equal tuned plans must not retrace"
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_cache_key_sensitivity(plan_cache):
    base = autotune.layer_key(*INT8_ARGS, emulate_hw=False, **INT8_KW)
    geom = autotune.layer_key((12, 17), *INT8_ARGS[1:], emulate_hw=False,
                              **INT8_KW)
    fdt = autotune.layer_key(*INT8_ARGS, emulate_hw=False,
                             **{**INT8_KW, "in_sz": 4})
    emu = autotune.layer_key(*INT8_ARGS, emulate_hw=True, **INT8_KW)
    epi = autotune.layer_key(*INT8_ARGS, emulate_hw=False,
                             **{**INT8_KW, "requant_kind": "shift"})
    assert len({base, geom, fdt, emu, epi}) == 5


def test_cache_key_carries_batch_axis(plan_cache):
    """Serving buckets tune independently: the layer key gained an ``n{N}``
    batch axis in PLAN_CACHE_VERSION 2, so an N=16 winner never shadows the
    N=1 one (a wide batch can prefer a different schedule)."""
    k1 = autotune.layer_key(*INT8_ARGS, emulate_hw=False, **INT8_KW)
    k4 = autotune.layer_key(*INT8_ARGS, emulate_hw=False, batch=4,
                            **INT8_KW)
    assert " n1 " in k1 and " n4 " in k4
    assert k1 != k4


def test_tune_at_batch_persists_batch_keyed_winner(plan_cache, monkeypatch):
    _fast_measure(monkeypatch)
    plan_conv_layer(*INT8_ARGS, **INT8_KW, batch=4,
                    policy=ExecutionPolicy(tuning="auto"))
    data = json.load(open(autotune.cache_path()))
    [(key, _)] = list(data["plans"].items())
    assert key == autotune.layer_key(*INT8_ARGS, emulate_hw=False, batch=4,
                                     **INT8_KW)
    # the N=1 lookup misses this winner (cached mode: default schedule)
    lp1 = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                          policy=ExecutionPolicy(tuning="cached"))
    assert not lp1.tuned


def test_cache_file_per_device_kind(plan_cache, monkeypatch):
    """A different device kind reads/writes a different cache file, so
    winners never leak across hardware classes."""
    p_cpu = autotune.cache_path()
    monkeypatch.setattr(autotune, "device_kind", lambda: "TPU v4")
    p_tpu = autotune.cache_path()
    assert p_cpu != p_tpu and "TPU-v4" in p_tpu


def test_corrupt_cache_degrades_with_warning(plan_cache):
    path = autotune.cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        f.write("{not json")
    with pytest.warns(RuntimeWarning, match="unreadable"):
        lp = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                             policy=ExecutionPolicy(tuning="cached"))
    default = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                              policy=ExecutionPolicy())
    assert not lp.tuned
    assert lp == default


def test_stale_cache_version_degrades_with_warning(plan_cache):
    path = autotune.cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    key = autotune.layer_key(*INT8_ARGS, emulate_hw=False, **INT8_KW)
    with open(path, "w") as f:
        json.dump({"version": autotune.PLAN_CACHE_VERSION + 1,
                   "plans": {key: {"schedule": {
                       "substrate": "f32exact", "tile_h": 8,
                       "tile_w": None, "block_c": 8, "block_f": 8}}}}, f)
    with pytest.warns(RuntimeWarning, match="version"):
        lp = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                             policy=ExecutionPolicy(tuning="cached"))
    assert not lp.tuned


def test_invalid_entry_degrades_with_warning(plan_cache):
    path = autotune.cache_path()
    os.makedirs(os.path.dirname(path), exist_ok=True)
    key = autotune.layer_key(*INT8_ARGS, emulate_hw=False, **INT8_KW)
    with open(path, "w") as f:
        json.dump({"version": autotune.PLAN_CACHE_VERSION,
                   "plans": {key: {"schedule": {"substrate": "fpga"}}}}, f)
    with pytest.warns(RuntimeWarning, match="invalid"):
        lp = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                             policy=ExecutionPolicy(tuning="cached"))
    assert not lp.tuned


def test_pinned_substrate_beats_cache(plan_cache, monkeypatch):
    """An explicitly pinned substrate is a stronger request than the
    cache: tuning only composes with substrate='auto', so a cached
    f32exact winner must not hijack an --substrate oracle/interpret run
    (the debug substrate especially)."""
    _fast_measure(monkeypatch,
                  scripted={"oracle": 100.0, "f32exact": 10.0})
    plan_conv_layer(*INT8_ARGS, **INT8_KW,
                    policy=ExecutionPolicy(tuning="auto"))
    for pin in ("oracle", "interpret"):
        lp = plan_conv_layer(
            *INT8_ARGS, **INT8_KW,
            policy=ExecutionPolicy(substrate=pin, tuning="cached"))
        assert lp.substrate == pin and not lp.tuned
    # auto still gets the winner
    lp = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                         policy=ExecutionPolicy(tuning="cached"))
    assert lp.substrate == "f32exact" and lp.tuned
    # a layer_substrates pin through plan_model behaves the same
    from repro.configs import CNN_SMOKES
    cfg = CNN_SMOKES["vgg16"]
    plan = plan_model(cfg, ExecutionPolicy(tuning="cached"),
                      layer_substrates=("oracle", None, None))
    assert plan.layers[0].substrate == "oracle" and not plan.layers[0].tuned


def test_cached_miss_is_default_plan(plan_cache):
    lp = plan_conv_layer(*INT8_ARGS, **INT8_KW,
                         policy=ExecutionPolicy(tuning="cached"))
    assert not lp.tuned and lp.substrate == \
        ExecutionPolicy().resolved_substrate()


# ---------------------------------------------------------------------------
# winner selection
# ---------------------------------------------------------------------------


def test_winner_never_slower_than_default(plan_cache, monkeypatch):
    """A candidate inside the MIN_GAIN margin loses to the default."""
    _fast_measure(monkeypatch,
                  scripted={"oracle": 100.0, "f32exact": 98.0})
    res = tune_conv_layer(*INT8_ARGS, **INT8_KW)
    assert res.schedule["substrate"] == "oracle"
    assert res.us == res.us_default == 100.0


def test_winner_beats_default_outside_margin(plan_cache, monkeypatch):
    _fast_measure(monkeypatch,
                  scripted={"oracle": 100.0, "f32exact": 10.0})
    res = tune_conv_layer(*INT8_ARGS, **INT8_KW)
    assert res.schedule["substrate"] == "f32exact"
    assert res.speedup == pytest.approx(10.0)
    # and the persisted entry round-trips through tune_conv_layer
    res2 = tune_conv_layer(*INT8_ARGS, **INT8_KW)
    assert res2.cached and res2.schedule == res.schedule


# ---------------------------------------------------------------------------
# model level: heterogeneous plans + bit-identity (acceptance)
# ---------------------------------------------------------------------------


def test_plan_model_layer_substrates_override():
    cfg = CNN_SMOKES["vgg16"]
    plan = plan_model(cfg, ExecutionPolicy(),
                      layer_substrates=("f32exact", None, "oracle"))
    assert [lp.substrate for lp in plan.layers] == \
        ["f32exact", ExecutionPolicy().resolved_substrate(), "oracle"]
    with pytest.raises(ValueError, match="layer_substrates"):
        plan_model(cfg, ExecutionPolicy(), layer_substrates=("oracle",))


def test_tuned_model_plan_bit_identical_vgg16_smoke(plan_cache):
    """Acceptance: a cached tuned ModelPlan is bit-identical in outputs to
    the default plan's — float forward AND fused int8 forward — while the
    int8 lane actually switches substrates per layer (real measurement)."""
    cfg = CNN_SMOKES["vgg16"]
    pol = ExecutionPolicy()
    tune_model(cfg, pol, datapath="float", reps=2)
    tune_model(cfg, pol, datapath="int8", reps=2)
    autotune.reset_cache()

    default = plan_model(cfg, pol)
    tuned = plan_model(cfg, ExecutionPolicy(tuning="cached"))
    assert all(lp.tuned for lp in tuned.layers)

    key = jax.random.PRNGKey(0)
    params = default.init(key)
    img = jax.random.normal(jax.random.fold_in(key, 1), (2, 16, 16, 3))
    np.testing.assert_array_equal(
        np.asarray(default.forward(params, img)),
        np.asarray(tuned.forward(params, img)))

    qp, _ = default.quantize(params)
    u8 = jax.random.randint(jax.random.fold_in(key, 2), (1, 16, 16, 3),
                            0, 255, jnp.uint8)
    pairs = default.calibrate_requant(qp, u8)
    feat_d = default.forward_int8(qp, u8, requant=pairs)
    feat_t = tuned.forward_int8(qp, u8, requant=pairs)
    assert feat_d.dtype == feat_t.dtype
    np.testing.assert_array_equal(np.asarray(feat_d), np.asarray(feat_t))


def test_tune_model_walk_matches_plan_model(plan_cache, monkeypatch):
    """tune_model tunes exactly the layer set plan_model resolves: after an
    int8 walk, every layer of the cached int8 sibling plan is tuned."""
    _fast_measure(monkeypatch)
    cfg = CNN_SMOKES["alexnet"]
    results = tune_model(cfg, ExecutionPolicy(), datapath="int8", reps=1)
    assert len(results) == len(cfg.layers)
    autotune.reset_cache()
    plan = plan_model(cfg, ExecutionPolicy(tuning="cached"))
    assert all(lp.tuned for lp in plan.int8.layers)
    assert not any(lp.tuned for lp in plan.layers)   # float keys untouched


# ---------------------------------------------------------------------------
# policy / CLI mapping
# ---------------------------------------------------------------------------


def test_policy_tuning_validation():
    assert ExecutionPolicy().tuning == "off"
    assert ExecutionPolicy(tuning="auto").resolve().tuning == "auto"
    with pytest.raises(ValueError, match="tuning"):
        ExecutionPolicy(tuning="always")


def test_cli_tuning_maps_to_policy():
    import argparse
    from repro.launch.cli import execution_parent, policy_from_args
    ap = argparse.ArgumentParser(parents=[execution_parent()])
    for mode in ("off", "cached", "auto"):
        args = ap.parse_args(["--tuning", mode])
        assert policy_from_args(args) == ExecutionPolicy(tuning=mode)
    assert policy_from_args(ap.parse_args([])).tuning == "off"
    # from_args tolerates namespaces without the flag (any Namespace works)
    assert ExecutionPolicy.from_args(argparse.Namespace()).tuning == "off"
    args = ap.parse_args(["--substrate", "f32exact"])
    assert policy_from_args(args).substrate == "f32exact"
