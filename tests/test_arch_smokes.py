"""Per-architecture smoke tests: a REDUCED config of the same family runs
one forward/train step on CPU (shapes + no NaNs), and the serve path
(prefill + decode) is exercised. The FULL configs are exercised only via
the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, CNN_SMOKES, get_config, get_smoke
from repro.distributed import StepConfig, make_train_state, make_train_step
from repro.nn.conv import cnn_forward, cnn_loss, init_cnn
from repro.nn.models import build_model, decoder_schedule


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    B, S = 2, 24
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab, (B, S + 1)), jnp.int32)}
    if cfg.family == "vlm":
        batch["extra_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)
    if cfg.family == "encdec":
        batch = {"tokens": batch["tokens"],
                 "src_embeds": jnp.asarray(
                     rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)}
    step = jax.jit(make_train_step(model, StepConfig(warmup_steps=1,
                                                     total_steps=10)))
    new_state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # params actually moved
    delta = jax.tree_util.tree_reduce(
        lambda a, b: a + float(jnp.abs(b).sum()),
        jax.tree.map(lambda a, b: a - b, new_state["params"],
                     state["params"]), 0.0)
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 16
    toks = jnp.zeros((B, S), jnp.int32)
    if cfg.family == "encdec":
        src = jnp.zeros((B, 8, cfg.d_model))
        logits = model.forward(params, src, toks)
        assert logits.shape == (B, S, cfg.vocab)
    elif cfg.family == "vlm":
        extra = jnp.zeros((B, cfg.frontend_tokens, cfg.d_model))
        logits, _ = model.forward(params, toks, extra)
        assert logits.shape == (B, S + cfg.frontend_tokens, cfg.vocab)
    else:
        logits, _ = model.forward(params, toks)
        assert logits.shape == (B, S, cfg.vocab)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_serve_consistency(arch):
    """prefill(t[:S-1]) + decode(t[S-1]) == forward(t)[-1] for every family
    (MoE archs: run with a high capacity factor so no token is dropped —
    capacity-dropping legitimately differs between S-token and 1-token
    routing; that semantics is covered in test_layers)."""
    cfg = get_smoke(arch).with_overrides(capacity_factor=16.0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    rng = np.random.default_rng(2)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32)
    if cfg.family == "encdec":
        src = jnp.asarray(rng.normal(size=(B, 8, cfg.d_model)), jnp.float32)
        full = model.forward(params, src, toks)
        cache = model.init_cache(B, S + 4, cross_len=8, dtype=jnp.float32)
        pre, cache = model.prefill(params, src, toks[:, :S - 1], cache)
        np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, S - 2]),
                                   rtol=2e-4, atol=2e-4)
        dec, _ = model.decode_step(params, toks[:, S - 1], cache,
                                   jnp.int32(S - 1))
        np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, S - 1]),
                                   rtol=2e-4, atol=2e-4)
        return
    extra = None
    n_extra = 0
    if cfg.family == "vlm":
        extra = jnp.asarray(
            rng.normal(size=(B, cfg.frontend_tokens, cfg.d_model)),
            jnp.float32)
        n_extra = cfg.frontend_tokens
    full, _ = model.forward(params, toks, extra)
    cache = model.init_cache(B, n_extra + S + 4, dtype=jnp.float32)
    pre, cache = model.prefill(params, toks[:, :S - 1], cache,
                               extra_embeds=extra)
    np.testing.assert_allclose(
        np.asarray(pre), np.asarray(full[:, n_extra + S - 2]),
        rtol=3e-4, atol=3e-4)
    dec, _ = model.decode_step(params, toks[:, S - 1], cache,
                               jnp.int32(n_extra + S - 1))
    np.testing.assert_allclose(
        np.asarray(dec), np.asarray(full[:, n_extra + S - 1]),
        rtol=3e-4, atol=3e-4)


def test_assigned_geometry_exact():
    """The registered FULL configs carry exactly the assigned geometry."""
    want = {
        "llava-next-34b": (60, 7168, 56, 8, 20480, 64000),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "starcoder2-3b": (30, 3072, 24, 2, 12288, 49152),
        "gemma-7b": (28, 3072, 16, 16, 24576, 256000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    }
    for name, (L, d, nq, nkv, ff, v) in want.items():
        c = get_config(name)
        assert (c.n_layers, c.d_model, c.n_q, c.n_kv, c.d_ff, c.vocab) == \
            (L, d, nq, nkv, ff, v), name
    m = get_config("mamba2-130m")
    assert (m.n_layers, m.d_model, m.vocab, m.ssm_d_state) == \
        (24, 768, 50280, 128)
    # MoE/hybrid structure markers
    assert get_config("arctic-480b").n_experts == 128
    assert get_config("arctic-480b").top_k == 2
    assert get_config("arctic-480b").dense_residual
    assert get_config("llama4-maverick-400b-a17b").top_k == 1
    assert get_config("jamba-1.5-large-398b").n_experts == 16
    # jamba 1:7 attention interleave
    slots, np_ = decoder_schedule(get_config("jamba-1.5-large-398b"))
    assert len(slots) == 8 and np_ == 9
    assert [s.mixer for s in slots].count("attn") == 1
    assert slots[4].mixer == "attn"


def test_long500k_gating():
    """long_500k runs only for the sub-quadratic archs (DESIGN.md §5)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        has_long = "long_500k" in cfg.shapes
        assert has_long == (arch in ("mamba2-130m", "jamba-1.5-large-398b"))


@pytest.mark.parametrize("name", sorted(CNN_SMOKES))
def test_cnn_smoke(name):
    cfg = CNN_SMOKES[name]
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    B = 2
    imgs = jnp.asarray(rng.normal(size=(B,) + cfg.input_hw + (
        cfg.layers[0].M,)), jnp.float32)
    logits = cnn_forward(params, imgs, cfg)
    assert logits.shape == (B, cfg.n_classes)
    assert bool(jnp.isfinite(logits).all())
    loss, mets = cnn_loss(params, {"images": imgs,
                                   "labels": jnp.zeros((B,), jnp.int32)}, cfg)
    g = jax.grad(lambda p: cnn_loss(p, {"images": imgs, "labels":
                                        jnp.zeros((B,), jnp.int32)},
                                    cfg)[0])(params)
    gn = jax.tree_util.tree_reduce(lambda a, b: a + float(jnp.abs(b).sum()),
                                   g, 0.0)
    assert np.isfinite(float(loss)) and gn > 0
