"""The sub-8-bit MSR weight lane (DESIGN.md §9.3).

Covers: the MSR codec against a pure-Python per-weight oracle
(compress/decompress, the ``w_hat == w5 << e`` operand factorization, the
5-bit pack/unpack byte stream), the requant fold theorem
(``requant(psum << e, m, s) == requant(psum, m, s - e)`` — exact on the
int64 reference), the planned lane end-to-end (``forward_int5`` with
calibrated pairs bit-identical to ``forward_int8`` run on the decompressed
weights, across substrates and through the AOT serving executable), the
plan/tuner plumbing (``w_bits=5`` plans, the ``... w5`` cache-key axis),
the emulate_hw access model (int5 weight traffic == exactly 5/8 of int8),
and the accuracy smoke: a small trained CNN where the compensated int5
lane's top-1 must stay within a fixed margin of the int8 lane's.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.trim.model import (PAPER_ENGINE, VGG16_LAYERS, ConvLayerSpec,
                                   trim_memory_accesses)
from repro.core.trim.quant import (MSR_CODE_BITS, MSR_OPERAND_MAX,
                                   MSR_STORAGE_BITS, fold_shift_into_requant,
                                   msr_compress, msr_decompress, msr_operand,
                                   pack_int5, packed_nbytes, unpack_int5)
from repro.engine import ExecutionPolicy, executable_for, execute, plan_model
from repro.engine.autotune import layer_key
from repro.kernels.requant import requant_ref_int64
from repro.nn.conv import CNNConfig

# A tiny stack that still exercises pooling, grouped towers, and stride-2.
# (No pool after the last layer: the integer forwards return the final
# int32 psums pre-pool, and the accuracy smoke compares features.)
INT5_CNN = CNNConfig(
    "int5-smoke",
    layers=(
        ConvLayerSpec("CL1", 12, 12, 3, 3, 8, stride=1, pad=1),
        ConvLayerSpec("CL2", 6, 6, 3, 4, 8, stride=1, pad=1),   # groups=2
        ConvLayerSpec("CL3", 6, 6, 3, 8, 8, stride=2, pad=1),
    ),
    pool_after=(0,), classifier=(16,), n_classes=4, input_hw=(12, 12))


def _rand_w(shape, seed=0, lo=-127, hi=127):
    return np.random.default_rng(seed).integers(lo, hi + 1, shape
                                                ).astype(np.int8)


# ---------------------------------------------------------------------------
# codec vs the pure-Python oracle
# ---------------------------------------------------------------------------


def _msr_oracle(w):
    """Per-weight Python ints only — the contract, restated independently."""
    w = np.asarray(w, np.int64)
    flat = w.reshape(-1, w.shape[-1])
    shifts, codes = [], np.zeros_like(flat)
    for c in range(flat.shape[1]):
        t = max(0, int(np.abs(flat[:, c]).max()).bit_length() - MSR_CODE_BITS)
        shifts.append(t)
        for r in range(flat.shape[0]):
            v = int(flat[r, c])
            codes[r, c] = (1 if v > 0 else -1 if v < 0 else 0) * (abs(v) >> t)
    return (codes.reshape(w.shape).astype(np.int8),
            np.asarray(shifts, np.int32))


def _pack_oracle(codes):
    """Bit-string packing oracle: sign bit + 4 magnitude bits, MSB-first."""
    bits = ""
    for v in np.asarray(codes, np.int64).reshape(-1):
        bits += "1" if v < 0 else "0"
        bits += format(abs(int(v)), f"0{MSR_CODE_BITS}b")
    bits += "0" * (-len(bits) % 8)
    return np.asarray([int(bits[i:i + 8], 2) for i in range(0, len(bits), 8)],
                      np.uint8)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_msr_compress_matches_python_oracle(seed):
    w = _rand_w((3, 3, 4, 8), seed)
    codes, shifts = msr_compress(w)
    ocodes, oshifts = _msr_oracle(w)
    np.testing.assert_array_equal(codes, ocodes)
    np.testing.assert_array_equal(shifts, oshifts)
    assert int(np.abs(codes).max()) < (1 << MSR_CODE_BITS)
    assert shifts.min() >= 0 and shifts.max() <= 8 - MSR_CODE_BITS - 1


def test_msr_compress_small_channels_are_lossless():
    """Channels whose magnitudes already fit 4 bits keep t=0 and survive
    the round trip exactly, compensated or not."""
    w = _rand_w((3, 3, 2, 4), 3, lo=-15, hi=15)
    codes, shifts = msr_compress(w)
    np.testing.assert_array_equal(shifts, 0)
    for comp in (True, False):
        np.testing.assert_array_equal(msr_decompress(codes, shifts, comp), w)


@pytest.mark.parametrize("compensate", [True, False])
def test_msr_decompress_matches_python_oracle(compensate):
    w = _rand_w((5, 5, 3, 6), 4)
    codes, shifts = msr_compress(w)
    w_hat = msr_decompress(codes, shifts, compensate)
    for c in range(w.shape[-1]):
        t = int(shifts[c])
        for v, vh in zip(codes[..., c].reshape(-1).tolist(),
                         w_hat[..., c].reshape(-1).tolist()):
            mag = abs(v) << t
            if compensate and v != 0 and t > 0:
                mag |= 1 << (t - 1)
            assert vh == (1 if v > 0 else -1 if v < 0 else 0) * mag
    # compensation never leaves the int8 domain: |code| <= 15 so
    # (15 << 3) | 4 == 124 <= 127
    assert int(np.abs(w_hat.astype(np.int32)).max()) <= 127


@pytest.mark.parametrize("compensate", [True, False])
def test_msr_operand_factorization_is_exact(compensate):
    w = _rand_w((3, 3, 8, 16), 5)
    codes, shifts = msr_compress(w)
    w5, e = msr_operand(codes, shifts, compensate)
    w_hat = msr_decompress(codes, shifts, compensate)
    np.testing.assert_array_equal(w5.astype(np.int32) << e, w_hat)
    assert int(np.abs(w5.astype(np.int32)).max()) <= MSR_OPERAND_MAX


@pytest.mark.parametrize("n", [1, 7, 8, 9, 1152])
def test_pack_unpack_roundtrip_and_oracle(n):
    codes = np.random.default_rng(n).integers(-15, 16, n).astype(np.int8)
    packed = pack_int5(codes)
    assert packed.nbytes == packed_nbytes(n) == (n * MSR_STORAGE_BITS + 7) // 8
    np.testing.assert_array_equal(packed, _pack_oracle(codes))
    np.testing.assert_array_equal(unpack_int5(packed, n), codes)


def test_pack_rejects_out_of_range_codes():
    with pytest.raises(ValueError):
        pack_int5(np.asarray([16], np.int8))


# ---------------------------------------------------------------------------
# the requant fold theorem
# ---------------------------------------------------------------------------


def test_fold_shift_into_requant_is_exact():
    psum = np.random.default_rng(0).integers(-(1 << 20), 1 << 20, 4096)
    for m, s, e in [(16384, 20, 0), (16384, 20, 2), (123, 7, 2),
                    (32767, 9, 3), (1, 31, 3)]:
        mf, sf = fold_shift_into_requant(np.asarray(m), np.asarray(s),
                                         np.asarray(e))
        np.testing.assert_array_equal(
            requant_ref_int64(psum << e, m, s),
            requant_ref_int64(psum, int(mf), int(sf)))


def test_fold_shift_saturates_at_domain_bounds():
    """When s - e < 1 the residue moves into the multiplier, saturating at
    the int16 bound; the returned pair stays in the kernel's domain."""
    mf, sf = fold_shift_into_requant(np.asarray(30000), np.asarray(2),
                                     np.asarray(3))
    assert int(mf) == 32767 and int(sf) == 1


# ---------------------------------------------------------------------------
# the planned lane, end-to-end
# ---------------------------------------------------------------------------


def _quantized(plan, seed=0, compensate=True):
    params = plan.init(jax.random.PRNGKey(seed))
    imgs = jnp.asarray(np.random.default_rng(seed).integers(
        0, 256, (4, 12, 12, 3), np.uint8))
    qp5, _ = plan.quantize_int5(params, compensate=compensate)
    return params, imgs, qp5


@pytest.mark.parametrize("substrate", ["oracle", "f32exact"])
def test_forward_int5_matches_decompressed_int8(substrate):
    """The int5 lane with e folded into the calibrated pairs must be
    bit-identical to the int8 lane run on the decompressed weights
    w_hat = w5 << e with the exponent left on the requant shift."""
    plan = plan_model(INT5_CNN, ExecutionPolicy(substrate=substrate))
    _, imgs, qp5 = _quantized(plan)
    pairs5 = plan.calibrate_requant_int5(qp5, imgs)
    out5 = plan.forward_int5(qp5, imgs, requant=pairs5)

    qp8 = {"conv": []}
    pairs8 = []
    for i, p in enumerate(qp5["conv"]):
        e = np.asarray(p["shift"])
        qp8["conv"].append({"kernel": jnp.asarray(
            np.asarray(p["kernel"], np.int32) << e).astype(jnp.int8)})
        if i < len(qp5["conv"]) - 1:
            m, s = pairs5[i]
            pairs8.append((m, s + jnp.asarray(e, jnp.int32)))
    out8 = plan.forward_int8(qp8, imgs, requant=pairs8)
    # identical final full-scale psums: forward_int5's last layer restores
    # the exponent (psum5 << e == conv(x, w5 << e)) before returning
    np.testing.assert_array_equal(np.asarray(out5), np.asarray(out8))


def test_forward_int5_dynamic_requant_runs():
    plan = plan_model(INT5_CNN, ExecutionPolicy())
    _, imgs, qp5 = _quantized(plan)
    out = plan.forward_int5(qp5, imgs)
    assert out.dtype == jnp.int32 and np.isfinite(np.asarray(out)).all()


def test_executable_for_int5_bit_identical():
    """The AOT serving executable (datapath="int5") reproduces the direct
    forward_int5 bit-for-bit."""
    plan = plan_model(INT5_CNN, ExecutionPolicy())
    _, imgs, qp5 = _quantized(plan)
    pairs = plan.calibrate_requant_int5(qp5, imgs)
    ex = executable_for(plan, 4, "int5")
    np.testing.assert_array_equal(
        np.asarray(ex(qp5, imgs, pairs)),
        np.asarray(plan.forward_int5(qp5, imgs, requant=pairs)))


def test_plan_model_int5_carries_w_bits():
    plan5 = plan_model(INT5_CNN, ExecutionPolicy(), datapath="int5")
    plan8 = plan_model(INT5_CNN, ExecutionPolicy(), datapath="int8")
    for lp5, lp8 in zip(plan5.layers, plan8.layers):
        assert lp5.w_bits == 5 and lp8.w_bits == 8
        assert lp5.describe()["w_bits"] == 5
        assert "w_bits" not in lp8.describe()
    # the int5 sibling property agrees with the explicit datapath
    assert plan_model(INT5_CNN, ExecutionPolicy()).int5.layers == plan5.layers


def test_layer_key_has_w_bits_axis():
    kw = dict(stride=1, padding=1, groups=1, relu=True, has_bias=False,
              requant_kind="mult_shift", in_sz=1, w_sz=1, out_sz=1,
              emulate_hw=False)
    k8 = layer_key((12, 12), 8, 3, 8, **kw)
    k5 = layer_key((12, 12), 8, 3, 8, w_bits=5, **kw)
    assert k8.endswith(" w8") and k5.endswith(" w5") and k8 != k5


def test_emulate_hw_int5_weight_traffic_is_five_eighths():
    """The access model counts in B-bit element units, so the 5-bit stored
    lane ships exactly 5/8 of the int8 lane's weight reads — and identical
    ifmap/ofmap traffic (MSR touches only weight storage)."""
    for layer in (VGG16_LAYERS[0], VGG16_LAYERS[7], INT5_CNN.layers[1]):
        base = trim_memory_accesses(layer, PAPER_ENGINE)
        msr = trim_memory_accesses(layer, PAPER_ENGINE, weight_bits=5)
        assert msr.weight_reads == base.weight_reads * 5 / 8
        assert msr.ifmap_reads == base.ifmap_reads
        assert msr.ofmap_writes == base.ofmap_writes
    with pytest.raises(ValueError):
        trim_memory_accesses(VGG16_LAYERS[0], PAPER_ENGINE, weight_bits=9)


# ---------------------------------------------------------------------------
# accuracy smoke: fp32 vs fused-int8 vs int5 (compensated + truncated)
# ---------------------------------------------------------------------------


def _head(params, feat):
    x = feat
    for j, fc in enumerate(params["fc"]):
        x = x @ fc["kernel"] + fc["bias"]
        if j < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x


def _conv_features(plan, params, imgs):
    x = imgs
    for i, lp in enumerate(plan.layers):
        x = execute.run_conv_layer(lp, params["conv"][i], x)
    return x.reshape(x.shape[0], -1)


def _int_top1(plan, params, qp, requant, imgs_u8, feat_float, eval_u8,
              forward):
    """Top-1 of an integer lane: fit one scalar gain from the calibration
    batch's integer features onto the float features (least squares), then
    reuse the trained FC head."""
    feat_cal = np.asarray(forward(qp, imgs_u8, requant=requant)
                          ).reshape(imgs_u8.shape[0], -1).astype(np.float64)
    g = float((feat_cal * feat_float).sum() / (feat_float ** 2).sum())
    feat = np.asarray(forward(qp, eval_u8, requant=requant)
                      ).reshape(eval_u8.shape[0], -1) / g
    return _head(params, jnp.asarray(feat, jnp.float32))


def test_int5_accuracy_within_margin_of_int8():
    """Train the tiny CNN on the synthetic image stream (inputs pre-mapped
    to exact u8 grid points so input quantization is lossless), then
    compare top-1 across the lanes.  The compensated int5 lane must stay
    within a fixed margin of int8; the truncation ablation runs for free
    as the compensate=False arm."""
    from repro.data import SyntheticImageDataset

    ds = SyntheticImageDataset(hw=(12, 12), channels=3, n_classes=4,
                               global_batch=64)

    def u8_batch(step):
        b = ds.batch_at(step)
        u8 = np.round(np.clip((b["images"] + 2.0) * 63.75, 0, 255))
        return u8.astype(np.uint8), b["labels"]

    plan = plan_model(INT5_CNN, ExecutionPolicy())
    params = plan.init(jax.random.PRNGKey(0))

    @jax.jit
    def step(p, batch):
        (ce, _), g = jax.value_and_grad(plan.loss, has_aux=True)(p, batch)
        return jax.tree_util.tree_map(lambda x, dx: x - 0.05 * dx, p, g), ce

    for s in range(120):
        u8, labels = u8_batch(s)
        batch = {"images": jnp.asarray(u8, jnp.float32) / 255.0,
                 "labels": jnp.asarray(labels)}
        params, ce = step(params, batch)

    cal_u8, _ = u8_batch(200)
    eval_u8, eval_labels = u8_batch(300)
    cal_u8, eval_u8 = jnp.asarray(cal_u8), jnp.asarray(eval_u8)
    feat_float = np.asarray(_conv_features(
        plan, params, cal_u8.astype(jnp.float32) / 255.0)).astype(np.float64)

    logits_f = plan.forward(params, eval_u8.astype(jnp.float32) / 255.0)
    acc = {"fp32": float((np.asarray(logits_f).argmax(-1) == eval_labels
                          ).mean())}

    qp8, _ = plan.quantize(params)
    rq8 = plan.calibrate_requant(qp8, cal_u8)
    logits = _int_top1(plan, params, qp8, rq8, cal_u8, feat_float, eval_u8,
                       plan.forward_int8)
    acc["int8"] = float((np.asarray(logits).argmax(-1) == eval_labels).mean())

    for name, comp in (("int5", True), ("int5_trunc", False)):
        qp5, _ = plan.quantize_int5(params, compensate=comp)
        rq5 = plan.calibrate_requant_int5(qp5, cal_u8)
        logits = _int_top1(plan, params, qp5, rq5, cal_u8, feat_float,
                           eval_u8, plan.forward_int5)
        acc[name] = float((np.asarray(logits).argmax(-1) == eval_labels
                           ).mean())

    print("accuracy smoke:", acc)
    assert acc["fp32"] >= 0.75, acc
    assert acc["int8"] >= acc["fp32"] - 0.20, acc
    # the lane under test: expect-value compensation keeps the 5-bit lane
    # within a fixed margin of the full int8 lane
    assert acc["int5"] >= acc["int8"] - 0.15, acc
