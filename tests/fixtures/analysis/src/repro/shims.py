"""Corpus: deprecation-shim hygiene, one seeded violation.

The dangling docs citation also lives here: DESIGN.md §99 names a section
the corpus DESIGN.md does not have (SEED docs-section-ref).
"""

import warnings


def old_entry_point(*args, **kwargs):
    """Deprecated: use ``new_entry_point``."""
    # SEED hygiene-deprecation-warns: documented Deprecated, never warns
    return new_entry_point(*args, **kwargs)


def good_shim(*args, **kwargs):
    """Deprecated: use ``new_entry_point`` (correct shim — not flagged)."""
    warnings.warn(
        "good_shim is deprecated; use new_entry_point",
        DeprecationWarning,
        stacklevel=2,
    )
    return new_entry_point(*args, **kwargs)


def new_entry_point(*args, **kwargs):
    """The replacement (see DESIGN.md §1 for the corpus architecture)."""
    return (args, tuple(sorted(kwargs)))
