"""Corpus: the three Pallas-contract violations, one each (never run)."""

import jax.numpy as jnp
from jax.experimental import pallas as pl

state = []


def _bad_kernel(x_ref, o_ref):
    # SEED pallas-int64: int64 dtype inside a kernel body
    o_ref[...] = x_ref[...].astype(jnp.int64)


def bad_call(x):
    return pl.pallas_call(
        _bad_kernel,
        grid=(4,),
        in_specs=[
            # SEED pallas-index-map: the map calls into mutable state
            pl.BlockSpec((8, 8), index_map=lambda i: (state.pop(), 0)),
        ],
        out_specs=pl.BlockSpec((8, 8), index_map=lambda i: (i, 0)),
        # SEED pallas-scratch-shape: an array value, not a declaration
        scratch_shapes=[jnp.zeros((8, 8), jnp.float32)],
        out_shape=jnp.zeros((8, 8), jnp.float32),
    )(x)
