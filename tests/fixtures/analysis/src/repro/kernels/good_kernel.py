"""Corpus: contract-clean Pallas counterpart in the repo's idiom — all
three index-map spellings (inline lambda, named def, factory-returned
lambda), declaration-style scratch, int32-only kernel arithmetic."""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

INT32_MASK = 2**31 - 1  # the largest int32 literal a kernel may carry


def _good_kernel(x_ref, o_ref, acc_ref):
    acc_ref[...] = (x_ref[...] & INT32_MASK).astype(jnp.int32)
    o_ref[...] = acc_ref[...]


def _out_idx(i, j):
    return (i, j)


def _shifted_idx(dh):
    """Factory in the trim_conv2d style: closes over a static offset."""
    return lambda i, j: (i + dh, j)


def _scratch(shape, dtype):
    """Declaration-style scratch helper (the trim_conv2d idiom): names a
    shape+dtype, builds no array."""
    return pl.BlockSpec(shape, None), dtype


def good_call(x):
    return pl.pallas_call(
        _good_kernel,
        grid=(4, 4),
        in_specs=[
            pl.BlockSpec((8, 8), index_map=_shifted_idx(1)),
        ],
        out_specs=pl.BlockSpec((8, 8), index_map=_out_idx),
        scratch_shapes=[_scratch((8, 8), jnp.int32)],
        out_shape=jax.ShapeDtypeStruct((32, 32), jnp.int32),
    )(x)
