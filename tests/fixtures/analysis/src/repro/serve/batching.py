"""Corpus: clean lock-discipline counterpart (no findings expected)."""

import threading


class BucketBatcher:
    def __init__(self):
        self._lock = threading.Lock()
        self._q = []
        self._last_t = 0.0
        self._n_deadlined = 0
        self._rid = iter(range(1 << 30))

    @property
    def depth(self):
        with self._lock:
            return len(self._q)

    def submit(self, payload, now):
        with self._lock:
            self._last_t = max(self._last_t, now)
            self._q.append(payload)
            return next(self._rid)

    def poll_safe(self, metrics):
        """Broad handlers that re-raise or record are disciplined."""
        try:
            with self._lock:
                return self._q.pop()
        except IndexError:  # narrow catch: deliberate control flow, fine
            return None
        except Exception as err:
            metrics.record_failed()  # records before swallowing: fine
            raise err
