"""Corpus: clean lock-discipline counterpart (no findings expected)."""

import threading


class BucketBatcher:
    def __init__(self):
        self._lock = threading.Lock()
        self._q = []
        self._last_t = 0.0
        self._n_deadlined = 0
        self._rid = iter(range(1 << 30))

    @property
    def depth(self):
        with self._lock:
            return len(self._q)

    def submit(self, payload, now):
        with self._lock:
            self._last_t = max(self._last_t, now)
            self._q.append(payload)
            return next(self._rid)
