"""Corpus: the three lock-ownership violations, one each (never run)."""

import threading
import time


class Server:
    """Mirrors the real Server's lock contract (cls/lock_attr match the
    DEFAULT_LOCK_MAP so the corpus runs under the default Config)."""

    def __init__(self):
        self._cv = threading.Condition()
        self._running = False
        self._draining = False
        self._closed = False
        self._worker = None
        self.requests = []

    def is_running(self):
        return self._running  # SEED lock-guarded-attr: read outside the cv

    def wait_once(self):
        with self._cv:
            self._cv.wait(0.1)  # SEED lock-wait-while: no enclosing while

    def stall(self):
        with self._cv:
            time.sleep(0.1)  # SEED lock-blocking-call: sleep under the cv

    def good_paths(self):
        """The disciplined versions of all three — must NOT be flagged."""
        with self._cv:
            while not self._running:
                self._cv.wait(0.1)
            self._draining = True
        time.sleep(0.0)  # blocking OUTSIDE the cv is fine

    def flush_once(self, batch):
        try:
            return list(batch)
        except Exception:  # SEED silent-except: swallowed, never recorded
            return None
