"""Corpus: trace-safe counterparts — none of these may be flagged."""

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("flavor", "relu"))
def static_branching(x, bias=None, flavor="relu", relu=True):
    if flavor == "relu":  # truthiness on a STATIC is the point of statics
        x = jnp.maximum(x, 0.0)
    if bias is not None:  # `is None` checks resolve at trace time
        x = x + bias
    if relu:  # bare truthiness on a static parameter
        x = jnp.maximum(x, 0.0)
    return x


@functools.lru_cache(maxsize=None)
def cached_on_statics(stride: int, padding: int, relu: bool):
    return (stride, padding, relu)


@functools.partial(jax.jit, static_argnames=("shape",))
def tuple_default(x, shape=(1, 1)):
    return jnp.reshape(x, shape)


def host_side(x):
    # concretization OUTSIDE any jitted/kernel body is host code: fine.
    return float(x)
