"""Corpus: the four trace-safety violations, one each (never run)."""

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("flavor",))
def truthy(x, flavor="relu"):
    if x:  # SEED trace-truthiness: truthiness on a traced parameter
        return jnp.maximum(x, 0.0)
    return x


@jax.jit
def concretizing(x):
    return jnp.full((4,), float(x))  # SEED trace-concretize: float(traced)


@functools.lru_cache(maxsize=None)
def cached_on_array(x: jnp.ndarray, scale: float):
    # SEED trace-lru-array: lru_cache keyed on an array argument
    return x * scale


@functools.partial(jax.jit, static_argnames=("shape",))
def mutable_static(x, shape, pads=[0, 0]):
    # SEED trace-mutable-default: list default on a jitted function
    return jnp.pad(x, pads), shape
