"""Corpus: the suppression mechanism itself, one seeded violation.

``quiet_shim`` omits its DeprecationWarning but carries a REASONED
suppression directly above the def — the hygiene rule must stay silent.
``reasonless`` carries a reason-free disable, which is itself the
seeded finding (suppress-needs-reason); there is deliberately no other
violation near it, so this file contributes exactly one finding.
"""


# trimcheck: disable=hygiene-deprecation-warns -- corpus fixture: shows a
# reasoned suppression silencing the rule at the def it covers.
def quiet_shim(x):
    """Deprecated: kept only for the corpus."""
    return x


def reasonless(x):
    # trimcheck: disable=lock-guarded-attr
    return x
