"""Analytical-model tests: the paper's equations (1)-(4) and the printed
Table I / II / Fig. 7 values."""
import math

import pytest

from repro.core.trim.model import (ALEXNET_LAYERS, PAPER_ENGINE,
                                   PAPER_TABLE1_TRIM, PAPER_TABLE2_TRIM,
                                   VGG16_LAYERS, TrimEngineConfig,
                                   engine_cycles, eyeriss_rs_memory_accesses,
                                   io_bandwidth_bits, layer_gops, layer_ops,
                                   network_gops, psum_buffer_bits,
                                   steady_pe_activity, trim_memory_accesses)
from repro.core.trim.explore import derive_fpga_parameters, explore


def test_peak_throughput_exact():
    # §V: 1512 PEs at 150 MHz -> 453.6 GOPs/s
    assert PAPER_ENGINE.n_pes == 1512
    assert PAPER_ENGINE.peak_gops == pytest.approx(453.6)


def test_eq1_ops():
    l = VGG16_LAYERS[1]  # 224x224, K=3, 64->64
    assert layer_ops(l) == 2 * 9 * 224 * 224 * 64 * 64


@pytest.mark.parametrize("layer", VGG16_LAYERS, ids=lambda l: l.name)
def test_table1_gops_per_layer(layer):
    """Every printed VGG-16 GOPs/s value reproduced within 1.5%."""
    want = PAPER_TABLE1_TRIM[layer.name][0]
    assert layer_gops(layer) == pytest.approx(want, rel=0.015)


def test_table1_network_totals():
    assert network_gops(VGG16_LAYERS) == pytest.approx(391.0, rel=0.01)


@pytest.mark.parametrize("layer", ALEXNET_LAYERS, ids=lambda l: l.name)
def test_table2_gops_per_layer(layer):
    """AlexNet layers (incl. the 11x11 tiled + stride-4 CL1 and 5x5 CL2)
    within 2.5% of the printed values."""
    want = PAPER_TABLE2_TRIM[layer.name][0]
    assert layer_gops(layer) == pytest.approx(want, rel=0.025)


def test_table2_pe_activity():
    # paper Table II "PE Util.": CL1 1.00 (tile-packed slices), CL2 0.57
    # (4 of 7 cores); VGG CL1 0.13 (3 of 24 slices)
    acts = {l.name: steady_pe_activity(l) for l in ALEXNET_LAYERS}
    assert acts["CL2"] == pytest.approx(0.57, abs=0.02)
    assert acts["CL1"] == pytest.approx(1.0)
    assert steady_pe_activity(VGG16_LAYERS[0]) == pytest.approx(0.13,
                                                                abs=0.01)


def test_eq3_psum_buffer():
    # §V: P_N = 7 buffers of 224*224*32b fit the XCZU7EV's 312 36-Kb BRAMs
    bits = psum_buffer_bits(PAPER_ENGINE, 224, 224)
    assert bits == 7 * 224 * 224 * 32
    assert bits <= 312 * 36 * 1024     # the device BRAM budget


def test_eq4_io_bandwidth():
    # (24*5 + 7) * 8 = 1016 bits -> rounded to 1024 in §V
    assert io_bandwidth_bits(PAPER_ENGINE) == 1016


def test_fig7_best_case():
    pts = {(p.P_N, p.P_M): p for p in explore()}
    best = pts[(24, 24)]
    assert best.gops == pytest.approx(1243, rel=0.02)  # §IV best case
    # equal-PE pairs have ~equal throughput but 4x different psum buffers
    a, b = pts[(4, 16)], pts[(16, 4)]
    assert a.n_pes == b.n_pes == 576
    assert a.gops == pytest.approx(b.gops, rel=0.02)
    assert b.psum_buffer_Mb == pytest.approx(4 * a.psum_buffer_Mb)
    # and the 4-core config needs more I/O bandwidth (more slices/core)
    assert a.io_bandwidth_bits > 2 * b.io_bandwidth_bits


def test_derive_fpga_parameters():
    # §V sizing procedure lands exactly on the paper's (P_N, P_M) = (7, 24)
    assert derive_fpga_parameters() == (7, 24)


def test_trim_vs_baselines_memory_ordering():
    """The paper's headline claims, from first principles:
    - ~9x fewer input fetches PER ENGINE PASS than Conv-to-GeMM (the im2col
      operand replicates every element K^2 times; TrIM fetches each padded
      element once — §I/§II "one order of magnitude");
    - >=2.5x fewer TOTAL accesses than Eyeriss-RS on VGG-16 (~3x, §V)."""
    from repro.core.trim.model import trim_input_fetches
    l = VGG16_LAYERS[1]
    im2col_per_pass = l.K * l.K * l.H_O * l.W_O
    trim_per_pass = trim_input_fetches(l)
    ratio = im2col_per_pass / trim_per_pass
    assert 8.0 < ratio < 9.2   # 9x minus the 1.8% padding overhead

    t_tot = sum(trim_memory_accesses(x, batch=3).total for x in VGG16_LAYERS)
    e_tot = sum(eyeriss_rs_memory_accesses(x, batch=3).total
                for x in VGG16_LAYERS)
    assert e_tot / t_tot > 1.5          # ordering, conservative 4 spad/MAC
    e_cal = sum(eyeriss_rs_memory_accesses(x, batch=3, spad_per_mac=6.8
                                           ).total for x in VGG16_LAYERS)
    assert e_cal / t_tot == pytest.approx(3.0, rel=0.15)  # the ~3x of §V
    # and our first-principles TrIM total is within 5% of the printed one
    assert t_tot == pytest.approx(864.06, rel=0.05)


def test_trim_input_overhead_1_8_percent():
    l = VGG16_LAYERS[0]
    acc = trim_memory_accesses(l)
    per_pass = acc.ifmap_reads * 1e6 / (l.M * math.ceil(l.N / 7))
    overhead = per_pass / (l.H_I * l.W_I) - 1
    assert overhead == pytest.approx(0.018, abs=0.002)  # §II "~1.8%"


def test_cycles_monotone_in_parallelism():
    l = VGG16_LAYERS[4]
    base = engine_cycles(l, TrimEngineConfig(P_N=1, P_M=1))
    fast = engine_cycles(l, TrimEngineConfig(P_N=8, P_M=16))
    assert fast < base
