"""nn substrate: attention cache-equivalence, MoE impl agreement, Mamba SSD
vs naive recurrence, quantization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trim.quant import psum_bit_width, quantize_activations_u8
from repro.nn.attention import (attn_layout, attention, flash_attention,
                                init_attention, init_kv_cache)
from repro.nn.mamba import (init_mamba, init_mamba_cache, mamba_dims,
                            mamba_mixer, ssd_chunked)
from repro.nn.moe import init_moe, moe


# -- attention ---------------------------------------------------------------

def _naive_attention(q, k, v, causal):
    # q (B,S,H,G,D), k/v (B,S,H,D)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / q.shape[-1] ** 0.5
    if causal:
        Sq, Sk = q.shape[1], k.shape[1]
        mask = jnp.tril(jnp.ones((Sq, Sk), bool), Sk - Sq)
        s = jnp.where(mask, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("chunk", [4, 16, 64])
def test_flash_matches_naive(causal, chunk):
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 24, 2, 3, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (2, 24, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (2, 24, 2, 8))
    out = flash_attention(q, k, v, causal=causal, chunk_k=chunk)
    want = _naive_attention(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_flash_block_causal_matches():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (1, 32, 2, 2, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 32, 2, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 32, 2, 8))
    a = flash_attention(q, k, v, causal=True, chunk_k=8, block_causal=False)
    b = flash_attention(q, k, v, causal=True, chunk_k=8, block_causal=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


@pytest.mark.parametrize("n_q,n_kv,tp", [(8, 2, 1), (8, 2, 4), (7, 7, 1),
                                         (56, 8, 16), (24, 2, 16)])
def test_layout_roundtrip_and_decode(n_q, n_kv, tp):
    """TP head layouts (incl. kv-repeat + group padding) keep train, prefill
    and decode numerically consistent."""
    D, d_model = 8, 32
    lay = attn_layout(n_q, n_kv, D, tp)
    assert lay.n_q_pad % max(tp, 1) == 0 or tp <= n_kv
    key = jax.random.PRNGKey(n_q * 100 + n_kv + tp)
    p = init_attention(key, d_model, n_q, n_kv, D)
    x = jax.random.normal(key, (2, 12, d_model))
    pos = jnp.broadcast_to(jnp.arange(12)[None], (2, 12))
    full, _ = attention(p, x, lay, positions=pos, mode="train")
    cache = init_kv_cache(2, 16, lay, jnp.float32)
    pre, cache = attention(p, x[:, :11], lay, positions=pos[:, :11],
                           mode="prefill", cache=cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :11]),
                               rtol=3e-5, atol=3e-5)
    dec, _ = attention(p, x[:, 11:12], lay, positions=pos[:, 11:12],
                       mode="decode", cache=cache, cache_pos=11)
    np.testing.assert_allclose(np.asarray(dec[:, 0]),
                               np.asarray(full[:, 11]), rtol=3e-5, atol=3e-5)


# -- MoE ----------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(k=st.integers(1, 3), cf=st.sampled_from([0.5, 1.0, 1.25, 4.0]),
       seed=st.integers(0, 1000))
def test_moe_gather_equals_einsum(k, cf, seed):
    """The production (sort/gather) dispatch and the GShard one-hot
    reference implement the SAME routing + drop policy."""
    key = jax.random.PRNGKey(seed)
    p = init_moe(key, 16, 32, 4)
    x = jax.random.normal(jax.random.fold_in(key, 1), (2, 12, 16))
    o1, _ = moe(p, x, top_k=k, capacity_factor=cf, impl="einsum")
    o2, _ = moe(p, x, top_k=k, capacity_factor=cf, impl="gather")
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=1e-4, atol=1e-5)


def test_moe_gradients_flow_both_impls():
    key = jax.random.PRNGKey(0)
    p = init_moe(key, 8, 16, 4, shared_expert=True)
    x = jax.random.normal(key, (2, 8, 8))
    for impl in ("einsum", "gather"):
        g = jax.grad(lambda pp: moe(pp, x, top_k=2, impl=impl)[0].sum())(p)
        total = jax.tree_util.tree_reduce(
            lambda a, b: a + float(jnp.abs(b).sum()), g, 0.0)
        assert np.isfinite(total) and total > 0


# -- Mamba (SSD) ----------------------------------------------------------------

def _ssd_naive(x, dt, A, B, C, D):
    Bb, L, H, P = x.shape
    G, S = B.shape[-2], B.shape[-1]
    rep = H // G
    h = np.zeros((Bb, H, P, S))
    Br = np.repeat(B, rep, axis=2)
    Cr = np.repeat(C, rep, axis=2)
    ys = []
    for t in range(L):
        h = h * np.exp(dt[:, t] * A)[..., None, None] + np.einsum(
            "bh,bhp,bhs->bhps", dt[:, t], x[:, t], Br[:, t])
        ys.append(np.einsum("bhs,bhps->bhp", Cr[:, t], h)
                  + x[:, t] * D[None, :, None])
    return np.stack(ys, 1), h


@settings(max_examples=8, deadline=None)
@given(L=st.integers(3, 50), chunk=st.sampled_from([4, 8, 16]),
       G=st.sampled_from([1, 2]), seed=st.integers(0, 100))
def test_ssd_chunked_matches_recurrence(L, chunk, G, seed):
    rng = np.random.default_rng(seed)
    Bb, H, P, S = 2, 4, 4, 8
    x = rng.normal(size=(Bb, L, H, P)).astype(np.float32)
    dt = rng.uniform(1e-3, 0.1, (Bb, L, H)).astype(np.float32)
    A = -rng.uniform(0.3, 2.0, (H,)).astype(np.float32)
    B = rng.normal(size=(Bb, L, G, S)).astype(np.float32)
    C = rng.normal(size=(Bb, L, G, S)).astype(np.float32)
    D = rng.normal(size=(H,)).astype(np.float32)
    y, h = ssd_chunked(jnp.array(x), jnp.array(dt), jnp.array(A),
                       jnp.array(B), jnp.array(C), jnp.array(D), chunk=chunk)
    y_ref, h_ref = _ssd_naive(x, dt, A, B, C, D)
    np.testing.assert_allclose(np.asarray(y), y_ref, rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h), h_ref, rtol=2e-4, atol=2e-4)


def test_mamba_prefill_decode_consistency():
    dims = mamba_dims(32, expand=2, headdim=8, d_state=16, n_groups=2,
                      d_conv=4, chunk=16)
    p = init_mamba(jax.random.PRNGKey(0), dims)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 21, 32))
    full, _ = mamba_mixer(p, u, dims, mode="train")
    cache = init_mamba_cache(2, dims)
    pre, cache = mamba_mixer(p, u[:, :20], dims, mode="prefill", cache=cache)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, :20]),
                               atol=1e-5)
    dec, cache = mamba_mixer(p, u[:, 20:21], dims, mode="decode", cache=cache)
    np.testing.assert_allclose(np.asarray(dec[:, 0]), np.asarray(full[:, 20]),
                               atol=1e-5)


# -- quantization ----------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 1000))
def test_quant_roundtrip(seed):
    rng = np.random.default_rng(seed)
    x = rng.normal(0, 2, (4, 4)).astype(np.float64)
    q, qp = quantize_activations_u8(x)
    err = np.abs(q.astype(np.float64) * qp.scale - qp.zero_point * qp.scale
                 - x).max()
    assert err <= qp.scale * 0.51 + 1e-9


def test_psum_bit_width_paper_case():
    # B=8, K=3, M<=512 -> 2*8+3+2+9 = 30 bits <= 32-bit buffers (eq. 3)
    assert psum_bit_width(8, 3, 24, 512) == 30
