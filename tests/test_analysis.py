"""trimcheck — the repo-native static-analysis suite (DESIGN.md §10).

Covers: the clean-tree guarantee (``python -m tools.analysis`` finds
nothing in this repo), the seeded-violation census (the corpus under
tests/fixtures/analysis yields EXACTLY one finding per rule), a
triggering + non-triggering fixture assertion for every rule, the
suppression mechanism (reasoned disables silence; reasonless disables
are themselves findings and silence nothing), the JSON/CLI contract, and
the runtime sanitizers (lock-order cycle detection, unguarded-attribute
access, retrace sentinel) on purpose-built violations.

The analyzer is stdlib-only; only the retrace-sentinel test touches jax.
"""
import json
import os
import pathlib
import threading

import pytest

from tools.analysis import RULES, SUPPRESS_RE
from tools.analysis.core import Config, LockSpec, run_analysis
from tools.analysis.runtime import (InstrumentedRLock, LockRegistry,
                                    sanitize_server)

REPO = str(pathlib.Path(__file__).resolve().parent.parent)
CORPUS = os.path.join(REPO, "tests", "fixtures", "analysis")


def corpus_findings(**overrides):
    return run_analysis(Config(root=CORPUS, **overrides))


def rules_of(findings):
    return sorted(f.rule for f in findings)


# ---------------------------------------------------------------------------
# the two headline guarantees: clean tree, one seeded finding per rule
# ---------------------------------------------------------------------------


def test_full_tree_is_clean():
    """The acceptance bar: the default run over THIS repo finds nothing.
    Any new finding is either a real violation (fix it) or an intentional
    exception (suppress it with a reason)."""
    findings = run_analysis(Config(root=REPO))
    assert findings == [], "\n".join(str(f) for f in findings)


def test_corpus_census_one_finding_per_rule():
    findings = corpus_findings()
    by_rule = {}
    for f in findings:
        by_rule.setdefault(f.rule, []).append(f)
    dupes = {r: fs for r, fs in by_rule.items() if len(fs) != 1}
    assert not dupes, f"rules with != 1 seeded finding: {dupes}"
    assert set(by_rule) == set(RULES), (
        f"missing seeds: {set(RULES) - set(by_rule)}; "
        f"unknown rules: {set(by_rule) - set(RULES)}"
    )


# ---------------------------------------------------------------------------
# per-rule triggering + non-triggering fixtures
# ---------------------------------------------------------------------------

#: rule -> (file that must trigger it, file that exercises the same
#: construct correctly and must NOT trigger it).
RULE_FIXTURES = {
    "lock-guarded-attr": ("src/repro/serve/server.py",
                          "src/repro/serve/batching.py"),
    "lock-wait-while": ("src/repro/serve/server.py",
                        "src/repro/serve/batching.py"),
    "lock-blocking-call": ("src/repro/serve/server.py",
                           "src/repro/serve/batching.py"),
    "trace-truthiness": ("src/repro/engine/bad_trace.py",
                         "src/repro/engine/good_trace.py"),
    "trace-concretize": ("src/repro/engine/bad_trace.py",
                         "src/repro/engine/good_trace.py"),
    "trace-lru-array": ("src/repro/engine/bad_trace.py",
                        "src/repro/engine/good_trace.py"),
    "trace-mutable-default": ("src/repro/engine/bad_trace.py",
                              "src/repro/engine/good_trace.py"),
    "pallas-index-map": ("src/repro/kernels/bad_kernel.py",
                         "src/repro/kernels/good_kernel.py"),
    "pallas-scratch-shape": ("src/repro/kernels/bad_kernel.py",
                             "src/repro/kernels/good_kernel.py"),
    "pallas-int64": ("src/repro/kernels/bad_kernel.py",
                     "src/repro/kernels/good_kernel.py"),
    "hygiene-deprecation-warns": ("src/repro/shims.py",
                                  "src/repro/suppressed.py"),
    "silent-except": ("src/repro/serve/server.py",
                      "src/repro/serve/batching.py"),
    "docs-link": ("DESIGN.md", "ROADMAP.md"),
    "docs-section-ref": ("src/repro/shims.py", "ROADMAP.md"),
    "suppress-needs-reason": ("src/repro/suppressed.py",
                              "src/repro/shims.py"),
}


def test_every_rule_has_fixture_pair():
    assert set(RULE_FIXTURES) == set(RULES)


@pytest.mark.parametrize("rule", sorted(RULES))
def test_rule_triggers_on_bad_and_not_on_good(rule):
    bad, good = RULE_FIXTURES[rule]
    findings = corpus_findings(select=(rule,))
    assert [f.path for f in findings] == [bad], (
        f"{rule}: expected exactly one finding in {bad}, got "
        f"{[(f.path, f.line) for f in findings]}"
    )
    assert not [f for f in findings if f.path == good]


def test_good_fixture_files_are_totally_clean():
    """The non-triggering counterparts are clean under EVERY rule, not
    just their own — good fixtures must not cross-trip other passes."""
    goods = {good for _, good in RULE_FIXTURES.values()}
    goods -= {bad for bad, _ in RULE_FIXTURES.values()}
    dirty = [f for f in corpus_findings() if f.path in goods]
    assert dirty == [], dirty


# ---------------------------------------------------------------------------
# suppressions
# ---------------------------------------------------------------------------


def test_reasoned_suppression_silences_rule():
    """suppressed.py's quiet_shim omits its DeprecationWarning but carries
    a reasoned disable — the hygiene rule stays silent there."""
    findings = corpus_findings(select=("hygiene-deprecation-warns",))
    assert all(f.path != "src/repro/suppressed.py" for f in findings)


def test_reasonless_suppression_is_a_finding_and_suppresses_nothing():
    findings = [
        f
        for f in corpus_findings(select=("suppress-needs-reason",))
        if f.path == "src/repro/suppressed.py"
    ]
    assert len(findings) == 1
    # a reasonless disable cannot silence its own finding
    assert findings[0].rule == "suppress-needs-reason"


def test_suppress_regex_shape():
    m = SUPPRESS_RE.search(
        "x = 1  # trimcheck: disable=lock-guarded-attr,pallas-int64 -- why"
    )
    assert m and m.group(1) == "lock-guarded-attr,pallas-int64"
    assert m.group(2) == "why"
    m2 = SUPPRESS_RE.search("# trimcheck: disable=pallas-int64")
    assert m2 and m2.group(2) is None


# ---------------------------------------------------------------------------
# CLI contract
# ---------------------------------------------------------------------------


def test_cli_json_output_and_exit_codes(capsys):
    from tools.analysis.__main__ import main

    rc = main(["--root", CORPUS, "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert out["count"] == len(out["findings"]) == len(RULES)
    sample = out["findings"][0]
    assert set(sample) == {"rule", "path", "line", "message"}
    # selection narrows; an unknown rule is a usage error
    assert main(["--root", CORPUS, "--select", "pallas-int64"]) == 1
    assert main(["--root", CORPUS, "--select", "no-such-rule"]) == 2
    capsys.readouterr()


def test_cli_clean_tree_exits_zero(capsys):
    from tools.analysis.__main__ import main

    assert main(["--root", REPO]) == 0
    assert "0 findings" in capsys.readouterr().out


def test_lock_map_is_config_overridable(tmp_path):
    """The guarded-attribute map is data, not code: pointing the pass at
    a different map flags a different attribute set."""
    src = tmp_path / "thing.py"
    src.write_text(
        "class Thing:\n"
        "    def peek(self):\n"
        "        return self._depth\n"
    )
    findings = run_analysis(
        Config(
            root=str(tmp_path),
            lock_map={
                "thing.py": (LockSpec("Thing", "_mu", ("_depth",)),)
            },
            trace_dirs=(),
            pallas_dirs=(),
            hygiene_dirs=(),
            docs=False,
        )
    )
    assert rules_of(findings) == ["lock-guarded-attr"]


# ---------------------------------------------------------------------------
# runtime sanitizers
# ---------------------------------------------------------------------------


def test_lock_registry_detects_order_inversion():
    reg = LockRegistry()
    a = InstrumentedRLock("A", reg)
    b = InstrumentedRLock("B", reg)
    with a:
        with b:
            pass
    assert reg.errors == []
    with b:
        with a:  # closes the A->B / B->A cycle
            pass
    assert any("cycle" in e for e in reg.errors)


def test_lock_registry_consistent_order_is_clean():
    reg = LockRegistry()
    a = InstrumentedRLock("A", reg)
    b = InstrumentedRLock("B", reg)
    for _ in range(3):
        with a:
            with b:
                pass
    assert reg.errors == []


def test_instrumented_lock_backs_a_condition():
    """cv.wait() releases and reacquires through the wrapper — the
    registry's held-stack stays consistent and records no errors."""
    reg = LockRegistry()
    cv = threading.Condition(InstrumentedRLock("cv", reg))
    with cv:
        cv.wait(timeout=0.01)
    assert reg.errors == []
    assert reg._stack() == []


class _FakeBatcher:
    def __init__(self):
        self._lock = threading.Lock()


class _FakeServer:
    def __init__(self):
        self._cv = threading.Condition()
        self.batcher = _FakeBatcher()
        self._running = False
        self._worker = None


def test_sanitizer_catches_unguarded_access():
    srv = _FakeServer()
    reg = sanitize_server(srv)
    with srv._cv:
        srv._running = True  # guarded write under the cv: clean
        assert srv._running
    assert reg.errors == []
    if srv._running:  # SIC: unguarded read — must be recorded
        pass
    srv._worker = None  # unguarded write — must be recorded
    assert len(reg.errors) == 2
    assert all("unguarded" in e for e in reg.errors)


def test_retrace_sentinel_detects_ledger_growth(retrace_sentinel):
    from repro.engine import execute

    key = ("trimcheck-selftest", 0, "float")
    retrace_sentinel.arm()
    retrace_sentinel.check()  # no growth yet
    execute.EXECUTABLE_COMPILES[key] = 1
    try:
        with pytest.raises(AssertionError, match="retrace outside warmup"):
            retrace_sentinel.check()
    finally:
        del execute.EXECUTABLE_COMPILES[key]
    retrace_sentinel.check()  # restored: teardown must pass too
