import importlib.util
import os
import pathlib
import sys

# Tests run on the single host device; the 512-device dry-run sets its own
# XLA_FLAGS before importing jax (and is exercised via subprocess here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests use hypothesis when available; in minimal environments
# (no hypothesis wheel baked in) fall back to the deterministic shim so the
# suite still collects and the property bodies still run over a fixed
# sample.  CI installs real hypothesis via requirements-dev.txt.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_shim.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies

import numpy as np
import pytest

# Repo root on sys.path: tests import the stdlib-only static-analysis
# package (tools.analysis) the same way ``python -m tools.analysis`` does.
_REPO = str(pathlib.Path(__file__).resolve().parent.parent)
if _REPO not in sys.path:
    sys.path.insert(0, _REPO)


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


class RetraceSentinel:
    """Asserts ``EXECUTABLE_COMPILES`` never grows outside warmup.

    Usage: run the warmup (server start / first request), call ``arm()``,
    run the load; the fixture's teardown fails the test if any serving
    executable (re)compiled after arming.  ``check()`` may also be called
    mid-test for a tighter window.
    """

    def __init__(self):
        self._baseline = None

    def arm(self):
        from repro.engine import execute

        self._baseline = dict(execute.EXECUTABLE_COMPILES)

    def check(self):
        from repro.engine import execute

        if self._baseline is None:
            return
        grown = {
            key: (self._baseline.get(key, 0), n)
            for key, n in execute.EXECUTABLE_COMPILES.items()
            if n > self._baseline.get(key, 0)
        }
        assert not grown, (
            "retrace outside warmup: executables compiled after "
            f"retrace_sentinel.arm(): { {k[1:]: v for k, v in grown.items()} }"
        )


@pytest.fixture
def retrace_sentinel():
    sentinel = RetraceSentinel()
    yield sentinel
    sentinel.check()
