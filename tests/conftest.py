import importlib.util
import os
import pathlib
import sys

# Tests run on the single host device; the 512-device dry-run sets its own
# XLA_FLAGS before importing jax (and is exercised via subprocess here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

# Property tests use hypothesis when available; in minimal environments
# (no hypothesis wheel baked in) fall back to the deterministic shim so the
# suite still collects and the property bodies still run over a fixed
# sample.  CI installs real hypothesis via requirements-dev.txt.
try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _spec = importlib.util.spec_from_file_location(
        "hypothesis",
        pathlib.Path(__file__).with_name("_hypothesis_shim.py"))
    _shim = importlib.util.module_from_spec(_spec)
    _spec.loader.exec_module(_shim)
    sys.modules["hypothesis"] = _shim
    sys.modules["hypothesis.strategies"] = _shim.strategies

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
