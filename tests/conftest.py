import os

# Tests run on the single host device; the 512-device dry-run sets its own
# XLA_FLAGS before importing jax (and is exercised via subprocess here).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
