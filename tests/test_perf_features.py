"""§Perf feature tests: padded/chunked CE, seq-sharded decode, FSDP specs,
bf16 SSD scores — each must preserve semantics (they only move bytes)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke
from repro.nn.layers import mask_pad_logits
from repro.nn.losses import chunked_softmax_xent, softmax_xent
from repro.nn.models import build_model

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_padded_ce_equals_sliced():
    key = jax.random.PRNGKey(0)
    B, S, d, V, Vpad = 2, 8, 16, 50, 64
    x = jax.random.normal(key, (B, S, d))
    table = jax.random.normal(jax.random.fold_in(key, 1), (Vpad, d))
    tgt = jax.random.randint(key, (B, S), 0, V)
    logits = jnp.einsum("bsd,vd->bsv", x, table)
    ce_pad = softmax_xent(mask_pad_logits(logits, V), tgt)
    ce_ref = softmax_xent(logits[..., :V], tgt)
    assert abs(float(ce_pad - ce_ref)) < 1e-6


@pytest.mark.parametrize("chunk", [16, 64, 100])
def test_chunked_ce_value_and_grad(chunk):
    key = jax.random.PRNGKey(1)
    B, S, d, V, Vpad = 2, 6, 12, 77, 96
    x = jax.random.normal(key, (B, S, d))
    table = jax.random.normal(jax.random.fold_in(key, 1), (Vpad, d))
    tgt = jax.random.randint(key, (B, S), 0, V)

    def ref(t):
        return softmax_xent(
            jnp.einsum("bsd,vd->bsv", x, t)[..., :V], tgt)

    def chk(t):
        return chunked_softmax_xent(x, t, tgt, V, chunk=chunk)

    assert abs(float(ref(table) - chk(table))) < 1e-5
    g1, g2 = jax.grad(ref)(table), jax.grad(chk)(table)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-6)


def test_model_ce_impls_agree():
    cfg = get_smoke("granite-3-2b")
    m1 = build_model(cfg)
    m2 = build_model(cfg.with_overrides(ce_impl="chunked"))
    p = m1.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 17), 0, cfg.vocab)
    l1, _ = m1.loss(p, {"tokens": toks})
    l2, _ = m2.loss(p, {"tokens": toks})
    assert abs(float(l1 - l2)) < 1e-5


def test_seqshard_decode_fallback_matches_baseline():
    cfg = get_smoke("mistral-large-123b")
    m1 = build_model(cfg)
    m2 = build_model(cfg.with_overrides(decode_kv_seqshard=True))
    p = m1.init(jax.random.PRNGKey(0))
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    full, _ = m1.forward(p, toks)
    for m in (m1, m2):
        cache = m.init_cache(B, S + 2, dtype=jnp.float32)
        pre, cache = m.prefill(p, toks[:, :S - 1], cache)
        dec, _ = m.decode_step(p, toks[:, S - 1], cache, jnp.int32(S - 1))
        np.testing.assert_allclose(np.asarray(dec),
                                   np.asarray(full[:, S - 1]),
                                   rtol=3e-4, atol=3e-4)


def test_seqshard_decode_distributed():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke
    from repro.nn.models import build_model
    from repro.distributed import activate_mesh
    from repro.distributed.steps import _to_shardings, cache_pspec
    cfg = get_smoke("mistral-large-123b").with_overrides(
        n_q=8, n_kv=2, head_dim=8)
    B, S = 4, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab)
    m_ref = build_model(cfg)
    p = m_ref.init(jax.random.PRNGKey(0))
    full, _ = m_ref.forward(p, toks)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with activate_mesh(mesh) as ctx, mesh:
        m = build_model(cfg.with_overrides(decode_kv_seqshard=True), tp=4)
        cache = m.init_cache(B, S, dtype=jnp.float32)
        cache = jax.device_put(cache,
                               _to_shardings(cache_pspec(cache, ctx), mesh))
        pre, cache = jax.jit(m.prefill)(p, toks[:, :S-1], cache)
        dec, cache2 = jax.jit(m.decode_step)(p, toks[:, S-1], cache,
                                             jnp.int32(S-1))
        kv = cache2["slot0"]["kv_seq"].k
        assert "model" in str(kv.sharding.spec), kv.sharding.spec
    err = float(jnp.abs(dec - full[:, S-1]).max())
    print("err", err)
    assert err < 1e-4
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    # fake host devices need the CPU platform; never let the child probe
    # TPU (libtpu-installed, TPU-less containers hang in TPU client init)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-4000:]


def test_fsdp_pspec_shards_params_over_dp():
    code = """
    import jax, numpy as np
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import (activate_mesh, fsdp_pspec,
                                            param_pspec)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    params = {"mlp": {"w_gate": {"kernel": np.zeros((64, 128))}},
              "norm": {"scale": np.zeros((64,))}}
    with activate_mesh(mesh) as ctx:
        base = param_pspec(params, ctx)
        fs = fsdp_pspec(params, ctx)
    # TP shards ff over model; FSDP additionally shards embed over data
    assert base["mlp"]["w_gate"]["kernel"] == P(None, "model")
    assert fs["mlp"]["w_gate"]["kernel"] == P("data", "model")
    print("ok")
    """
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=os.path.join(REPO, "src"))
    # fake host devices need the CPU platform; never let the child probe
    # TPU (libtpu-installed, TPU-less containers hang in TPU client init)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=360)
    assert out.returncode == 0, out.stderr[-4000:]
    assert "ok" in out.stdout


def test_ssd_bf16_close_to_f32():
    from repro.nn.mamba import mamba_dims, init_mamba, mamba_mixer
    dims = mamba_dims(32, expand=2, headdim=8, d_state=16, chunk=16)
    p = init_mamba(jax.random.PRNGKey(0), dims)
    u = jax.random.normal(jax.random.PRNGKey(1), (2, 40, 32))
    y32, _ = mamba_mixer(p, u, dims, mode="train",
                         score_dtype=jnp.float32)
    y16, _ = mamba_mixer(p, u, dims, mode="train",
                         score_dtype=jnp.bfloat16)
    rel = float(jnp.abs(y16 - y32).max()
                / jnp.maximum(jnp.abs(y32).max(), 1e-6))
    assert rel < 0.05, rel


def test_flash_kernel_matches_module_attention():
    """The Pallas flash kernel == nn.attention's XLA streaming flash on the
    same inputs (ties the §Perf kernel to the module it replaces)."""
    from repro.kernels.flash_attention import flash_attention_pallas
    from repro.nn.attention import flash_attention
    key = jax.random.PRNGKey(0)
    B, H, G, S, D = 1, 2, 3, 48, 16
    q5 = jax.random.normal(key, (B, S, H, G, D))
    k4 = jax.random.normal(jax.random.fold_in(key, 1), (B, S, H, D))
    v4 = jax.random.normal(jax.random.fold_in(key, 2), (B, S, H, D))
    ref = flash_attention(q5, k4, v4, causal=True, chunk_k=16)
    # kernel layout: (B, H*G, S, D) with k/v repeated per group
    qk = q5.transpose(0, 2, 3, 1, 4).reshape(B, H * G, S, D)
    kk = jnp.repeat(k4.transpose(0, 2, 1, 3), G, axis=1)
    vk = jnp.repeat(v4.transpose(0, 2, 1, 3), G, axis=1)
    out = flash_attention_pallas(qk, kk, vk, causal=True, block_q=16,
                                 block_k=16, interpret=True)
    out = out.reshape(B, H, G, S, D).transpose(0, 3, 1, 2, 4)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.slow
def test_dryrun_cnn_scaled():
    """The bonus CNN dry-run (paper's own workload) compiles at scale."""
    import json
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
                   PYTHONPATH=os.path.join(REPO, "src"))
        env["JAX_PLATFORMS"] = "cpu"
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun_cnn",
             "--arch", "vgg16", "--batch", "32", "--out", d],
            capture_output=True, text=True, env=env, timeout=560)
        assert out.returncode == 0, out.stderr[-3000:]
        rec = json.load(open(os.path.join(d, "vgg16__cnn_train__single.json")))
        assert rec["roofline"]["useful_flops_ratio"] > 0.5
