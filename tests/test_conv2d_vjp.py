"""Gradient-parity suite for the TrIM conv2d custom VJP (DESIGN.md §6).

``jax.grad`` through the Pallas path (input-grad transposed-conv forward +
weight-grad per-tap reduction kernel, interpret mode) must match the
lax.conv oracle path for stride 1/2/4, K=3/5/11, grouped conv, partial
W-tiles, and fp32/bf16 inputs — plus the model-level acceptance criterion:
grads of the full ConvNet loss agree to 1e-4 on CPU."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.trim.model import ConvLayerSpec
from repro.engine import ExecutionPolicy
from repro.kernels import ref
from repro.kernels.ops import trim_conv2d
from repro.kernels.trim_conv2d_vjp import (trim_conv2d_input_grad,
                                           trim_conv2d_wgrad_pallas)
from repro.nn.conv import CNNConfig, cnn_loss, init_cnn

#: Pallas everywhere (interpret off-TPU) vs the default oracle-on-CPU.
PALLAS = ExecutionPolicy(substrate="pallas")
ORACLE = ExecutionPolicy()


def _assert_tree_close(a, b, rtol=1e-4, atol=1e-4):
    for pa, pb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(pa, np.float32),
                                   np.asarray(pb, np.float32),
                                   rtol=rtol, atol=atol)


# ---------------------------------------------------------------------------
# kernel-level: the two backward kernels vs the oracle VJP
# ---------------------------------------------------------------------------

GRAD_CASES = [
    # (H, W, K, stride, pad) — pad=None means 'same' (K//2)
    (12, 12, 3, 1, None),
    (12, 13, 3, 2, 1),
    (11, 12, 3, 2, 0),           # (H+2p-K) % S > 0: remainder rows/cols
    (13, 13, 5, 1, 2),
    (13, 15, 5, 2, 2),
    (23, 23, 11, 4, 0),          # AlexNet CL1 shape family
]


@pytest.mark.parametrize("case", GRAD_CASES, ids=str)
def test_backward_kernels_match_oracle_vjp(case):
    """Input-grad and weight-grad Pallas kernels == jax.vjp of the oracle
    conv, directly at the kernel wrappers."""
    H, W, K, stride, pad = case
    key = jax.random.PRNGKey(sum(v or 0 for v in case))
    x = jax.random.normal(key, (2, H, W, 4), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, K, 4, 8),
                          jnp.float32)
    out, vjp = jax.vjp(
        lambda x, w: ref.conv2d_ref(x, w, stride=stride, padding=pad), x, w)
    g = jax.random.normal(jax.random.fold_in(key, 2), out.shape, jnp.float32)
    dx_ref, dw_ref = vjp(g)
    dx = trim_conv2d_input_grad(g, w, x_hw=(H, W), stride=stride,
                                padding=pad, tile_h=4, block_c=4, block_f=8,
                                interpret=True)
    dw = trim_conv2d_wgrad_pallas(x, g, K=K, stride=stride, padding=pad,
                                  tile_h=4, block_c=4, block_f=8,
                                  interpret=True)
    np.testing.assert_allclose(np.asarray(dx), np.asarray(dx_ref),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(dw), np.asarray(dw_ref),
                               rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# dispatcher-level: jax.grad through ops.trim_conv2d, Pallas vs oracle
# ---------------------------------------------------------------------------

OPS_CASES = [
    # (H, W, K, stride, pad, groups, tile_w)
    (12, 12, 3, 1, None, 1, None),
    (11, 12, 3, 2, 0, 1, None),
    (13, 15, 5, 2, 2, 1, None),
    (23, 23, 11, 4, 0, 1, None),
    (10, 10, 3, 1, None, 2, None),    # grouped (AlexNet two-tower)
    (9, 12, 3, 2, 1, 2, None),        # grouped + stride 2
    (8, 13, 3, 1, 1, 1, 4),           # partial W-tiles (W_O=13, TW=4)
    (9, 13, 3, 2, 1, 1, 3),           # partial W-tiles + stride-2 halo cols
]


def _ops_grads(x, w, b, cot, policy, **kw):
    def f(x, w, b):
        out = trim_conv2d(x, w, b, relu=True, policy=policy,
                          block_c=4, block_f=4, **kw)
        return (out.astype(jnp.float32) * cot).sum()
    return jax.grad(f, argnums=(0, 1, 2))(x, w, b)


@pytest.mark.parametrize("case", OPS_CASES, ids=str)
def test_ops_grad_parity_fp32(case):
    """jax.grad of the fused (conv+bias+ReLU) dispatcher: Pallas custom VJP
    == oracle autodiff, to 1e-4 (the acceptance tolerance)."""
    H, W, K, stride, pad, groups, tile_w = case
    C, F = 4, 8
    key = jax.random.PRNGKey(sum(v or 0 for v in case))
    x = jax.random.normal(key, (2, H, W, C), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1),
                          (K, K, C // groups, F), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 2), (F,), jnp.float32)
    kw = dict(stride=stride, padding=pad, groups=groups, tile_w=tile_w)
    out_sd = jax.eval_shape(
        lambda x, w, b: trim_conv2d(x, w, b, relu=True, **kw), x, w, b)
    cot = jax.random.normal(jax.random.fold_in(key, 3), out_sd.shape,
                            jnp.float32)
    g_pal = _ops_grads(x, w, b, cot, PALLAS, **kw)
    g_ref = _ops_grads(x, w, b, cot, ORACLE, **kw)
    _assert_tree_close(g_pal, g_ref)


def test_ops_grad_parity_bf16():
    """bf16 inputs: the Pallas VJP accumulates in f32 and returns bf16
    cotangents; parity vs the oracle within bf16 rounding."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (2, 10, 11, 4), jnp.bfloat16)
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 8),
                          jnp.bfloat16)
    b = jax.random.normal(jax.random.fold_in(key, 2), (8,), jnp.float32)
    cot = jax.random.normal(jax.random.fold_in(key, 3), (2, 5, 6, 8),
                            jnp.float32)

    def f(x, w, b, policy):
        out = trim_conv2d(x, w, b, stride=2, relu=True, policy=policy,
                          block_c=4, block_f=4)
        return (out.astype(jnp.float32) * cot).sum()

    g_pal = jax.grad(lambda *a: f(*a, PALLAS), (0, 1, 2))(x, w, b)
    for a in g_pal[:2]:
        assert a.dtype == jnp.bfloat16          # cotangents follow primals
    g_ref = jax.grad(lambda *a: f(*a, ORACLE), (0, 1, 2))(x, w, b)
    scale = max(float(jnp.abs(g.astype(jnp.float32)).max())
                for g in jax.tree.leaves(g_ref))
    _assert_tree_close(g_pal, g_ref, rtol=0.1, atol=0.05 * scale)


def test_emulate_hw_stays_forward_capable():
    """emulate_hw replays the FPGA decimation schedule; on the CPU oracle
    arm it still differentiates (through lax.conv) — the Pallas VJP is
    deliberately not wired into that mode (DESIGN.md §6)."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (1, 9, 9, 4), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 8),
                          jnp.float32)
    g = jax.grad(lambda x: trim_conv2d(
        x, w, stride=2,
        policy=ExecutionPolicy(emulate_hw=True)).sum())(x)
    assert np.isfinite(np.asarray(g)).all()


# ---------------------------------------------------------------------------
# model-level: the acceptance criterion
# ---------------------------------------------------------------------------

#: stride-2 + grouped two-tower mini-CNN — the acceptance case the paper
#: smokes don't cover (vgg16-smoke is all stride 1, alexnet-smoke stride 4).
GROUPED_S2_CNN = CNNConfig(
    "grouped-s2-smoke",
    layers=(
        ConvLayerSpec("CL1", 12, 12, 3, 3, 8, stride=1, pad=1),
        ConvLayerSpec("CL2", 12, 12, 3, 4, 8, stride=2, pad=1),   # groups=2
        ConvLayerSpec("CL3", 6, 6, 3, 8, 8, stride=1, pad=1),
    ),
    pool_after=(), classifier=(16,), n_classes=4, input_hw=(12, 12))


def _cnn_grad_parity(cfg, hw, c_in, n_classes, seed=0):
    params = init_cnn(jax.random.PRNGKey(seed), cfg)
    key = jax.random.PRNGKey(seed + 1)
    batch = {"images": jax.random.normal(key, (2,) + hw + (c_in,),
                                         jnp.float32),
             "labels": jax.random.randint(jax.random.fold_in(key, 1), (2,),
                                          0, n_classes, jnp.int32)}
    g_ref = jax.grad(lambda p: cnn_loss(p, batch, cfg)[0])(params)
    g_pal = jax.grad(
        lambda p: cnn_loss(p, batch, cfg, policy=PALLAS)[0])(params)
    _assert_tree_close(g_pal, g_ref)


def test_convnet_grad_parity_vgg16_smoke():
    """Acceptance: jax.grad of the full ConvNet loss (stride-1 3x3 stack +
    pool + FC head) — Pallas VJP vs oracle to 1e-4 on CPU."""
    from repro.configs import CNN_SMOKES
    cfg = CNN_SMOKES["vgg16"]
    _cnn_grad_parity(cfg, cfg.input_hw, cfg.layers[0].M, cfg.n_classes)


def test_convnet_grad_parity_grouped_stride2():
    """Acceptance: stride-2 + grouped conv layers through the model path."""
    cfg = GROUPED_S2_CNN
    _cnn_grad_parity(cfg, cfg.input_hw, cfg.layers[0].M, cfg.n_classes,
                     seed=3)


def test_convnet_grad_parity_alexnet_smoke():
    """Large-kernel family: K=11 stride-4 + K=5 layers (alexnet-smoke)."""
    from repro.configs import CNN_SMOKES
    cfg = CNN_SMOKES["alexnet"]
    _cnn_grad_parity(cfg, cfg.input_hw, cfg.layers[0].M, cfg.n_classes,
                     seed=5)
