"""Bit-faithful engine emulator: equivalence with the integer conv oracle,
schedule counters vs the analytical model, precision-growth contract."""
import math

import numpy as np
import pytest

from repro.core.trim.engine import (TrimEngine, reference_conv_layer,
                                    trim_conv_layer)
from repro.core.trim.model import (ConvLayerSpec, TrimEngineConfig,
                                   trim_memory_accesses)


def _rand_layer(rng, M, H, W, K, N, stride=1, pad=None):
    x = rng.integers(0, 256, (M, H, W), dtype=np.uint8)
    w = rng.integers(-128, 128, (N, M, K, K)).astype(np.int8)
    return x, w, ConvLayerSpec("t", H, W, K, M, N, stride=stride, pad=pad)


CASES = [
    dict(M=3, H=16, W=16, K=3, N=8),
    dict(M=24, H=14, W=14, K=3, N=7),          # exactly one (P_N, P_M) group
    dict(M=25, H=9, W=9, K=3, N=8),            # channel remainder
    dict(M=4, H=27, W=27, K=5, N=6, pad=2),    # 5x5 tiled into 3x3
    dict(M=3, H=23, W=23, K=11, N=2, stride=4, pad=0),  # AlexNet CL1 shape
    dict(M=2, H=12, W=12, K=1, N=3, pad=0),    # 1x1 degenerate
]


@pytest.mark.parametrize("case", CASES,
                         ids=lambda c: f"K{c['K']}s{c.get('stride',1)}")
def test_engine_matches_oracle(rng, case):
    x, w, layer = _rand_layer(rng, **case)
    out, trace = TrimEngine().run_layer(x, w, layer)
    ref = reference_conv_layer(x, w, stride=layer.stride, pad=layer.pad)
    np.testing.assert_array_equal(out, ref)
    assert trace.steps >= 1


def test_engine_counters_match_model(rng):
    """The emulator's fetch/writeback counters must agree with the
    closed-form access model (model.py) — the paper's Table I columns."""
    x, w, layer = _rand_layer(rng, M=48, H=14, W=14, K=3, N=16)
    eng = TrimEngineConfig(P_N=7, P_M=24)
    out, trace = TrimEngine(eng).run_layer(x, w, layer)
    model = trim_memory_accesses(layer, eng)
    assert trace.ifmap_fetches == pytest.approx(model.ifmap_reads * 1e6)
    assert trace.weight_fetches == model.weight_reads * 1e6
    assert trace.ofmap_writebacks == model.ofmap_writes * 1e6
    assert trace.psum_buffer_accesses == pytest.approx(
        model.onchip_raw * 1e6)


def test_engine_step_count(rng):
    x, w, layer = _rand_layer(rng, M=48, H=8, W=8, K=3, N=15)
    eng = TrimEngineConfig(P_N=7, P_M=24)
    _, trace = TrimEngine(eng).run_layer(x, w, layer)
    assert trace.steps == math.ceil(15 / 7) * math.ceil(48 / 24)


def test_width_contract_worst_case():
    """All-max inputs/weights: psums must stay within the paper's
    2B+K+ceil(log2 K)+ceil(log2 M) growth (checked inside the engine)."""
    M, K, N = 8, 3, 2
    x = np.full((M, 12, 12), 255, np.uint8)
    w = np.full((N, M, K, K), -128, np.int8)
    out, _ = TrimEngine(check_widths=True).run_layer(
        np.ascontiguousarray(x), w)
    ref = reference_conv_layer(x, w)
    np.testing.assert_array_equal(out, ref)


def test_psum_buffer_snapshots(rng):
    """Intermediate psum-buffer contents equal the partial-channel conv —
    the engine's temporal accumulation is the paper's schedule."""
    x, w, layer = _rand_layer(rng, M=8, H=10, W=10, K=3, N=2)
    eng = TrimEngineConfig(P_N=2, P_M=4)
    e = TrimEngine(eng, record_snapshots=True)
    out, trace = e.run_layer(x, w, layer)
    # first snapshot: channels 0..3 only, filters 0..1
    snap0 = trace.psum_buffer_snapshots[0]
    part = reference_conv_layer(x[:4], w[:, :4])
    np.testing.assert_array_equal(snap0[0], part[0])
    np.testing.assert_array_equal(snap0[1], part[1])


def test_quantized_wrapper(rng):
    x, w, layer = _rand_layer(rng, M=4, H=9, W=9, K=3, N=5)
    out = trim_conv_layer(x, w)
    np.testing.assert_array_equal(out, reference_conv_layer(x, w))
