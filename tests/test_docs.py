"""The docs gate's static half runs inside tier-1 (tools/check_docs.py).

CI's docs lane additionally executes examples/quickstart.py; here we keep
to the fast checks — broken markdown links and dangling ``DESIGN.md §N``
citations anywhere in the tree fail the suite, not just the docs lane.
"""
import importlib.util
import os

_TOOLS = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "tools", "check_docs.py")
_spec = importlib.util.spec_from_file_location("check_docs", _TOOLS)
check_docs = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_docs)


def test_markdown_links_resolve():
    errors = []
    check_docs.check_links(errors)
    assert not errors, errors


def test_design_section_citations_exist():
    errors = []
    check_docs.check_section_refs(errors)
    assert not errors, errors


def test_checker_catches_dangling_subsection():
    """The §-reference regex and section index must actually disagree on a
    bogus citation — guards the guard."""
    sections = check_docs.design_sections()
    # assemble the bogus citation at runtime so the tree-wide scan in
    # check_section_refs doesn't flag this very file
    bogus = "DESIGN.md §" + "42.7"
    refs = check_docs.SECTION_REF_RE.findall(
        f"per DESIGN.md §9.3; but {bogus} is fiction")
    assert refs == ["9.3", "42.7"]
    assert "9.3" in sections and "42.7" not in sections and \
        "42" not in sections
