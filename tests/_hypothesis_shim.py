"""Thin deterministic stand-in for `hypothesis` when it is not installed.

Loaded by conftest.py into ``sys.modules["hypothesis"]`` only when the real
package is missing (e.g. a clean container).  It implements just the API
surface the test-suite uses — ``given``, ``settings``, ``strategies.integers``
/ ``sampled_from`` / ``booleans`` — and replays each property test over a
fixed, seeded sample instead of hypothesis' adaptive search.  CI installs
real hypothesis (requirements-dev.txt) and gets the full property-based
suite; this shim only keeps the tier-1 lane collectable and meaningful in
minimal environments.

The per-test example count is capped by REPRO_SHIM_MAX_EXAMPLES (default 5)
so the fallback lane stays fast.
"""
from __future__ import annotations


import os
import random


class _Strategy:
    def __init__(self, draw):
        self._draw = draw

    def example(self, rng: random.Random):
        return self._draw(rng)


class strategies:  # mirrors `hypothesis.strategies` as used by the suite
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def sampled_from(elements) -> _Strategy:
        seq = list(elements)
        return _Strategy(lambda rng: rng.choice(seq))

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5)


def settings(max_examples: int = 10, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(**strats):
    def deco(fn):
        def wrapper():
            cap = int(os.environ.get("REPRO_SHIM_MAX_EXAMPLES", "5"))
            n = min(getattr(wrapper, "_max_examples", 10), cap)
            # str seeding is deterministic and PYTHONHASHSEED-independent
            rng = random.Random(f"{fn.__module__}.{fn.__name__}")
            for _ in range(n):
                fn(**{k: s.example(rng) for k, s in strats.items()})
        # Plain zero-arg wrapper on purpose: functools.wraps would copy
        # __wrapped__ and pytest would then treat the drawn parameters as
        # fixtures.  Copy only the identity attributes.
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        # mimic real hypothesis' attribute (pytest plugins introspect it)
        wrapper.hypothesis = type("hypothesis", (), {"inner_test": fn})()
        return wrapper
    return deco
