"""Width-tiled TrIM conv2d + arbitrary-scale fixed-point requant
(DESIGN.md §4): parity vs the oracles for partial tiles, strided halo
columns, the VMEM auto-pick, and bit-exact multiplier+shift rounding."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.engine import ExecutionPolicy
from repro.kernels import ref
from repro.kernels.ops import trim_conv2d
from repro.kernels.requant import (requant_mult_shift, requant_ref_int64,
                                   scale_to_mult_shift)
from repro.kernels.trim_conv2d import (VMEM_BUDGET_BYTES, pick_tile_w,
                                       trim_conv2d_pallas)

#: Pallas everywhere (interpret mode on CPU) — the old force-pallas mode.
PALLAS = ExecutionPolicy(substrate="pallas")


# ---------------------------------------------------------------------------
# width tiling: parity vs ref.py
# ---------------------------------------------------------------------------

TILED_CASES = [
    # (H, W, K, stride, tile_w)  — W_O deliberately not a TW multiple
    (6, 30, 3, 1, 8),            # 30 = 3*8 + 6 partial tail
    (9, 29, 3, 2, 4),            # halo columns with stride 2 (K > S)
    (11, 29, 5, 1, 6),           # K=5: 4 halo columns
    (13, 27, 5, 2, 5),           # K=5 stride 2: 3 halo columns
    (8, 21, 3, 1, 7),            # exact multiple (no partial tail)
    (6, 17, 1, 1, 4),            # K=1: no halo at all
]


@pytest.mark.parametrize("case", TILED_CASES, ids=str)
def test_conv2d_width_tiled_float(case):
    H, W, K, stride, tw = case
    key = jax.random.PRNGKey(sum(case))
    x = jax.random.normal(key, (1, H, W, 4), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, K, 4, 8),
                          jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 2), (8,), jnp.float32)
    out = trim_conv2d_pallas(x, w, stride=stride, tile_w=tw, bias=b,
                             relu=True, tile_h=4, block_c=4, block_f=8,
                             interpret=True)
    want = jnp.maximum(ref.conv2d_ref(x, w, stride=stride) + b, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", TILED_CASES[:4], ids=str)
def test_conv2d_width_tiled_int_exact(case):
    """uint8 x int8 -> int32 stays bit-exact through the tiled path."""
    H, W, K, stride, tw = case
    key = jax.random.PRNGKey(sum(case))
    x = jax.random.randint(key, (1, H, W, 4), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (K, K, 4, 8),
                           -127, 127, jnp.int8)
    out = trim_conv2d_pallas(x, w, stride=stride, tile_w=tw, tile_h=4,
                             block_c=4, block_f=8, interpret=True)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.conv2d_ref(x, w, stride=stride)))


@pytest.mark.parametrize("stride,W", [(1, 512), (2, 1023)], ids=str)
def test_conv2d_wide_512(stride, W):
    """Acceptance: W_O = 512 through the Pallas path with TW < W_O —
    int8 bitwise and fp32 within tolerance, stride 1 and 2."""
    key = jax.random.PRNGKey(stride)
    H = 4 if stride == 1 else 5
    xi = jax.random.randint(key, (1, H, W, 4), 0, 255, jnp.uint8)
    wi = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 4, 8),
                            -127, 127, jnp.int8)
    W_O = (W + 2 - 3) // stride + 1
    assert W_O == 512
    out = trim_conv2d_pallas(xi, wi, stride=stride, tile_w=128, tile_h=4,
                             block_c=4, block_f=8, interpret=True)
    np.testing.assert_array_equal(
        np.asarray(out), np.asarray(ref.conv2d_ref(xi, wi, stride=stride)))
    xf = (xi.astype(jnp.float32) / 255.0) - 0.5
    wf = wi.astype(jnp.float32) / 127.0
    outf = trim_conv2d_pallas(xf, wf, stride=stride, tile_w=128, tile_h=4,
                              block_c=4, block_f=8, interpret=True)
    np.testing.assert_allclose(
        np.asarray(outf), np.asarray(ref.conv2d_ref(xf, wf, stride=stride)),
        rtol=2e-5, atol=2e-5)


def test_conv2d_vmem_budget_forces_tiling():
    """A tight VMEM budget must trigger the auto-pick (TW < W_O) and stay
    correct; the kernel is the only thing that changes, not the math."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (1, 6, 64, 4), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 8),
                          jnp.float32)
    tw = pick_tile_w(64, K=3, stride=1, RB=4, TH=4, W_p=66, Cb=4, Fb=8,
                     vmem_budget=16384)
    assert tw < 64
    out = trim_conv2d_pallas(x, w, tile_h=4, block_c=4, block_f=8,
                             vmem_budget=16384, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv2d_ref(x, w)),
                               rtol=2e-5, atol=2e-5)


def test_pick_tile_w_paper_shapes_single_block():
    """Acceptance: the VGG-16 / AlexNet shapes keep the degenerate
    single-block layout (n_wt == 1) under the default VMEM budget."""
    # VGG-16 widest layer: 224x224, C/F blocks of 128, f32.
    assert pick_tile_w(224, K=3, stride=1, RB=8, TH=8, W_p=226, Cb=128,
                       Fb=128) == 224
    # AlexNet CL1: 227x227x3, K=11 stride 4.
    assert pick_tile_w(55, K=11, stride=4, RB=32, TH=8, W_p=227, Cb=3,
                       Fb=96) == 55
    # A genuinely wide map must tile under the same default budget.
    assert pick_tile_w(2048, K=3, stride=1, RB=8, TH=8, W_p=2050, Cb=128,
                       Fb=128) < 2048
    assert VMEM_BUDGET_BYTES <= 16 * 2 ** 20


def test_ops_tile_w_dispatch_parity():
    """tile_w threads through the public ops dispatcher (CPU oracle vs
    pallas-policy width-tiled kernel agree)."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 8, 26, 4))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 8))
    a = trim_conv2d(x, w, tile_w=8)
    b = trim_conv2d(x, w, tile_w=8, policy=PALLAS)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# arbitrary-scale requant: bit-exact fixed-point rounding
# ---------------------------------------------------------------------------


def test_requant_mult_shift_matches_int64_oracle():
    """The int32-only hi/lo-split requant == the int64 oracle over the
    full int32 accumulator range, for every shift regime."""
    rng = np.random.default_rng(0)
    acc = np.concatenate([
        rng.integers(-2 ** 31, 2 ** 31, 4096, dtype=np.int64),
        np.array([0, 1, -1, 2 ** 31 - 1, -2 ** 31, 65535, -65536],
                 np.int64)]).astype(np.int32)
    for s in [1, 2, 8, 15, 16, 17, 20, 24, 31]:
        for m in [1, 3, 255, 16384, 32767]:
            got = np.asarray(requant_mult_shift(jnp.asarray(acc), m, s),
                             np.int64)
            np.testing.assert_array_equal(got, requant_ref_int64(acc, m, s),
                                          err_msg=f"m={m} s={s}")


def test_requant_fp32_scale_oracle_bit_exact():
    """Fixed-point (mult, shift) from an fp32 scale reproduces
    clip(floor(acc * scale + 0.5)) bit-exactly — the scale m*2^-s is
    representable exactly, so the float oracle and the integer datapath
    must agree on every element."""
    rng = np.random.default_rng(1)
    scales = np.float32(rng.uniform(1e-6, 200.0, 16))
    m, s = scale_to_mult_shift(scales)
    acc = rng.integers(-10 ** 8, 10 ** 8, (3, 5, 7, 16),
                       dtype=np.int64).astype(np.int32)
    got = np.asarray(requant_mult_shift(jnp.asarray(acc), jnp.asarray(m),
                                        jnp.asarray(s)), np.int64)
    exact_scale = m.astype(np.float64) / np.exp2(s.astype(np.float64))
    want = np.clip(np.floor(acc.astype(np.float64) * exact_scale + 0.5),
                   0, 255).astype(np.int64)
    np.testing.assert_array_equal(got, want)
    # and the encoded scale is within 2^-14 relative of the requested one
    np.testing.assert_allclose(exact_scale, scales, rtol=2.0 ** -14)


@pytest.mark.parametrize("tiled", [False, True], ids=["single", "tiled"])
def test_conv2d_fused_requant_mult_shift(tiled):
    """Fused multiplier+shift requant in the kernel flush == unfused
    int64 oracle, bitwise, per-channel, with and without width tiling."""
    key = jax.random.PRNGKey(5)
    x = jax.random.randint(key, (1, 10, 22, 4), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 4, 8),
                           -127, 127, jnp.int8)
    rng = np.random.default_rng(2)
    m = rng.integers(8192, 32767, 8).astype(np.int32)
    s = rng.integers(14, 24, 8).astype(np.int32)
    out = trim_conv2d_pallas(x, w, stride=2, relu=True,
                             requant=(jnp.asarray(m), jnp.asarray(s)),
                             tile_w=4 if tiled else None,
                             tile_h=4, block_c=4, block_f=8, interpret=True)
    assert out.dtype == jnp.uint8
    psum = np.maximum(np.asarray(ref.conv2d_ref(x, w, stride=2)), 0)
    np.testing.assert_array_equal(np.asarray(out, np.int64),
                                  requant_ref_int64(psum, m, s))


def test_ops_requant_cpu_pallas_bitwise():
    """The jnp fallback epilogue and the fused kernel produce identical
    uint8 (the dispatcher is substrate-transparent for the int8 path)."""
    key = jax.random.PRNGKey(6)
    x = jax.random.randint(key, (1, 12, 12, 4), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 4, 8),
                           -127, 127, jnp.int8)
    rq = (jnp.full((8,), 21000, jnp.int32), jnp.full((8,), 19, jnp.int32))
    a = trim_conv2d(x, w, None, rq, relu=True)
    b = trim_conv2d(x, w, None, rq, relu=True, policy=PALLAS)
    assert a.dtype == b.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_ops_requant_grouped():
    """Grouped conv (AlexNet two-tower) slices per-channel requant arrays
    onto the right filter groups."""
    key = jax.random.PRNGKey(7)
    x = jax.random.randint(key, (1, 8, 8, 8), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 4, 6),
                           -127, 127, jnp.int8)
    rng = np.random.default_rng(3)
    m = jnp.asarray(rng.integers(8192, 32767, 6).astype(np.int32))
    s = jnp.asarray(rng.integers(14, 22, 6).astype(np.int32))
    a = trim_conv2d(x, w, None, (m, s), groups=2, relu=True)
    b = trim_conv2d(x, w, None, (m, s), groups=2, relu=True,
                    policy=PALLAS)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_cnn_int8_arbitrary_requant_fused():
    """Model-level: calibrate_requant pairs drive the fully-fused int8
    forward; parity vs an explicit unfused recomputation, bitwise."""
    from repro.configs import CNN_SMOKES
    from repro.nn.conv import (calibrate_requant, cnn_forward_int8,
                               init_cnn, max_pool2x2, quantize_cnn)
    cfg = CNN_SMOKES["vgg16"]
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    qp, _ = quantize_cnn(params, cfg)
    u8 = jax.random.randint(jax.random.PRNGKey(1), (1, 16, 16, 3), 0, 255,
                            jnp.uint8)
    pairs = calibrate_requant(qp, u8, cfg)
    assert len(pairs) == len(cfg.layers) - 1
    fused = cnn_forward_int8(qp, u8, cfg, requant=pairs)
    # unfused replay through the oracle conv + shared requant helper
    x = u8
    for i, l in enumerate(cfg.layers):
        w = qp["conv"][i]["kernel"]
        psum = jnp.maximum(ref.conv2d_ref(x, w, stride=l.stride,
                                          padding=l.padding), 0)
        if i == len(cfg.layers) - 1:
            want = psum
            break
        m, s = pairs[i]
        x = requant_mult_shift(psum, m, s).astype(jnp.uint8)
        if i in cfg.pool_after:
            x = max_pool2x2(x)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(want))


def test_cnn_int8_per_tensor_calibration():
    """per_channel=False emits scalar-per-layer pairs that still run the
    fused path end to end."""
    from repro.configs import CNN_SMOKES
    from repro.nn.conv import (calibrate_requant, cnn_forward_int8,
                               init_cnn, quantize_cnn)
    cfg = CNN_SMOKES["alexnet"]
    params = init_cnn(jax.random.PRNGKey(2), cfg)
    qp, _ = quantize_cnn(params, cfg)
    u8 = jax.random.randint(jax.random.PRNGKey(3), (1, 19, 19, 3), 0, 255,
                            jnp.uint8)
    pairs = calibrate_requant(qp, u8, cfg, per_channel=False)
    out = cnn_forward_int8(qp, u8, cfg, requant=pairs)
    assert out.dtype == jnp.int32
