"""Execution-policy / layer-plan API (repro.engine, DESIGN.md §3).

Covers: the single dispatch rule, plan determinism + hashability (lru and
``jax.jit`` static-arg cache hits on rebuilt plans), the cached VJP handle,
the degenerate single-W-block schedule on the paper's full-size shapes, the
deprecation shims (warning AND numerical identity with the plan path), and
the shared launcher CLI -> policy mapping.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import CNN_REGISTRY, CNN_SMOKES
from repro.engine import (ExecutionPolicy, plan_conv_layer, plan_model,
                          run_conv2d)
from repro.kernels.ops import trim_conv2d
from repro.kernels.trim_conv2d_vjp import make_trim_conv2d_vjp
from repro.nn.conv import cnn_forward, cnn_forward_int8, init_cnn, \
    quantize_cnn
from repro.nn.models import ConvNet, build_model

PALLAS = ExecutionPolicy(substrate="pallas")


# ---------------------------------------------------------------------------
# policy: the one dispatch rule
# ---------------------------------------------------------------------------


def test_dispatch_rule_off_tpu():
    """CPU backend: auto -> oracle, pallas -> interpret, explicit choices
    pass through.  (This suite runs on CPU; on TPU auto/pallas resolve to
    compiled pallas instead.)"""
    assert jax.default_backend() != "tpu"
    assert ExecutionPolicy().resolved_substrate() == "oracle"
    assert PALLAS.resolved_substrate() == "interpret"
    assert ExecutionPolicy(substrate="oracle").resolved_substrate() == \
        "oracle"
    assert ExecutionPolicy(substrate="interpret").resolved_substrate() == \
        "interpret"
    with pytest.raises(ValueError):
        ExecutionPolicy(substrate="fpga")


def test_policy_hashable_and_resolving():
    p = ExecutionPolicy(substrate="pallas", emulate_hw=True, tile_w=16)
    assert hash(p) == hash(ExecutionPolicy(substrate="pallas",
                                           emulate_hw=True, tile_w=16))
    r = p.resolve()
    assert r.substrate in ("pallas", "interpret")
    assert r.emulate_hw and r.tile_w == 16


# ---------------------------------------------------------------------------
# plans: determinism, hashability, cache hits
# ---------------------------------------------------------------------------


def test_plan_model_deterministic_and_cached():
    """Same cfg + policy -> the SAME ModelPlan object (lru hit), even when
    the config is a rebuilt equal value; plans hash and compare by value."""
    cfg = CNN_SMOKES["vgg16"]
    p1 = plan_model(cfg, ExecutionPolicy())
    p2 = plan_model(dataclasses.replace(cfg), ExecutionPolicy())
    assert p1 is p2
    assert hash(p1) == hash(p2) and p1 == p2
    assert len(p1.layers) == len(cfg.layers)
    # a different policy is a different plan
    p3 = plan_model(cfg, PALLAS)
    assert p3 is not p1 and p3.layers[0].substrate == "interpret"


def test_vjp_handle_lru_hit():
    """Equal layer plans share one cached custom-VJP handle (the
    make_trim_conv2d_vjp lru cache)."""
    kw = dict(stride=1, padding=1, relu=True, has_bias=True, policy=PALLAS)
    a = plan_conv_layer((12, 12), 4, 3, 8, **kw)
    b = plan_conv_layer((12, 12), 4, 3, 8, **kw)
    assert a is b
    assert a.vjp() is b.vjp()
    info = make_trim_conv2d_vjp.cache_info()
    a.vjp()
    assert make_trim_conv2d_vjp.cache_info().hits == info.hits + 1


def test_plan_jit_closure_no_retrace():
    """A rebuilt (equal) plan passed as a jit static argument must hit the
    trace cache — the round-trip the old kwargs-threading could not do."""
    cfg = CNN_SMOKES["vgg16"]
    traces = []

    @functools.partial(jax.jit, static_argnames=("plan",))
    def fwd(plan, params, images):
        traces.append(1)
        from repro.engine import execute
        return execute.forward(plan, params, images)

    params = init_cnn(jax.random.PRNGKey(0), cfg)
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    o1 = fwd(plan_model(cfg, ExecutionPolicy()), params, img)
    # rebuild cfg AND policy from scratch: equal values, fresh objects
    cfg2 = dataclasses.replace(cfg)
    o2 = fwd(plan_model(cfg2, ExecutionPolicy()), params, img)
    assert len(traces) == 1
    np.testing.assert_array_equal(np.asarray(o1), np.asarray(o2))


def test_executable_ledger_holds_after_warmup(retrace_sentinel):
    """``executable_for`` is the serving compile seam: after warmup,
    rebuilt-equal plans and repeat calls must hit the lru — the
    EXECUTABLE_COMPILES ledger may not grow once the sentinel is armed."""
    from repro.engine import execute

    cfg = CNN_SMOKES["vgg16"]
    plan = plan_model(cfg, ExecutionPolicy())
    compiled = execute.executable_for(plan, 2)          # warmup
    retrace_sentinel.arm()
    rebuilt = plan_model(dataclasses.replace(cfg), ExecutionPolicy())
    assert execute.executable_for(rebuilt, 2) is compiled
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    H, W = cfg.input_hw
    imgs = jnp.zeros((2, H, W, plan.layers[0].c_in), jnp.float32)
    np.asarray(compiled(params, imgs))                  # runs, no compile
    retrace_sentinel.check()


def test_paper_shapes_keep_single_wblock_schedule():
    """VGG-16 and AlexNet full-size plans keep the degenerate single-W-block
    schedule (n_wt == 1, tile covers W_O) — the paper shapes never tile."""
    for name in ("vgg16", "alexnet"):
        plan = plan_model(CNN_REGISTRY[name], ExecutionPolicy())
        for lp in plan.layers:
            assert lp.geom.n_wt == 1, (name, lp)
            assert lp.tile_w == lp.geom.W_O


def test_int8_plan_describes_integer_datapath():
    """ModelPlan.int8 is the lane forward_int8 actually runs: bias-free,
    fused requant on every non-last layer, raw psums out of the last."""
    plan = plan_model(CNN_SMOKES["vgg16"], ExecutionPolicy())
    int8 = plan.int8
    assert int8 is plan.int8                      # lru-cached sibling
    assert all(not lp.has_bias for lp in int8.layers)
    assert [lp.epilogue for lp in int8.layers] == \
        ["relu+requant"] * (len(int8.layers) - 1) + ["relu"]
    assert [lp.epilogue for lp in plan.layers] == \
        ["bias+relu"] * len(plan.layers)


def test_emulate_hw_plan_uses_stride1_geometry():
    lp = plan_conv_layer((23, 23), 3, 11, 8, stride=4, padding=0,
                         relu=True, has_bias=True,
                         policy=ExecutionPolicy(emulate_hw=True))
    assert lp.decimate and lp.geom.S == 1
    assert lp.epilogue.startswith("decimate->")


# ---------------------------------------------------------------------------
# deprecation shims: warning + numerical identity with the plan path
# ---------------------------------------------------------------------------


def test_trim_conv2d_legacy_kwargs_warn_and_match():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (1, 10, 10, 4))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 8))
    b = jax.random.normal(jax.random.fold_in(key, 2), (8,))
    new = trim_conv2d(x, w, b, relu=True, policy=PALLAS)
    with pytest.warns(DeprecationWarning, match="force_pallas"):
        old = trim_conv2d(x, w, b, relu=True, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))
    hw_new = trim_conv2d(x, w, b, stride=2, relu=True,
                         policy=ExecutionPolicy(emulate_hw=True))
    with pytest.warns(DeprecationWarning, match="emulate_hw"):
        hw_old = trim_conv2d(x, w, b, stride=2, relu=True, emulate_hw=True)
    np.testing.assert_array_equal(np.asarray(hw_old), np.asarray(hw_new))


def test_cnn_forward_legacy_kwargs_warn_and_match():
    cfg = CNN_SMOKES["vgg16"]
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    new = cnn_forward(params, img, cfg, policy=PALLAS)
    with pytest.warns(DeprecationWarning, match="force_pallas"):
        old = cnn_forward(params, img, cfg, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_cnn_forward_int8_legacy_kwargs_warn_and_match():
    """int8 path: bit-identical between the shim and the plan path."""
    cfg = CNN_SMOKES["vgg16"]
    params = init_cnn(jax.random.PRNGKey(2), cfg)
    qp, _ = quantize_cnn(params, cfg)
    u8 = jax.random.randint(jax.random.PRNGKey(3), (1, 16, 16, 3), 0, 255,
                            jnp.uint8)
    new = cnn_forward_int8(qp, u8, cfg, policy=PALLAS)
    with pytest.warns(DeprecationWarning, match="force_pallas"):
        old = cnn_forward_int8(qp, u8, cfg, force_pallas=True)
    np.testing.assert_array_equal(np.asarray(old), np.asarray(new))


def test_build_model_legacy_kwargs_warn_and_match():
    cfg = CNN_SMOKES["vgg16"]
    with pytest.warns(DeprecationWarning, match="force_pallas"):
        legacy = build_model(cfg, force_pallas=True)
    modern = build_model(cfg, policy=PALLAS)
    assert isinstance(legacy, ConvNet) and isinstance(modern, ConvNet)
    assert legacy.plan is modern.plan       # same resolved ModelPlan
    params = modern.init(jax.random.PRNGKey(0))
    img = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    np.testing.assert_array_equal(
        np.asarray(legacy.forward(params, img)),
        np.asarray(modern.forward(params, img)))


# ---------------------------------------------------------------------------
# the dispatch seam itself
# ---------------------------------------------------------------------------


def test_run_conv2d_substrate_agreement():
    """All three substrates agree through THE dispatch site directly."""
    key = jax.random.PRNGKey(9)
    x = jax.random.normal(key, (1, 9, 9, 4))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 8))
    outs = []
    for sub in ("oracle", "interpret"):
        lp = plan_conv_layer((9, 9), 4, 3, 8, relu=True,
                             policy=ExecutionPolicy(substrate=sub))
        outs.append(np.asarray(run_conv2d(lp, x, w)))
    np.testing.assert_allclose(outs[0], outs[1], rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# shared launcher CLI -> policy
# ---------------------------------------------------------------------------


def test_cli_parent_maps_to_policy():
    import argparse
    from repro.launch.cli import execution_parent, policy_from_args
    ap = argparse.ArgumentParser(parents=[execution_parent(
        arch_choices=("vgg16", "alexnet"), arch_default="vgg16")])
    args = ap.parse_args([])
    assert policy_from_args(args) == ExecutionPolicy()
    args = ap.parse_args(["--substrate", "interpret", "--emulate-hw"])
    assert policy_from_args(args) == ExecutionPolicy(
        substrate="interpret", emulate_hw=True)
    # --tuning maps onto ExecutionPolicy.tuning like --substrate does
    args = ap.parse_args(["--tuning", "cached"])
    assert policy_from_args(args) == ExecutionPolicy(tuning="cached")
    # the deprecated alias stores "pallas" into the same dest, and warns
    with pytest.warns(DeprecationWarning, match="force-pallas"):
        args = ap.parse_args(["--force-pallas", "--int8"])
    assert policy_from_args(args).substrate == "pallas"
    assert args.int8
    args = ap.parse_args(["--arch", "alexnet"])
    assert args.arch == "alexnet"
