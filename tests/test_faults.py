"""The fault-injection plane + self-healing serving (DESIGN.md §11).

Covers: FaultPlan parsing/validation and the deterministic budgets,
RetryPolicy's replayable crc32 jitter and the with_retries driver, the
CircuitBreaker state machine, PackedWire integrity (flip detection,
restore-from-master, bit-identity of restored params — a flipped int5
payload is structurally unservable), inline chaos on a fake clock
(transient staging faults, NaN batches, latency spikes: extended
conservation + bit-exact served results), breaker-driven int5 -> int8
degradation whose outputs are bit-identical to a native int8 server's,
the zero-cost-off contract (an unarmed server's snapshot carries none
of the resilience keys), and the threaded chaos property test (producer
threads under worker crashes + stage faults: extended conservation,
unique terminal statuses, bit-exact served results, watchdog restart —
deadlock-guarded, runtime-sanitized, retrace-sentineled).
"""
import faulthandler
import threading

import numpy as np
import pytest

import jax

from repro.configs import CNN_SMOKES
from repro.data.pipeline import SyntheticRequestStream
from repro.engine import ExecutionPolicy, plan_model
from repro.serve import (CircuitBreaker, FaultPlan, Lane, PackedWire,
                         RetryPolicy, Server, ServeConfig, TransientFault,
                         WorkerCrash)
from repro.serve.faults import with_retries
from tools.analysis.runtime import sanitize_server

CFG = CNN_SMOKES["vgg16"]

#: resilience counters that must NOT appear in a faults-off snapshot
RESILIENCE_KEYS = {"failed", "retried", "degraded", "worker_restarts",
                   "integrity_restored"}


class FakeClock:
    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += max(dt, 0.0)


def _stream(n=6, process="bursts", dtype="float32", seed=0, **kw):
    return SyntheticRequestStream(
        hw=CFG.input_hw, channels=CFG.layers[0].M, n_classes=CFG.n_classes,
        n_requests=n, seed=seed, process=process, dtype=dtype, **kw)


def _float_plan_params():
    plan = plan_model(CFG, ExecutionPolicy())
    return plan, plan.init(jax.random.PRNGKey(0))


def _int5_ladder_server(faults, buckets=(1, 4), clock=None, **cfgkw):
    """An int5 server with its full §11 ladder: PackedWire payload +
    an int8 fallback lane calibrated off the same float master (what
    ``launch.serve_cnn.build_server`` arms under ``--faults``)."""
    plan, params = _float_plan_params()
    calib = _stream(dtype="uint8").sample_batch(4)
    qparams, _ = plan.quantize_int5(params)
    requant = plan.calibrate_requant_int5(qparams, calib)
    q8, _ = plan.quantize(params)
    fallbacks = [Lane("int8", "int8", q8, plan.calibrate_requant(q8, calib))]
    cfg = ServeConfig(buckets=buckets, datapath="int5", faults=faults,
                      **cfgkw)
    kw = {}
    if clock is not None:
        kw = dict(clock=clock, sleep=clock.sleep)
    return Server.from_plan(plan, qparams, cfg, requant=requant,
                            fallbacks=fallbacks,
                            wire=PackedWire(CFG, params), **kw)


@pytest.fixture
def deadlock_guard():
    """A stuck thread must fail the suite fast, not hang CI (pytest-
    timeout covers this in CI; faulthandler covers local runs)."""
    faulthandler.dump_traceback_later(180, exit=True)
    yield
    faulthandler.cancel_dump_traceback_later()


# ---------------------------------------------------------------------------
# FaultPlan: the seeded chaos schedule
# ---------------------------------------------------------------------------


def test_fault_plan_parse_aliases_and_describe():
    plan = FaultPlan.parse(
        "seed=7,stage=2,worker=1,bitflip=1,latency=2,latency-ms=25")
    assert plan.seed == 7
    assert plan.stage_faults == 2 and plan.worker_crashes == 1
    assert plan.bitflips == 1 and plan.latency_spikes == 2
    assert plan.latency_spike_ms == 25.0
    assert plan.total_budget == 6
    d = plan.describe()
    assert d["seed"] == 7 and d["stage_faults"] == 2
    assert "exec_faults" not in d  # zero budgets stay out of the stamp


def test_fault_plan_parse_rejects_unknown_and_negative():
    with pytest.raises(ValueError, match="unknown --faults"):
        FaultPlan.parse("seed=1,frobnicate=3")
    with pytest.raises(ValueError):
        FaultPlan.parse("stage=-1")


def test_fault_plan_empty_spec_is_armed_but_inert():
    plan = FaultPlan.parse("seed=9")
    assert plan.total_budget == 0


# ---------------------------------------------------------------------------
# RetryPolicy: bounded backoff with replayable jitter
# ---------------------------------------------------------------------------


def test_retry_delay_is_deterministic_and_grows():
    pol = RetryPolicy(max_attempts=4, backoff_s=0.01, multiplier=2.0,
                      jitter=0.5, seed=3)
    d = [pol.delay(k, salt="x") for k in range(3)]
    assert d == [pol.delay(k, salt="x") for k in range(3)]  # replayable
    assert d[0] != pol.delay(0, salt="y")  # salted
    for k, dk in enumerate(d):
        base = 0.01 * 2.0 ** k
        assert base <= dk <= base * 1.5


def test_with_retries_recovers_transients_and_reraises_exhausted():
    clk = FakeClock()
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise TransientFault("boom")
        return "ok"

    pol = RetryPolicy(max_attempts=3, backoff_s=0.01)
    assert with_retries(flaky, pol, sleep=clk.sleep, salt="t") == "ok"
    assert len(calls) == 3 and clk.t > 0

    def always():
        raise TransientFault("never")

    with pytest.raises(TransientFault):
        with_retries(always, pol, sleep=clk.sleep, salt="t")


def test_with_retries_never_retries_worker_crash():
    calls = []

    def crash():
        calls.append(1)
        raise WorkerCrash("dead")

    with pytest.raises(WorkerCrash):
        with_retries(crash, RetryPolicy(max_attempts=5),
                     sleep=lambda s: None, salt="w")
    assert len(calls) == 1  # a dead thread cannot retry itself


# ---------------------------------------------------------------------------
# CircuitBreaker: closed -> open, success resets, open is permanent
# ---------------------------------------------------------------------------


def test_breaker_trips_once_at_threshold_and_stays_open():
    br = CircuitBreaker(threshold=3)
    assert [br.failure("k") for _ in range(3)] == [False, False, True]
    assert br.tripped("k")
    assert br.failure("k") is False  # open key never re-trips
    assert not br.tripped("other")


def test_breaker_success_resets_the_count():
    br = CircuitBreaker(threshold=2)
    assert br.failure("k") is False
    br.success("k")
    assert br.failure("k") is False  # count restarted
    assert br.failure("k") is True


# ---------------------------------------------------------------------------
# PackedWire: checksummed int5 payload, restore-from-master
# ---------------------------------------------------------------------------


def test_packed_wire_verifies_flips_and_restores():
    plan, params = _float_plan_params()
    wire = PackedWire(CFG, params)
    assert wire.verify() == []
    ref = wire.qparams()

    wire.flip_bit(0, 13)
    assert wire.verify() == [0]
    restored = []
    wire.on_restore = restored.append
    fixed = wire.qparams()  # verify-first: decode never sees the flip
    assert restored == [1] and wire.verify() == []
    for a, b in zip(ref["conv"], fixed["conv"]):
        np.testing.assert_array_equal(a["kernel"], b["kernel"])
        np.testing.assert_array_equal(a["shift"], b["shift"])


def test_packed_wire_params_match_plan_quantize_int5():
    """Restored/materialized wire params are bit-identical to the plan's
    own quantization — §9.3's requant calibration stays valid through an
    integrity restore (no recalibration needed)."""
    plan, params = _float_plan_params()
    wire = PackedWire(CFG, params)
    qparams, _ = plan.quantize_int5(params)
    got = wire.qparams()
    assert len(got["conv"]) == len(qparams["conv"])
    for w, q in zip(got["conv"], qparams["conv"]):
        np.testing.assert_array_equal(np.asarray(w["kernel"]),
                                      np.asarray(q["kernel"]))
        np.testing.assert_array_equal(np.asarray(w["shift"]),
                                      np.asarray(q["shift"]))


# ---------------------------------------------------------------------------
# inline chaos on the fake clock: conservation + bit-exactness
# ---------------------------------------------------------------------------


def test_inline_chaos_serves_bit_exact_with_conservation():
    """Transient staging faults, one NaN batch, one latency spike: every
    request still serves, retries are counted, and every served result
    is the bit-exact unbatched answer."""
    plan, params = _float_plan_params()
    clk = FakeClock()
    cfg = ServeConfig(
        buckets=(1, 4), faults=FaultPlan.parse(
            "seed=5,stage=2,nonfinite=1,latency=1"))
    srv = Server.from_plan(plan, params, cfg, clock=clk, sleep=clk.sleep)
    stream = _stream(n=6)
    metrics = srv.run_stream(stream)
    srv.close()
    tot = metrics.snapshot()["totals"]
    assert tot["submitted"] == 6 == tot["images"]
    assert tot.get("failed", 0) == 0
    assert tot["retried"] >= 3  # 2 stage faults + the NaN batch redo
    assert (tot["images"] + tot["shed"] + tot["expired"]
            + tot.get("failed", 0)) == tot["submitted"]
    imgs = list(_stream(n=6))
    for r, (_, img, _) in zip(metrics.requests, imgs):
        assert r.status == "served"
        np.testing.assert_array_equal(
            r.result, srv.engine.infer(img[None])[0])
    assert srv.engine.injector.exhausted()


def test_inline_chaos_latency_spike_can_expire_requests():
    """A latency spike pushes queued work past its per-request deadline:
    the spiked batch still serves, but conservation must absorb the
    expiry — no request may vanish."""
    plan, params = _float_plan_params()
    clk = FakeClock()
    cfg = ServeConfig(
        buckets=(1,), request_timeout_ms=20.0,
        faults=FaultPlan.parse("seed=2,latency=1,latency-ms=100"))
    srv = Server.from_plan(plan, params, cfg, clock=clk, sleep=clk.sleep)
    metrics = srv.run_stream(_stream(n=4, process="uniform", rate_hz=1e3))
    srv.close()
    tot = metrics.snapshot()["totals"]
    assert (tot["images"] + tot["shed"] + tot["expired"]
            + tot.get("failed", 0)) == tot["submitted"] == 4


# ---------------------------------------------------------------------------
# degradation: breaker trips int5 -> int8, bit-identical to native int8
# ---------------------------------------------------------------------------


def test_degradation_int5_to_int8_is_bit_identical(retrace_sentinel):
    """Persistent executable faults on the primary int5 lane trip the
    breaker; the bucket degrades to the int8 fallback lane and KEEPS
    SERVING — and every degraded output is bit-identical to what a
    native int8 server computes.  A planned bit-flip rides along: the
    trip-time integrity sweep restores the wire payload from the fp32
    master (counted, never served)."""
    faults = FaultPlan.parse("seed=4,exec=2,bitflip=1")
    clk = FakeClock()
    srv = _int5_ladder_server(faults, buckets=(1,), clock=clk,
                              breaker_threshold=2)
    retrace_sentinel.arm()  # every lane x bucket compiled at warmup
    stream = _stream(n=3, dtype="uint8")
    metrics = srv.run_stream(stream)
    srv.close()
    snap = metrics.snapshot()
    tot = snap["totals"]
    assert tot["images"] == 3 == tot["submitted"]
    assert tot.get("failed", 0) == 0
    assert tot["degraded"] == 1
    assert tot["integrity_restored"] >= 1
    key = f"{CFG.name} int5 n1"
    assert snap["degraded_lanes"] == {key: "int8"}
    assert srv.engine.lane_of(1).name == "int8"
    # compile-once held through the trip: one executable per lane/bucket
    assert all(v == 1 for v in srv.engine.compile_counts.values())
    # bit-identity with the int8 lane's own engine
    int8_lane = srv.engine.lanes[1]
    plan, _ = _float_plan_params()
    from repro.serve import ServeEngine
    eng8 = ServeEngine.build_for_plan(
        plan, int8_lane.params, buckets=(1,), datapath="int8",
        requant=int8_lane.requant)
    for r, (_, img, _) in zip(metrics.requests,
                          _stream(n=3, dtype="uint8")):
        assert r.status == "served"
        np.testing.assert_array_equal(r.result, eng8.infer(img[None])[0])


def test_flipped_payload_is_restored_before_serving():
    """A bit-flip with no executable faults: the next materialization's
    verify-first sweep restores the payload — the flipped bytes are
    never decoded into servable weights, and outputs stay bit-exact."""
    faults = FaultPlan.parse("seed=8,bitflip=1")
    clk = FakeClock()
    srv = _int5_ladder_server(faults, buckets=(1,), clock=clk)
    ref = [srv.engine.infer(img[None])[0]
           for _, img, _ in _stream(n=3, dtype="uint8")]
    metrics = srv.run_stream(_stream(n=3, dtype="uint8"))
    srv.close()
    tot = metrics.snapshot()["totals"]
    assert tot["images"] == 3 and tot.get("failed", 0) == 0
    assert tot["integrity_restored"] >= 1
    assert srv.engine.wire.verify() == []
    for r, want in zip(metrics.requests, ref):
        np.testing.assert_array_equal(r.result, want)


# ---------------------------------------------------------------------------
# zero-cost-off: an unarmed server's snapshot carries no resilience keys
# ---------------------------------------------------------------------------


def test_faults_off_snapshot_has_no_resilience_keys():
    plan, params = _float_plan_params()
    clk = FakeClock()
    srv = Server.from_plan(plan, params, ServeConfig(buckets=(1, 4)),
                           clock=clk, sleep=clk.sleep)
    snap = srv.run_stream(_stream(n=6)).snapshot()
    srv.close()
    assert not RESILIENCE_KEYS & set(snap["totals"])
    assert "degraded_lanes" not in snap
    assert srv.engine.injector is None


def test_armed_but_empty_plan_matches_fault_free_snapshot():
    """`--faults seed=N` with every budget zero: the plane is armed but
    inert — the run's snapshot is identical (modulo nothing) to a
    fault-free server's on the same fake-clock stream."""
    plan, params = _float_plan_params()

    def run(cfg):
        clk = FakeClock()
        srv = Server.from_plan(plan, params, cfg, clock=clk,
                               sleep=clk.sleep)
        snap = srv.run_stream(_stream(n=6)).snapshot()
        srv.close()
        return snap

    plain = run(ServeConfig(buckets=(1, 4)))
    armed = run(ServeConfig(buckets=(1, 4),
                            faults=FaultPlan.parse("seed=6")))
    assert plain == armed


# ---------------------------------------------------------------------------
# threaded chaos: worker crashes + stage faults under producer threads
# ---------------------------------------------------------------------------


def test_threaded_chaos_conserves_and_serves_bit_exact(deadlock_guard,
                                                       retrace_sentinel):
    """Property: N producers through an armed fault plane (one worker
    crash mid-batch, transient stage faults) still conserve requests
    exactly — served + shed + expired + failed == submitted, every
    request terminal exactly once, unique rids — and every served
    result is the bit-exact unbatched answer.  The watchdog must have
    replaced the crashed worker (the queue drains).  Runs under the
    runtime sanitizer: lock-order cycles or unguarded cv-state access
    in the crash/restart interleaving fail the test."""
    plan, params = _float_plan_params()
    cfg = ServeConfig(buckets=(1, 4), max_delay_ms=2.0,
                      faults=FaultPlan.parse("seed=11,worker=1,stage=2"))
    srv = Server.from_plan(plan, params, cfg)
    registry = sanitize_server(srv)
    retrace_sentinel.arm()
    n_threads, per_thread = 4, 8
    results = [[] for _ in range(n_threads)]

    def producer(k):
        imgs = _stream(n=per_thread, seed=k).sample_batch(per_thread)
        for i in range(per_thread):
            results[k].append(srv.submit(imgs[i]))

    threads = [threading.Thread(target=producer, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer thread deadlocked"
    srv.drain()
    srv.close()
    reqs = [r for rs in results for r in rs]
    assert len(reqs) == n_threads * per_thread
    assert all(r.done.is_set() for r in reqs)
    statuses = [r.status for r in reqs]
    assert statuses.count("pending") == 0
    tot = srv.metrics.snapshot()["totals"]
    assert tot["submitted"] == len(reqs)
    assert (statuses.count("served") + statuses.count("shed")
            + statuses.count("expired")
            + statuses.count("failed")) == len(reqs)
    assert tot["images"] == statuses.count("served")
    assert tot.get("failed", 0) == statuses.count("failed")
    rids = [r.rid for r in reqs]
    assert len(set(rids)) == len(rids), "duplicate request ids"
    # the crash fired iff its batch was in flight; when it did, the
    # watchdog must have restarted the worker and the failed requests
    # must carry the crash in their error
    fired = srv.engine.injector.fired
    if fired["worker"]:
        assert tot.get("worker_restarts", 0) >= 1
    for r in reqs:
        if r.status == "failed":
            assert r.error and r.result is None
    assert all(v == 1 for v in srv.engine.compile_counts.values())
    assert registry.errors == [], registry.errors
    for k in range(n_threads):
        imgs = _stream(n=per_thread, seed=k).sample_batch(per_thread)
        for i, r in enumerate(results[k]):
            if r.status == "served":
                np.testing.assert_array_equal(
                    r.result, srv.engine.infer(imgs[i:i + 1])[0])
