"""End-to-end system behaviour: training converges on structured data,
fault-tolerant resume is exact, NaN steps are skipped, straggler detection
fires, and the integer CNN datapath matches the bit-faithful engine."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_smoke
from repro.core.trim.engine import TrimEngine
from repro.data import SyntheticLMDataset
from repro.distributed import (StepConfig, StragglerMonitor, TrainLoopConfig,
                               make_train_state, make_train_step, train_loop)
from repro.kernels.ops import trim_conv2d
from repro.nn.models import build_model


def test_training_learns_structure():
    """A tiny model on the synthetic Markov stream: loss must drop well
    below the uniform-entropy floor within a few dozen steps."""
    cfg = get_smoke("starcoder2-3b").with_overrides(vocab=64, vocab_pad_to=64)
    model = build_model(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, StepConfig(
        peak_lr=3e-3, warmup_steps=10, total_steps=80)))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=33, global_batch=16)
    out = train_loop(step, state, ds, TrainLoopConfig(
        total_steps=80, ckpt_dir=None, log_every=1000))
    first = np.mean([h["loss"] for h in out["history"][:5]])
    last = np.mean([h["loss"] for h in out["history"][-5:]])
    assert first > last + 0.5, (first, last)  # clearly learning


def test_resume_is_exact():
    """Checkpoint at step k, then resume: the continued run reproduces the
    uninterrupted run bit-for-bit (deterministic data + saved opt state)."""
    cfg = get_smoke("granite-3-2b")
    model = build_model(cfg)
    scfg = StepConfig(peak_lr=1e-3, warmup_steps=2, total_steps=20)
    step = jax.jit(make_train_step(model, scfg))
    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=17, global_batch=4)

    ref_state = make_train_state(model, jax.random.PRNGKey(0))
    uninterrupted = train_loop(step, ref_state, ds, TrainLoopConfig(
        total_steps=10, ckpt_dir=None, log_every=1000))

    with tempfile.TemporaryDirectory() as d:
        s = make_train_state(model, jax.random.PRNGKey(0))
        train_loop(step, s, ds, TrainLoopConfig(
            total_steps=6, ckpt_every=3, ckpt_dir=d, log_every=1000))
        resumed = train_loop(step, make_train_state(
            model, jax.random.PRNGKey(1)),  # WRONG init: must be overwritten
            ds, TrainLoopConfig(total_steps=10, ckpt_every=100,
                                ckpt_dir=d, log_every=1000))
    assert resumed["resumed_from"] == 6
    ref_tail = [h["loss"] for h in uninterrupted["history"][6:]]
    res_tail = [h["loss"] for h in resumed["history"]]
    np.testing.assert_allclose(res_tail, ref_tail, rtol=1e-6)


def test_nan_step_skipped():
    """A poisoned batch (loss -> NaN) must leave params untouched and set
    the skipped flag; the next clean step proceeds."""
    cfg = get_smoke("granite-3-2b")
    model = build_model(cfg)

    class Poisoned:
        def __init__(self, m):
            self.m = m

        def loss(self, params, batch):
            loss, mets = self.m.loss(params, batch)
            bad = (batch["tokens"][0, 0] == 0)
            return jnp.where(bad, jnp.nan, loss), mets

    pm = Poisoned(model)
    state = make_train_state(model, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(pm, StepConfig(warmup_steps=1,
                                                  total_steps=10)))
    rng = np.random.default_rng(0)
    toks = rng.integers(1, cfg.vocab, (2, 17)).astype(np.int32)
    bad = toks.copy()
    bad[0, 0] = 0
    s1, m1 = step(state, {"tokens": jnp.asarray(bad)})
    assert float(m1["skipped"]) == 1.0
    d = jax.tree_util.tree_reduce(
        lambda a, b: a + float(jnp.abs(b).sum()),
        jax.tree.map(lambda a, b: (a - b).astype(jnp.float32),
                     s1["params"], state["params"]), 0.0)
    assert d == 0.0
    s2, m2 = step(s1, {"tokens": jnp.asarray(toks)})
    assert float(m2["skipped"]) == 0.0
    assert np.isfinite(float(m2["loss"]))


def test_straggler_monitor():
    m = StragglerMonitor(z_threshold=3.0)
    for s in range(20):
        m.observe(s, 0.1 + 0.001 * (s % 3))
    assert not m.flagged
    assert m.observe(20, 1.5)          # 15x slower -> flagged
    assert m.flagged[0]["step"] == 20


def test_int8_cnn_path_matches_engine():
    """The TPU-kernel integer datapath == the bit-faithful TrIM engine for
    one conv layer (same uint8/int8/int32 arithmetic, different machines)."""
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (6, 12, 12), dtype=np.uint8)     # (M, H, W)
    w = rng.integers(-127, 128, (4, 6, 3, 3)).astype(np.int8)  # (N, M, K, K)
    eng_out, _ = TrimEngine().run_layer(x, w)
    x_nhwc = jnp.asarray(x.transpose(1, 2, 0))[None]
    w_hwio = jnp.asarray(w.transpose(2, 3, 1, 0))
    from repro.engine import ExecutionPolicy
    kern_out = trim_conv2d(x_nhwc, w_hwio,
                           policy=ExecutionPolicy(substrate="pallas"))
    np.testing.assert_array_equal(
        np.asarray(kern_out[0]).transpose(2, 0, 1), eng_out)
