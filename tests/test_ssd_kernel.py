"""TrIM-SSD Pallas kernel vs the chunked-scan oracle (shape/chunk sweep +
hypothesis property)."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.trim_ssd import ssd_ref, trim_ssd_pallas


def _case(rng, B, L, H, P, S):
    return (jnp.asarray(rng.normal(size=(B, L, H, P)), jnp.float32),
            jnp.asarray(rng.uniform(1e-3, 0.1, (B, L, H)), jnp.float32),
            jnp.asarray(-rng.uniform(0.3, 2, (H,)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, L, H, S)), jnp.float32),
            jnp.asarray(rng.normal(size=(B, L, H, S)), jnp.float32),
            jnp.asarray(rng.normal(size=(H,)), jnp.float32))


CASES = [
    # (B, L, H, P, S, chunk)
    (2, 37, 3, 8, 16, 8),      # ragged chunks
    (1, 64, 2, 4, 8, 16),
    (2, 16, 1, 8, 8, 16),      # single chunk
    (1, 128, 2, 16, 32, 32),
]


@pytest.mark.parametrize("case", CASES, ids=str)
def test_ssd_kernel_sweep(case):
    B, L, H, P, S, CS = case
    rng = np.random.default_rng(sum(case))
    args = _case(rng, B, L, H, P, S)
    y = trim_ssd_pallas(*args, chunk=CS, interpret=True)
    r = ssd_ref(*args, chunk=CS)
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


@settings(max_examples=8, deadline=None)
@given(L=st.integers(2, 60), CS=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
def test_ssd_kernel_property(L, CS, seed):
    rng = np.random.default_rng(seed)
    args = _case(rng, 1, L, 2, 4, 8)
    y = trim_ssd_pallas(*args, chunk=CS, interpret=True)
    # oracle at a DIFFERENT chunking must agree (chunking is math-neutral)
    r = ssd_ref(*args, chunk=max(CS // 2, 2))
    np.testing.assert_allclose(np.asarray(y), np.asarray(r), rtol=5e-5,
                               atol=5e-5)


def test_ssd_kernel_bf16():
    rng = np.random.default_rng(3)
    x, dt, A, Bm, Cm, D = _case(rng, 1, 32, 2, 8, 8)
    y16 = trim_ssd_pallas(x.astype(jnp.bfloat16), dt, A,
                          Bm.astype(jnp.bfloat16), Cm.astype(jnp.bfloat16),
                          D, chunk=16, interpret=True)
    r = ssd_ref(x, dt, A, Bm, Cm, D, chunk=16)
    np.testing.assert_allclose(np.asarray(y16, np.float32), np.asarray(r),
                               rtol=5e-2, atol=5e-2)
