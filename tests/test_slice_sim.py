"""Cycle-level slice simulator: the triangular movement's contracts.

These are the paper's §II/§III-A claims at operand granularity:
1. every padded input element is fetched from external memory exactly once
   per pass (the single-fetch guarantee -> ~1.8% overhead for 3x3/224^2);
2. RSRB consumption order == push order (a shift register suffices — no
   random addressing);
3. the steady-state tap delay is a constant depending only on the sweep
   width (why the RSRB needs run-time reconfigurability, Fig. 4);
4. RSRB occupancy never exceeds the padded width (the W_IM sizing rule).
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.trim.slice_sim import (expected_external_fetches,
                                       padding_overhead, simulate_slice)
from repro.core.trim.engine import reference_conv_layer


def test_overhead_quote():
    assert padding_overhead(224, 224, 3) == pytest.approx(0.01794, abs=2e-4)


@settings(max_examples=15, deadline=None)
@given(H=st.integers(5, 18), W=st.integers(5, 18),
       K=st.sampled_from([3, 5]), seed=st.integers(0, 2**31 - 1))
def test_slice_contracts(H, W, K, seed):
    rng = np.random.default_rng(seed)
    x = rng.integers(0, 256, (H, W)).astype(np.int64)
    w = rng.integers(-8, 8, (K, K))
    r = simulate_slice(x, w)
    # 1. single-fetch guarantee
    assert r.external_fetches == expected_external_fetches(H, W, K)
    # 2. FIFO order
    assert r.fifo_order_ok
    # 3. constant steady tap
    assert r.interior_tap_constant
    # 4. occupancy bound: within the padded width
    assert r.max_rsrb_occupancy <= (W + 2 * (K // 2)) + K
    # correctness of the computed outputs
    ref = reference_conv_layer(x[None].astype(np.uint8),
                               w[None, None].astype(np.int8), pad=K // 2)[0]
    np.testing.assert_array_equal(r.outputs, ref.astype(np.int64))


def test_tap_delay_tracks_width():
    """The RSRB tap moves with the ifmap width and nothing else — the
    reconfigurability requirement of Fig. 4."""
    x = np.ones((10, 12), np.int64)
    w = np.ones((3, 3), np.int64)
    d12 = simulate_slice(x, w).steady_tap_delay
    d20 = simulate_slice(np.ones((10, 20), np.int64), w).steady_tap_delay
    assert d12 is not None and d20 is not None
    assert d20 - d12 == 8  # delay == sweep width - const
