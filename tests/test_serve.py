"""The shared serving core (repro.serve, DESIGN.md §8).

Covers: the BucketBatcher state machine on a fake clock (size flush,
deadline flush, drain), pad_batch, the synthetic request stream's
determinism and arrival processes, the serving bit-identity property
(padded-and-bucketed output == unbatched N=1 output, float AND fused-int8
lanes), the compile-once guarantee (ServeEngine.compile_counts and the
engine-level EXECUTABLE_COMPILES ledger), the calibrated-requant
requirement on the int8 lane, the full serve_stream loop on a fake clock,
and ServeMetrics snapshot arithmetic.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.configs import CNN_SMOKES
from repro.data.pipeline import SyntheticRequestStream
from repro.engine import ExecutionPolicy, execute, plan_model
from repro.serve import (BucketBatcher, ServeEngine, ServeMetrics, pad_batch,
                         serve_stream)

CFG = CNN_SMOKES["vgg16"]


class FakeClock:
    """Deterministic clock + sleep pair for driving the serve loop."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += max(dt, 0.0)


def _stream(n=6, process="bursts", dtype="float32", seed=0, **kw):
    return SyntheticRequestStream(
        hw=CFG.input_hw, channels=CFG.layers[0].M, n_classes=CFG.n_classes,
        n_requests=n, seed=seed, process=process, dtype=dtype, **kw)


def _float_engine(buckets=(1, 4), warm=True):
    plan = plan_model(CFG, ExecutionPolicy())
    params = plan.init(jax.random.PRNGKey(0))
    return ServeEngine.for_model_plan(plan, params, buckets=buckets,
                                      warm=warm)


def _int8_engine(buckets=(1, 4)):
    plan = plan_model(CFG, ExecutionPolicy())
    params = plan.init(jax.random.PRNGKey(0))
    qparams, _ = plan.quantize(params)
    requant = plan.calibrate_requant(
        qparams, _stream(dtype="uint8").sample_batch(4))
    return ServeEngine.for_model_plan(plan, qparams, buckets=buckets,
                                      datapath="int8", requant=requant)


# ---------------------------------------------------------------------------
# BucketBatcher: the pad-and-bucket admission state machine
# ---------------------------------------------------------------------------


def test_batcher_size_flush():
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=1.0, clock=clk)
    assert b.poll() is None
    for _ in range(4):
        b.submit("img")
    bucket, reqs = b.poll()
    assert bucket == 4 and len(reqs) == 4
    assert b.depth == 0 and b.poll() is None


def test_batcher_deadline_flush():
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=0.01, clock=clk)
    b.submit("a")
    assert b.poll() is None  # under-full, deadline not expired
    assert b.next_deadline() == pytest.approx(0.01)
    clk.t = 0.02
    bucket, reqs = b.poll()
    assert bucket == 2 and len(reqs) == 1  # padded into the smallest cover


def test_batcher_drain_and_bucket_for():
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=10.0, clock=clk)
    for _ in range(3):
        b.submit("x")
    bucket, reqs = b.poll(force=True)
    assert bucket == 4 and len(reqs) == 3
    assert b.bucket_for(1) == 2 and b.bucket_for(3) == 4


@settings(max_examples=10)
@given(n=st.integers(min_value=0, max_value=12))
def test_batcher_conserves_requests(n):
    """Property: every submitted request comes back out exactly once, in
    order, whatever mix of size- and force-flushes drains the queue."""
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=10.0, clock=clk)
    rids = [b.submit(i).rid for i in range(n)]
    out = []
    while True:
        got = b.poll(force=True)
        if got is None:
            break
        bucket, reqs = got
        assert len(reqs) <= bucket
        out.extend(r.rid for r in reqs)
    assert out == rids and b.depth == 0


def test_pad_batch_zero_pads():
    imgs = [np.ones((4, 4, 3), np.float32) * (i + 1) for i in range(3)]
    out = pad_batch(imgs, 4)
    assert out.shape == (4, 4, 4, 3)
    np.testing.assert_array_equal(out[:3], np.stack(imgs))
    np.testing.assert_array_equal(out[3], 0)


# ---------------------------------------------------------------------------
# SyntheticRequestStream: deterministic arrival-timed requests
# ---------------------------------------------------------------------------


def test_stream_deterministic_in_seed():
    a, b = _stream(process="poisson", seed=3), _stream(process="poisson",
                                                       seed=3)
    for (ta, xa, la), (tb, xb, lb) in zip(a, b):
        assert ta == tb and la == lb
        np.testing.assert_array_equal(xa, xb)
    assert not np.array_equal(_stream(process="poisson", seed=4)
                              .arrival_times(), a.arrival_times())


def test_stream_arrival_processes():
    uni = _stream(n=5, process="uniform", rate_hz=10.0).arrival_times()
    np.testing.assert_allclose(uni, np.arange(5) / 10.0)
    poi = _stream(n=8, process="poisson").arrival_times()
    assert poi[0] == 0.0 and (np.diff(poi) >= 0).all() and poi[-1] > 0
    bur = _stream(n=7, process="bursts", burst_sizes=(1, 2),
                  gap_s=0.5).arrival_times()
    # bursts cycle (1, 2): instants 0.0, 0.5, 1.0, ... carry 1,2,1,2,... reqs
    np.testing.assert_allclose(bur, [0.0, 0.5, 0.5, 1.0, 1.5, 1.5, 2.0])


def test_stream_uint8_dtype_for_int8_lane():
    img, _ = _stream(dtype="uint8").image_at(0)
    assert img.dtype == np.uint8
    assert _stream().image_at(0)[0].dtype == np.float32


# ---------------------------------------------------------------------------
# the serving bit-identity property (the reason serve_forward exists)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("datapath", ["float", "int8"])
@pytest.mark.parametrize("n", [1, 3, 4])
def test_bucketed_equals_unbatched_bitwise(datapath, n):
    """Padded-and-bucketed inference is bit-identical, per image, to the
    unbatched N=1 path — on the float lane (per-image FC head via
    serve_forward) and the fused-int8 lane (calibrated requant)."""
    eng = _float_engine() if datapath == "float" else _int8_engine()
    imgs = _stream(dtype="uint8" if datapath == "int8" else "float32"
                   ).sample_batch(n)
    batched = eng.infer(imgs)
    assert batched.shape[0] == n
    for i in range(n):
        single = eng.infer(imgs[i:i + 1])
        np.testing.assert_array_equal(batched[i], single[0])


def test_serve_forward_matches_training_forward_numerically():
    """serve_forward reorders only the FC head's accumulation (per-image
    lax.map), so it must agree with the training forward to float tolerance
    and produce identical argmax classes."""
    plan = plan_model(CFG, ExecutionPolicy())
    params = plan.init(jax.random.PRNGKey(0))
    x = _stream().sample_batch(2)
    a = np.asarray(execute.forward(plan, params, x))
    b = np.asarray(execute.serve_forward(plan, params, x))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))


# ---------------------------------------------------------------------------
# compile-once: the no-retrace guarantee
# ---------------------------------------------------------------------------


def test_engine_compiles_each_bucket_exactly_once():
    eng = _float_engine(buckets=(1, 4))
    assert len(eng.compile_counts) == 2
    # repeated warmup + serving traffic never rebuilds an executable
    eng.warmup()
    for _ in range(3):
        eng.infer(_stream().sample_batch(3))
    assert all(v == 1 for v in eng.compile_counts.values())
    # the engine-seam ledger agrees: every (plan, batch, datapath) compiled
    # at most once for the life of the process
    assert all(v == 1 for v in execute.EXECUTABLE_COMPILES.values())


def test_executable_keys_are_device_stamped():
    eng = _float_engine(buckets=(1,))
    backend = jax.default_backend()
    (key,) = eng.compile_counts
    assert key.startswith(f"{backend}-")
    assert key.endswith("n1")


def test_int8_engine_requires_calibrated_requant():
    plan = plan_model(CFG, ExecutionPolicy())
    params = plan.init(jax.random.PRNGKey(0))
    qparams, _ = plan.quantize(params)
    with pytest.raises(ValueError, match="requant"):
        ServeEngine.for_model_plan(plan, qparams, buckets=(1,),
                                   datapath="int8")


def test_infer_rejects_oversized_batch():
    eng = _float_engine(buckets=(1, 4))
    with pytest.raises(ValueError, match="exceeds"):
        eng.infer(_stream().sample_batch(5))


# ---------------------------------------------------------------------------
# the open-loop serve driver on a fake clock
# ---------------------------------------------------------------------------


def test_serve_stream_flushes_every_bucket_and_serves_all():
    clk = FakeClock()
    eng = _float_engine(buckets=(1, 4))
    stream = _stream(n=10, process="bursts", burst_sizes=(1, 4), gap_s=0.1)
    metrics = serve_stream(eng, stream, max_delay_s=0.01, clock=clk,
                           sleep=clk.sleep)
    assert metrics.total_images == 10
    for b in eng.buckets:
        assert metrics.flushes(b) >= 1, f"bucket {b} never flushed"
    assert all(r.result is not None for r in metrics.requests)
    assert all(v == 1 for v in eng.compile_counts.values())
    assert metrics.wall_s and metrics.wall_s > 0
    # every request's served result is the unbatched answer for its image
    for r, (t, img, label) in zip(metrics.requests, _stream(n=10)):
        np.testing.assert_array_equal(
            r.result, eng.infer(img[None])[0])


def test_serve_stream_deadline_flush_under_trickle():
    """A trickle below every bucket size still ships: the deadline flush
    pads each request into the smallest bucket within max_delay."""
    clk = FakeClock()
    eng = _float_engine(buckets=(4,))
    stream = _stream(n=3, process="uniform", rate_hz=10.0)  # 100 ms apart
    metrics = serve_stream(eng, stream, max_delay_s=0.005, clock=clk,
                           sleep=clk.sleep)
    assert metrics.total_images == 3
    assert metrics.flushes(4) == 3  # each arrival aged out alone
    snap = metrics.snapshot()
    assert snap["per_bucket"]["4"]["pad_waste"] == pytest.approx(0.75)
    # latency = queueing delay (deadline) + engine time, never negative
    assert snap["per_bucket"]["4"]["p50_ms"] >= 5.0


# ---------------------------------------------------------------------------
# metrics arithmetic
# ---------------------------------------------------------------------------


def test_metrics_snapshot_arithmetic():
    m = ServeMetrics(buckets=(1, 4))
    m.record_flush(4, 3, batch_s=0.01, latencies_s=[0.011, 0.012, 0.013],
                   queue_depth=2)
    m.record_flush(1, 1, batch_s=0.002, latencies_s=[0.003])
    m.wall_s = 0.1
    snap = m.snapshot()
    assert m.total_images == 4 and m.flushes(4) == 1
    b4 = snap["per_bucket"]["4"]
    assert b4["images"] == 3 and b4["pad_waste"] == 0.25
    assert b4["images_per_s"] == pytest.approx(300.0)
    assert b4["queue_depth_max"] == 2
    tot = snap["totals"]
    assert tot["images"] == 4 and tot["flushes"] == 2
    assert tot["pad_waste"] == pytest.approx(1 / 5)
    assert tot["images_per_s"] == pytest.approx(40.0)
    assert tot["p99_ms"] >= tot["p50_ms"] > 0


def test_metrics_write_wraps_extra_stamps(tmp_path):
    import json
    m = ServeMetrics(buckets=(1,))
    m.record_flush(1, 1, batch_s=0.001, latencies_s=[0.001])
    path = tmp_path / "metrics.json"
    payload = m.write(str(path), extra={"arch": "vgg16-smoke"})
    on_disk = json.load(open(path))
    assert on_disk == payload
    assert on_disk["arch"] == "vgg16-smoke"
    assert on_disk["metrics"]["per_bucket"]["1"]["images"] == 1
