"""The shared serving core (repro.serve, DESIGN.md §8).

Covers: the BucketBatcher state machine on a fake clock (size flush,
deadline flush, drain, the submit-timestamp clamp), pad_batch, the
synthetic request stream's determinism and arrival processes, the serving
bit-identity property (padded-and-bucketed output == unbatched N=1
output, float AND the fused int8/int5 lanes), the compile-once guarantee
(compile_counts and the engine-level EXECUTABLE_COMPILES ledger), the
calibrated-requant requirement on the int8 lane, the Server facade —
inline open loop on a fake clock, overload policies (block/shed/degrade),
per-request deadline expiry, threaded admission with a real flush worker
(request conservation under N producer threads, deadlock guarded by
faulthandler + joined-with-timeout), the deprecation shims
(serve_stream / for_model_plan: warn AND produce identical metrics), and
ServeMetrics snapshot arithmetic incl. the admission counters.
"""
import faulthandler
import threading

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax

from repro.configs import CNN_SMOKES
from repro.data.pipeline import SyntheticRequestStream
from repro.engine import ExecutionPolicy, execute, plan_model
from repro.serve import (BucketBatcher, Request, ServeConfig, ServeEngine,
                         ServeMetrics, Server, pad_batch, serve_stream,
                         stamp_payload)
from tools.analysis.runtime import sanitize_server

CFG = CNN_SMOKES["vgg16"]


class FakeClock:
    """Deterministic clock + sleep pair for driving the serve loop."""

    def __init__(self):
        self.t = 0.0

    def __call__(self) -> float:
        return self.t

    def sleep(self, dt: float) -> None:
        self.t += max(dt, 0.0)


def _stream(n=6, process="bursts", dtype="float32", seed=0, **kw):
    return SyntheticRequestStream(
        hw=CFG.input_hw, channels=CFG.layers[0].M, n_classes=CFG.n_classes,
        n_requests=n, seed=seed, process=process, dtype=dtype, **kw)


def _float_plan_params():
    plan = plan_model(CFG, ExecutionPolicy())
    return plan, plan.init(jax.random.PRNGKey(0))


def _float_server(buckets=(1, 4), clock=None, sleep=None, **cfgkw):
    plan, params = _float_plan_params()
    cfg = ServeConfig(buckets=buckets, **cfgkw)
    kw = {}
    if clock is not None:
        kw = dict(clock=clock, sleep=sleep)
    return Server.from_plan(plan, params, cfg, **kw)


def _int8_server(buckets=(1, 4), **cfgkw):
    plan, params = _float_plan_params()
    qparams, _ = plan.quantize(params)
    requant = plan.calibrate_requant(
        qparams, _stream(dtype="uint8").sample_batch(4))
    cfg = ServeConfig(buckets=buckets, datapath="int8", **cfgkw)
    return Server.from_plan(plan, qparams, cfg, requant=requant)


def _int5_server(buckets=(1, 4), **cfgkw):
    plan, params = _float_plan_params()
    qparams, _ = plan.quantize_int5(params)
    requant = plan.calibrate_requant_int5(
        qparams, _stream(dtype="uint8").sample_batch(4))
    cfg = ServeConfig(buckets=buckets, datapath="int5", **cfgkw)
    return Server.from_plan(plan, qparams, cfg, requant=requant)


@pytest.fixture
def deadlock_guard():
    """A stuck thread must fail the suite fast, not hang CI: dump all
    stacks and hard-exit if a threaded test overruns (pytest-timeout
    covers this in CI; faulthandler covers minimal local environments)."""
    faulthandler.dump_traceback_later(180, exit=True)
    yield
    faulthandler.cancel_dump_traceback_later()


# ---------------------------------------------------------------------------
# BucketBatcher: the pad-and-bucket admission state machine
# ---------------------------------------------------------------------------


def test_batcher_size_flush():
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=1.0, clock=clk)
    assert b.poll() is None
    for _ in range(4):
        b.submit("img")
    bucket, reqs = b.poll()
    assert bucket == 4 and len(reqs) == 4
    assert b.depth == 0 and b.poll() is None


def test_batcher_deadline_flush():
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=0.01, clock=clk)
    b.submit("a")
    assert b.poll() is None  # under-full, deadline not expired
    assert b.next_deadline() == pytest.approx(0.01)
    clk.t = 0.02
    bucket, reqs = b.poll()
    assert bucket == 2 and len(reqs) == 1  # padded into the smallest cover


def test_batcher_drain_and_bucket_for():
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=10.0, clock=clk)
    for _ in range(3):
        b.submit("x")
    bucket, reqs = b.poll(force=True)
    assert bucket == 4 and len(reqs) == 3
    assert b.bucket_for(1) == 2 and b.bucket_for(3) == 4


def test_batcher_submit_clamps_backwards_timestamp():
    """Regression: a caller-supplied `now` behind the monotone clock used
    to make the deadline flush fire early (a backdated t_submit ages out
    instantly); one ahead of the clock made it fire late or never.  Both
    are clamped into [previous submit, clock()]."""
    clk = FakeClock()
    clk.t = 1.0
    b = BucketBatcher(buckets=(4,), max_delay_s=0.01, clock=clk)
    # Backdated below the batcher's monotone floor (construction at t=1.0):
    # an unclamped t_submit=0.0 would have expired its deadline already.
    r = b.submit("a", now=0.0)
    assert r.t_submit == 1.0
    assert b.poll() is None  # NOT an instant deadline flush
    assert b.next_deadline() == pytest.approx(1.01)
    # Future timestamp: unclamped, next_deadline would sit at 100.01 and
    # the oldest-request contract ("ships within max_delay_s") would slip.
    clk.t = 1.005
    r2 = b.submit("b", now=100.0)
    assert r2.t_submit == pytest.approx(1.005)
    # Behind the previous submit: clamps up to the queue's monotone floor.
    r3 = b.submit("c", now=1.001)
    assert r3.t_submit >= r2.t_submit
    clk.t = 1.02
    bucket, reqs = b.poll()  # q[0]'s (clamped) deadline has now passed
    assert len(reqs) == 3


def test_batcher_purge_expired_on_fake_clock():
    clk = FakeClock()
    b = BucketBatcher(buckets=(4,), max_delay_s=10.0, clock=clk)
    b.submit("a", deadline_s=0.05)
    keep = b.submit("b")  # no deadline: never expires
    b.submit("c", deadline_s=0.2)
    assert b.purge_expired() == []
    clk.t = 0.1
    expired = b.purge_expired()
    assert [r.payload for r in expired] == ["a"]
    assert b.depth == 2
    clk.t = 0.3
    assert [r.payload for r in b.purge_expired()] == ["c"]
    assert b.depth == 1 and b.poll(force=True)[1] == [keep]


@settings(max_examples=10)
@given(n=st.integers(min_value=0, max_value=12))
def test_batcher_conserves_requests(n):
    """Property: every submitted request comes back out exactly once, in
    order, whatever mix of size- and force-flushes drains the queue."""
    clk = FakeClock()
    b = BucketBatcher(buckets=(2, 4), max_delay_s=10.0, clock=clk)
    rids = [b.submit(i).rid for i in range(n)]
    out = []
    while True:
        got = b.poll(force=True)
        if got is None:
            break
        bucket, reqs = got
        assert len(reqs) <= bucket
        out.extend(r.rid for r in reqs)
    assert out == rids and b.depth == 0


def test_pad_batch_zero_pads():
    imgs = [np.ones((4, 4, 3), np.float32) * (i + 1) for i in range(3)]
    out = pad_batch(imgs, 4)
    assert out.shape == (4, 4, 4, 3)
    np.testing.assert_array_equal(out[:3], np.stack(imgs))
    np.testing.assert_array_equal(out[3], 0)


# ---------------------------------------------------------------------------
# ServeConfig: the frozen serving policy object
# ---------------------------------------------------------------------------


def test_serve_config_frozen_hashable_and_normalized():
    a = ServeConfig(buckets=(4, 1, 4), overload="shed", queue_capacity=8)
    b = ServeConfig(buckets=(1, 4), overload="shed", queue_capacity=8)
    assert a == b and hash(a) == hash(b)
    assert a.buckets == (1, 4)
    assert a.max_delay_s == pytest.approx(0.005)
    with pytest.raises(ValueError, match="overload"):
        ServeConfig(overload="panic")
    with pytest.raises(ValueError, match="buckets"):
        ServeConfig(buckets=())
    with pytest.raises(ValueError, match="datapath"):
        ServeConfig(datapath="int4")
    with pytest.raises(ValueError, match="queue_capacity"):
        ServeConfig(queue_capacity=-1)


def test_serve_config_from_cli_args():
    """The shared launcher flags (launch.cli.serving_parent) map through
    ServeConfig.from_args — one mapping for both serving launchers."""
    import argparse

    from repro.launch.cli import serving_parent

    ap = argparse.ArgumentParser(parents=[serving_parent()])
    args = ap.parse_args(
        ["--buckets", "1,8", "--max-delay-ms", "2.5", "--queue-capacity",
         "32", "--overload", "degrade", "--request-timeout-ms", "40"])
    args.int8 = True
    cfg = ServeConfig.from_args(args)
    assert cfg == ServeConfig(buckets=(1, 8), max_delay_ms=2.5,
                              queue_capacity=32, overload="degrade",
                              datapath="int8", request_timeout_ms=40.0)
    # overrides pin fields a launcher's CLI does not expose (LM: --batch)
    assert ServeConfig.from_args(args, buckets=(4,),
                                 datapath="float").buckets == (4,)


# ---------------------------------------------------------------------------
# SyntheticRequestStream: deterministic arrival-timed requests
# ---------------------------------------------------------------------------


def test_stream_deterministic_in_seed():
    a, b = _stream(process="poisson", seed=3), _stream(process="poisson",
                                                       seed=3)
    for (ta, xa, la), (tb, xb, lb) in zip(a, b):
        assert ta == tb and la == lb
        np.testing.assert_array_equal(xa, xb)
    assert not np.array_equal(_stream(process="poisson", seed=4)
                              .arrival_times(), a.arrival_times())


def test_stream_arrival_processes():
    uni = _stream(n=5, process="uniform", rate_hz=10.0).arrival_times()
    np.testing.assert_allclose(uni, np.arange(5) / 10.0)
    poi = _stream(n=8, process="poisson").arrival_times()
    assert poi[0] == 0.0 and (np.diff(poi) >= 0).all() and poi[-1] > 0
    bur = _stream(n=7, process="bursts", burst_sizes=(1, 2),
                  gap_s=0.5).arrival_times()
    # bursts cycle (1, 2): instants 0.0, 0.5, 1.0, ... carry 1,2,1,2,... reqs
    np.testing.assert_allclose(bur, [0.0, 0.5, 0.5, 1.0, 1.5, 1.5, 2.0])


def test_stream_uint8_dtype_for_int8_lane():
    img, _ = _stream(dtype="uint8").image_at(0)
    assert img.dtype == np.uint8
    assert _stream().image_at(0)[0].dtype == np.float32


# ---------------------------------------------------------------------------
# the serving bit-identity property (the reason serve_forward exists)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("datapath", ["float", "int8", "int5"])
@pytest.mark.parametrize("n", [1, 3, 4])
def test_bucketed_equals_unbatched_bitwise(datapath, n):
    """Padded-and-bucketed inference is bit-identical, per image, to the
    unbatched N=1 path — on the float lane (per-image FC head via
    serve_forward) and the fused integer lanes (calibrated requant; int5
    is the MSR weight lane, DESIGN.md §9.3)."""
    srv = {"float": _float_server, "int8": _int8_server,
           "int5": _int5_server}[datapath]()
    eng = srv.engine
    imgs = _stream(dtype="float32" if datapath == "float" else "uint8"
                   ).sample_batch(n)
    batched = eng.infer(imgs)
    assert batched.shape[0] == n
    for i in range(n):
        single = eng.infer(imgs[i:i + 1])
        np.testing.assert_array_equal(batched[i], single[0])


def test_serve_forward_matches_training_forward_numerically():
    """serve_forward reorders only the FC head's accumulation (per-image
    lax.map), so it must agree with the training forward to float tolerance
    and produce identical argmax classes."""
    plan, params = _float_plan_params()
    x = _stream().sample_batch(2)
    a = np.asarray(execute.forward(plan, params, x))
    b = np.asarray(execute.serve_forward(plan, params, x))
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)
    np.testing.assert_array_equal(a.argmax(-1), b.argmax(-1))


# ---------------------------------------------------------------------------
# compile-once: the no-retrace guarantee
# ---------------------------------------------------------------------------


def test_engine_compiles_each_bucket_exactly_once():
    srv = _float_server(buckets=(1, 4))
    eng = srv.engine
    assert len(eng.compile_counts) == 2
    # repeated warmup + serving traffic never rebuilds an executable
    eng.warmup()
    for _ in range(3):
        eng.infer(_stream().sample_batch(3))
    assert all(v == 1 for v in eng.compile_counts.values())
    # the engine-seam ledger agrees: every (plan, batch, datapath) compiled
    # at most once for the life of the process
    assert all(v == 1 for v in execute.EXECUTABLE_COMPILES.values())


def test_executable_keys_are_device_stamped():
    srv = _float_server(buckets=(1,))
    backend = jax.default_backend()
    (key,) = srv.engine.compile_counts
    assert key.startswith(f"{backend}-")
    assert key.endswith("n1")


def test_int8_server_requires_calibrated_requant():
    plan, params = _float_plan_params()
    qparams, _ = plan.quantize(params)
    with pytest.raises(ValueError, match="requant"):
        Server.from_plan(plan, qparams,
                         ServeConfig(buckets=(1,), datapath="int8"))


def test_infer_rejects_oversized_batch():
    srv = _float_server(buckets=(1, 4))
    with pytest.raises(ValueError, match="exceeds"):
        srv.engine.infer(_stream().sample_batch(5))


# ---------------------------------------------------------------------------
# the Server facade: inline open loop on a fake clock
# ---------------------------------------------------------------------------


def test_run_stream_inline_flushes_every_bucket_and_serves_all():
    clk = FakeClock()
    srv = _float_server(buckets=(1, 4), clock=clk, sleep=clk.sleep,
                        max_delay_ms=10.0)
    stream = _stream(n=10, process="bursts", burst_sizes=(1, 4), gap_s=0.1)
    metrics = srv.run_stream(stream)
    assert metrics.total_images == 10
    for b in srv.engine.buckets:
        assert metrics.flushes(b) >= 1, f"bucket {b} never flushed"
    assert all(r.result is not None for r in metrics.requests)
    assert all(r.status == "served" for r in metrics.requests)
    assert all(v == 1 for v in srv.engine.compile_counts.values())
    assert metrics.wall_s and metrics.wall_s > 0
    tot = metrics.snapshot()["totals"]
    assert tot["submitted"] == 10 and tot["shed"] == 0 and tot["expired"] == 0
    # every request's served result is the unbatched answer for its image
    for r, (t, img, label) in zip(metrics.requests, _stream(n=10)):
        np.testing.assert_array_equal(
            r.result, srv.engine.infer(img[None])[0])


def test_run_stream_inline_deadline_flush_under_trickle():
    """A trickle below every bucket size still ships: the deadline flush
    pads each request into the smallest bucket within max_delay."""
    clk = FakeClock()
    srv = _float_server(buckets=(4,), clock=clk, sleep=clk.sleep,
                        max_delay_ms=5.0)
    stream = _stream(n=3, process="uniform", rate_hz=10.0)  # 100 ms apart
    metrics = srv.run_stream(stream)
    assert metrics.total_images == 3
    assert metrics.flushes(4) == 3  # each arrival aged out alone
    snap = metrics.snapshot()
    assert snap["per_bucket"]["4"]["pad_waste"] == pytest.approx(0.75)
    # latency = queueing delay (deadline) + engine time, never negative
    assert snap["per_bucket"]["4"]["p50_ms"] >= 5.0


def test_overload_shed_rejects_past_capacity():
    """shed: a full admission queue rejects instead of queueing — the
    request comes back terminal (status 'shed', done set, no result), and
    conservation (served + shed == submitted) holds at drain."""
    clk = FakeClock()
    srv = _float_server(buckets=(4,), clock=clk, sleep=clk.sleep,
                        max_delay_ms=1e6, queue_capacity=2, overload="shed")
    # burst of 6 at one instant: 2 admitted (the bucket never fills, the
    # deadline never fires, so nothing drains the queue mid-burst), then
    # the queue is full and the remaining 4 are shed; the end-of-stream
    # drain serves the 2 queued ones
    stream = _stream(n=6, process="bursts", burst_sizes=(6,), gap_s=1.0)
    metrics = srv.run_stream(stream)
    tot = metrics.snapshot()["totals"]
    assert tot["submitted"] == 6
    assert tot["images"] == 2 and tot["shed"] == 4
    shed = [r for r in metrics.requests if r.status == "shed"]
    assert len(shed) == tot["shed"]
    assert all(r.done.is_set() and r.result is None for r in shed)
    rids = [r.rid for r in metrics.requests]
    assert len(set(rids)) == len(rids)


def test_overload_degrade_ships_smaller_buckets_eagerly():
    """degrade: over capacity, ship what is queued into the smallest
    covering bucket NOW instead of waiting to fill the largest."""
    clk = FakeClock()
    srv = _float_server(buckets=(2, 8), clock=clk, sleep=clk.sleep,
                        max_delay_ms=1e6, queue_capacity=2,
                        overload="degrade")
    stream = _stream(n=8, process="bursts", burst_sizes=(8,), gap_s=1.0)
    metrics = srv.run_stream(stream)
    tot = metrics.snapshot()["totals"]
    assert tot["images"] == 8 and tot["shed"] == 0
    # the full-size bucket never filled: everything shipped degraded
    assert metrics.flushes(2) == 4
    assert metrics.flushes(8) == 0


def test_overload_block_inline_caps_queue_depth():
    """block in the inline loop: the caller IS the flush worker, so
    hitting capacity drains synchronously — depth never exceeds cap and
    nothing is shed."""
    clk = FakeClock()
    srv = _float_server(buckets=(4,), clock=clk, sleep=clk.sleep,
                        max_delay_ms=1e6, queue_capacity=2,
                        overload="block")
    stream = _stream(n=6, process="bursts", burst_sizes=(6,), gap_s=1.0)
    metrics = srv.run_stream(stream)
    tot = metrics.snapshot()["totals"]
    assert tot["images"] == 6 and tot["shed"] == 0
    snap = metrics.snapshot()
    assert snap["per_bucket"]["4"]["queue_depth_max"] <= 2


def test_request_timeout_expires_queued_work():
    """Per-request deadlines: work still queued past its deadline is
    expired (no result, status 'expired'), never served stale."""
    clk = FakeClock()
    srv = _float_server(buckets=(4,), clock=clk, sleep=clk.sleep,
                        max_delay_ms=1e6,  # deadline flush disabled
                        request_timeout_ms=5.0)
    stream = _stream(n=3, process="uniform", rate_hz=10.0)  # 100 ms apart
    metrics = srv.run_stream(stream)
    tot = metrics.snapshot()["totals"]
    # the first two requests sat queued past their 5 ms deadline while the
    # loop slept to the next arrival; the last one was still fresh at the
    # end-of-stream drain and is served, not dropped
    assert tot["expired"] == 2 and tot["images"] == 1
    expired = [r for r in metrics.requests if r.status == "expired"]
    assert len(expired) == 2
    assert all(r.result is None for r in expired)
    assert metrics.requests[-1].status == "served"
    assert tot["images"] + tot["shed"] + tot["expired"] == tot["submitted"]


# ---------------------------------------------------------------------------
# threaded admission: producer threads + the dedicated flush worker
# ---------------------------------------------------------------------------


def test_threaded_submit_conserves_requests(deadlock_guard, retrace_sentinel):
    """Property: N producer threads submitting concurrently conserve
    requests exactly — served + shed + expired == submitted, every
    request terminal, no duplicate rids — under a bounded queue with the
    shed policy (real clock, real flush worker).  Runs under the runtime
    sanitizer: lock-order cycles or unguarded cv-state access anywhere in
    the producer/worker interleaving fail the test."""
    srv = _float_server(buckets=(1, 4), max_delay_ms=2.0,
                        queue_capacity=8, overload="shed")
    registry = sanitize_server(srv)
    retrace_sentinel.arm()          # engine warmed at construction
    n_threads, per_thread = 4, 12
    results = [[] for _ in range(n_threads)]

    def producer(k):
        imgs = _stream(n=per_thread, seed=k).sample_batch(per_thread)
        for i in range(per_thread):
            results[k].append(srv.submit(imgs[i]))

    threads = [threading.Thread(target=producer, args=(k,))
               for k in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
        assert not t.is_alive(), "producer thread deadlocked"
    srv.drain()
    srv.close()
    reqs = [r for rs in results for r in rs]
    assert len(reqs) == n_threads * per_thread
    assert all(r.done.is_set() for r in reqs)
    statuses = [r.status for r in reqs]
    assert statuses.count("pending") == 0
    tot = srv.metrics.snapshot()["totals"]
    assert tot["submitted"] == len(reqs)
    assert (statuses.count("served") + statuses.count("shed")
            + statuses.count("expired")) == len(reqs)
    assert tot["images"] == statuses.count("served")
    assert tot["shed"] == statuses.count("shed")
    assert tot["expired"] == statuses.count("expired")
    rids = [r.rid for r in reqs]
    assert len(set(rids)) == len(rids), "duplicate request ids"
    assert all(v == 1 for v in srv.engine.compile_counts.values())
    assert registry.errors == [], registry.errors
    # served results are the bit-exact unbatched answers
    for k in range(n_threads):
        imgs = _stream(n=per_thread, seed=k).sample_batch(per_thread)
        for i, r in enumerate(results[k]):
            if r.status == "served":
                np.testing.assert_array_equal(
                    r.result, srv.engine.infer(imgs[i:i + 1])[0])


def test_threaded_run_stream_serves_all_and_overlaps(deadlock_guard,
                                                     retrace_sentinel):
    """Saturating load through producer threads: everything is served
    (block policy), compile-once holds, and the flush worker's
    double-buffered staging actually overlapped transfers with compute
    (overlapped > 0 — with a deep queue every non-first dispatch finds a
    prior bucket still in flight).  Sanitized: the saturating block-policy
    path exercises the cv-wait/notify edges hardest."""
    srv = _float_server(buckets=(1, 4), max_delay_ms=5.0)
    registry = sanitize_server(srv)
    retrace_sentinel.arm()
    stream = _stream(n=48, process="bursts", burst_sizes=(48,), gap_s=0.0)
    metrics = srv.run_stream(stream, producers=4)
    srv.close()
    tot = metrics.snapshot()["totals"]
    assert tot["images"] == 48 == tot["submitted"]
    assert tot["shed"] == 0 and tot["expired"] == 0
    assert tot["overlapped"] >= 1
    assert all(v == 1 for v in srv.engine.compile_counts.values())
    assert metrics.wall_s and metrics.wall_s > 0
    assert registry.errors == [], registry.errors


def test_threaded_expiry_and_closed_submit(deadlock_guard):
    """The worker expires pre-expired queued work instead of serving it,
    and a closed Server rejects new submissions.  Sanitized: close() walks
    the full drain/join/teardown edge of the lock protocol."""
    srv = _float_server(buckets=(4,), max_delay_ms=1.0)
    registry = sanitize_server(srv)
    srv.start()
    r = srv.submit(_stream().sample_batch(1)[0], deadline_s=-1.0)
    assert r.done.wait(30), "expiry never delivered"
    assert r.status == "expired" and r.result is None
    srv.close()
    with pytest.raises(RuntimeError, match="closed"):
        srv.submit(_stream().sample_batch(1)[0])
    assert registry.errors == [], registry.errors


# ---------------------------------------------------------------------------
# deprecation shims: serve_stream / for_model_plan warn and delegate
# ---------------------------------------------------------------------------


def test_for_model_plan_shim_warns_and_matches_facade():
    plan, params = _float_plan_params()
    with pytest.warns(DeprecationWarning, match="for_model_plan"):
        eng = ServeEngine.for_model_plan(plan, params, buckets=(1, 4))
    srv = Server.from_plan(plan, params, ServeConfig(buckets=(1, 4)))
    assert isinstance(eng, ServeEngine)
    assert eng.buckets == srv.engine.buckets
    assert set(eng.compile_counts) == set(srv.engine.compile_counts)
    imgs = _stream().sample_batch(3)
    np.testing.assert_array_equal(eng.infer(imgs), srv.engine.infer(imgs))


def test_serve_stream_shim_warns_and_metrics_identical():
    """The old open-loop entry point must keep producing byte-identical
    metrics through the Server facade it now delegates to."""
    stream_kw = dict(n=10, process="bursts", burst_sizes=(1, 4), gap_s=0.1)
    plan, params = _float_plan_params()

    clk_old = FakeClock()
    with pytest.warns(DeprecationWarning, match="serve_stream"):
        eng = ServeEngine.build_for_plan(plan, params, buckets=(1, 4))
        old = serve_stream(eng, _stream(**stream_kw), max_delay_s=0.01,
                           clock=clk_old, sleep=clk_old.sleep)

    clk_new = FakeClock()
    srv = Server.from_plan(plan, params,
                           ServeConfig(buckets=(1, 4), max_delay_ms=10.0),
                           clock=clk_new, sleep=clk_new.sleep)
    new = srv.run_stream(_stream(**stream_kw))
    assert old.snapshot() == new.snapshot()
    for a, b in zip(old.requests, new.requests):
        assert a.status == b.status == "served"
        np.testing.assert_array_equal(a.result, b.result)


# ---------------------------------------------------------------------------
# metrics arithmetic + the serve JSON schema header
# ---------------------------------------------------------------------------


def test_metrics_snapshot_arithmetic():
    m = ServeMetrics(buckets=(1, 4))
    m.record_flush(4, 3, batch_s=0.01, latencies_s=[0.011, 0.012, 0.013],
                   queue_depth=2)
    m.record_flush(1, 1, batch_s=0.002, latencies_s=[0.003])
    m.wall_s = 0.1
    snap = m.snapshot()
    assert m.total_images == 4 and m.flushes(4) == 1
    b4 = snap["per_bucket"]["4"]
    assert b4["images"] == 3 and b4["pad_waste"] == 0.25
    assert b4["images_per_s"] == pytest.approx(300.0)
    assert b4["queue_depth_max"] == 2
    tot = snap["totals"]
    assert tot["images"] == 4 and tot["flushes"] == 2
    assert tot["pad_waste"] == pytest.approx(1 / 5)
    assert tot["images_per_s"] == pytest.approx(40.0)
    assert tot["p99_ms"] >= tot["p50_ms"] > 0


def test_metrics_admission_counters():
    m = ServeMetrics(buckets=(1,))
    for _ in range(5):
        m.record_submit()
    m.record_shed()
    m.record_expired(2)
    m.record_overlap()
    tot = m.snapshot()["totals"]
    assert tot["submitted"] == 5 and tot["shed"] == 1
    assert tot["expired"] == 2 and tot["overlapped"] == 1


def test_metrics_write_stamps_schema_header(tmp_path):
    """Every serve JSON artifact carries schema_version + the same
    backend/device_kind header the BENCH artifacts do, from ONE writer
    (stamp_payload) — compare.py machine-scopes without sniffing."""
    import json

    from repro.serve.metrics import SCHEMA_VERSION

    m = ServeMetrics(buckets=(1,))
    m.record_flush(1, 1, batch_s=0.001, latencies_s=[0.001])
    path = tmp_path / "metrics.json"
    payload = m.write(str(path), extra={"arch": "vgg16-smoke"})
    on_disk = json.load(open(path))
    assert on_disk == payload
    assert on_disk["arch"] == "vgg16-smoke"
    assert on_disk["schema_version"] == SCHEMA_VERSION
    assert on_disk["backend"] == jax.default_backend()
    assert on_disk["device_kind"] == jax.devices()[0].device_kind
    assert on_disk["metrics"]["per_bucket"]["1"]["images"] == 1
    # the bench writer shares the same header rule
    bench = stamp_payload({"section": "serve", "records": []})
    assert bench["schema_version"] == SCHEMA_VERSION
    assert bench["backend"] == on_disk["backend"]


def test_request_handle_defaults():
    r = Request(0, "x", 0.0)
    assert r.status == "pending" and not r.done.is_set()
    assert r.deadline_s is None
