"""Substrate units: optimizer, schedules, data pipeline, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step, restore_pytree,
                              save_pytree)
from repro.data import SyntheticImageDataset, SyntheticLMDataset
from repro.data.pipeline import FileTokenDataset
from repro.optim import (AdamWConfig, adamw_init, adamw_update,
                         clip_by_global_norm, warmup_cosine)


# -- optimizer ---------------------------------------------------------------

def test_adamw_minimizes_quadratic():
    params = {"w": jnp.array([5.0, -3.0]), "norm/scale": jnp.array([2.0])}
    opt = adamw_init(params)
    cfg = AdamWConfig(weight_decay=0.0, clip_norm=None)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2)
                     + jnp.sum((p["norm/scale"] - 1) ** 2))(params)
        params, opt, _ = adamw_update(g, opt, params, 0.05, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert float(jnp.abs(params["norm/scale"] - 1).max()) < 0.05


def test_weight_decay_skips_norm_and_bias():
    params = {"dense/kernel": jnp.ones((2,)), "norm/scale": jnp.ones((2,)),
              "dense/bias": jnp.ones((2,))}
    opt = adamw_init(params)
    zeros = jax.tree.map(jnp.zeros_like, params)
    cfg = AdamWConfig(weight_decay=0.5, clip_norm=None)
    new, _, _ = adamw_update(zeros, opt, params, 0.1, cfg)
    assert float(new["dense/kernel"][0]) < 1.0       # decayed
    assert float(new["norm/scale"][0]) == 1.0        # not decayed
    assert float(new["dense/bias"][0]) == 1.0        # not decayed


def test_clip_by_global_norm():
    tree = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(tree, 1.0)
    assert float(norm) == pytest.approx(20.0)
    got = float(jnp.linalg.norm(clipped["a"]))
    assert got == pytest.approx(1.0, rel=1e-5)


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(jnp.asarray(s), peak_lr=1.0, warmup_steps=10,
                               total_steps=100)) for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert max(lrs) <= 1.0
    assert lrs[99] < 0.2


# -- data ----------------------------------------------------------------------

def test_lm_data_deterministic_and_sharded():
    full = SyntheticLMDataset(vocab=97, seq_len=16, global_batch=8)
    again = SyntheticLMDataset(vocab=97, seq_len=16, global_batch=8)
    np.testing.assert_array_equal(full.batch_at(3)["tokens"],
                                  again.batch_at(3)["tokens"])
    # two hosts see disjoint halves that concatenate to the global batch
    h0 = SyntheticLMDataset(vocab=97, seq_len=16, global_batch=8,
                            n_hosts=2, host_id=0)
    h1 = SyntheticLMDataset(vocab=97, seq_len=16, global_batch=8,
                            n_hosts=2, host_id=1)
    both = np.concatenate([h0.batch_at(3)["tokens"],
                           h1.batch_at(3)["tokens"]])
    np.testing.assert_array_equal(both, full.batch_at(3)["tokens"])
    # learnable structure: the period-4 copy holds for ~98% of positions
    t = full.batch_at(0)["tokens"]
    assert t.min() >= 0 and t.max() < 97
    match = (t[:, 4:] == t[:, :-4]).mean()
    assert match > 0.9


def test_lm_data_batches_differ_across_steps():
    ds = SyntheticLMDataset(vocab=97, seq_len=16, global_batch=4)
    assert not np.array_equal(ds.batch_at(0)["tokens"],
                              ds.batch_at(1)["tokens"])


def test_image_data():
    ds = SyntheticImageDataset(hw=(8, 8), channels=3, n_classes=4,
                               global_batch=4)
    b = ds.batch_at(0)
    assert b["images"].shape == (4, 8, 8, 3)
    assert b["labels"].shape == (4,)


def test_file_dataset_roundtrip(tmp_path):
    arr = np.arange(1000, dtype=np.int32)
    path = os.path.join(tmp_path, "toks.npy")
    np.save(path, arr)
    ds = FileTokenDataset(path=path, seq_len=16, global_batch=4)
    b = ds.batch_at(0)["tokens"]
    np.testing.assert_array_equal(b[0], arr[:16])
    np.testing.assert_array_equal(b[1], arr[16:32])


# -- checkpoint ------------------------------------------------------------------

def test_checkpoint_roundtrip_dtypes(tmp_path):
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "nested": {"b": jnp.ones((3,), jnp.int32),
                       "c": jnp.zeros((2,), jnp.float32)},
            "step": jnp.asarray(7, jnp.int32)}
    d = os.path.join(tmp_path, "ck")
    save_pytree(tree, d)
    template = jax.tree.map(jnp.zeros_like, tree)
    out = restore_pytree(template, d)
    for a, b in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(out)):
        assert a.dtype == b.dtype
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_latest_step_skips_torn(tmp_path):
    base = str(tmp_path)
    mgr = CheckpointManager(base, keep_last=10, async_write=False)
    mgr.save({"x": jnp.ones(2)}, 5)
    mgr.save({"x": jnp.ones(2)}, 10)
    # simulate a torn write at step 15 (no COMMITTED marker)
    os.makedirs(os.path.join(base, "step_15"))
    with open(os.path.join(base, "step_15", "manifest.json"), "w") as f:
        f.write("{}")
    assert latest_step(base) == 10


def test_manager_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save({"x": jnp.ones(1)}, s)
    kept = sorted(n for n in os.listdir(tmp_path) if n.startswith("step_"))
    assert kept == ["step_3", "step_4"]


def test_restore_shape_mismatch_raises(tmp_path):
    d = os.path.join(tmp_path, "ck")
    save_pytree({"x": jnp.ones((2,))}, d)
    with pytest.raises(ValueError):
        restore_pytree({"x": jnp.ones((3,))}, d)
