"""Pallas kernel validation: shape/dtype sweeps + hypothesis properties,
interpret=True (the kernel body executes on CPU) vs the pure-jnp oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.engine import ExecutionPolicy
from repro.kernels import ref
from repro.kernels.ops import trim_conv2d
from repro.kernels.trim_conv1d import trim_conv1d_pallas
from repro.kernels.trim_conv2d import trim_conv2d_pallas
from repro.kernels.trim_matmul import trim_matmul_pallas

#: Pallas everywhere (interpret mode on CPU) — the old force-pallas mode.
PALLAS = ExecutionPolicy(substrate="pallas")
#: Same, with the FPGA-faithful strided-layer decimation replay (§V).
PALLAS_HW = ExecutionPolicy(substrate="pallas", emulate_hw=True)


# ---------------------------------------------------------------------------
# conv2d — the TrIM kernel
# ---------------------------------------------------------------------------

CONV2D_CASES = [
    # (N, H, W, C, K, F, tile_h, bc, bf)
    (1, 8, 8, 4, 3, 8, 4, 4, 8),
    (2, 16, 20, 8, 3, 16, 8, 8, 16),
    (1, 13, 13, 3, 3, 5, 4, 3, 5),       # odd sizes force padding
    (1, 12, 12, 4, 5, 8, 4, 4, 8),       # K=5
    (1, 9, 9, 2, 1, 4, 4, 2, 4),         # K=1 degenerate
    (2, 24, 24, 16, 3, 32, 8, 16, 32),
]


@pytest.mark.parametrize("case", CONV2D_CASES, ids=str)
def test_conv2d_float_sweep(case):
    N, H, W, C, K, F, th, bc, bf = case
    key = jax.random.PRNGKey(sum(case))
    x = jax.random.normal(key, (N, H, W, C), jnp.float32)
    w = jax.random.normal(key, (K, K, C, F), jnp.float32)
    out = trim_conv2d_pallas(x, w, tile_h=th, block_c=bc, block_f=bf,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv2d_ref(x, w)),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("case", CONV2D_CASES[:4], ids=str)
def test_conv2d_int_exact(case):
    """The paper's integer datapath: uint8 x int8 -> int32, bit-exact."""
    N, H, W, C, K, F, th, bc, bf = case
    key = jax.random.PRNGKey(sum(case))
    x = jax.random.randint(key, (N, H, W, C), 0, 255, jnp.uint8)
    w = jax.random.randint(key, (K, K, C, F), -127, 127, jnp.int8)
    out = trim_conv2d_pallas(x, w, tile_h=th, block_c=bc, block_f=bf,
                             interpret=True)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(ref.conv2d_ref(x, w)))


def test_conv2d_bf16_accumulates_f32():
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 8, 8, 64), jnp.bfloat16)
    w = jax.random.normal(key, (3, 3, 64, 8), jnp.bfloat16)
    out = trim_conv2d_pallas(x, w, tile_h=4, block_c=64, block_f=8,
                             interpret=True)
    want = ref.conv2d_ref(x.astype(jnp.float32), w.astype(jnp.float32))
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), rtol=2e-2, atol=2e-2)


def test_conv2d_stride_decimation():
    """Striding = stride-1 sweep + decimation (the hardware's behaviour)."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 16, 16, 4))
    w = jax.random.normal(key, (3, 3, 4, 8))
    out = trim_conv2d(x, w, stride=2, policy=PALLAS)
    want = ref.conv2d_ref(x, w, stride=2)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# conv1d — the Mamba short-conv kernel
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(B=st.integers(1, 3), L=st.integers(1, 70), D=st.integers(1, 40),
       K=st.integers(1, 6), tile=st.sampled_from([8, 16, 32]))
def test_conv1d_property(B, L, D, K, tile):
    key = jax.random.PRNGKey(B * 1000 + L * 10 + D + K)
    x = jax.random.normal(key, (B, L, D), jnp.float32)
    w = jax.random.normal(key, (K, D), jnp.float32)
    out = trim_conv1d_pallas(x, w, tile_l=tile, block_d=16, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv1d_causal_ref(x, w)),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# matmul — the K=1 degenerate TrIM (weight-stationary blocked)
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(M=st.integers(1, 200), K=st.integers(1, 120), N=st.integers(1, 150),
       bm=st.sampled_from([16, 32, 64]), bk=st.sampled_from([16, 64]))
def test_matmul_property(M, K, N, bm, bk):
    key = jax.random.PRNGKey(M + K * 7 + N * 13)
    a = jax.random.normal(key, (M, K), jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 1), (K, N), jnp.float32)
    out = trim_matmul_pallas(a, b, block_m=bm, block_n=32, block_k=bk,
                             interpret=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(a @ b),
                               rtol=2e-4, atol=2e-4)


def test_matmul_int8_exact():
    key = jax.random.PRNGKey(3)
    a = jax.random.randint(key, (64, 96), -127, 127, jnp.int8)
    b = jax.random.randint(key, (96, 48), -127, 127, jnp.int8)
    out = trim_matmul_pallas(a, b, block_m=32, block_n=32, block_k=32,
                             interpret=True)
    want = ref.matmul_ref(a, b)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_ops_cpu_fallback_matches_pallas():
    """ops.* dispatches to the oracle on CPU; the pallas policy must
    agree."""
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (1, 10, 10, 4))
    w = jax.random.normal(key, (3, 3, 4, 8))
    a = trim_conv2d(x, w)
    b = trim_conv2d(x, w, policy=PALLAS)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention — the §Perf memory-term kernel
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, H, Sq, D, bq, bk, causal)
    (2, 3, 64, 16, 16, 16, True),
    (1, 2, 33, 8, 16, 8, True),      # ragged seq vs blocks
    (2, 2, 40, 16, 16, 16, False),
    (1, 1, 128, 32, 64, 32, True),
]


@pytest.mark.parametrize("case", FLASH_CASES, ids=str)
def test_flash_pallas_sweep(case):
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               flash_attention_ref)
    B, H, S, D, bq, bk, causal = case
    key = jax.random.PRNGKey(sum(case))
    q = jax.random.normal(key, (B, H, S, D))
    k = jax.random.normal(jax.random.fold_in(key, 1), (B, H, S, D))
    v = jax.random.normal(jax.random.fold_in(key, 2), (B, H, S, D))
    o = flash_attention_pallas(q, k, v, causal=causal, block_q=bq,
                               block_k=bk, interpret=True)
    r = flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_flash_pallas_kv_length():
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               flash_attention_ref)
    key = jax.random.PRNGKey(9)
    q = jax.random.normal(key, (1, 2, 16, 8))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 16, 8))
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 16, 8))
    o = flash_attention_pallas(q, k, v, causal=False, kv_length=9,
                               block_q=8, block_k=8, interpret=True)
    r = flash_attention_ref(q, k, v, causal=False, kv_length=9)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), rtol=2e-5,
                               atol=2e-5)


def test_flash_pallas_bf16():
    from repro.kernels.flash_attention import (flash_attention_pallas,
                                               flash_attention_ref)
    key = jax.random.PRNGKey(11)
    q = jax.random.normal(key, (1, 2, 32, 16), jnp.bfloat16)
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 2, 32, 16),
                          jnp.bfloat16)
    v = jax.random.normal(jax.random.fold_in(key, 2), (1, 2, 32, 16),
                          jnp.bfloat16)
    o = flash_attention_pallas(q, k, v, causal=True, block_q=16, block_k=16,
                               interpret=True)
    r = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), rtol=2e-2,
                               atol=2e-2)


def test_conv2d_grouped():
    """Grouped conv (AlexNet's two-tower CL2/4/5): per-group Pallas calls
    == lax grouped-conv oracle."""
    key = jax.random.PRNGKey(7)
    x = jax.random.normal(key, (1, 10, 10, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 6))
    a = trim_conv2d(x, w, groups=2)
    b = trim_conv2d(x, w, groups=2, policy=PALLAS)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-5,
                               atol=2e-5)


# ---------------------------------------------------------------------------
# stride-aware fused conv2d (DESIGN.md §2): parity vs the oracle for
# stride x kernel x dtype x epilogue, computing only the strided outputs
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("K", [1, 3])
@pytest.mark.parametrize("fused", [False, True], ids=["plain", "fused"])
def test_conv2d_strided_float(stride, K, fused):
    key = jax.random.PRNGKey(stride * 10 + K)
    x = jax.random.normal(key, (2, 13, 13, 4), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (K, K, 4, 8),
                          jnp.float32)
    b = jax.random.normal(jax.random.fold_in(key, 2), (8,), jnp.float32)
    out = trim_conv2d_pallas(x, w, stride=stride,
                             bias=b if fused else None, relu=fused,
                             tile_h=4, block_c=4, block_f=8, interpret=True)
    want = ref.conv2d_ref(x, w, stride=stride)
    if fused:
        want = jnp.maximum(want + b, 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("stride", [1, 2, 4])
@pytest.mark.parametrize("K", [1, 3])
@pytest.mark.parametrize("fused", [False, True], ids=["plain", "fused"])
def test_conv2d_strided_int_exact(stride, K, fused):
    """uint8 x int8 -> int32 stays bit-exact through the strided kernel,
    with and without the fused bias/ReLU epilogue."""
    key = jax.random.PRNGKey(stride * 100 + K)
    x = jax.random.randint(key, (1, 13, 13, 4), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (K, K, 4, 8),
                           -127, 127, jnp.int8)
    b = jax.random.randint(jax.random.fold_in(key, 2), (8,),
                           -1000, 1000, jnp.int32)
    out = trim_conv2d_pallas(x, w, stride=stride,
                             bias=b if fused else None, relu=fused,
                             tile_h=4, block_c=4, block_f=8, interpret=True)
    want = ref.conv2d_ref(x, w, stride=stride)
    if fused:
        want = jnp.maximum(want + b, 0)
    assert out.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(out), np.asarray(want))


def test_conv2d_fused_requant_uint8():
    """Fused power-of-two requantization (the engine's output stage) returns
    uint8 bit-identical to the unfused relu >> shift >> clip pipeline."""
    key = jax.random.PRNGKey(5)
    x = jax.random.randint(key, (1, 12, 12, 4), 0, 255, jnp.uint8)
    w = jax.random.randint(jax.random.fold_in(key, 1), (3, 3, 4, 8),
                           -127, 127, jnp.int8)
    out = trim_conv2d_pallas(x, w, stride=2, relu=True, requant_shift=9,
                             tile_h=4, block_c=4, block_f=8, interpret=True)
    want = jnp.clip(jnp.right_shift(
        jnp.maximum(ref.conv2d_ref(x, w, stride=2), 0), 9), 0, 255)
    assert out.dtype == jnp.uint8
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(want, np.uint8))


def test_conv2d_alexnet_cl1_shape():
    """AlexNet CL1 structure (K=11, stride 4, no padding) on a reduced map:
    the hard case for the halo/index-map math (K >> stride)."""
    key = jax.random.PRNGKey(6)
    x = jax.random.normal(key, (1, 23, 23, 3), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (11, 11, 3, 8),
                          jnp.float32)
    out = trim_conv2d_pallas(x, w, stride=4, padding=0, tile_h=2,
                             block_c=3, block_f=8, interpret=True)
    want = ref.conv2d_ref(x, w, stride=4, padding=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_conv2d_emulate_hw_matches_fused():
    """The FPGA-faithful decimation schedule (§V) and the stride-aware
    kernel agree: same outputs, different work."""
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (1, 16, 16, 4))
    w = jax.random.normal(key, (3, 3, 4, 8))
    b = jax.random.normal(jax.random.fold_in(key, 1), (8,))
    hw = trim_conv2d(x, w, b, stride=2, relu=True, policy=PALLAS_HW)
    fused = trim_conv2d(x, w, b, stride=2, relu=True, policy=PALLAS)
    want = jnp.maximum(ref.conv2d_ref(x, w, stride=2) + b, 0)
    np.testing.assert_allclose(np.asarray(hw), np.asarray(want), rtol=2e-5,
                               atol=2e-5)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_conv2d_scratch_fallback_off_tpu(monkeypatch):
    """Regression: when the pltpu import fails (non-TPU jaxlib), the kernel
    must fall back to a backend-neutral scratch, not crash on pltpu.VMEM."""
    import importlib
    m = importlib.import_module("repro.kernels.trim_conv2d")
    monkeypatch.setattr(m, "pltpu", None)
    monkeypatch.setattr(m, "_VMEM", None)
    key = jax.random.PRNGKey(2)
    x = jax.random.normal(key, (1, 10, 10, 4))
    w = jax.random.normal(key, (3, 3, 4, 8))
    out = m.trim_conv2d_pallas(x, w, stride=2, interpret=True)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(ref.conv2d_ref(x, w, stride=2)),
                               rtol=2e-5, atol=2e-5)


def test_conv2d_grouped_fused_bias():
    """Grouped conv (AlexNet two-tower) with the fused epilogue: per-group
    bias slices land on the right filters."""
    key = jax.random.PRNGKey(8)
    x = jax.random.normal(key, (1, 10, 10, 8))
    w = jax.random.normal(jax.random.fold_in(key, 1), (3, 3, 4, 6))
    b = jax.random.normal(jax.random.fold_in(key, 2), (6,))
    a = trim_conv2d(x, w, b, groups=2, relu=True)
    p = trim_conv2d(x, w, b, groups=2, relu=True, policy=PALLAS)
    np.testing.assert_allclose(np.asarray(a), np.asarray(p), rtol=2e-5,
                               atol=2e-5)


def test_cnn_int8_fused_requant_parity():
    """Calibrated fused-requant int8 forward == dynamic-shift forward,
    bit-exact (the whole epilogue moves into the kernel flush)."""
    from repro.configs import CNN_SMOKES
    from repro.nn.conv import (calibrate_requant_shifts, cnn_forward_int8,
                               init_cnn, quantize_cnn)
    cfg = CNN_SMOKES["vgg16"]
    params = init_cnn(jax.random.PRNGKey(0), cfg)
    qp, _ = quantize_cnn(params, cfg)
    u8 = jax.random.randint(jax.random.PRNGKey(1), (1, 16, 16, 3), 0, 255,
                            jnp.uint8)
    dyn = cnn_forward_int8(qp, u8, cfg)
    shifts = calibrate_requant_shifts(qp, u8, cfg)
    fused = cnn_forward_int8(qp, u8, cfg, requant_shifts=shifts)
    np.testing.assert_array_equal(np.asarray(dyn), np.asarray(fused))


def test_conv2d_halo_taller_than_block():
    """Regression: K - stride > tile_h * stride (e.g. K=11 stride 1 with the
    default tile_h, or tiny maps where H_O < K) must auto-grow the row block
    instead of slicing past the assembled tile."""
    key = jax.random.PRNGKey(12)
    x = jax.random.normal(key, (1, 16, 16, 3), jnp.float32)
    w = jax.random.normal(jax.random.fold_in(key, 1), (11, 11, 3, 4),
                          jnp.float32)
    out = trim_conv2d_pallas(x, w, padding=0, tile_h=8, block_c=3,
                             block_f=4, interpret=True)  # halo 10 > RB 8
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.conv2d_ref(x, w, padding=0)),
        rtol=2e-5, atol=2e-5)
    # tiny map: H_O = 1 forces TH = 1 < K - 1
    x2 = jax.random.normal(key, (1, 3, 3, 2), jnp.float32)
    w2 = jax.random.normal(jax.random.fold_in(key, 2), (3, 3, 2, 4),
                           jnp.float32)
    out2 = trim_conv2d_pallas(x2, w2, padding=0, tile_h=8, block_c=2,
                              block_f=4, interpret=True)
    np.testing.assert_allclose(
        np.asarray(out2), np.asarray(ref.conv2d_ref(x2, w2, padding=0)),
        rtol=2e-5, atol=2e-5)
    # the emulate_hw decimate arm on an AlexNet-CL1-like layer hits the
    # stride-1 sweep with the default tile_h
    x3 = jax.random.normal(key, (1, 23, 23, 3))
    w3 = jax.random.normal(jax.random.fold_in(key, 3), (11, 11, 3, 4))
    hw = trim_conv2d(x3, w3, stride=4, padding=0, policy=PALLAS_HW)
    np.testing.assert_allclose(
        np.asarray(hw), np.asarray(ref.conv2d_ref(x3, w3, stride=4,
                                                  padding=0)),
        rtol=2e-5, atol=2e-5)


def test_cnn_int8_grouped_layers():
    """Regression: the int8 datapath derives groups from the running channel
    count (AlexNet two-tower layers), incl. the calibrated fused path."""
    from repro.core.trim.model import ConvLayerSpec
    from repro.nn.conv import (CNNConfig, calibrate_requant_shifts,
                               cnn_forward_int8)
    cfg = CNNConfig(
        "two-tower-smoke",
        layers=(ConvLayerSpec("CL1", 8, 8, 3, 4, 8),
                ConvLayerSpec("CL2", 8, 8, 3, 4, 8)),   # 8 chans / M=4 -> 2
        pool_after=(), classifier=(8,), n_classes=4, input_hw=(8, 8))
    key = jax.random.PRNGKey(13)
    qp = {"conv": [
        {"kernel": jax.random.randint(key, (3, 3, 4, 8), -127, 127,
                                      jnp.int8)},
        {"kernel": jax.random.randint(jax.random.fold_in(key, 1),
                                      (3, 3, 4, 8), -127, 127, jnp.int8)}]}
    u8 = jax.random.randint(jax.random.fold_in(key, 2), (1, 8, 8, 4), 0,
                            255, jnp.uint8)
    dyn = cnn_forward_int8(qp, u8, cfg)
    assert dyn.dtype == jnp.int32 and dyn.shape == (1, 8, 8, 8)
    shifts = calibrate_requant_shifts(qp, u8, cfg)
    fused = cnn_forward_int8(qp, u8, cfg, requant_shifts=shifts)
    np.testing.assert_array_equal(np.asarray(dyn), np.asarray(fused))
