"""Distributed semantics: logical sharding rules, multi-device equivalence
(run in subprocesses with forced host device counts), compression,
pipeline, and the scaled-down dry-run."""
import json
import os
import subprocess
import sys
import tempfile
import textwrap

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (activate_mesh, logical_to_spec,
                                        param_logical_axes)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_py(code: str, devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=os.path.join(REPO, "src"))
    # fake host devices need the CPU platform; never let the child probe
    # TPU (libtpu-installed, TPU-less containers hang in TPU client init)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=timeout)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


# -- rule resolution (no devices needed) --------------------------------------

def test_logical_rules_divisibility_fallback():
    mesh = jax.make_mesh((1,), ("model",))  # single device, axis size 1
    with activate_mesh(mesh):
        # axis size 1 -> never shard
        assert logical_to_spec(["heads"], [56]) == P(None)


def test_param_axis_patterns():
    assert param_logical_axes("layer/q_proj/kernel", 2) == ("embed",
                                                            "qkv_dim")
    assert param_logical_axes("stack/slot0/moe/experts/w_gate", 3) == \
        ("experts", "embed", "ff")
    # stacked (scan) leading dim resolves to None
    assert param_logical_axes("stack/slot0/attn/q_proj/kernel", 3) == \
        (None, "embed", "qkv_dim")
    assert param_logical_axes("embed/table", 2) == ("vocab", "embed")
    assert param_logical_axes("stack/slot0/mamba/conv1d/w", 3) == \
        (None, "conv_k", "d_inner")


def test_spec_resolution_on_fake_mesh():
    code = """
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.distributed.sharding import activate_mesh, logical_to_spec
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with activate_mesh(mesh):
        # 56 heads do NOT divide model=4? 56/4=14 -> shard
        assert logical_to_spec(["heads"], [56]) == P("model")
        # 55 heads do not divide 4 -> replicate (fallback, no error)
        assert logical_to_spec(["heads"], [55]) == P(None)
        # batch prefers ("pod","data") but pod absent -> ("data",)
        assert logical_to_spec(["batch", None], [8, 3]) == P("data", None)
        # two axes never doubly assign one mesh axis
        spec = logical_to_spec(["heads", "ff"], [8, 8])
        assert tuple(spec) in ((("model"), None), ("model", None))
    print("ok")
    """
    assert "ok" in run_py(code, devices=8)


# -- multi-device numerics ------------------------------------------------------

def test_sharded_train_step_matches_single_device():
    """The same train step on a (2,2) mesh and on 1 device produce the same
    loss and parameter update (GSPMD partitioning is semantics-preserving
    for our sharding rules)."""
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import get_smoke
    from repro.nn.models import build_model
    from repro.distributed import (StepConfig, activate_mesh,
                                   make_train_state, make_train_step,
                                   state_pspec)
    from repro.distributed.steps import _to_shardings, batch_pspec
    cfg = get_smoke("granite-3-2b")
    model = build_model(cfg)
    state = make_train_state(model, jax.random.PRNGKey(0))
    rngb = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rngb.integers(0, cfg.vocab, (4, 17)),
                                   jnp.int32)}
    scfg = StepConfig(warmup_steps=1, total_steps=10)
    # single device
    s1, m1 = jax.jit(make_train_step(model, scfg))(state, batch)
    # sharded
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    with activate_mesh(mesh) as ctx, mesh:
        model2 = build_model(cfg, tp=2)
        step = make_train_step(model2, scfg)
        sspec = state_pspec(state, ctx)
        sshard = _to_shardings(sspec, mesh)
        state2 = jax.device_put(state, sshard)
        batch2 = jax.device_put(batch, _to_shardings(
            batch_pspec(batch, ctx), mesh))
        s2, m2 = jax.jit(step, in_shardings=(sshard, None),
                         out_shardings=(sshard, None))(state2, batch2)
    print("loss_diff", abs(float(m1["loss"]) - float(m2["loss"])))
    d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b.astype(jnp.float32)))),
        s1["params"], s2["params"])
    print("max_param_diff", max(jax.tree_util.tree_leaves(d)))
    """
    out = run_py(code, devices=4, timeout=560)
    loss_diff = float(out.split("loss_diff")[1].split()[0])
    param_diff = float(out.split("max_param_diff")[1].split()[0])
    assert loss_diff < 1e-4
    assert param_diff < 5e-3   # adamw rsqrt amplifies tiny reduction skew


def test_compressed_grads_close_and_ef():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.compression import compressed_grads, init_ef
    def loss_fn(p, b):
        return jnp.mean((b["x"] @ p["w"] - b["y"])**2), {}
    key = jax.random.PRNGKey(0)
    p = {"w": jax.random.normal(key, (16, 8))}
    b = {"x": jax.random.normal(key, (32, 16)),
         "y": jax.random.normal(key, (32, 8))}
    mesh = jax.make_mesh((4, 2), ("data", "model"))
    with mesh:
        (_, _), g1 = jax.jit(lambda p, b: jax.value_and_grad(
            loss_fn, has_aux=True)(p, b))(p, b)
        (_, _), g2 = jax.jit(
            lambda p, b: compressed_grads(loss_fn, p, b, mesh))(p, b)
        rel = float(jnp.abs(g2["w"] - g1["w"]).max()
                    / jnp.abs(g1["w"]).max())
        ef = init_ef(p, mesh)
        (_, _), g3, ef2 = jax.jit(lambda p, b, e: compressed_grads(
            loss_fn, p, b, mesh, e))(p, b, ef)
        # error feedback holds exactly the quantization residual
        resid = float(jnp.abs(ef2["w"]).max())
    print("rel", rel, "resid", resid)
    """
    out = run_py(code)
    rel = float(out.split("rel")[1].split()[0])
    resid = float(out.split("resid")[1].split()[0])
    assert rel < 0.02      # int8 quantization error bound
    assert resid > 0


def test_pipeline_matches_sequential():
    code = """
    import jax, jax.numpy as jnp, numpy as np
    from repro.distributed.pipeline import pipeline_run
    key = jax.random.PRNGKey(0)
    mesh = jax.make_mesh((4, 2), ("pod", "data"))
    def stage_fn(p, x):
        return jnp.tanh(x @ p["w"])
    sp = {"w": jax.random.normal(key, (4, 8, 8)) * 0.5}
    x = jax.random.normal(key, (6, 3, 8))
    with mesh:
        out = jax.jit(lambda p, x: pipeline_run(
            stage_fn, p, x, mesh=mesh, axis="pod"))(sp, x)
    ref = x
    for s in range(4):
        ref = jnp.tanh(ref @ sp["w"][s])
    print("err", float(jnp.abs(out - ref).max()))
    """
    out = run_py(code)
    assert float(out.split("err")[1].split()[0]) < 1e-6


@pytest.mark.slow
def test_dryrun_scaled_cell():
    """The real dry-run entrypoint, scaled to 8 host devices, produces a
    sane artifact for one (arch x shape x mesh) cell."""
    with tempfile.TemporaryDirectory() as d:
        env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
                   PYTHONPATH=os.path.join(REPO, "src"))
        env["JAX_PLATFORMS"] = "cpu"
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.dryrun",
             "--arch", "mamba2-130m", "--shape", "decode_32k",
             "--multi-pod", "--out", d],
            capture_output=True, text=True, env=env, timeout=560)
        assert out.returncode == 0, out.stderr[-4000:]
        path = os.path.join(d, "mamba2-130m__decode_32k__multi.json")
        rec = json.load(open(path))
        assert rec["mesh"].get("pod") == 2
        assert rec["roofline"]["step_time_bound_s"] > 0
        assert rec["cost_calibrated"]["flops"] > 0
