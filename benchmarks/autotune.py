"""Autotune driver: tune the VGG-16 / AlexNet / wide512 layer set, persist
the winning plans, and report tuned-vs-default.

  PYTHONPATH=src python -m benchmarks.autotune             # full layer set
  PYTHONPATH=src python -m benchmarks.autotune --smoke --check   # CI lane

Tunes, through ``repro.engine.autotune`` (DESIGN.md §7):

- the ``kernels_fused`` float kernel shapes (``FUSED_SHAPES`` — the same
  table ``benchmarks.run`` times, so the ``tuned`` bench variants run off
  exactly the plans tuned here);
- their int8 counterparts (``INT8_SHAPES`` — the integer inference lane,
  where the exact chunked-f32 substrate routinely wins on CPU) and the
  same shapes on the 5-bit MSR weight lane (``INT5_SHAPES`` — ``w_bits=5``
  plans with their own ``... w5`` cache keys, DESIGN.md §9.3);
- the full VGG-16 / AlexNet float model walks plus the smoke-config int8
  walks (full-size int8 oracle measurements take minutes on CPU; pass
  ``--full-int8`` to include them).

Winners land in the JSON plan cache (``tuned_plans/`` or
``$REPRO_TUNED_PLANS_DIR``), loaded transparently by ``plan_conv_layer``
under ``--tuning cached/auto``.  A tuned-vs-default report is printed as
CSV (``autotune,<name>,us_default,us_tuned,ratio,substrate``) and written
to ``experiments/autotune/report.json``.

``--check`` re-reads the cache as a fresh process would (caches reset) and
verifies the round-trip: every tuned layer's ``tuning="cached"`` plan must
carry the persisted winner without re-measurement, and its output must be
bit-identical to the default plan's.  Exits non-zero on any violation —
this is CI's ``autotune-smoke`` gate.

This driver supersedes ``benchmarks.hillclimb`` for the TrIM conv cells:
hillclimb's conv variants call back into :func:`tune_cell` here.
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Dict, List, Optional, Tuple

#: (name, x NHWC, w KKCF, stride, pad) — the kernels_fused float shapes.
#: ``benchmarks.run`` imports this table so bench records and tuned plans
#: stay keyed to the same geometry.
FUSED_SHAPES: Tuple = (
    ("alexnet_cl1", (1, 227, 227, 3), (11, 11, 3, 96), 4, 0),
    ("alexnet_cl2", (1, 27, 27, 48), (5, 5, 48, 256), 1, 2),
    ("vgg16_cl8", (1, 28, 28, 256), (3, 3, 256, 512), 1, 1),
    ("wide512_s1", (1, 96, 512, 64), (3, 3, 64, 64), 1, 1),
    ("wide512_s2", (1, 96, 1024, 64), (3, 3, 64, 64), 2, 1),
)

#: Integer-lane kernel shapes (uint8 x int8 -> int32, fused requant): the
#: wide512 int8 record is the headline — XLA's CPU integer conv lowers to
#: a scalar loop, and the tuner promotes these layers onto the exact
#: chunked-f32 substrate for an order-of-magnitude win.
INT8_SHAPES: Tuple = (
    ("alexnet_cl2_int8", (1, 27, 27, 48), (5, 5, 48, 256), 1, 2),
    ("vgg16_cl8_int8", (1, 28, 28, 256), (3, 3, 256, 512), 1, 1),
    ("wide512_int8", (1, 32, 512, 64), (3, 3, 64, 64), 1, 1),
)

#: The integer shapes again on the sub-8-bit MSR weight lane: ``w_bits=5``
#: plans (decompressed operands with |w| <= 31 — DESIGN.md §9.3) get their
#: own cache keys (``... w5``) because the tightened f32exact chunking
#: bound changes which schedule wins.
INT5_SHAPES: Tuple = tuple(
    (name.replace("_int8", "_int5"), xs, ws, stride, pad)
    for name, xs, ws, stride, pad in INT8_SHAPES
)

#: The --smoke search: one small int8 layer, two candidates (oracle vs
#: f32exact) — a complete tune->persist->reload round-trip in seconds.
SMOKE_SHAPES: Tuple = (
    ("smoke_int8", (1, 16, 128, 32), (3, 3, 32, 32), 1, 1),
)


def _spec_kw(xs, ws, stride, pad, int8: bool, w_bits: int = 8) -> Dict:
    """tune_conv_layer kwargs for one shape-table row."""
    return dict(
        stride=stride,
        padding=pad,
        relu=True,
        has_bias=not int8,
        requant_kind="mult_shift" if int8 else None,
        in_sz=1 if int8 else 4,
        w_sz=1 if int8 else 4,
        out_sz=1 if int8 else 4,
        w_bits=w_bits,
    )


def _tune_shape(name, xs, ws, stride, pad, *, int8, reps, force, batch=1,
                w_bits=8):
    from repro.engine import tune_conv_layer

    res = tune_conv_layer(
        (xs[1], xs[2]),
        xs[3],
        ws[0],
        ws[3],
        policy=_policy(),
        reps=reps,
        force=force,
        batch=batch,
        **_spec_kw(xs, ws, stride, pad, int8, w_bits),
    )
    return (name if batch == 1 else f"{name}@n{batch}"), res


def _policy():
    from repro.engine import ExecutionPolicy

    return ExecutionPolicy()


def tune_cell(
    cell: str, *, reps: int = 3, force: bool = False,
    batches: Tuple[int, ...] = (1,),
) -> List[Tuple[str, object]]:
    """Tune one named cell; returns [(name, TuneResult), ...].

    Cells: "vgg16" / "alexnet" (full-size float model walk + the smoke
    int8 walk + the cell's kernel-table shapes; alexnet — the paper's
    Table II integer workload — additionally tunes its full-size int8
    walk, cheap enough on CPU; vgg16's needs --full-int8), "wide512" (the
    wide-feature-map kernel shapes, float + int8), "smoke" (the tiny CI
    search).  ``batches`` sweeps the kernel-table shapes per batch size
    (the serving buckets: tuned-plan cache keys carry the batch axis, and
    a bucket's plan looks up the winner measured at its own N; names gain
    an ``@n{N}`` suffix past N=1).  Model walks stay at N=1 — serving
    buckets re-tune per layer through the same per-layer keys.
    ``benchmarks.hillclimb`` drives its TrIM conv variants through this
    entry point.
    """
    from repro.configs import CNN_REGISTRY, CNN_SMOKES
    from repro.engine import tune_model

    results: List[Tuple[str, object]] = []
    if cell in ("vgg16", "alexnet"):
        results += tune_model(
            CNN_REGISTRY[cell], _policy(), datapath="float", reps=reps,
            force=force,
        )
        results += tune_model(
            CNN_SMOKES[cell], _policy(), datapath="int8", reps=reps,
            force=force,
        )
        if cell == "alexnet":
            results += tune_model(
                CNN_REGISTRY[cell], _policy(), datapath="int8", reps=reps,
                force=force,
            )
        rows = [r for r in FUSED_SHAPES + INT8_SHAPES + INT5_SHAPES
                if r[0].startswith(cell)]
    elif cell == "wide512":
        rows = [r for r in FUSED_SHAPES + INT8_SHAPES + INT5_SHAPES
                if r[0].startswith("wide512")]
    elif cell == "smoke":
        rows = list(SMOKE_SHAPES)
    else:
        raise ValueError(f"unknown cell {cell!r}")
    for name, xs, ws, stride, pad in rows:
        for batch in batches:
            results.append(
                _tune_shape(name, xs, ws, stride, pad,
                            int8=name.endswith(("int8", "int5")), reps=reps,
                            force=force, batch=int(batch),
                            w_bits=5 if name.endswith("int5") else 8)
            )
    return results


def report_row(name: str, res) -> Dict:
    return {
        "name": name,
        "key": res.key,
        "us_default": round(res.us_default, 1),
        "us_tuned": round(res.us, 1),
        "ratio": round(res.speedup, 3),
        "schedule": dict(res.schedule),
        "cached": res.cached,
        "candidates": len(res.candidates),
    }


def check_roundtrip(rows: List[Dict]) -> List[str]:
    """Verify the persisted cache round-trips as a fresh process sees it.

    For every tuned row: reset the in-process caches, re-plan under
    ``tuning="cached"`` with measurement disabled (a pure cache hit must
    not re-measure), check the plan carries the persisted schedule, and
    check its output is bit-identical to the default plan's.
    """
    import numpy as np

    from repro.engine import ExecutionPolicy, plan_conv_layer
    from repro.engine import autotune

    failures = []
    autotune.reset_cache()
    measured = []
    real_measure = autotune._measure_plan

    def counting_measure(*a, **kw):
        measured.append(a)
        return real_measure(*a, **kw)

    autotune._measure_plan = counting_measure
    try:
        for row in rows:
            kw = row["_kw"]
            args = row["_args"]
            cached_plan = plan_conv_layer(
                *args, policy=ExecutionPolicy(tuning="cached"), **kw
            )
            default_plan = plan_conv_layer(
                *args, policy=ExecutionPolicy(), **kw
            )
            if not cached_plan.tuned:
                failures.append(f"{row['name']}: cached plan not tuned")
                continue
            sched = row["schedule"]
            got = {
                "substrate": cached_plan.substrate,
                "tile_h": cached_plan.tile_h,
                "tile_w": cached_plan.tile_w_arg,
                "block_c": cached_plan.block_c,
                "block_f": cached_plan.block_f,
            }
            if got != sched:
                failures.append(
                    f"{row['name']}: schedule mismatch {got} != {sched}"
                )
            in_sz = kw["in_sz"]
            _, out_tuned = real_measure(cached_plan, in_sz=in_sz, warmup=0,
                                        reps=1)
            _, out_default = real_measure(default_plan, in_sz=in_sz,
                                          warmup=0, reps=1)
            if out_tuned.dtype != out_default.dtype or not np.array_equal(
                out_tuned, out_default
            ):
                failures.append(f"{row['name']}: tuned output not "
                                "bit-identical to default")
        if measured:
            failures.append(
                f"cache hit re-measured {len(measured)} plan(s) — lookups "
                "must be pure"
            )
    finally:
        autotune._measure_plan = real_measure
    return failures


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "cells",
        nargs="*",
        default=[],
        help="cells to tune (vgg16 alexnet wide512); default: all",
    )
    ap.add_argument("--smoke", action="store_true",
                    help="tiny CI search: one small int8 layer")
    ap.add_argument("--full-int8", action="store_true",
                    help="also tune the full-size int8 model walks (slow "
                    "on CPU: the default integer oracle takes minutes)")
    ap.add_argument("--reps", type=int, default=3,
                    help="timed reps per candidate (median)")
    ap.add_argument("--batches", default="1",
                    help="comma-separated batch sizes to sweep the "
                    "kernel-table shapes at (serving buckets, e.g. 1,4,16 "
                    "— each N gets its own cache key and winner)")
    ap.add_argument("--force", action="store_true",
                    help="re-measure layers already in the cache")
    ap.add_argument("--check", action="store_true",
                    help="verify the cache round-trip (CI gate); exits "
                    "non-zero on failure")
    ap.add_argument("--report", default="experiments/autotune/report.json")
    args = ap.parse_args(argv)

    from repro.engine import autotune

    cells = ["smoke"] if args.smoke else (
        list(args.cells) or ["vgg16", "alexnet", "wide512"]
    )
    batches = tuple(int(b) for b in args.batches.split(","))
    results: List[Tuple[str, object]] = []
    for cell in cells:
        print(f"[autotune] tuning cell {cell} ...", flush=True)
        results += tune_cell(cell, reps=args.reps, force=args.force,
                             batches=batches)
    if args.full_int8:
        from repro.configs import CNN_REGISTRY
        from repro.engine import tune_model

        for arch in ("vgg16", "alexnet"):
            results += tune_model(
                CNN_REGISTRY[arch], _policy(), datapath="int8",
                reps=args.reps, force=args.force,
            )

    rows = []
    print("section,name,us_default,us_tuned,ratio,substrate,cached")
    for name, res in results:
        row = report_row(name, res)
        # stash the re-plan arguments for --check (not serialized); batch
        # sweeps suffix names with @n{N}, so match on the base name
        base, _, nsuf = name.partition("@n")
        tables = FUSED_SHAPES + INT8_SHAPES + INT5_SHAPES + SMOKE_SHAPES
        if base in {r[0] for r in tables}:
            shape = next(r for r in tables if r[0] == base)
            _, xs, ws, stride, pad = shape
            row["_args"] = ((xs[1], xs[2]), xs[3], ws[0], ws[3])
            row["_kw"] = dict(
                _spec_kw(xs, ws, stride, pad,
                         base.endswith(("int8", "int5")),
                         5 if base.endswith("int5") else 8),
                batch=int(nsuf) if nsuf else 1,
            )
        rows.append(row)
        print(
            f"autotune,{name},{row['us_default']:.0f},{row['us_tuned']:.0f},"
            f"{row['ratio']:.2f},{row['schedule']['substrate']},"
            f"{row['cached']}"
        )

    failures = []
    if args.check:
        failures = check_roundtrip([r for r in rows if "_args" in r])
        for f in failures:
            print(f"[autotune] CHECK FAIL: {f}", file=sys.stderr)
        if not failures:
            print("[autotune] cache round-trip check: PASS")

    import jax

    report = {
        "cache": autotune.cache_path(),
        "backend": jax.default_backend(),
        "device_kind": autotune.device_kind(),
        "records": [
            {k: v for k, v in r.items() if not k.startswith("_")}
            for r in rows
        ],
    }
    os.makedirs(os.path.dirname(args.report) or ".", exist_ok=True)
    with open(args.report, "w") as f:
        json.dump(report, f, indent=1)
    print(f"[autotune] wrote {args.report}; plan cache at "
          f"{autotune.cache_path()}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
