"""Benchmark harness — one section per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run            # all sections
  PYTHONPATH=src python -m benchmarks.run table1 fig7

Sections print CSV rows (`section,name,...`) so downstream tooling (and
EXPERIMENTS.md) can consume them directly. Sections:

  table1   VGG-16 per-layer throughput / PE util / memory accesses vs the
           paper's printed TrIM columns (Table I).
  table2   AlexNet, incl. the 11x11/5x5 kernel-tiling path (Table II).
  table3   State-of-the-art FPGA comparison re-derivation (Table III).
  fig7     Design-space exploration (throughput / psum size / BW).
  baselines TrIM vs Eyeriss-RS vs im2col-WS memory-access models.
  engine   Bit-faithful engine emulator timing + counter validation.
  kernels  Pallas kernel (interpret) vs oracle timing on small shapes.
  kernels_fused  Fused-strided conv vs the FPGA's decimate-then-activate
           schedule on the AlexNet/VGG layer shapes; writes
           BENCH_kernels.json (perf trajectory artifact).
  serve    Closed-loop bucketed CNN serving throughput/latency per
           (arch, datapath, bucket) off the shared serving core
           (DESIGN.md §8); writes BENCH_serve.json (serving gate
           artifact — ``benchmarks.compare --metric images_per_s``).
  roofline Dry-run roofline table (reads experiments/dryrun/*.json).
"""
from __future__ import annotations

import glob
import json
import os
import sys
import time
from typing import Dict, List

import numpy as np

from repro.core.trim.explore import derive_fpga_parameters, explore
from repro.core.trim.model import (ALEXNET_BATCH, ALEXNET_LAYERS,
                                   PAPER_ENGINE, PAPER_TABLE1_TRIM,
                                   PAPER_TABLE1_TRIM_TOTALS,
                                   PAPER_TABLE2_TRIM,
                                   PAPER_TABLE2_TRIM_TOTALS, VGG16_BATCH,
                                   VGG16_LAYERS, eyeriss_rs_memory_accesses,
                                   layer_gops, network_gops, pe_utilization,
                                   trim_memory_accesses,
                                   ws_im2col_memory_accesses)

DRYRUN_DIR = os.environ.get("REPRO_DRYRUN_DIR", "experiments/dryrun")


def _timeit(fn, n=3) -> float:
    fn()  # warmup / compile
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6  # us


def _timeit_pair(fa, fb, n=3):
    """Drift-robust A/B timing over two thunks: alternate the arms and
    aggregate with THE shared pair statistic
    (``repro.engine.autotune.aggregate_pair`` — median of per-round
    ratios + per-arm mins; see its docstring for the rationale).
    Returns (us_a, us_b, ratio_a_over_b)."""
    from repro.engine.autotune import aggregate_pair
    fa()  # warmup / compile
    fb()
    ta, tb = [], []
    for _ in range(n):
        t0 = time.perf_counter()
        fa()
        ta.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        fb()
        tb.append(time.perf_counter() - t0)
    us_b, us_a, ratio = aggregate_pair(tb, ta)  # ratio = a over b
    return us_a * 1e6, us_b * 1e6, ratio


def bench_table1() -> None:
    print("section,name,gops_model,gops_paper,pe_util_model,pe_util_paper,"
          "offchip_M_model,offchip_M_paper,onchip_M_model,onchip_M_paper")
    for l in VGG16_LAYERS:
        g_p, u_p, on_p, off_p = PAPER_TABLE1_TRIM[l.name]
        acc = trim_memory_accesses(l, batch=VGG16_BATCH)
        print(f"table1,{l.name},{layer_gops(l):.1f},{g_p},"
              f"{pe_utilization(l):.2f},{u_p},"
              f"{acc.off_chip:.2f},{off_p},{acc.onchip_equiv:.2f},{on_p}")
    tot = network_gops(VGG16_LAYERS)
    accs = [trim_memory_accesses(l, batch=VGG16_BATCH) for l in VGG16_LAYERS]
    print(f"table1,TOTAL,{tot:.1f},{PAPER_TABLE1_TRIM_TOTALS['gops']},"
          f",,{sum(a.off_chip for a in accs):.1f},"
          f"{PAPER_TABLE1_TRIM_TOTALS['off_chip_M']},"
          f"{sum(a.onchip_equiv for a in accs):.2f},"
          f"{PAPER_TABLE1_TRIM_TOTALS['on_chip_M']}")


def bench_table2() -> None:
    print("section,name,gops_model,gops_paper,offchip_M_model,"
          "offchip_M_paper")
    for l in ALEXNET_LAYERS:
        g_p, u_p, on_p, off_p = PAPER_TABLE2_TRIM[l.name]
        acc = trim_memory_accesses(l, batch=ALEXNET_BATCH)
        print(f"table2,{l.name},{layer_gops(l):.2f},{g_p},"
              f"{acc.off_chip:.2f},{off_p}")
    print(f"table2,TOTAL,{network_gops(ALEXNET_LAYERS):.1f},"
          f"{PAPER_TABLE2_TRIM_TOTALS['gops']},,")


def bench_table3() -> None:
    """Table III re-derivation: our engine's peak throughput + the published
    competitor figures (device/power figures are from the paper)."""
    rows = [
        ("Sense-TVLSI23", 1024, 200e6, 409.6, 11.0),
        ("TCASI24-WS", 256, 150e6, 76.8, 1.398),
        ("TCASII24-RS", 243, 150e6, 72.9, 8.25),
    ]
    print("section,name,pes,clock_MHz,peak_gops,power_W,gops_per_W")
    for name, pes, clk, gops, p in rows:
        print(f"table3,{name},{pes},{clk/1e6:.0f},{gops},{p},{gops/p:.2f}")
    eng = PAPER_ENGINE
    print(f"table3,TrIM(this work),{eng.n_pes},{eng.f_clk_hz/1e6:.0f},"
          f"{eng.peak_gops},4.329,{eng.peak_gops/4.329:.2f}")


def bench_fig7() -> None:
    print("section,P_N,P_M,n_pes,gops,psum_Mb,bw_bits")
    for p in explore():
        print(f"fig7,{p.P_N},{p.P_M},{p.n_pes},{p.gops:.1f},"
              f"{p.psum_buffer_Mb:.2f},{p.io_bandwidth_bits}")
    pn, pm = derive_fpga_parameters()
    print(f"fig7,derived_fpga_params,{pn},{pm},,,")


def bench_baselines() -> None:
    print("section,network,model,ifmap_M,weight_M,onchip_equiv_M,total_M")
    for net_name, layers, batch in (("vgg16", VGG16_LAYERS, VGG16_BATCH),
                                    ("alexnet", ALEXNET_LAYERS,
                                     ALEXNET_BATCH)):
        for model_name, fn in (("trim", trim_memory_accesses),
                               ("eyeriss_rs", eyeriss_rs_memory_accesses),
                               ("im2col_ws", ws_im2col_memory_accesses)):
            accs = [fn(l, batch=batch) if model_name != "trim"
                    else fn(l, PAPER_ENGINE, batch=batch) for l in layers]
            print(f"baselines,{net_name},{model_name},"
                  f"{sum(a.ifmap_reads for a in accs):.1f},"
                  f"{sum(a.weight_reads for a in accs):.1f},"
                  f"{sum(a.onchip_equiv for a in accs):.2f},"
                  f"{sum(a.total for a in accs):.1f}")


def bench_engine() -> None:
    from repro.core.trim.engine import TrimEngine, reference_conv_layer
    from repro.core.trim.model import TrimEngineConfig
    rng = np.random.default_rng(0)
    x = rng.integers(0, 256, (8, 28, 28), dtype=np.uint8)
    w = rng.integers(-128, 128, (8, 8, 3, 3)).astype(np.int8)
    eng = TrimEngine(TrimEngineConfig(P_N=4, P_M=4), check_widths=False)
    us = _timeit(lambda: eng.run_layer(x, w), n=3)
    out, trace = eng.run_layer(x, w)
    ref = reference_conv_layer(x, w)
    ok = bool((out == ref).all())
    print("section,name,us_per_call,derived")
    print(f"engine,emulator_28x28x8x8,{us:.0f},exact={ok}:"
          f"steps={trace.steps}")


def bench_kernels() -> None:
    import jax
    import jax.numpy as jnp
    from repro.kernels import ref
    from repro.kernels.trim_conv2d import trim_conv2d_pallas
    from repro.kernels.trim_matmul import trim_matmul_pallas
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (1, 16, 16, 16), jnp.float32)
    w = jax.random.normal(key, (3, 3, 16, 16), jnp.float32)
    print("section,name,us_per_call,derived")
    us_ref = _timeit(lambda: jax.block_until_ready(ref.conv2d_ref(x, w)))
    err = float(np.abs(np.asarray(
        trim_conv2d_pallas(x, w, tile_h=8, block_c=16, block_f=16,
                           interpret=True))
        - np.asarray(ref.conv2d_ref(x, w))).max())
    print(f"kernels,conv2d_oracle_16x16x16,{us_ref:.0f},"
          f"interpret_allclose_err={err:.1e}")
    a = jax.random.normal(key, (256, 256))
    b = jax.random.normal(key, (256, 256))
    us_mm = _timeit(lambda: jax.block_until_ready(ref.matmul_ref(a, b)))
    errm = float(np.abs(np.asarray(
        trim_matmul_pallas(a, b, block_m=64, block_n=64, block_k=64,
                           interpret=True)) - np.asarray(a @ b)).max())
    print(f"kernels,matmul_oracle_256,{us_mm:.0f},"
          f"interpret_allclose_err={errm:.1e}")


def bench_kernels_fused() -> None:
    """Fused-strided TrIM conv vs decimate-then-activate (§V schedule),
    plus the training direction (``conv2d_grads``) and the autotuned
    plans (``tuned`` variants).

    Both arms run through the public ``ops.trim_conv2d`` dispatcher, so on
    TPU this times the Pallas kernels and on CPU the jnp oracle with
    identical schedules: the emulate_hw arm does the full stride-1 sweep,
    decimates, then runs bias+ReLU as a separate jit (3 extra HBM
    round-trips); the fused arm computes only the strided outputs with the
    epilogue in the same pass.  The ``conv2d_grads`` records time
    ``jax.value_and_grad`` w.r.t. (x, w, bias) through the same dispatcher
    — on TPU that is the custom-VJP input-grad/weight-grad Pallas pair
    (DESIGN.md §6), on CPU the oracle's autodiff; they carry a ``us_grads``
    metric (gated separately by ``benchmarks.compare --metric us_grads``).

    Every float shape also runs under ``tuning="cached"`` (the persisted
    autotuner winners — ``benchmarks.autotune``, DESIGN.md §7) and records
    ``us_tuned`` + ``tuned_speedup`` (= us_fused / us_tuned, the
    tuned-vs-default ratio: >= 1.0, the tuner never ships a slower plan).
    When the tuned plan *equals* the default plan (the winner was the
    default — ``ConvLayerPlan.tuned`` is metadata, so equal schedules are
    value-equal and share one jit executable) the ratio is recorded as
    exactly 1.0 without a second timing: sampling the same executable
    twice measures machine noise, not the schedule.  Plans that actually
    differ are measured with drift-robust interleaved timing
    (``_timeit_pair``).
    The ``*_int8`` records track the integer inference lane the same way
    (metrics ``us_default``/``us_tuned``/``tuned_speedup`` only, so the
    slow integer-oracle default never enters the absolute ``us_fused``
    gate); the ``*_int5`` records repeat those shapes on ``w_bits=5``
    plans (the MSR weight lane, DESIGN.md §9.3) through the
    ``run_conv2d`` dispatch seam.  All records carry ``backend`` +
    ``device_kind`` stamps — ``benchmarks.compare`` skips absolute us
    gates across device kinds.
    Writes BENCH_kernels.json for the perf trajectory.
    """
    import jax
    import jax.numpy as jnp
    from benchmarks.autotune import FUSED_SHAPES, INT8_SHAPES
    from repro.engine import ExecutionPolicy, plan_conv_layer
    from repro.kernels.ops import trim_conv2d

    emu_policy = ExecutionPolicy(emulate_hw=True)
    tuned_policy = ExecutionPolicy(tuning="cached")

    def resolve_plan(xs, ws, stride, pad, policy=None, int8=False, w_bits=8):
        """The resolved plan for one arm — its describe() is recorded so
        bench-gate regressions are attributable to schedule changes."""
        return plan_conv_layer(
            (xs[1], xs[2]), xs[3], ws[0], ws[3], stride=stride, padding=pad,
            relu=True, has_bias=not int8,
            requant_kind="mult_shift" if int8 else None,
            in_sz=1 if int8 else 4, w_sz=1 if int8 else 4,
            out_sz=1 if int8 else 4, w_bits=w_bits,
            policy=policy or ExecutionPolicy())

    def plan_record(xs, ws, stride, pad, policy=None, int8=False, w_bits=8):
        return resolve_plan(xs, ws, stride, pad, policy, int8,
                            w_bits).describe()

    backend = jax.default_backend()
    device_kind = jax.devices()[0].device_kind
    stamp = {"backend": backend, "device_kind": device_kind}
    records: List[Dict] = []
    print("section,name,us_fused,us_decimate,speedup,us_tuned,"
          "tuned_speedup,backend")
    for name, xs, ws, stride, pad in FUSED_SHAPES:
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, xs, jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), ws, jnp.float32)
        b = jax.random.normal(jax.random.fold_in(key, 2), (ws[-1],),
                              jnp.float32)

        def fused():
            return jax.block_until_ready(trim_conv2d(
                x, w, b, stride=stride, padding=pad, relu=True))

        def tuned():
            return jax.block_until_ready(trim_conv2d(
                x, w, b, stride=stride, padding=pad, relu=True,
                policy=tuned_policy))

        epilogue = jax.jit(lambda o: jnp.maximum(o + b, 0))

        def decimate():
            o = trim_conv2d(x, w, stride=stride, padding=pad,
                            policy=emu_policy)
            return jax.block_until_ready(epilogue(o))

        us_f = _timeit(fused, n=3)
        us_d = _timeit(decimate, n=3)
        if resolve_plan(xs, ws, stride, pad) == \
                resolve_plan(xs, ws, stride, pad, tuned_policy):
            # winner == default: same plan, same jit executable — the
            # ratio is 1.0 by construction, not worth a noisy re-timing
            us_t, tuned_speedup = us_f, 1.0
        else:
            # a real schedule change: measure the arms interleaved
            _, us_t, tuned_speedup = _timeit_pair(fused, tuned, n=5)
        speedup = us_d / us_f if us_f else float("inf")
        print(f"kernels_fused,{name},{us_f:.0f},{us_d:.0f},"
              f"{speedup:.2f},{us_t:.0f},{tuned_speedup:.2f},{backend}")
        records.append({"name": name, "x": list(xs), "w": list(ws),
                        "stride": stride, "padding": pad,
                        "us_fused": round(us_f, 1),
                        "us_decimate": round(us_d, 1),
                        "speedup": round(speedup, 2),
                        "us_tuned": round(us_t, 1),
                        "tuned_speedup": round(tuned_speedup, 2),
                        **stamp,
                        "plan": plan_record(xs, ws, stride, pad),
                        "plan_tuned": plan_record(xs, ws, stride, pad,
                                                  tuned_policy)})

    # Integer inference lane: default plan vs the autotuned one (on CPU
    # the tuner promotes these onto the exact chunked-f32 substrate —
    # DESIGN.md §7; the default integer oracle is a scalar loop).
    print("section,name,us_default,us_tuned,tuned_speedup,backend")
    for name, xs, ws, stride, pad in INT8_SHAPES:
        key = jax.random.PRNGKey(0)
        x = jax.random.randint(key, xs, 0, 255, jnp.uint8)
        w = jax.random.randint(jax.random.fold_in(key, 1), ws, -127, 127,
                               jnp.int8)
        rq = (jnp.full((ws[-1],), 16384, jnp.int32),
              jnp.full((ws[-1],), 20, jnp.int32))

        def int8_default():
            return jax.block_until_ready(trim_conv2d(
                x, w, None, rq, stride=stride, padding=pad, relu=True))

        def int8_tuned():
            return jax.block_until_ready(trim_conv2d(
                x, w, None, rq, stride=stride, padding=pad, relu=True,
                policy=tuned_policy))

        if resolve_plan(xs, ws, stride, pad, int8=True) == \
                resolve_plan(xs, ws, stride, pad, tuned_policy, int8=True):
            us_def = _timeit(int8_default, n=2)
            us_t, tuned_speedup = us_def, 1.0
        else:
            us_def, us_t, tuned_speedup = _timeit_pair(
                int8_default, int8_tuned, n=2)
        print(f"kernels_fused,{name},{us_def:.0f},{us_t:.0f},"
              f"{tuned_speedup:.2f},{backend}")
        records.append({"name": name, "x": list(xs), "w": list(ws),
                        "stride": stride, "padding": pad,
                        "us_default": round(us_def, 1),
                        "us_tuned": round(us_t, 1),
                        "tuned_speedup": round(tuned_speedup, 2),
                        **stamp,
                        "plan": plan_record(xs, ws, stride, pad, int8=True),
                        "plan_tuned": plan_record(xs, ws, stride, pad,
                                                  tuned_policy, int8=True)})

    # Sub-8-bit weight lane: the same integer shapes with MSR-decompressed
    # int5 operands (|w| <= 31) and the shift folded into the requant pair
    # (DESIGN.md §9.3).  Timed through run_conv2d on the resolved w_bits=5
    # plans — the dedicated dispatch seam the serving lane uses — so the
    # records catch schedule regressions in the tightened f32exact chunking
    # (w_abs_max=31 widens the lossless channel chunks ~4x on CPU).
    from repro.engine import run_conv2d
    print("section,name,us_default,us_tuned,tuned_speedup,backend")
    for name, xs, ws, stride, pad in INT8_SHAPES:
        name = name.replace("_int8", "_int5")
        key = jax.random.PRNGKey(0)
        x = jax.random.randint(key, xs, 0, 255, jnp.uint8)
        w = jax.random.randint(jax.random.fold_in(key, 1), ws, -31, 31,
                               jnp.int8)
        rq = (jnp.full((ws[-1],), 16384, jnp.int32),
              jnp.full((ws[-1],), 20, jnp.int32))
        plan5 = resolve_plan(xs, ws, stride, pad, int8=True, w_bits=5)
        plan5_t = resolve_plan(xs, ws, stride, pad, tuned_policy,
                               int8=True, w_bits=5)

        def int5_default():
            return jax.block_until_ready(
                run_conv2d(plan5, x, w, None, rq))

        def int5_tuned():
            return jax.block_until_ready(
                run_conv2d(plan5_t, x, w, None, rq))

        if plan5 == plan5_t:
            us_def = _timeit(int5_default, n=2)
            us_t, tuned_speedup = us_def, 1.0
        else:
            us_def, us_t, tuned_speedup = _timeit_pair(
                int5_default, int5_tuned, n=2)
        print(f"kernels_fused,{name},{us_def:.0f},{us_t:.0f},"
              f"{tuned_speedup:.2f},{backend}")
        records.append({"name": name, "x": list(xs), "w": list(ws),
                        "stride": stride, "padding": pad,
                        "us_default": round(us_def, 1),
                        "us_tuned": round(us_t, 1),
                        "tuned_speedup": round(tuned_speedup, 2),
                        **stamp,
                        "plan": plan5.describe(),
                        "plan_tuned": plan5_t.describe()})

    # Training direction: value+grad through the same dispatcher.
    grad_shapes = [
        ("conv2d_grads_alexnet_cl2", (1, 27, 27, 48), (5, 5, 48, 256), 1, 2),
        ("conv2d_grads_vgg16_cl8", (1, 28, 28, 256), (3, 3, 256, 512), 1, 1),
        ("conv2d_grads_wide512_s2", (1, 96, 1024, 64), (3, 3, 64, 64), 2, 1),
    ]
    print("section,name,us_grads,substrate")
    for name, xs, ws, stride, pad in grad_shapes:
        key = jax.random.PRNGKey(0)
        x = jax.random.normal(key, xs, jnp.float32)
        w = jax.random.normal(jax.random.fold_in(key, 1), ws, jnp.float32)
        b = jax.random.normal(jax.random.fold_in(key, 2), (ws[-1],),
                              jnp.float32)

        grad_fn = jax.jit(jax.value_and_grad(
            lambda x, w, b: trim_conv2d(
                x, w, b, stride=stride, padding=pad, relu=True).sum(),
            argnums=(0, 1, 2)))

        def grads():
            return jax.block_until_ready(grad_fn(x, w, b))

        us_g = _timeit(grads, n=3)
        print(f"kernels_fused,{name},{us_g:.0f},{backend}")
        records.append({"name": name, "x": list(xs), "w": list(ws),
                        "stride": stride, "padding": pad,
                        "us_grads": round(us_g, 1),
                        **stamp,
                        "plan": plan_record(xs, ws, stride, pad)})
    out_dir = os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "BENCH_kernels.json")
    with open(out_path, "w") as f:
        json.dump({"section": "kernels_fused", "device": stamp,
                   "records": records}, f, indent=1)
    print(f"kernels_fused,WROTE,{out_path},,,")


def _serve_load_items(cfg, n_requests, dtype):
    """A saturating request list (every arrival at t=0) — the equal
    offered load both serve_concurrent arms replay."""
    from repro.data.pipeline import SyntheticRequestStream

    stream = SyntheticRequestStream(
        hw=cfg.input_hw, channels=cfg.layers[0].M, n_classes=cfg.n_classes,
        n_requests=n_requests, process="bursts", burst_sizes=(n_requests,),
        gap_s=0.0, dtype=dtype)
    return list(stream)


def _serve_round(engine, serve_config, items, producers):
    """One measured serve run over ``items``: a fresh Server around the
    shared (already compiled) engine; returns its filled metrics."""
    from repro.serve import Server

    srv = Server(engine, serve_config)
    try:
        metrics = srv.run_stream(iter(items), producers=producers)
    finally:
        srv.close()
    tot = metrics.snapshot()["totals"]
    if (tot["images"] + tot["shed"] + tot["expired"]
            + tot.get("failed", 0)) != tot["submitted"]:
        raise RuntimeError(f"serve bench conservation violated: {tot}")
    return metrics


def bench_serve() -> None:
    """Bucketed serving: closed-loop per-bucket throughput/latency plus
    the serve_concurrent threaded-vs-open-loop arm (DESIGN.md §8).

    Per-bucket records time ``ServeEngine.run_bucket`` on a full bucket
    (no pad waste — the peak-throughput arm; the open-loop launcher
    ``repro.launch.serve_cnn`` measures the queueing side).  Engines come
    from the production facade path (``launch.serve_cnn.build_server``:
    ahead-of-time compiled bucket executables, calibrated requant on the
    int8 lane) with ``tuning="cached"`` so batch-specific persisted
    autotuner winners apply.  Records carry ``images_per_s``
    (higher-is-better throughput gate) and ``p50_ms``/``p99_ms``
    (lower-is-better latency gate) — ``benchmarks.compare`` skips these
    machine-scoped gates across device kinds.

    ``serve_concurrent`` records replay the SAME saturating request list
    through two arms — N producer threads feeding the flush worker
    (``Server.run_stream(..., producers=N)``) vs the single-threaded
    inline open loop — in adjacent rounds, and gate the drift-robust
    median per-round wall ratio (``repro.engine.autotune.aggregate_pair``)
    as ``concurrent_speedup`` (compare.py --floor: threaded admission must
    not lose throughput at equal offered load).  The
    ``serve_fault_overhead`` record replays the same load through a
    Server whose ``FaultPlan`` is armed but carries zero budgets vs the
    plain path and gates the ratio as ``fault_overhead_speedup`` —
    zero-cost-off (DESIGN.md §11.6) as a floor, not prose.  A
    shed-policy record exercises the bounded queue (``shed_rate``).
    Knobs:
    REPRO_SERVE_BENCH_REPS (default 15), REPRO_SERVE_CONC_REQUESTS (64),
    REPRO_SERVE_CONC_ROUNDS (5).  Writes BENCH_serve.json under the
    schema_version-2 header (``repro.serve.stamp_payload``).
    """
    import jax
    from repro.configs import CNN_SMOKES
    from repro.data.pipeline import SyntheticRequestStream
    from repro.engine import ExecutionPolicy
    from repro.engine.autotune import aggregate_pair
    from repro.launch.serve_cnn import build_server
    from repro.serve import ServeConfig, stamp_payload

    reps = int(os.environ.get("REPRO_SERVE_BENCH_REPS", "15"))
    conc_requests = int(os.environ.get("REPRO_SERVE_CONC_REQUESTS", "256"))
    conc_rounds = int(os.environ.get("REPRO_SERVE_CONC_ROUNDS", "5"))
    producers = 4
    buckets = (1, 4, 16)
    policy = ExecutionPolicy(tuning="cached")
    backend = jax.default_backend()
    device_kind = jax.devices()[0].device_kind
    stamp = {"backend": backend, "device_kind": device_kind}
    records: List[Dict] = []
    engines = {}
    print("section,name,bucket,images_per_s,p50_ms,p99_ms,backend")
    for arch in ("vgg16", "alexnet"):
        cfg = CNN_SMOKES[arch]
        for datapath in ("float", "int8"):
            int8 = datapath == "int8"
            server = build_server(
                cfg, policy, ServeConfig(buckets=buckets, datapath=datapath))
            engine = server.engine
            engines[(arch, datapath)] = (cfg, engine)
            stream = SyntheticRequestStream(
                hw=cfg.input_hw, channels=cfg.layers[0].M,
                n_classes=cfg.n_classes,
                dtype="uint8" if int8 else "float32")
            for b in buckets:
                images = stream.sample_batch(b)
                np.asarray(engine.run_bucket(b, images))  # warm
                times = []
                for _ in range(reps):
                    t0 = time.perf_counter()
                    np.asarray(engine.run_bucket(b, images))
                    times.append(time.perf_counter() - t0)
                busy = sum(times)
                img_per_s = b * reps / busy if busy else 0.0
                p50 = float(np.percentile(times, 50)) * 1e3
                p99 = float(np.percentile(times, 99)) * 1e3
                name = f"serve_{arch}_{datapath}_n{b}"
                print(f"serve,{name},{b},{img_per_s:.1f},"
                      f"{p50:.2f},{p99:.2f},{backend}")
                records.append({
                    "name": name, "arch": cfg.name, "datapath": datapath,
                    "bucket": b, "reps": reps,
                    "images_per_s": round(img_per_s, 1),
                    "p50_ms": round(p50, 3), "p99_ms": round(p99, 3),
                    **stamp,
                    "plan": list(engine.bucket_plan(b).describe()),
                })
            # no-retrace ledger: the closed loop must not have compiled
            # anything beyond the one warmup executable per bucket
            bad = {k: v for k, v in engine.compile_counts.items() if v != 1}
            if bad:
                raise RuntimeError(
                    f"serve bench recompiled executables: {bad}")

    # -- serve_concurrent: threaded admission vs the open-loop baseline --
    print("section,name,producers,images_per_s,p99_ms,shed_rate,"
          "concurrent_speedup")
    for arch, datapath in (("vgg16", "float"), ("vgg16", "int8")):
        cfg, engine = engines[(arch, datapath)]
        serve_config = ServeConfig(buckets=buckets, datapath=datapath)
        items = _serve_load_items(
            cfg, conc_requests, "uint8" if datapath == "int8" else "float32")
        # warm both arms outside the timed rounds
        _serve_round(engine, serve_config, items, producers)
        _serve_round(engine, serve_config, items, 0)
        walls_thr, walls_inline = [], []
        last_thr = None
        for _ in range(conc_rounds):
            last_thr = _serve_round(engine, serve_config, items, producers)
            walls_thr.append(last_thr.wall_s)
            walls_inline.append(
                _serve_round(engine, serve_config, items, 0).wall_s)
        wall_thr, wall_inline, speedup = aggregate_pair(
            walls_thr, walls_inline)
        snap = last_thr.snapshot()
        tot = snap["totals"]
        if tot["images"] != conc_requests:
            raise RuntimeError(
                f"serve_concurrent dropped work: served {tot['images']} of "
                f"{conc_requests}")
        bad = {k: v for k, v in engine.compile_counts.items() if v != 1}
        if bad:
            raise RuntimeError(
                f"serve_concurrent recompiled executables: {bad}")
        name = f"serve_concurrent_{arch}_{datapath}"
        img_per_s = conc_requests / wall_thr if wall_thr else 0.0
        print(f"serve,{name},{producers},{img_per_s:.1f},"
              f"{tot['p99_ms']:.2f},0.000,{speedup:.3f}")
        records.append({
            "name": name, "arch": cfg.name, "datapath": datapath,
            "producers": producers, "requests": conc_requests,
            "rounds": conc_rounds, "overload": serve_config.overload,
            "images_per_s": round(img_per_s, 1),
            "open_loop_images_per_s": round(
                conc_requests / wall_inline, 1) if wall_inline else 0.0,
            "p99_ms": tot["p99_ms"],
            "shed_rate": 0.0,
            "overlapped": tot["overlapped"],
            "concurrent_speedup": round(speedup, 3),
            **stamp,
        })

    # -- fault-plane overhead: armed-but-empty plan vs plain (§11.6) --
    # zero-cost-off is a gated invariant, not prose: a Server whose
    # FaultPlan is armed but carries zero budgets (the injector branches
    # + success bookkeeping, no faults) must not cost throughput vs the
    # plain path.  fault_overhead_speedup = plain wall / armed wall
    # (compare.py --floor: ~1.0 honest expectation, fires on collapse).
    from repro.serve import FaultPlan

    cfg, engine = engines[("vgg16", "float")]
    plain_config = ServeConfig(buckets=buckets)
    armed_config = ServeConfig(buckets=buckets,
                               faults=FaultPlan(seed=0))
    items = _serve_load_items(cfg, conc_requests, "float32")
    _serve_round(engine, armed_config, items, producers)  # warm
    walls_armed, walls_plain = [], []
    for _ in range(conc_rounds):
        walls_armed.append(
            _serve_round(engine, armed_config, items, producers).wall_s)
        walls_plain.append(
            _serve_round(engine, plain_config, items, producers).wall_s)
    wall_armed, wall_plain, overhead = aggregate_pair(
        walls_armed, walls_plain)
    name = "serve_fault_overhead_vgg16_float"
    img_per_s = conc_requests / wall_armed if wall_armed else 0.0
    print(f"serve,{name},{producers},{img_per_s:.1f},,,{overhead:.3f}")
    records.append({
        "name": name, "arch": cfg.name, "datapath": "float",
        "producers": producers, "requests": conc_requests,
        "rounds": conc_rounds,
        "armed_images_per_s": round(
            conc_requests / wall_armed, 1) if wall_armed else 0.0,
        "plain_images_per_s": round(
            conc_requests / wall_plain, 1) if wall_plain else 0.0,
        "fault_overhead_speedup": round(overhead, 3),
        **stamp,
    })

    # shed policy under the same load: the bounded queue must reject,
    # not wedge — shed_rate documents how much this load overdrives a
    # capacity-8 queue
    cfg, engine = engines[("vgg16", "float")]
    shed_config = ServeConfig(buckets=buckets, queue_capacity=8,
                              overload="shed")
    items = _serve_load_items(cfg, conc_requests, "float32")
    metrics = _serve_round(engine, shed_config, items, producers)
    tot = metrics.snapshot()["totals"]
    shed_rate = tot["shed"] / tot["submitted"] if tot["submitted"] else 0.0
    name = "serve_concurrent_vgg16_float_shed"
    print(f"serve,{name},{producers},"
          f"{tot.get('images_per_s', 0.0):.1f},{tot['p99_ms']:.2f},"
          f"{shed_rate:.3f},")
    records.append({
        "name": name, "arch": cfg.name, "datapath": "float",
        "producers": producers, "requests": conc_requests,
        "queue_capacity": shed_config.queue_capacity,
        "overload": "shed",
        "served": tot["images"], "shed": tot["shed"],
        "shed_rate": round(shed_rate, 4),
        "p99_ms": tot["p99_ms"],
        **stamp,
    })

    out_dir = os.environ.get("REPRO_BENCH_DIR", ".")
    os.makedirs(out_dir, exist_ok=True)
    out_path = os.path.join(out_dir, "BENCH_serve.json")
    with open(out_path, "w") as f:
        json.dump(stamp_payload({"section": "serve", "records": records}),
                  f, indent=1)
    print(f"serve,WROTE,{out_path},,,,")


def bench_roofline() -> None:
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    print("section,arch,shape,mesh,compute_s,memory_s,collective_s,"
          "dominant,useful_ratio,fits_hbm,step_bound_s")
    if not files:
        print(f"roofline,NO_ARTIFACTS,run `python -m repro.launch.dryrun` "
              f"first (looked in {DRYRUN_DIR}),,,,,,,,")
        return
    for f in files:
        r = json.load(open(f))
        ro = r.get("roofline", {})
        mesh = "multi" if r.get("multi_pod") else "single"
        print(f"roofline,{r['arch']},{r['shape']},{mesh},"
              f"{ro.get('compute_s', 0):.4f},{ro.get('memory_s', 0):.4f},"
              f"{ro.get('collective_s', 0):.4f},{ro.get('dominant','?')},"
              f"{ro.get('useful_flops_ratio', 0):.3f},"
              f"{r.get('fits_hbm')},{ro.get('step_time_bound_s', 0):.4f}")


SECTIONS = {
    "table1": bench_table1,
    "table2": bench_table2,
    "table3": bench_table3,
    "fig7": bench_fig7,
    "baselines": bench_baselines,
    "engine": bench_engine,
    "kernels": bench_kernels,
    "kernels_fused": bench_kernels_fused,
    "serve": bench_serve,
    "roofline": bench_roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(SECTIONS)
    for n in names:
        SECTIONS[n]()


if __name__ == "__main__":
    main()
