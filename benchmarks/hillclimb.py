import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""§Perf hillclimb driver: runs named optimization variants of the three
chosen cells through the dry-run pipeline and records the roofline deltas.

  PYTHONPATH=src python -m benchmarks.hillclimb            # all variants
  PYTHONPATH=src python -m benchmarks.hillclimb mamba2     # one cell

The iteration log (hypothesis / napkin math / result) lives in
EXPERIMENTS.md §Perf; this script produces the measured numbers it cites.
"""
import json
import sys

import jax

from repro.configs.base import DECODE_32K, TRAIN_4K
from repro.launch.dryrun import run_cell

OUT = "experiments/perf"

#: (cell-key, arch, cell, variant-name, cfg_overrides, fsdp[, accum])
VARIANTS = [
    # arctic fit completion: fsdp + 4-way gradient accumulation drops the
    # per-microbatch activation peak ~4x (the B2 residual)
    ("arctic3", "arctic-480b", TRAIN_4K, "it3_fsdp_accum4", {}, True, 4),
    # --- Cell A: mamba2-130m train_4k (paper-representative: TrIM-1D +
    #     SSD chunked; worst memory/compute ratio among train cells) ---
    ("mamba2", "mamba2-130m", TRAIN_4K, "it1_sharded_padded_ce", {}, False),
    ("mamba2", "mamba2-130m", TRAIN_4K, "it2_chunked_ce",
     {"ce_impl": "chunked"}, False),
    ("mamba2", "mamba2-130m", TRAIN_4K, "it3_ssd_bf16",
     {"ce_impl": "chunked", "ssd_bf16": True}, False),
    ("mamba2", "mamba2-130m", TRAIN_4K, "it4_remat_none",
     {"ce_impl": "chunked", "ssd_bf16": True, "remat": "none"}, False),
    # it2/it3 refuted -> revert to padded CE + f32 scores; vary structure
    ("mamba2b", "mamba2-130m", TRAIN_4K, "it5_remat_none_only",
     {"remat": "none"}, False),
    ("mamba2b", "mamba2-130m", TRAIN_4K, "it6_chunk128",
     {"remat": "none", "ssm_chunk": 128}, False),
    ("mamba2b", "mamba2-130m", TRAIN_4K, "it7_chunk64",
     {"remat": "none", "ssm_chunk": 64}, False),
    # remat=none exceeds 16 GB/chip activations (fits_hbm False): keep the
    # remat=dots fit and take the chunk-size win alone
    ("mamba2c", "mamba2-130m", TRAIN_4K, "it8_chunk128_dots",
     {"ssm_chunk": 128}, False),
    # --- Cell B: arctic-480b train_4k (most collective-bound) ---
    ("arctic", "arctic-480b", TRAIN_4K, "it1_index_gather_dispatch",
     {}, False),
    ("arctic", "arctic-480b", TRAIN_4K, "it2_fsdp",
     {}, True),
    # --- Cell C: mistral-large-123b decode_32k (serve; misses HBM) ---
    ("mistral", "mistral-large-123b", DECODE_32K, "it1_kv_seqshard",
     {"decode_kv_seqshard": True}, False),
    ("mistral", "mistral-large-123b", DECODE_32K, "it2_kv_seqshard_fsdp",
     {"decode_kv_seqshard": True}, True),
    # it2 fits but the per-step weight all-gathers dominate; the 2d layout
    # (seq over data+model, batch replicated, partial-sum matmuls) should
    # drop the memory term ~16x with only tiny activation psums.
    ("mistral2", "mistral-large-123b", DECODE_32K, "it3_serve2d",
     {"decode_kv_seqshard": "2d"}, True),
]


def main() -> None:
    os.makedirs(OUT, exist_ok=True)
    only = set(sys.argv[1:])
    for key, arch, cell, name, overrides, fsdp, *rest in VARIANTS:
        accum = rest[0] if rest else 1
        if only and key not in only:
            continue
        tag = f"{arch}__{cell.name}__{name}"
        print(f"[perf] {tag} ...", flush=True)
        try:
            rec = run_cell(arch, cell, multi_pod=False, fsdp=fsdp,
                           cfg_overrides=overrides, accum=accum)
        except Exception as e:
            print(f"[perf] FAIL {tag}: {e}")
            import traceback
            traceback.print_exc()
            continue
        finally:
            jax.clear_caches()
        rec["variant"] = name
        with open(os.path.join(OUT, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        r = rec["roofline"]
        print(f"[perf]   compute {r['compute_s']*1e3:.2f}ms  "
              f"memory {r['memory_s']*1e3:.2f}ms  "
              f"collective {r['collective_s']*1e3:.2f}ms  "
              f"bound {r['step_time_bound_s']*1e3:.2f}ms  "
              f"useful {r['useful_flops_ratio']:.3f}  "
              f"fits={rec['fits_hbm']}", flush=True)


if __name__ == "__main__":
    main()
