import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""§Perf hillclimb driver: runs named optimization variants of the chosen
cells and records the deltas.

  PYTHONPATH=src python -m benchmarks.hillclimb            # all variants
  PYTHONPATH=src python -m benchmarks.hillclimb mamba2     # one LM cell
  PYTHONPATH=src python -m benchmarks.hillclimb vgg16_conv # one conv cell

Two variant families:

- LM cells (the transformer/Mamba dry-run variants below) go through the
  dry-run pipeline and record roofline deltas.
- TrIM conv cells (``vgg16_conv`` / ``alexnet_conv`` / ``wide512_conv``)
  are driven through the per-layer plan autotuner
  (``benchmarks.autotune.tune_cell`` — the search/measure/persist engine
  lives there, DESIGN.md §7): each cell tunes its layer set and records
  the measured default-vs-tuned schedule deltas per layer.  Hillclimbing
  conv schedules by hand predates the autotuner; these variants now
  report what the tuner found instead.

The iteration log (hypothesis / napkin math / result) lives in
EXPERIMENTS.md §Perf; this script produces the measured numbers it cites.
"""
import json
import sys

import jax

from repro.configs.base import DECODE_32K, TRAIN_4K
from repro.launch.dryrun import run_cell

OUT = "experiments/perf"

#: (cell-key, arch, cell, variant-name, cfg_overrides, fsdp[, accum])
VARIANTS = [
    # arctic fit completion: fsdp + 4-way gradient accumulation drops the
    # per-microbatch activation peak ~4x (the B2 residual)
    ("arctic3", "arctic-480b", TRAIN_4K, "it3_fsdp_accum4", {}, True, 4),
    # --- Cell A: mamba2-130m train_4k (paper-representative: TrIM-1D +
    #     SSD chunked; worst memory/compute ratio among train cells) ---
    ("mamba2", "mamba2-130m", TRAIN_4K, "it1_sharded_padded_ce", {}, False),
    ("mamba2", "mamba2-130m", TRAIN_4K, "it2_chunked_ce",
     {"ce_impl": "chunked"}, False),
    ("mamba2", "mamba2-130m", TRAIN_4K, "it3_ssd_bf16",
     {"ce_impl": "chunked", "ssd_bf16": True}, False),
    ("mamba2", "mamba2-130m", TRAIN_4K, "it4_remat_none",
     {"ce_impl": "chunked", "ssd_bf16": True, "remat": "none"}, False),
    # it2/it3 refuted -> revert to padded CE + f32 scores; vary structure
    ("mamba2b", "mamba2-130m", TRAIN_4K, "it5_remat_none_only",
     {"remat": "none"}, False),
    ("mamba2b", "mamba2-130m", TRAIN_4K, "it6_chunk128",
     {"remat": "none", "ssm_chunk": 128}, False),
    ("mamba2b", "mamba2-130m", TRAIN_4K, "it7_chunk64",
     {"remat": "none", "ssm_chunk": 64}, False),
    # remat=none exceeds 16 GB/chip activations (fits_hbm False): keep the
    # remat=dots fit and take the chunk-size win alone
    ("mamba2c", "mamba2-130m", TRAIN_4K, "it8_chunk128_dots",
     {"ssm_chunk": 128}, False),
    # --- Cell B: arctic-480b train_4k (most collective-bound) ---
    ("arctic", "arctic-480b", TRAIN_4K, "it1_index_gather_dispatch",
     {}, False),
    ("arctic", "arctic-480b", TRAIN_4K, "it2_fsdp",
     {}, True),
    # --- Cell C: mistral-large-123b decode_32k (serve; misses HBM) ---
    ("mistral", "mistral-large-123b", DECODE_32K, "it1_kv_seqshard",
     {"decode_kv_seqshard": True}, False),
    ("mistral", "mistral-large-123b", DECODE_32K, "it2_kv_seqshard_fsdp",
     {"decode_kv_seqshard": True}, True),
    # it2 fits but the per-step weight all-gathers dominate; the 2d layout
    # (seq over data+model, batch replicated, partial-sum matmuls) should
    # drop the memory term ~16x with only tiny activation psums.
    ("mistral2", "mistral-large-123b", DECODE_32K, "it3_serve2d",
     {"decode_kv_seqshard": "2d"}, True),
]


#: TrIM conv cells: tuned through benchmarks.autotune (vgg16/alexnet =
#: full float model walk + smoke int8 walk + the cell's kernel-table
#: shapes; wide512 = the wide-feature-map kernel shapes, float + int8).
CNN_CELLS = {
    "vgg16_conv": "vgg16",
    "alexnet_conv": "alexnet",
    "wide512_conv": "wide512",
}


def run_cnn_cell(key: str) -> None:
    """One conv cell through the autotuner; record per-layer deltas
    (rows share `benchmarks.autotune.report_row`'s schema, so these
    artifacts stay consistent with autotune's report.json)."""
    from benchmarks.autotune import report_row, tune_cell
    tag = f"trim__{key}__autotune"
    print(f"[perf] {tag} ...", flush=True)
    try:
        results = tune_cell(CNN_CELLS[key], reps=3)
    except Exception as e:
        print(f"[perf] FAIL {tag}: {e}")
        import traceback
        traceback.print_exc()
        return
    finally:
        jax.clear_caches()
    rows = [report_row(n, r) for n, r in results]
    with open(os.path.join(OUT, tag + ".json"), "w") as f:
        json.dump({"variant": key, "records": rows}, f, indent=1)
    for row in rows:
        print(f"[perf]   {row['name']}: default {row['us_default']:.0f}us"
              f" -> tuned {row['us_tuned']:.0f}us"
              f" ({row['ratio']:.2f}x, {row['schedule']['substrate']})",
              flush=True)


def main() -> None:
    os.makedirs(OUT, exist_ok=True)
    only = set(sys.argv[1:])
    for key in sorted(only & set(CNN_CELLS) if only else set(CNN_CELLS)):
        run_cnn_cell(key)
    for key, arch, cell, name, overrides, fsdp, *rest in VARIANTS:
        accum = rest[0] if rest else 1
        if only and key not in only:
            continue
        tag = f"{arch}__{cell.name}__{name}"
        print(f"[perf] {tag} ...", flush=True)
        try:
            rec = run_cell(arch, cell, multi_pod=False, fsdp=fsdp,
                           cfg_overrides=overrides, accum=accum)
        except Exception as e:
            print(f"[perf] FAIL {tag}: {e}")
            import traceback
            traceback.print_exc()
            continue
        finally:
            jax.clear_caches()
        rec["variant"] = name
        with open(os.path.join(OUT, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        r = rec["roofline"]
        print(f"[perf]   compute {r['compute_s']*1e3:.2f}ms  "
              f"memory {r['memory_s']*1e3:.2f}ms  "
              f"collective {r['collective_s']*1e3:.2f}ms  "
              f"bound {r['step_time_bound_s']*1e3:.2f}ms  "
              f"useful {r['useful_flops_ratio']:.3f}  "
              f"fits={rec['fits_hbm']}", flush=True)


if __name__ == "__main__":
    main()
