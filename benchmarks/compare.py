"""Benchmark regression gate: fresh BENCH_kernels.json vs the baseline.

CI's bench-gate lane re-runs ``benchmarks.run kernels_fused`` and calls

  python -m benchmarks.compare --baseline BENCH_baseline.json

failing (exit 1) when any fused timing regresses by more than the
threshold (default 1.3x) against the committed baseline.  Records present
only on one side are reported but do not fail the gate (new shapes land
with the PR that adds them; the baseline is refreshed deliberately), and
records that do not carry the requested metric are skipped with a warning
— e.g. the ``conv2d_grads`` records carry ``us_grads`` but no
``us_fused``/``speedup``, and vice versa — so mixed-metric record sets
never KeyError the gate.

Each ``kernels_fused`` record also carries the resolved execution plan
(substrate, chosen width tile, epilogue kind — ``repro.engine``); when a
record regresses, the plan diff between baseline and current is printed so
schedule changes (a different tile pick, a substrate switch) are
attributable at the gate.

Metric direction is automatic: ``us_*`` / ``*_ms`` metrics are
lower-is-better wall-clock timings, ``speedup`` / ``tuned_speedup`` /
``*per_s`` throughputs are higher-is-better.  Absolute wall-clock-derived
comparisons (``us_*``, ``*_ms`` latencies, ``*per_s`` throughputs — the
serving gate's ``images_per_s`` / ``p99_ms``) are only meaningful against
a baseline from the same runner class — every record (and the artifact
header) carries a ``backend`` + ``device_kind`` stamp, and when baseline
and candidate device kinds differ those machine-scoped gates are SKIPPED
with a visible warning (a dev-machine or TPU baseline must not fail a CPU
CI runner on wall-clock alone).  The machine-neutral ratio gates
(``--metric speedup`` — fused vs decimate arm measured in the *same* run
— and ``tuned_speedup``) always apply.  Refresh BENCH_baseline.json when
the fleet (or a TPU runner) changes.

Exit codes: 0 ok, 1 regression, 2 usage/input error.
"""

from __future__ import annotations

import argparse
import json
import os
import sys


def load_records(path):
    with open(path) as f:
        data = json.load(f)
    return {r["name"]: r for r in data.get("records", [])}


def device_kind_of(path):
    """The artifact's device kind: the schema_version>=2 top-level header
    (``repro.serve.stamp_payload`` — BENCH_serve.json and the launcher
    metrics artifacts), else the legacy ``device`` stamp dict
    (BENCH_kernels.json), else the first stamped record, else None
    (pre-stamp artifacts)."""
    with open(path) as f:
        data = json.load(f)
    kind = data.get("device_kind") or (data.get("device") or {}).get(
        "device_kind")
    if kind:
        return kind
    for r in data.get("records", []):
        if r.get("device_kind"):
            return r["device_kind"]
    return None


def higher_is_better(metric):
    """speedup ratios and ``*per_s`` throughputs go up; timings go down."""
    return metric.endswith("speedup") or metric.endswith("per_s")


def machine_scoped(metric):
    """True for absolute wall-clock-derived metrics that only compare
    within one (backend, device kind) class: ``us_*`` timings, ``*_ms``
    latencies, ``*per_s`` throughputs.  Ratio metrics measured within a
    single run (``speedup``, ``tuned_speedup``) are machine-neutral."""
    return (
        metric.startswith("us_")
        or metric.endswith("_ms")
        or metric.endswith("per_s")
    )


def check_floor(current, metric, floor):
    """Absolute-floor gate: fail any record whose ``metric`` value sits
    below ``floor``.  Used for ratios that are >= 1 by construction (the
    tuned-vs-default ratio — DESIGN.md §7): a relative-to-baseline check
    would red-flag machine-dependent swings of a 50x win, while the floor
    only fires when the lane actually collapses (tuned slower than the
    default it replaced).  Records without the metric are skipped with a
    warning, like compare()."""
    failures = []
    lines = []
    for name in sorted(current):
        if metric not in current[name]:
            lines.append(
                f"SKIPPED   {name}: record has no metric '{metric}' "
                "(warning)"
            )
            continue
        val = float(current[name][metric])
        status = "OK"
        if val < floor:
            status = "REGRESSED"
            failures.append(name)
        lines.append(
            f"{status:<10}{name}: {metric} {val:.2f} (floor {floor:.2f})"
        )
    return failures, lines


def compare(baseline, current, metric, threshold):
    """Return (failures, lines) comparing current vs baseline records."""
    lower_is_better = not higher_is_better(metric)
    failures = []
    lines = []
    for name in sorted(set(baseline) | set(current)):
        if name not in baseline:
            lines.append(f"NEW       {name}: no baseline entry (ok)")
            continue
        if name not in current:
            lines.append(
                f"MISSING   {name}: baseline entry absent from the fresh "
                "run — skipped (warning)"
            )
            continue
        if metric not in baseline[name] or metric not in current[name]:
            side = "baseline" if metric not in baseline[name] else "current"
            lines.append(
                f"SKIPPED   {name}: {side} record has no metric "
                f"'{metric}' (warning)"
            )
            continue
        base = float(baseline[name][metric])
        cur = float(current[name][metric])
        if lower_is_better:
            ratio = cur / base if base > 0 else float("inf")
        else:
            ratio = base / cur if cur > 0 else float("inf")
        status = "OK"
        if ratio > threshold:
            status = "REGRESSED"
            failures.append(name)
        msg = f"{status:<10}{name}: {metric} {base:.1f} -> {cur:.1f}"
        lines.append(msg + f" ({ratio:.2f}x worse, gate {threshold:.2f}x)")
        if status == "REGRESSED":
            # Attribute the regression: records carry the resolved
            # execution plan (substrate / width tile / epilogue kind) —
            # print the diff so schedule changes are visible at the gate.
            bp = baseline[name].get("plan")
            cp = current[name].get("plan")
            if bp != cp:
                lines.append(f"          plan changed: {bp} -> {cp}")
            elif cp is not None:
                lines.append(f"          plan unchanged: {cp}")
    return failures, lines


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", default="BENCH_baseline.json")
    ap.add_argument("--current", default="BENCH_kernels.json")
    ap.add_argument("--metric", default="us_fused")
    default_thresh = float(os.environ.get("BENCH_GATE_THRESHOLD", "1.3"))
    ap.add_argument("--threshold", type=float, default=default_thresh)
    ap.add_argument(
        "--floor",
        type=float,
        default=None,
        help="absolute gate instead of baseline-relative: fail records "
        "whose metric value is below this floor (for by-construction "
        ">= 1 ratios like tuned_speedup)",
    )
    args = ap.parse_args(argv)
    if args.floor is not None:
        if not os.path.exists(args.current):
            print(f"bench-gate: missing {args.current}", file=sys.stderr)
            return 2
        current = load_records(args.current)
        if not current:
            print("bench-gate: empty record set", file=sys.stderr)
            return 2
        failures, lines = check_floor(current, args.metric, args.floor)
        for line in lines:
            print(f"bench-gate: {line}")
        if failures:
            print(f"bench-gate: FAIL — below floor: {', '.join(failures)}")
            return 1
        print("bench-gate: PASS")
        return 0
    for path in (args.baseline, args.current):
        if not os.path.exists(path):
            print(f"bench-gate: missing {path}", file=sys.stderr)
            return 2
    baseline = load_records(args.baseline)
    current = load_records(args.current)
    if not baseline or not current:
        print("bench-gate: empty record set", file=sys.stderr)
        return 2
    if machine_scoped(args.metric):
        bk = device_kind_of(args.baseline)
        ck = device_kind_of(args.current)
        if bk and ck and bk != ck:
            print(
                "bench-gate: WARNING — baseline device kind "
                f"{bk!r} != current {ck!r}; absolute {args.metric!r} "
                "values do not compare across device kinds, SKIPPING "
                "this gate (the machine-neutral ratio gates still apply)"
            )
            print("bench-gate: PASS (skipped: device-kind mismatch)")
            return 0
    failures, lines = compare(baseline, current, args.metric, args.threshold)
    for line in lines:
        print(f"bench-gate: {line}")
    if failures:
        names = ", ".join(failures)
        print(f"bench-gate: FAIL — regressions in: {names}")
        return 1
    print("bench-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
