"""Docs rules (docs-link / docs-section-ref) — the static half of the old
``tools/check_docs.py``, absorbed into the api-hygiene pass.

``tools/check_docs.py`` remains as a thin CLI shim (it adds the
quickstart execution check, which needs a subprocess and jax and so does
not belong in the pure-AST analyzer).  The regexes and file sets here are
the single copy; the shim re-exports them.
"""

from __future__ import annotations

import os
import re
from typing import Iterable, List, Set

from tools.analysis.core import Finding

MARKDOWN_FILES = ["README.md", "DESIGN.md", "ROADMAP.md", "benchmarks/README.md"]

#: ``[text](target)`` — good enough for our docs; skips images/autolinks.
LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
#: A section citation: "DESIGN.md §9.3", "DESIGN.md §4", "(§7)", "§9.2's".
SECTION_REF_RE = re.compile(r"DESIGN\.md[^§\n]{0,20}§(\d+(?:\.\d+)?)")
HEADING_RE = re.compile(r"^#{2,3}\s+§(\d+(?:\.\d+)?)\b", re.M)
#: Source globs scanned for DESIGN.md citations.
SOURCE_DIRS = ["src", "tests", "benchmarks", "examples", "tools"]
#: The seeded-violation corpus contains deliberately-broken docs repos;
#: they are analyzed with an explicit root by the tests, never implicitly.
SKIP_MARKER = "fixtures/analysis"


def design_sections(root: str) -> Set[str]:
    path = os.path.join(root, "DESIGN.md")
    if not os.path.exists(path):
        return set()
    with open(path, encoding="utf-8") as f:
        return set(HEADING_RE.findall(f.read()))


def iter_source_files(root: str) -> Iterable[str]:
    for d in SOURCE_DIRS:
        base = os.path.join(root, d)
        for dirpath, dirnames, files in os.walk(base):
            dirnames[:] = [x for x in dirnames if x != "__pycache__"]
            for f in sorted(files):
                if f.endswith((".py", ".md", ".yml")):
                    rel = os.path.relpath(os.path.join(dirpath, f), root)
                    rel = rel.replace(os.sep, "/")
                    if SKIP_MARKER in rel:
                        continue
                    yield rel


def check_links(root: str) -> List[Finding]:
    findings: List[Finding] = []
    for md in MARKDOWN_FILES:
        path = os.path.join(root, md)
        if not os.path.exists(path):
            findings.append(
                Finding("docs-link", md, 1, "tracked markdown file missing")
            )
            continue
        with open(path, encoding="utf-8") as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines, start=1):
            for target in LINK_RE.findall(line):
                if "://" in target or target.startswith(("mailto:", "#")):
                    continue
                rel = target.split("#")[0]
                if not rel:
                    continue
                resolved = os.path.normpath(
                    os.path.join(root, os.path.dirname(md), rel)
                )
                if not os.path.exists(resolved):
                    findings.append(
                        Finding(
                            "docs-link", md, i, f"broken link -> {target}"
                        )
                    )
    return findings


def check_section_refs(root: str) -> List[Finding]:
    findings: List[Finding] = []
    sections = design_sections(root)
    if not sections:
        findings.append(
            Finding(
                "docs-section-ref",
                "DESIGN.md",
                1,
                "no §-numbered headings found",
            )
        )
        return findings
    targets = list(MARKDOWN_FILES) + list(iter_source_files(root))
    seen = set()
    for rel in targets:
        if rel in seen:
            continue
        seen.add(rel)
        path = os.path.join(root, rel)
        if not os.path.exists(path):
            continue
        with open(path, encoding="utf-8", errors="replace") as f:
            lines = f.read().splitlines()
        for i, line in enumerate(lines, start=1):
            for ref in SECTION_REF_RE.findall(line):
                top = ref.split(".")[0]
                if ref not in sections and top not in sections:
                    findings.append(
                        Finding(
                            "docs-section-ref",
                            rel,
                            i,
                            f"cites DESIGN.md §{ref} but DESIGN.md has no "
                            f"such heading",
                        )
                    )
                elif ref not in sections and "." in ref:
                    findings.append(
                        Finding(
                            "docs-section-ref",
                            rel,
                            i,
                            f"cites DESIGN.md §{ref}; §{top} exists but "
                            f"the subsection heading does not",
                        )
                    )
    return findings


def check(root: str) -> List[Finding]:
    return check_links(root) + check_section_refs(root)
