"""API-hygiene pass (rule hygiene-deprecation-warns).

Two complementary checks on deprecation shims:

1. A function whose docstring begins with "Deprecated" promises callers a
   migration signal — its body must contain
   ``warnings.warn(..., DeprecationWarning)`` (``FutureWarning`` also
   accepted: it is the louder, user-facing variant).
2. Conversely, any ``warnings.warn`` whose message mentions
   "deprecated" must pass one of those categories — the default
   ``UserWarning`` is invisible to ``-W error::DeprecationWarning`` test
   rigs, so the shim would rot silently.
"""

from __future__ import annotations

import ast
from typing import List

from tools.analysis.core import Finding, SourceFile, attr_chain

_OK_CATEGORIES = {"DeprecationWarning", "FutureWarning", "PendingDeprecationWarning"}


def _warn_category(call: ast.Call) -> str:
    """Category name passed to warnings.warn, or 'UserWarning' default."""
    cat = None
    if len(call.args) >= 2:
        cat = call.args[1]
    for kw in call.keywords:
        if kw.arg == "category":
            cat = kw.value
    if cat is None:
        return "UserWarning"
    chain = attr_chain(cat)
    return chain.split(".")[-1] if chain else "<expr>"


def _is_warn(call: ast.Call) -> bool:
    chain = attr_chain(call.func)
    return chain in ("warnings.warn", "warn")


def _msg_mentions_deprecated(call: ast.Call) -> bool:
    if not call.args:
        return False
    msg = call.args[0]
    for sub in ast.walk(msg):
        if isinstance(sub, ast.Constant) and isinstance(sub.value, str):
            if "deprecat" in sub.value.lower():
                return True
    return False


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for fn in ast.walk(sf.tree):
        if not isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        doc = ast.get_docstring(fn) or ""
        documented_deprecated = doc.lstrip().lower().startswith("deprecated")
        warned_ok = False
        for sub in ast.walk(fn):
            if not (isinstance(sub, ast.Call) and _is_warn(sub)):
                continue
            cat = _warn_category(sub)
            if cat in _OK_CATEGORIES:
                warned_ok = True
            elif _msg_mentions_deprecated(sub):
                findings.append(
                    sf.finding(
                        "hygiene-deprecation-warns",
                        sub,
                        f"{fn.name}: warns about deprecation with category "
                        f"{cat} — pass DeprecationWarning so -W filters "
                        f"and test rigs can see it",
                    )
                )
        if documented_deprecated and not warned_ok:
            findings.append(
                sf.finding(
                    "hygiene-deprecation-warns",
                    fn,
                    f"{fn.name}: docstring says Deprecated but the body "
                    f"never emits DeprecationWarning — silent shims rot",
                )
            )
    return findings
