"""trimcheck core: findings, suppressions, source files, and the driver.

The analysis framework is deliberately stdlib-only (``ast`` + ``re``): the
CI ``static-analysis`` lane and the tier-1 ``tests/test_analysis.py`` run
it without importing jax, so a broken accelerator install can never mask a
source-level invariant violation.

Vocabulary:

- A **rule** is one named invariant (``lock-guarded-attr``,
  ``pallas-int64``, ...).  ``tools.analysis.RULES`` is the catalog
  (DESIGN.md §10 documents each rule's rationale).
- A **pass** is a group of rules sharing one traversal (lock-ownership,
  trace-safety, pallas-contract, api-hygiene, silent-except).
- A **Finding** is one violation at one source line.  ``python -m
  tools.analysis`` exits non-zero when any finding survives suppression.
- A **suppression** is an inline ``# trimcheck: disable=<rule>[,...] --
  <reason>`` comment on the offending line (or the line directly above
  it).  The reason is REQUIRED: a reasonless disable is itself a finding
  (``suppress-needs-reason``) — intentional exceptions must say why.
"""

from __future__ import annotations

import ast
import dataclasses
import os
import re
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: Bumped when finding semantics / JSON schema change.
TRIMCHECK_VERSION = 1

#: ``# trimcheck: disable=rule-a,rule-b -- why this is fine``
SUPPRESS_RE = re.compile(
    r"#\s*trimcheck:\s*disable=([A-Za-z0-9_,-]+)\s*(?:--\s*(\S.*))?$"
)


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one source location."""

    rule: str
    path: str  # repo-relative, "/"-separated
    line: int  # 1-based
    message: str

    def to_dict(self) -> Dict[str, object]:
        return {
            "rule": self.rule,
            "path": self.path,
            "line": self.line,
            "message": self.message,
        }

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"


class SourceFile:
    """A parsed Python source file plus parent links for ancestor walks."""

    def __init__(self, root: str, relpath: str):
        self.root = root
        self.path = relpath.replace(os.sep, "/")
        self.abspath = os.path.join(root, relpath)
        with open(self.abspath, encoding="utf-8") as f:
            self.text = f.read()
        self.lines = self.text.splitlines()
        self.tree = ast.parse(self.text, filename=self.path)
        self._parents: Optional[Dict[ast.AST, ast.AST]] = None

    @property
    def parents(self) -> Dict[ast.AST, ast.AST]:
        if self._parents is None:
            self._parents = {}
            for node in ast.walk(self.tree):
                for child in ast.iter_child_nodes(node):
                    self._parents[child] = node
        return self._parents

    def ancestors(self, node: ast.AST) -> Iterable[ast.AST]:
        """Walk from ``node``'s parent up to the module node."""
        parents = self.parents
        cur = parents.get(node)
        while cur is not None:
            yield cur
            cur = parents.get(cur)

    def finding(self, rule: str, node_or_line, message: str) -> Finding:
        line = (
            node_or_line
            if isinstance(node_or_line, int)
            else getattr(node_or_line, "lineno", 0)
        )
        return Finding(rule=rule, path=self.path, line=line, message=message)


def attr_chain(node: ast.AST) -> Optional[str]:
    """``a.b.c`` -> "a.b.c" for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def terminal_name(func: ast.AST) -> Optional[str]:
    """The last path segment of a call target: ``np.asarray`` -> "asarray",
    ``sleep`` -> "sleep"."""
    if isinstance(func, ast.Attribute):
        return func.attr
    if isinstance(func, ast.Name):
        return func.id
    return None


# ---------------------------------------------------------------------------
# Suppressions
# ---------------------------------------------------------------------------


def scan_suppressions(
    sf: SourceFile,
) -> Tuple[Dict[int, Set[str]], List[Finding]]:
    """Parse ``# trimcheck: disable=...`` comments.

    Returns (line -> suppressed rule names, findings for reasonless
    disables).  A trailing suppression covers its own line; a standalone
    comment covers itself, any immediately following comment-only lines
    (the reason may wrap), and the first code line after them.
    """
    by_line: Dict[int, Set[str]] = {}
    findings: List[Finding] = []
    for i, text in enumerate(sf.lines, start=1):
        m = SUPPRESS_RE.search(text)
        if not m:
            continue
        rules = {r.strip() for r in m.group(1).split(",") if r.strip()}
        reason = (m.group(2) or "").strip()
        if not reason:
            findings.append(
                sf.finding(
                    "suppress-needs-reason",
                    i,
                    "trimcheck: disable without a reason — append "
                    "'-- <why this exception is sound>'",
                )
            )
            continue
        by_line.setdefault(i, set()).update(rules)
        if text.lstrip().startswith("#"):
            # Standalone comment: cover through the wrapped-reason comment
            # block and the first code line that follows it.
            j = i + 1
            while j <= len(sf.lines) and sf.lines[j - 1].lstrip().startswith("#"):
                by_line.setdefault(j, set()).update(rules)
                j += 1
            by_line.setdefault(j, set()).update(rules)
    return by_line, findings


def apply_suppressions(
    findings: Sequence[Finding],
    suppressed: Dict[str, Dict[int, Set[str]]],
) -> List[Finding]:
    """Drop findings covered by a (path, line) suppression for their rule
    (or for ``all``).  ``suppress-needs-reason`` findings are never
    droppable — the reasonless comment itself is the defect."""
    out = []
    for f in findings:
        if f.rule != "suppress-needs-reason":
            rules = suppressed.get(f.path, {}).get(f.line, set())
            if f.rule in rules or "all" in rules:
                continue
        out.append(f)
    return out


# ---------------------------------------------------------------------------
# Config + driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LockSpec:
    """One declared lock-ownership contract: inside class ``cls`` (in the
    mapped file), reads/writes of ``guarded`` attributes must happen under
    ``with self.<lock_attr>``.  THE guarded-attribute map — the single
    source of truth DESIGN.md §8 defers to — lives in
    ``tools.analysis.locks.DEFAULT_LOCK_MAP``."""

    cls: str
    lock_attr: str
    guarded: Tuple[str, ...]


@dataclasses.dataclass(frozen=True)
class Config:
    """What to analyze.  The zero-arg default is THE repo contract: the
    committed lock map, the engine/kernels trace scope, and the markdown
    set — ``python -m tools.analysis`` runs exactly this."""

    root: str = "."
    #: path -> LockSpecs for the lock-ownership pass.
    lock_map: Optional[Dict[str, Tuple[LockSpec, ...]]] = None
    #: directories (repo-relative) scanned by the trace-safety pass.
    trace_dirs: Tuple[str, ...] = ("src/repro/engine", "src/repro/kernels")
    #: directories scanned by the pallas-contract pass.
    pallas_dirs: Tuple[str, ...] = ("src/repro/kernels",)
    #: directories scanned by the api-hygiene (deprecation) pass.
    hygiene_dirs: Tuple[str, ...] = ("src/repro",)
    #: directories scanned by the silent-except pass (the serve layer's
    #: no-silent-swallow discipline, DESIGN.md §11).
    except_dirs: Tuple[str, ...] = ("src/repro/serve",)
    #: run the repo-level docs rules (markdown links + §-citations).
    docs: bool = True
    #: restrict to these rules (None = all).
    select: Optional[Tuple[str, ...]] = None
    #: restrict findings to paths carrying one of these prefixes.
    paths: Optional[Tuple[str, ...]] = None


def iter_py_files(root: str, rel_dirs: Sequence[str]) -> Iterable[str]:
    seen = set()
    for d in rel_dirs:
        base = os.path.join(root, d)
        if os.path.isfile(base) and d.endswith(".py"):
            if d not in seen:
                seen.add(d)
                yield d
            continue
        for dirpath, dirnames, filenames in os.walk(base):
            dirnames[:] = [x for x in dirnames if x != "__pycache__"]
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                rel = os.path.relpath(os.path.join(dirpath, fn), root)
                rel = rel.replace(os.sep, "/")
                if rel not in seen:
                    seen.add(rel)
                    yield rel


def load_source(root: str, rel: str) -> Optional[SourceFile]:
    try:
        return SourceFile(root, rel)
    except (OSError, SyntaxError):
        return None


def run_analysis(cfg: Optional[Config] = None) -> List[Finding]:
    """Run every selected pass under ``cfg``; returns surviving findings."""
    from tools.analysis import docs, excepts, hygiene, locks, pallas_pass, trace

    cfg = cfg or Config()
    lock_map = cfg.lock_map if cfg.lock_map is not None else locks.DEFAULT_LOCK_MAP

    raw: List[Finding] = []
    suppressed: Dict[str, Dict[int, Set[str]]] = {}
    scanned: Dict[str, SourceFile] = {}

    def get(rel: str) -> Optional[SourceFile]:
        if rel not in scanned:
            sf = load_source(cfg.root, rel)
            if sf is None:
                return None
            scanned[rel] = sf
            sup, sup_findings = scan_suppressions(sf)
            suppressed[sf.path] = sup
            raw.extend(sup_findings)
        return scanned[rel]

    # Lock-ownership pass: only the declared files.
    for rel, specs in sorted(lock_map.items()):
        sf = get(rel)
        if sf is not None:
            raw.extend(locks.check(sf, specs))

    # Trace-safety pass.
    for rel in iter_py_files(cfg.root, cfg.trace_dirs):
        sf = get(rel)
        if sf is not None:
            raw.extend(trace.check(sf))

    # Pallas kernel-contract pass.
    for rel in iter_py_files(cfg.root, cfg.pallas_dirs):
        sf = get(rel)
        if sf is not None:
            raw.extend(pallas_pass.check(sf))

    # API-hygiene pass (deprecation shims).
    for rel in iter_py_files(cfg.root, cfg.hygiene_dirs):
        sf = get(rel)
        if sf is not None:
            raw.extend(hygiene.check(sf))

    # Silent-except pass (serve-layer swallow discipline).
    for rel in iter_py_files(cfg.root, cfg.except_dirs):
        sf = get(rel)
        if sf is not None:
            raw.extend(excepts.check(sf))

    # Repo-level docs rules (absorbed tools/check_docs.py static half).
    if cfg.docs:
        raw.extend(docs.check(cfg.root))

    findings = apply_suppressions(raw, suppressed)
    if cfg.select is not None:
        keep = set(cfg.select)
        findings = [f for f in findings if f.rule in keep]
    if cfg.paths is not None:
        findings = [
            f for f in findings if any(f.path.startswith(p) for p in cfg.paths)
        ]
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
