"""``python -m tools.analysis`` — run trimcheck over the repo.

Exit codes: 0 clean, 1 findings, 2 usage error.

Examples::

    python -m tools.analysis                      # all passes, human output
    python -m tools.analysis --json               # machine-readable (CI)
    python -m tools.analysis --select lock-guarded-attr,lock-wait-while
    python -m tools.analysis --paths src/repro/serve
    python -m tools.analysis --list               # print the rule catalog
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import Optional

from tools.analysis import RULES, TRIMCHECK_VERSION
from tools.analysis.core import Config, run_analysis


def main(argv: Optional[list] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m tools.analysis",
        description="trimcheck: repo-native static analysis "
        "(lock-ownership, trace-safety, pallas-contract, api-hygiene).",
    )
    ap.add_argument(
        "--root",
        default=None,
        help="repo root to analyze (default: the repo containing tools/)",
    )
    ap.add_argument(
        "--select",
        default=None,
        metavar="RULE[,RULE...]",
        help="only report these rules",
    )
    ap.add_argument(
        "--paths",
        default=None,
        metavar="PREFIX[,PREFIX...]",
        help="only report findings under these path prefixes",
    )
    ap.add_argument(
        "--json", action="store_true", help="machine-readable output"
    )
    ap.add_argument(
        "--list", action="store_true", help="print the rule catalog and exit"
    )
    args = ap.parse_args(argv)

    if args.list:
        width = max(len(r) for r in RULES)
        for rule, desc in RULES.items():
            print(f"{rule.ljust(width)}  {desc}")
        return 0

    select = None
    if args.select:
        select = tuple(r.strip() for r in args.select.split(",") if r.strip())
        unknown = [r for r in select if r not in RULES]
        if unknown:
            print(
                f"trimcheck: unknown rule(s): {', '.join(unknown)} "
                f"(see --list)",
                file=sys.stderr,
            )
            return 2
    paths = None
    if args.paths:
        paths = tuple(p.strip() for p in args.paths.split(",") if p.strip())

    root = args.root or os.path.dirname(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    )
    findings = run_analysis(Config(root=root, select=select, paths=paths))

    if args.json:
        print(
            json.dumps(
                {
                    "version": TRIMCHECK_VERSION,
                    "root": root,
                    "count": len(findings),
                    "findings": [f.to_dict() for f in findings],
                },
                indent=2,
            )
        )
    else:
        for f in findings:
            print(str(f))
        n = len(findings)
        label = "finding" if n == 1 else "findings"
        print(
            f"trimcheck: {n} {label} across {len(RULES)} rules"
            + ("" if n else " — clean")
        )
    return 1 if findings else 0


if __name__ == "__main__":
    sys.exit(main())
