"""Pallas kernel-contract pass (rules pallas-index-map /
pallas-scratch-shape / pallas-int64).

Every ``pl.pallas_call`` site is located syntactically and three
contracts are checked:

1. **Index-map purity.**  BlockSpec index maps must be pure functions of
   the grid indices and static closure.  The repo writes them three
   ways — inline lambdas, module/function-level ``def``s, and factory
   functions returning lambdas (``x_idx(dh, dw)``); all three are
   resolved.  Inside the map body we flag ``self.*`` access and any
   call: both are how mutable state sneaks into what XLA assumes is a
   replayable pure function.
2. **Static scratch shapes.**  ``scratch_shapes`` entries declare VMEM
   allocations; an entry rooted at ``jnp.``/``jax.`` is an array value,
   not a shape declaration, and would bake a traced value into the
   allocation.
3. **int32-only arithmetic.**  TPU Pallas has no int64 (the constraint
   behind the hi/lo-split requant, DESIGN.md §6): kernel bodies must not
   reference int64/uint64 dtypes (attribute, string, or np.dtype form)
   or integer literals outside int32 range.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional

from tools.analysis.core import Finding, SourceFile, attr_chain, terminal_name
from tools.analysis.trace import kernel_functions

INT32_MAX = 2**31 - 1
INT32_MIN = -(2**31)


def _resolve_index_map(
    node: ast.AST, defs: Dict[str, ast.FunctionDef]
) -> Optional[ast.AST]:
    """Lambda | Name-of-def | factory-call-returning-lambda -> map body."""
    if isinstance(node, ast.Lambda):
        return node
    if isinstance(node, ast.Name) and node.id in defs:
        return defs[node.id]
    if isinstance(node, ast.Call):
        callee = terminal_name(node.func)
        fn = defs.get(callee or "")
        if fn is not None:
            for sub in ast.walk(fn):
                if isinstance(sub, ast.Return) and isinstance(
                    sub.value, ast.Lambda
                ):
                    return sub.value
    return None


def _check_map_body(
    sf: SourceFile, site: ast.AST, body: ast.AST, findings: List[Finding]
) -> None:
    for sub in ast.walk(body):
        if isinstance(sub, ast.Attribute) and isinstance(sub.value, ast.Name):
            if sub.value.id == "self":
                findings.append(
                    sf.finding(
                        "pallas-index-map",
                        site,
                        f"index map closes over self.{sub.attr} — instance "
                        f"state is mutable; pass it in as a static instead",
                    )
                )
        elif isinstance(sub, ast.Call):
            findings.append(
                sf.finding(
                    "pallas-index-map",
                    site,
                    f"index map body calls "
                    f"{terminal_name(sub.func) or '<expr>'}() — maps must "
                    f"be pure arithmetic over grid indices",
                )
            )


def _int64ish(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Attribute) and node.attr in ("int64", "uint64"):
        return f".{node.attr}"
    if isinstance(node, ast.Constant):
        if isinstance(node.value, int) and not isinstance(node.value, bool):
            if node.value > INT32_MAX or node.value < INT32_MIN:
                return f"literal {node.value}"
        if isinstance(node.value, str) and node.value in ("int64", "uint64"):
            return f'dtype string "{node.value}"'
    return None


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    defs = {
        n.name: n for n in ast.walk(sf.tree) if isinstance(n, ast.FunctionDef)
    }

    for node in ast.walk(sf.tree):
        if not (
            isinstance(node, ast.Call)
            and terminal_name(node.func) == "pallas_call"
        ):
            continue
        for kw in node.keywords:
            if kw.arg in ("in_specs", "out_specs"):
                specs = (
                    kw.value.elts
                    if isinstance(kw.value, (ast.List, ast.Tuple))
                    else [kw.value]
                )
                for spec in specs:
                    if not (
                        isinstance(spec, ast.Call)
                        and terminal_name(spec.func) == "BlockSpec"
                    ):
                        continue
                    im = None
                    for skw in spec.keywords:
                        if skw.arg == "index_map":
                            im = skw.value
                    if im is None and len(spec.args) >= 2:
                        im = spec.args[1]
                    if im is None:
                        continue
                    body = _resolve_index_map(im, defs)
                    if body is None:
                        findings.append(
                            sf.finding(
                                "pallas-index-map",
                                spec,
                                "index map is not a lambda, named def, or "
                                "factory-returned lambda resolvable in "
                                "this module — purity cannot be verified",
                            )
                        )
                    else:
                        _check_map_body(sf, spec, body, findings)
            elif kw.arg == "scratch_shapes":
                shapes = (
                    kw.value.elts
                    if isinstance(kw.value, (ast.List, ast.Tuple))
                    else [kw.value]
                )
                for sh in shapes:
                    for sub in ast.walk(sh):
                        chain = attr_chain(sub) or ""
                        if isinstance(sub, ast.Call) and (
                            (attr_chain(sub.func) or "").split(".")[0]
                            in ("jnp", "jax", "np", "numpy")
                        ):
                            findings.append(
                                sf.finding(
                                    "pallas-scratch-shape",
                                    sh,
                                    f"scratch_shapes entry builds an array "
                                    f"via {attr_chain(sub.func)}() — must "
                                    f"be a static shape declaration",
                                )
                            )
                            break
                        del chain

    # int32-only discipline inside kernel bodies (and same-file callees).
    for name, fn in sorted(kernel_functions(sf).items()):
        for sub in ast.walk(fn):
            why = _int64ish(sub)
            if why is not None:
                findings.append(
                    sf.finding(
                        "pallas-int64",
                        sub,
                        f"{name}: {why} inside a kernel body — TPU Pallas "
                        f"has no int64 (hi/lo-split instead, DESIGN.md §6)",
                    )
                )
    return findings
