"""Trace-safety pass (rules trace-truthiness / trace-concretize /
trace-lru-array / trace-mutable-default).

Scope: the engine's jitted entry points and the Pallas kernel bodies.
"Traced parameter" means a parameter of a jitted function that is NOT
named in ``static_argnames`` (we read it straight out of the
``functools.partial(jax.jit, static_argnames=...)`` decorator), or any
parameter of a kernel body other than scratch/ref conventions — at trace
time those are abstract values, and Python-level control flow on them
either retraces per value or crashes outright.

What is deliberately NOT flagged:

- ``if x is None`` / ``is not None``: identity checks against None are
  resolved at trace time and are the repo's idiom for optional operands
  (``run_conv2d``'s bias).
- truthiness on *static* parameters (named in static_argnames) — that's
  exactly what statics are for.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from tools.analysis.core import Finding, SourceFile, attr_chain, terminal_name

#: annotation substrings that mark a parameter as an array.
ARRAYISH = ("Array", "ndarray")

CONCRETIZERS = {"int", "float", "bool"}


def _decorator_chains(fn: ast.AST) -> List[ast.AST]:
    return list(getattr(fn, "decorator_list", []))


def _jit_static_argnames(dec: ast.AST) -> Optional[Set[str]]:
    """If ``dec`` is a jit decorator, return its static_argnames (possibly
    empty); else None."""
    chain = attr_chain(dec)
    if chain in ("jax.jit", "jit"):
        return set()
    if not isinstance(dec, ast.Call):
        return None
    head = attr_chain(dec.func)
    statics: Set[str] = set()
    target = None
    if head in ("jax.jit", "jit"):
        target = dec
    elif head in ("functools.partial", "partial") and dec.args:
        inner = attr_chain(dec.args[0])
        if inner in ("jax.jit", "jit"):
            target = dec
    if target is None:
        return None
    for kw in target.keywords:
        if kw.arg in ("static_argnames", "static_argnums"):
            statics |= _const_strings(kw.value)
    return statics


def _const_strings(node: ast.AST) -> Set[str]:
    out: Set[str] = set()
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        out.add(node.value)
    elif isinstance(node, (ast.Tuple, ast.List, ast.Set)):
        for el in node.elts:
            out |= _const_strings(el)
    return out


def _is_lru_decorator(dec: ast.AST) -> bool:
    chain = attr_chain(dec)
    if chain in ("functools.lru_cache", "lru_cache", "functools.cache", "cache"):
        return True
    if isinstance(dec, ast.Call):
        return attr_chain(dec.func) in (
            "functools.lru_cache",
            "lru_cache",
            "functools.cache",
            "cache",
        )
    return False


def _params(fn) -> List[ast.arg]:
    a = fn.args
    return list(a.posonlyargs) + list(a.args) + list(a.kwonlyargs)


def kernel_functions(sf: SourceFile) -> Dict[str, ast.FunctionDef]:
    """Functions handed to ``pl.pallas_call`` as the kernel, plus their
    same-file transitive callees — everything that runs inside a trace."""
    defs = {
        n.name: n
        for n in ast.walk(sf.tree)
        if isinstance(n, ast.FunctionDef)
    }
    roots: List[str] = []
    for node in ast.walk(sf.tree):
        if not (
            isinstance(node, ast.Call)
            and terminal_name(node.func) == "pallas_call"
            and node.args
        ):
            continue
        k = node.args[0]
        if isinstance(k, ast.Call) and terminal_name(k.func) == "partial":
            k = k.args[0] if k.args else k
        name = k.id if isinstance(k, ast.Name) else None
        if name and name in defs:
            roots.append(name)
    # BFS into same-file callees (e.g. requant helpers called from the body).
    out: Dict[str, ast.FunctionDef] = {}
    queue = list(roots)
    while queue:
        name = queue.pop()
        if name in out or name not in defs:
            continue
        out[name] = defs[name]
        for sub in ast.walk(defs[name]):
            if isinstance(sub, ast.Call):
                callee = terminal_name(sub.func)
                if callee in defs and callee not in out:
                    queue.append(callee)
    return out


def _bare_param(node: ast.AST, traced: Set[str]) -> Optional[str]:
    if isinstance(node, ast.Name) and node.id in traced:
        return node.id
    return None


def _check_traced_body(
    sf: SourceFile,
    fn: ast.FunctionDef,
    traced: Set[str],
    kind: str,
    findings: List[Finding],
) -> None:
    for node in ast.walk(fn):
        if isinstance(node, (ast.If, ast.While)):
            test = node.test
            name = _bare_param(test, traced)
            if name is None and isinstance(test, ast.UnaryOp) and isinstance(
                test.op, ast.Not
            ):
                name = _bare_param(test.operand, traced)
            if name is not None:
                findings.append(
                    sf.finding(
                        "trace-truthiness",
                        node,
                        f"{fn.name}: Python truthiness on traced "
                        f"parameter {name!r} inside a {kind} body — use "
                        f"jnp.where / static args instead",
                    )
                )
        elif isinstance(node, ast.Call):
            tname = terminal_name(node.func)
            if tname in CONCRETIZERS and len(node.args) == 1:
                name = _bare_param(node.args[0], traced)
                if name is not None:
                    findings.append(
                        sf.finding(
                            "trace-concretize",
                            node,
                            f"{fn.name}: {tname}() concretizes traced "
                            f"parameter {name!r} inside a {kind} body",
                        )
                    )
            elif (
                tname == "item"
                and isinstance(node.func, ast.Attribute)
                and _bare_param(node.func.value, traced) is not None
            ):
                findings.append(
                    sf.finding(
                        "trace-concretize",
                        node,
                        f"{fn.name}: .item() concretizes traced parameter "
                        f"{node.func.value.id!r} inside a {kind} body",
                    )
                )


def _mutable_default(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call) and terminal_name(node.func) in (
        "list",
        "dict",
        "set",
        "bytearray",
    ):
        return True
    return False


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    kernels = kernel_functions(sf)

    for fn in ast.walk(sf.tree):
        if not isinstance(fn, ast.FunctionDef):
            continue
        statics: Optional[Set[str]] = None
        is_jitted = False
        for dec in _decorator_chains(fn):
            s = _jit_static_argnames(dec)
            if s is not None:
                statics = s
                is_jitted = True
            if _is_lru_decorator(dec):
                for p in _params(fn):
                    ann = ast.unparse(p.annotation) if p.annotation else ""
                    if any(tag in ann for tag in ARRAYISH):
                        findings.append(
                            sf.finding(
                                "trace-lru-array",
                                fn,
                                f"{fn.name}: functools.lru_cache on a "
                                f"function taking array parameter "
                                f"{p.arg!r} ({ann}) — cache keys on array "
                                f"identity and never evicts",
                            )
                        )
        if is_jitted:
            traced = {p.arg for p in _params(fn)} - (statics or set())
            _check_traced_body(sf, fn, traced, "jitted", findings)
            defaults = fn.args.defaults + [
                d for d in fn.args.kw_defaults if d is not None
            ]
            for d in defaults:
                if _mutable_default(d):
                    findings.append(
                        sf.finding(
                            "trace-mutable-default",
                            d,
                            f"{fn.name}: mutable default argument on a "
                            f"jitted function — unhashable as a static, "
                            f"shared across traces",
                        )
                    )
        if fn.name in kernels:
            # Every non-ref parameter of a kernel body is traced; _ref /
            # _scratch suffixed names follow the repo convention for
            # memory references (indexable, but still not Python values).
            traced = {p.arg for p in _params(fn)}
            _check_traced_body(sf, fn, traced, "kernel", findings)
    return findings
