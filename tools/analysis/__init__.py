"""trimcheck — repo-native static analysis for TrIM's invariants.

Run as ``python -m tools.analysis`` (see ``--help``).  DESIGN.md §10 is
the narrative rule catalog; this table is the executable one.
"""

from tools.analysis.core import (  # noqa: F401
    Config,
    Finding,
    LockSpec,
    SUPPRESS_RE,
    TRIMCHECK_VERSION,
    run_analysis,
)

#: rule name -> one-line contract.  ``python -m tools.analysis --list``
#: prints this; DESIGN.md §10 explains the why behind each.
RULES = {
    "lock-guarded-attr": (
        "declared cv/lock-guarded attributes must be read and written "
        "inside `with self.<lock>` (map: tools.analysis.locks)"
    ),
    "lock-wait-while": (
        "Condition.wait()/wait_for-less waits must sit inside a `while` "
        "that re-checks the predicate (spurious wakeups)"
    ),
    "lock-blocking-call": (
        "no blocking work (device compute, sleeps, host transfers, thread "
        "joins) while holding a serve lock"
    ),
    "trace-truthiness": (
        "no Python `if`/`while`/`not` on traced parameters inside jitted "
        "or Pallas-kernel bodies (is/is-None checks are fine)"
    ),
    "trace-concretize": (
        "no int()/float()/bool()/.item() on traced parameters inside "
        "jitted or kernel bodies"
    ),
    "trace-lru-array": (
        "functools.lru_cache must not wrap functions whose signature "
        "accepts arrays (unbounded cache keyed on array identity)"
    ),
    "trace-mutable-default": (
        "jitted callables must not carry mutable default arguments "
        "(unhashable as static args; shared across traces)"
    ),
    "pallas-index-map": (
        "pl.pallas_call index maps must be pure functions of grid "
        "indices and static closure (no self.*, no calls)"
    ),
    "pallas-scratch-shape": (
        "scratch_shapes entries must be static shape declarations, not "
        "jnp/jax array values"
    ),
    "pallas-int64": (
        "kernel bodies must stay int32-clean: no int64/uint64 dtypes or "
        "literals beyond 2**31-1 (TPU Pallas has no int64)"
    ),
    "hygiene-deprecation-warns": (
        "a shim documented as Deprecated must emit DeprecationWarning "
        "(and any 'deprecated' warn must pass that category)"
    ),
    "silent-except": (
        "serve-layer `except Exception` handlers must re-raise or record "
        "(metrics/log) — a swallowed failure breaks extended conservation"
    ),
    "docs-link": (
        "relative markdown links in the tracked docs set must resolve"
    ),
    "docs-section-ref": (
        "every `DESIGN.md §N[.M]` citation (docs and source) must name a "
        "real DESIGN.md heading"
    ),
    "suppress-needs-reason": (
        "`# trimcheck: disable=<rule>` requires `-- <reason>`; a "
        "reasonless disable is itself a finding and cannot be suppressed"
    ),
}
