"""Lock-ownership pass (rules lock-guarded-attr / lock-wait-while /
lock-blocking-call).

``DEFAULT_LOCK_MAP`` below is THE guarded-attribute map: the single
source of truth for which ``self.*`` state each serve class may only
touch under its lock.  DESIGN.md §8's concurrency model and the runtime
sanitizer (tools.analysis.runtime) both defer to it — edit it here, not
in prose.

Semantics are lexical, matching how the serve layer is written:

- an attribute access is "guarded" when a ``with self.<lock>`` block
  encloses it *within the same function body* (a nested ``def``/
  ``lambda`` resets guarding — the closure runs later, lock not held);
- ``__init__`` is exempt: construction happens-before any thread that
  could contend (the same happens-before the CPython memory model gives
  ``Thread.start``);
- ``<lock>.wait(...)`` must have a ``while`` ancestor in the same
  function (the repo-wide spurious-wakeup discipline);
- inside a ``with self.<lock>`` body, calls whose terminal name is in
  ``BLOCKING_NAMES`` (or ``.join`` on something that looks like a
  thread) are flagged: blocking under the cv stalls every producer.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Tuple

from tools.analysis.core import Finding, LockSpec, SourceFile, terminal_name

#: path -> lock contracts.  Keep in lock-step with DESIGN.md §10's table.
DEFAULT_LOCK_MAP: Dict[str, Tuple[LockSpec, ...]] = {
    "src/repro/serve/server.py": (
        LockSpec(
            cls="Server",
            lock_attr="_cv",
            guarded=(
                "_running",
                "_draining",
                "_closed",
                "_worker",
                "_worker_work",
                "requests",
            ),
        ),
    ),
    "src/repro/serve/batching.py": (
        LockSpec(
            cls="BucketBatcher",
            lock_attr="_lock",
            guarded=("_q", "_last_t", "_n_deadlined", "_rid"),
        ),
    ),
}

#: Terminal call names that block: device compute / host transfers /
#: sleeps / the serve layer's own dispatch helpers.
BLOCKING_NAMES = {
    "sleep",
    "asarray",
    "block_until_ready",
    "device_put",
    "run_bucket",
    "stage",
    "_dispatch",
    "_dispatch_async",
    "_finalize",
    "_complete",
    "_run_batch",
    "_stage_retry",
}
#: ``.join`` is only blocking when the receiver smells like a thread —
#: keeps ``", ".join(...)`` out of the blast radius.
THREADISH_RE = re.compile(r"(worker|thread|producer)|^_?t\d*$", re.I)

_FUNC_NODES = (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)


def _is_self_attr(node: ast.AST, attr: str) -> bool:
    return (
        isinstance(node, ast.Attribute)
        and node.attr == attr
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    )


def _with_guards(node: ast.With, lock_attr: str) -> bool:
    return any(_is_self_attr(item.context_expr, lock_attr) for item in node.items)


def _enclosing_function(sf: SourceFile, node: ast.AST) -> Optional[ast.AST]:
    for anc in sf.ancestors(node):
        if isinstance(anc, _FUNC_NODES):
            return anc
    return None


def _guarded_here(sf: SourceFile, node: ast.AST, lock_attr: str) -> bool:
    """True when a ``with self.<lock_attr>`` encloses ``node`` before any
    intervening function boundary."""
    for anc in sf.ancestors(node):
        if isinstance(anc, _FUNC_NODES):
            return False
        if isinstance(anc, ast.With) and _with_guards(anc, lock_attr):
            return True
    return False


def check(sf: SourceFile, specs: Tuple[LockSpec, ...]) -> List[Finding]:
    findings: List[Finding] = []
    for spec in specs:
        cls = next(
            (
                n
                for n in ast.walk(sf.tree)
                if isinstance(n, ast.ClassDef) and n.name == spec.cls
            ),
            None,
        )
        if cls is None:
            findings.append(
                sf.finding(
                    "lock-guarded-attr",
                    1,
                    f"lock map declares class {spec.cls!r} but this file "
                    f"does not define it — update tools.analysis.locks",
                )
            )
            continue
        guarded = set(spec.guarded)
        for node in ast.walk(cls):
            # --- guarded attribute discipline -------------------------
            if (
                isinstance(node, ast.Attribute)
                and node.attr in guarded
                and isinstance(node.value, ast.Name)
                and node.value.id == "self"
            ):
                fn = _enclosing_function(sf, node)
                fn_name = getattr(fn, "name", "<lambda>") if fn else "<class>"
                if fn_name == "__init__":
                    continue
                if not _guarded_here(sf, node, spec.lock_attr):
                    mode = "write" if isinstance(node.ctx, ast.Store) else "read"
                    findings.append(
                        sf.finding(
                            "lock-guarded-attr",
                            node,
                            f"{spec.cls}.{fn_name}: {mode} of guarded "
                            f"self.{node.attr} outside `with "
                            f"self.{spec.lock_attr}`",
                        )
                    )
            if not isinstance(node, ast.Call):
                continue
            name = terminal_name(node.func)
            # --- wait-in-while ---------------------------------------
            if (
                name in ("wait", "wait_for")
                and isinstance(node.func, ast.Attribute)
                and _is_self_attr(node.func.value, spec.lock_attr)
            ):
                if name == "wait" and not _has_while_ancestor(sf, node):
                    findings.append(
                        sf.finding(
                            "lock-wait-while",
                            node,
                            f"{spec.cls}: self.{spec.lock_attr}.wait() "
                            f"without an enclosing while — predicate must "
                            f"be re-checked after spurious wakeups",
                        )
                    )
                continue
            # --- blocking work under the lock ------------------------
            if not _guarded_here(sf, node, spec.lock_attr):
                continue
            if name in BLOCKING_NAMES:
                findings.append(
                    sf.finding(
                        "lock-blocking-call",
                        node,
                        f"{spec.cls}: blocking call {name}() while "
                        f"holding self.{spec.lock_attr}",
                    )
                )
            elif name == "join" and isinstance(node.func, ast.Attribute):
                recv = node.func.value
                recv_name = (
                    recv.attr
                    if isinstance(recv, ast.Attribute)
                    else recv.id
                    if isinstance(recv, ast.Name)
                    else ""
                )
                if THREADISH_RE.search(recv_name):
                    findings.append(
                        sf.finding(
                            "lock-blocking-call",
                            node,
                            f"{spec.cls}: {recv_name}.join() while holding "
                            f"self.{spec.lock_attr} — joining a worker that "
                            f"needs the lock deadlocks",
                        )
                    )
    return findings


def _has_while_ancestor(sf: SourceFile, node: ast.AST) -> bool:
    for anc in sf.ancestors(node):
        if isinstance(anc, _FUNC_NODES):
            return False
        if isinstance(anc, ast.While):
            return True
    return False
