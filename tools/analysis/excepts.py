"""Silent-exception pass (rule silent-except).

The serve layer's recovery machinery (DESIGN.md §11) is built on one
discipline: a broad ``except Exception`` handler is only legitimate when
it either re-raises or *records* — feeds the failure into metrics, the
breaker, or a log — because a swallowed exception there silently breaks
extended conservation (a request that never reaches a terminal state).

A handler is **broad** when it catches nothing in particular: bare
``except:``, ``except Exception``, ``except BaseException``, or a tuple
containing either.  Narrow catches (``except KeyError``) are deliberate
control flow and stay out of scope.

A broad handler is **accepted** when its body (nested functions
excluded — they run later, if ever) contains:

- a ``raise`` statement (bare re-raise or raise-from), or
- a call that records: its terminal name — underscores stripped —
  starts with ``record``/``warn``/``log``/``fail``, or its attribute
  chain passes through ``metrics`` (``self.metrics.record_x``,
  ``logging.warning``, ``self._record_batch_failure``, ...).

Anything else is a finding.
"""

from __future__ import annotations

import ast
from typing import List, Optional

from tools.analysis.core import Finding, SourceFile, attr_chain, terminal_name

_BROAD_NAMES = {"Exception", "BaseException"}
_RECORD_PREFIXES = ("record", "warn", "log", "fail")


def _is_broad(handler_type: Optional[ast.AST]) -> bool:
    if handler_type is None:
        return True
    if isinstance(handler_type, ast.Tuple):
        return any(_is_broad(elt) for elt in handler_type.elts)
    name = terminal_name(handler_type)
    return name in _BROAD_NAMES


def _records(call: ast.Call) -> bool:
    name = terminal_name(call.func)
    if name is not None and name.lstrip("_").startswith(_RECORD_PREFIXES):
        return True
    chain = attr_chain(call.func)
    return chain is not None and "metrics" in chain.split(".")


def _own_body_nodes(handler: ast.ExceptHandler):
    """Walk the handler body without descending into nested functions —
    a closure's ``raise``/record runs later (if ever), not on this
    exception."""
    stack = list(handler.body)
    while stack:
        node = stack.pop()
        yield node
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda)):
            continue
        stack.extend(ast.iter_child_nodes(node))


def check(sf: SourceFile) -> List[Finding]:
    findings: List[Finding] = []
    for node in ast.walk(sf.tree):
        if not isinstance(node, ast.ExceptHandler) or not _is_broad(node.type):
            continue
        handled = False
        for sub in _own_body_nodes(node):
            if isinstance(sub, ast.Raise):
                handled = True
                break
            if isinstance(sub, ast.Call) and _records(sub):
                handled = True
                break
        if not handled:
            caught = (
                "bare except" if node.type is None else ast.unparse(node.type)
            )
            findings.append(
                sf.finding(
                    "silent-except",
                    node,
                    f"broad handler ({caught}) neither re-raises nor "
                    f"records — a swallowed serve-layer failure breaks "
                    f"extended conservation (DESIGN.md §11)",
                )
            )
    return findings
