"""Runtime sanitizers — the dynamic counterpart to the static lock pass.

Static analysis proves the *lexical* discipline; these hooks check the
*actual* execution under the threaded serve tests:

- :class:`LockRegistry` + :class:`InstrumentedRLock` record every lock
  acquisition per thread and maintain a global lock-order graph.  An
  acquisition that would close a cycle (lock A held while taking B after
  some thread took B while holding A) is recorded as a potential
  deadlock — the classic two-lock inversion no single-threaded test can
  reproduce deterministically.
- :func:`sanitize_server` swaps a ``Server``'s condition variable and
  its batcher's lock for instrumented ones and subclasses the instance
  so every read/write of the cv-guarded attributes verifies, at access
  time, that the current thread owns the cv.

Violations are RECORDED, not raised: raising inside a flush worker or a
producer would change the very interleaving being tested.  Tests assert
``registry.errors == []`` after the run.

Unlike the rest of tools.analysis this module imports ``threading`` but
still no jax — it wraps objects it is handed, so it stays importable
everywhere.
"""

from __future__ import annotations

import threading
from typing import Dict, List, Optional, Set, Tuple

#: Runtime-checked guarded attributes for Server.  ``requests`` is in the
#: static map but carries audited GIL-atomic suppressions (server.py), so
#: the runtime check sticks to the strictly cv-owned state machine.
SERVER_GUARDED = ("_running", "_draining", "_closed", "_worker", "_worker_work")


class LockRegistry:
    """Process-wide (per test) acquisition-order graph + violation log."""

    def __init__(self) -> None:
        self._graph_lock = threading.Lock()
        #: edge a -> b: some thread acquired b while holding a.
        self.edges: Dict[str, Set[str]] = {}
        self.errors: List[str] = []
        self._held = threading.local()

    # -- held-stack bookkeeping (per thread) ---------------------------

    def _stack(self) -> List[str]:
        if not hasattr(self._held, "stack"):
            self._held.stack = []
        return self._held.stack

    def note_acquired(self, name: str) -> None:
        stack = self._stack()
        with self._graph_lock:
            for held in stack:
                if held == name:
                    continue
                self.edges.setdefault(held, set()).add(name)
                if self._reaches(name, held):
                    self.errors.append(
                        f"lock-order cycle: acquired {name!r} while "
                        f"holding {held!r}, but {name!r} -> {held!r} "
                        f"already observed"
                    )
        stack.append(name)

    def note_released(self, name: str) -> None:
        stack = self._stack()
        if name in stack:
            # remove the innermost occurrence (release order may not be
            # strictly LIFO across cv waits).
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] == name:
                    del stack[i]
                    break

    def _reaches(self, src: str, dst: str) -> bool:
        seen = set()
        frontier = [src]
        while frontier:
            cur = frontier.pop()
            if cur == dst:
                return True
            if cur in seen:
                continue
            seen.add(cur)
            frontier.extend(self.edges.get(cur, ()))
        return False


class InstrumentedRLock:
    """An RLock that reports acquisitions to a :class:`LockRegistry`.

    Implements the full ``Condition``-compatibility surface
    (``_is_owned`` / ``_release_save`` / ``_acquire_restore``) so it can
    back ``threading.Condition`` — a ``cv.wait()`` then shows up in the
    registry as a release + reacquire, exactly what really happens.
    """

    def __init__(self, name: str, registry: LockRegistry) -> None:
        self.name = name
        self.registry = registry
        self._inner = threading.RLock()
        self._owner: Optional[int] = None
        self._count = 0

    # -- lock protocol -------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        got = self._inner.acquire(blocking, timeout)
        if got:
            if self._count == 0:
                self._owner = threading.get_ident()
                self.registry.note_acquired(self.name)
            self._count += 1
        return got

    def release(self) -> None:
        if self._owner != threading.get_ident():
            self.registry.errors.append(
                f"{self.name}: release() by a thread that does not own it"
            )
        self._count -= 1
        if self._count == 0:
            self._owner = None
            self.registry.note_released(self.name)
        self._inner.release()

    __enter__ = acquire

    def __exit__(self, *exc) -> None:
        self.release()

    # -- Condition compatibility ---------------------------------------

    def _is_owned(self) -> bool:
        return self._owner == threading.get_ident()

    def _release_save(self) -> Tuple[int, object]:
        count = self._count
        self._count = 0
        self._owner = None
        self.registry.note_released(self.name)
        return (count, self._inner._release_save())

    def _acquire_restore(self, state: Tuple[int, object]) -> None:
        count, inner_state = state
        self._inner._acquire_restore(inner_state)
        self._owner = threading.get_ident()
        self._count = count
        self.registry.note_acquired(self.name)


def _sanitized_subclass(cls, guarded: Tuple[str, ...], registry: LockRegistry):
    """A subclass of ``cls`` whose guarded-attribute accesses verify cv
    ownership at runtime.  Built per sanitize call so the registry and
    guard set ride on the class, not the instance (keeps ``__setattr__``
    out of its own way)."""

    guarded_set = frozenset(guarded)

    def _check(self, name: str, mode: str) -> None:
        cv = object.__getattribute__(self, "_cv")
        lock = getattr(cv, "_lock", None)
        owned = lock._is_owned() if hasattr(lock, "_is_owned") else False
        if not owned:
            fn = threading.current_thread().name
            registry.errors.append(
                f"unguarded {mode} of {name} (thread {fn}) — cv not held"
            )

    class Sanitized(cls):
        def __getattribute__(self, name):
            if name in guarded_set:
                _check(self, name, "read")
            return super().__getattribute__(name)

        def __setattr__(self, name, value):
            if name in guarded_set:
                _check(self, name, "write")
            super().__setattr__(name, value)

    Sanitized.__name__ = f"Sanitized{cls.__name__}"
    Sanitized.__qualname__ = Sanitized.__name__
    return Sanitized


def sanitize_server(server, registry: Optional[LockRegistry] = None,
                    guarded: Tuple[str, ...] = SERVER_GUARDED) -> LockRegistry:
    """Instrument a ``Server`` (before ``start()``): swap its cv and its
    batcher's lock for registry-backed ones and enable runtime
    guarded-attribute checks.  Returns the registry; assert
    ``registry.errors == []`` when the test's threads are done."""
    reg = registry if registry is not None else LockRegistry()
    server._cv = threading.Condition(InstrumentedRLock("Server._cv", reg))
    server.batcher._lock = InstrumentedRLock("BucketBatcher._lock", reg)
    server.__class__ = _sanitized_subclass(type(server), guarded, reg)
    return reg
