# Repo tooling package (``python -m tools.analysis``, ``tools/check_docs.py``).
