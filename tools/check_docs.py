"""Docs consistency gate — thin shim over trimcheck's docs rules.

The static checks (markdown links, `DESIGN.md §N` citations) live in
``tools.analysis.docs`` and run via ``python -m tools.analysis`` and the
tier-1 suite; this CLI keeps the historical entry point and adds the one
check that needs a subprocess and jax:

**The quickstart executes** (skippable via ``--skip-examples``):
``examples/quickstart.py`` runs to completion on CPU with
``PYTHONPATH=src`` — the README's first command must never rot.

Exit codes: 0 ok, 1 any check failed.
"""

from __future__ import annotations

import argparse
import os
import subprocess
import sys
from typing import List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if REPO not in sys.path:
    sys.path.insert(0, REPO)

from tools.analysis import docs as _docs  # noqa: E402

MARKDOWN_FILES = _docs.MARKDOWN_FILES
LINK_RE = _docs.LINK_RE
SECTION_REF_RE = _docs.SECTION_REF_RE
HEADING_RE = _docs.HEADING_RE
SOURCE_DIRS = _docs.SOURCE_DIRS


def design_sections() -> set:
    return _docs.design_sections(REPO)


def check_links(errors: List[str]) -> None:
    for f in _docs.check_links(REPO):
        errors.append(f"{f.path}: {f.message}")


def check_section_refs(errors: List[str]) -> None:
    for f in _docs.check_section_refs(REPO):
        errors.append(f"{f.path}: {f.message}")


def check_quickstart(errors: List[str]) -> None:
    env = dict(
        os.environ, PYTHONPATH=os.path.join(REPO, "src"), JAX_PLATFORMS="cpu"
    )
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "quickstart.py")],
        env=env,
        cwd=REPO,
        capture_output=True,
        text=True,
        timeout=600,
    )
    if proc.returncode != 0:
        tail = "\n".join((proc.stdout + proc.stderr).splitlines()[-15:])
        errors.append(f"examples/quickstart.py exited {proc.returncode}:\n{tail}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument(
        "--skip-examples",
        action="store_true",
        help="only run the static link/§-reference checks",
    )
    args = ap.parse_args(argv)

    errors: List[str] = []
    check_links(errors)
    check_section_refs(errors)
    if not args.skip_examples:
        check_quickstart(errors)

    for e in errors:
        print(f"[check_docs] FAIL: {e}", file=sys.stderr)
    if not errors:
        n = len(MARKDOWN_FILES)
        print(
            f"[check_docs] OK: links + §-references across {n} markdown "
            f"files and the source tree (via tools.analysis)"
            + ("" if args.skip_examples else "; quickstart ran clean")
        )
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
