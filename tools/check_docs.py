"""Docs consistency gate (CI ``docs`` lane).

Three checks, all rooted at the repo top:

1. **Markdown links.**  Every relative ``[text](target)`` in the tracked
   markdown set (README.md, DESIGN.md, ROADMAP.md, benchmarks/README.md)
   must point at a file or directory that exists (anchors are stripped;
   absolute URLs are ignored).
2. **Section references.**  Every ``DESIGN.md §N[.M]`` citation — in the
   markdown set AND in the source tree's docstrings/comments — must name
   a section heading that actually exists in DESIGN.md (``## §N ...`` /
   ``### §N.M ...``).  This is what keeps code like ``run_conv2d``'s
   "DESIGN.md §9.3" pointers honest as sections move.
3. **The quickstart executes** (skippable via ``--skip-examples``):
   ``examples/quickstart.py`` runs to completion on CPU with
   ``PYTHONPATH=src`` — the README's first command must never rot.

Exit codes: 0 ok, 1 any check failed.
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
from typing import List

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MARKDOWN_FILES = ["README.md", "DESIGN.md", "ROADMAP.md",
                  "benchmarks/README.md"]

#: ``[text](target)`` — good enough for our docs; skips images/autolinks.
LINK_RE = re.compile(r"\[[^\]]+\]\(([^)\s]+)\)")
#: A section citation: "DESIGN.md §9.3", "DESIGN.md §4", "(§7)", "§9.2's".
SECTION_REF_RE = re.compile(r"DESIGN\.md[^§\n]{0,20}§(\d+(?:\.\d+)?)")
HEADING_RE = re.compile(r"^#{2,3}\s+§(\d+(?:\.\d+)?)\b", re.M)
#: Source globs scanned for DESIGN.md citations.
SOURCE_DIRS = ["src", "tests", "benchmarks", "examples", "tools"]


def check_links(errors: List[str]) -> None:
    for md in MARKDOWN_FILES:
        path = os.path.join(REPO, md)
        if not os.path.exists(path):
            errors.append(f"{md}: tracked markdown file missing")
            continue
        text = open(path, encoding="utf-8").read()
        for target in LINK_RE.findall(text):
            if "://" in target or target.startswith(("mailto:", "#")):
                continue
            rel = target.split("#")[0]
            if not rel:
                continue
            resolved = os.path.normpath(
                os.path.join(REPO, os.path.dirname(md), rel))
            if not os.path.exists(resolved):
                errors.append(f"{md}: broken link -> {target}")


def design_sections() -> set:
    text = open(os.path.join(REPO, "DESIGN.md"), encoding="utf-8").read()
    return set(HEADING_RE.findall(text))


def iter_source_files():
    for d in SOURCE_DIRS:
        for root, _dirs, files in os.walk(os.path.join(REPO, d)):
            for f in files:
                if f.endswith((".py", ".md", ".yml")):
                    yield os.path.join(root, f)


def check_section_refs(errors: List[str]) -> None:
    sections = design_sections()
    if not sections:
        errors.append("DESIGN.md: no §-numbered headings found")
        return
    targets = [os.path.join(REPO, m) for m in MARKDOWN_FILES]
    targets += list(iter_source_files())
    for path in targets:
        if not os.path.exists(path):
            continue
        text = open(path, encoding="utf-8", errors="replace").read()
        for ref in SECTION_REF_RE.findall(text):
            top = ref.split(".")[0]
            if ref not in sections and top not in sections:
                rel = os.path.relpath(path, REPO)
                errors.append(
                    f"{rel}: cites DESIGN.md §{ref} but DESIGN.md has no "
                    f"such heading")
            elif ref not in sections and "." in ref:
                rel = os.path.relpath(path, REPO)
                errors.append(
                    f"{rel}: cites DESIGN.md §{ref}; §{top} exists but the "
                    f"subsection heading does not")


def check_quickstart(errors: List[str]) -> None:
    env = dict(os.environ,
               PYTHONPATH=os.path.join(REPO, "src"),
               JAX_PLATFORMS="cpu")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "examples", "quickstart.py")],
        env=env, cwd=REPO, capture_output=True, text=True, timeout=600)
    if proc.returncode != 0:
        tail = "\n".join((proc.stdout + proc.stderr).splitlines()[-15:])
        errors.append(
            f"examples/quickstart.py exited {proc.returncode}:\n{tail}")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--skip-examples", action="store_true",
                    help="only run the static link/§-reference checks")
    args = ap.parse_args(argv)

    errors: List[str] = []
    check_links(errors)
    check_section_refs(errors)
    if not args.skip_examples:
        check_quickstart(errors)

    for e in errors:
        print(f"[check_docs] FAIL: {e}", file=sys.stderr)
    if not errors:
        n = len(MARKDOWN_FILES)
        print(f"[check_docs] OK: links + §-references across {n} markdown "
              f"files and the source tree"
              + ("" if args.skip_examples else "; quickstart ran clean"))
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
