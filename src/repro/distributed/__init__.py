"""Distributed runtime: logical sharding rules, step builders, collectives."""
from repro.distributed.sharding import (  # noqa: F401
    LOGICAL_RULES,
    MeshContext,
    activate_mesh,
    logical_to_spec,
    shard,
    param_pspec,
    zero1_pspec,
)
from repro.distributed.steps import (  # noqa: F401
    StepConfig,
    make_train_state,
    train_state_shapes,
    make_train_step,
    jit_train_step,
    make_prefill_step,
    make_decode_step,
    state_pspec,
    batch_pspec,
    cache_pspec,
)
from repro.distributed.trainer import (  # noqa: F401
    TrainLoopConfig,
    train_loop,
    StragglerMonitor,
)
