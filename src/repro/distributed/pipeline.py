"""Optional pipeline parallelism over the "pod" axis (GPipe schedule).

At the assigned meshes (256/512 chips) every model fits with TP x DP + ZeRO,
so PP is OFF by default (DESIGN.md §6). For >2-pod scaling this module turns
the "pod" axis into a pipeline axis: each pod holds n_layers/PP contiguous
layers and microbatches flow stage-to-stage with ``lax.ppermute``.

Schedule: standard GPipe fill-drain over T = n_micro + PP - 1 ticks. At tick
t, stage s computes microbatch (t - s) if 0 <= t - s < n_micro. Bubble
fraction = (PP - 1) / T — reported by ``bubble_fraction``.

Implemented with shard_map manual over the pipeline axis; the stage body
stays in GSPMD auto mode over the remaining axes (so TP/DP still partition
each stage's compute).
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map_compat


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def pipeline_run(stage_fn: Callable[[Any, jax.Array], jax.Array],
                 stage_params: Any, x_micro: jax.Array, *, mesh: Mesh,
                 axis: str = "pod") -> jax.Array:
    """Run a GPipe pipeline over `axis`.

    stage_fn(params_for_stage, x) -> x  — one stage's layers.
    stage_params: pytree whose leaves have leading dim = n_stages.
    x_micro: (n_micro, mb, ...) microbatched activations (replicated over
    `axis`; stage 0 consumes them in order).
    Returns (n_micro, mb, ...) outputs (valid on the last stage, broadcast
    back to all).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    T = n_micro + n_stages - 1
    params_spec = jax.tree.map(lambda _: P(axis), stage_params)

    @functools.partial(
        shard_map_compat, mesh=mesh,
        in_specs=(params_spec, P()),
        out_specs=P(),
        check_vma=False, axis_names=frozenset({axis}))
    def run(params, xs):
        # params leaves now have leading dim 1 (this stage's slice)
        params = jax.tree.map(lambda p: p[0], params)
        sid = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t; others use the permuted buffer
            feed = jnp.where(t < n_micro, t, 0)
            x_in = jnp.where(sid == 0, xs[feed], buf)
            active = (t >= sid) & (t - sid < n_micro)
            y = stage_fn(params, x_in)
            y = jnp.where(active, y, buf)
            # last stage records its finished microbatch
            done_idx = t - (n_stages - 1)
            is_done = (sid == n_stages - 1) & (done_idx >= 0)
            outs = jax.lax.cond(
                is_done & (done_idx < n_micro),
                lambda o: o.at[jnp.maximum(done_idx, 0)].set(y),
                lambda o: o, outs)
            # shift activations to the next stage
            buf = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (buf, outs), None

        buf0 = jnp.zeros(mb_shape, xs.dtype)
        outs0 = jnp.zeros_like(xs)
        (_, outs), _ = jax.lax.scan(tick, (buf0, outs0), jnp.arange(T))
        # broadcast the last stage's outputs to every stage (masked psum)
        outs = jax.lax.psum(
            jnp.where(sid == n_stages - 1, outs, jnp.zeros_like(outs)), axis)
        return outs

    return run(stage_params, x_micro)
