"""int8-compressed data-parallel gradient reduction with error feedback.

Wire format per leaf: bf16 reduce-scatter (the summation must stay high
precision) followed by an **int8 all-gather** of the reduced shard plus one
f32 scale — 2B + 1B ≈ 3B/element on the wire vs 8B for a plain f32
all-reduce (the ~2.7x saving quoted in DESIGN.md §6). Quantization error is
carried in an error-feedback accumulator folded into the *next* step's
gradient (Karimireddy et al. 2019), which keeps SGD/Adam convergence
unbiased to first order.

Implementation: ``shard_map`` manual over the DP axes with ``auto`` over
the remaining axes — tensor-parallel partitioning inside the body is still
GSPMD's job, only the data-parallel reduction is taken over manually.
Leaves whose leading dim does not divide the DP world size fall back to a
plain bf16 psum (counted, not hidden).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed.sharding import shard_map_compat

DP_AXES = ("pod", "data")


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in DP_AXES if a in mesh.axis_names)


def _wire_dtype():
    """bf16 reduce on TPU; f32 on CPU (XLA CPU cannot promote bf16
    all-reduce — the *format* is unchanged, only the CI-runnable dtype)."""
    return jnp.bfloat16 if jax.default_backend() == "tpu" else jnp.float32


def int8_psum(g: jax.Array, axes: Tuple[str, ...],
              ef: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """all-reduce(g) over `axes` with the compressed wire format
    (bf16 reduce-scatter + int8 all-gather), with optional error-feedback
    shard `ef` (the local reduce-scattered residual from the previous
    step). Caller guarantees dim 0 divides the DP world size.

    Returns (reduced g, new ef shard or None)."""
    gf = g.astype(_wire_dtype())
    # reduce-scatter over the (flattened) DP axes, tiled on dim 0
    rs = gf
    for ax in axes:
        rs = jax.lax.psum_scatter(rs, ax, scatter_dimension=0, tiled=True)
    rs = rs.astype(jnp.float32)
    if ef is not None:
        rs = rs + ef
    # int8 quantize the reduced shard
    scale = jnp.maximum(jnp.max(jnp.abs(rs)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(rs / scale), -127, 127).astype(jnp.int8)
    new_ef = rs - q.astype(jnp.float32) * scale if ef is not None else None
    # all-gather shards back (int8 + f32 scale on the wire)
    out = q
    scales = scale[None]
    for ax in reversed(axes):
        out = jax.lax.all_gather(out, ax, axis=0, tiled=True)
        scales = jax.lax.all_gather(scales, ax, axis=0, tiled=True)
    # per-shard dequant: shard i occupies rows [i*lead/world, ...)
    n_shards = scales.shape[0]
    out = out.reshape((n_shards, out.shape[0] // n_shards) + out.shape[1:])
    deq = out.astype(jnp.float32) * scales.reshape(
        (n_shards,) + (1,) * (out.ndim - 1))
    return deq.reshape((-1,) + deq.shape[2:]), new_ef


def _compressible(g, world: int) -> bool:
    return g.ndim >= 1 and g.shape[0] % world == 0 and g.shape[0] >= world


def _reduce_leaf(g: jax.Array, ef: Optional[jax.Array],
                 axes: Tuple[str, ...], world: int):
    if _compressible(g, world):
        return int8_psum(g, axes, ef)
    # fallback: plain bf16 all-reduce (small leaves: norms, biases)
    return (jax.lax.psum(g.astype(_wire_dtype()), axes).astype(jnp.float32),
            ef)


def init_ef(params, mesh: Mesh):
    """Error-feedback accumulator tree: zeros shaped like each compressible
    grad's reduce-scattered shard, f32, sharded over the DP axes on dim 0
    (non-compressible leaves get a zero scalar placeholder)."""
    axes = _dp_axes(mesh)
    world = int(np.prod([mesh.shape[a] for a in axes])) if axes else 1

    def one(p):
        if axes and _compressible(p, world):
            return jnp.zeros(p.shape, jnp.float32)
        return jnp.zeros((), jnp.float32)
    return jax.tree.map(one, params)


def compressed_grads(loss_fn: Callable, params, batch, mesh: Mesh,
                     ef=None):
    """value_and_grad with manual compressed DP reduction.

    loss_fn(params, batch) -> (loss, aux_dict). The DP axes are manual
    (shard_map); everything else stays in GSPMD auto mode.
    Returns ((loss, {}), grads) or ((loss, {}), grads, new_ef) when an
    error-feedback tree is supplied.
    """
    axes = _dp_axes(mesh)
    if not axes:
        out = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return out if ef is None else (*out, ef)
    world = int(np.prod([mesh.shape[a] for a in axes]))

    batch_spec = jax.tree.map(lambda _: P(axes), batch)
    param_spec = jax.tree.map(lambda _: P(), params)
    has_ef = ef is not None
    ef_spec = jax.tree.map(
        lambda e: P(axes) if e.ndim else P(),
        ef) if has_ef else jax.tree.map(lambda _: P(), params)
    ef_in = ef if has_ef else params  # placeholder tree (unused)

    @functools.partial(
        shard_map_compat, mesh=mesh,
        in_specs=(param_spec, batch_spec, ef_spec),
        out_specs=(P(), param_spec, ef_spec),
        check_vma=False, axis_names=frozenset(axes))
    def body(p, b, e):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(p, b)
        loss = jax.lax.pmean(loss.astype(jnp.float32), axes)

        def leaf(gl, el):
            red, ne = _reduce_leaf(gl, el if has_ef and el.ndim else None,
                                   axes, world)
            return red / world, (ne if ne is not None else el)
        pairs = jax.tree.map(leaf, g, e)
        treedef = jax.tree_util.tree_structure(g)
        flat = treedef.flatten_up_to(pairs)
        g_out = treedef.unflatten([f[0] for f in flat])
        e_out = treedef.unflatten([f[1] for f in flat])
        return loss, g_out, e_out

    loss, grads, new_ef = body(params, batch, ef_in)
    if has_ef:
        return (loss, {}), grads, new_ef
    return (loss, {}), grads
