"""Step builders: pjit-ready train_step / prefill / decode functions with
logical-rule shardings, gradient accumulation, NaN-step skip, and optional
int8-compressed data-parallel gradient reduction.

All builders return plain python functions *plus* the sharding trees needed
to jit them on a mesh; ``jit_on_mesh`` assembles the jitted callable. The
launch layer lowers the same functions with ShapeDtypeStructs for the
multi-pod dry-run.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.compression import compressed_grads
from repro.distributed.sharding import (MeshContext, activate_mesh,
                                        fsdp_pspec, logical_to_spec,
                                        param_pspec, zero1_pspec)
from repro.optim import AdamWConfig, adamw_init, adamw_update, warmup_cosine


@dataclass(frozen=True)
class StepConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    adamw: AdamWConfig = field(default_factory=AdamWConfig)
    accum: int = 1                    # gradient-accumulation microbatches
    aux_weight: float = 0.01          # MoE load-balance loss weight
    skip_nonfinite: bool = True       # NaN/Inf step -> keep old state
    compress_grads: bool = False      # int8 DP gradient reduction


def make_train_state(model, rng) -> Dict[str, Any]:
    params = model.init(rng)
    return {"params": params, "opt": adamw_init(params)}


def train_state_shapes(model, rng=None) -> Dict[str, Any]:
    """ShapeDtypeStruct tree of the train state (no allocation)."""
    rng = jax.random.PRNGKey(0) if rng is None else rng
    return jax.eval_shape(lambda r: make_train_state(model, r), rng)


def batch_pspec(batch_shapes, ctx: Optional[MeshContext] = None):
    """Shard every batch leaf's leading dim over the DP axes; replicate the
    rest. extra/src embeds additionally keep trailing dims replicated."""
    def one(leaf):
        axes = ["batch"] + [None] * (len(leaf.shape) - 1)
        return logical_to_spec(axes, leaf.shape, ctx)
    return jax.tree.map(one, batch_shapes)


def state_pspec(state_shapes, ctx: Optional[MeshContext] = None,
                fsdp: bool = False):
    pfn = fsdp_pspec if fsdp else param_pspec
    return {
        "params": pfn(state_shapes["params"], ctx),
        "opt": {
            "m": zero1_pspec(state_shapes["opt"]["m"], ctx),
            "v": zero1_pspec(state_shapes["opt"]["v"], ctx),
            "step": P(),
        },
    }


def _to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# train
# ---------------------------------------------------------------------------


def make_train_step(model, scfg: StepConfig = StepConfig(),
                    mesh: Optional[Mesh] = None) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics)."""

    def loss_fn(params, batch):
        out = model.loss(params, batch)
        if isinstance(out, tuple) and isinstance(out[1], dict):
            loss, mets = out
        else:
            loss, mets = out, {}
        return loss, mets

    def grads_of(params, batch, ef=None):
        if scfg.compress_grads and mesh is not None:
            if ef is not None:
                return compressed_grads(loss_fn, params, batch, mesh, ef)
            return (*compressed_grads(loss_fn, params, batch, mesh), None)
        return (*jax.value_and_grad(loss_fn, has_aux=True)(params, batch),
                None)

    def train_step(state, batch):
        params, opt = state["params"], state["opt"]
        ef = state.get("ef")
        new_ef = ef
        if scfg.accum > 1:
            def micro(carry, mb):
                g_acc, l_acc = carry
                (loss, mets), g, _ = grads_of(params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), mets
            micro_batch = jax.tree.map(
                lambda x: x.reshape((scfg.accum, x.shape[0] // scfg.accum)
                                    + x.shape[1:]), batch)
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), mets_all = jax.lax.scan(
                micro, (g0, jnp.zeros((), jnp.float32)), micro_batch)
            grads = jax.tree.map(lambda g: g / scfg.accum, grads)
            loss = loss / scfg.accum
            mets = jax.tree.map(lambda m: m[-1], mets_all)
        else:
            (loss, mets), grads, new_ef = grads_of(params, batch, ef)

        lr = warmup_cosine(opt["step"], peak_lr=scfg.peak_lr,
                           warmup_steps=scfg.warmup_steps,
                           total_steps=scfg.total_steps)
        new_params, new_opt, opt_mets = adamw_update(
            grads, opt, params, lr, scfg.adamw)

        if scfg.skip_nonfinite:
            ok = jnp.isfinite(loss) & jnp.isfinite(opt_mets["grad_norm"])
            new_params = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_params, params)
            new_opt = jax.tree.map(
                lambda n, o: jnp.where(ok, n, o), new_opt, opt)
            opt_mets["skipped"] = (~ok).astype(jnp.float32)

        metrics = {"loss": loss, "lr": lr, **mets, **opt_mets}
        new_state = {"params": new_params, "opt": new_opt}
        if ef is not None:
            new_state["ef"] = new_ef if new_ef is not None else ef
        return new_state, metrics

    return train_step


def jit_train_step(model, scfg: StepConfig, mesh: Mesh, batch_shapes,
                   donate: bool = True):
    """Jitted train step with explicit in/out shardings for `mesh`."""
    with activate_mesh(mesh) as ctx:
        shapes = train_state_shapes(model)
        sspec = state_pspec(shapes, ctx)
        bspec = batch_pspec(batch_shapes, ctx)
        step = make_train_step(model, scfg, mesh)
        jitted = jax.jit(
            step,
            in_shardings=(_to_shardings(sspec, mesh),
                          _to_shardings(bspec, mesh)),
            out_shardings=(_to_shardings(sspec, mesh), None),
            donate_argnums=(0,) if donate else ())
    return jitted, sspec, bspec


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------


def cache_pspec(cache_shapes, ctx: Optional[MeshContext] = None):
    """KV caches (NP, B, S, kv_eff, D): batch over DP, kv heads over model.
    Mamba caches: SSD state (NP, B, H, P, S) shards heads over model; the
    conv window (NP, B, K-1, CC) shards channels over model."""
    def one(path, leaf):
        ndim = len(leaf.shape)
        names = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path)
        if "mamba" in names:
            axes = ([None, "batch", "heads", None, None] if ndim == 5
                    else [None, "batch", None, "d_inner"])
        elif "kv_seq2" in names:          # 2d serve: seq over data+model
            axes = [None, "batch_pod", "kv_seq2", None, None]
        elif "kv_seq" in names:           # seq-sharded unrepeated KV
            axes = [None, "batch", "kv_seq", None, None]
        elif ndim == 5:                   # stacked (cross-)KV (NP,B,S,H,D)
            axes = [None, "batch", "kv_len", "kv_heads", None]
        else:
            axes = [None, "batch"] + [None] * max(ndim - 2, 0)
        axes = axes[:ndim] + [None] * (ndim - len(axes))
        return logical_to_spec(axes, leaf.shape, ctx)
    return jax.tree_util.tree_map_with_path(one, cache_shapes)


def make_prefill_step(model) -> Callable:
    def prefill_step(params, batch, cache):
        kw = {}
        if "extra_embeds" in batch:
            kw["extra_embeds"] = batch["extra_embeds"]
        if "src_embeds" in batch:   # enc-dec
            return model.prefill(params, batch["src_embeds"],
                                 batch["tokens"], cache)
        return model.prefill(params, batch["tokens"], cache, **kw)
    return prefill_step


def make_decode_step(model) -> Callable:
    def decode_step(params, token, cache, pos):
        return model.decode_step(params, token, cache, pos)
    return decode_step


def serve_shardings(model, cache_shapes, mesh: Mesh):
    with activate_mesh(mesh) as ctx:
        pspec = param_pspec(
            jax.eval_shape(model.init, jax.random.PRNGKey(0)), ctx)
        cspec = cache_pspec(cache_shapes, ctx)
    return (_to_shardings(pspec, mesh), _to_shardings(cspec, mesh))
