"""Logical-axis sharding rules with divisibility fallback.

MaxText-style indirection: model code annotates activations/params with
*logical* axis names ("batch", "heads", "ff", ...); this module resolves
them against the active mesh using LOGICAL_RULES, picking the first mesh
axis (or axis tuple) whose size divides the dimension — falling back to
replication rather than erroring. That single rule-set makes all 12
architectures shardable on the production meshes without per-arch
special-casing (e.g. llava's 56 q-heads simply don't shard over model=16;
the fused q-projection output dim 7168 still does).

Inside jit-traced model code, ``shard(x, *axes)`` applies a
with_sharding_constraint when a MeshContext is active and is a no-op
otherwise (single-device tests).
"""
from __future__ import annotations

import re
from contextlib import contextmanager
from contextvars import ContextVar
from dataclasses import dataclass, field
from typing import Dict, Optional, Sequence, Tuple

import jax
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisCandidates = Tuple[Tuple[str, ...], ...]

#: logical axis -> ordered candidates (each candidate is a mesh-axis tuple).
#: First candidate whose total size divides the dim wins; else replicate.
LOGICAL_RULES: Dict[str, AxisCandidates] = {
    # data-parallel axes
    "batch": (("pod", "data"), ("data",), ("pod",)),
    "seq_shard": (("pod", "data"), ("data",)),     # sequence parallelism
    # tensor-parallel axes
    "vocab": (("model",),),
    "heads": (("model",),),
    "kv_heads": (("model",),),
    "ff": (("model",),),
    "qkv_dim": (("model",),),
    "d_inner": (("model",),),                       # mamba expanded dim
    "experts": (("model",),),
    "kv_seq": (("model",),),                        # seq-sharded decode KV
    "kv_seq2": (("data", "model"),),                # 2d serve layout
    "batch_pod": (("pod",),),                       # 2d serve: batch->pod
    # replicated axes
    "embed": (),
    "seq": (),
    "kv_len": (),
    "head_dim": (),
    "ssm_state": (),
    "conv_k": (),
    "layers": (),
    "capacity": (),
    # CNN path
    "img_h": (), "img_w": (),
    "cin": (), "cout": (("model",),),
}


@dataclass(frozen=True)
class MeshContext:
    mesh: Mesh
    rules: Dict[str, AxisCandidates] = field(default_factory=lambda: LOGICAL_RULES)
    extra: Dict[str, AxisCandidates] = field(default_factory=dict)

    def candidates(self, name: str) -> AxisCandidates:
        if name in self.extra:
            return self.extra[name]
        return self.rules.get(name, ())


_ACTIVE: ContextVar[Optional[MeshContext]] = ContextVar("mesh_ctx", default=None)


@contextmanager
def activate_mesh(mesh: Optional[Mesh],
                  extra_rules: Optional[Dict[str, AxisCandidates]] = None):
    """Make `mesh` the resolution target for shard()/logical_to_spec()."""
    ctx = None if mesh is None else MeshContext(mesh, extra=extra_rules or {})
    token = _ACTIVE.set(ctx)
    try:
        yield ctx
    finally:
        _ACTIVE.reset(token)


def current_mesh_context() -> Optional[MeshContext]:
    return _ACTIVE.get()


def _mesh_axis_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        if a not in mesh.axis_names:
            return 0  # candidate references an axis this mesh doesn't have
        size *= mesh.shape[a]
    return size


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    shape: Sequence[int],
                    ctx: Optional[MeshContext] = None) -> P:
    """Resolve logical axis names to a PartitionSpec for `shape`."""
    ctx = ctx or _ACTIVE.get()
    if ctx is None:
        return P()
    assert len(logical_axes) == len(shape), (logical_axes, shape)
    spec = []
    used: set = set()
    for name, dim in zip(logical_axes, shape):
        entry = None
        if name is not None:
            for cand in ctx.candidates(name):
                size = _mesh_axis_size(ctx.mesh, cand)
                if size > 1 and dim % size == 0 and not (set(cand) & used):
                    entry = cand if len(cand) > 1 else cand[0]
                    used.update(cand)
                    break
        spec.append(entry)
    return P(*spec)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain an activation's sharding by logical axis names (no-op when
    no mesh is active)."""
    ctx = _ACTIVE.get()
    if ctx is None:
        return x
    spec = logical_to_spec(logical_axes, x.shape, ctx)
    return lax.with_sharding_constraint(x, NamedSharding(ctx.mesh, spec))


# ---------------------------------------------------------------------------
# Parameter sharding: path-pattern -> logical axes
# ---------------------------------------------------------------------------

#: Parameter-path regex -> logical axes per dim (applied to the *trailing*
#: dims; leading scan/stack dims resolve to None). First match wins.
PARAM_AXIS_PATTERNS: Tuple[Tuple[str, Tuple[Optional[str], ...]], ...] = (
    (r"embed/table", ("vocab", "embed")),
    (r"lm_head/kernel", ("embed", "vocab")),
    (r"(q_proj|k_proj|v_proj)/kernel", ("embed", "qkv_dim")),
    (r"o_proj/kernel", ("qkv_dim", "embed")),
    (r"experts/w_(gate|up)", ("experts", "embed", "ff")),
    (r"experts/w_down", ("experts", "ff", "embed")),
    (r"router/kernel", ("embed", None)),
    (r"(mlp|shared_expert|dense_mlp)/w_(gate|up)/kernel", ("embed", "ff")),
    (r"(mlp|shared_expert|dense_mlp)/w_down/kernel", ("ff", "embed")),
    (r"mlp/w_in/kernel", ("embed", "ff")),
    (r"mlp/w_out/kernel", ("ff", "embed")),
    (r"in_proj/kernel", ("embed", "d_inner")),
    (r"out_proj/kernel", ("d_inner", "embed")),
    (r"conv1d/w", ("conv_k", "d_inner")),
    (r"(A_log|dt_bias|D)$", ("d_inner",)),
    (r"ssm_norm/scale", ("d_inner",)),
    # ConvNet params live in a list: conv/<layer-idx>/kernel.
    (r"conv/(\d+/)?kernel", ("conv_k", "conv_k", "cin", "cout")),
    (r"(norm|ln)[^/]*/(scale|bias)", ("embed",)),
    (r"bias$", (None,)),
)


def param_logical_axes(path: str, ndim: int) -> Tuple[Optional[str], ...]:
    """Logical axes for a parameter, by path pattern (trailing-dim aligned)."""
    for pat, axes in PARAM_AXIS_PATTERNS:
        if re.search(pat, path):
            if len(axes) > ndim:
                axes = axes[len(axes) - ndim:]
            return (None,) * (ndim - len(axes)) + tuple(axes)
    return (None,) * ndim


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def param_pspec(params, ctx: Optional[MeshContext] = None):
    """PartitionSpec tree for a parameter pytree (by path patterns)."""
    ctx = ctx or _ACTIVE.get()

    def one(path, leaf):
        axes = param_logical_axes(_path_str(path), np.ndim(leaf))
        return logical_to_spec(axes, np.shape(leaf), ctx)

    return jax.tree_util.tree_map_with_path(one, params)


def _dp_extend(spec, shape, ctx, dp_axes):
    """Shard the largest still-unsharded dim over the data axes."""
    spec = list(spec) + [None] * (len(shape) - len(spec))
    if ctx is None:
        return P(*spec)
    avail = tuple(a for a in dp_axes if a in ctx.mesh.axis_names)
    size = int(np.prod([ctx.mesh.shape[a] for a in avail])) if avail else 0
    if size > 1:
        dims = sorted(range(len(shape)), key=lambda d: -shape[d])
        for d in dims:
            if spec[d] is None and shape[d] % size == 0:
                spec[d] = avail if len(avail) > 1 else avail[0]
                break
    return P(*spec)


def zero1_pspec(params, ctx: Optional[MeshContext] = None,
                dp_axes: Tuple[str, ...] = ("pod", "data")):
    """ZeRO-1 spec for optimizer state: param spec + shard the largest
    still-unsharded dim over the data axes (divisibility permitting)."""
    ctx = ctx or _ACTIVE.get()

    def one(path, leaf):
        axes = param_logical_axes(_path_str(path), np.ndim(leaf))
        spec = logical_to_spec(axes, np.shape(leaf), ctx)
        return _dp_extend(spec, np.shape(leaf), ctx, dp_axes)

    return jax.tree_util.tree_map_with_path(one, params)


def fsdp_pspec(params, ctx: Optional[MeshContext] = None,
               dp_axes: Tuple[str, ...] = ("pod", "data")):
    """FSDP/ZeRO-3-style PARAMETER sharding: on top of the TP assignment,
    the largest remaining dim of every weight shards over the data axes.
    GSPMD inserts the per-layer weight all-gathers (and reduce-scatters on
    the gradients) automatically — HBM for resident params drops by the
    DP world size, traded against the collective term (measured in
    §Perf). This is what lets arctic-480b / llama4 / mistral-large fit a
    16 GB/chip pod (§Roofline fits_hbm)."""
    return zero1_pspec(params, ctx, dp_axes)


def shard_map_compat(f, *, mesh, in_specs, out_specs, check_vma: bool = True,
                     axis_names=frozenset()):
    """``jax.shard_map`` across jax versions.

    Newer jax exposes ``jax.shard_map(..., check_vma=, axis_names=)``;
    0.4.x has ``jax.experimental.shard_map.shard_map(..., check_rep=,
    auto=)`` where ``auto`` is the complement of the manual axis set.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma,
                             axis_names=axis_names)
    # 0.4.x fallback: partial-manual (auto=) + check_rep=False trips an XLA
    # partitioner check, so run fully manual — unnamed axes simply see
    # replicated blocks per the specs, which our bodies already assume.
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)
