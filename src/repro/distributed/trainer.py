"""Fault-tolerant training loop.

Features (DESIGN.md §6):
- async sharded checkpoints every `ckpt_every` steps, atomic commit;
- auto-resume from the latest *committed* step (torn checkpoints skipped);
- elastic restore: the checkpoint is mesh-agnostic; restoring under a
  different mesh re-shards via the current PartitionSpecs;
- NaN/Inf step skip (inside the jitted step — the state update is gated);
- straggler/flake detection: per-step wall time EWMA + z-score flagging,
  with the slow-step log returned to the caller;
- deterministic data: the pipeline is a pure function of (seed, step), so
  resume at step k replays exactly the batches steps k, k+1, ... would
  have seen.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager


@dataclass
class StragglerMonitor:
    """EWMA wall-time tracker; flags steps slower than mean + z * std."""
    alpha: float = 0.1
    z_threshold: float = 3.0
    mean: float = 0.0
    var: float = 0.0
    n: int = 0
    flagged: List[Dict[str, float]] = field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n >= 5:   # warmup
            std = max(self.var ** 0.5, 1e-6)
            if dt > self.mean + self.z_threshold * std:
                self.flagged.append({"step": step, "dt": dt,
                                     "mean": self.mean, "std": std})
                # do not poison the EWMA with the outlier
                self.n += 1
                return True
        delta = dt - self.mean
        self.mean += self.alpha * delta if self.n else delta
        self.var = (1 - self.alpha) * (self.var + self.alpha * delta ** 2) \
            if self.n else 0.0
        self.n += 1
        return False


@dataclass
class TrainLoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    keep_last: int = 3
    log_every: int = 10
    resume: bool = True


def train_loop(step_fn: Callable, state, dataset, loop_cfg: TrainLoopConfig,
               state_shardings=None, log_fn: Callable = print,
               ) -> Dict[str, Any]:
    """Run the loop; returns {state, history, stragglers, resumed_from}."""
    mgr = (CheckpointManager(loop_cfg.ckpt_dir, loop_cfg.keep_last)
           if loop_cfg.ckpt_dir else None)
    start = 0
    resumed_from = None
    if mgr is not None and loop_cfg.resume:
        step, restored = mgr.restore_latest(state, state_shardings)
        if step is not None:
            state, start, resumed_from = restored, step, step
            log_fn(f"[trainer] resumed from step {step}")

    monitor = StragglerMonitor()
    history: List[Dict[str, float]] = []
    for step in range(start, loop_cfg.total_steps):
        batch = dataset.batch_at(step)
        t0 = time.perf_counter()
        state, metrics = step_fn(state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        slow = monitor.observe(step, dt)
        row = {"step": step, "dt_s": dt,
               **{k: float(np.asarray(v)) for k, v in metrics.items()
                  if np.ndim(v) == 0}}
        history.append(row)
        if slow:
            log_fn(f"[trainer] straggler step {step}: {dt:.3f}s "
                   f"(mean {monitor.mean:.3f}s)")
        if step % loop_cfg.log_every == 0 or step == loop_cfg.total_steps - 1:
            log_fn(f"[trainer] step {step} loss {row.get('loss', float('nan')):.4f} "
                   f"({dt*1e3:.0f} ms)")
        if mgr is not None and (step + 1) % loop_cfg.ckpt_every == 0:
            mgr.save(state, step + 1)
    if mgr is not None:
        mgr.save(state, loop_cfg.total_steps)
        mgr.wait()
    return {"state": state, "history": history,
            "stragglers": monitor.flagged, "resumed_from": resumed_from}
