"""Mamba2 (SSD — state-space duality) mixer: chunked train/prefill scan and
O(1) recurrent decode, with the TrIM-1D Pallas kernel as the short-conv
hot spot.

The SSD recurrence  h_t = exp(dt_t A) h_{t-1} + dt_t x_t B_t^T,
                    y_t = C_t h_t + D x_t
is evaluated in chunks (arXiv:2405.21060 §6): a within-chunk quadratic
"attention-like" term plus an inter-chunk state carried by a lax.scan —
structurally the TrIM engine's psum-buffer temporal accumulation (chunk-local
compute + carried partial state), which is why the chunked path shares the
kernels' accumulate-in-f32 discipline.

Shapes: u (B, L, d_model); internal x (B, L, H, P) with H heads of headdim P,
state S per head, G B/C groups (G divides H).
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.kernels.ops import trim_conv1d
from repro.nn.layers import Params, _normal, init_dense, dense

NEG_INF = -1e30


class MambaDims(NamedTuple):
    d_model: int
    d_inner: int     # expand * d_model
    n_heads: int     # d_inner // headdim
    headdim: int
    d_state: int
    n_groups: int
    d_conv: int
    chunk: int

    @property
    def conv_channels(self) -> int:
        return self.d_inner + 2 * self.n_groups * self.d_state

    @property
    def in_proj_out(self) -> int:
        # z, x, B, C, dt
        return 2 * self.d_inner + 2 * self.n_groups * self.d_state + self.n_heads


def mamba_dims(d_model: int, *, expand: int = 2, headdim: int = 64,
               d_state: int = 128, n_groups: int = 1, d_conv: int = 4,
               chunk: int = 256) -> MambaDims:
    d_inner = expand * d_model
    assert d_inner % headdim == 0
    return MambaDims(d_model, d_inner, d_inner // headdim, headdim, d_state,
                     n_groups, d_conv, chunk)


def init_mamba(key, dims: MambaDims, dtype=jnp.float32) -> Params:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    H = dims.n_heads
    # dt bias initialized so softplus(dt_bias) spans [1e-3, 1e-1] (std init)
    dt = jnp.exp(jax.random.uniform(k3, (H,), jnp.float32)
                 * (math.log(0.1) - math.log(1e-3)) + math.log(1e-3))
    dt_bias = dt + jnp.log(-jnp.expm1(-dt))
    return {
        "in_proj": init_dense(k1, dims.d_model, dims.in_proj_out, dtype=dtype),
        "conv1d": {"w": _normal(k2, (dims.d_conv, dims.conv_channels),
                                dims.d_conv ** -0.5, dtype)},
        "A_log": jnp.log(jnp.arange(1, H + 1, dtype=jnp.float32)),
        "dt_bias": dt_bias.astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "ssm_norm": {"scale": jnp.ones((dims.d_inner,), dtype)},
        "out_proj": init_dense(k4, dims.d_inner, dims.d_model,
                               std=dims.d_inner ** -0.5, dtype=dtype),
    }


# ---------------------------------------------------------------------------
# Chunked SSD (train / prefill)
# ---------------------------------------------------------------------------


def _segsum(x: jax.Array) -> jax.Array:
    """x (..., T) -> (..., T, T) lower-triangular segment sums:
    out[..., t, s] = sum_{s < u <= t} x[..., u] (NEG_INF above diagonal)."""
    T = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), bool), 0)
    return jnp.where(mask, seg, NEG_INF)


def ssd_chunked(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                C: jax.Array, D: jax.Array, *, chunk: int,
                h0: Optional[jax.Array] = None,
                score_dtype=jnp.float32,
                ) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.

    x (B, L, H, P) f32; dt (B, L, H) f32 (post-softplus); A (H,) negative;
    B/C (B, L, G, S); D (H,). h0 optional initial state (B, H, P, S).
    score_dtype: dtype of the within-chunk quadratic tensors (the (CS, CS)
    "attention-like" term) — bf16 halves their HBM traffic (§Perf); the
    decay statistics (cumsums, exps) and the inter-chunk state stay f32.
    Returns (y (B, L, H, P), h_final (B, H, P, S)).
    """
    Bb, L, H, P = x.shape
    G, S = B.shape[-2], B.shape[-1]
    rep = H // G
    CS = min(chunk, L)
    NC = -(-L // CS)
    pad = NC * CS - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0)))

    xc = x.reshape(Bb, NC, CS, H, P)
    dtc = dt.reshape(Bb, NC, CS, H)
    Bc = B.reshape(Bb, NC, CS, G, S)
    Cc = C.reshape(Bb, NC, CS, G, S)

    dA = dtc * A  # (B, NC, CS, H) negative decay increments
    dAcs = jnp.cumsum(dA, axis=2)

    # within-chunk quadratic term (score_dtype; f32 accumulation)
    Lmat = jnp.exp(_segsum(dA.transpose(0, 1, 3, 2))
                   ).astype(score_dtype)                    # (B,NC,H,CS,CS)
    CB = jnp.einsum("bntgs,bnugs->bngtu", Cc.astype(score_dtype),
                    Bc.astype(score_dtype),
                    preferred_element_type=score_dtype)     # (B,NC,G,CS,CS)
    CB = jnp.repeat(CB, rep, axis=2) if rep > 1 else CB     # (B,NC,H,CS,CS)
    scores = CB * Lmat * dtc.transpose(0, 1, 3, 2)[:, :, :, None, :
                                                   ].astype(score_dtype)
    y_diag = jnp.einsum("bnhtu,bnuhp->bnthp", scores,
                        xc.astype(score_dtype),
                        preferred_element_type=jnp.float32)

    # per-chunk terminal states
    decay_to_end = jnp.exp(dAcs[:, :, -1:, :] - dAcs)        # (B,NC,CS,H)
    Brep = jnp.repeat(Bc, rep, axis=3) if rep > 1 else Bc   # (B,NC,CS,H,S)
    dBx = jnp.einsum("bnth,bnths,bnthp->bnhps",
                     dtc * decay_to_end, Brep, xc)

    chunk_decay = jnp.exp(dAcs[:, :, -1, :])                 # (B, NC, H)

    def scan_f(h, inp):
        dec, s = inp                                          # (B,H), (B,H,P,S)
        h_new = h * dec[..., None, None] + s
        return h_new, h
    h_init = (jnp.zeros((Bb, H, P, S), jnp.float32) if h0 is None
              else h0.astype(jnp.float32))
    h_last, h_prevs = jax.lax.scan(
        scan_f, h_init,
        (chunk_decay.transpose(1, 0, 2), dBx.transpose(1, 0, 2, 3, 4)))
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                # (B,NC,H,P,S)

    # inter-chunk contribution
    Crep = jnp.repeat(Cc, rep, axis=3) if rep > 1 else Cc     # (B,NC,CS,H,S)
    y_off = jnp.einsum("bnths,bnhps,bnth->bnthp", Crep, h_prevs,
                       jnp.exp(dAcs))
    y = (y_diag + y_off).reshape(Bb, NC * CS, H, P)[:, :L]
    y = y + x.reshape(Bb, NC * CS, H, P)[:, :L] * D[None, None, :, None]
    return y, h_last


def ssd_decode_step(h: jax.Array, x: jax.Array, dt: jax.Array, A: jax.Array,
                    B: jax.Array, C: jax.Array, D: jax.Array,
                    ) -> Tuple[jax.Array, jax.Array]:
    """One-token recurrence. h (B,H,P,S); x (B,H,P); dt (B,H); B/C (B,G,S).
    Returns (y (B,H,P), h_new)."""
    H = x.shape[1]
    G = B.shape[1]
    rep = H // G
    Br = jnp.repeat(B, rep, axis=1) if rep > 1 else B         # (B,H,S)
    Cr = jnp.repeat(C, rep, axis=1) if rep > 1 else C
    decay = jnp.exp(dt * A)                                   # (B,H)
    h_new = (h * decay[..., None, None]
             + jnp.einsum("bh,bhp,bhs->bhps", dt, x, Br))
    y = jnp.einsum("bhs,bhps->bhp", Cr, h_new) + x * D[None, :, None]
    return y, h_new


# ---------------------------------------------------------------------------
# Full mixer (block-level API)
# ---------------------------------------------------------------------------


class MambaCache(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, conv_channels) trailing conv window
    ssm: jax.Array    # (B, H, P, S) recurrent state


def init_mamba_cache(batch: int, dims: MambaDims, dtype=jnp.float32,
                     ) -> MambaCache:
    return MambaCache(
        jnp.zeros((batch, dims.d_conv - 1, dims.conv_channels), dtype),
        jnp.zeros((batch, dims.n_heads, dims.headdim, dims.d_state),
                  jnp.float32))


def _gated_rmsnorm(params: Params, y: jax.Array, z: jax.Array,
                   eps: float = 1e-5) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps)
            * params["scale"].astype(jnp.float32)).astype(y.dtype)


def _split_proj(proj: jax.Array, dims: MambaDims):
    d_in, gs = dims.d_inner, dims.n_groups * dims.d_state
    z = proj[..., :d_in]
    xBC = proj[..., d_in:d_in + d_in + 2 * gs]
    dt = proj[..., d_in + d_in + 2 * gs:]
    return z, xBC, dt


def mamba_mixer(params: Params, u: jax.Array, dims: MambaDims, *,
                mode: str = "train", cache: Optional[MambaCache] = None,
                score_dtype=jnp.float32,
                ) -> Tuple[jax.Array, Optional[MambaCache]]:
    """u (B, L, d_model) -> (out, new_cache).

    mode "train"/"prefill": chunked SSD over the sequence (prefill returns
    the terminal cache); mode "decode": L == 1 recurrent step.
    """
    Bb, L, _ = u.shape
    d_in, gs = dims.d_inner, dims.n_groups * dims.d_state
    proj = dense(params["in_proj"], u)
    z, xBC, dt_raw = _split_proj(proj, dims)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))

    new_cache = None
    if mode == "decode":
        assert cache is not None and L == 1
        window = jnp.concatenate(
            [cache.conv.astype(xBC.dtype), xBC], axis=1)      # (B, K, CC)
        conv_out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32),
                              params["conv1d"]["w"].astype(jnp.float32))
        # round to the compute dtype BEFORE the activation — bit-consistent
        # with the train path (trim_conv1d returns x.dtype, then silu)
        xBC_c = jax.nn.silu(conv_out.astype(xBC.dtype))[:, None]
        new_conv = window[:, 1:]
        x = xBC_c[..., :d_in].reshape(Bb, 1, dims.n_heads, dims.headdim)
        Bm = xBC_c[..., d_in:d_in + gs].reshape(Bb, dims.n_groups, dims.d_state)
        Cm = xBC_c[..., d_in + gs:].reshape(Bb, dims.n_groups, dims.d_state)
        y, h_new = ssd_decode_step(
            cache.ssm, x[:, 0].astype(jnp.float32), dt[:, 0], A,
            Bm.astype(jnp.float32), Cm.astype(jnp.float32), params["D"])
        y = y[:, None].reshape(Bb, 1, d_in).astype(u.dtype)
        new_cache = MambaCache(new_conv, h_new)
    else:
        xBC_c = jax.nn.silu(trim_conv1d(xBC, params["conv1d"]["w"]
                                        .astype(xBC.dtype)))
        xBC_c = shard(xBC_c, "batch", "seq", "d_inner")
        x = xBC_c[..., :d_in].reshape(Bb, L, dims.n_heads, dims.headdim)
        Bm = xBC_c[..., d_in:d_in + gs].reshape(Bb, L, dims.n_groups,
                                                dims.d_state)
        Cm = xBC_c[..., d_in + gs:].reshape(Bb, L, dims.n_groups, dims.d_state)
        y, h_last = ssd_chunked(x.astype(jnp.float32), dt, A,
                                Bm.astype(jnp.float32),
                                Cm.astype(jnp.float32), params["D"],
                                chunk=dims.chunk, score_dtype=score_dtype)
        y = y.reshape(Bb, L, d_in).astype(u.dtype)
        if mode == "prefill":
            assert cache is not None
            # trailing conv window of the raw (pre-activation) stream
            tail = xBC[:, -(dims.d_conv - 1):]
            pad = dims.d_conv - 1 - tail.shape[1]
            if pad > 0:
                tail = jnp.pad(tail, ((0, 0), (pad, 0), (0, 0)))
            new_cache = MambaCache(tail.astype(cache.conv.dtype), h_last)

    y = _gated_rmsnorm(params["ssm_norm"], y, z)
    y = shard(y, "batch", "seq", "d_inner")
    out = dense(params["out_proj"], y)
    return shard(out, "batch", "seq", "embed"), new_cache
