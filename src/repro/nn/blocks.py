"""Layer stacks: periodic layer schedules + scan-over-layers execution,
plus the CNN conv block (conv + fused epilogue + pool) for the paper's
own workloads.

Every assigned architecture is expressible as a *periodic* schedule of slots
(mixer, ffn) repeated n_layers/period times:

- dense transformers:      period 1, (attn, mlp)
- llama4 (interleaved MoE): period 2, (attn, mlp), (attn, moe)
- arctic (MoE+dense-res):  period 1, (attn, moe[dense_residual])
- mamba2:                  period 1, (mamba, none)
- jamba (1:7 attn:mamba, MoE on odd layers): period 8,
    slots i=0..7 -> mixer = attn if i==4 else mamba; ffn = moe if i odd else mlp
- seamless encoder:        period 1, (attn[non-causal], mlp)
- seamless decoder:        period 1, (attn + cross-attn, mlp)

Parameters for each slot are stacked over periods on a leading axis and the
stack is executed with ``lax.scan`` (fast compiles, small HLO — essential for
the 512-device dry-run), optionally under ``jax.checkpoint`` (remat).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

#: 2x2/stride-2 max pool (moved to repro.engine.execute; alias kept here
#: for the CNN callers that historically imported it from nn.blocks).
from repro.engine.execute import max_pool2x2  # noqa: F401
from repro.nn.attention import (AttnLayout, KVCache, attention,
                                init_attention, init_kv_cache, make_cross_kv)
from repro.nn.layers import (Params, init_layernorm, init_mlp, init_rmsnorm,
                             layernorm, mlp, rmsnorm)
from repro.nn.mamba import (MambaCache, MambaDims, init_mamba,
                            init_mamba_cache, mamba_mixer)
from repro.nn.moe import init_moe, moe


@dataclass(frozen=True)
class SlotSpec:
    mixer: str                 # "attn" | "mamba" | "none"
    ffn: str                   # "mlp" | "moe" | "none"
    cross_attn: bool = False   # decoder slot with encoder cross-attention


@dataclass(frozen=True)
class StackSpec:
    slots: Tuple[SlotSpec, ...]
    n_periods: int
    d_model: int
    d_ff: int
    mlp_kind: str = "swiglu"
    norm: str = "rmsnorm"
    layout: Optional[AttnLayout] = None
    rope_theta: float = 1e4
    causal: bool = True
    dims: Optional[MambaDims] = None         # mamba dims (ssm/hybrid)
    n_experts: int = 0
    top_k: int = 0
    shared_expert: bool = False
    dense_residual: bool = False
    dense_ff: Optional[int] = None
    capacity_factor: float = 1.25
    moe_impl: str = "einsum"                 # einsum | gather
    remat: str = "none"                      # none | dots | full
    chunk_k: int = 1024
    block_causal: bool = False
    scan_layers: bool = True                 # False: unroll (cost calib.)
    kv_seqshard: str = ""                    # "" | "model" | "2d"
    ssd_bf16: bool = False                   # bf16 SSD quadratic term

    @property
    def n_layers(self) -> int:
        return len(self.slots) * self.n_periods


def _norm_fns(kind: str):
    if kind == "rmsnorm":
        return init_rmsnorm, rmsnorm
    if kind == "layernorm":
        return init_layernorm, layernorm
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _init_slot(key, spec: StackSpec, slot: SlotSpec, dtype) -> Params:
    init_norm, _ = _norm_fns(spec.norm)
    keys = jax.random.split(key, 4)
    p: Params = {}
    if slot.mixer == "attn":
        lay = spec.layout
        p["norm_mixer"] = init_norm(spec.d_model, dtype)
        p["attn"] = init_attention(keys[0], spec.d_model, lay.n_q, lay.n_kv,
                                   lay.head_dim, dtype)
        if slot.cross_attn:
            p["norm_cross"] = init_norm(spec.d_model, dtype)
            p["cross"] = init_attention(keys[3], spec.d_model, lay.n_q,
                                        lay.n_kv, lay.head_dim, dtype)
    elif slot.mixer == "mamba":
        p["norm_mixer"] = init_norm(spec.d_model, dtype)
        p["mamba"] = init_mamba(keys[0], spec.dims, dtype)
    if slot.ffn == "mlp":
        p["norm_ffn"] = init_norm(spec.d_model, dtype)
        p["mlp"] = init_mlp(keys[1], spec.d_model, spec.d_ff, spec.mlp_kind,
                            dtype)
    elif slot.ffn == "moe":
        p["norm_ffn"] = init_norm(spec.d_model, dtype)
        p["moe"] = init_moe(keys[2], spec.d_model, spec.d_ff, spec.n_experts,
                            mlp_kind=spec.mlp_kind,
                            shared_expert=spec.shared_expert,
                            dense_residual=spec.dense_residual,
                            dense_ff=spec.dense_ff, dtype=dtype)
    return p


def init_stack(key, spec: StackSpec, dtype=jnp.float32) -> Params:
    """Stacked params: {"slot<i>": pytree with leading n_periods axis}."""
    out: Params = {}
    for i, slot in enumerate(spec.slots):
        keys = jax.random.split(jax.random.fold_in(key, i), spec.n_periods)
        per = [_init_slot(k, spec, slot, dtype) for k in keys]
        out[f"slot{i}"] = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    return out


def init_stack_cache(spec: StackSpec, batch: int, max_len: int,
                     dtype=jnp.bfloat16, cross_len: int = 0) -> Params:
    """Decode caches, stacked over periods per slot. Slots without state get
    empty dicts (keeps the treedef static)."""
    cache: Params = {}
    for i, slot in enumerate(spec.slots):
        if slot.mixer == "attn":
            kv = init_kv_cache(batch, max_len, spec.layout, dtype,
                               seqshard=bool(spec.kv_seqshard))
            key = ("kv" if not spec.kv_seqshard else
                   "kv_seq2" if spec.kv_seqshard == "2d" else "kv_seq")
            c: Dict[str, Any] = {key: KVCache(
                jnp.broadcast_to(kv.k, (spec.n_periods,) + kv.k.shape),
                jnp.broadcast_to(kv.v, (spec.n_periods,) + kv.v.shape))}
            if slot.cross_attn:
                lay = spec.layout
                shape = (spec.n_periods, batch, cross_len, lay.kv_eff,
                         lay.head_dim)
                c["cross_kv"] = (jnp.zeros(shape, dtype),
                                 jnp.zeros(shape, dtype))
            cache[f"slot{i}"] = c
        elif slot.mixer == "mamba":
            mc = init_mamba_cache(batch, spec.dims, dtype)
            cache[f"slot{i}"] = {"mamba": MambaCache(
                jnp.broadcast_to(mc.conv, (spec.n_periods,) + mc.conv.shape),
                jnp.broadcast_to(mc.ssm, (spec.n_periods,) + mc.ssm.shape))}
        else:
            cache[f"slot{i}"] = {}
    return cache


# ---------------------------------------------------------------------------
# Execution
# ---------------------------------------------------------------------------


def _run_slot(p: Params, x: jax.Array, spec: StackSpec, slot: SlotSpec, *,
              mode: str, positions, cache_pos, kv_length,
              cache: Optional[Dict[str, Any]],
              enc_out: Optional[jax.Array],
              ) -> Tuple[jax.Array, Dict[str, Any], jax.Array]:
    _, norm = _norm_fns(spec.norm)
    new_cache: Dict[str, Any] = {}
    aux = jnp.zeros((), jnp.float32)
    if slot.mixer == "attn":
        kv_key = ("kv" if not spec.kv_seqshard else
                  "kv_seq2" if spec.kv_seqshard == "2d" else "kv_seq")
        kv = cache.get(kv_key) if cache else None
        h, nkv = attention(p["attn"], norm(p["norm_mixer"], x), spec.layout,
                           positions=positions, rope_theta=spec.rope_theta,
                           causal=spec.causal, mode=mode, cache=kv,
                           cache_pos=cache_pos, kv_length=kv_length,
                           chunk_k=spec.chunk_k,
                           block_causal=spec.block_causal,
                           kv_seqshard=spec.kv_seqshard)
        x = x + h
        if nkv is not None:
            new_cache[kv_key] = nkv
        elif cache and kv_key in cache:
            new_cache[kv_key] = cache[kv_key]
        if slot.cross_attn:
            if cache is not None and "cross_kv" in cache and enc_out is None:
                ckv = cache["cross_kv"]
            else:
                ckv = make_cross_kv(p["cross"], enc_out, spec.layout)
            h, _ = attention(p["cross"], norm(p["norm_cross"], x),
                             spec.layout, positions=positions,
                             mode="train", causal=False, cross_kv=ckv,
                             chunk_k=spec.chunk_k)
            x = x + h
            if cache is not None:
                new_cache["cross_kv"] = ckv
    elif slot.mixer == "mamba":
        mc = cache.get("mamba") if cache else None
        h, nmc = mamba_mixer(p["mamba"], norm(p["norm_mixer"], x), spec.dims,
                             mode=mode, cache=mc,
                             score_dtype=jnp.bfloat16 if spec.ssd_bf16
                             else jnp.float32)
        x = x + h
        if nmc is not None:
            new_cache["mamba"] = nmc
        elif cache and "mamba" in cache:
            new_cache["mamba"] = cache["mamba"]
    if slot.ffn == "mlp":
        x = x + mlp(p["mlp"], norm(p["norm_ffn"], x), spec.mlp_kind)
    elif slot.ffn == "moe":
        h, a = moe(p["moe"], norm(p["norm_ffn"], x), top_k=spec.top_k,
                   mlp_kind=spec.mlp_kind,
                   capacity_factor=spec.capacity_factor,
                   impl=spec.moe_impl)
        x = x + h
        aux = aux + a
    return x, new_cache, aux


def run_stack(params: Params, x: jax.Array, spec: StackSpec, *,
              mode: str = "train", positions: Optional[jax.Array] = None,
              cache: Optional[Params] = None,
              cache_pos: Optional[jax.Array] = None,
              kv_length: Optional[jax.Array] = None,
              enc_out: Optional[jax.Array] = None,
              ) -> Tuple[jax.Array, Optional[Params], jax.Array]:
    """Run the full stack. Returns (x, new_cache_or_None, moe_aux_sum).

    mode: "train" | "encoder" (no cache), "prefill", "decode".
    """
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(x.shape[1])[None],
                                     x.shape[:2])
    has_cache = cache is not None

    def period_fn(x, slot_params, slot_cache):
        new_caches = {}
        aux = jnp.zeros((), jnp.float32)
        for i, slot in enumerate(spec.slots):
            x, nc, a = _run_slot(
                slot_params[f"slot{i}"], x, spec, slot, mode=mode,
                positions=positions, cache_pos=cache_pos,
                kv_length=kv_length,
                cache=slot_cache[f"slot{i}"] if has_cache else None,
                enc_out=enc_out)
            new_caches[f"slot{i}"] = nc
            aux = aux + a
        return x, new_caches, aux

    if spec.remat == "full":
        period_fn = jax.checkpoint(
            period_fn, policy=jax.checkpoint_policies.nothing_saveable)
    elif spec.remat == "dots":
        period_fn = jax.checkpoint(
            period_fn,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)

    def scan_body(carry, xs):
        x, aux = carry
        slot_params, slot_cache = xs
        x, new_cache, a = period_fn(x, slot_params, slot_cache)
        return (x, aux + a), new_cache

    if not spec.scan_layers:
        # unrolled execution: identical math, python loop over periods.
        # Used by the dry-run's cost calibration (XLA cost_analysis counts
        # a while body once; unrolled small variants give exact per-period
        # costs) and available as a compile-time/runtime trade-off.
        aux = jnp.zeros((), jnp.float32)
        new_caches = []
        for i in range(spec.n_periods):
            p_i = jax.tree.map(lambda p: p[i], params)
            c_i = (jax.tree.map(lambda c: c[i], cache) if has_cache
                   else {f"slot{j}": {} for j in range(len(spec.slots))})
            x, nc, a = period_fn(x, p_i, c_i)
            aux = aux + a
            new_caches.append(nc)
        if not has_cache:
            return x, None, aux
        new_cache = jax.tree.map(lambda *cs: jnp.stack(cs), *new_caches)
        return x, new_cache, aux

    if not has_cache:
        # stateless run: empty per-slot caches (same dict every period)
        empty = {f"slot{i}": {} for i in range(len(spec.slots))}
        (x, aux), _ = jax.lax.scan(
            lambda c, p: scan_body(c, (p, empty)),
            (x, jnp.zeros((), jnp.float32)), params)
        return x, None, aux

    (x, aux), new_cache = jax.lax.scan(
        scan_body, (x, jnp.zeros((), jnp.float32)), (params, cache))
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# CNN conv blocks (the paper's VGG-16 / AlexNet layers)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvBlockSpec:
    """One TrIM conv layer's *architecture*: conv -> fused bias/ReLU ->
    [pool].

    Execution choices (substrate, ``emulate_hw`` decimation replay, tiling,
    requant fusion) no longer live here — they are compiled separately from
    an ``ExecutionPolicy`` into a ``ConvLayerPlan`` (``repro.engine``,
    DESIGN.md §3).
    """
    stride: int = 1
    padding: Optional[int] = None
    groups: int = 1
    relu: bool = True
    pool: bool = False               # 2x2/stride-2 max pool after the conv


def conv_block(p: Params, x: jax.Array, spec: ConvBlockSpec,
               policy: Optional["ExecutionPolicy"] = None) -> jax.Array:
    """Run one conv block. p: {"kernel": (K,K,C/groups,F) [, "bias": (F,)]}.

    Delegates to ``ops.trim_conv2d`` (which plans the call — dtype-aware
    tile sizing — and runs it through the engine's one dispatch site)
    under ``policy`` (default: ``ExecutionPolicy()`` — compiled Pallas on
    TPU, oracle elsewhere), then shards and pools.  A ``"requant"`` entry
    in ``p`` ((F,) int32 (mult, shift) arrays) fuses the calibrated
    per-channel requantization into the kernel flush.
    """
    from repro.distributed.sharding import shard
    from repro.kernels.ops import trim_conv2d

    w = p["kernel"]
    if jnp.issubdtype(x.dtype, jnp.floating):
        w = w.astype(x.dtype)
    x = trim_conv2d(x, w, p.get("bias"), p.get("requant"),
                    stride=spec.stride, padding=spec.padding,
                    groups=spec.groups, relu=spec.relu, policy=policy)
    x = shard(x, "batch", "img_h", "img_w", "cout")
    if spec.pool:
        x = max_pool2x2(x)
    return x
