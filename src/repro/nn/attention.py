"""Attention: GQA/MQA/MHA with chunked-flash training/prefill, KV-cached
decode, cross-attention (enc-dec), RoPE, and TP-friendly head layout.

TP head layout (``attn_layout``): on a `tp`-way model axis, kv heads are
*repeated* r = tp/n_kv times (the vLLM/TGI approach to TP > n_kv) and q
heads are zero-padded group-wise from G = n_q/n_kv to G_pad = ceil(G/r)*r,
giving an effective (kv_eff = n_kv*r) x (G' = G_pad/r) grouping in which
head<->kv correspondence is preserved *and* both q and kv head axes divide
the model axis. Padded q heads produce garbage that is sliced off before
o_proj (zero extra projection FLOPs; the attention-FLOP overhead shows up
honestly in the roofline useful-compute ratio).

The chunked flash attention is a pure-JAX streaming softmax (lax.scan over
KV chunks with running (m, l, o)), differentiable and SPMD-partitionable;
``block_causal=True`` switches to a q-block x kv-block sweep that skips
fully-masked upper-triangle blocks (a §Perf hillclimb lever).
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.nn.layers import Params, apply_rope, dense, init_dense, rope_angles

NEG_INF = -1e30


class AttnLayout(NamedTuple):
    n_q: int          # logical q heads
    n_kv: int         # logical kv heads
    head_dim: int
    kv_repeat: int    # r
    g_pad: int        # padded group size (q heads per logical kv head)

    @property
    def kv_eff(self) -> int:
        return self.n_kv * self.kv_repeat

    @property
    def g_eff(self) -> int:
        return self.g_pad // self.kv_repeat

    @property
    def n_q_pad(self) -> int:
        return self.n_kv * self.g_pad


def attn_layout(n_q: int, n_kv: int, head_dim: int, tp: int = 1) -> AttnLayout:
    assert n_q % n_kv == 0, (n_q, n_kv)
    g = n_q // n_kv
    r = tp // n_kv if (tp > n_kv and tp % n_kv == 0) else 1
    g_pad = -(-g // r) * r    # r divides g_pad by construction
    return AttnLayout(n_q, n_kv, head_dim, r, g_pad)


# -- params -------------------------------------------------------------------

def init_attention(key, d_model: int, n_q: int, n_kv: int, head_dim: int,
                   dtype=jnp.float32) -> Params:
    kq, kk, kv, ko = jax.random.split(key, 4)
    return {
        "q_proj": init_dense(kq, d_model, n_q * head_dim, dtype=dtype),
        "k_proj": init_dense(kk, d_model, n_kv * head_dim, dtype=dtype),
        "v_proj": init_dense(kv, d_model, n_kv * head_dim, dtype=dtype),
        "o_proj": init_dense(ko, n_q * head_dim, d_model,
                             std=(n_q * head_dim) ** -0.5, dtype=dtype),
    }


# -- flash core ----------------------------------------------------------------

def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool, q_offset: int = 0,
                    kv_length: Optional[jax.Array] = None,
                    chunk_k: int = 1024, block_causal: bool = False,
                    ) -> jax.Array:
    """Streaming-softmax attention.

    q (B, Sq, H_eff, G, D); k/v (B, Sk, H_eff, D). Returns (B, Sq, H_eff, G, D).
    H_eff is the (possibly repeated) kv head count; G the q group per head.
    """
    B, Sq, H, G, D = q.shape
    Sk = k.shape[1]
    scale = D ** -0.5
    ck = min(chunk_k, Sk)
    nk = -(-Sk // ck)
    pad_k = nk * ck - Sk
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))

    qT = q.transpose(0, 2, 3, 1, 4).astype(jnp.float32)      # (B,H,G,Sq,D)
    kc = k.reshape(B, nk, ck, H, D).transpose(1, 0, 3, 2, 4)  # (nk,B,H,ck,D)
    vc = v.reshape(B, nk, ck, H, D).transpose(1, 0, 3, 2, 4)

    rows = q_offset + jnp.arange(Sq)

    def chunk_step(carry, xs):
        m, l, o = carry
        kci, vci, idx = xs
        s = jnp.einsum("bhgqd,bhcd->bhgqc", qT, kci.astype(jnp.float32))
        s = s * scale
        cols = idx * ck + jnp.arange(ck)
        mask = jnp.ones((Sq, ck), bool)
        if causal:
            mask &= cols[None, :] <= rows[:, None]
        mask &= (cols < Sk)[None, :]
        if kv_length is not None:
            mask = mask[None] & (cols[None, None, :]
                                 < kv_length[:, None, None])
            mask = mask[:, None, None]                       # (B,1,1,Sq,ck)
        else:
            mask = mask[None, None, None]                    # (1,1,1,Sq,ck)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        o_new = o * alpha[..., None] + jnp.einsum(
            "bhgqc,bhcd->bhgqd", p, vci.astype(jnp.float32))
        return (m_new, l_new, o_new), None

    m0 = jnp.full((B, H, G, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, G, Sq), jnp.float32)
    o0 = jnp.zeros((B, H, G, Sq, D), jnp.float32)

    if block_causal and causal and Sq > 1:
        # q-block sweep: block i only scans kv chunks [0, hi_i] — skips the
        # fully-masked upper triangle (~2x less attention compute).
        bq = ck
        nq = -(-Sq // bq)
        outs = []
        for qi in range(nq):
            lo, hi = qi * bq, min((qi + 1) * bq, Sq)
            hi_chunk = min(nk, (q_offset + hi + ck - 1) // ck)
            sub_q = q[:, lo:hi]
            out = flash_attention(sub_q, k[:, :hi_chunk * ck],
                                  v[:, :hi_chunk * ck], causal=True,
                                  q_offset=q_offset + lo,
                                  kv_length=kv_length, chunk_k=ck,
                                  block_causal=False)
            outs.append(out)
        return jnp.concatenate(outs, axis=1)

    idxs = jnp.arange(nk)
    (m, l, o), _ = jax.lax.scan(chunk_step, (m0, l0, o0), (kc, vc, idxs))
    out = o / jnp.maximum(l, 1e-20)[..., None]
    return out.transpose(0, 3, 1, 2, 4).astype(q.dtype)      # (B,Sq,H,G,D)


# -- full layer ----------------------------------------------------------------

def _split_heads(x: jax.Array, n: int, d: int) -> jax.Array:
    return x.reshape(x.shape[:-1] + (n, d))


def _layout_q(q: jax.Array, lay: AttnLayout) -> jax.Array:
    """(B,S,n_q,D) -> (B,S,kv_eff,G',D) with group-preserving padding."""
    B, S, _, D = q.shape
    g = lay.n_q // lay.n_kv
    q = q.reshape(B, S, lay.n_kv, g, D)
    if lay.g_pad != g:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, 0), (0, lay.g_pad - g), (0, 0)))
    q = q.reshape(B, S, lay.n_kv, lay.kv_repeat, lay.g_eff, D)
    return q.reshape(B, S, lay.kv_eff, lay.g_eff, D)


def _unlayout_o(o: jax.Array, lay: AttnLayout) -> jax.Array:
    """(B,S,kv_eff,G',D) -> (B,S,n_q*D), dropping padded heads."""
    B, S = o.shape[:2]
    g = lay.n_q // lay.n_kv
    o = o.reshape(B, S, lay.n_kv, lay.g_pad, o.shape[-1])
    o = o[:, :, :, :g]
    return o.reshape(B, S, lay.n_q * o.shape[-1])


def _repeat_kv(kv: jax.Array, r: int) -> jax.Array:
    if r == 1:
        return kv
    return jnp.repeat(kv, r, axis=2)


class KVCache(NamedTuple):
    k: jax.Array      # (B, S_max, kv_eff, D) — or (B, S_max, n_kv, D)
    v: jax.Array      # when sequence-sharded (unrepeated heads)


def init_kv_cache(batch: int, max_len: int, lay: AttnLayout,
                  dtype=jnp.bfloat16, seqshard: bool = False) -> KVCache:
    heads = lay.n_kv if seqshard else lay.kv_eff
    shape = (batch, max_len, heads, lay.head_dim)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


def attention(params: Params, x: jax.Array, lay: AttnLayout, *,
              positions: jax.Array, rope_theta: float = 10000.0,
              causal: bool = True, mode: str = "train",
              cache: Optional[KVCache] = None,
              cache_pos: Optional[jax.Array] = None,
              kv_length: Optional[jax.Array] = None,
              cross_kv: Optional[Tuple[jax.Array, jax.Array]] = None,
              chunk_k: int = 1024, block_causal: bool = False,
              kv_seqshard: bool = False,
              ) -> Tuple[jax.Array, Optional[KVCache]]:
    """Self- or cross-attention over x (B, S, d_model).

    mode: "train"/"encoder" (no cache), "prefill" (writes cache),
    "decode" (S==1, reads+writes cache at cache_pos).
    kv_seqshard: serve caches hold UNREPEATED kv heads with the sequence
    axis sharded over the model axis (shard_map flash decode + logsumexp
    merge) instead of repeated heads sharded over model — 1/kv_repeat the
    cache HBM (see nn.decode_attn).
    Returns (out (B,S,d_model), new_cache_or_None).
    """
    B, S, _ = x.shape
    D = lay.head_dim
    q = _split_heads(dense(params["q_proj"], x), lay.n_q, D)
    q = shard(q, "batch", "seq", "heads", None)
    if cross_kv is None:
        k_raw = _split_heads(dense(params["k_proj"], x), lay.n_kv, D)
        v_raw = _split_heads(dense(params["v_proj"], x), lay.n_kv, D)
        cos, sin = rope_angles(positions, D, rope_theta)
        q = apply_rope(q, cos, sin)
        k_raw = apply_rope(k_raw, cos, sin)
        k = _repeat_kv(k_raw, lay.kv_repeat)
        v = _repeat_kv(v_raw, lay.kv_repeat)
    else:
        k, v = cross_kv                                 # already laid out
        k_raw = v_raw = None

    seqshard_mode = ("model" if kv_seqshard is True else kv_seqshard) or ""
    new_cache = None
    if mode == "decode" and seqshard_mode:
        from repro.nn.decode_attn import seqshard_flash_decode
        assert cache is not None and cache_pos is not None
        axes = (("data", "model") if seqshard_mode == "2d"
                else ("model",))
        o_full, k_cache, v_cache = seqshard_flash_decode(
            q, cache.k, cache.v, k_raw, v_raw, cache_pos,
            kv_length=kv_length, axes=axes)
        new_cache = KVCache(k_cache, v_cache)
        out = dense(params["o_proj"], o_full.reshape(B, S, lay.n_q * D))
        return shard(out, "batch", "seq", "embed"), new_cache
    if mode == "decode":
        assert cache is not None and cache_pos is not None
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.k, k.astype(cache.k.dtype), cache_pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            cache.v, v.astype(cache.v.dtype), cache_pos, axis=1)
        new_cache = KVCache(k_cache, v_cache)
        k_cache = shard(k_cache, "batch", "kv_len", "kv_heads", None)
        v_cache = shard(v_cache, "batch", "kv_len", "kv_heads", None)
        qL = _layout_q(q, lay)
        length = (kv_length if kv_length is not None
                  else jnp.full((B,), cache_pos + 1, jnp.int32))
        o = flash_attention(qL, k_cache, v_cache, causal=False,
                            kv_length=length, chunk_k=chunk_k)
    else:
        if mode == "prefill" and cross_kv is None:
            assert cache is not None
            k_w, v_w = (k_raw, v_raw) if seqshard_mode else (k, v)
            k_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.k, k_w.astype(cache.k.dtype), 0, axis=1)
            v_cache = jax.lax.dynamic_update_slice_in_dim(
                cache.v, v_w.astype(cache.v.dtype), 0, axis=1)
            new_cache = KVCache(k_cache, v_cache)
            if seqshard_mode:
                seq_ax = "kv_seq2" if seqshard_mode == "2d" else "kv_seq"
                new_cache = KVCache(
                    shard(new_cache.k, "batch", seq_ax, None, None),
                    shard(new_cache.v, "batch", seq_ax, None, None))
        k = shard(k, "batch", "seq", "kv_heads", None)
        v = shard(v, "batch", "seq", "kv_heads", None)
        qL = _layout_q(q, lay)
        o = flash_attention(qL, k, v, causal=causal and cross_kv is None,
                            kv_length=kv_length, chunk_k=chunk_k,
                            block_causal=block_causal)
    o = _unlayout_o(o, lay)
    o = shard(o, "batch", "seq", "qkv_dim")
    out = dense(params["o_proj"], o)
    return shard(out, "batch", "seq", "embed"), new_cache


def make_cross_kv(params: Params, enc_out: jax.Array, lay: AttnLayout,
                  ) -> Tuple[jax.Array, jax.Array]:
    """Precompute (and layout) encoder K/V for decoder cross-attention."""
    D = lay.head_dim
    k = _split_heads(dense(params["k_proj"], enc_out), lay.n_kv, D)
    v = _split_heads(dense(params["v_proj"], enc_out), lay.n_kv, D)
    return _repeat_kv(k, lay.kv_repeat), _repeat_kv(v, lay.kv_repeat)
