"""Model compositions: CausalLM (dense/MoE/SSM/hybrid/VLM-stub), EncDecLM,
and ConvNet (the paper's own CNN workloads on the TrIM conv path).

Pure-functional: a ``Model`` object holds only static structure (the config,
the derived StackSpec(s)); parameters/caches are explicit pytrees. ``tp`` is
the model-axis size of the target mesh — it determines the attention head
layout (kv repetition / group padding for TP > n_kv, see nn.attention).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.nn.attention import attn_layout
from repro.nn.blocks import (SlotSpec, StackSpec, init_stack,
                             init_stack_cache, run_stack)
from repro.nn.layers import (Params, embed_logits, embed_lookup,
                             init_embedding, init_lm_head, init_rmsnorm,
                             init_layernorm, layernorm, lm_head_logits,
                             rmsnorm)
from repro.nn.losses import chunked_softmax_xent, softmax_xent
from repro.nn.mamba import mamba_dims


def decoder_schedule(cfg: ModelConfig) -> Tuple[Tuple[SlotSpec, ...], int]:
    """Derive the (period slots, n_periods) schedule from the config."""
    def slot(i: int) -> SlotSpec:
        if cfg.family == "ssm":
            return SlotSpec("mamba", "none")
        if cfg.family == "hybrid":
            mixer = ("attn" if cfg.attn_every
                     and i % cfg.attn_every == cfg.attn_offset else "mamba")
        else:
            mixer = "attn"
        if cfg.n_experts and i % cfg.moe_every == cfg.moe_offset:
            ffn = "moe"
        else:
            ffn = "mlp" if cfg.family != "ssm" else "none"
        return SlotSpec(mixer, ffn)

    full = tuple(slot(i) for i in range(cfg.n_layers))
    # minimal period
    for period in range(1, cfg.n_layers + 1):
        if cfg.n_layers % period:
            continue
        if all(full[i] == full[i % period] for i in range(cfg.n_layers)):
            return full[:period], cfg.n_layers // period
    return full, 1


def _stack_spec(cfg: ModelConfig, slots, n_periods, *, tp: int,
                causal: bool = True, cross: bool = False) -> StackSpec:
    lay = (attn_layout(cfg.n_q, cfg.n_kv, cfg.head_dim, tp)
           if cfg.n_q else None)
    dims = (mamba_dims(cfg.d_model, expand=cfg.ssm_expand,
                       headdim=cfg.ssm_headdim, d_state=cfg.ssm_d_state,
                       n_groups=cfg.ssm_n_groups, d_conv=cfg.ssm_d_conv,
                       chunk=cfg.ssm_chunk)
            if cfg.family in ("ssm", "hybrid") else None)
    if cross:
        slots = tuple(SlotSpec(s.mixer, s.ffn, cross_attn=True)
                      for s in slots)
    return StackSpec(
        slots=slots, n_periods=n_periods, d_model=cfg.d_model,
        d_ff=cfg.d_ff, mlp_kind=cfg.mlp_kind, norm=cfg.norm, layout=lay,
        rope_theta=cfg.rope_theta, causal=causal, dims=dims,
        n_experts=cfg.n_experts, top_k=cfg.top_k,
        shared_expert=cfg.shared_expert, dense_residual=cfg.dense_residual,
        dense_ff=cfg.dense_ff, capacity_factor=cfg.capacity_factor,
        moe_impl=cfg.moe_impl, remat=cfg.remat, chunk_k=cfg.chunk_k,
        block_causal=cfg.block_causal, scan_layers=cfg.scan_layers,
        kv_seqshard=("model" if cfg.decode_kv_seqshard is True
                     else cfg.decode_kv_seqshard or ""),
        ssd_bf16=cfg.ssd_bf16)


def _final_norm_fns(cfg: ModelConfig):
    return ((init_rmsnorm, rmsnorm) if cfg.norm == "rmsnorm"
            else (init_layernorm, layernorm))


@dataclass(frozen=True)
class CausalLM:
    """Decoder-only LM; covers dense / moe / ssm / hybrid / vlm families."""

    cfg: ModelConfig
    tp: int = 1

    @property
    def spec(self) -> StackSpec:
        slots, n_periods = decoder_schedule(self.cfg)
        return _stack_spec(self.cfg, slots, n_periods, tp=self.tp)

    # -- params ------------------------------------------------------------
    def init(self, key) -> Params:
        cfg = self.cfg
        ke, ks, kh = jax.random.split(key, 3)
        init_norm, _ = _final_norm_fns(cfg)
        p: Params = {
            "embed": init_embedding(ke, cfg.vocab, cfg.d_model,
                                    pad_to=cfg.vocab_pad_to, dtype=cfg.dtype),
            "stack": init_stack(ks, self.spec, cfg.dtype),
            "final_norm": init_norm(cfg.d_model, cfg.dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = init_lm_head(kh, cfg.d_model, cfg.vocab,
                                        pad_to=cfg.vocab_pad_to,
                                        dtype=cfg.dtype)
        return p

    # -- shared pieces -------------------------------------------------------
    def _embed(self, params: Params, tokens: jax.Array,
               extra_embeds: Optional[jax.Array]) -> jax.Array:
        x = embed_lookup(params["embed"], tokens)
        if self.cfg.scale_embed:
            x = x * jnp.asarray(math.sqrt(self.cfg.d_model), x.dtype)
        if extra_embeds is not None:
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        return x

    def _logits(self, params: Params, x: jax.Array,
                keep_pad: bool = False) -> jax.Array:
        _, norm = _final_norm_fns(self.cfg)
        x = norm(params["final_norm"], x)
        if self.cfg.tie_embeddings:
            logits = embed_logits(params["embed"], x, self.cfg.vocab,
                                  keep_pad=keep_pad)
        else:
            logits = lm_head_logits(params["lm_head"], x, self.cfg.vocab,
                                    keep_pad=keep_pad)
        return shard(logits, "batch", "seq", "vocab")

    # -- train --------------------------------------------------------------
    def forward(self, params: Params, tokens: jax.Array,
                extra_embeds: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, jax.Array]:
        """tokens (B, S) -> (logits (B, S_total, vocab), moe_aux)."""
        x = self._embed(params, tokens, extra_embeds)
        x, _, aux = run_stack(params["stack"], x, self.spec, mode="train")
        return self._logits(params, x), aux

    def loss(self, params: Params, batch: Dict[str, jax.Array],
             aux_weight: float = 0.01) -> Tuple[jax.Array, Dict[str, Any]]:
        """Next-token CE over text positions. batch: tokens (B, S)
        [+ extra_embeds (B, S_img, d)]; loss positions are text-only.

        The CE runs on PADDED-vocab logits (pad entries masked to -inf):
        the padded width divides the TP axis so the (B, S, V) f32 tensor
        stays vocab-sharded for ragged vocabs (see embed_logits)."""
        tokens = batch["tokens"]
        extra = batch.get("extra_embeds")
        x = self._embed(params, tokens[:, :-1], extra)
        x, _, aux = run_stack(params["stack"], x, self.spec, mode="train")
        n_extra = 0 if extra is None else extra.shape[1]
        targets = tokens[:, 1:]
        if self.cfg.ce_impl == "chunked":
            _, norm = _final_norm_fns(self.cfg)
            h = norm(params["final_norm"], x)[:, n_extra:]
            table = (params["embed"]["table"] if self.cfg.tie_embeddings
                     else params["lm_head"]["kernel"])
            ce = chunked_softmax_xent(
                h, table, targets, self.cfg.vocab,
                transpose_readout=not self.cfg.tie_embeddings)
        else:
            logits = self._logits(params, x, keep_pad=True)
            ce = softmax_xent(logits[:, n_extra:], targets)
        total = ce + aux_weight * aux
        return total, {"ce": ce, "moe_aux": aux,
                       "ppl": jnp.exp(jnp.minimum(ce, 20.0))}

    # -- serve --------------------------------------------------------------
    def init_cache(self, batch: int, max_len: int,
                   dtype=jnp.bfloat16) -> Params:
        return init_stack_cache(self.spec, batch, max_len, dtype)

    def prefill(self, params: Params, tokens: jax.Array, cache: Params,
                extra_embeds: Optional[jax.Array] = None,
                lengths: Optional[jax.Array] = None,
                ) -> Tuple[jax.Array, Params]:
        """Returns (logits at the last position (B, vocab), cache)."""
        x = self._embed(params, tokens, extra_embeds)
        x, cache, _ = run_stack(params["stack"], x, self.spec,
                                mode="prefill", cache=cache)
        if lengths is None:
            last = x[:, -1:]
        else:
            idx = jnp.maximum(lengths - 1, 0)
            last = jnp.take_along_axis(
                x, idx[:, None, None].astype(jnp.int32), axis=1)
        return self._logits(params, last)[:, 0], cache

    def decode_step(self, params: Params, token: jax.Array, cache: Params,
                    pos: jax.Array, kv_length: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Params]:
        """token (B,) int32; pos scalar int32 (position being written).
        Returns (logits (B, vocab), new cache)."""
        x = self._embed(params, token[:, None], None)
        positions = jnp.broadcast_to(
            jnp.asarray(pos, jnp.int32)[None, None], x.shape[:2])
        x, cache, _ = run_stack(params["stack"], x, self.spec, mode="decode",
                                cache=cache, positions=positions,
                                cache_pos=pos, kv_length=kv_length)
        return self._logits(params, x)[:, 0], cache


@dataclass(frozen=True)
class EncDecLM:
    """Encoder-decoder LM (seamless-m4t): stub frontend supplies source
    frame embeddings (B, S_src, d); decoder is a causal token LM with
    per-layer cross-attention into the encoder output."""

    cfg: ModelConfig
    tp: int = 1

    @property
    def enc_spec(self) -> StackSpec:
        slots = (SlotSpec("attn", "mlp"),)
        return _stack_spec(self.cfg, slots, self.cfg.n_enc_layers, tp=self.tp,
                           causal=False)

    @property
    def dec_spec(self) -> StackSpec:
        slots = (SlotSpec("attn", "mlp"),)
        return _stack_spec(self.cfg, slots, self.cfg.n_layers, tp=self.tp,
                           causal=True, cross=True)

    def init(self, key) -> Params:
        cfg = self.cfg
        ke, k1, k2, kh = jax.random.split(key, 4)
        init_norm, _ = _final_norm_fns(cfg)
        p: Params = {
            "embed": init_embedding(ke, cfg.vocab, cfg.d_model,
                                    pad_to=cfg.vocab_pad_to, dtype=cfg.dtype),
            "encoder": init_stack(k1, self.enc_spec, cfg.dtype),
            "enc_norm": init_norm(cfg.d_model, cfg.dtype),
            "decoder": init_stack(k2, self.dec_spec, cfg.dtype),
            "final_norm": init_norm(cfg.d_model, cfg.dtype),
        }
        if not cfg.tie_embeddings:
            p["lm_head"] = init_lm_head(kh, cfg.d_model, cfg.vocab,
                                        pad_to=cfg.vocab_pad_to,
                                        dtype=cfg.dtype)
        return p

    def encode(self, params: Params, src_embeds: jax.Array) -> jax.Array:
        _, norm = _final_norm_fns(self.cfg)
        x, _, _ = run_stack(params["encoder"], src_embeds.astype(
            self.cfg.dtype), self.enc_spec, mode="encoder")
        return norm(params["enc_norm"], x)

    def _logits(self, params: Params, x: jax.Array,
                keep_pad: bool = False) -> jax.Array:
        _, norm = _final_norm_fns(self.cfg)
        x = norm(params["final_norm"], x)
        if self.cfg.tie_embeddings:
            return embed_logits(params["embed"], x, self.cfg.vocab,
                                keep_pad=keep_pad)
        return lm_head_logits(params["lm_head"], x, self.cfg.vocab,
                              keep_pad=keep_pad)

    def forward(self, params: Params, src_embeds: jax.Array,
                tgt_tokens: jax.Array) -> jax.Array:
        enc = self.encode(params, src_embeds)
        x = embed_lookup(params["embed"], tgt_tokens)
        x, _, _ = run_stack(params["decoder"], x, self.dec_spec,
                            mode="train", enc_out=enc)
        return self._logits(params, x)

    def loss(self, params: Params, batch: Dict[str, jax.Array],
             ) -> Tuple[jax.Array, Dict[str, Any]]:
        enc = self.encode(params, batch["src_embeds"])
        x = embed_lookup(params["embed"], batch["tokens"][:, :-1])
        x, _, _ = run_stack(params["decoder"], x, self.dec_spec,
                            mode="train", enc_out=enc)
        logits = self._logits(params, x, keep_pad=True)
        ce = softmax_xent(logits, batch["tokens"][:, 1:])
        return ce, {"ce": ce, "ppl": jnp.exp(jnp.minimum(ce, 20.0))}

    def init_cache(self, batch: int, max_len: int, cross_len: int,
                   dtype=jnp.bfloat16) -> Params:
        return init_stack_cache(self.dec_spec, batch, max_len, dtype,
                                cross_len=cross_len)

    def prefill(self, params: Params, src_embeds: jax.Array,
                tgt_tokens: jax.Array, cache: Params,
                ) -> Tuple[jax.Array, Params]:
        enc = self.encode(params, src_embeds)
        x = embed_lookup(params["embed"], tgt_tokens)
        x, cache, _ = run_stack(params["decoder"], x, self.dec_spec,
                                mode="prefill", cache=cache, enc_out=enc)
        return self._logits(params, x[:, -1:])[:, 0], cache

    def decode_step(self, params: Params, token: jax.Array, cache: Params,
                    pos: jax.Array, kv_length: Optional[jax.Array] = None,
                    ) -> Tuple[jax.Array, Params]:
        x = embed_lookup(params["embed"], token[:, None])
        positions = jnp.broadcast_to(
            jnp.asarray(pos, jnp.int32)[None, None], x.shape[:2])
        x, cache, _ = run_stack(params["decoder"], x, self.dec_spec,
                                mode="decode", cache=cache,
                                positions=positions, cache_pos=pos,
                                kv_length=kv_length)
        return self._logits(params, x)[:, 0], cache


@dataclass(frozen=True)
class ConvNet:
    """The paper's CNN workloads (VGG-16 / AlexNet) on the TrIM conv path.

    ``policy`` (an ``repro.engine.ExecutionPolicy``) decides *how* the
    network runs — substrate (compiled Pallas / oracle / interpret), the
    FPGA-faithful ``emulate_hw`` decimation replay, tiling, VMEM budget.
    The (cfg, policy) pair is compiled once into a ``ModelPlan``
    (``repro.engine.plan_model``, cached) and every entry point consumes
    the plan; with ``ExecutionPolicy(substrate="pallas")`` the custom VJP
    (DESIGN.md §6) runs the TrIM input-grad and weight-grad kernels even
    off-TPU — what the gradient-parity tests and CI's train-smoke lane
    assert.
    """

    cfg: "CNNConfig"
    policy: "ExecutionPolicy" = None  # None: ExecutionPolicy() defaults

    def _plan(self, c_in: Optional[int] = None):
        from repro.engine import ExecutionPolicy, plan_model
        pol = self.policy if self.policy is not None else ExecutionPolicy()
        return plan_model(self.cfg, pol, c_in=c_in)

    @property
    def plan(self):
        return self._plan()

    def init(self, key) -> Params:
        return self.plan.init(key)

    def forward(self, params: Params, images: jax.Array) -> jax.Array:
        # c_in from the actual input: grouped first layers (two-tower
        # inputs with C = groups * layer.M) plan their group count from it.
        return self._plan(int(images.shape[-1])).forward(params, images)

    def loss(self, params: Params, batch: Dict[str, jax.Array]):
        plan = self._plan(int(batch["images"].shape[-1]))
        return plan.loss(params, batch)

    def quantize(self, params: Params):
        return self.plan.quantize(params)

    def forward_int8(self, qparams: Params, images_u8: jax.Array,
                     requant_shifts=None, requant=None) -> jax.Array:
        plan = self._plan(int(images_u8.shape[-1]))
        return plan.forward_int8(qparams, images_u8,
                                 requant_shifts=requant_shifts,
                                 requant=requant)

    def calibrate(self, qparams: Params, sample_u8: jax.Array):
        plan = self._plan(int(sample_u8.shape[-1]))
        return plan.calibrate_requant_shifts(qparams, sample_u8)

    def calibrate_requant(self, qparams: Params, sample_u8: jax.Array,
                          per_channel: bool = True):
        """Arbitrary-scale (mult, shift) calibration — see repro.engine."""
        plan = self._plan(int(sample_u8.shape[-1]))
        return plan.calibrate_requant(qparams, sample_u8,
                                      per_channel=per_channel)


def build_model(cfg, tp: int = 1, emulate_hw: Optional[bool] = None,
                force_pallas: Optional[bool] = None, policy=None):
    """Build the model for ``cfg``.  For CNN configs, ``policy`` (an
    ``ExecutionPolicy``) selects the execution substrate; the legacy
    ``emulate_hw=`` / ``force_pallas=`` kwargs are deprecated shims onto
    it (``DeprecationWarning``)."""
    from repro.nn.conv import CNNConfig
    if isinstance(cfg, CNNConfig):
        from repro.engine import policy_from_legacy
        if emulate_hw is not None or force_pallas is not None:
            policy = policy_from_legacy(policy, emulate_hw=emulate_hw,
                                        force_pallas=force_pallas,
                                        caller="build_model")
        return ConvNet(cfg, policy=policy)
    if cfg.family == "encdec":
        return EncDecLM(cfg, tp)
    return CausalLM(cfg, tp)
