"""CNN path — the paper's own workload (VGG-16 / AlexNet) built on the TrIM
conv kernels.

Float mode (training + inference): NHWC convs through ``nn.blocks.conv_block``
(Pallas TrIM kernel on TPU / interpret validation, lax.conv oracle on CPU)
with the bias+ReLU epilogue fused into the kernel flush, max-pool, dense
classifier.

Integer mode (the paper's inference datapath): uint8 activations x int8
weights -> int32 psums, per-layer requantization — numerically identical to
the bit-faithful engine in ``repro.core.trim.engine`` (tests assert this),
but running through the TPU-native kernel.  With calibrated
``requant_shifts`` (power-of-two) or ``requant`` (arbitrary-scale
multiplier+shift pairs from ``calibrate_requant``, per-channel capable) the
ReLU+requant epilogue also fuses into the kernel, so int32 psums never
round-trip through HBM (DESIGN.md §2, §4).

``CNNConfig.emulate_hw`` / the ``emulate_hw=`` overrides select the
FPGA-faithful strided-layer schedule (stride-1 sweep + downstream
decimation, §V) for honest Table I/II comparisons.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.trim.model import (ALEXNET_LAYERS, VGG16_LAYERS,
                                   ConvLayerSpec)
from repro.kernels.ops import trim_conv2d
from repro.nn.blocks import ConvBlockSpec, conv_block, max_pool2x2
from repro.nn.layers import Params, _normal


@dataclass(frozen=True)
class CNNConfig:
    name: str
    layers: Tuple[ConvLayerSpec, ...]
    pool_after: Tuple[int, ...]          # indices (into layers) with 2x2 pool
    classifier: Tuple[int, ...]          # hidden dims of the FC head
    n_classes: int = 1000
    input_hw: Tuple[int, int] = (224, 224)
    emulate_hw: bool = False             # FPGA-faithful strided-layer path
    force_pallas: bool = False           # Pallas fwd + VJP even off-TPU


VGG16_CNN = CNNConfig(
    "vgg16", VGG16_LAYERS, pool_after=(1, 3, 6, 9, 12),
    classifier=(4096, 4096), input_hw=(224, 224))

ALEXNET_CNN = CNNConfig(
    "alexnet", ALEXNET_LAYERS, pool_after=(0, 1, 4),
    classifier=(4096, 4096), input_hw=(227, 227))


#: 2x2/stride-2 max pool (moved to nn.blocks; alias kept for callers)
_pool = max_pool2x2


def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32) -> Params:
    p: Params = {"conv": [], "fc": []}
    feat_hw = cfg.input_hw
    c_in = cfg.layers[0].M
    for i, l in enumerate(cfg.layers):
        key, k = jax.random.split(key)
        fan_in = l.K * l.K * l.M
        p["conv"].append({
            "kernel": _normal(k, (l.K, l.K, l.M, l.N), (2.0 / fan_in) ** 0.5,
                              dtype),
            "bias": jnp.zeros((l.N,), dtype)})
        feat_hw = (l.H_O, l.W_O)
        if i in cfg.pool_after:
            feat_hw = (feat_hw[0] // 2, feat_hw[1] // 2)
        c_in = l.N
    flat = feat_hw[0] * feat_hw[1] * c_in
    dims = (flat,) + cfg.classifier + (cfg.n_classes,)
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        p["fc"].append({
            "kernel": _normal(k, (dims[i], dims[i + 1]), dims[i] ** -0.5,
                              dtype),
            "bias": jnp.zeros((dims[i + 1],), dtype)})
    return p


def conv_block_specs(cfg: CNNConfig, c_in: Optional[int] = None,
                     ) -> Tuple[ConvBlockSpec, ...]:
    """Per-layer ConvBlockSpecs (fused bias/ReLU epilogue + pool schedule).

    ``c_in`` is the actual input channel count of the first layer's input
    (grouped AlexNet two-tower layers have running C = groups * layer.M)."""
    specs = []
    c = cfg.layers[0].M if c_in is None else c_in
    for i, l in enumerate(cfg.layers):
        specs.append(ConvBlockSpec(
            stride=l.stride, padding=l.padding, groups=c // l.M,
            relu=True, pool=i in cfg.pool_after,
            emulate_hw=cfg.emulate_hw, force_pallas=cfg.force_pallas))
        c = l.N
    return tuple(specs)


def cnn_forward(params: Params, images: jax.Array, cfg: CNNConfig,
                emulate_hw: Optional[bool] = None,
                force_pallas: Optional[bool] = None) -> jax.Array:
    """images (B, H, W, C) float -> logits (B, n_classes).

    Each conv layer runs as one fused conv_block (conv + bias + ReLU inside
    the kernel flush); ``emulate_hw`` (default: cfg.emulate_hw) opts into
    the FPGA's decimation schedule for strided layers.  ``force_pallas``
    (default: cfg.force_pallas) runs the Pallas kernels — forward and the
    custom-VJP backward pair — even off-TPU, so ``jax.grad`` of this
    forward exercises the TrIM kernel in both directions (DESIGN.md §6)."""
    x = images
    hw = cfg.emulate_hw if emulate_hw is None else emulate_hw
    fp = cfg.force_pallas if force_pallas is None else force_pallas
    if hw != cfg.emulate_hw or fp != cfg.force_pallas:
        cfg = dataclasses.replace(cfg, emulate_hw=hw, force_pallas=fp)
    specs = conv_block_specs(cfg, c_in=x.shape[-1])
    for i, spec in enumerate(specs):
        x = conv_block(params["conv"][i], x, spec)
    x = x.reshape(x.shape[0], -1)
    for j, fc in enumerate(params["fc"]):
        x = x @ fc["kernel"].astype(x.dtype) + fc["bias"].astype(x.dtype)
        if j < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x


def cnn_loss(params: Params, batch: Dict[str, jax.Array], cfg: CNNConfig,
             emulate_hw: Optional[bool] = None,
             force_pallas: Optional[bool] = None,
             ) -> Tuple[jax.Array, Dict[str, Any]]:
    logits = cnn_forward(params, batch["images"], cfg, emulate_hw=emulate_hw,
                         force_pallas=force_pallas)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    ce = -ll.mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return ce, {"ce": ce, "acc": acc}


# ---------------------------------------------------------------------------
# Integer (paper-faithful) inference datapath
# ---------------------------------------------------------------------------


def quantize_cnn(params: Params, cfg: CNNConfig,
                 ) -> Tuple[Params, List[float]]:
    """Float conv weights -> int8 (symmetric); returns (int params, scales)."""
    qp: Params = {"conv": []}
    scales: List[float] = []
    for i, l in enumerate(cfg.layers):
        w = params["conv"][i]["kernel"]
        amax = jnp.maximum(jnp.abs(w).max(), 1e-8)
        s = amax / 127.0
        qw = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
        qp["conv"].append({"kernel": qw})
        scales.append(float(s))
    return qp, scales


def _int8_forward(qparams: Params, images_u8: jax.Array, cfg: CNNConfig,
                  requant_shifts: Optional[Sequence[int]] = None,
                  requant: Optional[Sequence[Tuple[jax.Array, jax.Array]]]
                  = None,
                  ) -> Tuple[jax.Array, List[jax.Array]]:
    """Shared int8 datapath: returns (final int32 psums, dynamic shifts).

    ``requant_shifts`` fuses calibrated power-of-two shifts into the kernel;
    ``requant`` fuses calibrated arbitrary-scale (mult, shift) pairs
    (per-tensor scalars or per-channel (F,) arrays) instead.  The shifts
    list collects the per-layer power-of-two requant shifts actually used
    on the dynamic (uncalibrated) path — traced scalars, so calibration
    must run this eagerly to concretize them."""
    assert requant_shifts is None or requant is None
    x = images_u8
    shifts: List[jax.Array] = []
    for i, l in enumerate(cfg.layers):
        w = qparams["conv"][i]["kernel"]
        groups = x.shape[-1] // w.shape[-2]  # AlexNet two-tower layers: 2
        last = i == len(cfg.layers) - 1
        if requant is not None and not last:
            # Calibrated arbitrary scale: conv + ReLU + multiplier+shift
            # requant in one kernel pass (DESIGN.md §4).
            x = trim_conv2d(x, w, None, tuple(requant[i]), stride=l.stride,
                            padding=l.padding, groups=groups, relu=True,
                            emulate_hw=cfg.emulate_hw,
                            force_pallas=cfg.force_pallas)
        elif requant_shifts is not None and not last:
            # Calibrated shift: conv + ReLU + requant in one kernel pass.
            x = trim_conv2d(x, w, stride=l.stride, padding=l.padding,
                            groups=groups, relu=True,
                            requant_shift=int(requant_shifts[i]),
                            emulate_hw=cfg.emulate_hw,
                            force_pallas=cfg.force_pallas)
        else:
            psum = trim_conv2d(x, w, stride=l.stride, padding=l.padding,
                               groups=groups, relu=True,
                               emulate_hw=cfg.emulate_hw,
                               force_pallas=cfg.force_pallas)
            if last:
                return psum, shifts
            # power-of-two requantize back to uint8 for the next layer
            shift = jnp.maximum(
                jnp.ceil(jnp.log2(jnp.maximum(
                    psum.max().astype(jnp.float32), 1.0) / 255.0)), 0
            ).astype(jnp.int32)
            shifts.append(shift)
            x = jnp.clip(psum >> shift, 0, 255).astype(jnp.uint8)
        if i in cfg.pool_after:
            x = _pool(x)
    return x, shifts


def cnn_forward_int8(qparams: Params, images_u8: jax.Array, cfg: CNNConfig,
                     act_scales: Optional[Sequence[float]] = None,
                     requant_shifts: Optional[Sequence[int]] = None,
                     requant: Optional[Sequence[Tuple[jax.Array, jax.Array]]]
                     = None,
                     ) -> jax.Array:
    """uint8 NHWC images through the integer TrIM datapath.

    Each layer: uint8 x int8 -> int32 psums (exact), ReLU in int32 (fused
    into the kernel flush), then requantize to uint8 for the next layer.
    When ``requant_shifts`` supplies calibrated per-layer power-of-two
    shifts (what the paper's engine output stage does), or ``requant``
    supplies calibrated per-layer (mult, shift) fixed-point pairs
    (arbitrary scales, per-channel capable — ``calibrate_requant``), the
    whole epilogue fuses into the conv kernel and the int32 psums never
    reach HBM; otherwise the shift is derived from the running psum
    maximum (data-dependent, so it runs post-kernel).
    Returns the final int32 feature map (pre-classifier).
    """
    return _int8_forward(qparams, images_u8, cfg, requant_shifts,
                         requant)[0]


def calibrate_requant_shifts(qparams: Params, sample_u8: jax.Array,
                             cfg: CNNConfig) -> List[int]:
    """Derive static per-layer power-of-two requant shifts from a sample
    batch (the engine's offline output-stage calibration).  The returned
    shifts make ``cnn_forward_int8(..., requant_shifts=...)`` fully fused.
    Runs the dynamic datapath eagerly (not under jit) to concretize the
    per-layer shifts."""
    return [int(s) for s in _int8_forward(qparams, sample_u8, cfg)[1]]


def calibrate_requant(qparams: Params, sample_u8: jax.Array, cfg: CNNConfig,
                      per_channel: bool = True,
                      ) -> List[Tuple[jax.Array, jax.Array]]:
    """Arbitrary-scale calibration: per-layer (mult, shift) pairs.

    Generalizes ``calibrate_requant_shifts`` from power-of-two scales to
    15-bit-mantissa fixed-point scales (DESIGN.md §4): each non-last layer
    maps its observed post-ReLU psum range [0, amax] onto [0, 255] with
    ``scale = 255 / amax``, encoded as ``m * 2**-s`` via
    ``kernels.requant.scale_to_mult_shift``.  ``per_channel=True`` (the
    default) calibrates one scale per output channel — the headroom win
    arbitrary scales exist for.  Runs eagerly; the returned (F,) int32
    array pairs make ``cnn_forward_int8(..., requant=...)`` fully fused.
    """
    from repro.kernels.requant import (requant_mult_shift,
                                       scale_to_mult_shift)
    x = sample_u8
    pairs: List[Tuple[jax.Array, jax.Array]] = []
    for i, l in enumerate(cfg.layers[:-1]):
        w = qparams["conv"][i]["kernel"]
        groups = x.shape[-1] // w.shape[-2]
        psum = trim_conv2d(x, w, stride=l.stride, padding=l.padding,
                           groups=groups, relu=True,
                           emulate_hw=cfg.emulate_hw,
                           force_pallas=cfg.force_pallas)
        axes = (0, 1, 2) if per_channel else None
        amax = np.maximum(np.asarray(psum.max(axis=axes),
                                     np.float64), 1.0)
        m, s = scale_to_mult_shift(255.0 / amax)
        F = w.shape[-1]
        m = jnp.broadcast_to(jnp.asarray(m, jnp.int32), (F,))
        s = jnp.broadcast_to(jnp.asarray(s, jnp.int32), (F,))
        pairs.append((m, s))
        # Propagate through the exact fixed-point datapath the fused
        # forward will run, so downstream layers calibrate on what they
        # will actually see.
        x = requant_mult_shift(psum, m, s).astype(jnp.uint8)
        if i in cfg.pool_after:
            x = _pool(x)
    return pairs
