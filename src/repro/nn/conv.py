"""CNN path — the paper's own workload (VGG-16 / AlexNet) built on the TrIM
conv kernels.

Float mode (training + inference): NHWC convs through ``ops.trim_conv2d``
(Pallas TrIM kernel on TPU / interpret validation, lax.conv oracle on CPU),
ReLU, max-pool, dense classifier.

Integer mode (the paper's inference datapath): uint8 activations x int8
weights -> int32 psums, per-layer requantization — numerically identical to
the bit-faithful engine in ``repro.core.trim.engine`` (tests assert this),
but running through the TPU-native kernel.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.trim.model import (ALEXNET_LAYERS, VGG16_LAYERS,
                                   ConvLayerSpec)
from repro.distributed.sharding import shard
from repro.kernels.ops import trim_conv2d
from repro.nn.layers import Params, _normal


@dataclass(frozen=True)
class CNNConfig:
    name: str
    layers: Tuple[ConvLayerSpec, ...]
    pool_after: Tuple[int, ...]          # indices (into layers) with 2x2 pool
    classifier: Tuple[int, ...]          # hidden dims of the FC head
    n_classes: int = 1000
    input_hw: Tuple[int, int] = (224, 224)


VGG16_CNN = CNNConfig(
    "vgg16", VGG16_LAYERS, pool_after=(1, 3, 6, 9, 12),
    classifier=(4096, 4096), input_hw=(224, 224))

ALEXNET_CNN = CNNConfig(
    "alexnet", ALEXNET_LAYERS, pool_after=(0, 1, 4),
    classifier=(4096, 4096), input_hw=(227, 227))


def _pool(x: jax.Array, window: int = 2, stride: int = 2) -> jax.Array:
    """2x2/stride-2 max pool via reshape+max (VALID). Equivalent to
    reduce_window but robustly reverse-differentiable under nested jit."""
    assert window == 2 and stride == 2
    B, H, W, C = x.shape
    x = x[:, : H // 2 * 2, : W // 2 * 2]
    x = x.reshape(B, H // 2, 2, W // 2, 2, C)
    return x.max(axis=(2, 4))


def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32) -> Params:
    p: Params = {"conv": [], "fc": []}
    feat_hw = cfg.input_hw
    c_in = cfg.layers[0].M
    for i, l in enumerate(cfg.layers):
        key, k = jax.random.split(key)
        fan_in = l.K * l.K * l.M
        p["conv"].append({
            "kernel": _normal(k, (l.K, l.K, l.M, l.N), (2.0 / fan_in) ** 0.5,
                              dtype),
            "bias": jnp.zeros((l.N,), dtype)})
        feat_hw = (l.H_O, l.W_O)
        if i in cfg.pool_after:
            feat_hw = (feat_hw[0] // 2, feat_hw[1] // 2)
        c_in = l.N
    flat = feat_hw[0] * feat_hw[1] * c_in
    dims = (flat,) + cfg.classifier + (cfg.n_classes,)
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        p["fc"].append({
            "kernel": _normal(k, (dims[i], dims[i + 1]), dims[i] ** -0.5,
                              dtype),
            "bias": jnp.zeros((dims[i + 1],), dtype)})
    return p


def cnn_forward(params: Params, images: jax.Array, cfg: CNNConfig,
                ) -> jax.Array:
    """images (B, H, W, C) float -> logits (B, n_classes)."""
    x = images
    for i, l in enumerate(cfg.layers):
        w = params["conv"][i]["kernel"].astype(x.dtype)
        groups = x.shape[-1] // l.M     # AlexNet two-tower layers: 2
        x = trim_conv2d(x, w, stride=l.stride, padding=l.padding,
                        groups=groups)
        x = x + params["conv"][i]["bias"].astype(x.dtype)
        x = jax.nn.relu(x)
        x = shard(x, "batch", "img_h", "img_w", "cout")
        if i in cfg.pool_after:
            x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    for j, fc in enumerate(params["fc"]):
        x = x @ fc["kernel"].astype(x.dtype) + fc["bias"].astype(x.dtype)
        if j < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x


def cnn_loss(params: Params, batch: Dict[str, jax.Array], cfg: CNNConfig,
             ) -> Tuple[jax.Array, Dict[str, Any]]:
    logits = cnn_forward(params, batch["images"], cfg)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    ce = -ll.mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return ce, {"ce": ce, "acc": acc}


# ---------------------------------------------------------------------------
# Integer (paper-faithful) inference datapath
# ---------------------------------------------------------------------------


def quantize_cnn(params: Params, cfg: CNNConfig,
                 ) -> Tuple[Params, List[float]]:
    """Float conv weights -> int8 (symmetric); returns (int params, scales)."""
    qp: Params = {"conv": []}
    scales: List[float] = []
    for i, l in enumerate(cfg.layers):
        w = params["conv"][i]["kernel"]
        amax = jnp.maximum(jnp.abs(w).max(), 1e-8)
        s = amax / 127.0
        qw = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
        qp["conv"].append({"kernel": qw})
        scales.append(float(s))
    return qp, scales


def cnn_forward_int8(qparams: Params, images_u8: jax.Array, cfg: CNNConfig,
                     act_scales: Optional[Sequence[float]] = None,
                     ) -> jax.Array:
    """uint8 NHWC images through the integer TrIM datapath.

    Each layer: uint8 x int8 -> int32 psums (exact), ReLU in int32, then
    requantize to uint8 with a per-layer right-shift scale (power-of-two
    requantization — what the paper's engine output stage does).
    Returns the final int32 feature map (pre-classifier).
    """
    x = images_u8
    for i, l in enumerate(cfg.layers):
        w = qparams["conv"][i]["kernel"]
        psum = trim_conv2d(x, w, stride=l.stride, padding=l.padding)
        psum = jax.nn.relu(psum)                      # int32 relu
        if i < len(cfg.layers) - 1:
            # power-of-two requantize back to uint8 for the next layer
            shift = jnp.maximum(
                jnp.ceil(jnp.log2(jnp.maximum(
                    psum.max().astype(jnp.float32), 1.0) / 255.0)), 0
            ).astype(jnp.int32)
            x = jnp.clip(psum >> shift, 0, 255).astype(jnp.uint8)
        else:
            return psum
        if i in cfg.pool_after:
            x = _pool(x)
    return x
