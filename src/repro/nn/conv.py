"""CNN path — the paper's own workload (VGG-16 / AlexNet) built on the TrIM
conv kernels, executed through ``repro.engine`` plans.

``CNNConfig`` is pure architecture (layers, pools, classifier head).  *How*
the network runs — substrate, ``emulate_hw`` decimation replay, tiling,
requant fusion — is an :class:`repro.engine.ExecutionPolicy`, compiled once
per (config, policy) into a :class:`repro.engine.ModelPlan` whose per-layer
:class:`repro.engine.ConvLayerPlan` schedules drive the one kernel dispatch
site (DESIGN.md §3).

The public functions here (``cnn_forward``, ``cnn_loss``,
``cnn_forward_int8``, ``calibrate_requant*``) keep their historical
signatures as thin shims over the plan entry points; the legacy
``emulate_hw=`` / ``force_pallas=`` kwargs still work but emit
``DeprecationWarning`` — pass ``policy=ExecutionPolicy(...)`` instead.

Float mode (training + inference): NHWC convs with the bias+ReLU epilogue
fused into the kernel flush, max-pool, dense classifier.  Integer mode (the
paper's inference datapath): uint8 activations x int8 weights -> int32
psums, per-layer requantization — numerically identical to the bit-faithful
engine in ``repro.core.trim.engine`` (tests assert this); calibrated
``requant_shifts`` (power-of-two) or ``requant`` (arbitrary-scale
multiplier+shift, per-channel capable) fuse the whole epilogue into the
kernel so int32 psums never round-trip through HBM (DESIGN.md §2, §4).
``quantize_cnn_int5`` compresses the int8 weights further to the 5-bit
MSR lane (sign + 4-bit most-significant-run codes with expect-value
compensation; DESIGN.md §9.3) consumed by ``ModelPlan.forward_int5``.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core.trim.model import (ALEXNET_LAYERS, VGG16_LAYERS,
                                   ConvLayerSpec)
from repro.engine import ExecutionPolicy, plan_model, policy_from_legacy
from repro.nn.blocks import ConvBlockSpec, max_pool2x2  # noqa: F401
from repro.nn.layers import Params, _normal


@dataclass(frozen=True)
class CNNConfig:
    """Pure architecture: what to run (execution policy rides separately)."""
    name: str
    layers: Tuple[ConvLayerSpec, ...]
    pool_after: Tuple[int, ...]          # indices (into layers) with 2x2 pool
    classifier: Tuple[int, ...]          # hidden dims of the FC head
    n_classes: int = 1000
    input_hw: Tuple[int, int] = (224, 224)


VGG16_CNN = CNNConfig(
    "vgg16", VGG16_LAYERS, pool_after=(1, 3, 6, 9, 12),
    classifier=(4096, 4096), input_hw=(224, 224))

ALEXNET_CNN = CNNConfig(
    "alexnet", ALEXNET_LAYERS, pool_after=(0, 1, 4),
    classifier=(4096, 4096), input_hw=(227, 227))


def init_cnn(key, cfg: CNNConfig, dtype=jnp.float32) -> Params:
    p: Params = {"conv": [], "fc": []}
    feat_hw = cfg.input_hw
    c_in = cfg.layers[0].M
    for i, l in enumerate(cfg.layers):
        key, k = jax.random.split(key)
        fan_in = l.K * l.K * l.M
        p["conv"].append({
            "kernel": _normal(k, (l.K, l.K, l.M, l.N), (2.0 / fan_in) ** 0.5,
                              dtype),
            "bias": jnp.zeros((l.N,), dtype)})
        feat_hw = (l.H_O, l.W_O)
        if i in cfg.pool_after:
            feat_hw = (feat_hw[0] // 2, feat_hw[1] // 2)
        c_in = l.N
    flat = feat_hw[0] * feat_hw[1] * c_in
    dims = (flat,) + cfg.classifier + (cfg.n_classes,)
    for i in range(len(dims) - 1):
        key, k = jax.random.split(key)
        p["fc"].append({
            "kernel": _normal(k, (dims[i], dims[i + 1]), dims[i] ** -0.5,
                              dtype),
            "bias": jnp.zeros((dims[i + 1],), dtype)})
    return p


def conv_block_specs(cfg: CNNConfig, c_in: Optional[int] = None,
                     ) -> Tuple[ConvBlockSpec, ...]:
    """Per-layer architectural ConvBlockSpecs (stride/groups/pool schedule).

    ``c_in`` is the actual input channel count of the first layer's input
    (grouped AlexNet two-tower layers have running C = groups * layer.M).
    Execution choices live in the ``ConvLayerPlan``s of ``plan_model``."""
    specs = []
    c = cfg.layers[0].M if c_in is None else c_in
    for i, l in enumerate(cfg.layers):
        specs.append(ConvBlockSpec(
            stride=l.stride, padding=l.padding, groups=c // l.M,
            relu=True, pool=i in cfg.pool_after))
        c = l.N
    return tuple(specs)


def _plan(cfg: CNNConfig, policy: Optional[ExecutionPolicy],
          emulate_hw: Optional[bool], force_pallas: Optional[bool],
          caller: str, c_in: Optional[int] = None):
    pol = policy_from_legacy(policy, emulate_hw=emulate_hw,
                             force_pallas=force_pallas, caller=caller)
    return plan_model(cfg, pol, c_in=c_in)


def cnn_forward(params: Params, images: jax.Array, cfg: CNNConfig,
                emulate_hw: Optional[bool] = None,
                force_pallas: Optional[bool] = None,
                policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """images (B, H, W, C) float -> logits (B, n_classes).

    Each conv layer runs as one planned fused block (conv + bias + ReLU
    inside the kernel flush).  ``policy`` selects the substrate /
    ``emulate_hw`` replay; the ``emulate_hw=`` / ``force_pallas=`` kwargs
    are deprecated shims onto it."""
    plan = _plan(cfg, policy, emulate_hw, force_pallas, "cnn_forward",
                 c_in=int(images.shape[-1]))
    return plan.forward(params, images)


def cnn_loss(params: Params, batch, cfg: CNNConfig,
             emulate_hw: Optional[bool] = None,
             force_pallas: Optional[bool] = None,
             policy: Optional[ExecutionPolicy] = None):
    plan = _plan(cfg, policy, emulate_hw, force_pallas, "cnn_loss",
                 c_in=int(batch["images"].shape[-1]))
    return plan.loss(params, batch)


# ---------------------------------------------------------------------------
# Integer (paper-faithful) inference datapath
# ---------------------------------------------------------------------------


def quantize_cnn(params: Params, cfg: CNNConfig,
                 ) -> Tuple[Params, List[float]]:
    """Float conv weights -> int8 (symmetric); returns (int params, scales)."""
    qp: Params = {"conv": []}
    scales: List[float] = []
    for i, l in enumerate(cfg.layers):
        w = params["conv"][i]["kernel"]
        amax = jnp.maximum(jnp.abs(w).max(), 1e-8)
        s = amax / 127.0
        qw = jnp.clip(jnp.round(w / s), -127, 127).astype(jnp.int8)
        qp["conv"].append({"kernel": qw})
        scales.append(float(s))
    return qp, scales


def quantize_cnn_int5(params: Params, cfg: CNNConfig, compensate: bool = True,
                      ) -> Tuple[Params, List[float]]:
    """Float conv weights -> the MSR-compressed int5 lane's runtime params.

    Quantizes to int8 exactly like :func:`quantize_cnn`, then compresses
    each kernel to sign + 4-bit most-significant-run codes with one shared
    shift per output channel (``core.trim.quant.msr_compress`` —
    DESIGN.md §9.3).  Each returned conv entry carries the *decompressed
    runtime operand pair*:

    - ``"kernel"``: int8 operand ``w5`` with ``|w5| <= 31`` (the
      expect-value compensation bit already folded in when
      ``compensate=True``; plain truncation otherwise — the ablation);
    - ``"shift"``: per-output-channel int32 exponent ``e``, with the
      decompressed weight ``w_hat == w5 << e`` exactly.

    The 5-bit packed storage form is ``quant.pack_int5(codes)`` — what a
    weight DMA would ship; ``forward_int5`` consumes the operand pair.
    Returns ``(qparams5, scales)`` with the same per-layer float scales as
    the int8 lane (MSR reuses them — the codes approximate the int8
    integers, not the floats).
    """
    import numpy as np

    from repro.core.trim.quant import msr_compress, msr_operand

    qp8, scales = quantize_cnn(params, cfg)
    qp: Params = {"conv": []}
    for entry in qp8["conv"]:
        codes, shifts = msr_compress(np.asarray(entry["kernel"]))
        w5, e = msr_operand(codes, shifts, compensate=compensate)
        qp["conv"].append({"kernel": jnp.asarray(w5),
                           "shift": jnp.asarray(e, jnp.int32)})
    return qp, scales


def cnn_forward_int8(qparams: Params, images_u8: jax.Array, cfg: CNNConfig,
                     act_scales: Optional[Sequence[float]] = None,
                     requant_shifts: Optional[Sequence[int]] = None,
                     requant: Optional[Sequence[Tuple[jax.Array, jax.Array]]]
                     = None,
                     emulate_hw: Optional[bool] = None,
                     force_pallas: Optional[bool] = None,
                     policy: Optional[ExecutionPolicy] = None) -> jax.Array:
    """uint8 NHWC images through the planned integer TrIM datapath
    (``repro.engine.execute.forward_int8``); returns the final int32
    feature map (pre-classifier)."""
    plan = _plan(cfg, policy, emulate_hw, force_pallas, "cnn_forward_int8",
                 c_in=int(images_u8.shape[-1]))
    return plan.forward_int8(qparams, images_u8,
                             requant_shifts=requant_shifts, requant=requant)


def calibrate_requant_shifts(qparams: Params, sample_u8: jax.Array,
                             cfg: CNNConfig,
                             emulate_hw: Optional[bool] = None,
                             force_pallas: Optional[bool] = None,
                             policy: Optional[ExecutionPolicy] = None,
                             ) -> List[int]:
    """Static per-layer power-of-two requant shifts from a sample batch
    (the engine's offline output-stage calibration)."""
    plan = _plan(cfg, policy, emulate_hw, force_pallas,
                 "calibrate_requant_shifts", c_in=int(sample_u8.shape[-1]))
    return plan.calibrate_requant_shifts(qparams, sample_u8)


def calibrate_requant(qparams: Params, sample_u8: jax.Array, cfg: CNNConfig,
                      per_channel: bool = True,
                      emulate_hw: Optional[bool] = None,
                      force_pallas: Optional[bool] = None,
                      policy: Optional[ExecutionPolicy] = None,
                      ) -> List[Tuple[jax.Array, jax.Array]]:
    """Arbitrary-scale calibration: per-layer (mult, shift) pairs
    (per-channel capable — see ``repro.engine.execute.calibrate_requant``)."""
    plan = _plan(cfg, policy, emulate_hw, force_pallas, "calibrate_requant",
                 c_in=int(sample_u8.shape[-1]))
    return plan.calibrate_requant(qparams, sample_u8,
                                  per_channel=per_channel)
