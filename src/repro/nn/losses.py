"""Cross-entropy losses, including the vocab-chunked variant (§Perf).

``softmax_xent``: standard f32 log-softmax CE on (possibly padded-vocab,
-inf-masked) logits.

``chunked_softmax_xent``: never materializes the full (B, S, V) f32 logits.
The logsumexp is accumulated over vocab chunks with a lax.scan (running
(m, l) like flash attention — TrIM's psum-accumulation idea applied to the
vocab axis) and each chunk's logits are recomputed in the backward pass
(jax.checkpoint on the chunk matmul). HBM traffic for the loss drops from
~3x B*S*V*4 bytes to ~B*S*V*2 (one bf16 pass) + O(B*S) statistics.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp


def softmax_xent(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """logits (B, S, V) any float; targets (B, S) int. Mean CE, f32."""
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -ll.mean()


def chunked_softmax_xent(x: jax.Array, readout: jax.Array,
                         targets: jax.Array, vocab: int,
                         chunk: int = 8192,
                         transpose_readout: bool = False) -> jax.Array:
    """CE without materializing full logits.

    x (B, S, d) hidden states; readout (Vpad, d) (tied embedding table) or
    (d, Vpad) with transpose_readout=True; targets (B, S) < vocab.
    """
    if transpose_readout:
        readout = readout.T
    vpad, d = readout.shape
    nc = -(-vpad // chunk)
    pad = nc * chunk - vpad
    table = jnp.pad(readout, ((0, pad), (0, 0)))
    table_c = table.reshape(nc, chunk, d)
    xf = x

    def chunk_fn(carry, inp):
        m, l, tgt_logit = carry
        tab, ci = inp

        def logits_of(tab):
            lg = jnp.einsum("bsd,vd->bsv", xf, tab.astype(xf.dtype),
                            preferred_element_type=jnp.float32)
            base = ci * chunk
            valid = (base + jnp.arange(chunk)) < vocab
            return jnp.where(valid, lg, -1e30)

        lg = jax.checkpoint(logits_of)(tab)               # recompute in bwd
        m_new = jnp.maximum(m, lg.max(-1))
        l = l * jnp.exp(m - m_new) + jnp.exp(
            lg - m_new[..., None]).sum(-1)
        # pick up the target logit if it lives in this chunk
        local = targets - ci * chunk
        in_chunk = (local >= 0) & (local < chunk)
        picked = jnp.take_along_axis(
            lg, jnp.clip(local, 0, chunk - 1)[..., None], axis=-1)[..., 0]
        tgt_logit = jnp.where(in_chunk, picked, tgt_logit)
        return (m_new, l, tgt_logit), None

    B, S = targets.shape
    m0 = jnp.full((B, S), -1e30, jnp.float32)
    l0 = jnp.zeros((B, S), jnp.float32)
    t0 = jnp.full((B, S), -1e30, jnp.float32)
    (m, l, tgt), _ = jax.lax.scan(chunk_fn, (m0, l0, t0),
                                  (table_c, jnp.arange(nc)))
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    return (lse - tgt).mean()
