"""Mixture-of-Experts: top-k router + GShard-style capacity dispatch.

Dispatch/combine are expressed as one-hot einsums over a (tokens, experts,
capacity) routing tensor, with experts sharded over the "model" mesh axis
and tokens over the data axes — the SPMD partitioner lowers the dispatch
and return einsums to all-to-all collectives (visible in the §Roofline
collective term). Over-capacity tokens are dropped (standard GShard
behaviour; the residual connection carries them through unchanged).

Variants required by the assigned architectures:
- plain top-k (arctic top-2, jamba top-2, llama4 top-1);
- ``shared_expert``: a dense expert added to every token (llama4);
- ``dense_residual``: a full dense-MLP branch in parallel (arctic).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.nn.layers import Params, _normal, init_mlp, mlp


def init_moe(key, d: int, ff: int, n_experts: int, *, mlp_kind: str = "swiglu",
             shared_expert: bool = False, dense_residual: bool = False,
             dense_ff: Optional[int] = None, dtype=jnp.float32) -> Params:
    kr, kg, ku, kd, ks, kdr = jax.random.split(key, 6)
    p: Params = {
        "router": {"kernel": _normal(kr, (d, n_experts), d ** -0.5, dtype)},
        "experts": {
            "w_gate": _normal(kg, (n_experts, d, ff), d ** -0.5, dtype),
            "w_up": _normal(ku, (n_experts, d, ff), d ** -0.5, dtype),
            "w_down": _normal(kd, (n_experts, ff, d), ff ** -0.5, dtype),
        },
    }
    if shared_expert:
        p["shared_expert"] = init_mlp(ks, d, ff, mlp_kind, dtype)
    if dense_residual:
        p["dense_mlp"] = init_mlp(kdr, d, dense_ff or ff, mlp_kind, dtype)
    return p


def _topk_dispatch(gates: jax.Array, k: int, capacity: int
                   ) -> Tuple[jax.Array, jax.Array]:
    """gates (B, S, E) probs -> dispatch (B,S,E,C) bool-ish, combine (B,S,E,C).

    Iterative top-k with positional capacity assignment (GShard)."""
    B, S, E = gates.shape
    remaining = gates
    dispatch = jnp.zeros((B, S, E, capacity), gates.dtype)
    combine = jnp.zeros((B, S, E, capacity), gates.dtype)
    # track per-expert fill across the k rounds
    fill = jnp.zeros((B, E), jnp.int32)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)                      # (B, S)
        onehot = jax.nn.one_hot(idx, E, dtype=gates.dtype)        # (B, S, E)
        gate_val = (remaining * onehot).sum(-1)                   # (B, S)
        # position of each token in its expert's queue this round
        pos = (jnp.cumsum(onehot, axis=1) - onehot) + fill[:, None, :]
        pos_tok = (pos * onehot).sum(-1).astype(jnp.int32)        # (B, S)
        keep = pos_tok < capacity
        cap_oh = jax.nn.one_hot(pos_tok, capacity, dtype=gates.dtype)
        d_k = (onehot[..., None] * cap_oh[..., None, :]
               * keep[..., None, None].astype(gates.dtype))
        dispatch = dispatch + d_k
        combine = combine + d_k * gate_val[..., None, None]
        fill = fill + onehot.sum(axis=1).astype(jnp.int32)
        remaining = remaining * (1.0 - onehot)
    return dispatch, combine


def _gather_dispatch_moe(params: Params, x: jax.Array, probs: jax.Array, *,
                         top_k: int, capacity: int, mlp_kind: str,
                         renorm: bool) -> jax.Array:
    """Sort/gather-based dispatch (no (B,S,E,C) one-hot tensor).

    FLOP cost is E*cap*3*d*ff*2 = tokens*k*cf*(expert MLP) — only the
    capacity-factor overhead vs ideal, unlike the einsum dispatch whose
    routing einsums alone cost O(B*S^2*k*cf*d). Routing is a per-row stable
    sort (GShard priority = position), expressible in pure jnp and
    batch-partitionable with no cross-row collectives.
    """
    B, S, d = x.shape
    E = probs.shape[-1]
    gate_vals, experts = jax.lax.top_k(probs, top_k)          # (B, S, k)
    if renorm:
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)
    Tk = S * top_k
    # rounds-major flattening (j = round*S + s): GShard priority — round-0
    # assignments claim capacity before round-1, positional order within.
    expert_flat = experts.transpose(0, 2, 1).reshape(B, Tk)   # (B, Tk)
    gates_flat = gate_vals.transpose(0, 2, 1).reshape(B, Tk)
    order = jnp.argsort(expert_flat, axis=1, stable=True)     # (B, Tk)
    sorted_exp = jnp.take_along_axis(expert_flat, order, axis=1)
    tok_idx = order % S                                       # source token
    # rank of each kept slot within its expert queue
    counts = jax.vmap(lambda e: jnp.bincount(e, length=E))(sorted_exp)
    starts = jnp.cumsum(counts, axis=1) - counts              # (B, E)
    rank = (jnp.arange(Tk)[None, :]
            - jnp.take_along_axis(starts, sorted_exp, axis=1))
    keep = rank < capacity
    slot = jnp.where(keep, sorted_exp * capacity + rank, E * capacity)
    # dispatch by INDEX GATHER, not data scatter: build the tiny int32
    # slot->token map first (B, E*cap), then gather rows of x. The gather
    # is local under batch-sharding (x is replicated over the model axis
    # at layer entry), so GSPMD emits NO collective for the dispatch —
    # a data scatter here forces a replicated (B, E*cap, d) buffer and a
    # full-size all-gather (the dominant collective of the MoE baseline).
    slot_tok = jnp.full((B, E * capacity + 1), S, jnp.int32)
    slot_tok = slot_tok.at[jnp.arange(B)[:, None], slot].set(
        tok_idx.astype(jnp.int32), mode="drop")
    slot_tok = slot_tok[:, :-1]
    x_pad = jnp.pad(x, ((0, 0), (0, 1), (0, 0)))              # zero row @ S
    xin = jnp.take_along_axis(x_pad, slot_tok[..., None], axis=1)
    xin = xin.reshape(B, E, capacity, d)
    xin = shard(xin.transpose(1, 0, 2, 3), "experts", "batch", None, "embed")
    # expert MLPs (E sharded over "model")
    w = params["experts"]
    g = jnp.einsum("ebcd,edf->ebcf", xin, w["w_gate"].astype(x.dtype))
    u = jnp.einsum("ebcd,edf->ebcf", xin, w["w_up"].astype(x.dtype))
    g = shard(g, "experts", "batch", None, "ff")
    act = jax.nn.silu(g) if mlp_kind == "swiglu" else jax.nn.gelu(g)
    eout = jnp.einsum("ebcf,efd->ebcd", act * u,
                      w["w_down"].astype(x.dtype))
    eout = shard(eout, "experts", "batch", None, "embed")
    eout = eout.transpose(1, 0, 2, 3).reshape(B, E * capacity, d)
    eout = jnp.pad(eout, ((0, 0), (0, 1), (0, 0)))            # drop slot
    # combine: gather back and weight by (sorted) gates
    ys = jnp.take_along_axis(eout, slot[..., None], axis=1)   # (B, Tk, d)
    gs = jnp.take_along_axis(gates_flat, order, axis=1)
    ys = ys * jnp.where(keep, gs, 0.0)[..., None].astype(x.dtype)
    out = jnp.zeros((B, S, d), x.dtype)
    out = out.at[jnp.arange(B)[:, None], tok_idx].add(ys)
    return out


def moe(params: Params, x: jax.Array, *, top_k: int, mlp_kind: str = "swiglu",
        capacity_factor: float = 1.25, router_softmax_topk: bool = True,
        impl: str = "einsum") -> Tuple[jax.Array, jax.Array]:
    """x (B, S, d) -> (out (B, S, d), aux_loss scalar).

    impl="einsum": GShard one-hot dispatch (reference; dispatch tensor
    (B, S, E, C)). impl="gather": sort/gather dispatch (production default —
    no S^2-scaling routing FLOPs; tests assert it matches einsum whenever
    per-expert queues are within capacity).

    The batch dim doubles as the GShard token-group dim (tokens compete for
    capacity within their own batch row), so dispatch tensors stay modest:
    (B, S, E, C) with C = ceil(S * k * cf / E).
    """
    B, S, d = x.shape
    E = params["router"]["kernel"].shape[-1]
    capacity = max(1, int(S * top_k * capacity_factor / E))

    logits = jnp.einsum("bsd,de->bse", x.astype(jnp.float32),
                        params["router"]["kernel"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)

    if impl == "gather":
        out = _gather_dispatch_moe(params, x, probs, top_k=top_k,
                                   capacity=capacity, mlp_kind=mlp_kind,
                                   renorm=router_softmax_topk)
        # aux loss from router stats (fraction routed ~ top-1 assignment)
        me = probs.mean(axis=(0, 1))
        top1 = jnp.argmax(probs, axis=-1)
        ce = jnp.zeros((E,), jnp.float32).at[top1.reshape(-1)].add(
            1.0 / top1.size)
        aux = E * jnp.sum(me * ce)
        if "shared_expert" in params:
            out = out + mlp(params["shared_expert"], x, mlp_kind)
        if "dense_mlp" in params:
            out = out + mlp(params["dense_mlp"], x, mlp_kind)
        return out, aux

    probs_d = probs
    if router_softmax_topk:
        # renormalize by the top-k mass BEFORE capacity dropping (t5x
        # semantics; per-token positive scaling keeps the argmax order)
        mass = jax.lax.top_k(probs, top_k)[0].sum(-1, keepdims=True)
        probs_d = probs / jnp.maximum(mass, 1e-9)
    dispatch, combine = _topk_dispatch(probs_d, top_k, capacity)
    dispatch = dispatch.astype(x.dtype)
    combine = combine.astype(x.dtype)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    ce = dispatch.sum(axis=3).mean(axis=(0, 1))
    aux = E * jnp.sum(me * ce)

    # dispatch: (B,S,E,C) x (B,S,d) -> (E, B, C, d); experts sharded
    xin = jnp.einsum("bsec,bsd->ebcd", dispatch, x)
    xin = shard(xin, "experts", "batch", None, "embed")
    w = params["experts"]
    g = jnp.einsum("ebcd,edf->ebcf", xin, w["w_gate"].astype(x.dtype))
    u = jnp.einsum("ebcd,edf->ebcf", xin, w["w_up"].astype(x.dtype))
    g = shard(g, "experts", "batch", None, "ff")
    act = jax.nn.silu(g) if mlp_kind == "swiglu" else jax.nn.gelu(g)
    h = act * u
    eout = jnp.einsum("ebcf,efd->ebcd", h, w["w_down"].astype(x.dtype))
    eout = shard(eout, "experts", "batch", None, "embed")
    out = jnp.einsum("bsec,ebcd->bsd", combine, eout)
    out = shard(out, "batch", "seq", "embed")

    if "shared_expert" in params:
        out = out + mlp(params["shared_expert"], x, mlp_kind)
    if "dense_mlp" in params:
        out = out + mlp(params["dense_mlp"], x, mlp_kind)
    return out, aux
