"""Base layers: embedding, norms, dense projections, MLPs, rotary embedding.

Conventions:
- params are nested dicts; leaf names follow the patterns in
  ``repro.distributed.sharding.PARAM_AXIS_PATTERNS`` (that is how sharding
  is attached — by path, not by plumbing);
- compute dtype is the input's dtype (bf16 in production), accumulation and
  normalization statistics are f32;
- ``init_*`` functions take an ``nn_rng`` (jax PRNG key) and return params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard

Params = Dict[str, Any]


def _normal(key, shape, std, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


# -- embedding ---------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, *, pad_to: int = 1,
                   dtype=jnp.float32) -> Params:
    """Token embedding; vocab padded up to `pad_to` multiple for TP
    shardability (granite's 49155 -> 49280). Logical vocab is kept by the
    caller; padded rows are zero-initialized and never updated by real ids."""
    vpad = -(-vocab // pad_to) * pad_to
    table = _normal(key, (vpad, d), d ** -0.5, dtype)
    if vpad != vocab:
        table = table.at[vocab:].set(0.0)
    return {"table": table}


def embed_lookup(params: Params, ids: jax.Array) -> jax.Array:
    out = jnp.take(params["table"], ids, axis=0)
    return shard(out, "batch", "seq", "embed")


def embed_logits(params: Params, x: jax.Array, vocab: int,
                 keep_pad: bool = False) -> jax.Array:
    """Tied-readout logits.

    keep_pad=False slices back to the logical vocab (public API).
    keep_pad=True returns the PADDED width with -inf on pad entries — the
    padded width divides the model axis, so the logits stay vocab-sharded
    (slicing first would make ragged vocabs like 50280/49155 unshardable
    and replicate a (B, S, V) f32 tensor on every device)."""
    logits = jnp.einsum("...d,vd->...v", x, params["table"],
                        preferred_element_type=jnp.float32)
    if keep_pad:
        return mask_pad_logits(logits, vocab)
    return logits[..., :vocab]


def mask_pad_logits(logits: jax.Array, vocab: int) -> jax.Array:
    vpad = logits.shape[-1]
    if vpad == vocab:
        return logits
    mask = jnp.arange(vpad) < vocab
    return jnp.where(mask, logits, -1e30)


def init_lm_head(key, d: int, vocab: int, *, pad_to: int = 1,
                 dtype=jnp.float32) -> Params:
    vpad = -(-vocab // pad_to) * pad_to
    return {"kernel": _normal(key, (d, vpad), d ** -0.5, dtype)}


def lm_head_logits(params: Params, x: jax.Array, vocab: int,
                   keep_pad: bool = False) -> jax.Array:
    logits = jnp.einsum("...d,dv->...v", x, params["kernel"],
                        preferred_element_type=jnp.float32)
    if keep_pad:
        return mask_pad_logits(logits, vocab)
    return logits[..., :vocab]


# -- norms -------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm(params: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)
            + params["bias"].astype(jnp.float32)).astype(x.dtype)


# -- dense -------------------------------------------------------------------

def init_dense(key, d_in: int, d_out: int, *, std: Optional[float] = None,
               dtype=jnp.float32) -> Params:
    std = d_in ** -0.5 if std is None else std
    return {"kernel": _normal(key, (d_in, d_out), std, dtype)}


def dense(params: Params, x: jax.Array) -> jax.Array:
    return jnp.einsum("...i,io->...o", x, params["kernel"].astype(x.dtype))


# -- MLPs --------------------------------------------------------------------

def init_mlp(key, d: int, ff: int, kind: str, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {"w_gate": init_dense(k1, d, ff, dtype=dtype),
                "w_up": init_dense(k2, d, ff, dtype=dtype),
                "w_down": init_dense(k3, ff, d, std=ff ** -0.5, dtype=dtype)}
    if kind == "gelu":
        return {"w_in": init_dense(k1, d, ff, dtype=dtype),
                "w_out": init_dense(k2, ff, d, std=ff ** -0.5, dtype=dtype)}
    raise ValueError(kind)


def mlp(params: Params, x: jax.Array, kind: str) -> jax.Array:
    if kind in ("swiglu", "geglu"):
        g = dense(params["w_gate"], x)
        u = dense(params["w_up"], x)
        g = shard(g, "batch", "seq", "ff")
        act = jax.nn.silu(g) if kind == "swiglu" else jax.nn.gelu(g)
        h = act * u
        out = dense(params["w_down"], h)
    else:
        h = dense(params["w_in"], x)
        h = shard(h, "batch", "seq", "ff")
        out = dense(params["w_out"], jax.nn.gelu(h))
    return shard(out, "batch", "seq", "embed")


# -- rotary ------------------------------------------------------------------

def rope_angles(positions: jax.Array, head_dim: int,
                theta: float = 10000.0) -> Tuple[jax.Array, jax.Array]:
    """positions (...,) -> (cos, sin) of shape (..., head_dim//2), f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., S, H, D); cos/sin (..., S, D/2) broadcast over heads."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate([xf1 * c - xf2 * s, xf2 * c + xf1 * s],
                           axis=-1).astype(x.dtype)
