"""Sequence-sharded flash decode (§Perf, serve cells).

The baseline decode stores the KV cache with kv heads repeated to the TP
width (kv_eff = n_kv * repeat) so GSPMD can shard the head axis — 2x cache
HBM for kv=8 on a 16-way model axis, and the big-model serve cells miss
HBM (mistral-large decode_32k: 15.4 GB params + 11.8 GB cache > 16 GB).

This path stores the cache UNREPEATED (B, S, n_kv, D) and shards the
*sequence* axis over the model axis instead: each TP rank holds S/tp of
the cache, computes a partial flash (m, l, o) over its slice for ALL q
heads, and the partials merge with a logsumexp reduction (pmax + psum) —
the distributed equivalent of the flash-attention streaming softmax, and
structurally the TrIM psum-accumulation applied across chips.

Implemented as shard_map manual over the "model" axis, auto elsewhere
(batch stays GSPMD-sharded over the data axes). The single-token cache
write happens on the rank that owns the target position (predicated
dynamic-update-slice, no full-cache copy).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import (current_mesh_context,
                                        shard_map_compat)

NEG_INF = -1e30


def _local_flash_decode(q, k_loc, v_loc, lo, pos, kv_length):
    """Partial flash over a local KV slice.

    q (B, n_kv, G, D) f32; k/v_loc (B, S_loc, n_kv, D); lo: global index of
    the slice start. Returns (o_unnorm (B,n_kv,G,D), m (B,n_kv,G), l)."""
    B, S_loc, n_kv, D = k_loc.shape
    s = jnp.einsum("bhgd,bshd->bhgs", q, k_loc.astype(jnp.float32))
    s = s * (D ** -0.5)
    cols = lo + jnp.arange(S_loc)
    limit = (pos + 1) if kv_length is None else kv_length
    if jnp.ndim(limit) == 0:
        mask = (cols < limit)[None, None, None, :]
    else:   # per-row lengths (B,)
        mask = cols[None, :] < limit[:, None]
        mask = mask[:, None, None, :]
    s = jnp.where(mask, s, NEG_INF)
    m = s.max(axis=-1)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(mask, p, 0.0)
    l = p.sum(axis=-1)
    o = jnp.einsum("bhgs,bshd->bhgd", p, v_loc.astype(jnp.float32))
    return o, m, l


def _kv_len_array(B: int, pos, kv_length):
    if kv_length is None:
        return jnp.full((B,), pos + 1, jnp.int32)
    return kv_length.astype(jnp.int32)


def seqshard_flash_decode(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, new_k: jax.Array,
                          new_v: jax.Array, pos: jax.Array,
                          kv_length: Optional[jax.Array] = None,
                          axes: Tuple[str, ...] = ("model",),
                          ) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One decode step against a sequence-sharded unrepeated cache.

    q (B, 1, n_q, D); k/v_cache (B, S, n_kv, D) sharded on dim 1 over
    `axes` (one or more mesh axes — the "2d" serve layout shards the
    sequence over ("data","model") with the batch replicated);
    new_k/v (B, 1, n_kv, D); pos scalar int32 (position written).
    Returns (o (B, 1, n_q, D), new k_cache, new v_cache).

    Without an active mesh (or without the axes) this runs the same math
    single-device — the oracle the distributed path is tested against.
    """
    B, _, n_q, D = q.shape
    n_kv = k_cache.shape[2]
    G = n_q // n_kv
    qg = q[:, 0].reshape(B, n_kv, G, D).astype(jnp.float32)

    kv_len = _kv_len_array(B, pos, kv_length)

    ctx = current_mesh_context()
    axes = tuple(a for a in axes
                 if ctx is not None and a in ctx.mesh.axis_names
                 and ctx.mesh.shape[a] > 1)
    if ctx is None or not axes:
        k_new = jax.lax.dynamic_update_slice_in_dim(
            k_cache, new_k.astype(k_cache.dtype), pos, axis=1)
        v_new = jax.lax.dynamic_update_slice_in_dim(
            v_cache, new_v.astype(v_cache.dtype), pos, axis=1)
        o, m, l = _local_flash_decode(qg, k_new, v_new, 0, pos, kv_len)
        out = (o / jnp.maximum(l, 1e-20)[..., None])
        return out.reshape(B, 1, n_q, D).astype(q.dtype), k_new, v_new

    mesh = ctx.mesh

    sizes = [mesh.shape[a] for a in axes]

    @functools.partial(
        shard_map_compat, mesh=mesh,
        in_specs=(P(), P(None, axes), P(None, axes), P(), P(), P(), P()),
        out_specs=(P(), P(None, axes), P(None, axes)),
        check_vma=False, axis_names=frozenset(axes))
    def body(qg, k_loc, v_loc, nk, nv, pos, kv_len):
        S_loc = k_loc.shape[1]
        idx = jnp.int32(0)                  # flattened over the axis tuple
        for a, s in zip(axes, sizes):
            idx = idx * s + jax.lax.axis_index(a)
        lo = idx * S_loc
        # predicated single-position write (no full-cache copy)
        loc = jnp.clip(pos - lo, 0, S_loc - 1)
        own = (pos >= lo) & (pos < lo + S_loc)
        old_k = jax.lax.dynamic_slice_in_dim(k_loc, loc, 1, axis=1)
        old_v = jax.lax.dynamic_slice_in_dim(v_loc, loc, 1, axis=1)
        k_w = jnp.where(own, nk.astype(k_loc.dtype), old_k)
        v_w = jnp.where(own, nv.astype(v_loc.dtype), old_v)
        k_loc = jax.lax.dynamic_update_slice_in_dim(k_loc, k_w, loc, axis=1)
        v_loc = jax.lax.dynamic_update_slice_in_dim(v_loc, v_w, loc, axis=1)
        o, m, l = _local_flash_decode(qg, k_loc, v_loc, lo, pos, kv_len)
        # distributed logsumexp merge
        m_g = jax.lax.pmax(m, axes)
        w = jnp.exp(m - m_g)
        l_g = jax.lax.psum(l * w, axes)
        o_g = jax.lax.psum(o * w[..., None], axes)
        out = o_g / jnp.maximum(l_g, 1e-20)[..., None]
        return out, k_loc, v_loc

    out, k_new, v_new = body(qg, k_cache, v_cache, new_k, new_v, pos,
                             kv_len)
    return (out.reshape(B, 1, n_q, D).astype(q.dtype), k_new, v_new)
