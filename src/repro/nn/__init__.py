"""Model substrate: pure-JAX functional layers (pytree params, no flax)."""
