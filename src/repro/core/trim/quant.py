"""Quantization utilities for the TrIM CNN path (paper §III-A precision).

The paper's PEs consume B-bit *unsigned* integer inputs and B-bit *signed*
integer weights (B = 8 on the FPGA), producing signed psums whose width grows
as 2B+K (slice bottom row) + ceil(log2 K) (slice adder tree) + ceil(log2 P_M)
(core tree) + ceil(log2 M) (engine temporal accumulation). Final activations
are re-quantized to B bits before leaving the engine.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class QuantParams:
    scale: float
    zero_point: int = 0


def quantize_activations_u8(x: np.ndarray) -> Tuple[np.ndarray, QuantParams]:
    """Asymmetric uint8 quantization (inputs are unsigned in the paper)."""
    lo, hi = float(x.min()), float(x.max())
    hi = max(hi, lo + 1e-8)
    scale = (hi - lo) / 255.0
    zp = int(round(-lo / scale))
    q = np.clip(np.round(x / scale) + zp, 0, 255).astype(np.uint8)
    return q, QuantParams(scale, zp)


def quantize_weights_i8(w: np.ndarray) -> Tuple[np.ndarray, QuantParams]:
    """Symmetric int8 quantization (weights are signed in the paper)."""
    amax = max(float(np.abs(w).max()), 1e-8)
    scale = amax / 127.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, QuantParams(scale, 0)


def dequantize_psums(psums: np.ndarray, act: QuantParams, wgt: QuantParams,
                     w_int: np.ndarray) -> np.ndarray:
    """int32 psums -> float, correcting for the activation zero point.

    conv(q_x, q_w) = conv(x, w)/(s_x*s_w) + zp * sum(q_w); the correction term
    is per-output-channel.
    """
    corr = w_int.astype(np.int64).sum(axis=tuple(range(1, w_int.ndim)))
    shaped = corr.reshape((-1,) + (1,) * (psums.ndim - 1))
    return (psums.astype(np.float64) - act.zero_point * shaped) * (
        act.scale * wgt.scale)


def requantize_u8(psums: np.ndarray, out_scale: float,
                  act: QuantParams, wgt: QuantParams,
                  w_int: np.ndarray) -> np.ndarray:
    """Engine output stage: psums -> B-bit activations for the next layer."""
    f = dequantize_psums(psums, act, wgt, w_int)
    return np.clip(np.round(f / out_scale), 0, 255).astype(np.uint8)


def psum_bit_width(B: int, K: int, P_M: int, M: int) -> int:
    """The paper's worst-case engine-output width (§III-A/§III-C)."""
    return (2 * B + K + math.ceil(math.log2(K))
            + math.ceil(math.log2(max(M, 2))))
