"""Quantization utilities for the TrIM CNN path (paper §III-A precision).

The paper's PEs consume B-bit *unsigned* integer inputs and B-bit *signed*
integer weights (B = 8 on the FPGA), producing signed psums whose width grows
as 2B+K (slice bottom row) + ceil(log2 K) (slice adder tree) + ceil(log2 P_M)
(core tree) + ceil(log2 M) (engine temporal accumulation). Final activations
are re-quantized to B bits before leaving the engine.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass(frozen=True)
class QuantParams:
    scale: float
    zero_point: int = 0


def quantize_activations_u8(x: np.ndarray) -> Tuple[np.ndarray, QuantParams]:
    """Asymmetric uint8 quantization (inputs are unsigned in the paper)."""
    lo, hi = float(x.min()), float(x.max())
    hi = max(hi, lo + 1e-8)
    scale = (hi - lo) / 255.0
    zp = int(round(-lo / scale))
    q = np.clip(np.round(x / scale) + zp, 0, 255).astype(np.uint8)
    return q, QuantParams(scale, zp)


def quantize_weights_i8(w: np.ndarray) -> Tuple[np.ndarray, QuantParams]:
    """Symmetric int8 quantization (weights are signed in the paper)."""
    amax = max(float(np.abs(w).max()), 1e-8)
    scale = amax / 127.0
    q = np.clip(np.round(w / scale), -127, 127).astype(np.int8)
    return q, QuantParams(scale, 0)


def dequantize_psums(psums: np.ndarray, act: QuantParams, wgt: QuantParams,
                     w_int: np.ndarray) -> np.ndarray:
    """int32 psums -> float, correcting for the activation zero point.

    conv(q_x, q_w) = conv(x, w)/(s_x*s_w) + zp * sum(q_w); the correction term
    is per-output-channel.
    """
    corr = w_int.astype(np.int64).sum(axis=tuple(range(1, w_int.ndim)))
    shaped = corr.reshape((-1,) + (1,) * (psums.ndim - 1))
    return (psums.astype(np.float64) - act.zero_point * shaped) * (
        act.scale * wgt.scale)


def requantize_u8(psums: np.ndarray, out_scale: float,
                  act: QuantParams, wgt: QuantParams,
                  w_int: np.ndarray) -> np.ndarray:
    """Engine output stage: psums -> B-bit activations for the next layer."""
    f = dequantize_psums(psums, act, wgt, w_int)
    return np.clip(np.round(f / out_scale), 0, 255).astype(np.uint8)


def psum_bit_width(B: int, K: int, P_M: int, M: int) -> int:
    """The paper's worst-case engine-output width (§III-A/§III-C)."""
    return (2 * B + K + math.ceil(math.log2(K))
            + math.ceil(math.log2(max(M, 2))))


# ---------------------------------------------------------------------------
# MSR (most-significant-run) 8 -> 5-bit weight compression  (DESIGN.md §9.3)
#
# Trained int8 conv weights concentrate their information in a short run of
# most-significant bits: within one output channel, every magnitude fits in
# ``bitlength(max|w|)`` bits, and keeping only the top MSR_CODE_BITS of that
# run loses at most the channel's bottom ``t`` bits.  We therefore store, per
# weight, a sign + 4-bit code (int5), plus one shared 2-bit shift ``t`` per
# output channel:
#
#     t_c   = max(0, bitlength(max |w| over channel c) - 4)      # 0..3
#     code  = sign(w) * (|w| >> t_c)                             # in [-15, 15]
#
# Decompression applies the expect-value compensation: the discarded low
# ``t`` bits are uniform in [0, 2^t), so adding their expectation ~2^(t-1)
# (a single 1 bit just below the kept run) halves the truncation bias:
#
#     |w^| = (|code| << t) | (1 << (t-1))     if |code| > 0 and t > 0
#          = |code| << t                      otherwise
#
# The compensated magnitude is odd, so |w^| = |w5| << e factors exactly with
#     e  = t - 1,  w5 = sign * (2*|code| + 1)        (t > 0, code != 0)
#     e  = 0,      w5 = code                         (t == 0 or code == 0)
# giving a small operand |w5| <= 31 plus a per-channel power-of-two exponent
# that the requant stage absorbs losslessly (`fold_shift_into_requant`).
# ---------------------------------------------------------------------------

#: Bits kept from each weight's most-significant run (excluding sign).
MSR_CODE_BITS = 4
#: Stored bits per weight: sign + MSR_CODE_BITS.
MSR_STORAGE_BITS = MSR_CODE_BITS + 1
#: Largest decompressed-operand magnitude: 2 * (2^4 - 1) + 1.
MSR_OPERAND_MAX = 2 * ((1 << MSR_CODE_BITS) - 1) + 1


def msr_compress(w: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Compress int8 weights to signed 4-bit MSR codes + per-channel shifts.

    ``w`` is any integer array whose **last axis** is the output channel
    (conv kernels are HWIO).  Returns ``(codes, shifts)``: ``codes`` is int8
    in [-15, 15] with ``w``'s shape, ``shifts`` is int32 of shape
    ``(w.shape[-1],)`` with values in [0, 3] for int8 inputs.
    """
    w = np.asarray(w)
    if not np.issubdtype(w.dtype, np.integer):
        raise TypeError(f"msr_compress expects integer weights, got {w.dtype}")
    mag = np.abs(w.astype(np.int32))
    if mag.size and int(mag.max()) > 127:
        raise ValueError("msr_compress expects int8-range weights (|w|<=127)")
    ch_max = mag.reshape(-1, w.shape[-1]).max(axis=0) if w.size else \
        np.zeros((w.shape[-1],), np.int32)
    bitlen = np.zeros_like(ch_max)  # bitlength(m): index of top set bit + 1
    nz = ch_max > 0
    bitlen[nz] = np.floor(np.log2(ch_max[nz])).astype(np.int32) + 1
    shifts = np.maximum(bitlen - MSR_CODE_BITS, 0).astype(np.int32)
    codes = np.sign(w.astype(np.int32)) * (mag >> shifts)
    return codes.astype(np.int8), shifts


def msr_decompress(codes: np.ndarray, shifts: np.ndarray,
                   compensate: bool = True) -> np.ndarray:
    """Reconstruct int8 weight estimates from MSR codes.

    With ``compensate=True`` (the lane's default) a single 1 bit is appended
    just below the kept run — the expected value of the truncated low bits —
    whenever the code is nonzero and the channel shift is positive.  With
    ``compensate=False`` this is plain truncation (the ablation baseline).
    """
    codes = codes.astype(np.int32)
    t = np.asarray(shifts, np.int32)
    mag = np.abs(codes) << t
    if compensate:
        comp = np.where((np.abs(codes) > 0) & (t > 0), 1 << np.maximum(t - 1, 0), 0)
        mag = mag | comp
    return (np.sign(codes) * mag).astype(np.int8)


def msr_operand(codes: np.ndarray, shifts: np.ndarray,
                compensate: bool = True) -> Tuple[np.ndarray, np.ndarray]:
    """Factor the decompressed weights as ``w_hat == w5 << e`` exactly.

    Returns ``(w5, e)``: ``w5`` int8 with ``|w5| <= MSR_OPERAND_MAX`` (31)
    and ``e`` int32 per output channel.  ``w5`` is the operand the conv
    kernels multiply by — its small magnitude is what widens the f32exact
    channel chunks ~4x (kernels/ref.py) — and ``e`` folds into the requant
    shift (`fold_shift_into_requant`) or an explicit left-shift on the last
    layer's raw psums.
    """
    codes = codes.astype(np.int32)
    t = np.asarray(shifts, np.int32)
    e = np.maximum(t - 1, 0).astype(np.int32)
    mag = np.abs(codes)
    if compensate:
        w5 = np.where(t > 0, np.sign(codes) * (2 * mag + (mag > 0)),
                      codes)
    else:
        w5 = np.where(t > 0, np.sign(codes) * (2 * mag), codes)
    return w5.astype(np.int8), e


def fold_shift_into_requant(mult: np.ndarray, shift: np.ndarray,
                            e: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Absorb the per-channel MSR exponent into (mult, shift) requant pairs.

    For psums computed against the small operand ``w5`` the full-precision
    psum is ``psum << e``, and

        requant(psum << e, m, s) == requant(psum, m, s - e)

    exactly: both equal ``clip(floor((psum * m * 2^e + 2^(s-1)) / 2^s))``.
    (Left-shifting the accumulator multiplies the numerator by 2^e; dropping
    ``e`` from the shift divides the denominator and the rounding constant by
    the same factor.)  When ``s - e`` would leave the kernel's domain
    (shift >= 1), the residue moves into the multiplier with saturation at
    the int16 domain bound — psum magnitudes that large are out of the
    calibrated range anyway.
    """
    m = np.asarray(mult, np.int64)
    s = np.asarray(shift, np.int64) - np.asarray(e, np.int64)
    short = np.maximum(1 - s, 0)
    m = np.minimum(m << short, 32767)
    s = np.maximum(s, 1)
    return m.astype(np.int32), s.astype(np.int32)


def pack_int5(codes: np.ndarray) -> np.ndarray:
    """Pack signed 4-bit MSR codes into a dense 5-bit/weight byte stream.

    Each code becomes ``(sign << 4) | |code|``; the 5-bit fields are
    concatenated MSB-first and packed 8-codes-per-5-bytes.  Returns a uint8
    array of ``ceil(5 * n / 8)`` bytes.  Exact inverse: `unpack_int5`.
    """
    flat = np.asarray(codes, np.int32).reshape(-1)
    if flat.size and int(np.abs(flat).max()) >= (1 << MSR_CODE_BITS):
        raise ValueError("codes exceed the 4-bit MSR magnitude range")
    five = ((flat < 0).astype(np.uint8) << MSR_CODE_BITS) | \
        np.abs(flat).astype(np.uint8)
    bits = np.unpackbits(five[:, None], axis=1)[:, -MSR_STORAGE_BITS:]
    return np.packbits(bits.reshape(-1))


def unpack_int5(packed: np.ndarray, count: int) -> np.ndarray:
    """Inverse of `pack_int5`: recover ``count`` signed codes (flat int8)."""
    bits = np.unpackbits(np.asarray(packed, np.uint8))
    need = count * MSR_STORAGE_BITS
    if bits.size < need:
        raise ValueError(f"packed stream too short for {count} codes")
    fields = bits[:need].reshape(count, MSR_STORAGE_BITS)
    weights = 1 << np.arange(MSR_CODE_BITS - 1, -1, -1)
    mag = fields[:, 1:].astype(np.int32) @ weights
    sign = np.where(fields[:, 0] > 0, -1, 1).astype(np.int32)
    return (sign * mag.astype(np.int32)).astype(np.int8)


def packed_nbytes(n_weights: int) -> int:
    """Storage for ``n_weights`` packed int5 codes, in bytes."""
    return (n_weights * MSR_STORAGE_BITS + 7) // 8


def wire_checksum(packed: np.ndarray) -> int:
    """CRC-32 over a packed int5 byte stream (`pack_int5` output).

    The integrity word a deployment stores next to each layer's BRAM
    weight image: a soft-error bit-flip anywhere in the packed payload
    changes the checksum, so a consumer that verifies before decoding
    (``serve.faults.PackedWire``) can never materialize flipped weights.
    """
    import zlib

    return zlib.crc32(np.ascontiguousarray(
        np.asarray(packed, np.uint8)).tobytes()) & 0xFFFFFFFF
