"""TrIM analytical model — paper §IV equations (1)-(4) + memory-access models.

Everything here is pure-Python arithmetic (no jax): these are the closed-form
models the paper uses for its design-space exploration (Fig. 7) and for the
throughput / utilization / memory-access columns of Tables I and II.

Modelling notes (divergences from the paper are *documented*, not hidden):

* Cycle model (eq. 2) is implemented verbatim and is EXACT for every
  K=3 / stride-1 layer of Tables I-II (all 13 VGG-16 CLs and AlexNet CL3-5).
* Large kernels (K>3) are decomposed into ceil(K/3)^2 tiles of 3x3, as §V
  describes for AlexNet. The paper does not give the full cycle equation for
  the tiled/strided path; we model it as (filter x tile) pairs scheduled over
  the P_N cores with stride-1 slice sweeps, which lands within ~25% of the
  printed CL1/CL2 AlexNet numbers. Both model and paper values are reported
  side by side by the benchmarks.
* The memory-access counting methodology comes from the companion dataflow
  paper (arXiv:2408.01254) and is not fully specified here; our
  first-principles model (inputs fetched once per engine pass + triangular
  warm-up overhead; weights once; outputs once) reproduces the printed
  off-chip column within ~5% on VGG-16.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

# ---------------------------------------------------------------------------
# Layer / engine descriptors
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ConvLayerSpec:
    """One convolutional layer, in the paper's nomenclature.

    H_I, W_I : input feature-map height/width (pre-padding)
    K        : kernel size (square)
    M        : input channels  (# ifmaps)
    N        : output channels (# filters / ofmaps)
    stride   : convolution stride
    pad      : symmetric zero padding
    """

    name: str
    H_I: int
    W_I: int
    K: int
    M: int
    N: int
    stride: int = 1
    pad: Optional[int] = None  # default: 'same' for stride 1 -> K//2

    @property
    def padding(self) -> int:
        return self.K // 2 if self.pad is None else self.pad

    @property
    def H_O(self) -> int:
        return (self.H_I + 2 * self.padding - self.K) // self.stride + 1

    @property
    def W_O(self) -> int:
        return (self.W_I + 2 * self.padding - self.K) // self.stride + 1


@dataclass(frozen=True)
class TrimEngineConfig:
    """The TrIM engine's architectural parameters (paper §III-§V)."""

    P_N: int = 7      # parallel cores (filters / ofmaps)
    P_M: int = 24     # parallel slices per core (ifmaps)
    K: int = 3        # native slice kernel size
    B: int = 8        # operand bit width (uint8 inputs, int8 weights)
    f_clk_hz: float = 150e6
    L_I: int = 9      # engine pipeline depth (5 slice + 3 core tree + 1 accum)

    @property
    def n_pes(self) -> int:
        return self.P_N * self.P_M * self.K * self.K

    @property
    def peak_gops(self) -> float:
        """Peak throughput: 2 ops (mul+add) per PE per cycle."""
        return 2.0 * self.n_pes * self.f_clk_hz / 1e9


#: The configuration implemented on the XCZU7EV FPGA in §V.
PAPER_ENGINE = TrimEngineConfig()

# ---------------------------------------------------------------------------
# Networks from the paper (Tables I and II)
# ---------------------------------------------------------------------------

VGG16_LAYERS: Tuple[ConvLayerSpec, ...] = (
    ConvLayerSpec("CL1", 224, 224, 3, 3, 64),
    ConvLayerSpec("CL2", 224, 224, 3, 64, 64),
    ConvLayerSpec("CL3", 112, 112, 3, 64, 128),
    ConvLayerSpec("CL4", 112, 112, 3, 128, 128),
    ConvLayerSpec("CL5", 56, 56, 3, 128, 256),
    ConvLayerSpec("CL6", 56, 56, 3, 256, 256),
    ConvLayerSpec("CL7", 56, 56, 3, 256, 256),
    ConvLayerSpec("CL8", 28, 28, 3, 256, 512),
    ConvLayerSpec("CL9", 28, 28, 3, 512, 512),
    ConvLayerSpec("CL10", 28, 28, 3, 512, 512),
    ConvLayerSpec("CL11", 14, 14, 3, 512, 512),
    ConvLayerSpec("CL12", 14, 14, 3, 512, 512),
    ConvLayerSpec("CL13", 14, 14, 3, 512, 512),
)

ALEXNET_LAYERS: Tuple[ConvLayerSpec, ...] = (
    ConvLayerSpec("CL1", 227, 227, 11, 3, 96, stride=4, pad=0),
    ConvLayerSpec("CL2", 27, 27, 5, 48, 256, pad=2),
    ConvLayerSpec("CL3", 13, 13, 3, 256, 384, pad=1),
    ConvLayerSpec("CL4", 13, 13, 3, 192, 384, pad=1),
    ConvLayerSpec("CL5", 13, 13, 3, 192, 256, pad=1),
)

#: Paper Table I / II reference values (TrIM columns), used by the benchmarks
#: for side-by-side validation. (GOPs/s, PE util, on-chip M, off-chip M).
PAPER_TABLE1_TRIM: Dict[str, Tuple[float, float, float, float]] = {
    "CL1": (51.8, 0.13, 0.00, 13.57),
    "CL2": (368.0, 1.00, 0.57, 102.79),
    "CL3": (387.0, 1.00, 0.27, 49.96),
    "CL4": (387.0, 1.00, 0.68, 95.33),
    "CL5": (396.0, 1.00, 0.33, 48.51),
    "CL6": (432.0, 1.00, 0.66, 94.71),
    "CL7": (432.0, 1.00, 0.66, 94.71),
    "CL8": (422.0, 1.00, 0.33, 52.44),
    "CL9": (422.0, 1.00, 0.70, 103.72),
    "CL10": (422.0, 1.00, 0.70, 103.72),
    "CL11": (389.0, 1.00, 0.17, 33.05),
    "CL12": (389.0, 1.00, 0.17, 33.05),
    "CL13": (389.0, 1.00, 0.17, 33.05),
}
PAPER_TABLE1_EYERISS_TOTALS = {"on_chip_M": 2427.63, "off_chip_M": 160.65,
                               "total_M": 2588.28, "gops": 24.5}
PAPER_TABLE1_TRIM_TOTALS = {"on_chip_M": 5.44, "off_chip_M": 858.63,
                            "total_M": 864.06, "gops": 391.0}

PAPER_TABLE2_TRIM: Dict[str, Tuple[float, float, float, float]] = {
    "CL1": (2.13, 1.00, 0.08, 8.44),
    "CL2": (179.0, 0.57, 0.21, 3.50),
    "CL3": (390.0, 1.00, 0.11, 14.85),
    "CL4": (402.0, 1.00, 0.07, 11.20),
    "CL5": (399.0, 1.00, 0.05, 7.52),
}
PAPER_TABLE2_TRIM_TOTALS = {"on_chip_M": 0.53, "off_chip_M": 45.50,
                            "total_M": 46.03, "gops": 12.9}
PAPER_TABLE2_EYERISS_TOTALS = {"on_chip_M": 77.45, "off_chip_M": 7.70,
                               "total_M": 85.15, "gops": 51.5}

#: Batch sizes used by the paper's normalization footnotes.
VGG16_BATCH = 3
ALEXNET_BATCH = 4

# ---------------------------------------------------------------------------
# Paper equations (1)-(4)
# ---------------------------------------------------------------------------


def layer_ops(layer: ConvLayerSpec) -> int:
    """Eq. (1): OPs = 2 * K^2 * H_O * W_O * M * N (multiply + add)."""
    return 2 * layer.K * layer.K * layer.H_O * layer.W_O * layer.M * layer.N


def _kernel_tiles(K: int, native_k: int) -> int:
    """Number of native_k x native_k tiles covering a K x K kernel (§V)."""
    t = math.ceil(K / native_k)
    return t * t


def engine_cycles(layer: ConvLayerSpec, eng: TrimEngineConfig = PAPER_ENGINE) -> int:
    """Eq. (2): clock cycles to execute one CL on the engine.

    NC = L_I + ceil(N/P_N) * ceil(M/P_M) * (P_N*K + H_O*W_O)

    For K > native slice size, the kernel is decomposed into ceil(K/3)^2
    3x3 tiles and *cores cooperate on one filter* (paper §V: "P_M 5x5
    kernels are split in 4 groups of P_M tiles each. Each group is
    processed by a TrIM Core"):

    - concurrent filters = max(1, floor(P_N / tiles)); a filter whose tile
      count exceeds P_N takes ceil(tiles/P_N) rounds (AlexNet 11x11: 16
      tiles over 7 cores -> 3 rounds);
    - stride-1 tile sweeps cover H_O*W_O positions; *strided* layers must
      stream the full stride-1 extent and decimate downstream, which is why
      AlexNet CL1 shows full PE activity but only 2.13 useful GOPs/s.

    This reproduces Table II within ~2.5% on CL1/CL2 and exactly on CL3-5.
    """
    if layer.K <= eng.K and layer.stride == 1:
        steps = math.ceil(layer.N / eng.P_N) * math.ceil(layer.M / eng.P_M)
        return eng.L_I + steps * (eng.P_N * eng.K + layer.H_O * layer.W_O)
    # Tiled / strided path (§V, AlexNet).
    tiles = _kernel_tiles(layer.K, eng.K)
    concurrent = max(1, eng.P_N // tiles)
    tile_rounds = math.ceil(tiles / min(tiles, eng.P_N))
    filter_rounds = math.ceil(layer.N / concurrent) * tile_rounds
    steps = filter_rounds * math.ceil(layer.M / eng.P_M)
    if layer.stride == 1:
        sweep = layer.H_O * layer.W_O
    else:  # stream the full stride-1 extent, decimate downstream
        h_sweep = layer.H_I + 2 * layer.padding - eng.K + 1
        w_sweep = layer.W_I + 2 * layer.padding - eng.K + 1
        sweep = h_sweep * w_sweep
    return eng.L_I + steps * (eng.P_N * eng.K + sweep)


def steady_pe_activity(layer: ConvLayerSpec,
                       eng: TrimEngineConfig = PAPER_ENGINE) -> float:
    """Fraction of PEs busy during steady-state compute steps.

    This matches the paper's "PE Util." column definition: full groups count
    as fully busy; under-filled *structural* parallelism shows up here.

    - untiled layers (K <= native): slices hold channels -> activity is
      min(1, M/P_M). VGG CL1: 3 of 24 slices -> 0.13 (paper: 0.13).
    - tiled layers with M >= P_M: each filter's P_M-channel group needs
      `tiles` cores. AlexNet CL2 (5x5, 4 tiles): 4 of 7 cores -> 0.57
      (paper: 0.57).
    - tiled layers with M < P_M: (channel x tile) pairs PACK into a core's
      slices (the hardware re-purposes idle slices for other tiles), and
      filters stagger across rounds. AlexNet CL1 (11x11, M=3): 3*16 = 48
      slice-jobs per filter over 96 filters saturate the array -> 1.00
      (paper: 1.00).
    """
    tiles = _kernel_tiles(layer.K, eng.K) if layer.K > eng.K else 1
    if tiles == 1:
        return min(1.0, layer.M / eng.P_M)
    if layer.M < eng.P_M:
        total_jobs = layer.N * layer.M * tiles
        return min(1.0, total_jobs / (eng.P_N * eng.P_M))
    core_act = (max(1, eng.P_N // tiles) * min(tiles, eng.P_N)) / eng.P_N
    return min(1.0, layer.M / eng.P_M) * core_act


def layer_time_s(layer: ConvLayerSpec, eng: TrimEngineConfig = PAPER_ENGINE) -> float:
    return engine_cycles(layer, eng) / eng.f_clk_hz


def layer_gops(layer: ConvLayerSpec, eng: TrimEngineConfig = PAPER_ENGINE) -> float:
    """Sustained throughput for one layer, GOPs/s (useful operations only)."""
    return layer_ops(layer) / layer_time_s(layer, eng) / 1e9


def pe_utilization(layer: ConvLayerSpec, eng: TrimEngineConfig = PAPER_ENGINE) -> float:
    """Useful MACs per cycle over peak MACs per cycle."""
    return layer_gops(layer, eng) / eng.peak_gops


def psum_buffer_bits(eng: TrimEngineConfig, H_OM: int, W_OM: int,
                     act_bits: int = 32) -> int:
    """Eq. (3): total psum buffer size = P_N * H_OM * W_OM * 32 bits."""
    return eng.P_N * H_OM * W_OM * act_bits


def io_bandwidth_bits(eng: TrimEngineConfig) -> int:
    """Eq. (4): BW_I/O = (P_M * 5 + P_N) * B bits per cycle (K=3 peak)."""
    return (eng.P_M * 5 + eng.P_N) * eng.B


def network_cycles(layers: Sequence[ConvLayerSpec],
                   eng: TrimEngineConfig = PAPER_ENGINE) -> int:
    return sum(engine_cycles(l, eng) for l in layers)


def network_gops(layers: Sequence[ConvLayerSpec],
                 eng: TrimEngineConfig = PAPER_ENGINE) -> float:
    ops = sum(layer_ops(l) for l in layers)
    t = network_cycles(layers, eng) / eng.f_clk_hz
    return ops / t / 1e9


# ---------------------------------------------------------------------------
# Memory-access models
# ---------------------------------------------------------------------------
# All counts are in element accesses (one access = one B-bit operand), per
# batch of `batch` images, matching the paper's footnote normalization.


@dataclass(frozen=True)
class MemoryAccesses:
    """Access counts, in millions of element accesses."""

    ifmap_reads: float
    weight_reads: float
    ofmap_writes: float
    onchip_raw: float          # raw on-chip (psum buffer / scratchpad) accesses
    onchip_equiv: float        # energy-normalized to off-chip units (/128)

    @property
    def off_chip(self) -> float:
        return self.ifmap_reads + self.weight_reads + self.ofmap_writes

    @property
    def total(self) -> float:
        return self.off_chip + self.onchip_equiv


#: 32-bit DRAM read ~640 pJ vs 32-bit SRAM read ~5 pJ (paper §I, Horowitz) —
#: the factor used to express on-chip accesses in off-chip-equivalent units.
DRAM_OVER_SRAM_ENERGY = 128.0


def trim_input_fetches(layer: ConvLayerSpec, native_k: int = 3) -> float:
    """External (off-chip) fetches for ONE ifmap, one engine pass.

    The triangular movement's single-fetch guarantee: every *padded* input
    element is fetched exactly once per pass (validated operand-by-operand by
    ``slice_sim.simulate_slice``). The overhead over the useful H*W elements
    is therefore just the padded boundary: 900/50176 = 1.79% for a 3x3
    kernel over 224x224 — the "negligible 1.8% overhead" quoted in §II.
    """
    H_p = layer.H_I + 2 * layer.padding
    W_p = layer.W_I + 2 * layer.padding
    return H_p * W_p


def trim_memory_accesses(layer: ConvLayerSpec,
                         eng: TrimEngineConfig = PAPER_ENGINE,
                         batch: int = 1,
                         weight_bits: Optional[int] = None) -> MemoryAccesses:
    """First-principles TrIM access model (see module docstring).

    ``weight_bits`` models a sub-``B``-bit stored weight lane: accesses are
    counted in ``B``-bit element units, so storing each weight in
    ``weight_bits`` bits scales ``weight_reads`` by ``weight_bits / B`` —
    the int5 MSR lane (DESIGN.md §9.3) ships 5/8 of the int8 lane's weight
    traffic (its 4-bit magnitude plane alone is exactly half; the sign
    plane is the remaining 1/8).  ``None`` keeps full-width weights.
    """
    tiles = _kernel_tiles(layer.K, eng.K) if layer.K > eng.K else 1
    # Every group of P_N filters requires one full pass over the ifmaps
    # (broadcast to all cores); weights are fetched exactly once overall.
    # For tiled kernels (K>3) we assume tile rounds within a filter group
    # re-circulate the stream from the on-chip sub-buffers — a conservative
    # *upper bound* on the paper's (unspecified) large-K accounting.
    passes = math.ceil(layer.N / eng.P_N)
    ifmap_reads = batch * passes * layer.M * trim_input_fetches(layer, eng.K)
    weight_reads = layer.N * layer.M * layer.K * layer.K
    if weight_bits is not None:
        if not 0 < weight_bits <= eng.B:
            raise ValueError(
                f"weight_bits must be in (0, {eng.B}], got {weight_bits}")
        weight_reads *= weight_bits / eng.B
    ofmap_writes = batch * layer.N * layer.H_O * layer.W_O
    # Psum-buffer traffic: per (filter-group pass, core): S = ceil(M/P_M)
    # temporal steps; step 1 write-only, steps 2..S-1 read+write, step S
    # read-only -> 2S-2 buffer accesses per output activation (S>1), else 0
    # (single-step layers bypass the buffer).
    S = math.ceil(layer.M / eng.P_M)
    rmw = max(2 * S - 2, 0) if S > 1 else 0
    # one psum-buffer slot per (filter, tile) pair actually scheduled
    onchip_raw = batch * layer.N * tiles * rmw * layer.H_O * layer.W_O
    # Psums are 32-bit vs B-bit operands: count in B-bit equivalents first.
    onchip_raw_equiv_width = onchip_raw * (32 / eng.B)
    onchip_equiv = onchip_raw_equiv_width / DRAM_OVER_SRAM_ENERGY
    return MemoryAccesses(
        ifmap_reads=ifmap_reads / 1e6,
        weight_reads=weight_reads / 1e6,
        ofmap_writes=ofmap_writes / 1e6,
        onchip_raw=onchip_raw / 1e6,
        onchip_equiv=onchip_equiv / 1e6,
    )


def ws_im2col_memory_accesses(layer: ConvLayerSpec, batch: int = 1,
                              array_cols: int = 256) -> MemoryAccesses:
    """GeMM-based weight-stationary baseline (TPU-style, paper §II).

    Conv-to-GeMM materializes each input element K^2 times (sliding-window
    redundancy): the im2col operand is (H_O*W_O) x (K^2*M) and is streamed
    once per group of `array_cols` filters held stationary.
    """
    passes = math.ceil(layer.N / array_cols)
    im2col_elems = layer.H_O * layer.W_O * layer.K * layer.K * layer.M
    ifmap_reads = batch * passes * im2col_elems
    weight_reads = layer.N * layer.M * layer.K * layer.K
    ofmap_writes = batch * layer.N * layer.H_O * layer.W_O
    return MemoryAccesses(ifmap_reads / 1e6, weight_reads / 1e6,
                          ofmap_writes / 1e6, 0.0, 0.0)


def eyeriss_rs_memory_accesses(layer: ConvLayerSpec, batch: int = 1,
                               pe_rows: int = 12, pe_cols: int = 14,
                               spad_per_mac: float = 4.0,
                               ) -> MemoryAccesses:
    """Row-stationary (Eyeriss) access model, first-principles.

    Each PE circulates one ifmap row against one kernel row in scratchpads:
    every MAC touches >= (ifmap spad + weight spad + psum spad read&write)
    = 4 scratchpad accesses — this is why §V reports ~94% of Eyeriss'
    equivalent on-chip accesses coming from PE scratchpads. The paper's
    printed Table-I Eyeriss column corresponds to ~6.8 accesses/MAC
    (their count also folds in spad refills and GLB traffic; the exact
    methodology comes from the Eyeriss energy model and is not specified
    here) — pass ``spad_per_mac=6.8`` to reproduce the printed ~3x ratio;
    the default 4.0 is the conservative lower bound and still preserves
    the TrIM < Eyeriss total-access ordering. Off-chip: the global buffer
    + RLC compression lets Eyeriss fetch ifmaps ~once and weights once per
    row-tile pass (the paper credits Eyeriss with 5.3x fewer off-chip
    accesses than TrIM on VGG-16).
    """
    macs = layer.K * layer.K * layer.H_O * layer.W_O * layer.M * layer.N
    onchip_raw = batch * spad_per_mac * macs
    onchip_equiv = onchip_raw / DRAM_OVER_SRAM_ENERGY
    # Off-chip: ifmaps once + weights re-fetched per spatial fold + ofmaps.
    folds = math.ceil(layer.H_O / pe_rows)
    ifmap_reads = batch * layer.M * layer.H_I * layer.W_I
    weight_reads = folds * layer.N * layer.M * layer.K * layer.K
    ofmap_writes = batch * layer.N * layer.H_O * layer.W_O
    return MemoryAccesses(ifmap_reads / 1e6, weight_reads / 1e6,
                          ofmap_writes / 1e6, onchip_raw / 1e6, onchip_equiv / 1e6)


# ---------------------------------------------------------------------------
# Whole-network report (drives the Table I/II benchmarks)
# ---------------------------------------------------------------------------


def network_report(layers: Sequence[ConvLayerSpec],
                   eng: TrimEngineConfig = PAPER_ENGINE,
                   batch: int = 1,
                   weight_bits: Optional[int] = None) -> List[Dict[str, float]]:
    """Per-layer model outputs in the shape of the paper's Tables I/II.

    ``weight_bits`` scales the weight-read column for sub-8-bit stored
    weight lanes (see :func:`trim_memory_accesses`)."""
    rows: List[Dict[str, float]] = []
    for l in layers:
        acc = trim_memory_accesses(l, eng, batch=batch,
                                   weight_bits=weight_bits)
        rows.append({
            "name": l.name,
            "ops_G": layer_ops(l) / 1e9,
            "cycles": engine_cycles(l, eng),
            "time_ms": layer_time_s(l, eng) * 1e3,
            "gops": layer_gops(l, eng),
            "pe_util": pe_utilization(l, eng),
            "pe_activity": steady_pe_activity(l, eng),
            "offchip_M": acc.off_chip,
            "onchip_M": acc.onchip_equiv,
            "total_M": acc.total,
        })
    return rows
