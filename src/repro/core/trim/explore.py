"""Design-space exploration (paper §IV, Fig. 7).

Sweeps the parallelism parameters (P_N cores x P_M slices/core) and reports
throughput (eq. 1-2), psum-buffer size (eq. 3) and I/O bandwidth (eq. 4) —
reproducing Fig. 7 including the 1243 GOPs/s best case at P_N = P_M = 24 and
the P_N-vs-P_M efficiency asymmetry discussed in the text (576-PE example).

Also provides ``derive_fpga_parameters``: the §V procedure that picks
P_N = 7 from the BRAM budget and P_M = 24 from the DDR4 I/O budget.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from typing import List, Sequence, Tuple

from repro.core.trim.model import (
    ConvLayerSpec,
    TrimEngineConfig,
    VGG16_LAYERS,
    io_bandwidth_bits,
    network_gops,
    psum_buffer_bits,
)

FIG7_GRID: Tuple[int, ...] = (1, 4, 8, 16, 24)


@dataclass(frozen=True)
class DesignPoint:
    P_N: int
    P_M: int
    n_pes: int
    gops: float
    psum_buffer_Mb: float
    io_bandwidth_bits: int


def explore(layers: Sequence[ConvLayerSpec] = VGG16_LAYERS,
            grid: Sequence[int] = FIG7_GRID,
            base: TrimEngineConfig = TrimEngineConfig(),
            H_OM: int = 224, W_OM: int = 224) -> List[DesignPoint]:
    points = []
    for pn in grid:
        for pm in grid:
            eng = replace(base, P_N=pn, P_M=pm)
            points.append(DesignPoint(
                P_N=pn, P_M=pm, n_pes=eng.n_pes,
                gops=network_gops(layers, eng),
                psum_buffer_Mb=psum_buffer_bits(eng, H_OM, W_OM) / 1e6,
                io_bandwidth_bits=io_bandwidth_bits(eng),
            ))
    return points


def derive_fpga_parameters(bram_bits: float = 312 * 36 * 1024,
                           ddr_peak_bytes_s: float = 19200e6,
                           f_clk_hz: float = 150e6,
                           H_OM: int = 224, W_OM: int = 224,
                           B: int = 8, K: int = 3) -> Tuple[int, int]:
    """§V sizing: P_N from on-chip memory, P_M from I/O bandwidth.

    The XCZU7EV's "11 Mb of BRAMs" is 312 36-Kb blocks = 11.50e6 bits —
    with the paper's rounded 11e6 the floor lands at 6, with the actual
    block count it lands at the paper's P_N = 7.

    P_N = floor(BRAM_bits / (H_OM*W_OM*32));   (eq. 3)
    BW_io = DDR bits per engine cycle, rounded down to a power of two;
    P_M = floor((BW_io - P_N*B) / (5*B)).      (eq. 4)
    """
    p_n = int(bram_bits // (H_OM * W_OM * 32))
    bits_per_cycle = ddr_peak_bytes_s * 8 / f_clk_hz
    bw = 2 ** int(math.floor(math.log2(bits_per_cycle)))
    p_m = int((bw - p_n * B) // (5 * B))
    return p_n, p_m
