"""Cycle-level simulator of one TrIM slice (paper Fig. 3 + Fig. 4).

Simulates the triangular input movement at operand granularity, one sliding
window per cycle in row-major order over the stride-1 sweep (the slice's
steady-state throughput is one output per cycle, paper §III-A):

- the *bottom* PE row (Row_{K-1}) consumes the newest (padded) ifmap row: one
  element enters externally per cycle at the rightmost PE (vertical
  movement) — K elements at each window-row start to refill the horizontal
  pipeline — then shifts right-to-left (horizontal movement);
- when the leftmost PE of Row_i is done with an element, it is pushed into
  RSRB_{i-1}, which re-delivers it to Row_{i-1} exactly one window-row later
  (diagonal movement), so upper rows never touch external memory after the
  first window row;
- the simulator checks *FIFO feasibility* (elements are consumed in exactly
  the order they were pushed — i.e. a shift register suffices), records the
  steady-state read-tap delay, tracks occupancy, and counts external fetches.

What this validates against the paper:

1. external fetches per pass == H_p * W_p (every padded element exactly
   once): the overhead over H*W useful elements is the padded boundary,
   900/50176 = **1.79%** for a 3x3 kernel over 224x224 — the "negligible
   1.8% overhead" quoted in §II;
2. the steady-state RSRB tap delay is the constant W_sweep - K + 1, a
   function of the ifmap width only — exactly why the paper's RSRB needs
   run-time reconfigurability (Fig. 4): changing W_I between layers moves
   the tap, nothing else;
3. RSRB occupancy never exceeds the padded ifmap width W_p (the capacity
   the paper provisions: W_IM registers, sized for the largest ifmap).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Optional, Tuple

import numpy as np


@dataclass
class SliceSimResult:
    external_fetches: int          # off-chip reads performed by the slice
    warmup_fetches: int            # part of the above: first-window-row rows
    total_cycles: int
    valid_outputs: int
    max_rsrb_occupancy: int        # peak FIFO depth across all K-1 RSRBs
    steady_tap_delay: Optional[int]  # constant interior consume-push delay
    interior_tap_constant: bool    # True -> a fixed shift-register tap works
    fifo_order_ok: bool            # True -> consumption order == push order
    outputs: np.ndarray            # (H_sweep, W_sweep) int64 conv outputs


def simulate_slice(x: np.ndarray, w: np.ndarray, pad: Optional[int] = None,
                   ) -> SliceSimResult:
    """Cycle-level run of one K x K TrIM slice over one ifmap.

    x: (H, W) integer ifmap; w: (K, K) integer kernel.
    """
    K = int(w.shape[0])
    p = K // 2 if pad is None else pad
    xp = np.pad(x.astype(np.int64), p)
    H_p, W_p = xp.shape
    H_s, W_s = H_p - K + 1, W_p - K + 1
    assert H_s > 0 and W_s > 0, "ifmap smaller than kernel"

    external = 0
    warmup = 0
    max_occ = 0
    fifo_order_ok = True
    interior_delays = set()

    # RSRB_i delivers to Row_i (i = 0..K-2); fed by Row_{i+1}'s retirements.
    rsrbs: List[List[Tuple[int, int, int]]] = [[] for _ in range(max(K - 1, 0))]

    outputs = np.zeros((H_s, W_s), dtype=np.int64)
    cycle = 0
    for r in range(H_s):
        for c in range(W_s):
            # ---- operand arrivals ----------------------------------------
            new_cols = list(range(K)) if c == 0 else [c + K - 1]
            for i in range(K):
                row = r + i
                for e in new_cols:
                    if i == K - 1 or r == 0:
                        # Vertical external injection (bottom row always;
                        # all rows during the first window row = warm-up).
                        external += 1
                        if i < K - 1:
                            warmup += 1
                    else:
                        # Diagonal delivery from RSRB_i.
                        fifo = rsrbs[i]
                        assert fifo, "RSRB underflow: dataflow infeasible"
                        er, ec, pc = fifo[0]
                        if (er, ec) == (row, e):
                            fifo.pop(0)
                        else:  # not at the head -> not shift-register-feasible
                            fifo_order_ok = False
                            for idx, (fr, fc, fpc) in enumerate(fifo):
                                if (fr, fc) == (row, e):
                                    pc = fpc
                                    fifo.pop(idx)
                                    break
                        delay = cycle - pc
                        # interior elements: constant-tap steady state
                        if r >= 1 and K - 1 <= e < W_s:
                            interior_delays.add(delay)
            # ---- compute: PE(i, j) MACs x[r+i, c+j] * w[i, j] -------------
            outputs[r, c] = int(
                (xp[r:r + K, c:c + K] * w.astype(np.int64)).sum())
            # ---- retirements: leftmost PE -> RSRB for the row above -------
            retired_cols = [c]
            if c == W_s - 1:  # end of window row: flush the pipeline tail
                retired_cols += list(range(W_s, W_p))
            if r + 1 < H_s:   # the row above will need these next window row
                for i in range(1, K):       # Row_i feeds RSRB_{i-1}
                    # Row_i is processing physical row r+i, which is exactly
                    # the row Row_{i-1} needs at window row r+1.
                    for e in retired_cols:
                        rsrbs[i - 1].append((r + i, e, cycle))
            for f in rsrbs:
                max_occ = max(max_occ, len(f))
            cycle += 1

    tap_constant = len(interior_delays) <= 1
    steady = interior_delays.pop() if len(interior_delays) == 1 else None
    return SliceSimResult(
        external_fetches=external,
        warmup_fetches=warmup,
        total_cycles=cycle,
        valid_outputs=H_s * W_s,
        max_rsrb_occupancy=max_occ,
        steady_tap_delay=steady,
        interior_tap_constant=tap_constant,
        fifo_order_ok=fifo_order_ok,
        outputs=outputs,
    )


def expected_external_fetches(H: int, W: int, K: int,
                              pad: Optional[int] = None) -> int:
    """Model contract: every padded element fetched exactly once per pass."""
    p = K // 2 if pad is None else pad
    return (H + 2 * p) * (W + 2 * p)


def padding_overhead(H: int, W: int, K: int, pad: Optional[int] = None) -> float:
    """Fractional fetch overhead vs the useful H*W elements (§II: ~1.8%)."""
    return expected_external_fetches(H, W, K, pad) / (H * W) - 1.0
