"""Bit-faithful functional emulator of the TrIM Slice/Core/Engine hierarchy.

This module executes a convolutional layer exactly the way the paper's
hardware does — same arithmetic (uint8 inputs x int8 weights -> signed int32
psums), same hierarchical reduction order (slice column psums -> slice adder
tree -> core adder tree -> engine temporal accumulation into psum buffers),
and the same ceil(N/P_N) x ceil(M/P_M) step schedule (paper §III).

Because integer addition is associative, the final tensor must equal a plain
int32 convolution — the *faithfulness* validated here is the schedule, the
psum-buffer contents per step, the bit-width growth contract
(2B+K -> +ceil(log2 K) -> +ceil(log2 P_M) -> +ceil(log2 M) bits), and the
memory-access counters, all of which tests compare against the paper.

Implementation is numpy (integer-exact, deterministic); the TPU-native
realization of the same dataflow is the Pallas kernel in
``repro.kernels.trim_conv2d``.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional, Tuple

import numpy as np

from repro.core.trim.model import (ConvLayerSpec, TrimEngineConfig,
                                   PAPER_ENGINE, trim_input_fetches)


# ---------------------------------------------------------------------------
# Slice: one 2-D K x K convolution, column-psum + adder-tree order
# ---------------------------------------------------------------------------


def _slice_conv2d(x_pad: np.ndarray, w: np.ndarray, check_widths: bool,
                  B: int) -> np.ndarray:
    """Stride-1 valid conv of one padded ifmap with one K x K kernel.

    Reduction order matches the slice hardware: per output pixel, each PE
    column accumulates K products vertically (bottom-row psum, 2B+K bits),
    then the adder tree reduces the K column psums (+ceil(log2 K) bits).
    """
    K = w.shape[0]
    H_p, W_p = x_pad.shape
    H_s, W_s = H_p - K + 1, W_p - K + 1
    windows = np.lib.stride_tricks.sliding_window_view(x_pad, (K, K))
    # (H_s, W_s, K, K) * (K, K) -> column psums then tree: sum over axis -2
    # (vertical/PE-column) first, then axis -1 (adder tree over columns).
    prods = windows.astype(np.int64) * w.astype(np.int64)
    col_psums = prods.sum(axis=-2)           # (H_s, W_s, K) bottom-row psums
    out = col_psums.sum(axis=-1)             # adder tree
    if check_widths:
        lim_col = 2 ** (2 * B + K - 1)
        lim_out = 2 ** (2 * B + K + math.ceil(math.log2(K)) - 1)
        assert np.abs(col_psums).max(initial=0) < lim_col, "2B+K width violated"
        assert np.abs(out).max(initial=0) < lim_out, "slice output width violated"
    return out


@dataclass
class EngineTrace:
    """Counters and per-step artifacts produced by one layer execution."""

    steps: int = 0
    weight_load_cycles: int = 0
    compute_cycles: int = 0
    ifmap_fetches: int = 0          # off-chip input element reads (modelled)
    weight_fetches: int = 0
    ofmap_writebacks: int = 0
    psum_buffer_accesses: int = 0   # on-chip RMW element accesses
    psum_buffer_snapshots: List[np.ndarray] = field(default_factory=list)
    max_abs_psum: int = 0


class TrimEngine:
    """Functional TrIM engine: P_N cores x P_M slices (paper Fig. 6)."""

    def __init__(self, config: TrimEngineConfig = PAPER_ENGINE,
                 check_widths: bool = True, record_snapshots: bool = False):
        self.cfg = config
        self.check_widths = check_widths
        self.record_snapshots = record_snapshots

    # -- core: P_M slices + adder tree ------------------------------------
    def _core_step(self, x_pad: np.ndarray, w_group: np.ndarray) -> np.ndarray:
        """3-D conv of a channel group: sum of per-slice 2-D convs.

        x_pad:   (m_g, H_p, W_p) uint8 ifmaps of this channel group
        w_group: (m_g, K, K) int8 kernels (one filter, this channel group)
        """
        cfg = self.cfg
        acc = None
        for m in range(x_pad.shape[0]):
            s = _slice_conv2d(x_pad[m], w_group[m], self.check_widths, cfg.B)
            acc = s if acc is None else acc + s
        if self.check_widths and acc is not None:
            lim = 2 ** (2 * cfg.B + cfg.K + math.ceil(math.log2(cfg.K))
                        + math.ceil(math.log2(max(cfg.P_M, 2))) - 1)
            assert np.abs(acc).max(initial=0) < lim, "core output width violated"
        return acc

    # -- engine ------------------------------------------------------------
    def run_layer(self, ifmaps: np.ndarray, weights: np.ndarray,
                  layer: Optional[ConvLayerSpec] = None,
                  ) -> Tuple[np.ndarray, EngineTrace]:
        """Execute one CL. ifmaps (M,H,W) uint8; weights (N,M,K,K) int8.

        Returns (ofmaps (N,H_O,W_O) int32, trace). Kernels with K larger than
        the native slice size are decomposed into 3x3 tiles (§V) and strides
        are applied by decimating the stride-1 sweep.
        """
        cfg = self.cfg
        M, H, W = ifmaps.shape
        N, M_w, K, K2 = weights.shape
        assert M == M_w and K == K2
        if layer is None:
            layer = ConvLayerSpec("layer", H, W, K, M, N)
        assert ifmaps.dtype == np.uint8 and weights.dtype == np.int8
        pad = layer.padding
        native = cfg.K
        t_side = math.ceil(K / native)
        # Tail padding so every tile's stride-1 sweep covers all output
        # positions (the zero-padded tile-kernel rows/cols multiply it away).
        extra = t_side * native - K
        x_pad = np.pad(ifmaps, ((0, 0), (pad, pad + extra),
                                (pad, pad + extra))).astype(np.int64)

        trace = EngineTrace()
        H_O, W_O = layer.H_O, layer.W_O
        out = np.zeros((N, H_O, W_O), dtype=np.int64)

        tiles = [(th * native, tw * native)
                 for th in range(t_side) for tw in range(t_side)]
        n_steps_m = math.ceil(M / cfg.P_M)

        # (filter, tile) pairs are the engine's unit of core assignment (§V);
        # for K<=3 there is a single tile and this is the plain schedule.
        pairs = [(f, t) for f in range(N) for t in range(len(tiles))]
        for pg in range(math.ceil(len(pairs) / cfg.P_N)):
            group = pairs[pg * cfg.P_N:(pg + 1) * cfg.P_N]
            psum_buffers = np.zeros((len(group), H_O, W_O), dtype=np.int64)
            for cg in range(n_steps_m):
                m0, m1 = cg * cfg.P_M, min((cg + 1) * cfg.P_M, M)
                for slot, (f, t) in enumerate(group):
                    oy, ox = tiles[t]
                    # tile kernel, zero-padded to native x native
                    wt = np.zeros((m1 - m0, native, native), dtype=np.int8)
                    sub = weights[f, m0:m1, oy:min(oy + native, K),
                                  ox:min(ox + native, K)]
                    wt[:, :sub.shape[1], :sub.shape[2]] = sub
                    # tile sweep: stride-1 over the padded map, offset (oy,ox)
                    xp = x_pad[m0:m1, oy:, ox:]
                    core_out = self._core_step(xp, wt)
                    # decimate to the layer's stride on the output grid
                    core_out = core_out[: layer.stride * H_O:layer.stride,
                                        : layer.stride * W_O:layer.stride]
                    psum_buffers[slot] += core_out
                    # RMW accounting: first step writes, middle steps R+W,
                    # last step reads out (matches model.py's 2S-2 rule).
                    if n_steps_m > 1:
                        trace.psum_buffer_accesses += (
                            H_O * W_O if cg in (0, n_steps_m - 1) else 2 * H_O * W_O)
                trace.steps += 1
                trace.weight_load_cycles += cfg.P_N * cfg.K
                trace.compute_cycles += (x_pad.shape[1] - native + 1) * (
                    x_pad.shape[2] - native + 1) if (K > native or layer.stride > 1) \
                    else H_O * W_O
                if self.record_snapshots:
                    trace.psum_buffer_snapshots.append(psum_buffers.copy())
            for slot, (f, t) in enumerate(group):
                out[f] += psum_buffers[slot]
            trace.ifmap_fetches += M * int(trim_input_fetches(layer, native))
            trace.max_abs_psum = max(trace.max_abs_psum,
                                     int(np.abs(psum_buffers).max(initial=0)))
        trace.weight_fetches = N * M * K * K
        trace.ofmap_writebacks = N * H_O * W_O

        if self.check_widths:
            lim = 2 ** (2 * cfg.B + cfg.K + math.ceil(math.log2(cfg.K))
                        + math.ceil(math.log2(max(M * len(tiles), 2))) + 1 - 1)
            assert np.abs(out).max(initial=0) < lim, "engine accum width violated"
        return out.astype(np.int32), trace


def trim_conv_layer(ifmaps: np.ndarray, weights: np.ndarray,
                    stride: int = 1, pad: Optional[int] = None,
                    config: TrimEngineConfig = PAPER_ENGINE) -> np.ndarray:
    """Convenience wrapper: run one layer through the emulator, outputs only."""
    M, H, W = ifmaps.shape
    N, _, K, _ = weights.shape
    layer = ConvLayerSpec("layer", H, W, K, M, N, stride=stride, pad=pad)
    out, _ = TrimEngine(config).run_layer(ifmaps, weights, layer)
    return out


def reference_conv_layer(ifmaps: np.ndarray, weights: np.ndarray,
                         stride: int = 1, pad: Optional[int] = None) -> np.ndarray:
    """Plain int conv oracle (numpy) for the emulator tests."""
    M, H, W = ifmaps.shape
    N, _, K, _ = weights.shape
    p = K // 2 if pad is None else pad
    x = np.pad(ifmaps.astype(np.int64), ((0, 0), (p, p), (p, p)))
    H_O = (H + 2 * p - K) // stride + 1
    W_O = (W + 2 * p - K) // stride + 1
    out = np.zeros((N, H_O, W_O), dtype=np.int64)
    for n in range(N):
        for i in range(K):
            for j in range(K):
                patch = x[:, i:i + stride * H_O:stride, j:j + stride * W_O:stride]
                out[n] += (patch * weights[n, :, i, j, None, None].astype(np.int64)
                           ).sum(axis=0)
    return out.astype(np.int32)
