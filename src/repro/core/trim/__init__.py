"""TrIM — Triangular Input Movement systolic dataflow (Sestito et al., TCAS-I 2024).

The paper's primary contribution, implemented at three fidelity levels:

- :mod:`repro.core.trim.model`    — the analytical model (paper eqs. 1-4) plus
  memory-access models for TrIM, Eyeriss-RS and im2col-WS baselines.
- :mod:`repro.core.trim.engine`   — bit-faithful functional emulator of the
  Slice/Core/Engine hierarchy (uint8 x int8 -> int32, step-by-step schedule).
- :mod:`repro.core.trim.slice_sim`— cycle-level simulator of a single TrIM slice
  (PE array + shift-register buffers) used to validate the triangular movement
  and the external-fetch overhead claim (~1.8% for 3x3 over 224x224).
- :mod:`repro.core.trim.explore`  — design-space exploration (paper Fig. 7).

The TPU-native realization of the same dataflow lives in
:mod:`repro.kernels.trim_conv2d` (Pallas).
"""
from repro.core.trim.model import (  # noqa: F401
    ConvLayerSpec,
    TrimEngineConfig,
    VGG16_LAYERS,
    ALEXNET_LAYERS,
    layer_ops,
    engine_cycles,
    layer_gops,
    pe_utilization,
    steady_pe_activity,
    psum_buffer_bits,
    io_bandwidth_bits,
    trim_memory_accesses,
    ws_im2col_memory_accesses,
    eyeriss_rs_memory_accesses,
    network_report,
)
from repro.core.trim.engine import (  # noqa: F401
    TrimEngine,
    trim_conv_layer,
)
