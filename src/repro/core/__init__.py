"""Core: the paper's primary contribution (TrIM dataflow) in JAX/numpy."""
