"""Deterministic, shardable data pipelines.

Every dataset is a pure function of (seed, step, example-index): any host
can materialize any shard of any batch without coordination, which is what
makes restart/elastic-rescale exact — after restoring a checkpoint at step
k, host h regenerates exactly the batches it would have seen, regardless of
how many hosts there now are.

Synthetic LM data is a order-3 Markov-ish stream (mixed congruential over
token history) — cheap, deterministic, and with enough structure that a
~100M model visibly learns (loss drops well below uniform entropy), which
the examples/tests rely on.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional, Tuple

import numpy as np


def _philox(seed: int, counters: np.ndarray) -> np.ndarray:
    """Counter-based uniform uint32s (stateless splitmix-style mix)."""
    # fold counters through a splitmix-style mix (vectorized, stateless)
    x = counters.astype(np.uint64) + np.uint64(
        (seed * 0x9E3779B97F4A7C15) & 0xFFFFFFFFFFFFFFFF
    )
    x = (x ^ (x >> np.uint64(30))) * np.uint64(0xBF58476D1CE4E5B9)
    x = (x ^ (x >> np.uint64(27))) * np.uint64(0x94D049BB133111EB)
    x = x ^ (x >> np.uint64(31))
    return (x & np.uint64(0xFFFFFFFF)).astype(np.uint32)


@dataclass(frozen=True)
class SyntheticLMDataset:
    """Deterministic synthetic token stream with learnable structure.

    Each sequence repeats a per-row random block of ``period`` tokens
    (tokens[t] = tokens[t - period] for t >= period), with a small amount
    of substitution noise. Predicting position t >= period is a copy task
    — small LMs drive the loss far below the ln(vocab) floor within tens
    of steps, which the e2e tests/examples assert. Generation is a pure
    function of (seed, step, row): any host materializes any shard of any
    batch without coordination (exact restart/elastic rescale).
    """

    vocab: int
    seq_len: int  # tokens per example INCLUDING the label shift
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    period: int = 4
    noise: float = 0.02

    @property
    def per_host_batch(self) -> int:
        assert self.global_batch % self.n_hosts == 0
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B = self.per_host_batch
        rows = (np.arange(B) + self.host_id * B + step * self.global_batch).astype(
            np.uint64
        )
        toks = np.zeros((B, self.seq_len), np.int64)
        for t in range(self.seq_len):
            if t < self.period:
                toks[:, t] = _philox(self.seed + 3 + t, rows) % self.vocab
            else:
                u = _philox(self.seed + 101 + t, rows) % 10_000
                flip = u < self.noise * 10_000
                rand = _philox(self.seed + 211 + t, rows) % self.vocab
                toks[:, t] = np.where(flip, rand, toks[:, t - self.period])
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


@dataclass(frozen=True)
class SyntheticImageDataset:
    """Deterministic images: class-dependent low-frequency patterns + noise
    (a linear probe reaches high accuracy — enough for e2e CNN training)."""

    hw: Tuple[int, int]
    channels: int
    n_classes: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0

    @property
    def per_host_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B = self.per_host_batch
        rows = (np.arange(B) + self.host_id * B + step * self.global_batch).astype(
            np.uint64
        )
        labels = (_philox(self.seed, rows) % self.n_classes).astype(np.int32)
        H, W = self.hw
        yy, xx = np.meshgrid(np.linspace(0, 1, H), np.linspace(0, 1, W), indexing="ij")
        freq = 1 + labels[:, None, None] % 4
        phase = labels[:, None, None] * 2.399
        base = np.sin(2 * np.pi * freq * yy[None] + phase) * np.cos(
            2 * np.pi * freq * xx[None]
        )
        noise_seed = _philox(self.seed + 7, rows)
        noise = np.stack(
            [
                np.random.Generator(np.random.Philox(key=int(s))).normal(
                    0, 0.3, (H, W)
                )
                for s in noise_seed
            ]
        )
        img = (base + noise)[..., None].repeat(self.channels, -1)
        return {"images": img.astype(np.float32), "labels": labels}


@dataclass(frozen=True)
class SyntheticRequestStream:
    """Deterministic serving request stream with a configurable arrival
    process (open-loop load for the serve launchers and benchmarks).

    Iterating yields ``(t_arrival_s, image, label)`` with arrival times as
    offsets from stream start; the serve loop sleeps to honor them, so
    queueing delay is measured, not simulated.  Arrival processes:

    - "poisson": exponential inter-arrivals at ``rate_hz`` (the classic
      open-loop load model);
    - "uniform": fixed ``1/rate_hz`` spacing;
    - "bursts": cycles ``burst_sizes`` — each burst lands at one instant,
      bursts ``gap_s`` apart.  Sized to the serving buckets (and with
      ``gap_s`` past the flush deadline) this exercises every bucket at
      least once, which is what the CI serve-smoke lane asserts.

    Images come from :class:`SyntheticImageDataset` (request index = step
    at batch 1), so everything is a pure function of (seed, request
    index).  ``dtype="uint8"`` affine-maps the float images (≈[-2, 2])
    onto [0, 255] for the integer serving lane.
    """

    hw: Tuple[int, int]
    channels: int
    n_classes: int = 10
    n_requests: int = 64
    rate_hz: float = 100.0
    seed: int = 0
    process: str = "poisson"
    burst_sizes: Tuple[int, ...] = (1, 4, 16)
    gap_s: float = 0.02
    dtype: str = "float32"

    def __post_init__(self):
        if self.process not in ("poisson", "uniform", "bursts"):
            raise ValueError(
                f"process {self.process!r} not in ('poisson', 'uniform', 'bursts')"
            )
        if self.dtype not in ("float32", "uint8"):
            raise ValueError(f"dtype {self.dtype!r} not in ('float32', 'uint8')")

    def _images(self) -> SyntheticImageDataset:
        return SyntheticImageDataset(
            hw=self.hw,
            channels=self.channels,
            n_classes=self.n_classes,
            global_batch=1,
            seed=self.seed,
        )

    def image_at(self, i: int) -> Tuple[np.ndarray, int]:
        """Request ``i``'s (image, label) — pure in (seed, i)."""
        b = self._images().batch_at(i)
        img = b["images"][0]
        if self.dtype == "uint8":
            img = np.clip((img + 2.0) * 63.75, 0, 255).astype(np.uint8)
        return img, int(b["labels"][0])

    def sample_batch(self, n: int) -> np.ndarray:
        """The stream's first ``n`` images as one (n, H, W, C) batch —
        calibration samples drawn from the distribution being served."""
        return np.stack([self.image_at(i)[0] for i in range(n)])

    def arrival_times(self) -> np.ndarray:
        n = self.n_requests
        if self.process == "uniform":
            return np.arange(n) / self.rate_hz
        if self.process == "poisson":
            counters = np.arange(n).astype(np.uint64)
            u = (_philox(self.seed + 31, counters).astype(np.float64) + 1.0) / 2.0**32
            t = np.cumsum(-np.log(u) / self.rate_hz)
            return t - t[0]
        times: list = []
        t, i, k = 0.0, 0, 0
        while i < n:
            size = self.burst_sizes[k % len(self.burst_sizes)]
            for _ in range(min(int(size), n - i)):
                times.append(t)
                i += 1
            t += self.gap_s
            k += 1
        return np.asarray(times)

    def __iter__(self) -> Iterator[Tuple[float, np.ndarray, int]]:
        ts = self.arrival_times()
        for i in range(self.n_requests):
            img, label = self.image_at(i)
            yield float(ts[i]), img, label


@dataclass(frozen=True)
class FileTokenDataset:
    """Memory-mapped flat token file (.npy int32/uint16): the production
    path. Examples are fixed-length windows; window k of batch step s is
    row  (s * global_batch + k) * stride  — deterministic and host-local."""

    path: str
    seq_len: int
    global_batch: int
    stride: Optional[int] = None
    n_hosts: int = 1
    host_id: int = 0

    def __post_init__(self):
        arr = np.load(self.path, mmap_mode="r")
        object.__setattr__(self, "_arr", arr)

    @property
    def per_host_batch(self) -> int:
        return self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        arr = self._arr
        stride = self.stride or self.seq_len
        n_windows = max(1, (len(arr) - self.seq_len) // stride)
        B = self.per_host_batch
        idx = (np.arange(B) + self.host_id * B + step * self.global_batch) % n_windows
        toks = np.stack([arr[i * stride : i * stride + self.seq_len] for i in idx])
        return {"tokens": toks.astype(np.int32)}
