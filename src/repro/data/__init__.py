"""Deterministic sharded data pipeline."""

from repro.data.pipeline import (  # noqa: F401
    SyntheticLMDataset,
    SyntheticImageDataset,
    FileTokenDataset,
)
