"""Deterministic sharded data pipeline."""
from repro.data.pipeline import (SyntheticLMDataset, SyntheticImageDataset,
                                 FileTokenDataset)  # noqa: F401
