"""LR schedules as jnp-traceable functions of the step counter."""

from __future__ import annotations

import jax.numpy as jnp


def warmup_cosine(
    step,
    *,
    peak_lr: float,
    warmup_steps: int,
    total_steps: int,
    min_ratio: float = 0.1,
):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
    frac = jnp.clip(
        (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)


def warmup_linear(step, *, peak_lr: float, warmup_steps: int, total_steps: int):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak_lr * jnp.minimum(1.0, (step + 1) / max(warmup_steps, 1))
    frac = jnp.clip(
        (step - warmup_steps) / max(total_steps - warmup_steps, 1), 0.0, 1.0
    )
    return jnp.where(step < warmup_steps, warm, peak_lr * (1 - frac))
