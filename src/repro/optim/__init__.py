"""Optimizers, schedules, clipping, gradient accumulation."""

from repro.optim.adamw import (
    AdamWConfig,
    adamw_init,
    adamw_update,
    global_norm,
    clip_by_global_norm,
)
from repro.optim.schedules import warmup_cosine, warmup_linear  # noqa: F401
