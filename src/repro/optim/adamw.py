"""AdamW (decoupled weight decay) as pure pytree functions.

Optimizer moments are kept in f32 regardless of the param dtype; with the
ZeRO-1 sharding spec (``distributed.sharding.zero1_pspec``) the moments are
additionally sharded over the data axes.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    # params whose path matches any of these fragments skip weight decay
    no_decay_fragments: Tuple[str, ...] = ("norm", "bias", "A_log", "dt_bias", "/D")


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    clipped = jax.tree.map(
        lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype), tree
    )
    return clipped, norm


def adamw_init(params) -> Dict[str, Any]:
    def zeros(p):
        return jnp.zeros(p.shape, jnp.float32)

    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def _path_str(path) -> str:
    return "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)


def adamw_update(
    grads, opt_state, params, lr, cfg: AdamWConfig = AdamWConfig()
) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """One AdamW step. Returns (new_params, new_opt_state, metrics)."""
    metrics: Dict[str, jax.Array] = {}
    if cfg.clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
        metrics["grad_norm"] = gnorm
    step = opt_state["step"] + 1
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(path, p, g, m, v):
        gf = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * gf
        v_new = cfg.b2 * v + (1 - cfg.b2) * gf * gf
        update = (m_new / b1c) / (jnp.sqrt(v_new / b2c) + cfg.eps)
        ps = _path_str(path)
        if cfg.weight_decay and not any(f in ps for f in cfg.no_decay_fragments):
            update = update + cfg.weight_decay * p.astype(jnp.float32)
        p_new = (p.astype(jnp.float32) - lr * update).astype(p.dtype)
        return p_new, m_new, v_new

    flat = jax.tree_util.tree_map_with_path(
        lambda path, p, g, m, v: upd(path, p, g, m, v),
        params,
        grads,
        opt_state["m"],
        opt_state["v"],
    )
    # unzip the (p, m, v) triples
    treedef = jax.tree_util.tree_structure(params)
    triples = treedef.flatten_up_to(flat)
    new_params = treedef.unflatten([t[0] for t in triples])
    new_m = treedef.unflatten([t[1] for t in triples])
    new_v = treedef.unflatten([t[2] for t in triples])
    metrics["param_norm"] = global_norm(new_params)
    return new_params, {"m": new_m, "v": new_v, "step": step}, metrics
