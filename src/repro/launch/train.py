"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU slice this would run under `jax.distributed.initialize()`
with the production mesh; in this container it runs the smoke config on
the host devices (the full configs are exercised by the dry-run).
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke
from repro.data import SyntheticLMDataset
from repro.distributed import (StepConfig, TrainLoopConfig, activate_mesh,
                               make_train_state, make_train_step, state_pspec,
                               train_loop)
from repro.distributed.steps import _to_shardings, batch_pspec
from repro.launch.mesh import make_host_mesh
from repro.nn.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis size of the host mesh")
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.tp)
    model = build_model(cfg, tp=int(mesh.shape["model"]))
    scfg = StepConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps, accum=args.accum,
                      compress_grads=args.compress_grads)

    ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq + 1,
                            global_batch=args.batch)

    with activate_mesh(mesh) as ctx, mesh:
        state = make_train_state(model, jax.random.PRNGKey(0))
        sspec = state_pspec(state, ctx)
        sshard = _to_shardings(sspec, mesh)
        state = jax.device_put(state, sshard)
        step = jax.jit(make_train_step(model, scfg, mesh),
                       in_shardings=(sshard, _to_shardings(
                           batch_pspec({"tokens": jax.ShapeDtypeStruct(
                               (args.batch, args.seq + 1), jnp.int32)},
                               ctx), mesh)),
                       out_shardings=(sshard, None),
                       donate_argnums=(0,))
        out = train_loop(step, state, ds,
                         TrainLoopConfig(total_steps=args.steps,
                                         ckpt_every=args.ckpt_every,
                                         ckpt_dir=args.ckpt_dir),
                         state_shardings=sshard)
    losses = [h["loss"] for h in out["history"]]
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"{len(out['stragglers'])} straggler steps")


if __name__ == "__main__":
    main()
