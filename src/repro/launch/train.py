"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 100 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

On a real TPU slice this would run under `jax.distributed.initialize()`
with the production mesh; in this container it runs the smoke config on
the host devices (the full configs are exercised by the dry-run).

CNN archs (vgg16 / alexnet — the paper's own workloads) train through the
TrIM conv path in BOTH directions: the fused forward Pallas kernel and its
custom VJP (input-grad / weight-grad kernel pair, DESIGN.md §6).

  PYTHONPATH=src python -m repro.launch.train --arch vgg16 --smoke \
      --steps 3 --batch 4 --substrate pallas

``--substrate pallas`` (or the deprecated ``--force-pallas`` alias) runs
the Pallas kernels off-TPU in interpret mode — CI's train-smoke lane uses
it to prove the backward path on CPU runners; the launcher exits non-zero
unless the loss AND grad_norm of every step are finite, so backward-path
regressions fail PRs.  ``--int8`` additionally quantizes the trained conv
stack and runs the fused-requant integer datapath once through the same
execution plan.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import CNN_REGISTRY, CNN_SMOKES, get_config, get_smoke
from repro.data import SyntheticImageDataset, SyntheticLMDataset
from repro.distributed import (StepConfig, TrainLoopConfig, activate_mesh,
                               make_train_state, make_train_step, state_pspec,
                               train_loop)
from repro.distributed.steps import _to_shardings, batch_pspec
from repro.launch.cli import execution_parent, policy_from_args
from repro.launch.mesh import make_host_mesh
from repro.nn.models import build_model


def _int8_check(model, params, batch) -> None:
    """Quantize + calibrate + run the fused int8 inference datapath once
    (plan entry points), printing the output stats."""
    qp, _ = model.quantize(params)
    imgs = np.asarray(batch["images"])
    lo, hi = float(imgs.min()), float(imgs.max())
    u8 = jnp.asarray(np.clip((imgs - lo) / max(hi - lo, 1e-6) * 255,
                             0, 255).astype(np.uint8))
    pairs = model.calibrate_requant(qp, u8)
    feat = model.forward_int8(qp, u8, requant=pairs)
    finite = bool(np.isfinite(np.asarray(feat, np.float64)).all())
    print(f"[train] int8 datapath: output {feat.shape} dtype {feat.dtype} "
          f"finite={finite} (fused per-channel requant)")
    if not finite:
        raise SystemExit("[train] FAIL: non-finite int8 feature map")


def _int5_check(model, params, batch) -> None:
    """Quantize to the MSR-compressed int5 lane (DESIGN.md §9.3), calibrate
    the exponent-folded requant pairs, and run the fused datapath once."""
    qp, _ = model.quantize_int5(params)
    imgs = np.asarray(batch["images"])
    lo, hi = float(imgs.min()), float(imgs.max())
    u8 = jnp.asarray(np.clip((imgs - lo) / max(hi - lo, 1e-6) * 255,
                             0, 255).astype(np.uint8))
    pairs = model.calibrate_requant_int5(qp, u8)
    feat = model.forward_int5(qp, u8, requant=pairs)
    finite = bool(np.isfinite(np.asarray(feat, np.float64)).all())
    print(f"[train] int5 datapath: output {feat.shape} dtype {feat.dtype} "
          f"finite={finite} (MSR weights, exponent-folded requant)")
    if not finite:
        raise SystemExit("[train] FAIL: non-finite int5 feature map")


def main() -> None:
    ap = argparse.ArgumentParser(parents=[execution_parent(
        arch_required=True)])
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--tp", type=int, default=1,
                    help="model-axis size of the host mesh")
    args = ap.parse_args()

    policy = policy_from_args(args)
    is_cnn = args.arch in CNN_REGISTRY
    if is_cnn:
        cfg = CNN_SMOKES[args.arch] if args.smoke else CNN_REGISTRY[args.arch]
        H, W = cfg.input_hw
        c_in = cfg.layers[0].M
        ds = SyntheticImageDataset(hw=cfg.input_hw, channels=c_in,
                                   n_classes=cfg.n_classes,
                                   global_batch=args.batch)
        batch_shapes = {
            "images": jax.ShapeDtypeStruct((args.batch, H, W, c_in),
                                           jnp.float32),
            "labels": jax.ShapeDtypeStruct((args.batch,), jnp.int32)}
    else:
        cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
        ds = SyntheticLMDataset(vocab=cfg.vocab, seq_len=args.seq + 1,
                                global_batch=args.batch)
        batch_shapes = {
            "tokens": jax.ShapeDtypeStruct((args.batch, args.seq + 1),
                                           jnp.int32)}

    mesh = make_host_mesh(model=args.tp)
    model = build_model(cfg, tp=int(mesh.shape["model"]),
                        policy=policy if is_cnn else None)
    scfg = StepConfig(peak_lr=args.lr, warmup_steps=max(args.steps // 20, 5),
                      total_steps=args.steps, accum=args.accum,
                      compress_grads=args.compress_grads)

    with activate_mesh(mesh) as ctx, mesh:
        state = make_train_state(model, jax.random.PRNGKey(0))
        sspec = state_pspec(state, ctx)
        sshard = _to_shardings(sspec, mesh)
        state = jax.device_put(state, sshard)
        step = jax.jit(make_train_step(model, scfg, mesh),
                       in_shardings=(sshard, _to_shardings(
                           batch_pspec(batch_shapes, ctx), mesh)),
                       out_shardings=(sshard, None),
                       donate_argnums=(0,))
        out = train_loop(step, state, ds,
                         TrainLoopConfig(total_steps=args.steps,
                                         ckpt_every=args.ckpt_every,
                                         ckpt_dir=args.ckpt_dir),
                         state_shardings=sshard)
    hist = out["history"]
    losses = [h["loss"] for h in hist]
    grad_norm = hist[-1].get("grad_norm", float("nan"))
    print(f"[train] done: loss {losses[0]:.4f} -> {losses[-1]:.4f}; "
          f"grad_norm {grad_norm:.4f}; "
          f"{len(out['stragglers'])} straggler steps")
    # Backward-path health gate (CI train-smoke lane): a broken VJP shows
    # up as NaN/Inf loss or grad_norm — fail loudly, not silently.  Every
    # step is checked (skip_nonfinite keeps the *state* sane on a bad
    # step, which would otherwise mask a batch-dependent NaN from a
    # final-step-only check).
    bad = [h["step"] for h in hist
           if not (np.isfinite(h["loss"])
                   and np.isfinite(h.get("grad_norm", float("nan"))))]
    if bad:
        raise SystemExit(f"[train] FAIL: non-finite loss or grad_norm at "
                         f"steps {bad} — backward path broken")
    if args.int8:
        if not is_cnn:
            print("[train] --int8 ignored: LM arch has no int8 conv path")
        else:
            b = ds.batch_at(0)
            _int8_check(model, out["state"]["params"],
                        {"images": jnp.asarray(b["images"])})
    if getattr(args, "int5", False):
        if not is_cnn:
            print("[train] --int5 ignored: LM arch has no int5 conv path")
        else:
            b = ds.batch_at(0)
            _int5_check(model, out["state"]["params"],
                        {"images": jnp.asarray(b["images"])})


if __name__ == "__main__":
    main()
