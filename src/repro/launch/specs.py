"""input_specs(): ShapeDtypeStruct stand-ins for every (arch x shape) cell.

No device allocation anywhere — everything is eval_shape / ShapeDtypeStruct,
weak-type-correct and shardable, which is what lets the 512-device dry-run
lower full-size llama4/arctic/mistral-large graphs on a CPU host.

Shape-cell semantics (DESIGN.md §5):
- train_4k:    tokens (gb, S+1) — the step processes exactly S positions.
- prefill_32k: serve prefill over S tokens writing the KV/SSM caches.
- decode_32k:  ONE new token against caches of length S (lowers serve_step,
  not train_step). long_500k likewise at S=524288 (subquadratic archs only).
- vlm: text tokens are S - frontend_tokens; patch embeddings supplied.
- encdec: train splits S as S/2 source frames + S/2 target tokens; prefill
  encodes S source frames and primes the decoder; decode uses a fixed
  4096-frame cross-KV and an S-long self-KV.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeCell

SDS = jax.ShapeDtypeStruct

ENCDEC_DECODE_SRC = 4_096       # source frames for enc-dec decode cells
ENCDEC_PREFILL_TGT_BUF = 1_024  # decoder self-cache length at prefill


def train_batch_specs(cfg: ModelConfig, cell: ShapeCell) -> Dict[str, Any]:
    gb, S = cell.global_batch, cell.seq_len
    if cfg.family == "encdec":
        return {"src_embeds": SDS((gb, S // 2, cfg.d_model), cfg.dtype),
                "tokens": SDS((gb, S // 2 + 1), jnp.int32)}
    batch: Dict[str, Any] = {}
    if cfg.family == "vlm":
        n_img = cfg.frontend_tokens
        batch["extra_embeds"] = SDS((gb, n_img, cfg.d_model), cfg.dtype)
        batch["tokens"] = SDS((gb, S - n_img + 1), jnp.int32)
    else:
        batch["tokens"] = SDS((gb, S + 1), jnp.int32)
    return batch


def prefill_specs(cfg: ModelConfig, model, cell: ShapeCell,
                  ) -> Tuple[Dict[str, Any], Any]:
    """Returns (batch specs, cache specs)."""
    gb, S = cell.global_batch, cell.seq_len
    if cfg.family == "encdec":
        batch = {"src_embeds": SDS((gb, S, cfg.d_model), cfg.dtype),
                 "tokens": SDS((gb, 1), jnp.int32)}
        cache = jax.eval_shape(
            lambda: model.init_cache(gb, ENCDEC_PREFILL_TGT_BUF,
                                     cross_len=S, dtype=jnp.bfloat16))
        return batch, cache
    batch = {}
    if cfg.family == "vlm":
        n_img = cfg.frontend_tokens
        batch["extra_embeds"] = SDS((gb, n_img, cfg.d_model), cfg.dtype)
        batch["tokens"] = SDS((gb, S - n_img), jnp.int32)
    else:
        batch["tokens"] = SDS((gb, S), jnp.int32)
    cache = jax.eval_shape(
        lambda: model.init_cache(gb, S, dtype=jnp.bfloat16))
    return batch, cache


def decode_specs(cfg: ModelConfig, model, cell: ShapeCell,
                 ) -> Tuple[Dict[str, Any], Any]:
    """Returns ({token, pos}, cache specs) for one-token decode."""
    gb, S = cell.global_batch, cell.seq_len
    if cfg.family == "encdec":
        cache = jax.eval_shape(
            lambda: model.init_cache(gb, S, cross_len=ENCDEC_DECODE_SRC,
                                     dtype=jnp.bfloat16))
    else:
        cache = jax.eval_shape(
            lambda: model.init_cache(gb, S, dtype=jnp.bfloat16))
    batch = {"token": SDS((gb,), jnp.int32),
             "pos": SDS((), jnp.int32)}
    return batch, cache


def input_specs(cfg: ModelConfig, model, cell: ShapeCell):
    """Dispatch on the cell kind. Returns whatever the matching step
    builder consumes (documented per-kind above)."""
    if cell.kind == "train":
        return train_batch_specs(cfg, cell)
    if cell.kind == "prefill":
        return prefill_specs(cfg, model, cell)
    if cell.kind == "decode":
        return decode_specs(cfg, model, cell)
    raise ValueError(cell.kind)


def model_flops(cfg: ModelConfig, cell: ShapeCell) -> float:
    """MODEL_FLOPS for the roofline usefulness ratio: 6*N_active*D for a
    train step, 2*N_active*D for serve (D = tokens processed).

    enc-dec is split per stack: the encoder's params only see the source
    tokens and the decoder's only the target tokens (train splits the cell
    S/2+S/2; prefill runs S source frames + 1 target token)."""
    gb, S = cell.global_batch, cell.seq_len
    if cfg.family == "encdec":
        d = cfg.d_model
        attn = d * (cfg.n_q + 2 * cfg.n_kv) * cfg.head_dim \
            + cfg.n_q * cfg.head_dim * d
        width = 3 if cfg.mlp_kind in ("swiglu", "geglu") else 2
        mlp = width * d * cfg.d_ff
        enc_p = cfg.n_enc_layers * (attn + mlp)
        dec_p = cfg.n_layers * (2 * attn + mlp)   # self + cross attention
        emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
        mult = 6.0 if cell.kind == "train" else 2.0
        if cell.kind == "train":
            return mult * gb * (S // 2 * enc_p + S // 2 * (dec_p + emb))
        if cell.kind == "prefill":
            return mult * gb * (S * enc_p + 1 * (dec_p + emb))
        return mult * gb * (dec_p + emb)
    n_active = cfg.active_param_count_estimate()
    if cell.kind == "train":
        return 6.0 * n_active * gb * S
    if cell.kind == "prefill":
        return 2.0 * n_active * gb * S
    # decode: one token per sequence
    return 2.0 * n_active * gb
