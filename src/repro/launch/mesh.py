"""Production mesh builders.

A FUNCTION, not a module-level constant: importing this module must never
touch jax device state (the dry-run locks the device count via XLA_FLAGS
*before* any jax initialization).

Mesh geometry (DESIGN.md §6):
- single-pod: (16, 16) over ("data", "model") — 256 chips (one v5e pod).
- multi-pod:  (2, 16, 16) over ("pod", "data", "model") — 512 chips.
  The "pod" axis carries data parallelism by default (batch shards over
  ("pod", "data")); ``distributed.pipeline`` can repurpose it as a
  pipeline axis for >2-pod scaling.
"""
from __future__ import annotations

from typing import Optional

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: Optional[int] = None):
    """Small mesh over however many (host) devices exist — used by tests
    and the smoke examples."""
    n = len(jax.devices())
    model = model or 1
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))


#: v5e hardware constants used by the roofline (per chip).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW = 5.0e10                # bytes/s per link direction (~50 GB/s)
HBM_BYTES = 16 * 2 ** 30       # 16 GiB HBM per v5e chip
