"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \\
      --batch 4 --prompt-len 32 --gen 16

A thin shim over the shared serving core (``repro.serve.ServeEngine``,
DESIGN.md §8): the prefill and decode step executables are ahead-of-time
compiled once through the engine's backend/device-kind-stamped executable
cache (``jit().lower().compile()``, like the CNN bucket executables), so
the decode loop never retraces and no compile lands inside a timer.

Throughput accounting reports prefill latency and decode tok/s
*separately*: the old single ``tok/s`` number divided ``gen-1`` decode
steps by a timer that excluded prefill (and hid the first decode step's
compile inside it), overstating short-gen runs.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.distributed import activate_mesh
from repro.distributed.steps import make_decode_step, make_prefill_step
from repro.launch.cli import serve_config_from_args, serving_parent
from repro.launch.mesh import make_host_mesh
from repro.nn.models import build_model
from repro.serve import ServeEngine


def main() -> None:
    ap = argparse.ArgumentParser(parents=[serving_parent()])
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    # One config mapping shared with serve_cnn (launch.cli serving flags
    # -> ServeConfig); the LM loop's only "bucket" is its static decode
    # batch, so that field is pinned from --batch.
    serve_config = serve_config_from_args(args, buckets=(args.batch,),
                                          datapath="float")
    mesh = make_host_mesh(model=args.tp)
    model = build_model(cfg, tp=int(mesh.shape["model"]))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))

    eng = ServeEngine(name=f"lm-{cfg.name}", buckets=serve_config.buckets)
    shape_tag = f"b{args.batch} p{args.prompt_len}"
    with activate_mesh(mesh), mesh:
        params = model.init(jax.random.PRNGKey(0))
        if cfg.family == "encdec":
            src = jnp.asarray(rng.normal(
                size=(args.batch, args.prompt_len, cfg.d_model)), cfg.dtype)
            cache = model.init_cache(args.batch, max_len,
                                     cross_len=args.prompt_len,
                                     dtype=cfg.dtype)
            bos = jnp.zeros((args.batch, 1), jnp.int32)
            prefill = eng.executable(
                eng.executable_key(cfg.name, "prefill", shape_tag),
                lambda: jax.jit(model.prefill)
                .lower(params, src, bos, cache).compile())
            t0 = time.perf_counter()
            logits, cache = prefill(params, src, bos, cache)
            jax.block_until_ready(logits)
            prefill_s = time.perf_counter() - t0
            pos0 = 1
        else:
            cache = model.init_cache(args.batch, max_len, dtype=cfg.dtype)
            batch0 = {"tokens": jnp.asarray(prompts)}
            prefill = eng.executable(
                eng.executable_key(cfg.name, "prefill", shape_tag),
                lambda: jax.jit(make_prefill_step(model))
                .lower(params, batch0, cache).compile())
            t0 = time.perf_counter()
            logits, cache = prefill(params, batch0, cache)
            jax.block_until_ready(logits)
            prefill_s = time.perf_counter() - t0
            pos0 = args.prompt_len

        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens = [tok]
        decode_s = 0.0
        if args.gen > 1:
            # Compiled BEFORE the timed loop: the old code jitted lazily,
            # so the first decode step's compile landed inside the timer.
            decode = eng.executable(
                eng.executable_key(cfg.name, "decode", f"b{args.batch}"),
                lambda: jax.jit(make_decode_step(model))
                .lower(params, tok, cache, jnp.int32(pos0)).compile())
            t0 = time.perf_counter()
            for i in range(args.gen - 1):
                logits, cache = decode(params, tok, cache,
                                       jnp.int32(pos0 + i))
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                out_tokens.append(tok)
            jax.block_until_ready(tok)
            decode_s = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], 1)
    decode_tps = args.batch * (args.gen - 1) / max(decode_s, 1e-9)
    print(f"[serve] generated {gen.shape} tokens; prefill "
          f"{prefill_s * 1e3:.1f} ms (batch {args.batch}, prompt "
          f"{args.prompt_len}); decode {decode_tps:.1f} tok/s over "
          f"{args.gen - 1} steps (host-CPU decode, batch {args.batch})")
    print("[serve] sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
