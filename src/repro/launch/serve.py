"""Serving launcher: batched prefill + greedy decode loop.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.distributed import activate_mesh
from repro.distributed.steps import make_decode_step, make_prefill_step
from repro.launch.mesh import make_host_mesh
from repro.nn.models import build_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--tp", type=int, default=1)
    args = ap.parse_args()

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(model=args.tp)
    model = build_model(cfg, tp=int(mesh.shape["model"]))
    max_len = args.prompt_len + args.gen

    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len))

    with activate_mesh(mesh), mesh:
        params = model.init(jax.random.PRNGKey(0))
        if cfg.family == "encdec":
            src = jnp.asarray(rng.normal(
                size=(args.batch, args.prompt_len, cfg.d_model)), cfg.dtype)
            cache = model.init_cache(args.batch, max_len,
                                     cross_len=args.prompt_len,
                                     dtype=cfg.dtype)
            bos = jnp.zeros((args.batch, 1), jnp.int32)
            logits, cache = jax.jit(model.prefill)(params, src, bos, cache)
            pos0 = 1
        else:
            cache = model.init_cache(args.batch, max_len, dtype=cfg.dtype)
            prefill = jax.jit(make_prefill_step(model))
            logits, cache = prefill(params,
                                    {"tokens": jnp.asarray(prompts)}, cache)
            pos0 = args.prompt_len

        decode = jax.jit(make_decode_step(model))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        out_tokens = [tok]
        t0 = time.perf_counter()
        for i in range(args.gen - 1):
            logits, cache = decode(params, tok, cache,
                                   jnp.int32(pos0 + i))
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            out_tokens.append(tok)
        jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
    gen = np.stack([np.asarray(t) for t in out_tokens], 1)
    tps = args.batch * (args.gen - 1) / max(dt, 1e-9)
    print(f"[serve] generated {gen.shape} tokens; "
          f"{tps:.1f} tok/s (host-CPU decode, batch {args.batch})")
    print("[serve] sample:", gen[0][:16].tolist())


if __name__ == "__main__":
    main()
