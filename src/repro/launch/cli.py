"""Shared CLI surface for the CNN launchers (dryrun_cnn / train).

One argparse *parent* carries the execution flags both launchers used to
re-declare (arch selection, ``--substrate`` / the deprecated
``--force-pallas`` alias, ``--emulate-hw``, ``--int8``, ``--int5``,
``--tuning``), mapped onto a single
:meth:`repro.engine.ExecutionPolicy.from_args`.
"""

from __future__ import annotations

import argparse
import warnings
from typing import Optional, Sequence

from repro.engine import SUBSTRATES, TUNING_MODES, ExecutionPolicy


class _DeprecatedSubstrateAlias(argparse.Action):
    """Store a substrate constant while warning that the flag is legacy —
    the CLI counterpart of ``policy_from_legacy``'s kwarg shims."""

    def __init__(self, option_strings, dest, const=None, **kw):
        super().__init__(option_strings, dest, nargs=0, const=const, **kw)

    def __call__(self, parser, namespace, values, option_string=None):
        warnings.warn(
            f"{option_string} is deprecated; use --substrate {self.const}",
            DeprecationWarning,
            stacklevel=2,
        )
        setattr(namespace, self.dest, self.const)


def execution_parent(
    arch_choices: Optional[Sequence[str]] = None,
    arch_default: Optional[str] = None,
    arch_required: bool = False,
) -> argparse.ArgumentParser:
    """Parent parser with the shared CNN execution flags.

    ``--substrate`` picks the kernel substrate (auto / pallas / oracle /
    interpret — resolved by ``ExecutionPolicy.resolved_substrate``, the one
    dispatch rule); ``--force-pallas`` is kept as a deprecated alias that
    stores "pallas" into the same destination.  ``--emulate-hw`` selects
    the FPGA-faithful strided-layer replay (paper §V) and ``--int8`` asks
    the launcher to also exercise the fused int8 inference datapath.
    ``--tuning {off,cached,auto}`` selects per-layer plan tuning
    (``repro.engine.autotune``): "cached" applies persisted autotuner
    winners from ``tuned_plans/``, "auto" tunes on a cache miss and
    persists the winner.
    """
    p = argparse.ArgumentParser(add_help=False)
    if arch_required:
        p.add_argument("--arch", required=True, help="architecture id")
    else:
        p.add_argument(
            "--arch",
            default=arch_default,
            choices=sorted(arch_choices) if arch_choices else None,
            help="architecture id",
        )
    p.add_argument(
        "--substrate",
        choices=list(SUBSTRATES),
        default="auto",
        help="kernel substrate: auto (TPU->compiled Pallas, CPU->oracle), "
        "pallas (Pallas everywhere; interpret mode off-TPU), oracle, "
        "interpret, or f32exact (integer convs exactly on the f32 conv "
        "path)",
    )
    p.add_argument(
        "--force-pallas",
        dest="substrate",
        action=_DeprecatedSubstrateAlias,
        const="pallas",
        help="deprecated alias for --substrate pallas (warns)",
    )
    p.add_argument(
        "--emulate-hw",
        action="store_true",
        help="FPGA-faithful strided layers: stride-1 sweep + decimation + "
        "unfused epilogue (paper §V) instead of the stride-aware fused "
        "kernel",
    )
    p.add_argument(
        "--int8",
        action="store_true",
        help="also run/compile the int8 inference datapath with the fused "
        "arbitrary-scale requant epilogue",
    )
    p.add_argument(
        "--int5",
        action="store_true",
        help="the MSR-compressed int5 weight lane (sign + 4-bit "
        "most-significant-run codes with expect-value compensation, "
        "DESIGN.md §9.3): same fused epilogues as --int8 off 5-bit-stored "
        "weights; takes precedence over --int8 where both select a serving "
        "datapath",
    )
    p.add_argument(
        "--tuning",
        choices=list(TUNING_MODES),
        default="off",
        help="per-layer plan tuning: off (policy defaults), cached (apply "
        "persisted autotuner winners from tuned_plans/; miss -> default "
        "plan), auto (tune on miss, then persist — see "
        "benchmarks.autotune)",
    )
    return p


def policy_from_args(args: argparse.Namespace) -> ExecutionPolicy:
    """One place mapping parsed launcher args -> ExecutionPolicy."""
    return ExecutionPolicy.from_args(args)


def serving_parent(
    buckets_default: str = "1,4,16,64",
    max_delay_ms_default: float = 5.0,
) -> argparse.ArgumentParser:
    """Parent parser with the shared serving flags (DESIGN.md §8).

    Both serving launchers (``serve_cnn``, ``serve``) mount this and map
    it through ``ServeConfig.from_args`` — one flag surface, one mapping
    (the serving mirror of ``execution_parent`` ->
    ``ExecutionPolicy.from_args``).  ``--queue-capacity`` bounds the
    admission queue (0 = unbounded) and ``--overload`` picks what a full
    queue does: block producers (backpressure), shed the request, or
    degrade to eager smaller-bucket flushes.  ``--producers`` drives the
    threaded closed/open-loop load mode (0 = the deterministic inline
    open loop); it is a load-generation knob, not a ServeConfig field.
    """
    from repro.serve.config import OVERLOAD_POLICIES

    p = argparse.ArgumentParser(add_help=False)
    p.add_argument("--buckets", default=buckets_default,
                   help="static batch buckets, comma-separated")
    p.add_argument("--max-delay-ms", type=float,
                   default=max_delay_ms_default,
                   help="deadline: oldest request ships within this")
    p.add_argument("--queue-capacity", type=int, default=0,
                   help="bounded admission queue (backpressure); "
                        "0 = unbounded")
    p.add_argument("--overload", choices=list(OVERLOAD_POLICIES),
                   default="block",
                   help="full-queue policy: block producers, shed the "
                        "request, or degrade to eager smaller-bucket "
                        "flushes")
    p.add_argument("--request-timeout-ms", type=float, default=None,
                   help="per-request deadline: queued work older than "
                        "this is expired, never served stale")
    p.add_argument("--producers", type=int, default=0,
                   help="producer threads submitting concurrently "
                        "(0 = single-threaded inline open loop)")
    p.add_argument("--faults", default=None, metavar="SPEC",
                   help="arm the seeded fault-injection plane "
                        "(DESIGN.md §11): comma-separated budgets, e.g. "
                        "'seed=7,stage=2,worker=1,bitflip=1,exec=2,"
                        "nonfinite=1,latency=1,latency-ms=50'; omitted = "
                        "the plane is compiled out (zero cost)")
    p.add_argument("--breaker-threshold", type=int, default=None,
                   help="consecutive batch failures per (arch, lane, "
                        "bucket) before the circuit breaker trips and "
                        "serving degrades to the next lane")
    return p


def serve_config_from_args(args: argparse.Namespace, **overrides):
    """One place mapping parsed serving args -> ServeConfig."""
    from repro.serve.config import ServeConfig

    return ServeConfig.from_args(args, **overrides)
