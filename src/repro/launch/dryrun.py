import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any jax import (jax locks the device
# count on first init). Tests may scale the dry-run down via env var —
# still set before jax initializes:
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Multi-pod dry-run: lower + compile every (architecture x input shape x
mesh) cell with 512 placeholder host devices, and record the artifacts the
roofline analysis reads (memory_analysis, cost_analysis, collective bytes).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                  # all cells
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma-7b \
      --shape train_4k --multi-pod                              # one cell
  ... --out experiments/dryrun                                  # artifacts

Every failure here (sharding mismatch, OOM at compile, unsupported
collective) is a bug in the system, not in the dry-run.
"""
import argparse
import json
import math
import time
import traceback
from typing import Any, Dict, Optional

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, shape_cells
from repro.configs.base import ModelConfig, ShapeCell
from repro.distributed.sharding import activate_mesh, fsdp_pspec, param_pspec
from repro.distributed.steps import (StepConfig, batch_pspec, cache_pspec,
                                     make_decode_step, make_prefill_step,
                                     make_train_step, state_pspec,
                                     train_state_shapes, _to_shardings)
from repro.launch.hlo_stats import (collective_stats, cost_dict,
                                    hbm_bytes_estimate,
                                    total_collective_bytes)
from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.launch.specs import input_specs, model_flops
from repro.nn.models import build_model


def scaled_mesh(multi_pod: bool):
    """Production mesh, or a proportionally scaled one when the dry-run
    device count was overridden (REPRO_DRYRUN_DEVICES, tests only)."""
    n = len(jax.devices())
    if n >= 512:
        return make_production_mesh(multi_pod=multi_pod)
    # scale down, keeping the axis structure
    if multi_pod:
        pod = 2
        rest = n // pod
        side = int(math.sqrt(rest))
        while rest % side:
            side -= 1
        return jax.make_mesh((pod, rest // side, side),
                             ("pod", "data", "model"))
    side = int(math.sqrt(n))
    while n % side:
        side -= 1
    return jax.make_mesh((n // side, side), ("data", "model"))


def build_cell(cfg: ModelConfig, cell: ShapeCell, mesh, fsdp: bool = False,
               accum: int = 1):
    """Returns (fn, example_args (SDS pytrees), in_shardings, out_shardings)."""
    tp = mesh.shape["model"]
    model = build_model(cfg, tp=tp)
    # "2d" serve layout: batch replicated over data (only pod, if present);
    # the data axis carries the weight 2D shard + the KV sequence shard.
    serve_2d = (cell.kind == "decode"
                and getattr(cfg, "decode_kv_seqshard", "") == "2d")
    extra_rules = {"batch": (("pod",),)} if serve_2d else None
    with activate_mesh(mesh, extra_rules=extra_rules) as ctx:
        if cell.kind == "train":
            batch = input_specs(cfg, model, cell)
            shapes = train_state_shapes(model)
            sspec = state_pspec(shapes, ctx, fsdp=fsdp)
            bspec = batch_pspec(batch, ctx)
            fn = make_train_step(model, StepConfig(accum=accum), mesh)
            args = (shapes, batch)
            in_sh = (_to_shardings(sspec, mesh), _to_shardings(bspec, mesh))
            out_sh = (_to_shardings(sspec, mesh), None)
        elif cell.kind == "prefill":
            batch, cache = input_specs(cfg, model, cell)
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            pspec = (fsdp_pspec if fsdp else param_pspec)(pshapes, ctx)
            bspec = batch_pspec(batch, ctx)
            cspec = cache_pspec(cache, ctx)
            fn = make_prefill_step(model)
            args = (pshapes, batch, cache)
            in_sh = (_to_shardings(pspec, mesh), _to_shardings(bspec, mesh),
                     _to_shardings(cspec, mesh))
            out_sh = (None, _to_shardings(cspec, mesh))
        elif cell.kind == "decode":
            batch, cache = input_specs(cfg, model, cell)
            pshapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
            if serve_2d and fsdp:
                # 2D weight sharding: TP dim over model, other dim over
                # data (pod stays free for batch) -> partial-sum matmuls
                pspec = fsdp_pspec(pshapes, ctx, dp_axes=("data",))
            else:
                pspec = (fsdp_pspec if fsdp else param_pspec)(pshapes, ctx)
            cspec = cache_pspec(cache, ctx)
            fn = make_decode_step(model)
            args = (pshapes, batch["token"], cache, batch["pos"])
            tok_sh = NamedSharding(mesh, batch_pspec(
                {"t": batch["token"]}, ctx)["t"])
            in_sh = (_to_shardings(pspec, mesh), tok_sh,
                     _to_shardings(cspec, mesh), NamedSharding(mesh, P()))
            out_sh = (None, _to_shardings(cspec, mesh))
        else:
            raise ValueError(cell.kind)
    return fn, args, in_sh, out_sh


def _cell_costs(cfg: ModelConfig, cell: ShapeCell, mesh,
                fsdp: bool = False) -> Dict[str, float]:
    """flops / bytes / collective_bytes of one compiled variant."""
    fn, args, in_sh, out_sh = build_cell(cfg, cell, mesh, fsdp=fsdp)
    with activate_mesh(mesh), mesh:
        compiled = jax.jit(fn, in_shardings=in_sh,
                           out_shardings=out_sh).lower(*args).compile()
    cost = cost_dict(compiled.cost_analysis())
    hlo = compiled.as_text()
    stats = collective_stats(hlo)
    out = {"flops": float(cost.get("flops", 0.0)),
           "bytes": float(cost.get("bytes accessed", 0.0)),
           "collective_bytes": total_collective_bytes(hlo)}
    for op, s in stats.items():
        out[f"coll_{op}"] = s["bytes"]
    return out


def calibrated_costs(cfg: ModelConfig, cell: ShapeCell, mesh,
                     fsdp: bool = False) -> Dict[str, float]:
    """Exact per-device cost of the FULL model, extrapolated linearly from
    small *unrolled* variants (XLA cost_analysis counts a while/scan body
    once, so the scanned artifact's numbers undercount by the trip count;
    layer costs are exactly additive, so const + n_periods * per_period
    from unrolled 2- and 4-period compiles recovers the true total)."""
    from repro.nn.models import decoder_schedule
    period = len(decoder_schedule(cfg)[0])

    def variant(n_lay: int, n_enc: int = 0) -> Dict[str, float]:
        over = {"n_layers": n_lay, "scan_layers": False}
        if cfg.family == "encdec":
            over["n_enc_layers"] = n_enc
        return _cell_costs(cfg.with_overrides(**over), cell, mesh,
                           fsdp=fsdp)

    def keys_of(*ds):
        return sorted(set().union(*[d.keys() for d in ds]))

    if cfg.family == "encdec":
        c22 = variant(2, 2)
        c42 = variant(4, 2)
        c24 = variant(2, 4)
        out = {}
        for k in keys_of(c22, c42, c24):
            per_dec = (c42.get(k, 0) - c22.get(k, 0)) / 2
            per_enc = (c24.get(k, 0) - c22.get(k, 0)) / 2
            const = c22.get(k, 0) - 2 * per_dec - 2 * per_enc
            out[k] = max(const + cfg.n_layers * per_dec
                         + cfg.n_enc_layers * per_enc, 0.0)
        return out
    c2 = variant(2 * period)
    c4 = variant(4 * period)
    n_periods = cfg.n_layers // period
    out = {}
    for k in keys_of(c2, c4):
        per = (c4.get(k, 0) - c2.get(k, 0)) / 2
        const = c2.get(k, 0) - 2 * per
        out[k] = max(const + n_periods * per, 0.0)
    return out


def run_cell(arch: str, cell: ShapeCell, multi_pod: bool,
             save_hlo: Optional[str] = None, fsdp: bool = False,
             cfg_overrides: Optional[Dict[str, Any]] = None,
             accum: int = 1) -> Dict[str, Any]:
    cfg = get_config(arch)
    if cfg_overrides:
        cfg = cfg.with_overrides(**cfg_overrides)
    fsdp = fsdp or getattr(cfg, "fsdp", False)
    mesh = scaled_mesh(multi_pod)
    chips = mesh.size
    record: Dict[str, Any] = {
        "arch": arch, "shape": cell.name, "kind": cell.kind,
        "mesh": {ax: int(mesh.shape[ax]) for ax in mesh.axis_names},
        "chips": chips, "multi_pod": multi_pod,
    }
    record["fsdp"] = fsdp
    record["accum"] = accum
    if cfg_overrides:
        record["cfg_overrides"] = {k: str(v) for k, v in
                                   cfg_overrides.items()}
    t0 = time.time()
    fn, args, in_sh, out_sh = build_cell(cfg, cell, mesh, fsdp=fsdp,
                                         accum=accum)
    with activate_mesh(mesh), mesh:
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower
    record["lower_s"] = round(t_lower, 2)
    record["compile_s"] = round(t_compile, 2)

    try:
        mem = compiled.memory_analysis()
        record["memory"] = hbm_bytes_estimate(mem)
    except Exception as e:  # pragma: no cover
        record["memory"] = {"error": str(e)}
    try:
        cost = cost_dict(compiled.cost_analysis())
        record["cost"] = {k: float(v) for k, v in cost.items()
                          if isinstance(v, (int, float))
                          and k in ("flops", "bytes accessed",
                                    "transcendentals", "optimal_seconds")}
    except Exception as e:  # pragma: no cover
        record["cost"] = {"error": str(e)}

    hlo = compiled.as_text()
    record["collectives"] = collective_stats(hlo)
    record["collective_bytes_raw"] = total_collective_bytes(hlo)
    if save_hlo:
        with open(save_hlo, "w") as f:
            f.write(hlo)
    del hlo

    # --- calibrated per-device costs (scan-trip-count-exact) ---
    calib = calibrated_costs(cfg, cell, mesh, fsdp=fsdp)
    record["cost_calibrated"] = calib
    record["collective_bytes"] = calib.get("collective_bytes", 0.0)

    # --- roofline terms (per step, v5e constants) ---
    # cost_analysis on a partitioned module reports PER-DEVICE numbers
    flops = calib.get("flops", 0.0)
    bytes_acc = calib.get("bytes", 0.0)
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_acc / HBM_BW
    collective_s = record["collective_bytes"] / ICI_BW
    mf = model_flops(cfg, cell)
    record["roofline"] = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": max((("compute", compute_s), ("memory", memory_s),
                         ("collective", collective_s)),
                        key=lambda kv: kv[1])[0],
        "model_flops_total": mf,
        "model_flops_per_chip": mf / chips,
        "useful_flops_ratio": (mf / chips) / flops if flops else 0.0,
        "step_time_bound_s": max(compute_s, memory_s, collective_s),
    }
    # per-device HBM check: XLA's peak-memory estimate (live-set peak over
    # the buffer assignment) where available; else arguments + outputs.
    # CPU buffer assignment lacks TPU-grade fusion, so this is conservative.
    mem = record.get("memory", {})
    peak = mem.get("peak_memory_in_bytes", 0)
    args_b = mem.get("argument_size_in_bytes", 0)
    per_dev = max(peak, args_b) or (args_b + mem.get(
        "output_size_in_bytes", 0))
    record["fits_hbm"] = bool(per_dev <= HBM_BYTES) if per_dev else None
    record["per_device_bytes"] = per_dev
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id (default: all)")
    ap.add_argument("--shape", default=None, help="shape cell (default: all)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true",
                    help="run single-pod AND multi-pod")
    ap.add_argument("--out", default="experiments/dryrun",
                    help="artifact directory")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="FSDP/ZeRO-3 parameter sharding over the DP axes")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        cfg = get_config(arch)
        cells = [c for c in shape_cells(cfg)
                 if args.shape is None or c.name == args.shape]
        for cell in cells:
            for mp in meshes:
                tag = f"{arch}__{cell.name}__{'multi' if mp else 'single'}"
                hlo_path = (os.path.join(args.out, tag + ".hlo.txt")
                            if args.save_hlo else None)
                print(f"[dryrun] {tag} ...", flush=True)
                try:
                    rec = run_cell(arch, cell, mp, save_hlo=hlo_path,
                                   fsdp=args.fsdp)
                except Exception as e:
                    print(f"[dryrun] FAIL {tag}: {e}")
                    traceback.print_exc()
                    failures.append(tag)
                    continue
                finally:
                    jax.clear_caches()   # keep single-process RSS bounded
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
                r = rec["roofline"]
                print(f"[dryrun]   ok: compile {rec['compile_s']:.1f}s  "
                      f"compute {r['compute_s']*1e3:.2f}ms  "
                      f"memory {r['memory_s']*1e3:.2f}ms  "
                      f"collective {r['collective_s']*1e3:.2f}ms  "
                      f"dominant={r['dominant']}  "
                      f"useful={r['useful_flops_ratio']:.2f}", flush=True)
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {failures}")
    print(f"[dryrun] all cells passed.")


if __name__ == "__main__":
    main()
