import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count="
                               + os.environ["REPRO_DRYRUN_DEVICES"])

"""Bonus dry-run: the paper's own CNN workloads (VGG-16 / AlexNet) as a
pod-scale data-parallel training step through the TrIM conv path.

  PYTHONPATH=src python -m repro.launch.dryrun_cnn --arch vgg16

Execution flags (``--substrate`` / ``--emulate-hw`` / ``--int8`` /
``--tuning``) come from the shared launcher parent (``launch.cli``) and
map onto one ``ExecutionPolicy``; the resolved per-layer plan (substrate,
width tile, epilogue kind, tuned flag) is recorded in the emitted JSON —
with ``--tuning cached`` each layer runs the autotuner's persisted winner
(DESIGN.md §7).  ``--int8`` additionally
compiles the integer inference datapath with the arbitrary-scale fused
requant epilogue (DESIGN.md §4) and emits a second roofline record;
``--int5`` does the same for the MSR-compressed weight lane
(DESIGN.md §9.3).
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import CNN_REGISTRY
from repro.distributed.sharding import activate_mesh
from repro.engine import plan_model
from repro.launch.cli import execution_parent, policy_from_args
from repro.launch.dryrun import scaled_mesh
from repro.launch.hlo_stats import (collective_stats, cost_dict,
                                    hbm_bytes_estimate,
                                    total_collective_bytes)
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16
from repro.nn.conv import cnn_forward_int8, cnn_loss, init_cnn
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.core.trim.model import layer_ops


def _int_record(cfg, args, mesh, dp, policy, datapath="int8"):
    """Compile an integer inference forward (fused multiplier+shift requant
    in every non-last layer) and derive its roofline.  Requant constants
    are placeholder calibrations — the dry-run only studies the compiled
    schedule, not accuracy.  ``datapath="int5"`` compiles the MSR weight
    lane instead (per-channel exponent operands, DESIGN.md §9.3)."""
    H, W = cfg.input_hw
    int5 = datapath == "int5"
    qshapes = {"conv": [
        dict({"kernel": jax.ShapeDtypeStruct((l.K, l.K, l.M, l.N),
                                             jnp.int8)},
             **({"shift": jax.ShapeDtypeStruct((l.N,), jnp.int32)}
                if int5 else {}))
        for l in cfg.layers]}
    requant = [(jnp.full((l.N,), 16384, jnp.int32),
                jnp.full((l.N,), 20, jnp.int32)) for l in cfg.layers[:-1]]
    imgs = jax.ShapeDtypeStruct((args.batch, H, W, cfg.layers[0].M),
                                jnp.uint8)
    mplan = plan_model(cfg, policy)

    def infer(qp, u8):
        if int5:
            return mplan.forward_int5(qp, u8, requant=requant)
        return cnn_forward_int8(qp, u8, cfg, requant=requant, policy=policy)

    rep = jax.tree.map(lambda _: NamedSharding(mesh, P()), qshapes)
    ish = NamedSharding(mesh, P(dp))
    t0 = time.time()
    with activate_mesh(mesh), mesh:
        compiled = jax.jit(infer, in_shardings=(rep, ish)).lower(
            qshapes, imgs).compile()
    hlo = compiled.as_text()
    cost = cost_dict(compiled.cost_analysis())
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = total_collective_bytes(hlo)
    conv_flops = sum(layer_ops(l) for l in cfg.layers) * args.batch
    times = {"compute": flops / PEAK_FLOPS_BF16, "memory": byts / HBM_BW,
             "collective": coll / ICI_BW}
    return {
        "arch": cfg.name, "shape": f"{datapath}_infer_{H}x{W}_b{args.batch}",
        "kind": f"{datapath}_infer", "chips": mesh.size,
        "multi_pod": args.multi_pod,
        "mesh": {ax: int(mesh.shape[ax]) for ax in mesh.axis_names},
        "plan": list((mplan.int5 if int5 else mplan.int8).describe()),
        "compile_s": round(time.time() - t0, 1),
        "memory": hbm_bytes_estimate(compiled.memory_analysis()),
        "cost": {"flops": flops, "bytes accessed": byts},
        "collectives": collective_stats(hlo),
        "collective_bytes": coll,
        "roofline": {
            "compute_s": times["compute"],
            "memory_s": times["memory"],
            "collective_s": times["collective"],
            "dominant": max(times, key=times.get),
            "model_flops_total": conv_flops,
            "useful_flops_ratio": (conv_flops / mesh.size) / flops
            if flops else 0.0,
        },
    }


def main() -> None:
    ap = argparse.ArgumentParser(parents=[execution_parent(
        arch_choices=CNN_REGISTRY, arch_default="vgg16")])
    ap.add_argument("--batch", type=int, default=256)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    policy = policy_from_args(args)
    cfg = CNN_REGISTRY[args.arch]
    mesh = scaled_mesh(args.multi_pod)
    chips = mesh.size

    def train_step(state, batch):
        params, opt = state
        (loss, mets), g = jax.value_and_grad(
            lambda p: cnn_loss(p, batch, cfg, policy=policy),
            has_aux=True)(params)
        params, opt, _ = adamw_update(g, opt, params, 1e-3, AdamWConfig())
        return (params, opt), loss

    pshapes = jax.eval_shape(lambda k: init_cnn(k, cfg),
                             jax.random.PRNGKey(0))
    oshapes = jax.eval_shape(adamw_init, pshapes)
    H, W = cfg.input_hw
    batch = {
        "images": jax.ShapeDtypeStruct(
            (args.batch, H, W, cfg.layers[0].M), jnp.float32),
        "labels": jax.ShapeDtypeStruct((args.batch,), jnp.int32)}

    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    rep = jax.tree.map(lambda _: NamedSharding(mesh, P()),
                       (pshapes, oshapes))
    bsh = {"images": NamedSharding(mesh, P(dp)),
           "labels": NamedSharding(mesh, P(dp))}

    t0 = time.time()
    with activate_mesh(mesh), mesh:
        compiled = jax.jit(train_step, in_shardings=(rep, bsh),
                           out_shardings=(rep, None)).lower(
            (pshapes, oshapes), batch).compile()
    hlo = compiled.as_text()
    cost = cost_dict(compiled.cost_analysis())
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    coll = total_collective_bytes(hlo)
    conv_flops = 3 * sum(layer_ops(l) for l in cfg.layers) * args.batch
    rec = {
        "arch": args.arch, "shape": f"train_{H}x{W}_b{args.batch}",
        "kind": "train", "chips": chips, "emulate_hw": args.emulate_hw,
        "mesh": {ax: int(mesh.shape[ax]) for ax in mesh.axis_names},
        "plan": list(plan_model(cfg, policy).describe()),
        "compile_s": round(time.time() - t0, 1),
        "memory": hbm_bytes_estimate(compiled.memory_analysis()),
        "cost": {"flops": flops, "bytes accessed": byts},
        "collectives": collective_stats(hlo),
        "collective_bytes": coll,
        "roofline": {
            "compute_s": flops / PEAK_FLOPS_BF16,
            "memory_s": byts / HBM_BW,
            "collective_s": coll / ICI_BW,
            "dominant": max(
                (("compute", flops / PEAK_FLOPS_BF16),
                 ("memory", byts / HBM_BW),
                 ("collective", coll / ICI_BW)), key=lambda kv: kv[1])[0],
            "model_flops_total": conv_flops,
            "useful_flops_ratio": (conv_flops / chips) / flops
            if flops else 0.0,
        },
    }
    os.makedirs(args.out, exist_ok=True)
    tag = (f"{args.arch}__cnn_train__"
           f"{'multi' if args.multi_pod else 'single'}"
           f"{'__emuhw' if args.emulate_hw else ''}")
    with open(os.path.join(args.out, tag + ".json"), "w") as f:
        json.dump(rec, f, indent=1)
    r = rec["roofline"]
    print(f"[dryrun_cnn] {tag}: compile {rec['compile_s']}s  "
          f"compute {r['compute_s']*1e3:.1f}ms  memory "
          f"{r['memory_s']*1e3:.1f}ms  collective "
          f"{r['collective_s']*1e3:.1f}ms  useful "
          f"{r['useful_flops_ratio']:.2f}")

    lanes = ([("int8", args.int8)]
             + [("int5", getattr(args, "int5", False))])
    for datapath, wanted in lanes:
        if not wanted:
            continue
        irec = _int_record(cfg, args, mesh, dp, policy, datapath)
        itag = (f"{args.arch}__cnn_{datapath}__"
                f"{'multi' if args.multi_pod else 'single'}")
        with open(os.path.join(args.out, itag + ".json"), "w") as f:
            json.dump(irec, f, indent=1)
        ir = irec["roofline"]
        print(f"[dryrun_cnn] {itag}: compile {irec['compile_s']}s  "
              f"compute {ir['compute_s']*1e3:.1f}ms  memory "
              f"{ir['memory_s']*1e3:.1f}ms  collective "
              f"{ir['collective_s']*1e3:.1f}ms")


if __name__ == "__main__":
    main()
