"""Collective-traffic accounting from compiled (post-SPMD) HLO text.

``cost_analysis()`` does not expose collective bytes, so the roofline's
collective term is derived here: every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute op is matched and its
per-device wire bytes estimated with the standard ring model:

- all-reduce:          2 x operand bytes   (reduce-scatter + all-gather)
- all-gather:          result bytes        (each device receives ~(n-1)/n)
- reduce-scatter:      operand bytes
- all-to-all:          operand bytes
- collective-permute:  operand bytes

Shapes in compiled HLO are already per-device (post-partitioning), so the
sums are per-device wire bytes per step. Async pairs (-start/-done) are
counted once via the -start op.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")

_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
        "collective-permute")

# '%name = <result> <op>(<operands>)'
_LINE_RE = re.compile(
    r"=\s*(?P<result>.*?)\s+"
    r"(?P<op>" + "|".join(_OPS) + r")(?P<async>-start)?\("
    r"(?P<operands>[^)]*)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-op-type {bytes, count} from compiled HLO text (per device)."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"bytes": 0.0, "count": 0})
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        # skip the -done halves of async pairs (the -start carries shapes)
        if f"{op}-done" in line:
            continue
        if op == "all-gather":
            nbytes = _shape_bytes(m.group("result"))
        else:
            nbytes = _shape_bytes(m.group("operands"))
        if op == "all-reduce":
            nbytes *= 2
        stats[op]["bytes"] += nbytes
        stats[op]["count"] += 1
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(v["bytes"] for v in collective_stats(hlo_text).values())


def hbm_bytes_estimate(memory_analysis) -> Dict[str, float]:
    """Pull the useful fields out of compiled.memory_analysis()."""
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "peak_memory_in_bytes"):
        val = getattr(memory_analysis, field, None)
        if val is not None:
            out[field] = float(val)
    return out


def cost_dict(cost) -> dict:
    """``Compiled.cost_analysis()`` compat: newer jax returns a dict,
    0.4.x returns a one-element list of dicts."""
    if isinstance(cost, (list, tuple)):
        return dict(cost[0]) if cost else {}
    return cost
