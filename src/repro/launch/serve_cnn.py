"""Production CNN serving CLI on the shared serving core (DESIGN.md §8).

  PYTHONPATH=src python -m repro.launch.serve_cnn --arch vgg16 --smoke \\
      --buckets 1,4,16 --requests 64 --rate 200 --max-delay-ms 5 \\
      --producers 4 --queue-capacity 32 --overload block

Builds one ``repro.serve.Server`` from a frozen ``ServeConfig``
(``launch.cli.serving_parent`` flags -> ``ServeConfig.from_args``, the
one mapping both serving launchers share).  The server AOT-compiles one
executable per (ModelPlan, batch bucket) up front
(``ModelPlan.executable_for`` -> ``jit().lower().compile()``, so the
request stream cannot retrace), then serves a deterministic synthetic
request stream (``data.pipeline.SyntheticRequestStream``) through
pad-and-bucket admission with deadline flush — single-threaded inline
(``--producers 0``, deterministic) or through ``--producers N`` real
producer threads feeding the dedicated flush worker (double-buffered
host<->device staging; bounded queue + ``--overload`` policy).

Execution flags (``--substrate`` / ``--int8`` / ``--int5`` / ``--tuning``)
come from the shared launcher parent (``launch.cli``) — ``--tuning
cached`` plans each bucket off its batch-specific persisted autotuner
winners; ``--int8`` serves the fused integer datapath off calibrated
per-channel requant pairs (the only batch-shape-independent int8 lane);
``--int5`` serves the same fused datapath off MSR-compressed 5-bit-stored
weights (DESIGN.md §9.3).  ``--check`` (the CI
serve-smoke / serve-stress / chaos-smoke gate) exits non-zero unless
extended request conservation holds (served + shed + expired + failed ==
submitted, no request left pending), metrics are non-empty, no
executable compiled more than once — and, in the deterministic inline
mode, every bucket flushed at least once; on failure it also dumps the
admission ledger (every request's terminal state + the fault ledger) as
JSON to stderr.

``--faults SPEC`` arms the seeded fault-injection plane (DESIGN.md §11)
and the degradation ladder behind it: injected stage/compile/executable
faults, worker crashes, int5 wire bit-flips, NaN batches, and latency
spikes, recovered by bounded retries, the watchdog, checksummed-weight
restore, and the circuit breaker's lane degradation.
"""

import argparse
import json
import sys

import jax

from repro.configs import CNN_REGISTRY, CNN_SMOKES
from repro.data.pipeline import SyntheticRequestStream
from repro.engine import plan_model
from repro.launch.cli import (execution_parent, policy_from_args,
                              serve_config_from_args, serving_parent)
from repro.serve import Lane, PackedWire, Server


def make_stream(cfg, args, buckets):
    """The synthetic request stream for one serve run: the bursts process
    cycles the bucket sizes (with gaps past the flush deadline), so every
    bucket flushes at least once — what the CI smoke asserts."""
    return SyntheticRequestStream(
        hw=cfg.input_hw,
        channels=cfg.layers[0].M,
        n_classes=cfg.n_classes,
        n_requests=args.requests,
        rate_hz=args.rate,
        seed=args.seed,
        process=args.arrival,
        burst_sizes=tuple(buckets),
        gap_s=4.0 * args.max_delay_ms / 1e3,
        dtype="uint8" if (args.int8 or getattr(args, "int5", False))
        else "float32",
    )


def build_server(cfg, policy, serve_config, *, seed=0, calib_batch=8):
    """ModelPlan -> params (+ integer quantization/calibration) -> warm
    Server (every bucket executable compiled before the first request).

    The integer datapaths quantize the freshly-initialized float params
    (int8: symmetric per-tensor weights; int5: the MSR-compressed lane,
    DESIGN.md §9.3) and calibrate per-channel requant pairs on a sample
    burst — both requirements of bit-faithful padded-bucket serving.

    With ``--faults`` armed the server also carries its degradation
    ladder (DESIGN.md §11.3): int5 serves off the checksummed
    ``PackedWire`` payload with an int8 fallback lane (calibrated off the
    same float master, so degraded outputs are a native int8 server's);
    int8/float get a substrate sibling (f32exact / oracle — bit-identical
    numerics, throughput-only sacrifice)."""
    plan = plan_model(cfg, policy)
    params = plan.init(jax.random.PRNGKey(seed))
    armed = serve_config.faults is not None
    if serve_config.datapath == "float":
        fallbacks = [Lane("float-oracle", "float", params,
                          substrate="oracle")] if armed else None
        return Server.from_plan(plan, params, serve_config,
                                fallbacks=fallbacks)
    sample = SyntheticRequestStream(
        hw=cfg.input_hw, channels=cfg.layers[0].M, n_classes=cfg.n_classes,
        seed=seed, dtype="uint8").sample_batch(calib_batch)
    if serve_config.datapath == "int5":
        qparams, _ = plan.quantize_int5(params)
        requant = plan.calibrate_requant_int5(qparams, sample)
        fallbacks = wire = None
        if armed:
            wire = PackedWire(cfg, params)
            q8, _ = plan.quantize(params)
            fallbacks = [Lane("int8", "int8", q8,
                              plan.calibrate_requant(q8, sample))]
        return Server.from_plan(plan, qparams, serve_config,
                                requant=requant, fallbacks=fallbacks,
                                wire=wire)
    qparams, _ = plan.quantize(params)
    requant = plan.calibrate_requant(qparams, sample)
    fallbacks = [Lane("int8-f32exact", "int8", qparams, requant,
                      substrate="f32exact")] if armed else None
    return Server.from_plan(plan, qparams, serve_config, requant=requant,
                            fallbacks=fallbacks)


def check_run(server, metrics, n_requests, *, expect_all_buckets) -> list:
    """The --check assertions; returns a list of failure strings.

    Extended conservation (DESIGN.md §11.4) is the invariant that must
    hold in every mode, fault plane armed or not: every submitted
    request ends in exactly one terminal state.  Per-bucket
    flush coverage is only deterministic in the inline open loop (the
    bursts stream is sized to the buckets); under ``--producers N`` the
    interleaving decides bucket fills, so that check is skipped.
    """
    fails = []
    tot = metrics.snapshot()["totals"]
    if tot["submitted"] != n_requests:
        fails.append(f"submitted {tot['submitted']} != offered {n_requests}")
    failed = tot.get("failed", 0)
    if tot["images"] + tot["shed"] + tot["expired"] + failed \
            != tot["submitted"]:
        fails.append(
            "conservation violated: served %d + shed %d + expired %d + "
            "failed %d != submitted %d"
            % (tot["images"], tot["shed"], tot["expired"], failed,
               tot["submitted"]))
    statuses = [r.status for r in metrics.requests]
    if any(s == "pending" for s in statuses):
        fails.append(f"{statuses.count('pending')} requests left pending")
    rids = [r.rid for r in metrics.requests]
    if len(set(rids)) != len(rids):
        fails.append("duplicate request ids")
    for r in metrics.requests:
        if r.status == "served" and r.result is None:
            fails.append(f"request {r.rid} served without a result")
            break
    if expect_all_buckets:
        for b in server.engine.buckets:
            if metrics.flushes(b) < 1:
                fails.append(f"bucket {b} never flushed")
    bad = {k: v for k, v in server.engine.compile_counts.items() if v != 1}
    if bad:
        fails.append(f"executables compiled more than once: {bad}")
    if not metrics.snapshot()["per_bucket"]:
        fails.append("metrics snapshot is empty")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n")[0],
        parents=[execution_parent(arch_choices=CNN_REGISTRY,
                                  arch_default="vgg16"),
                 serving_parent()])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny arch variant (CNN_SMOKES) for CI")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean arrival rate (req/s) for poisson/uniform")
    ap.add_argument("--arrival", choices=("poisson", "uniform", "bursts"),
                    default="bursts",
                    help="arrival process (bursts cycles the bucket sizes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/serve/metrics.json")
    ap.add_argument("--check", action="store_true",
                    help="assert request conservation, compile-once (and "
                         ">=1 flush per bucket in inline mode); exit "
                         "non-zero on failure (CI gate)")
    args = ap.parse_args()

    policy = policy_from_args(args)
    serve_config = serve_config_from_args(args)
    cfg = (CNN_SMOKES if args.smoke else CNN_REGISTRY)[args.arch]

    server = build_server(cfg, policy, serve_config, seed=args.seed)
    try:
        metrics = server.run_stream(
            make_stream(cfg, args, serve_config.buckets),
            producers=args.producers)
    finally:
        server.close()
    snap = metrics.snapshot()

    extra = {
        "arch": cfg.name,
        "datapath": serve_config.datapath,
        "arrival": args.arrival,
        "requests": args.requests,
        "max_delay_ms": args.max_delay_ms,
        "producers": args.producers,
        "queue_capacity": serve_config.queue_capacity,
        "overload": serve_config.overload,
        "plan": list(server.engine.plan.describe()),
        "executables": dict(server.engine.compile_counts),
    }
    injector = server.engine.injector
    if injector is not None:
        # stamp the chaos schedule + what actually fired, so a degraded
        # run is visible in its artifact (DESIGN.md §11.3)
        extra["faults"] = injector.plan.describe()
        extra["fault_ledger"] = dict(injector.fired)
        extra["lanes"] = [ln.name for ln in server.engine.lanes]
    payload = metrics.write(args.out, extra=extra)

    tot = snap["totals"]
    mode = (f"{args.producers} producers" if args.producers
            else "inline open loop")
    print(f"[serve_cnn] {cfg.name} {serve_config.datapath} "
          f"buckets={list(serve_config.buckets)} ({mode}) "
          f"served {tot['images']}/{tot['submitted']} "
          f"(shed {tot['shed']}, expired {tot['expired']}, "
          f"overlapped {tot['overlapped']}) in {tot.get('wall_s', 0):.3f}s "
          f"({tot.get('images_per_s', 0):.1f} img/s, p99 {tot['p99_ms']:.1f} ms, "
          f"pad waste {tot['pad_waste']:.1%})")
    for b, rec in snap["per_bucket"].items():
        print(f"[serve_cnn]   bucket {b:>3}: {rec['flushes']} flushes, "
              f"{rec['images_per_s']:.1f} img/s, p99 {rec['p99_ms']:.2f} ms")
    print(f"[serve_cnn] wrote {args.out} "
          f"({len(json.dumps(payload))} bytes)")

    if args.check:
        fails = check_run(server, metrics, args.requests,
                          expect_all_buckets=args.producers == 0)
        if fails:
            for f in fails:
                print(f"[serve_cnn] CHECK FAILED: {f}", file=sys.stderr)
            # the admission ledger: every request's terminal state (plus
            # what the fault plane fired), so a CI failure is debuggable
            # from the log alone
            ledger = {
                "fails": fails,
                "totals": tot,
                "requests": [
                    dict({"rid": r.rid, "status": r.status},
                         **({"error": r.error} if r.error else {}))
                    for r in sorted(metrics.requests, key=lambda r: r.rid)
                ],
            }
            if injector is not None:
                ledger["fault_ledger"] = dict(injector.fired)
            json.dump(ledger, sys.stderr, indent=1)
            print(file=sys.stderr)
            sys.exit(1)
        print("[serve_cnn] check OK: request conservation holds, every "
              "executable compiled exactly once"
              + ("" if args.producers else ", every bucket flushed"))


if __name__ == "__main__":
    main()
