"""Production CNN serving CLI on the shared serving core (DESIGN.md §8).

  PYTHONPATH=src python -m repro.launch.serve_cnn --arch vgg16 --smoke \\
      --buckets 1,4,16 --requests 64 --rate 200 --max-delay-ms 5

Compiles one executable per (ModelPlan, batch bucket) up front
(``ModelPlan.executable_for`` → ahead-of-time ``jit().lower().compile()``,
so the request stream cannot retrace), then serves a deterministic
synthetic request stream (``data.pipeline.SyntheticRequestStream``)
through pad-and-bucket admission with deadline flush, and writes the
per-bucket metrics JSON.  Execution flags (``--substrate`` / ``--int8`` /
``--tuning``) come from the shared launcher parent (``launch.cli``) —
``--tuning cached`` plans each bucket off its batch-specific persisted
autotuner winners.  ``--int8`` serves the fused integer datapath off
calibrated per-channel requant pairs (the only batch-shape-independent
int8 lane).  ``--check`` (the CI serve-smoke gate) exits non-zero unless
every bucket flushed at least once, every request got a result, metrics
are non-empty, and no executable compiled more than once.
"""

import argparse
import json
import sys

import jax

from repro.configs import CNN_REGISTRY, CNN_SMOKES
from repro.data.pipeline import SyntheticRequestStream
from repro.engine import plan_model
from repro.launch.cli import execution_parent, policy_from_args
from repro.serve import ServeEngine, serve_stream


def make_stream(cfg, args, buckets):
    """The synthetic request stream for one serve run: the bursts process
    cycles the bucket sizes (with gaps past the flush deadline), so every
    bucket flushes at least once — what the CI smoke asserts."""
    return SyntheticRequestStream(
        hw=cfg.input_hw,
        channels=cfg.layers[0].M,
        n_classes=cfg.n_classes,
        n_requests=args.requests,
        rate_hz=args.rate,
        seed=args.seed,
        process=args.arrival,
        burst_sizes=tuple(buckets),
        gap_s=4.0 * args.max_delay_ms / 1e3,
        dtype="uint8" if args.int8 else "float32",
    )


def build_engine(cfg, policy, buckets, *, int8=False, seed=0, calib_batch=8):
    """ModelPlan → params (+ int8 quantization/calibration) → warm engine."""
    plan = plan_model(cfg, policy)
    params = plan.init(jax.random.PRNGKey(seed))
    if not int8:
        return ServeEngine.for_model_plan(plan, params, buckets=buckets)
    qparams, _ = plan.quantize(params)
    sample = SyntheticRequestStream(
        hw=cfg.input_hw, channels=cfg.layers[0].M, n_classes=cfg.n_classes,
        seed=seed, dtype="uint8").sample_batch(calib_batch)
    requant = plan.calibrate_requant(qparams, sample)
    return ServeEngine.for_model_plan(
        plan, qparams, buckets=buckets, datapath="int8", requant=requant)


def check_run(engine, metrics, n_requests) -> list:
    """The --check assertions; returns a list of failure strings."""
    fails = []
    for b in engine.buckets:
        if metrics.flushes(b) < 1:
            fails.append(f"bucket {b} never flushed")
    if metrics.total_images != n_requests:
        fails.append(
            f"served {metrics.total_images} of {n_requests} requests")
    for r in metrics.requests:
        if r.result is None:
            fails.append(f"request {r.rid} has no result")
            break
    bad = {k: v for k, v in engine.compile_counts.items() if v != 1}
    if bad:
        fails.append(f"executables compiled more than once: {bad}")
    if not metrics.snapshot()["per_bucket"]:
        fails.append("metrics snapshot is empty")
    return fails


def main() -> None:
    ap = argparse.ArgumentParser(
        description=__doc__.split("\n")[0],
        parents=[execution_parent(arch_choices=CNN_REGISTRY,
                                  arch_default="vgg16")])
    ap.add_argument("--smoke", action="store_true",
                    help="tiny arch variant (CNN_SMOKES) for CI")
    ap.add_argument("--buckets", default="1,4,16,64",
                    help="static batch buckets, comma-separated")
    ap.add_argument("--max-delay-ms", type=float, default=5.0,
                    help="deadline: oldest request ships within this")
    ap.add_argument("--requests", type=int, default=256)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean arrival rate (req/s) for poisson/uniform")
    ap.add_argument("--arrival", choices=("poisson", "uniform", "bursts"),
                    default="bursts",
                    help="arrival process (bursts cycles the bucket sizes)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="experiments/serve/metrics.json")
    ap.add_argument("--check", action="store_true",
                    help="assert >=1 flush per bucket, all requests served, "
                         "compile-once; exit non-zero on failure (CI gate)")
    args = ap.parse_args()

    policy = policy_from_args(args)
    cfg = (CNN_SMOKES if args.smoke else CNN_REGISTRY)[args.arch]
    buckets = tuple(int(b) for b in args.buckets.split(","))
    datapath = "int8" if args.int8 else "float"

    engine = build_engine(cfg, policy, buckets, int8=args.int8, seed=args.seed)
    metrics = serve_stream(engine, make_stream(cfg, args, buckets),
                           max_delay_s=args.max_delay_ms / 1e3)
    snap = metrics.snapshot()

    payload = metrics.write(args.out, extra={
        "arch": cfg.name,
        "datapath": datapath,
        "arrival": args.arrival,
        "requests": args.requests,
        "max_delay_ms": args.max_delay_ms,
        "backend": jax.default_backend(),
        "device_kind": jax.devices()[0].device_kind,
        "plan": list(engine.plan.describe()),
        "executables": dict(engine.compile_counts),
    })

    tot = snap["totals"]
    print(f"[serve_cnn] {cfg.name} {datapath} buckets={list(buckets)} "
          f"served {tot['images']} images in {tot.get('wall_s', 0):.3f}s "
          f"({tot.get('images_per_s', 0):.1f} img/s, p99 {tot['p99_ms']:.1f} ms, "
          f"pad waste {tot['pad_waste']:.1%})")
    for b, rec in snap["per_bucket"].items():
        print(f"[serve_cnn]   bucket {b:>3}: {rec['flushes']} flushes, "
              f"{rec['images_per_s']:.1f} img/s, p99 {rec['p99_ms']:.2f} ms")
    print(f"[serve_cnn] wrote {args.out} "
          f"({len(json.dumps(payload))} bytes)")

    if args.check:
        fails = check_run(engine, metrics, args.requests)
        if fails:
            for f in fails:
                print(f"[serve_cnn] CHECK FAILED: {f}", file=sys.stderr)
            sys.exit(1)
        print("[serve_cnn] check OK: every bucket flushed, all requests "
              "served, every executable compiled exactly once")


if __name__ == "__main__":
    main()
