"""ModelConfig — the single architecture descriptor all 12 configs share.

Pure-dataclass (no jax imports at module scope beyond dtypes) so importing a
config never touches device state — a hard requirement for the dry-run's
device-count env ordering.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, Optional, Tuple

import jax.numpy as jnp


@dataclass(frozen=True)
class ShapeCell:
    """One (input-shape) cell from the assignment."""
    name: str            # train_4k | prefill_32k | decode_32k | long_500k
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeCell("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeCell("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeCell("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeCell("long_500k", "decode", 524_288, 1)

ALL_SHAPES: Tuple[ShapeCell, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K,
                                     LONG_500K)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    n_q: int = 0
    n_kv: int = 0
    head_dim: int = 0
    d_ff: int = 0
    mlp_kind: str = "swiglu"
    norm: str = "rmsnorm"
    rope_theta: float = 1e4
    tie_embeddings: bool = True
    scale_embed: bool = False    # gemma: embeddings scaled by sqrt(d_model)
    vocab_pad_to: int = 256
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_every: int = 1           # layer i is MoE iff i % moe_every == moe_offset
    moe_offset: int = 0
    shared_expert: bool = False
    dense_residual: bool = False
    dense_ff: Optional[int] = None
    capacity_factor: float = 1.25
    moe_impl: str = "gather"     # production default; "einsum" = GShard ref
    # SSM / hybrid
    ssm_d_state: int = 0
    ssm_d_conv: int = 4
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_n_groups: int = 1
    ssm_chunk: int = 256
    attn_every: int = 0          # hybrid: attention iff i % attn_every == attn_offset
    attn_offset: int = 0
    # enc-dec
    n_enc_layers: int = 0
    # modality frontend stub (vlm/audio): # of precomputed embedding positions
    frontend_tokens: int = 0
    # numerics / execution
    dtype: Any = jnp.bfloat16
    remat: str = "dots"          # none | dots | full
    chunk_k: int = 1024
    block_causal: bool = False
    scan_layers: bool = True
    ce_impl: str = "padded"      # padded | chunked (vocab-chunked CE, §Perf)
    # serve KV layout: "" (repeated heads over model) | "model" (unrepeated,
    # seq over model) | "2d" (seq over data+model, batch replicated, pairs
    # with 2D weight sharding — see nn.decode_attn)
    decode_kv_seqshard: Any = ""
    # FSDP/ZeRO-3 parameter sharding (the >=34B models need it to fit a
    # 16 GB/chip pod — §Roofline fits_hbm; measured in §Perf)
    fsdp: bool = False
    ssd_bf16: bool = False       # bf16 SSD within-chunk quadratic term
    # capability markers
    subquadratic: bool = False   # may run long_500k
    # shape cells this arch runs (names); long_500k only when subquadratic
    shapes: Tuple[str, ...] = ("train_4k", "prefill_32k", "decode_32k")
    # provenance note (source + verification tier from the assignment)
    source: str = ""

    def with_overrides(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    def param_count_estimate(self) -> int:
        """Closed-form parameter estimate (used by roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        emb = v * d * (1 if self.tie_embeddings else 2)

        def attn_params() -> int:
            return d * (self.n_q + 2 * self.n_kv) * self.head_dim \
                + self.n_q * self.head_dim * d

        def mlp_params(ff: int) -> int:
            return (3 if self.mlp_kind in ("swiglu", "geglu") else 2) * d * ff

        def mamba_params() -> int:
            d_in = self.ssm_expand * d
            gs = self.ssm_n_groups * self.ssm_d_state
            h = d_in // self.ssm_headdim
            in_proj = d * (2 * d_in + 2 * gs + h)
            conv = self.ssm_d_conv * (d_in + 2 * gs)
            return in_proj + conv + d_in * d + 3 * h + d_in

        total = emb
        n_dec = self.n_layers
        for i in range(n_dec):
            if self.family == "ssm":
                total += mamba_params()
                continue
            if self.family == "hybrid":
                is_attn = (self.attn_every and
                           i % self.attn_every == self.attn_offset)
                total += attn_params() if is_attn else mamba_params()
                is_moe = (self.n_experts and i % self.moe_every
                          == self.moe_offset)
                if is_moe:
                    total += self.n_experts * mlp_params(self.d_ff)
                else:
                    total += mlp_params(self.dense_ff or self.d_ff)
                continue
            total += attn_params()
            is_moe = (self.n_experts and
                      i % self.moe_every == self.moe_offset)
            if is_moe:
                total += self.n_experts * mlp_params(self.d_ff)
                if self.shared_expert:
                    total += mlp_params(self.d_ff)
                if self.dense_residual:
                    total += mlp_params(self.dense_ff or self.d_ff)
            else:
                total += mlp_params(self.dense_ff or self.d_ff)
        for _ in range(self.n_enc_layers):
            total += attn_params() + mlp_params(self.d_ff)
            if self.family == "encdec":      # decoder cross-attention
                total += attn_params()
        return total

    def active_param_count_estimate(self) -> int:
        """Active params per token (MoE: top_k experts instead of all)."""
        if not self.n_experts:
            return self.param_count_estimate()
        n_moe = sum(1 for i in range(self.n_layers)
                    if i % self.moe_every == self.moe_offset)
        width = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        per_expert = width * self.d_model * self.d_ff
        return (self.param_count_estimate()
                - n_moe * (self.n_experts - self.top_k) * per_expert)


#: registry filled by repro.configs (one entry per architecture id)
REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    # populate on first use
    import repro.configs  # noqa: F401
    if name not in REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(REGISTRY)}")
    return REGISTRY[name]
