"""gemma-7b [dense] — 28L d_model=3072 16H (kv=16) d_ff=24576 vocab=256000,
GeGLU, head_dim=256 (q/kv width 4096 != d_model — true Gemma geometry),
embeddings scaled by sqrt(d_model), tied readout. [arXiv:2403.08295; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="gemma-7b", family="dense",
    n_layers=28, d_model=3072, n_q=16, n_kv=16, head_dim=256,
    d_ff=24576, vocab=256000, mlp_kind="geglu", norm="rmsnorm",
    rope_theta=1e4, tie_embeddings=True, scale_embed=True,
    vocab_pad_to=128,
    source="arXiv:2403.08295; hf",
))

SMOKE = CONFIG.with_overrides(
    name="gemma-7b-smoke", n_layers=2, d_model=64, n_q=4, n_kv=4,
    head_dim=16, d_ff=128, vocab=512, vocab_pad_to=64, remat="none",
    chunk_k=64)
