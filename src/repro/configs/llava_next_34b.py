"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000, anyres tiling. [hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]

The vision frontend (anyres tile patchify) is a STUB per the assignment:
``input_specs`` supplies precomputed patch embeddings (B, 576, d) — one
24x24 base tile — prepended to the text sequence. The backbone is the
assigned 60-layer geometry (Yi-34B-like).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b", family="vlm",
    n_layers=60, d_model=7168, n_q=56, n_kv=8, head_dim=128,
    d_ff=20480, vocab=64000, mlp_kind="swiglu", norm="rmsnorm",
    rope_theta=5e6, tie_embeddings=False, vocab_pad_to=128,
    frontend_tokens=576,
    fsdp=True, decode_kv_seqshard="model",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified",
))

SMOKE = CONFIG.with_overrides(
    name="llava-next-34b-smoke", n_layers=2, d_model=64, n_q=8, n_kv=2,
    head_dim=8, d_ff=128, vocab=512, vocab_pad_to=64, frontend_tokens=8,
    remat="none", chunk_k=64)
