"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, MoE 16e top-2, Mamba+attention 1:7 interleave.
[arXiv:2403.19887; hf]

Schedule (period 8): attention at layer i % 8 == 4, Mamba elsewhere;
MoE (16e top-2) on odd layers, dense MLP on even layers — the published
Jamba interleave. Hardware adaptation (DESIGN.md §9): the Mamba mixer is
implemented as Mamba-2 SSD (chunked, MXU-friendly) rather than Jamba's
Mamba-1 selective scan; state size 128, headdim 128, 8 B/C groups.
subquadratic=True: this arch runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    n_layers=72, d_model=8192, n_q=64, n_kv=8, head_dim=128,
    d_ff=24576, vocab=65536, mlp_kind="swiglu", norm="rmsnorm",
    rope_theta=1e4, tie_embeddings=False, vocab_pad_to=128,
    n_experts=16, top_k=2, moe_every=2, moe_offset=1,
    attn_every=8, attn_offset=4,
    ssm_d_state=128, ssm_d_conv=4, ssm_expand=2, ssm_headdim=128,
    ssm_n_groups=8, ssm_chunk=256,
    fsdp=True, decode_kv_seqshard="model",
    subquadratic=True,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2403.19887; hf",
))

SMOKE = CONFIG.with_overrides(
    name="jamba-1.5-large-398b-smoke", n_layers=8, d_model=64, n_q=8,
    n_kv=2, head_dim=8, d_ff=128, vocab=512, vocab_pad_to=64, n_experts=4,
    ssm_d_state=16, ssm_headdim=16, ssm_n_groups=2, ssm_chunk=32,
    remat="none", chunk_k=64)
