"""seamless-m4t-large-v2 [audio] — enc-dec, 24L encoder + 24L decoder,
d_model=1024 16H (kv=16) d_ff=8192 vocab=256206. [arXiv:2308.11596; hf]

The speech frontend is a STUB per the assignment: ``input_specs`` supplies
precomputed frame embeddings (B, S_src, d). The decoder is a causal token
LM with per-layer cross-attention into the encoder output. Decode shapes
use a fixed source length of 4096 frames (cross-KV) with the self-KV cache
at the assigned seq_len (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2", family="encdec",
    n_layers=24, n_enc_layers=24, d_model=1024, n_q=16, n_kv=16,
    head_dim=64, d_ff=8192, vocab=256206, mlp_kind="gelu",
    norm="layernorm", rope_theta=1e4, tie_embeddings=True,
    vocab_pad_to=128,
    source="arXiv:2308.11596; hf",
))

SMOKE = CONFIG.with_overrides(
    name="seamless-m4t-large-v2-smoke", n_layers=2, n_enc_layers=2,
    d_model=64, n_q=4, n_kv=4, head_dim=16, d_ff=128, vocab=518,
    vocab_pad_to=64, remat="none", chunk_k=64)
