"""granite-3-2b [dense] — 40L d_model=2048 32H (GQA kv=8) d_ff=8192
vocab=49155. [hf:ibm-granite/granite-3.0-2b-base; hf]

vocab 49155 is not 16-divisible: the embedding table is padded internally
to 49280 (385*128) for TP shardability; logical vocab stays 49155 (logits
sliced back).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_q=32, n_kv=8, head_dim=64,
    d_ff=8192, vocab=49155, mlp_kind="swiglu", norm="rmsnorm",
    rope_theta=1e4, tie_embeddings=True, vocab_pad_to=128,
    source="hf:ibm-granite/granite-3.0-2b-base; hf",
))

SMOKE = CONFIG.with_overrides(
    name="granite-3-2b-smoke", n_layers=2, d_model=64, n_q=8, n_kv=2,
    head_dim=8, d_ff=128, vocab=515, vocab_pad_to=64, remat="none",
    chunk_k=64)
