"""vgg16 — the paper's primary case study (13 CLs, §IV-§V, Table I).

CNN-family config: selectable via --arch vgg16 in the CNN examples and
benchmarks; runs through the TrIM conv kernels / the bit-faithful engine.
"""
from repro.core.trim.model import ConvLayerSpec
from repro.nn.conv import VGG16_CNN, CNNConfig

CONFIG = VGG16_CNN

#: reduced smoke config: same family (3x3 stacks + pools), tiny maps
SMOKE = CNNConfig(
    "vgg16-smoke",
    layers=(
        ConvLayerSpec("CL1", 16, 16, 3, 3, 8),
        ConvLayerSpec("CL2", 16, 16, 3, 8, 8),
        ConvLayerSpec("CL3", 8, 8, 3, 8, 16),
    ),
    pool_after=(1,), classifier=(32,), n_classes=10, input_hw=(16, 16))
