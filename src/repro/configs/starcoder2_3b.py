"""starcoder2-3b [dense] — 30L d_model=3072 24H (GQA kv=2) d_ff=12288
vocab=49152, GQA + RoPE. [arXiv:2402.19173; hf]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-3b", family="dense",
    n_layers=30, d_model=3072, n_q=24, n_kv=2, head_dim=128,
    d_ff=12288, vocab=49152, mlp_kind="gelu", norm="layernorm",
    rope_theta=1e5, tie_embeddings=True, vocab_pad_to=128,
    source="arXiv:2402.19173; hf",
))

SMOKE = CONFIG.with_overrides(
    name="starcoder2-3b-smoke", n_layers=2, d_model=64, n_q=8, n_kv=2,
    head_dim=8, d_ff=128, vocab=512, vocab_pad_to=64, remat="none",
    chunk_k=64)
