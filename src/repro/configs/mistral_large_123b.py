"""mistral-large-123b [dense] — 88L d_model=12288 96H (GQA kv=8)
d_ff=28672 vocab=32768. [hf:mistralai/Mistral-Large-Instruct-2407;
unverified]
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mistral-large-123b", family="dense",
    n_layers=88, d_model=12288, n_q=96, n_kv=8, head_dim=128,
    d_ff=28672, vocab=32768, mlp_kind="swiglu", norm="rmsnorm",
    rope_theta=1e6, tie_embeddings=False, vocab_pad_to=128,
    fsdp=True, decode_kv_seqshard="model",
    source="hf:mistralai/Mistral-Large-Instruct-2407; unverified",
))

SMOKE = CONFIG.with_overrides(
    name="mistral-large-123b-smoke", n_layers=2, d_model=64, n_q=8, n_kv=2,
    head_dim=8, d_ff=128, vocab=512, vocab_pad_to=64, remat="none",
    chunk_k=64)
