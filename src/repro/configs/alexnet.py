"""alexnet — the paper's second benchmark CNN (Table II): exercises the
large-kernel tiling path (11x11 and 5x5 kernels split into 3x3 tiles, §V).
"""
from repro.core.trim.model import ConvLayerSpec
from repro.nn.conv import ALEXNET_CNN, CNNConfig

CONFIG = ALEXNET_CNN

#: reduced smoke config keeping the large-kernel + stride structure
SMOKE = CNNConfig(
    "alexnet-smoke",
    layers=(
        # 23x23 --11x11 s4--> 4x4 --5x5 p2--> 4x4 --3x3 p1--> 4x4
        ConvLayerSpec("CL1", 23, 23, 11, 3, 8, stride=4, pad=0),
        ConvLayerSpec("CL2", 4, 4, 5, 8, 16, pad=2),
        ConvLayerSpec("CL3", 4, 4, 3, 16, 16, pad=1),
    ),
    pool_after=(), classifier=(32,), n_classes=10, input_hw=(23, 23))
