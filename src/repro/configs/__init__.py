"""Architecture registry: one module per assigned architecture (+ the
paper's own CNNs). ``get_config(name)`` / ``get_smoke(name)`` select by the
assigned id (--arch flag).
"""
from repro.configs.base import (ALL_SHAPES, DECODE_32K, LONG_500K,
                                PREFILL_32K, REGISTRY, TRAIN_4K, ModelConfig,
                                ShapeCell, get_config, register)

# LM-family architectures (importing registers them)
from repro.configs import llava_next_34b          # noqa: F401
from repro.configs import llama4_maverick_400b_a17b  # noqa: F401
from repro.configs import arctic_480b             # noqa: F401
from repro.configs import starcoder2_3b           # noqa: F401
from repro.configs import gemma_7b                # noqa: F401
from repro.configs import granite_3_2b            # noqa: F401
from repro.configs import mistral_large_123b      # noqa: F401
from repro.configs import seamless_m4t_large_v2   # noqa: F401
from repro.configs import jamba_1_5_large_398b    # noqa: F401
from repro.configs import mamba2_130m             # noqa: F401

_SMOKES = {
    m.CONFIG.name: m.SMOKE for m in (
        llava_next_34b, llama4_maverick_400b_a17b, arctic_480b,
        starcoder2_3b, gemma_7b, granite_3_2b, mistral_large_123b,
        seamless_m4t_large_v2, jamba_1_5_large_398b, mamba2_130m)
}

ARCH_IDS = tuple(sorted(REGISTRY))

# CNN-family (the paper's own workloads) — separate registry: they are
# selected by the CNN examples/benchmarks, not the LM dry-run cells.
from repro.configs import vgg16 as _vgg16         # noqa: E402
from repro.configs import alexnet as _alexnet     # noqa: E402

CNN_REGISTRY = {"vgg16": _vgg16.CONFIG, "alexnet": _alexnet.CONFIG}
CNN_SMOKES = {"vgg16": _vgg16.SMOKE, "alexnet": _alexnet.SMOKE}


def get_smoke(name: str, dtype=None) -> ModelConfig:
    """Reduced config of the same family. Smoke tests run in f32 by default
    (bit-stable train/serve agreement on CPU); pass dtype=jnp.bfloat16 to
    exercise the production dtype."""
    import jax.numpy as jnp
    return _SMOKES[name].with_overrides(dtype=dtype or jnp.float32)


def shape_cells(cfg: ModelConfig):
    """The ShapeCell list this architecture runs (long_500k gated on
    subquadratic — see DESIGN.md §5)."""
    by_name = {c.name: c for c in ALL_SHAPES}
    return tuple(by_name[s] for s in cfg.shapes)
