"""arctic-480b [moe] — 35L d_model=7168 56H (GQA kv=8) d_ff=4864,
MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]

Snowflake Arctic's dense-MoE hybrid: every layer has a 128-expert top-2
MoE *in parallel with* a dense-FFN residual branch.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="arctic-480b", family="moe",
    n_layers=35, d_model=7168, n_q=56, n_kv=8, head_dim=128,
    d_ff=4864, vocab=32000, mlp_kind="swiglu", norm="rmsnorm",
    rope_theta=1e4, tie_embeddings=False, vocab_pad_to=128,
    n_experts=128, top_k=2, moe_every=1, dense_residual=True,
    dense_ff=4864, capacity_factor=1.25,
    fsdp=True, decode_kv_seqshard="model",
    source="hf:Snowflake/snowflake-arctic-base; hf",
))

SMOKE = CONFIG.with_overrides(
    name="arctic-480b-smoke", n_layers=2, d_model=64, n_q=8, n_kv=2,
    head_dim=8, d_ff=96, dense_ff=96, vocab=512, vocab_pad_to=64,
    n_experts=4, remat="none", chunk_k=64)
