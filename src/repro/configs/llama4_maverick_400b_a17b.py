"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]

Config note (DESIGN.md §5): the assigned row with *every* layer MoE gives
~775 B params; Llama-4 Maverick interleaves dense/MoE layers and adds a
shared expert. With MoE on odd layers + shared expert this lands at
~397 B total / ~13 B active — matching the 400b-a17b name. Documented
deviation: interleave + shared expert.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_q=40, n_kv=8, head_dim=128,
    d_ff=8192, vocab=202048, mlp_kind="swiglu", norm="rmsnorm",
    rope_theta=5e5, tie_embeddings=False, vocab_pad_to=128,
    n_experts=128, top_k=1, moe_every=2, moe_offset=1, shared_expert=True,
    capacity_factor=1.25,
    fsdp=True, decode_kv_seqshard="model",
    source="hf:meta-llama/Llama-4-Scout-17B-16E; unverified",
))

SMOKE = CONFIG.with_overrides(
    name="llama4-maverick-400b-a17b-smoke", n_layers=4, d_model=64, n_q=8,
    n_kv=2, head_dim=8, d_ff=128, vocab=512, vocab_pad_to=64, n_experts=4,
    remat="none", chunk_k=64)
