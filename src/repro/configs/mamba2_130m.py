"""mamba2-130m [ssm] — 24L d_model=768, attention-free, vocab=50280,
ssm_state=128, SSD (state-space duality). [arXiv:2405.21060; unverified]

expand=2 (d_inner 1536), headdim=64 (24 SSD heads), d_conv=4, 1 B/C group,
chunk 256. subquadratic=True: runs the long_500k cell. The pre-SSM causal
depthwise conv is the TrIM-1D Pallas kernel hotspot (DESIGN.md §5).
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-130m", family="ssm",
    n_layers=24, d_model=768, vocab=50280, norm="rmsnorm",
    tie_embeddings=True, vocab_pad_to=128,
    ssm_d_state=128, ssm_d_conv=4, ssm_expand=2, ssm_headdim=64,
    ssm_n_groups=1, ssm_chunk=256,
    subquadratic=True,
    shapes=("train_4k", "prefill_32k", "decode_32k", "long_500k"),
    source="arXiv:2405.21060; unverified",
))

SMOKE = CONFIG.with_overrides(
    name="mamba2-130m-smoke", n_layers=2, d_model=64, vocab=512,
    vocab_pad_to=64, ssm_d_state=16, ssm_headdim=16, ssm_chunk=32,
    remat="none")
