"""Per-layer plan autotuner: search schedules, persist winners (DESIGN.md §7).

The TrIM papers' central claim is that the *schedule* — tiling, blocking,
and which engine runs the layer — determines memory traffic and therefore
throughput; the companion dataflow-modelling paper derives per-layer
optimal schedules analytically.  This module finds them empirically: given
one conv layer's static description (the same arguments
:func:`repro.engine.plan.plan_conv_layer` takes), it

1. enumerates a candidate schedule space — substrate switches (pallas /
   oracle / f32exact), and for the Pallas substrate a one-factor-at-a-time
   sweep of ``tile_h`` / ``tile_w`` / ``block_c`` / ``block_f`` with
   ``pick_tile_w``'s VMEM cost model (``_vmem_bytes``) pruning width tiles
   that cannot fit the budget;
2. compiles each candidate once through the one dispatch site
   (``execute.run_conv2d``) and times it with warmup + median-of-k;
3. gates candidates on *bit-identity* with the default plan's output
   (schedule changes timing, not math — spatial re-tiling and exact
   integer substrates pass, accumulation-order changes on floats are
   rejected unless ``allow_inexact=True``);
4. returns the winner, preferring the default unless a candidate beats it
   by more than ``MIN_GAIN`` — a tuned plan is never slower than the
   default it replaces;
5. persists the winner in a JSON plan cache under ``tuned_plans/`` keyed
   by (layer geometry, dtype byte sizes, epilogue kind, emulate_hw) inside
   a per-(backend, device kind) cache file stamped with
   ``PLAN_CACHE_VERSION``.

``plan_conv_layer`` consults :func:`tuned_schedule` transparently when the
policy requests ``tuning="cached"`` (miss -> default plan) or
``tuning="auto"`` (tune-on-miss, then persist), so models planned via
``plan_model`` run each layer on its measured-best schedule.
"""

from __future__ import annotations

import json
import os
import re
import time
import warnings
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine import execute
from repro.engine.plan import plan_conv_layer, plan_model
from repro.engine.policy import RESOLVED_SUBSTRATES, ExecutionPolicy, on_tpu
from repro.kernels.trim_conv2d import _vmem_bytes

#: Bump when plan semantics change (new schedule fields, kernel geometry
#: changes, …): cache files with a different version are ignored with a
#: warning, so stale winners never silently misconfigure new kernels.
#: v2: layer keys gained the batch axis ``n{N}`` — a schedule measured at
#: N=1 is not a winner under a loaded server's batch buckets.
#: v3: layer keys gained the weight-width axis ``w{bits}`` — the int5 MSR
#: lane (DESIGN.md §9.3) shares layer geometry with int8 but widens the
#: f32exact chunking ~4x, so its winners are measured separately.
PLAN_CACHE_VERSION = 3

#: The policy fields a persisted schedule may override.
SCHEDULE_FIELDS = ("substrate", "tile_h", "tile_w", "block_c", "block_f")

#: A non-default candidate must beat the default by this fraction to be
#: shipped — inside the margin the default wins (measurement noise must
#: never make a tuned plan slower than the default it replaces).
MIN_GAIN = 0.05

#: One-factor-at-a-time sweep values for the Pallas schedule knobs.
TILE_H_CANDIDATES = (4, 8, 16, 32)
BLOCK_CANDIDATES = (64, 128, 256)


# ---------------------------------------------------------------------------
# Cache keys and the JSON plan cache
# ---------------------------------------------------------------------------


def cache_dir() -> str:
    """Plan-cache directory (``REPRO_TUNED_PLANS_DIR``, default
    ``tuned_plans/`` under the current working directory)."""
    return os.environ.get("REPRO_TUNED_PLANS_DIR", "tuned_plans")


def device_kind() -> str:
    return jax.devices()[0].device_kind


def cache_path() -> str:
    """One cache file per (backend, device kind) — measured schedules only
    transfer within one hardware class."""
    slug = re.sub(r"[^A-Za-z0-9_.-]+", "-", device_kind())
    return os.path.join(cache_dir(), f"{jax.default_backend()}-{slug}.json")


def layer_key(
    x_hw: Tuple[int, int],
    c_in: int,
    k: int,
    c_out: int,
    *,
    stride: int,
    padding: Optional[int],
    groups: int,
    relu: bool,
    has_bias: bool,
    requant_kind: Optional[str],
    in_sz: int,
    w_sz: int,
    out_sz: int,
    emulate_hw: bool,
    batch: int = 1,
    w_bits: int = 8,
) -> str:
    """The layer's plan-cache key: geometry + dtype byte sizes + epilogue.

    ``batch`` is the batch size the schedule was measured at — a serving
    bucket runs N images per call, and the winning schedule can differ
    from the N=1 winner (the serving core plans each bucket with its own
    batch, so each bucket gets its own persisted winner).  ``w_bits`` is
    the stored weight width (8, or 5 for the MSR lane): the sub-8-bit
    operands change the f32exact chunk count, so the lanes tune apart.

    Backend, device kind, and code version live at the cache-file level
    (:func:`cache_path`, ``PLAN_CACHE_VERSION``) — together they complete
    the key the issue tracker calls (layer geometry, dtype, epilogue kind,
    batch, backend + device kind, code version).
    """
    pad = "same" if padding is None else str(padding)
    epi = f"{int(relu)}{int(has_bias)}.{requant_kind or 'none'}"
    return (
        f"conv2d n{batch} h{x_hw[0]}x{x_hw[1]} c{c_in} k{k} f{c_out} "
        f"s{stride} p{pad} g{groups} ep{epi} "
        f"sz{in_sz}.{w_sz}.{out_sz} emu{int(emulate_hw)} w{w_bits}"
    )


#: In-process mirror of the cache files: path -> {key -> entry}.  A second
#: lookup in the same process never re-reads the file, and a lookup after
#: :func:`store_schedule` sees the new entry without one either.
_LOADED: Dict[str, Dict[str, dict]] = {}


def reset_cache() -> None:
    """Forget in-process plan-cache state (tests, cache-dir switches).

    Also drops the plan lru caches: cached ``ConvLayerPlan``s bake tuned
    schedules in, so they must be re-resolved after the cache changes.
    """
    _LOADED.clear()
    plan_conv_layer.cache_clear()
    plan_model.cache_clear()


def _load_plans(path: str) -> Dict[str, dict]:
    if path in _LOADED:
        return _LOADED[path]
    plans: Dict[str, dict] = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                data = json.load(f)
            version = data.get("version") if isinstance(data, dict) else None
            if version != PLAN_CACHE_VERSION:
                raise ValueError(f"cache version {version!r} != {PLAN_CACHE_VERSION}")
            plans = data.get("plans")
            if not isinstance(plans, dict):
                raise ValueError("'plans' is not a mapping")
        except Exception as e:  # corrupt/stale cache: degrade, don't crash
            warnings.warn(
                f"tuned-plan cache {path} is unreadable ({e}); "
                "falling back to default plans",
                RuntimeWarning,
                stacklevel=3,
            )
            plans = {}
    _LOADED[path] = plans
    return plans


def _valid_schedule(sched: object) -> bool:
    if not isinstance(sched, dict) or set(sched) != set(SCHEDULE_FIELDS):
        return False
    if sched["substrate"] not in RESOLVED_SUBSTRATES:
        return False
    for field in ("tile_h", "block_c", "block_f"):
        if not isinstance(sched[field], int) or sched[field] < 1:
            return False
    tw = sched["tile_w"]
    return tw is None or (isinstance(tw, int) and tw >= 1)


def load_schedule(key: str) -> Optional[Dict[str, object]]:
    """The persisted winning schedule for ``key``, or None on a miss (or on
    an invalid entry, which warns and degrades to a miss)."""
    entry = _load_plans(cache_path()).get(key)
    if entry is None:
        return None
    sched = entry.get("schedule") if isinstance(entry, dict) else None
    if not _valid_schedule(sched):
        warnings.warn(
            f"tuned-plan cache entry for {key!r} is invalid; "
            "falling back to the default plan",
            RuntimeWarning,
            stacklevel=3,
        )
        return None
    return dict(sched)


def store_schedule(key: str, entry: Dict[str, object]) -> None:
    """Persist one tuning result (atomic write) and refresh the in-process
    mirror + plan lru caches so the winner is visible immediately."""
    path = cache_path()
    plans = dict(_load_plans(path))
    plans[key] = entry
    payload = {
        "version": PLAN_CACHE_VERSION,
        "backend": jax.default_backend(),
        "device_kind": device_kind(),
        "plans": plans,
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    _LOADED[path] = plans
    plan_conv_layer.cache_clear()
    plan_model.cache_clear()


# ---------------------------------------------------------------------------
# Candidate enumeration (cost-model pruned)
# ---------------------------------------------------------------------------


def tile_w_candidates(
    x_hw: Tuple[int, int],
    c_in: int,
    k: int,
    c_out: int,
    *,
    stride: int,
    padding: Optional[int],
    groups: int,
    tile_h: int,
    block_c: int,
    block_f: int,
    in_sz: int,
    w_sz: int,
    out_sz: int,
    vmem_budget: int,
) -> List[Optional[int]]:
    """Divisor-aligned ``tile_w`` picks that fit the VMEM budget.

    Mirrors ``pick_tile_w``'s cost conventions (2 input passes for the
    full-width halo layout, 4 for the column-tiled one) so the pruner and
    the kernel agree on what fits; candidates are ceil(W_O / n) for
    n = 1, 2, 4, 8, … rounded up to 8-sublane multiples.  ``None`` (let
    ``pick_tile_w`` auto-size at plan time) is always the first candidate.
    """
    p = k // 2 if padding is None else padding
    H_p = x_hw[0] + 2 * p
    W_p = x_hw[1] + 2 * p
    H_O = (H_p - k) // stride + 1
    W_O = (W_p - k) // stride + 1
    halo = k - stride
    TH = min(tile_h, H_O)
    if halo > 0:
        TH = max(TH, -(-halo // stride))
    Cb = min(block_c, c_in // groups)
    Fb = min(block_f, c_out // groups)
    cands: List[Optional[int]] = [None]
    seen = set()
    n = 1
    while n <= W_O:
        tw = W_O if n == 1 else -(-(-(-W_O // n)) // 8) * 8
        if halo > 0:
            tw = max(tw, -(-halo // stride))
        tw = min(tw, W_O)
        full_width = tw == W_O
        cost = _vmem_bytes(
            RB=TH * stride,
            cols=W_p if full_width else tw * stride,
            Cb=Cb,
            Fb=Fb,
            K=k,
            TH=TH,
            TW=tw,
            passes=(2 if full_width else 4) if halo > 0 else 1,
            in_sz=in_sz,
            w_sz=w_sz,
            out_sz=out_sz,
        )
        if cost <= vmem_budget and tw not in seen:
            seen.add(tw)
            cands.append(tw)
        if full_width and n > 1:
            break
        n *= 2
    return cands[:4]


def candidate_policies(
    x_hw: Tuple[int, int],
    c_in: int,
    k: int,
    c_out: int,
    *,
    stride: int = 1,
    padding: Optional[int] = None,
    groups: int = 1,
    in_sz: int = 4,
    w_sz: int = 4,
    out_sz: int = 4,
    policy: ExecutionPolicy = ExecutionPolicy(),
    include_pallas: Optional[bool] = None,
) -> List[ExecutionPolicy]:
    """Enumerate candidate policies for one layer (default first).

    Substrate moves: the resolved default always leads; integer layers
    (``in_sz == 1``) add "f32exact" (the exact chunked-f32 oracle); the
    plain "oracle" is added when the default is something else (so small
    layers where XLA wins get routed there per-layer).  When the compiled
    Pallas kernel is available (on TPU, or ``include_pallas=True`` in
    tests) the Pallas schedule knobs get a one-factor-at-a-time sweep —
    ``tile_h``, cost-model-pruned ``tile_w``, ``block_c``/``block_f`` caps
    — rather than a full cross product (the analytical model says the
    knobs are near-separable; a full product is measurement budget, not
    insight).  "interpret" is a debugging substrate and is never searched:
    a policy already resolved to it keeps its single default candidate.
    """
    base = policy.resolve().with_overrides(tuning="off")
    cands = [base]
    if base.substrate == "interpret":
        return cands
    if in_sz == 1 and base.substrate != "f32exact":
        cands.append(base.with_overrides(substrate="f32exact"))
    if base.substrate != "oracle":
        cands.append(base.with_overrides(substrate="oracle"))
    if include_pallas is None:
        include_pallas = on_tpu()
    if include_pallas:
        p = k // 2 if padding is None else padding
        H_O = (x_hw[0] + 2 * p - k) // stride + 1
        pallas = base.with_overrides(substrate="pallas")
        if base.substrate != "pallas":
            cands.append(pallas)
        for th in TILE_H_CANDIDATES:
            if th != pallas.tile_h and th <= max(H_O, 1):
                cands.append(pallas.with_overrides(tile_h=th))
        for tw in tile_w_candidates(
            x_hw,
            c_in,
            k,
            c_out,
            stride=stride,
            padding=padding,
            groups=groups,
            tile_h=pallas.tile_h,
            block_c=pallas.block_c,
            block_f=pallas.block_f,
            in_sz=in_sz,
            w_sz=w_sz,
            out_sz=out_sz,
            vmem_budget=pallas.vmem_budget,
        ):
            if tw != pallas.tile_w:
                cands.append(pallas.with_overrides(tile_w=tw))
        for bc in BLOCK_CANDIDATES:
            if bc != pallas.block_c and bc <= c_in // groups:
                cands.append(pallas.with_overrides(block_c=bc))
        for bf in BLOCK_CANDIDATES:
            if bf != pallas.block_f and bf <= c_out // groups:
                cands.append(pallas.with_overrides(block_f=bf))
    return list(dict.fromkeys(cands))


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------


def _measure_plan(
    plan,
    *,
    in_sz: int,
    warmup: int = 1,
    reps: int = 5,
    batch: int = 1,
) -> Tuple[float, np.ndarray]:
    """Compile ``plan`` once via ``execute.run_conv2d``, then time it.

    Returns (median wall-clock in us over ``reps`` timed calls after
    ``warmup`` extra calls, output as a numpy array for the bit-identity
    gate).  Inputs are synthesized from the plan — ``batch`` images of
    uint8 x / int8 w for the integer lane (``in_sz == 1``), bf16/f32
    otherwise — so a schedule tuned for a serving bucket is measured at
    that bucket's batch size.
    """
    key = jax.random.PRNGKey(0)
    x_shape = (int(batch), plan.x_hw[0], plan.x_hw[1], plan.c_in)
    w_shape = (plan.k, plan.k, plan.c_in // plan.groups, plan.c_out)
    F = plan.c_out
    requant = None
    requant_shift = None
    bias = None
    if in_sz == 1:
        # Sub-8-bit plans are measured with representative small-magnitude
        # operands: the f32exact substrate's chunk count (its cost) depends
        # on the |w| bound the plan's w_bits guarantees.
        wmax = (1 << plan.w_bits) - 1 if plan.w_bits < 8 else 127
        x = jax.random.randint(key, x_shape, 0, 255, jnp.uint8)
        w = jax.random.randint(
            jax.random.fold_in(key, 1), w_shape, -wmax, wmax, jnp.int8
        )
        if plan.requant_kind == "mult_shift":
            requant = (
                jnp.full((F,), 16384, jnp.int32),
                jnp.full((F,), 20, jnp.int32),
            )
        elif plan.requant_kind == "shift":
            requant_shift = 8
        if plan.has_bias:
            bias = jnp.zeros((F,), jnp.int32)
    else:
        dt = jnp.bfloat16 if in_sz == 2 else jnp.float32
        x = jax.random.normal(key, x_shape, dt)
        w = jax.random.normal(jax.random.fold_in(key, 1), w_shape, dt)
        if plan.has_bias:
            bias = jax.random.normal(jax.random.fold_in(key, 2), (F,), dt)

    def call():
        return execute.run_conv2d(
            plan, x, w, bias, requant, requant_shift=requant_shift
        )

    out = jax.block_until_ready(call())  # compile + identity-gate output
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(call())
    times = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(call())
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6, np.asarray(out)


def aggregate_pair(ta, tb):
    """THE drift-robust A/B statistic, shared by the tuner and the
    benchmarks (``benchmarks.run._timeit_pair``).

    Machine load, cgroup CPU throttling, and thermal drift can skew
    sequential timings by 2-3x within one process.  Two *adjacent* calls
    share one throttle state, so each round's ``tb/ta`` is clean even
    when absolute times move 3x between rounds: the median of the
    per-round ratios is the decision statistic, the per-arm mins are the
    least-contended wall-clock observations.  ``ta``/``tb`` are the
    paired per-round timings (same units in = same units out); returns
    (t_a, t_b, ratio_b_over_a).
    """
    ratio = float(np.median([b / a for a, b in zip(ta, tb)]))
    return float(np.min(ta)), float(np.min(tb)), ratio


def _measure_pair(plan_a, plan_b, *, in_sz: int, reps: int = 5, batch: int = 1):
    """Alternate single-rep measurements of two plans; aggregate with
    :func:`aggregate_pair`.  Returns (us_a, us_b, ratio_b_over_a)."""
    _measure_plan(plan_a, in_sz=in_sz, warmup=0, reps=1, batch=batch)  # warm
    _measure_plan(plan_b, in_sz=in_sz, warmup=0, reps=1, batch=batch)
    ta, tb = [], []
    for _ in range(max(reps, 1)):
        ta.append(_measure_plan(plan_a, in_sz=in_sz, warmup=0, reps=1, batch=batch)[0])
        tb.append(_measure_plan(plan_b, in_sz=in_sz, warmup=0, reps=1, batch=batch)[0])
    return aggregate_pair(ta, tb)


# ---------------------------------------------------------------------------
# Tuning
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class CandidateTiming:
    schedule: Dict[str, object]
    us: float
    exact: bool


@dataclass(frozen=True)
class TuneResult:
    """One layer's tuning outcome (also what gets persisted)."""

    key: str
    schedule: Dict[str, object]
    us: float
    us_default: float
    candidates: Tuple[CandidateTiming, ...]
    cached: bool = False

    @property
    def speedup(self) -> float:
        """Default-vs-tuned ratio (>= 1.0: the winner is never slower)."""
        return self.us_default / self.us if self.us else float("inf")


def _schedule_of_plan(plan) -> Dict[str, object]:
    """The persistable schedule a plan encodes.

    ``tile_w`` persists the explicit override (None = auto-pick at plan
    time); ``block_*`` persist the per-group-capped values — re-applying a
    capped value as the policy cap resolves to the identical plan.
    """
    return {
        "substrate": plan.substrate,
        "tile_h": plan.tile_h,
        "tile_w": plan.tile_w_arg,
        "block_c": plan.block_c,
        "block_f": plan.block_f,
    }


def tune_conv_layer(
    x_hw: Tuple[int, int],
    c_in: int,
    k: int,
    c_out: int,
    *,
    stride: int = 1,
    padding: Optional[int] = None,
    groups: int = 1,
    relu: bool = False,
    has_bias: bool = False,
    requant_kind: Optional[str] = None,
    in_sz: int = 4,
    w_sz: int = 4,
    out_sz: int = 4,
    w_bits: int = 8,
    policy: ExecutionPolicy = ExecutionPolicy(),
    batch: int = 1,
    warmup: int = 1,
    reps: int = 5,
    allow_inexact: bool = False,
    persist: bool = True,
    force: bool = False,
) -> TuneResult:
    """Tune one conv layer: measure the candidates, pick + persist a winner.

    Unless ``force``, a persisted winner for this key is returned as-is
    (``cached=True``, no re-measurement).  ``batch`` is part of the cache
    key and sizes the synthesized measurement inputs (the serving buckets
    tune per batch size).  Candidates whose output is not bit-identical to
    the default plan's are discarded unless ``allow_inexact`` (then a
    float-tolerance ``allclose`` gate applies instead); among survivors
    the fastest wins, but only if it beats the default by more than
    ``MIN_GAIN`` — otherwise the default ships.
    """
    kw = dict(
        stride=stride,
        padding=padding,
        groups=groups,
        relu=relu,
        has_bias=has_bias,
        requant_kind=requant_kind,
        in_sz=in_sz,
        w_sz=w_sz,
        out_sz=out_sz,
        w_bits=w_bits,
    )
    key = layer_key(
        x_hw, c_in, k, c_out, emulate_hw=policy.resolve().emulate_hw, batch=batch, **kw
    )
    if not force:
        entry = _load_plans(cache_path()).get(key)
        sched = load_schedule(key)
        if sched is not None:
            return TuneResult(
                key=key,
                schedule=sched,
                us=float(entry.get("us", 0.0)),
                us_default=float(entry.get("us_default", 0.0)),
                candidates=(),
                cached=True,
            )
    base = policy.resolve().with_overrides(tuning="off")

    def build(pol):
        return plan_conv_layer(x_hw, c_in, k, c_out, policy=pol, **kw)

    policies = candidate_policies(
        x_hw,
        c_in,
        k,
        c_out,
        stride=stride,
        padding=padding,
        groups=groups,
        in_sz=in_sz,
        w_sz=w_sz,
        out_sz=out_sz,
        policy=base,
    )
    # Distinct policies can resolve to the same plan (caps, degenerate
    # tiles) — measure each distinct *plan* once.
    plans = list(dict.fromkeys(build(p) for p in policies))
    default_plan = plans[0]
    us_default, ref_out = _measure_plan(
        default_plan, in_sz=in_sz, warmup=warmup, reps=reps, batch=batch
    )
    timings = [CandidateTiming(_schedule_of_plan(default_plan), us_default, True)]
    best_plan, best_us = default_plan, us_default
    for plan in plans[1:]:
        try:
            us, out = _measure_plan(
                plan, in_sz=in_sz, warmup=warmup, reps=reps, batch=batch
            )
        except Exception as e:
            # Candidates come from an *estimated* cost model; one whose
            # real footprint the compiler rejects (VMEM overflow, …) is
            # discarded like an inexact one, not allowed to abort the
            # whole search.
            warnings.warn(
                f"autotune candidate {_schedule_of_plan(plan)} failed to "
                f"compile/run ({e}); discarded",
                RuntimeWarning,
                stacklevel=2,
            )
            continue
        if out.dtype == ref_out.dtype and np.array_equal(out, ref_out):
            exact = True
        elif allow_inexact and np.allclose(
            out.astype(np.float64),
            ref_out.astype(np.float64),
            rtol=1e-4,
            atol=1e-4,
        ):
            exact = False
        else:
            continue  # changes math: never a legal schedule move
        timings.append(CandidateTiming(_schedule_of_plan(plan), us, exact))
        if us < best_us:
            best_plan, best_us = plan, us
    if best_plan is not default_plan:
        # Drift-robust verification of the win: re-measure the default and
        # the challenger interleaved before shipping a non-default plan —
        # the never-slower rule must hold against a paired ratio, not
        # against two timings taken minutes apart on a drifting machine.
        try:
            us_d2, us_b2, ratio = _measure_pair(
                default_plan, best_plan, in_sz=in_sz, reps=reps, batch=batch
            )
        except Exception:  # challenger died on re-measure: default ships
            ratio = float("inf")
        if ratio > 1 - MIN_GAIN:
            best_plan, best_us = default_plan, us_default
        else:
            best_us, us_default = us_b2, us_d2
    schedule = _schedule_of_plan(best_plan)
    result = TuneResult(
        key=key,
        schedule=schedule,
        us=best_us,
        us_default=us_default,
        candidates=tuple(timings),
    )
    if persist:
        store_schedule(
            key,
            {
                "schedule": schedule,
                "us": round(best_us, 1),
                "us_default": round(us_default, 1),
                "speedup": round(result.speedup, 3),
                "candidates": len(plans),
                "reps": reps,
            },
        )
    return result


def tuned_schedule(
    x_hw: Tuple[int, int],
    c_in: int,
    k: int,
    c_out: int,
    *,
    stride: int,
    padding: Optional[int],
    groups: int,
    relu: bool,
    has_bias: bool,
    requant_kind: Optional[str],
    in_sz: int,
    w_sz: int,
    out_sz: int,
    w_bits: int = 8,
    policy: ExecutionPolicy,
    batch: int = 1,
) -> Optional[Dict[str, object]]:
    """The schedule ``plan_conv_layer`` should apply under ``policy.tuning``.

    "cached": the persisted winner or None (default plan).  "auto": the
    persisted winner, tuning (measuring) once on a miss and persisting.
    ``batch`` selects the batch-specific winner (a plan built for a
    serving bucket looks up the schedule measured at that bucket's N).
    """
    kw = dict(
        stride=stride,
        padding=padding,
        groups=groups,
        relu=relu,
        has_bias=has_bias,
        requant_kind=requant_kind,
        in_sz=in_sz,
        w_sz=w_sz,
        out_sz=out_sz,
        w_bits=w_bits,
    )
    key = layer_key(
        x_hw, c_in, k, c_out, emulate_hw=policy.resolve().emulate_hw, batch=batch, **kw
    )
    sched = load_schedule(key)
    if sched is None and policy.tuning == "auto":
        sched = tune_conv_layer(
            x_hw, c_in, k, c_out, policy=policy, batch=batch, **kw
        ).schedule
    return sched


def tune_model(
    cfg,
    policy: ExecutionPolicy = ExecutionPolicy(),
    c_in: Optional[int] = None,
    datapath: str = "float",
    **tune_kw,
) -> List[Tuple[str, TuneResult]]:
    """Tune every conv layer of a ``CNNConfig`` (the ``plan_model`` walk).

    Returns ``[(layer label, TuneResult), ...]``; repeated identical
    layers hit the plan cache after their first tuning.  ``tune_kw``
    forwards to :func:`tune_conv_layer` (``reps``, ``force``, ``batch`` —
    pass the serving bucket's batch size to tune the model for it, …).
    """
    if datapath not in ("float", "int8", "int5"):
        raise ValueError(
            f"datapath {datapath!r} not in ('float', 'int8', 'int5')")
    int8 = datapath in ("int8", "int5")
    pol = policy.resolve()
    results = []
    c = cfg.layers[0].M if c_in is None else int(c_in)
    last_i = len(cfg.layers) - 1
    for i, l in enumerate(cfg.layers):
        res = tune_conv_layer(
            (l.H_I, l.W_I),
            c,
            l.K,
            l.N,
            stride=l.stride,
            padding=l.padding,
            groups=c // l.M,
            relu=True,
            has_bias=not int8,
            requant_kind="mult_shift" if int8 and i != last_i else None,
            in_sz=1 if int8 else 4,
            w_sz=1 if int8 else 4,
            out_sz=(4 if i == last_i else 1) if int8 else 4,
            w_bits=5 if datapath == "int5" else 8,
            policy=pol,
            **tune_kw,
        )
        results.append((f"{cfg.name}/{l.name}.{datapath}", res))
        c = l.N
    return results
