"""Execution policy: *how* to run the TrIM kernels, decided in one place.

Before this module existed every kernel decision (substrate, ``emulate_hw``,
tile sizes, VMEM budget) travelled as ad-hoc kwargs through six layers of
the stack (``kernels/ops`` -> ``nn/blocks`` -> ``nn/conv`` -> ``nn/models``
-> ``launch/*`` -> CLI flags).  ``ExecutionPolicy`` is the frozen, hashable
replacement: one value object that says how to execute, carried once and
compiled into per-layer :class:`repro.engine.plan.ConvLayerPlan` schedules.

The kernel dispatch rule ("TPU -> compiled Pallas, CPU -> oracle, force ->
interpret") lives here, in :meth:`ExecutionPolicy.resolved_substrate`, and
nowhere else.
"""

from __future__ import annotations

import dataclasses
import warnings
from dataclasses import dataclass
from typing import Optional

import jax

from repro.kernels.trim_conv2d import VMEM_BUDGET_BYTES

#: User-facing substrate choices ("auto" resolves per backend at plan time).
SUBSTRATES = ("auto", "pallas", "oracle", "interpret", "f32exact")

#: Concrete substrates a resolved policy / layer plan can carry.
RESOLVED_SUBSTRATES = ("pallas", "oracle", "interpret", "f32exact")

#: Plan-tuning modes: "off" plans from the policy defaults, "cached" applies
#: persisted autotuner winners (miss -> default plan), "auto" tunes on miss
#: and persists the winner (``repro.engine.autotune``, DESIGN.md §7).
TUNING_MODES = ("off", "cached", "auto")


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@dataclass(frozen=True)
class ExecutionPolicy:
    """Frozen, hashable description of *how* to run the TrIM kernels.

    ``substrate``
        "auto" (compiled Pallas on TPU, jnp oracle elsewhere — the
        production default), "pallas" (the Pallas kernels everywhere:
        compiled on TPU, interpret mode off-TPU — what the legacy
        ``force_pallas=True`` meant), "oracle" (the pure-jnp reference on
        every backend), "interpret" (Pallas interpret mode even on TPU), or
        "f32exact" (integer convs evaluated exactly on the fast f32 conv
        path via channel chunking — ``kernels.ref.conv2d_exact_f32``;
        floats fall back to the oracle).  "auto" never resolves to
        "f32exact": the autotuner promotes layers onto it only after
        measuring a win (DESIGN.md §7).
    ``emulate_hw``
        Replay the FPGA's strided-layer schedule (stride-1 sweep +
        downstream decimation + unfused epilogue, paper §V) instead of the
        stride-aware fused kernel — Table I/II fidelity mode.
    ``tile_h`` / ``tile_w`` / ``block_c`` / ``block_f``
        Kernel schedule overrides.  ``tile_w=None`` lets ``pick_tile_w``
        auto-size the output-width tile from ``vmem_budget``; ``block_*``
        are upper bounds, capped per layer (and per conv group) at plan
        time.
    ``vmem_budget``
        Byte budget for the width-tile auto-pick (DESIGN.md §4).
    ``tuning``
        Per-layer plan tuning mode (the ``--tuning {off,cached,auto}`` CLI
        flag).  "off" resolves every layer from the policy defaults above;
        "cached" makes ``plan_conv_layer`` transparently apply the
        persisted autotuner winner for the layer's cache key (geometry,
        dtype byte sizes, epilogue, backend + device kind — see
        ``repro.engine.autotune``), falling back to the default plan on a
        miss; "auto" additionally tunes on a miss (measures the candidate
        schedules once) and persists the winner under ``tuned_plans/``.
        Tuning composes with ``substrate="auto"`` only: an explicitly
        pinned substrate is a stronger request than the cache, so pinned
        policies plan as if tuning were off.

    Policies are plain frozen dataclasses: hashable (usable as ``jax.jit``
    static arguments and ``lru_cache`` keys) and comparable by value.
    """

    substrate: str = "auto"
    emulate_hw: bool = False
    tile_h: int = 8
    tile_w: Optional[int] = None
    block_c: int = 128
    block_f: int = 128
    vmem_budget: int = VMEM_BUDGET_BYTES
    tuning: str = "off"

    def __post_init__(self) -> None:
        if self.substrate not in SUBSTRATES:
            raise ValueError(f"substrate {self.substrate!r} not in {SUBSTRATES}")
        if self.tuning not in TUNING_MODES:
            raise ValueError(f"tuning {self.tuning!r} not in {TUNING_MODES}")

    def resolved_substrate(self) -> str:
        """THE kernel dispatch rule — the only copy in the tree.

        auto -> compiled Pallas on TPU, jnp oracle elsewhere;
        pallas -> compiled on TPU, interpret mode off-TPU;
        oracle / interpret -> exactly that, on every backend.
        """
        if self.substrate == "auto":
            return "pallas" if on_tpu() else "oracle"
        if self.substrate == "pallas" and not on_tpu():
            return "interpret"
        return self.substrate

    def resolve(self) -> "ExecutionPolicy":
        """Pin the substrate to a concrete choice for the current backend."""
        return dataclasses.replace(self, substrate=self.resolved_substrate())

    def with_overrides(self, **kw) -> "ExecutionPolicy":
        return dataclasses.replace(self, **kw)

    @classmethod
    def from_args(cls, args) -> "ExecutionPolicy":
        """Build a policy from parsed CLI args (``launch.cli``).

        Reads ``args.substrate`` (the ``--substrate`` flag; the deprecated
        ``--force-pallas`` alias stores "pallas" into the same dest),
        ``args.emulate_hw``, and ``args.tuning`` (the ``--tuning
        {off,cached,auto}`` flag mapping onto :attr:`tuning`) — missing
        attributes fall back to the defaults, so any
        ``argparse.Namespace`` works.
        """
        return cls(
            substrate=getattr(args, "substrate", None) or "auto",
            emulate_hw=bool(getattr(args, "emulate_hw", False)),
            tuning=getattr(args, "tuning", None) or "off",
        )


def policy_from_legacy(
    policy: Optional[ExecutionPolicy],
    *,
    emulate_hw: Optional[bool] = None,
    force_pallas: Optional[bool] = None,
    caller: str = "",
    **schedule: object,
) -> ExecutionPolicy:
    """Deprecation shim: fold the legacy per-call kwargs into a policy.

    ``emulate_hw`` / ``force_pallas`` passed as non-None emit a
    ``DeprecationWarning`` and override the corresponding policy fields
    (``force_pallas=True`` maps to ``substrate="pallas"``).  ``schedule``
    kwargs (``tile_h``/``tile_w``/``block_c``/``block_f``) are silent
    per-call schedule overrides — non-None values replace the policy's.
    """
    pol = policy if policy is not None else ExecutionPolicy()
    legacy = {"emulate_hw": emulate_hw, "force_pallas": force_pallas}
    named = [k for k, v in legacy.items() if v is not None]
    if named:
        warnings.warn(
            f"{caller or 'trim kernel call'}: the {', '.join(named)} "
            "kwarg(s) are deprecated; pass "
            "policy=repro.engine.ExecutionPolicy(...) instead",
            DeprecationWarning,
            stacklevel=3,
        )
    if emulate_hw is not None:
        pol = dataclasses.replace(pol, emulate_hw=bool(emulate_hw))
    if force_pallas is not None:
        sub = "pallas" if force_pallas else "auto"
        pol = dataclasses.replace(pol, substrate=sub)
    overrides = {k: v for k, v in schedule.items() if v is not None}
    if overrides:
        pol = dataclasses.replace(pol, **overrides)
    return pol
