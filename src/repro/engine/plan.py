"""Static execution plans: compile the TrIM kernel configuration once.

A :class:`ConvLayerPlan` is the fully-resolved static schedule for one conv
layer — substrate, decimation mode, tiling geometry (``conv2d_geom`` /
``pick_tile_w``), per-group block caps, and the fused-epilogue descriptor —
computed once from an :class:`~repro.engine.policy.ExecutionPolicy` and the
layer shape, then handed to the executor (``repro.engine.execute``) and to
``jax.jit`` as a hashable static argument.

:func:`plan_model` walks a ``CNNConfig``'s layer stack (tracking the
running channel count for the grouped AlexNet two-tower layers) and emits a
:class:`ModelPlan` whose ``forward`` / ``loss`` / ``quantize`` /
``calibrate*`` / ``forward_int8`` entry points run the whole network off
the per-layer plans — ``ConvNet``, ``build_model``, the launchers, and the
benchmarks all consume plans instead of re-deriving kernel kwargs.

Both plan types are frozen dataclasses of plain values: hashable,
comparable by value, and cached (``lru_cache``), so rebuilding a plan from
an equal config + policy hits every downstream cache — the planner's own,
the ``make_trim_conv2d_vjp`` handle cache, and ``jax.jit``'s static-arg
trace cache.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

from repro.engine.policy import ExecutionPolicy
from repro.kernels.trim_conv2d import Conv2DGeom, conv2d_geom
from repro.kernels.trim_conv2d_vjp import make_trim_conv2d_vjp


@dataclass(frozen=True)
class ConvLayerPlan:
    """Fully-resolved static schedule for one TrIM conv layer.

    ``substrate`` is already resolved ("pallas" | "oracle" | "interpret" —
    the policy's dispatch rule ran at plan time).  ``tile_w`` is the
    output-width tile ``pick_tile_w`` chose for one group's kernel call
    (``geom.n_wt == 1`` means the degenerate single-W-block schedule the
    paper shapes keep); ``tile_w_arg`` preserves an explicit user override
    (None lets each kernel invocation auto-pick with its actual dtypes —
    identical to ``tile_w`` for the planned dtype).  ``block_c`` /
    ``block_f`` are capped to the per-group channel/filter counts.
    ``geom`` is the per-group kernel geometry — computed at stride 1 when
    ``emulate_hw`` decimation replays the FPGA's strided-layer schedule.
    """

    x_hw: Tuple[int, int]
    c_in: int
    k: int
    c_out: int
    stride: int
    padding: Optional[int]
    groups: int
    relu: bool
    pool: bool
    has_bias: bool
    requant_kind: Optional[str]
    substrate: str
    emulate_hw: bool
    tile_h: int
    tile_w: int
    tile_w_arg: Optional[int]
    block_c: int
    block_f: int
    vmem_budget: int
    epilogue: str
    geom: Conv2DGeom
    #: Stored weight width in bits. 8 = plain int8 weights; 5 = the MSR
    #: compressed lane (sign + 4-bit most-significant-run codes,
    #: ``core.trim.quant.msr_compress`` — DESIGN.md §9.3), whose runtime
    #: operand is int8 with ``|w| <= 31``, widening the f32exact lossless
    #: chunks (`run_conv2d` derives the bound from this field).  Part of
    #: the plan's identity: tuned-plan cache keys carry it.
    w_bits: int = 8
    #: True when this schedule came from the autotuner's plan cache
    #: (``repro.engine.autotune``, DESIGN.md §7) rather than the policy
    #: defaults.  Metadata, not schedule: ``compare=False`` keeps a tuned
    #: plan whose winning schedule IS the default equal (and hash-equal)
    #: to the default plan, so ``jax.jit`` reuses one executable for both.
    tuned: bool = field(default=False, compare=False)

    @property
    def decimate(self) -> bool:
        """FPGA-faithful strided-layer replay: stride-1 sweep + decimation
        + unfused epilogue (paper §V)."""
        return self.emulate_hw and self.stride > 1

    @property
    def interpret(self) -> bool:
        return self.substrate == "interpret"

    def vjp(self, has_bias: Optional[bool] = None):
        """The ``jax.custom_vjp``-wrapped fused forward for this schedule
        (float Pallas path).  Cached per static config in
        ``make_trim_conv2d_vjp`` — equal plans share one handle."""
        return make_trim_conv2d_vjp(
            stride=self.stride,
            padding=self.padding,
            relu=self.relu,
            has_bias=self.has_bias if has_bias is None else has_bias,
            tile_h=self.tile_h,
            tile_w=self.tile_w_arg,
            block_c=self.block_c,
            block_f=self.block_f,
            vmem_budget=self.vmem_budget,
            interpret=self.interpret,
        )

    def describe(self) -> Dict[str, object]:
        """Compact schedule record (benchmark artifacts, dry-run JSON)."""
        d = {
            "substrate": self.substrate,
            "tile_w": self.tile_w,
            "n_wt": self.geom.n_wt,
            "epilogue": self.epilogue,
        }
        if self.w_bits != 8:
            d["w_bits"] = self.w_bits
        if self.tuned:
            d["tuned"] = True
        return d


@functools.lru_cache(maxsize=None)
def plan_conv_layer(
    x_hw: Tuple[int, int],
    c_in: int,
    k: int,
    c_out: int,
    *,
    stride: int = 1,
    padding: Optional[int] = None,
    groups: int = 1,
    relu: bool = False,
    pool: bool = False,
    has_bias: bool = False,
    requant_kind: Optional[str] = None,
    in_sz: int = 4,
    w_sz: int = 4,
    out_sz: int = 4,
    w_bits: int = 8,
    policy: ExecutionPolicy = ExecutionPolicy(),
    batch: int = 1,
) -> ConvLayerPlan:
    """Resolve one layer's static schedule under ``policy`` (cached).

    ``x_hw`` is the layer's input spatial extent, ``c_in`` the *total*
    input channel count (all groups), ``c_out`` the total filter count.
    ``requant_kind`` describes the planned fused requantization (None |
    "shift" | "mult_shift") — the actual multiplier/shift values stay
    runtime arguments (per-channel calibrations are traced arrays).
    ``in_sz``/``w_sz``/``out_sz`` are element byte sizes for the VMEM
    width-tile auto-pick (pass the real itemsizes for non-f32 datapaths).
    ``batch`` only selects which batch-specific autotuner winner applies
    (tuned-plan cache keys carry the batch axis); it is not a field of the
    resulting plan — kernels take the batch from the runtime array.

    When ``policy.tuning`` is "cached" or "auto" the persisted autotuner
    winner for this layer's cache key is applied transparently on top of
    the policy (substrate + tile/block schedule — DESIGN.md §7); a cache
    miss under "cached" falls back to the default plan, under "auto" it
    tunes once (measures the candidate schedules) and persists the winner.
    Tuning composes with ``substrate="auto"`` only: an explicitly pinned
    substrate (``--substrate oracle/interpret/...``) is a stronger request
    than the cache — the persisted winner was measured against the auto
    default, so it is NOT applied over a pin (the plan resolves as if
    tuning were off).  Tuning happens here, at plan time — plan eagerly
    (outside ``jit``) when tuning is on.
    """
    pol = policy.resolve()
    tuned = False
    if pol.tuning != "off" and policy.substrate == "auto":
        from repro.engine import autotune  # deferred: autotune imports us

        schedule = autotune.tuned_schedule(
            x_hw,
            c_in,
            k,
            c_out,
            stride=stride,
            padding=padding,
            groups=groups,
            relu=relu,
            has_bias=has_bias,
            requant_kind=requant_kind,
            in_sz=in_sz,
            w_sz=w_sz,
            out_sz=out_sz,
            w_bits=w_bits,
            policy=pol,
            batch=batch,
        )
        pol = pol.with_overrides(tuning="off")
        if schedule is not None:
            pol = pol.with_overrides(**schedule)
            tuned = True
    cg = c_in // groups
    fg = c_out // groups
    block_c = min(pol.block_c, cg)
    block_f = min(pol.block_f, fg)
    decimate = pol.emulate_hw and stride > 1
    geom = conv2d_geom(
        (1, x_hw[0], x_hw[1], cg),
        (k, k, cg, fg),
        stride=1 if decimate else stride,
        padding=padding,
        tile_h=pol.tile_h,
        tile_w=pol.tile_w,
        block_c=block_c,
        block_f=block_f,
        in_sz=in_sz,
        w_sz=w_sz,
        out_sz=out_sz,
        vmem_budget=pol.vmem_budget,
    )
    parts = []
    if has_bias:
        parts.append("bias")
    if relu:
        parts.append("relu")
    if requant_kind == "shift":
        parts.append("requant_shift")
    elif requant_kind == "mult_shift":
        parts.append("requant")
    epilogue = "+".join(parts) if parts else "linear"
    if decimate:
        epilogue = f"decimate->{epilogue}"
    return ConvLayerPlan(
        x_hw=x_hw,
        c_in=c_in,
        k=k,
        c_out=c_out,
        stride=stride,
        padding=padding,
        groups=groups,
        relu=relu,
        pool=pool,
        has_bias=has_bias,
        requant_kind=requant_kind,
        substrate=pol.substrate,
        emulate_hw=pol.emulate_hw,
        tile_h=pol.tile_h,
        tile_w=geom.TW,
        tile_w_arg=pol.tile_w,
        block_c=block_c,
        block_f=block_f,
        vmem_budget=pol.vmem_budget,
        epilogue=epilogue,
        geom=geom,
        w_bits=w_bits,
        tuned=tuned,
    )


@dataclass(frozen=True)
class ModelPlan:
    """Per-layer plans + entry points for one CNN under one policy.

    Execution entry points delegate to ``repro.engine.execute`` (lazy
    imports keep the module graph acyclic); the plan itself is pure static
    data and safe to close over under ``jax.jit``.
    """

    cfg: object
    policy: ExecutionPolicy
    layers: Tuple[ConvLayerPlan, ...]
    #: Batch size the per-layer tuned schedules were selected for (the
    #: autotuner's cache keys carry a batch axis).  Kernels still take the
    #: batch from the runtime array — this only picks which persisted
    #: winners the layer plans baked in, so a serving bucket's plan can
    #: differ from the N=1 plan.
    batch: int = 1

    def init(self, key):
        from repro.nn.conv import init_cnn

        return init_cnn(key, self.cfg)

    def forward(self, params, images):
        from repro.engine import execute

        return execute.forward(self, params, images)

    def loss(self, params, batch):
        from repro.engine import execute

        return execute.loss(self, params, batch)

    def quantize(self, params):
        from repro.nn.conv import quantize_cnn

        return quantize_cnn(params, self.cfg)

    def forward_int8(self, qparams, images_u8, requant_shifts=None, requant=None):
        from repro.engine import execute

        return execute.forward_int8(
            self, qparams, images_u8, requant_shifts=requant_shifts, requant=requant
        )

    def calibrate_requant_shifts(self, qparams, sample_u8):
        from repro.engine import execute

        return execute.calibrate_requant_shifts(self, qparams, sample_u8)

    def calibrate_requant(self, qparams, sample_u8, per_channel=True):
        from repro.engine import execute

        return execute.calibrate_requant(
            self, qparams, sample_u8, per_channel=per_channel
        )

    def quantize_int5(self, params, compensate=True):
        from repro.nn.conv import quantize_cnn_int5

        return quantize_cnn_int5(params, self.cfg, compensate=compensate)

    def forward_int5(self, qparams, images_u8, requant=None):
        from repro.engine import execute

        return execute.forward_int5(self, qparams, images_u8, requant=requant)

    def calibrate_requant_int5(self, qparams, sample_u8, per_channel=True):
        from repro.engine import execute

        return execute.calibrate_requant_int5(
            self, qparams, sample_u8, per_channel=per_channel
        )

    @property
    def int8(self) -> "ModelPlan":
        """This model's integer-datapath sibling plan: same architecture +
        policy, but bias-free fused-requant epilogues and uint8/int8 byte
        sizes for the VMEM tile pick — what ``forward_int8`` actually runs
        and what its benchmark/dry-run records should describe."""
        return plan_model(
            self.cfg,
            self.policy,
            c_in=self.layers[0].c_in,
            datapath="int8",
            batch=self.batch,
        )

    @property
    def int5(self) -> "ModelPlan":
        """The MSR-compressed weight lane's sibling plan (DESIGN.md §9.3):
        identical to :attr:`int8` except every layer plan carries
        ``w_bits=5``, so ``run_conv2d`` widens the f32exact chunk bound for
        the ``|w| <= 31`` decompressed operands and the autotuner keys the
        lane separately.  What ``forward_int5`` actually runs."""
        return plan_model(
            self.cfg,
            self.policy,
            c_in=self.layers[0].c_in,
            datapath="int5",
            batch=self.batch,
        )

    def executable_for(self, batch: int, datapath: str = "float"):
        """Ahead-of-time-compiled model forward for one static batch size.

        The serving hook (DESIGN.md §8): ``jax.jit(...).lower(...).compile()``
        over this plan's forward at exactly ``(batch, H, W, C)``, cached per
        (plan, batch, datapath) in ``execute.executable_for`` — a request
        stream served through the returned callable structurally cannot
        retrace.  "float" → ``compiled(params, images_f32)``;
        "int8" → ``compiled(qparams, images_u8, requant)`` with calibrated
        per-layer (mult, shift) pairs (the dynamic-shift requant path is
        batch-dependent and therefore not servable from buckets);
        "int5" → same signature, ``qparams`` additionally carrying the
        per-channel MSR exponents and ``requant`` the exponent-folded pairs
        from ``calibrate_requant_int5`` (DESIGN.md §9.3).
        """
        from repro.engine import execute

        return execute.executable_for(self, batch, datapath)

    def describe(self) -> Tuple[Dict[str, object], ...]:
        return tuple(lp.describe() for lp in self.layers)


@functools.lru_cache(maxsize=None)
def plan_model(
    cfg,
    policy: ExecutionPolicy = ExecutionPolicy(),
    c_in: Optional[int] = None,
    datapath: str = "float",
    layer_substrates: Optional[Tuple[Optional[str], ...]] = None,
    batch: int = 1,
) -> ModelPlan:
    """Compile a ``CNNConfig`` into a :class:`ModelPlan` (cached).

    Walks ``cfg.layers`` tracking the running channel count ``c`` (grouped
    AlexNet two-tower layers have ``groups = c // layer.M``), resolving one
    :class:`ConvLayerPlan` per layer under the policy.  ``c_in``
    overrides the first layer's input channel count (defaults to
    ``cfg.layers[0].M``).  ``datapath`` is "float" (biased conv + fused
    bias/ReLU, f32 byte sizes), "int8" (the paper's integer inference
    lane: bias-free, fused mult+shift requant on every non-last layer,
    uint8/int8 byte sizes — the last layer emits raw int32 psums), or
    "int5" (the MSR-compressed weight lane: identical layer shapes and
    epilogues but ``w_bits=5`` on every layer plan — DESIGN.md §9.3).
    ``batch`` selects batch-specific autotuner winners per layer (serving
    buckets plan at their own N); the default 1 keeps historical plans.

    ``layer_substrates`` pins per-layer substrates (a tuple with one entry
    per conv layer; ``None`` entries keep the policy's choice), so a
    ModelPlan can be heterogeneous — small layers on the XLA oracle, wide
    layers on Pallas, integer layers on f32exact.  Plans resolved under
    ``policy.tuning != "off"`` become heterogeneous the same way, from the
    autotuner's per-layer cache instead of an explicit tuple (a pinned
    layer beats the cache, like a pinned ``--substrate`` does).

    The policy is passed to the per-layer planner *unresolved*: each
    ``plan_conv_layer`` call resolves it, and tuning only composes with
    ``substrate="auto"`` — resolving here would erase that marker.
    """
    if datapath not in ("float", "int8", "int5"):
        raise ValueError(
            f"datapath {datapath!r} not in ('float', 'int8', 'int5')")
    if layer_substrates is not None and len(layer_substrates) != len(cfg.layers):
        raise ValueError(
            f"layer_substrates has {len(layer_substrates)} entries for "
            f"{len(cfg.layers)} conv layers"
        )
    int8 = datapath in ("int8", "int5")
    plans = []
    c = cfg.layers[0].M if c_in is None else int(c_in)
    last_i = len(cfg.layers) - 1
    for i, l in enumerate(cfg.layers):
        lpol = policy
        if layer_substrates is not None and layer_substrates[i] is not None:
            lpol = policy.with_overrides(substrate=layer_substrates[i])
        plans.append(
            plan_conv_layer(
                (l.H_I, l.W_I),
                c,
                l.K,
                l.N,
                stride=l.stride,
                padding=l.padding,
                groups=c // l.M,
                relu=True,
                pool=i in cfg.pool_after,
                has_bias=not int8,
                requant_kind="mult_shift" if int8 and i != last_i else None,
                in_sz=1 if int8 else 4,
                w_sz=1 if int8 else 4,
                out_sz=(4 if i == last_i else 1) if int8 else 4,
                w_bits=5 if datapath == "int5" else 8,
                policy=lpol,
                batch=batch,
            )
        )
        c = l.N
    return ModelPlan(cfg=cfg, policy=policy, layers=tuple(plans), batch=int(batch))
