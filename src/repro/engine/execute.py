"""Execute planned TrIM conv layers and planned CNN models.

This module owns the ONLY kernel dispatch site in the tree:
:func:`run_conv2d` takes a resolved :class:`~repro.engine.plan.ConvLayerPlan`
(a ``jax.jit`` static argument) and runs exactly the substrate the plan
chose — the jnp oracle, the compiled Pallas kernel, or Pallas interpret
mode — with the fused epilogue, grouped-conv splitting, the float custom
VJP, and the ``emulate_hw`` decimation replay all handled here once.

The model-level entry points (:func:`forward`, :func:`loss`,
:func:`forward_int8`, :func:`forward_int5`,
:func:`calibrate_requant_shifts`, :func:`calibrate_requant`,
:func:`calibrate_requant_int5`) iterate a :class:`~repro.engine.plan.ModelPlan`'s
per-layer plans; they are what ``ConvNet``, the launchers, and the
benchmarks call — nothing above this layer re-derives kernel kwargs.
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.engine.plan import ConvLayerPlan, ModelPlan
from repro.kernels import ref
from repro.kernels.requant import requant_mult_shift
from repro.kernels.trim_conv2d import trim_conv2d_pallas


def apply_epilogue(
    out: jax.Array,
    bias: Optional[jax.Array],
    relu: bool,
    requant_shift: Optional[int],
    requant: Optional[Tuple[jax.Array, jax.Array]] = None,
) -> jax.Array:
    """Unfused epilogue (oracle + emulate_hw decimation arms).

    Bit-identical to the fused kernel flush: the power-of-two path shifts
    without rounding (the engine's output stage) and the multiplier+shift
    path reuses ``kernels.requant.requant_mult_shift``.
    """
    if bias is not None:
        out = out + bias.astype(out.dtype)
    if relu:
        out = jnp.maximum(out, 0)
    if requant_shift is not None:
        out = jnp.clip(jnp.right_shift(out, requant_shift), 0, 255)
        out = out.astype(jnp.uint8)
    if requant is not None:
        out = requant_mult_shift(out, requant[0], requant[1])
        out = out.astype(jnp.uint8)
    return out


def max_pool2x2(x: jax.Array) -> jax.Array:
    """2x2/stride-2 max pool via reshape+max (VALID).  Equivalent to
    reduce_window but robustly reverse-differentiable under nested jit."""
    B, H, W, C = x.shape
    x = x[:, : H // 2 * 2, : W // 2 * 2]
    x = x.reshape(B, H // 2, 2, W // 2, 2, C)
    return x.max(axis=(2, 4))


def _group_call(plan, xg, wg, bg, rq, requant_shift):
    """One conv group on the planned Pallas/interpret substrate."""
    kw = dict(
        padding=plan.padding,
        tile_h=plan.tile_h,
        tile_w=plan.tile_w_arg,
        block_c=min(plan.block_c, xg.shape[-1]),
        block_f=min(plan.block_f, wg.shape[-1]),
        vmem_budget=plan.vmem_budget,
        interpret=plan.interpret,
    )
    if plan.decimate:
        # emulate_hw stays forward-only on the Pallas path (DESIGN.md §6):
        # the FPGA-faithful decimation schedule is an inference/benchmark
        # artifact, not a training datapath.
        s = plan.stride
        o = trim_conv2d_pallas(xg, wg, **kw)
        return o[:, ::s, ::s, :]
    if jnp.issubdtype(xg.dtype, jnp.floating):
        # Float path: the custom-VJP-wrapped fused kernel, so jax.grad
        # runs the Pallas input-grad/weight-grad pair (DESIGN.md §6).
        f = plan.vjp(has_bias=bg is not None)
        return f(xg, wg, bg) if bg is not None else f(xg, wg)
    return trim_conv2d_pallas(
        xg,
        wg,
        stride=plan.stride,
        bias=bg,
        relu=plan.relu,
        requant_shift=requant_shift,
        requant=rq,
        **kw,
    )


@functools.partial(jax.jit, static_argnames=("plan", "requant_shift"))
def run_conv2d(
    plan: ConvLayerPlan,
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    requant: Optional[Tuple[jax.Array, jax.Array]] = None,
    *,
    requant_shift: Optional[int] = None,
) -> jax.Array:
    """Run one planned conv (+ fused epilogue).  THE dispatch site.

    x (N,H,W,C), w (K,K,C/groups,F) -> (N,H_O,W_O,F); the substrate,
    decimation mode, and tiling all come from ``plan`` (static).  ``bias``
    / ``requant_shift`` / ``requant`` are the runtime epilogue inputs —
    per-channel requant calibrations are traced (F,) int32 array pairs.
    """
    if plan.substrate in ("oracle", "f32exact"):
        # f32exact: integer convs run exactly on the fast f32 conv path
        # (channel-chunked, bit-identical — ref.conv2d_exact_f32); float
        # inputs degrade to the plain oracle inside the helper.  Sub-8-bit
        # weight plans tighten the chunking bound: the int5 MSR lane's
        # decompressed operands satisfy |w| <= 2^w_bits - 1 = 31, widening
        # the lossless channel chunks ~4x (DESIGN.md §9.3).
        oracle = plan.substrate == "oracle"
        s = plan.stride
        kw = dict(padding=plan.padding, groups=plan.groups)
        if not oracle and plan.w_bits < 8:
            kw["w_abs_max"] = (1 << plan.w_bits) - 1
        conv = ref.conv2d_ref if oracle else ref.conv2d_exact_f32
        if plan.decimate:
            full = conv(x, w, stride=1, **kw)
            out = full[:, ::s, ::s, :]
        else:
            out = conv(x, w, stride=s, **kw)
        return apply_epilogue(out, bias, plan.relu, requant_shift, requant)

    if plan.groups == 1:
        out = _group_call(plan, x, w, bias, requant, requant_shift)
    else:
        cg = x.shape[-1] // plan.groups
        F = w.shape[-1]
        fg = F // plan.groups

        def rq_slice(g):
            # Per-group requant slices (scalars broadcast to (F,) first so
            # per-channel and per-tensor calibrations both land per group).
            if requant is None:
                return None
            m, s = requant
            m = jnp.broadcast_to(jnp.asarray(m, jnp.int32), (F,))
            s = jnp.broadcast_to(jnp.asarray(s, jnp.int32), (F,))
            return (m[g * fg : (g + 1) * fg], s[g * fg : (g + 1) * fg])

        outs = [
            _group_call(
                plan,
                x[..., g * cg : (g + 1) * cg],
                w[..., g * fg : (g + 1) * fg],
                None if bias is None else bias[g * fg : (g + 1) * fg],
                rq_slice(g),
                requant_shift,
            )
            for g in range(plan.groups)
        ]
        out = jnp.concatenate(outs, axis=-1)
    if plan.decimate:
        out = apply_epilogue(out, bias, plan.relu, requant_shift, requant)
    return out


def run_conv_layer(plan: ConvLayerPlan, p, x: jax.Array) -> jax.Array:
    """One model conv block: planned conv -> shard -> optional 2x2 pool.

    ``p``: {"kernel": (K,K,C/groups,F) [, "bias": (F,) , "requant":
    ((F,), (F,)) int32 calibration]} — params-borne requant takes
    precedence (the per-channel calibrated int8 datapath).
    """
    from repro.distributed.sharding import shard

    w = p["kernel"]
    if jnp.issubdtype(x.dtype, jnp.floating):
        w = w.astype(x.dtype)
    x = run_conv2d(plan, x, w, p.get("bias"), p.get("requant"))
    x = shard(x, "batch", "img_h", "img_w", "cout")
    if plan.pool:
        x = max_pool2x2(x)
    return x


# ---------------------------------------------------------------------------
# Model-level entry points (consumed via ModelPlan)
# ---------------------------------------------------------------------------


def forward(plan: ModelPlan, params, images: jax.Array) -> jax.Array:
    """images (B,H,W,C) float -> logits (B, n_classes) through the planned
    conv stack (fused bias+ReLU epilogues) and the FC head."""
    x = images
    for i, lp in enumerate(plan.layers):
        x = run_conv_layer(lp, params["conv"][i], x)
    x = x.reshape(x.shape[0], -1)
    for j, fc in enumerate(params["fc"]):
        x = x @ fc["kernel"].astype(x.dtype) + fc["bias"].astype(x.dtype)
        if j < len(params["fc"]) - 1:
            x = jax.nn.relu(x)
    return x


def serve_forward(plan: ModelPlan, params, images: jax.Array) -> jax.Array:
    """Batch-invariant :func:`forward` for the serving executables.

    The conv stack is already batch-invariant (each image's kernels see
    only that image).  The FC head's batched GEMM is not: matmul kernels
    block differently per row count, so row i of an (N,K)@(K,F) product
    need not bit-match the (1,K)@(K,F) result.  Serving guarantees
    bucketed == unbatched per image bit-exactly, so the head runs per
    image via ``lax.map`` — identical accumulation order at every batch
    size, for ~1% of VGG-16's MACs (the convs dominate).
    """
    x = images
    for i, lp in enumerate(plan.layers):
        x = run_conv_layer(lp, params["conv"][i], x)
    x = x.reshape(x.shape[0], -1)

    def head(row):
        h = row
        for j, fc in enumerate(params["fc"]):
            h = h @ fc["kernel"].astype(h.dtype) + fc["bias"].astype(h.dtype)
            if j < len(params["fc"]) - 1:
                h = jax.nn.relu(h)
        return h

    return jax.lax.map(head, x)


def loss(plan: ModelPlan, params, batch):
    logits = forward(plan, params, batch["images"])
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, batch["labels"][:, None], axis=-1)[:, 0]
    ce = -ll.mean()
    acc = (logits.argmax(-1) == batch["labels"]).mean()
    return ce, {"ce": ce, "acc": acc}


def _int8_forward(
    plan: ModelPlan,
    qparams,
    images_u8: jax.Array,
    requant_shifts: Optional[Sequence[int]] = None,
    requant: Optional[Sequence[Tuple[jax.Array, jax.Array]]] = None,
) -> Tuple[jax.Array, List[jax.Array]]:
    """Shared int8 datapath: returns (final int32 psums, dynamic shifts).

    ``requant_shifts`` fuses calibrated power-of-two shifts into the
    kernel; ``requant`` fuses calibrated arbitrary-scale (mult, shift)
    pairs (per-tensor scalars or per-channel (F,) arrays) instead.  The
    shifts list collects the per-layer power-of-two shifts actually used
    on the dynamic (uncalibrated) path — traced scalars, so calibration
    must run this eagerly to concretize them.
    """
    assert requant_shifts is None or requant is None
    x = images_u8
    shifts: List[jax.Array] = []
    layers = plan.int8.layers
    n = len(layers)
    for i, lp in enumerate(layers):
        w = qparams["conv"][i]["kernel"]
        last = i == n - 1
        if requant is not None and not last:
            # Calibrated arbitrary scale: conv + ReLU + multiplier+shift
            # requant in one kernel pass (DESIGN.md §4).
            x = run_conv2d(lp, x, w, None, tuple(requant[i]))
        elif requant_shifts is not None and not last:
            # Calibrated shift: conv + ReLU + requant in one kernel pass.
            x = run_conv2d(lp, x, w, None, None, requant_shift=int(requant_shifts[i]))
        else:
            psum = run_conv2d(lp, x, w, None, None)
            if last:
                return psum, shifts
            # power-of-two requantize back to uint8 for the next layer
            amax = jnp.maximum(psum.max().astype(jnp.float32), 1.0)
            shift = jnp.maximum(jnp.ceil(jnp.log2(amax / 255.0)), 0)
            shift = shift.astype(jnp.int32)
            shifts.append(shift)
            x = jnp.clip(psum >> shift, 0, 255).astype(jnp.uint8)
        if lp.pool:
            x = max_pool2x2(x)
    return x, shifts


def forward_int8(
    plan: ModelPlan,
    qparams,
    images_u8: jax.Array,
    requant_shifts: Optional[Sequence[int]] = None,
    requant: Optional[Sequence[Tuple[jax.Array, jax.Array]]] = None,
) -> jax.Array:
    """uint8 NHWC images through the planned integer TrIM datapath.

    Each layer: uint8 x int8 -> int32 psums (exact), ReLU in int32 (fused
    into the kernel flush), then requantize to uint8 for the next layer —
    fully fused when calibrated shifts/pairs are supplied (see
    ``calibrate_requant_shifts`` / ``calibrate_requant``).  Returns the
    final int32 feature map (pre-classifier).
    """
    return _int8_forward(plan, qparams, images_u8, requant_shifts, requant)[0]


def calibrate_requant_shifts(plan: ModelPlan, qparams, sample_u8) -> List[int]:
    """Derive static per-layer power-of-two requant shifts from a sample
    batch (the engine's offline output-stage calibration).  Runs the
    dynamic datapath eagerly (not under jit) to concretize the shifts."""
    return [int(s) for s in _int8_forward(plan, qparams, sample_u8)[1]]


def calibrate_requant(
    plan: ModelPlan, qparams, sample_u8, per_channel: bool = True
) -> List[Tuple[jax.Array, jax.Array]]:
    """Arbitrary-scale calibration: per-layer (mult, shift) pairs.

    Maps each non-last layer's observed post-ReLU psum range [0, amax]
    onto [0, 255] with ``scale = 255 / amax`` encoded as ``m * 2**-s``
    (``kernels.requant.scale_to_mult_shift``; DESIGN.md §4).
    ``per_channel=True`` calibrates one scale per output channel.  Runs
    eagerly; the returned (F,) int32 pairs make
    ``forward_int8(..., requant=...)`` fully fused.
    """
    from repro.kernels.requant import scale_to_mult_shift

    x = sample_u8
    pairs: List[Tuple[jax.Array, jax.Array]] = []
    for i, lp in enumerate(plan.int8.layers[:-1]):
        w = qparams["conv"][i]["kernel"]
        psum = run_conv2d(lp, x, w, None, None)
        axes = (0, 1, 2) if per_channel else None
        amax = np.maximum(np.asarray(psum.max(axis=axes), np.float64), 1.0)
        m, s = scale_to_mult_shift(255.0 / amax)
        F = w.shape[-1]
        m = jnp.broadcast_to(jnp.asarray(m, jnp.int32), (F,))
        s = jnp.broadcast_to(jnp.asarray(s, jnp.int32), (F,))
        pairs.append((m, s))
        # Propagate through the exact fixed-point datapath the fused
        # forward will run, so downstream layers calibrate on what they
        # will actually see.
        x = requant_mult_shift(psum, m, s).astype(jnp.uint8)
        if lp.pool:
            x = max_pool2x2(x)
    return pairs


def forward_int5(
    plan: ModelPlan,
    qparams,
    images_u8: jax.Array,
    requant: Optional[Sequence[Tuple[jax.Array, jax.Array]]] = None,
) -> jax.Array:
    """uint8 images through the MSR-compressed int5 weight lane.

    ``qparams["conv"][i]`` carries ``{"kernel", "shift"}`` from
    ``nn.conv.quantize_cnn_int5``: the small decompressed operand ``w5``
    (int8, ``|w5| <= 31``) and the per-output-channel MSR exponent ``e``
    with ``w_hat == w5 << e`` (``core.trim.quant.msr_operand``).  The conv
    kernels multiply by ``w5`` unchanged — the exponent is applied
    losslessly after the fact:

    - calibrated path (``requant`` from :func:`calibrate_requant_int5`):
      the pairs already absorbed ``e`` via ``fold_shift_into_requant``, so
      each non-last layer is one fused conv+ReLU+requant pass, same as
      int8;
    - dynamic path (no ``requant``): the psums are explicitly left-shifted
      by ``e`` before the power-of-two requantize (batch-dependent, not
      servable — mirrors the int8 dynamic path);
    - the last layer always returns ``psums << e``: full-scale int32
      features comparable to the int8 lane's output.

    Bit-exactness contract: with calibrated pairs this equals running
    :func:`forward_int8` on the decompressed weights ``w5 << e`` exactly
    (DESIGN.md §9.3 has the proof sketch; tests/test_int5.py checks it).
    """
    x = images_u8
    layers = plan.int5.layers
    n = len(layers)
    for i, lp in enumerate(layers):
        p = qparams["conv"][i]
        w5 = p["kernel"]
        e = jnp.asarray(p["shift"], jnp.int32)
        last = i == n - 1
        if requant is not None and not last:
            x = run_conv2d(lp, x, w5, None, tuple(requant[i]))
        else:
            psum = jnp.left_shift(run_conv2d(lp, x, w5, None, None), e)
            if last:
                return psum
            amax = jnp.maximum(psum.max().astype(jnp.float32), 1.0)
            shift = jnp.maximum(jnp.ceil(jnp.log2(amax / 255.0)), 0)
            x = jnp.clip(psum >> shift.astype(jnp.int32), 0, 255).astype(jnp.uint8)
        if lp.pool:
            x = max_pool2x2(x)
    return x


def calibrate_requant_int5(
    plan: ModelPlan, qparams, sample_u8, per_channel: bool = True
) -> List[Tuple[jax.Array, jax.Array]]:
    """(mult, shift) calibration for the int5 lane, exponent pre-folded.

    Same procedure as :func:`calibrate_requant` — map each non-last
    layer's observed full-scale psum range onto [0, 255] — except the
    psums observed here are ``psum5 << e`` (the MSR exponent restored),
    and the resulting pairs are returned with ``e`` folded back in
    (``core.trim.quant.fold_shift_into_requant``), so the fused kernels
    can consume the raw ``w5`` psums directly:
    ``requant(psum5, m, s - e) == requant(psum5 << e, m, s)`` exactly.
    """
    from repro.core.trim.quant import fold_shift_into_requant
    from repro.kernels.requant import scale_to_mult_shift

    x = sample_u8
    pairs: List[Tuple[jax.Array, jax.Array]] = []
    for i, lp in enumerate(plan.int5.layers[:-1]):
        p = qparams["conv"][i]
        w5 = p["kernel"]
        e = np.asarray(p["shift"], np.int32)
        psum5 = run_conv2d(lp, x, w5, None, None)
        full = jnp.left_shift(psum5, jnp.asarray(e))
        axes = (0, 1, 2) if per_channel else None
        amax = np.maximum(np.asarray(full.max(axis=axes), np.float64), 1.0)
        m, s = scale_to_mult_shift(255.0 / amax)
        F = w5.shape[-1]
        m = np.broadcast_to(np.asarray(m, np.int32), (F,))
        s = np.broadcast_to(np.asarray(s, np.int32), (F,))
        mf, sf = fold_shift_into_requant(m, s, e)
        mf = jnp.asarray(mf, jnp.int32)
        sf = jnp.asarray(sf, jnp.int32)
        pairs.append((mf, sf))
        x = requant_mult_shift(psum5, mf, sf).astype(jnp.uint8)
        if lp.pool:
            x = max_pool2x2(x)
    return pairs


# ---------------------------------------------------------------------------
# Serving executables: ahead-of-time compiles per (plan, batch, datapath)
# ---------------------------------------------------------------------------


#: Compile ledger: (plan, batch, datapath) -> number of times an executable
#: was actually built.  ``lru_cache`` hits never touch it, so the serving
#: tests can assert each (ModelPlan, bucket) executable compiled exactly
#: once across a whole request stream.
EXECUTABLE_COMPILES: Dict[Tuple[ModelPlan, int, str], int] = {}

#: Fault-injection seam for the serving chaos plane (DESIGN.md §11):
#: when set, called as ``hook(plan, batch, datapath)`` at the top of
#: :func:`executable_for` *before* any work — raising there simulates a
#: rejected/failed AOT compile.  ``lru_cache`` never caches a call that
#: raised, so a bounded retry after a transient fault recompiles cleanly.
#: Installed/cleared by ``ServeEngine.warmup`` only; always ``None`` in
#: production.
COMPILE_FAULT_HOOK = None


def _donate_images_argnums() -> tuple:
    """Donation spec for the serving executables' image argument.

    The serving flush worker stages each bucket with ``jax.device_put``
    and never reuses the staged buffer, so donating it lets the runtime
    recycle that transfer target in place — the staging half of the
    transfer/compute overlap.  CPU jaxlib does not implement input
    donation (it warns and ignores), so donation is requested only on
    backends that honor it.
    """
    import jax

    return (1,) if jax.default_backend() in ("gpu", "tpu", "cuda", "rocm") else ()


@functools.lru_cache(maxsize=None)
def executable_for(plan: ModelPlan, batch: int, datapath: str = "float"):
    """AOT-compile ``plan``'s forward for one static batch size (cached).

    ``jax.jit(...).lower(shapes).compile()`` pins the executable to exactly
    ``(batch, H, W, C)`` inputs — a serving loop calling it structurally
    cannot retrace, which is the no-retrace-under-load guarantee
    (DESIGN.md §8).  Returns the compiled callable:

    - ``datapath="float"``: ``compiled(params, images_f32) -> logits``
      (param shapes via ``jax.eval_shape`` over ``init_cnn``; runs
      :func:`serve_forward` — the batch-invariant head — so per-image
      outputs are bit-identical across buckets);
    - ``datapath="int8"``: ``compiled(qparams, images_u8, requant) ->
      int32 feature map`` — ``requant`` is the calibrated per-layer list of
      per-channel (mult, shift) int32 pairs and is *required*: the
      uncalibrated dynamic-shift path requantizes off ``psum.max()`` over
      the whole batch, so its per-image outputs depend on batch
      composition and can never be served from padded buckets;
    - ``datapath="int5"``: same signature as int8, but ``qparams`` carries
      the MSR operand + per-channel exponent pair per layer
      (``quantize_cnn_int5``) and ``requant`` the exponent-folded pairs
      from ``calibrate_requant_int5`` (DESIGN.md §9.3).

    Cached per (plan, batch, datapath); equal plans share executables.
    """
    if COMPILE_FAULT_HOOK is not None:
        COMPILE_FAULT_HOOK(plan, batch, datapath)
    if datapath not in ("float", "int8", "int5"):
        raise ValueError(
            f"datapath {datapath!r} not in ('float', 'int8', 'int5')")
    cfg = plan.cfg
    H, W = cfg.input_hw
    C = plan.layers[0].c_in
    batch = int(batch)
    if batch < 1:
        raise ValueError(f"batch must be >= 1, got {batch}")
    if datapath == "float":
        from repro.nn.conv import init_cnn

        pshapes = jax.eval_shape(lambda k: init_cnn(k, cfg), jax.random.PRNGKey(0))
        img = jax.ShapeDtypeStruct((batch, H, W, C), jnp.float32)
        compiled = (
            jax.jit(lambda p, x: serve_forward(plan, p, x),
                    donate_argnums=_donate_images_argnums())
            .lower(pshapes, img)
            .compile()
        )
    else:
        # Integer param shapes come straight from the config (quantize_cnn
        # concretizes scales, so it is not eval_shape-able).  The int5 lane
        # adds the per-channel MSR exponent array next to each kernel.
        def _qshape(l):
            d = {"kernel": jax.ShapeDtypeStruct((l.K, l.K, l.M, l.N), jnp.int8)}
            if datapath == "int5":
                d["shift"] = jax.ShapeDtypeStruct((l.N,), jnp.int32)
            return d

        qshapes = {"conv": [_qshape(l) for l in cfg.layers]}
        rshapes = [
            (
                jax.ShapeDtypeStruct((l.N,), jnp.int32),
                jax.ShapeDtypeStruct((l.N,), jnp.int32),
            )
            for l in cfg.layers[:-1]
        ]
        img = jax.ShapeDtypeStruct((batch, H, W, C), jnp.uint8)
        if datapath == "int5":
            fwd = lambda qp, x, rq: forward_int5(plan, qp, x, requant=rq)  # noqa: E731
        else:
            fwd = lambda qp, x, rq: forward_int8(plan, qp, x, requant=rq)  # noqa: E731
        compiled = (
            jax.jit(fwd, donate_argnums=_donate_images_argnums())
            .lower(qshapes, img, rshapes)
            .compile()
        )
    key = (plan, batch, datapath)
    EXECUTABLE_COMPILES[key] = EXECUTABLE_COMPILES.get(key, 0) + 1
    return compiled
