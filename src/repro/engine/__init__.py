"""Execution planning for the TrIM kernels (DESIGN.md §3).

``ExecutionPolicy`` (how to run) + ``plan_conv_layer``/``plan_model``
(what was resolved) + ``execute`` (the one dispatch site that runs it).
"""

from repro.engine.policy import (
    RESOLVED_SUBSTRATES,
    SUBSTRATES,
    TUNING_MODES,
    ExecutionPolicy,
    policy_from_legacy,
)
from repro.engine.plan import (
    ConvLayerPlan,
    ModelPlan,
    plan_conv_layer,
    plan_model,
)
from repro.engine.execute import executable_for, run_conv2d, run_conv_layer
from repro.engine.autotune import (
    TuneResult,
    tune_conv_layer,
    tune_model,
)

__all__ = [
    "RESOLVED_SUBSTRATES",
    "SUBSTRATES",
    "TUNING_MODES",
    "ConvLayerPlan",
    "ExecutionPolicy",
    "ModelPlan",
    "TuneResult",
    "executable_for",
    "plan_conv_layer",
    "plan_model",
    "policy_from_legacy",
    "run_conv2d",
    "run_conv_layer",
    "tune_conv_layer",
    "tune_model",
]
