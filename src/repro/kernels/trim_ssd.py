"""TrIM-SSD — the Mamba2 chunked SSD scan as a Pallas TPU kernel.

The §Perf analysis of the mamba2-130m train cell shows the XLA-visible SSD
materializing its within-chunk quadratic tensors ((CS, CS) decay/score
blocks) in HBM ~tens of times per layer — the dominant roofline memory
term. This kernel is the TrIM treatment of that hot spot:

- the inter-chunk state h (P, S) lives in VMEM scratch and is carried
  across the chunk grid axis — the engine's psum-buffer temporal
  accumulation, verbatim;
- the (CS, CS) quadratic block (segsum decays, CB^T scores) exists ONLY in
  VMEM/registers inside one grid step — the single-fetch discipline: HBM
  traffic is x/dt/B/C in once, y out once;
- grid (B, H, NC) with NC innermost so the revolving-buffer pipeline keeps
  the per-(b, h) state resident while chunks stream.

Forward-only (serving / activation recompute; the XLA path remains the
differentiable reference). x (B, L, H, P); dt (B, L, H) post-softplus;
A (H,); Bm/Cm (B, L, G, S) with G == 1 supported in-kernel (groups > 1:
pre-repeat outside). Matches ``ref.ssd_ref`` == ``nn.mamba.ssd_chunked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_ref, *,
                CS: int, n_chunks: int):
    """One grid step: chunk ci of one (batch, head)."""
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (CS, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (CS, 1)
    a = a_ref[0]                                 # scalar, negative
    Bm = b_ref[0, 0].astype(jnp.float32)         # (CS, S)
    Cm = c_ref[0, 0].astype(jnp.float32)         # (CS, S)
    D = d_ref[0]                                 # scalar

    dA = dt[:, 0] * a                         # (CS,)
    cum = jnp.cumsum(dA)                         # inclusive within-chunk
    # within-chunk quadratic term — VMEM only
    seg = cum[:, None] - cum[None, :]            # (CS, CS)
    tri = jax.lax.broadcasted_iota(jnp.int32, (CS, CS), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (CS, CS), 1)
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    scores = CB * Lmat * dt[:, 0][None, :]       # (CS, CS)
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    # inter-chunk contribution from the carried state
    h = h_ref[...]                               # (P, S)
    y = y + jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    y = y + x * D
    y_ref[0, 0] = y.astype(y_ref.dtype)
    # state update: h' = exp(sum dA) h + sum_t exp(cum_last - cum_t) dt_t x_t B_t^T
    decay_to_end = jnp.exp(cum[CS - 1] - cum) * dt[:, 0]     # (CS,)
    dBx = jax.lax.dot_general(x * decay_to_end[:, None], Bm,
                              (((0,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (P, S)
    h_ref[...] = jnp.exp(cum[CS - 1]) * h + dBx


def trim_ssd_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                    Bm: jax.Array, Cm: jax.Array, D: jax.Array, *,
                    chunk: int = 256, interpret: bool = False) -> jax.Array:
    """x (B, L, H, P); dt (B, L, H); A (H,); Bm/Cm (B, L, H, S) (pre-repeated
    per head); D (H,) -> y (B, L, H, P)."""
    Bb, L, H, P = x.shape
    S = Bm.shape[-1]
    CS = min(chunk, L)
    NC = -(-L // CS)
    pad = NC * CS - L
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # layout: (B, H, NC*CS, feat) so the chunk axis tiles cleanly
    xt = x.transpose(0, 2, 1, 3)
    dtt = dt.transpose(0, 2, 1)[..., None]
    bt = Bm.transpose(0, 2, 1, 3)
    ct = Cm.transpose(0, 2, 1, 3)

    kernel = functools.partial(_ssd_kernel, CS=CS, n_chunks=NC)
    out = pl.pallas_call(
        kernel,
        grid=(Bb, H, NC),
        in_specs=[
            pl.BlockSpec((1, 1, CS, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, CS, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, CS, S), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, CS, S), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, CS, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb, H, NC * CS, P), x.dtype),
        scratch_shapes=[_VMEM((P, S), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), bt, ct, D.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3)[:, :L]


def ssd_ref(x, dt, A, Bm, Cm, D, chunk: int = 256):
    """Oracle: nn.mamba.ssd_chunked with per-head B/C (G == H)."""
    from repro.nn.mamba import ssd_chunked
    y, _ = ssd_chunked(x.astype(jnp.float32), dt.astype(jnp.float32), A,
                       Bm.astype(jnp.float32), Cm.astype(jnp.float32), D,
                       chunk=chunk)
    return y
