"""TrIM conv2d — the paper's dataflow, realized as a Pallas TPU kernel.

Mapping of the paper's triangular input movement onto the TPU memory
hierarchy (DESIGN.md §2):

- **Single-fetch inputs**: each haloed input tile travels HBM -> VMEM
  exactly once per (spatial, C_in) grid step and is then reused K*K times
  via *shifted VMEM slices* — the horizontal + diagonal movements of the
  paper collapse into VMEM addressing (the halo rows play the role of the
  shift-register buffers).
- **Weight-stationary**: the (K, K, Cb, Fb) weight block's index_map is
  constant along the spatial grid axis, so Pallas' revolving-buffer pipeline
  keeps it resident in VMEM while the spatial sweep runs (the paper's
  weights loaded once, held for the whole layer).
- **Psum accumulation**: a VMEM scratch accumulator integrates over the
  C_in grid axis (the engine's ceil(M/P_M) temporal steps + psum buffers);
  the output tile is written exactly once, on the last C_in step (the
  paper's single quantized writeback).
- **Stride-aware sweep**: for stride S the input row blocks are TH*S rows
  and the K*K shifted views decimate *at the slice* (step-S slices), so only
  the H_O x W_O strided outputs are ever computed.  The FPGA instead streams
  the full stride-1 extent and decimates downstream (§V, AlexNet CL1); that
  behaviour is preserved as the wrapper's ``emulate_hw=True`` mode for
  honest Table I/II comparisons (see ``ops.trim_conv2d``).
- **Fused epilogue**: bias add + ReLU + optional power-of-two int32->uint8
  requantization (the engine's output stage, ``core/trim/quant.py``) run in
  the final-C_in flush, so the int32 psums never round-trip through HBM
  between conv, bias, activation, and quant.
- **Engine broadcast**: the input tile's index_map does not depend on the
  F (C_out) grid axis — the same fetched inputs serve all P_N "cores".

The halo is expressed with plain blocked BlockSpecs by passing the input
twice (row-block ht and ht+1) and concatenating the first K-S rows of the
second block — this keeps the kernel compatible with both compiled TPU
lowering and interpret=True CPU validation.  When K <= S no halo is needed
and the input is passed once.

Supports float (bf16/f32 in, f32 accum) and the paper's integer mode
(uint8 x int8 -> int32 accum).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; fall back gracefully off-TPU.
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _acc_dtype(x_dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(x_dtype, jnp.integer) else jnp.float32


def _scratch(shape: Tuple[int, ...], dtype):
    """Psum accumulator scratch: VMEM on TPU, backend-neutral otherwise."""
    if _VMEM is not None:
        return _VMEM(shape, dtype)
    return pl.MemoryRef(shape, dtype, pl.ANY)


def _trim_conv2d_kernel(*refs, K: int, TH: int, W_O: int, n_cin: int,
                        stride: int, has_halo: bool, has_bias: bool,
                        relu: bool, requant_shift: Optional[int]):
    """One grid step: TH output rows x W_O cols x Fb filters, one Cin block."""
    it = iter(refs)
    x_lo_ref = next(it)
    x_hi_ref = next(it) if has_halo else None
    w_ref = next(it)
    b_ref = next(it) if has_bias else None
    o_ref = next(it)
    acc_ref = next(it)

    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Assemble the haloed tile: TH*S + max(K-S, 0) input rows, fetched once.
    x = x_lo_ref[0]                         # (TH*S, W_p, Cb)
    if has_halo:
        x = jnp.concatenate([x, x_hi_ref[0, :K - stride]], axis=0)
    w = w_ref[...]                          # (K, K, Cb, Fb) — stationary
    acc = acc_ref[...]
    cb = x.shape[-1]
    fb = w.shape[-1]
    acc_t = acc.dtype
    rows = (TH - 1) * stride + 1
    cols = (W_O - 1) * stride + 1
    # Triangular reuse: K*K shifted (step-S) views of the SAME resident tile.
    for kh in range(K):
        for kw in range(K):
            patch = jax.lax.slice(x, (kh, kw, 0),
                                  (kh + rows, kw + cols, cb),
                                  (stride, stride, 1))  # (TH, W_O, Cb)
            tap = jnp.dot(
                patch.reshape(TH * W_O, cb).astype(acc_t if acc_t == jnp.int32
                                                   else patch.dtype),
                w[kh, kw].astype(acc_t if acc_t == jnp.int32 else w.dtype),
                preferred_element_type=acc_t)
            acc = acc + tap.reshape(TH, W_O, fb)
    acc_ref[...] = acc

    @pl.when(ci == n_cin - 1)
    def _flush():
        r = acc_ref[...]
        # Fused epilogue: bias -> ReLU -> power-of-two requant, all while the
        # int32/f32 psums are still accumulator-resident.
        if has_bias:
            r = r + b_ref[0]
        if relu:
            r = jnp.maximum(r, 0)
        if requant_shift is not None:
            r = jnp.clip(jnp.right_shift(r, requant_shift), 0, 255)
        o_ref[0] = r.astype(o_ref.dtype)


def trim_conv2d_pallas(x: jax.Array, w: jax.Array, *,
                       stride: int = 1,
                       tile_h: int = 8, block_c: int = 128,
                       block_f: int = 128, padding: Optional[int] = None,
                       bias: Optional[jax.Array] = None,
                       relu: bool = False,
                       requant_shift: Optional[int] = None,
                       out_dtype=None, interpret: bool = False) -> jax.Array:
    """TrIM conv. x (N,H,W,C), w (K,K,C,F) -> (N,H_O,W_O,F).

    ``stride`` is static; only the strided H_O x W_O outputs are computed
    (see DESIGN.md §2).  ``bias`` (F,), ``relu`` and ``requant_shift`` fuse
    the layer epilogue into the final C_in flush; ``requant_shift`` (int
    path only) applies the engine's power-of-two requantization and returns
    uint8.  The wrapper pads H/C/F up to tile multiples (zero padding is
    free w.r.t. the convolution result) and slices the result back.
    """
    N, H, W, C = x.shape
    K, K2, Cw, F = w.shape
    assert K == K2 and Cw == C, (x.shape, w.shape)
    S = int(stride)
    assert S >= 1
    p = K // 2 if padding is None else padding
    acc_dtype = _acc_dtype(x.dtype)
    if requant_shift is not None:
        assert acc_dtype == jnp.int32, "requant_shift needs the integer path"
        out_dtype = jnp.uint8
    if out_dtype is None:
        out_dtype = acc_dtype if acc_dtype == jnp.int32 else x.dtype

    H_p, W_p = H + 2 * p, W + 2 * p
    assert H_p >= K and W_p >= K, (x.shape, w.shape, p)
    H_O, W_O = (H_p - K) // S + 1, (W_p - K) // S + 1

    TH = min(tile_h, H_O)
    if K > S:
        # The halo comes from a single following row block, so the block
        # must be tall enough to contain it: K - S <= TH*S.  (Covers large
        # kernels at small strides — e.g. K=11 stride-1 — and tiny maps.)
        TH = max(TH, -(-(K - S) // S))
    n_ht = -(-H_O // TH)                    # ceil
    Cb = min(block_c, C)
    n_ci = -(-C // Cb)
    Fb = min(block_f, F)
    n_f = -(-F // Fb)

    RB = TH * S                             # input rows per spatial block
    halo = K - S
    has_halo = halo > 0
    # Row padding: n_ht blocks of RB input rows cover the strided sweep; one
    # extra RB-row block (halo case) makes the ht+1 halo index always valid.
    n_rb = n_ht + (1 if has_halo else 0)
    rows_needed = -(-max(n_rb * RB, H_p) // RB) * RB
    x_pad = jnp.pad(x, ((0, 0), (p, rows_needed - H - p), (p, p),
                        (0, n_ci * Cb - C)))
    w_pad = jnp.pad(w, ((0, 0), (0, 0), (0, n_ci * Cb - C),
                        (0, n_f * Fb - F)))

    grid = (N * n_ht, n_f, n_ci)

    def x_lo_idx(bt, f, c):
        return (bt // n_ht, bt % n_ht, 0, c)

    def x_hi_idx(bt, f, c):
        return (bt // n_ht, bt % n_ht + 1, 0, c)

    inputs = [x_pad]
    in_specs = [pl.BlockSpec((1, RB, W_p, Cb), x_lo_idx)]
    if has_halo:
        inputs.append(x_pad)
        in_specs.append(pl.BlockSpec((1, RB, W_p, Cb), x_hi_idx))
    inputs.append(w_pad)
    in_specs.append(pl.BlockSpec((K, K, Cb, Fb), lambda bt, f, c: (0, 0, c, f)))
    if bias is not None:
        assert bias.shape == (F,), bias.shape
        b_pad = jnp.pad(bias.astype(acc_dtype),
                        (0, n_f * Fb - F)).reshape(1, n_f * Fb)
        inputs.append(b_pad)
        in_specs.append(pl.BlockSpec((1, Fb), lambda bt, f, c: (0, f)))

    kernel = functools.partial(_trim_conv2d_kernel, K=K, TH=TH, W_O=W_O,
                               n_cin=n_ci, stride=S, has_halo=has_halo,
                               has_bias=bias is not None, relu=relu,
                               requant_shift=requant_shift)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, TH, W_O, Fb),
                               lambda bt, f, c: (bt // n_ht, bt % n_ht, 0, f)),
        out_shape=jax.ShapeDtypeStruct((N, n_ht * TH, W_O, n_f * Fb),
                                       out_dtype),
        scratch_shapes=[_scratch((TH, W_O, Fb), acc_dtype)],
        interpret=interpret,
    )(*inputs)
    return out[:, :H_O, :, :F]
