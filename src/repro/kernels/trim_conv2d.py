"""TrIM conv2d — the paper's dataflow, realized as a Pallas TPU kernel.

Mapping of the paper's triangular input movement onto the TPU memory
hierarchy (DESIGN.md §2):

- **Single-fetch inputs**: each haloed input tile (TH+K-1 rows) travels
  HBM -> VMEM exactly once per (spatial, C_in) grid step and is then reused
  K*K times via *shifted VMEM slices* — the horizontal + diagonal movements
  of the paper collapse into VMEM addressing (the halo rows play the role of
  the shift-register buffers).
- **Weight-stationary**: the (K, K, Cb, Fb) weight block's index_map is
  constant along the spatial grid axis, so Pallas' revolving-buffer pipeline
  keeps it resident in VMEM while the spatial sweep runs (the paper's
  weights loaded once, held for the whole layer).
- **Psum accumulation**: a VMEM scratch accumulator integrates over the
  C_in grid axis (the engine's ceil(M/P_M) temporal steps + psum buffers);
  the output tile is written exactly once, on the last C_in step (the
  paper's single quantized writeback).
- **Engine broadcast**: the input tile's index_map does not depend on the
  F (C_out) grid axis — the same fetched inputs serve all P_N "cores".

The halo is expressed with plain blocked BlockSpecs by passing the input
twice (row-block ht and ht+1) and concatenating the first K-1 rows of the
second block — this keeps the kernel compatible with both compiled TPU
lowering and interpret=True CPU validation.

Supports float (bf16/f32 in, f32 accum) and the paper's integer mode
(uint8 x int8 -> int32 accum). Stride 1; striding/decimation is done by the
wrapper (``ops.trim_conv2d``), matching the hardware (§V: strided layers
stream the stride-1 sweep and decimate downstream).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU-specific memory spaces; fall back gracefully off-TPU.
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None


def _acc_dtype(x_dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(x_dtype, jnp.integer) else jnp.float32


def _trim_conv2d_kernel(x_lo_ref, x_hi_ref, w_ref, o_ref, acc_ref, *,
                        K: int, TH: int, W_O: int, n_cin: int):
    """One grid step: TH output rows x W_O cols x Fb filters, one Cin block."""
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Assemble the haloed tile: TH + K - 1 input rows, fetched once.
    x_lo = x_lo_ref[0]                      # (TH, W_p, Cb)
    if K > 1:
        x_hi = x_hi_ref[0, :K - 1]          # halo rows from the next block
        x = jnp.concatenate([x_lo, x_hi], axis=0)
    else:
        x = x_lo
    w = w_ref[...]                          # (K, K, Cb, Fb) — stationary
    acc = acc_ref[...]
    cb = x.shape[-1]
    fb = w.shape[-1]
    acc_t = acc.dtype
    # Triangular reuse: K*K shifted views of the SAME VMEM-resident tile.
    for kh in range(K):
        for kw in range(K):
            patch = x[kh:kh + TH, kw:kw + W_O, :]          # (TH, W_O, Cb)
            tap = jnp.dot(
                patch.reshape(TH * W_O, cb).astype(acc_t if acc_t == jnp.int32
                                                   else patch.dtype),
                w[kh, kw].astype(acc_t if acc_t == jnp.int32 else w.dtype),
                preferred_element_type=acc_t)
            acc = acc + tap.reshape(TH, W_O, fb)
    acc_ref[...] = acc

    @pl.when(ci == n_cin - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def trim_conv2d_pallas(x: jax.Array, w: jax.Array, *,
                       tile_h: int = 8, block_c: int = 128,
                       block_f: int = 128, padding: Optional[int] = None,
                       out_dtype=None, interpret: bool = False) -> jax.Array:
    """Stride-1 TrIM conv. x (N,H,W,C), w (K,K,C,F) -> (N,H_O,W_O,F).

    The wrapper pads H/C/F up to tile multiples (zero padding is free w.r.t.
    the convolution result) and slices the result back.
    """
    N, H, W, C = x.shape
    K, K2, Cw, F = w.shape
    assert K == K2 and Cw == C, (x.shape, w.shape)
    p = K // 2 if padding is None else padding
    acc_dtype = _acc_dtype(x.dtype)
    if out_dtype is None:
        out_dtype = acc_dtype if acc_dtype == jnp.int32 else x.dtype

    H_p, W_p = H + 2 * p, W + 2 * p
    H_O, W_O = H_p - K + 1, W_p - K + 1

    TH = min(tile_h, H_O)
    n_ht = -(-H_O // TH)                    # ceil
    Cb = min(block_c, C)
    n_ci = -(-C // Cb)
    Fb = min(block_f, F)
    n_f = -(-F // Fb)

    # Row padding: n_ht blocks of TH output rows need n_ht*TH + K - 1 input
    # rows; one extra TH-row block makes the ht+1 halo index always valid.
    rows_needed = (n_ht + 1) * TH
    x_pad = jnp.pad(x, ((0, 0), (p, rows_needed - H - p), (p, p),
                        (0, n_ci * Cb - C)))
    w_pad = jnp.pad(w, ((0, 0), (0, 0), (0, n_ci * Cb - C),
                        (0, n_f * Fb - F)))

    grid = (N * n_ht, n_f, n_ci)

    def x_lo_idx(bt, f, c):
        return (bt // n_ht, bt % n_ht, 0, c)

    def x_hi_idx(bt, f, c):
        return (bt // n_ht, bt % n_ht + 1, 0, c)

    kernel = functools.partial(_trim_conv2d_kernel, K=K, TH=TH, W_O=W_O,
                               n_cin=n_ci)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TH, W_p, Cb), x_lo_idx),
            pl.BlockSpec((1, TH, W_p, Cb), x_hi_idx),
            pl.BlockSpec((K, K, Cb, Fb), lambda bt, f, c: (0, 0, c, f)),
        ],
        out_specs=pl.BlockSpec((1, TH, W_O, Fb),
                               lambda bt, f, c: (bt // n_ht, bt % n_ht, 0, f)),
        out_shape=jax.ShapeDtypeStruct((N, n_ht * TH, W_O, n_f * Fb),
                                       out_dtype),
        scratch_shapes=[
            _VMEM((TH, W_O, Fb), acc_dtype) if _VMEM is not None else
            pltpu.VMEM((TH, W_O, Fb), acc_dtype)  # pragma: no cover
        ],
        interpret=interpret,
    )(x_pad, x_pad, w_pad)
    return out[:, :H_O, :, :F]
