"""TrIM conv2d — the paper's dataflow, realized as a Pallas TPU kernel.

Mapping of the paper's triangular input movement onto the TPU memory
hierarchy (DESIGN.md §2, §4):

- **Single-fetch inputs**: each haloed input tile travels HBM -> VMEM
  exactly once per (spatial, C_in) grid step and is then reused K*K times
  via *shifted VMEM slices* — the horizontal + diagonal movements of the
  paper collapse into VMEM addressing (the halo rows/columns play the role
  of the shift-register buffers).
- **Weight-stationary**: the (K, K, Cb, Fb) weight block's index_map is
  constant along the spatial grid axes, so Pallas' revolving-buffer pipeline
  keeps it resident in VMEM while the spatial sweep runs (the paper's
  weights loaded once, held for the whole layer).
- **Psum accumulation**: a VMEM scratch accumulator integrates over the
  C_in grid axis (the engine's ceil(M/P_M) temporal steps + psum buffers);
  the output tile is written exactly once, on the last C_in step (the
  paper's single quantized writeback).
- **Stride-aware sweep**: for stride S the input row blocks are TH*S rows
  and the K*K shifted views decimate *at the slice* (step-S slices), so only
  the H_O x W_O strided outputs are ever computed.  The FPGA instead streams
  the full stride-1 extent and decimates downstream (§V, AlexNet CL1); that
  behaviour is preserved for honest Table I/II comparisons — request it
  with ``ExecutionPolicy(emulate_hw=True)`` and plan through
  ``repro.engine`` (``plan_conv_layer`` / ``plan_model``; DESIGN.md §3).
- **Width tiling** (DESIGN.md §4): W_O is split into ``n_wt`` tiles of TW
  output columns; each input block is a ``(TH*S, (TW-1)*S + K)`` window
  with K-S halo columns, mirroring the halo-row logic, so maps wider than
  the VGG/AlexNet shapes no longer blow VMEM.  ``tile_w=None`` auto-picks
  TW from a VMEM budget (``pick_tile_w``); ``n_wt == 1`` degenerates to
  the original single-block layout (same grid, same schedule).
- **Fused epilogue**: bias add + ReLU + requantization (power-of-two shift
  or arbitrary-scale multiplier+shift, ``kernels/requant.py``) run in the
  final-C_in flush, so the int32 psums never round-trip through HBM
  between conv, bias, activation, and quant.
- **Engine broadcast**: the input tile's index_map does not depend on the
  F (C_out) grid axis — the same fetched inputs serve all P_N "cores".

Halos are expressed with plain blocked BlockSpecs by passing the input
multiple times at shifted block indices — row-block ht+1 for the K-S halo
rows, column-block wt+1 for the K-S halo columns (up to four passes when
width-tiled) — and concatenating inside the kernel.  This keeps the kernel
compatible with both compiled TPU lowering and interpret=True CPU
validation.  When K <= S no halo is needed and the input is passed once.

Supports float (bf16/f32 in, f32 accum) and the paper's integer mode
(uint8 x int8 -> int32 accum).

The tiling geometry (``conv2d_geom``), padding (``pad_conv2d_x`` /
``pad_conv2d_w``), halo BlockSpec construction (``halo_x_specs``) and
in-kernel halo assembly (``assemble_halo_tile``) are shared with the
backward pass (``trim_conv2d_vjp.py``, DESIGN.md §6): the weight-grad
kernel sweeps the *same* haloed input blocks and the input-grad kernel is
this forward kernel applied to the dilated cotangent.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.requant import requant_mult_shift

try:  # TPU-specific memory spaces; fall back gracefully off-TPU.
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    pltpu = None
    _VMEM = None

#: Default per-core VMEM budget for the width-tile auto-pick: conservative
#: vs the ~16 MiB of a TPU core so weights + revolving buffers still fit.
VMEM_BUDGET_BYTES = 12 * 2 ** 20


def _acc_dtype(x_dtype) -> jnp.dtype:
    return jnp.int32 if jnp.issubdtype(x_dtype, jnp.integer) else jnp.float32


def _scratch(shape: Tuple[int, ...], dtype):
    """Psum accumulator scratch: VMEM on TPU, backend-neutral otherwise."""
    if _VMEM is not None:
        return _VMEM(shape, dtype)
    return pl.MemoryRef(shape, dtype, pl.ANY)


def _vmem_bytes(*, RB: int, cols: int, Cb: int, Fb: int, K: int, TH: int,
                TW: int, passes: int, in_sz: int, w_sz: int,
                out_sz: int) -> int:
    """Estimated VMEM for one grid step: double-buffered in/out blocks +
    the weight block + the psum scratch."""
    xb = passes * RB * cols * Cb * in_sz
    wb = K * K * Cb * Fb * w_sz
    ob = TH * TW * Fb * out_sz
    ab = TH * TW * Fb * 4
    return 2 * (xb + wb + ob) + ab


def pick_tile_w(W_O: int, *, K: int, stride: int, RB: int, TH: int,
                W_p: int, Cb: int, Fb: int, in_sz: int = 4, w_sz: int = 4,
                out_sz: int = 4,
                vmem_budget: int = VMEM_BUDGET_BYTES) -> int:
    """Auto-pick the output-column tile TW from a VMEM budget.

    Returns ``W_O`` (single block — the degenerate layout) whenever the
    full-width block fits the budget, so the VGG/AlexNet shapes keep their
    original schedule; otherwise halves TW (rounded up to a multiple of 8
    sublanes) until the 4-pass haloed tile fits.
    """
    halo = max(K - stride, 0)
    full = _vmem_bytes(RB=RB, cols=W_p, Cb=Cb, Fb=Fb, K=K, TH=TH, TW=W_O,
                       passes=2 if halo else 1, in_sz=in_sz, w_sz=w_sz,
                       out_sz=out_sz)
    if full <= vmem_budget:
        return W_O
    TW = W_O
    while TW > 8:
        TW = -(-TW // 2)
        TW = -(-TW // 8) * 8
        used = _vmem_bytes(RB=RB, cols=TW * stride, Cb=Cb, Fb=Fb, K=K,
                           TH=TH, TW=TW, passes=4 if halo else 1,
                           in_sz=in_sz, w_sz=w_sz, out_sz=out_sz)
        if used <= vmem_budget:
            break
    if halo:
        TW = max(TW, -(-halo // stride))
    return min(TW, W_O)


@dataclasses.dataclass(frozen=True)
class Conv2DGeom:
    """Tiling geometry shared by the forward and weight-grad kernels.

    Both passes sweep identical haloed input blocks with identical
    (TH, TW) output tiles (DESIGN.md §2, §4, §6); computing the geometry
    once keeps their block maps bit-identical.
    """
    S: int                  # stride
    p: int                  # symmetric spatial padding
    K: int
    H_O: int
    W_O: int
    halo: int               # K - S (halo rows/cols when > 0)
    has_halo: bool
    TH: int                 # output rows per tile
    n_ht: int
    TW: int                 # output cols per tile
    n_wt: int
    tiled: bool             # n_wt > 1 (width-tiled grid)
    RB: int                 # input rows per spatial block (TH * S)
    CB: int                 # input cols per spatial block
    Cb: int
    n_ci: int
    Fb: int
    n_f: int
    rows_needed: int        # padded input rows (block multiples + halo)
    cols_needed: int


def conv2d_geom(x_shape, w_shape, *, stride: int, padding: Optional[int],
                tile_h: int, tile_w: Optional[int], block_c: int,
                block_f: int, in_sz: int = 4, w_sz: int = 4,
                out_sz: int = 4,
                vmem_budget: int = VMEM_BUDGET_BYTES) -> Conv2DGeom:
    """Derive the blocked-grid geometry for x (N,H,W,C), w (K,K,C,F)."""
    N, H, W, C = x_shape
    K, K2, Cw, F = w_shape
    assert K == K2 and Cw == C, (x_shape, w_shape)
    S = int(stride)
    assert S >= 1
    p = K // 2 if padding is None else padding
    H_p, W_p = H + 2 * p, W + 2 * p
    assert H_p >= K and W_p >= K, (x_shape, w_shape, p)
    H_O, W_O = (H_p - K) // S + 1, (W_p - K) // S + 1

    halo = K - S
    has_halo = halo > 0
    TH = min(tile_h, H_O)
    if has_halo:
        # The halo comes from a single following row block, so the block
        # must be tall enough to contain it: K - S <= TH*S.  (Covers large
        # kernels at small strides — e.g. K=11 stride-1 — and tiny maps.)
        TH = max(TH, -(-halo // S))
    n_ht = -(-H_O // TH)                    # ceil
    Cb = min(block_c, C)
    n_ci = -(-C // Cb)
    Fb = min(block_f, F)
    n_f = -(-F // Fb)

    RB = TH * S                             # input rows per spatial block

    if tile_w is not None:
        TW = min(int(tile_w), W_O)
    else:
        TW = pick_tile_w(W_O, K=K, stride=S, RB=RB, TH=TH, W_p=W_p, Cb=Cb,
                         Fb=Fb, in_sz=in_sz, w_sz=w_sz, out_sz=out_sz,
                         vmem_budget=vmem_budget)
    if has_halo:
        # Same single-following-block constraint along the width.
        TW = max(TW, -(-halo // S))
    n_wt = -(-W_O // TW)                    # ceil
    tiled = n_wt > 1
    if not tiled:
        TW = W_O

    # Row padding: n_ht blocks of RB input rows cover the strided sweep; one
    # extra RB-row block (halo case) makes the ht+1 halo index always valid.
    n_rb = n_ht + (1 if has_halo else 0)
    rows_needed = -(-max(n_rb * RB, H_p) // RB) * RB
    if tiled:
        # Column padding mirrors the rows: n_wt blocks of CB input columns
        # plus one extra block backing the wt+1 halo columns.
        CB = TW * S
        n_cb = n_wt + (1 if has_halo else 0)
        cols_needed = -(-max(n_cb * CB, W_p) // CB) * CB
    else:
        CB = W_p
        cols_needed = W_p
    return Conv2DGeom(S=S, p=p, K=K, H_O=H_O, W_O=W_O, halo=halo,
                      has_halo=has_halo, TH=TH, n_ht=n_ht, TW=TW, n_wt=n_wt,
                      tiled=tiled, RB=RB, CB=CB, Cb=Cb, n_ci=n_ci, Fb=Fb,
                      n_f=n_f, rows_needed=rows_needed,
                      cols_needed=cols_needed)


def pad_conv2d_x(x: jax.Array, g: Conv2DGeom) -> jax.Array:
    """Zero-pad x (N,H,W,C) to the blocked grid extent: the p-border plus
    block-multiple rows/cols/channels (free w.r.t. the conv result)."""
    N, H, W, C = x.shape
    return jnp.pad(x, ((0, 0), (g.p, g.rows_needed - H - g.p),
                       (g.p, g.cols_needed - W - g.p),
                       (0, g.n_ci * g.Cb - C)))


def pad_conv2d_w(w: jax.Array, g: Conv2DGeom) -> jax.Array:
    """Zero-pad w (K,K,C,F) channels/filters to block multiples."""
    return jnp.pad(w, ((0, 0), (0, 0), (0, g.n_ci * g.Cb - w.shape[2]),
                       (0, g.n_f * g.Fb - w.shape[3])))


def halo_x_specs(x_pad: jax.Array, g: Conv2DGeom,
                 x_idx: Callable[[int, int], Callable]):
    """The up-to-four shifted passes of the padded input (the ll/lh/hl/hh
    table of DESIGN.md §4).  ``x_idx(dh, dw)`` must return the index_map
    for a pass shifted ``dh`` row blocks and ``dw`` column blocks; the
    grid signature is the caller's (forward and weight-grad kernels order
    their grids differently)."""
    xspec = (1, g.RB, g.CB, g.Cb)
    inputs = [x_pad]
    specs = [pl.BlockSpec(xspec, x_idx(0, 0))]
    if g.has_halo and g.tiled:              # lh: halo columns, top rows
        inputs.append(x_pad)
        specs.append(pl.BlockSpec(xspec, x_idx(0, 1)))
    if g.has_halo:                          # hl: halo rows
        inputs.append(x_pad)
        specs.append(pl.BlockSpec(xspec, x_idx(1, 0)))
    if g.has_halo and g.tiled:              # hh: halo corner
        inputs.append(x_pad)
        specs.append(pl.BlockSpec(xspec, x_idx(1, 1)))
    return inputs, specs


def assemble_halo_tile(x_ll_ref, x_lh_ref, x_hl_ref, x_hh_ref,
                       halo: int) -> jax.Array:
    """Concatenate the ll/lh/hl/hh passes into the haloed VMEM tile —
    (TH*S + max(K-S,0), TW*S + max(K-S,0)) input pixels, each fetched
    exactly once per grid step (shared by forward and weight-grad)."""
    x = x_ll_ref[0]                         # (TH*S, cols, Cb)
    if x_lh_ref is not None:
        x = jnp.concatenate([x, x_lh_ref[0][:, :halo]], axis=1)
    if x_hl_ref is not None:
        bot = x_hl_ref[0][:halo]
        if x_hh_ref is not None:
            bot = jnp.concatenate([bot, x_hh_ref[0][:halo, :halo]], axis=1)
        x = jnp.concatenate([x, bot], axis=0)
    return x


def _trim_conv2d_kernel(*refs, K: int, TH: int, TW: int, n_cin: int,
                        stride: int, ci_axis: int, has_halo_h: bool,
                        has_halo_w: bool, has_bias: bool, relu: bool,
                        requant_shift: Optional[int], has_requant: bool):
    """One grid step: TH output rows x TW cols x Fb filters, one Cin block."""
    it = iter(refs)
    x_ll_ref = next(it)
    x_lh_ref = next(it) if has_halo_w else None
    x_hl_ref = next(it) if has_halo_h else None
    x_hh_ref = next(it) if (has_halo_h and has_halo_w) else None
    w_ref = next(it)
    b_ref = next(it) if has_bias else None
    m_ref = next(it) if has_requant else None
    s_ref = next(it) if has_requant else None
    o_ref = next(it)
    acc_ref = next(it)

    ci = pl.program_id(ci_axis)

    @pl.when(ci == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Assemble the haloed tile — (TH*S + max(K-S,0), TW*S + max(K-S,0))
    # input pixels, each fetched exactly once per (spatial, Cin) step.
    halo = K - stride
    x = assemble_halo_tile(x_ll_ref, x_lh_ref, x_hl_ref, x_hh_ref, halo)
    w = w_ref[...]                          # (K, K, Cb, Fb) — stationary
    acc = acc_ref[...]
    cb = x.shape[-1]
    fb = w.shape[-1]
    acc_t = acc.dtype
    rows = (TH - 1) * stride + 1
    cols = (TW - 1) * stride + 1
    # Triangular reuse: K*K shifted (step-S) views of the SAME resident tile.
    for kh in range(K):
        for kw in range(K):
            patch = jax.lax.slice(x, (kh, kw, 0),
                                  (kh + rows, kw + cols, cb),
                                  (stride, stride, 1))  # (TH, TW, Cb)
            tap = jnp.dot(
                patch.reshape(TH * TW, cb).astype(acc_t if acc_t == jnp.int32
                                                  else patch.dtype),
                w[kh, kw].astype(acc_t if acc_t == jnp.int32 else w.dtype),
                preferred_element_type=acc_t)
            acc = acc + tap.reshape(TH, TW, fb)
    acc_ref[...] = acc

    @pl.when(ci == n_cin - 1)
    def _flush():
        r = acc_ref[...]
        # Fused epilogue: bias -> ReLU -> requant, all while the int32/f32
        # psums are still accumulator-resident.
        if has_bias:
            r = r + b_ref[0]
        if relu:
            r = jnp.maximum(r, 0)
        if requant_shift is not None:
            r = jnp.clip(jnp.right_shift(r, requant_shift), 0, 255)
        if has_requant:
            r = requant_mult_shift(r, m_ref[0], s_ref[0])
        o_ref[0] = r.astype(o_ref.dtype)


def trim_conv2d_pallas(x: jax.Array, w: jax.Array, *,
                       stride: int = 1,
                       tile_h: int = 8, tile_w: Optional[int] = None,
                       block_c: int = 128,
                       block_f: int = 128, padding: Optional[int] = None,
                       bias: Optional[jax.Array] = None,
                       relu: bool = False,
                       requant_shift: Optional[int] = None,
                       requant: Optional[Tuple[jax.Array, jax.Array]] = None,
                       vmem_budget: int = VMEM_BUDGET_BYTES,
                       out_dtype=None, interpret: bool = False) -> jax.Array:
    """TrIM conv. x (N,H,W,C), w (K,K,C,F) -> (N,H_O,W_O,F).

    ``stride`` is static; only the strided H_O x W_O outputs are computed
    (see DESIGN.md §2).  ``tile_w`` tiles the output width (None: auto-pick
    from ``vmem_budget``; the single-block layout is kept whenever one tile
    covers W_O).  ``bias`` (F,), ``relu``, ``requant_shift`` and ``requant``
    fuse the layer epilogue into the final C_in flush; ``requant_shift``
    (int path only) applies the engine's power-of-two requantization,
    ``requant=(mult, shift)`` (scalars or per-channel (F,) int32 arrays,
    see ``kernels/requant.py``) the arbitrary-scale fixed-point
    requantization — both return uint8.  The wrapper pads H/W/C/F up to
    tile multiples (zero padding is free w.r.t. the convolution result)
    and slices the result back.
    """
    N, H, W, C = x.shape
    K, _, _, F = w.shape
    acc_dtype = _acc_dtype(x.dtype)
    assert requant_shift is None or requant is None, \
        "requant_shift (power-of-two) and requant (mult+shift) are exclusive"
    if requant_shift is not None or requant is not None:
        assert acc_dtype == jnp.int32, "requantization needs the integer path"
        out_dtype = jnp.uint8
    if out_dtype is None:
        out_dtype = acc_dtype if acc_dtype == jnp.int32 else x.dtype

    g = conv2d_geom(x.shape, w.shape, stride=stride, padding=padding,
                    tile_h=tile_h, tile_w=tile_w, block_c=block_c,
                    block_f=block_f, in_sz=x.dtype.itemsize,
                    w_sz=w.dtype.itemsize,
                    out_sz=jnp.dtype(out_dtype).itemsize,
                    vmem_budget=vmem_budget)
    TH, TW, n_ht, n_wt = g.TH, g.TW, g.n_ht, g.n_wt
    Cb, n_ci, Fb, n_f = g.Cb, g.n_ci, g.Fb, g.n_f

    x_pad = pad_conv2d_x(x, g)
    w_pad = pad_conv2d_w(w, g)

    if g.tiled:
        grid = (N * n_ht, n_wt, n_f, n_ci)
        ci_axis = 3

        def x_idx(dh, dw):
            return lambda bt, wt, f, c: (bt // n_ht, bt % n_ht + dh,
                                         wt + dw, c)

        def chan_idx():
            return lambda bt, wt, f, c: (0, f)

        def w_idx(bt, wt, f, c):
            return (0, 0, c, f)

        def o_idx(bt, wt, f, c):
            return (bt // n_ht, bt % n_ht, wt, f)
    else:
        grid = (N * n_ht, n_f, n_ci)
        ci_axis = 2

        def x_idx(dh, dw):
            return lambda bt, f, c: (bt // n_ht, bt % n_ht + dh, 0, c)

        def chan_idx():
            return lambda bt, f, c: (0, f)

        def w_idx(bt, f, c):
            return (0, 0, c, f)

        def o_idx(bt, f, c):
            return (bt // n_ht, bt % n_ht, 0, f)

    inputs, in_specs = halo_x_specs(x_pad, g, x_idx)
    inputs.append(w_pad)
    in_specs.append(pl.BlockSpec((K, K, Cb, Fb), w_idx))
    if bias is not None:
        assert bias.shape == (F,), bias.shape
        b_pad = jnp.pad(bias.astype(acc_dtype),
                        (0, n_f * Fb - F)).reshape(1, n_f * Fb)
        inputs.append(b_pad)
        in_specs.append(pl.BlockSpec((1, Fb), chan_idx()))
    if requant is not None:
        mult, shift = requant
        # Scalars broadcast; padded channels carry (m=1, s=15) and their
        # zero psums requantize to 0.
        m_pad = jnp.pad(jnp.broadcast_to(
            jnp.asarray(mult, jnp.int32), (F,)), (0, n_f * Fb - F),
            constant_values=1).reshape(1, n_f * Fb)
        s_pad = jnp.pad(jnp.broadcast_to(
            jnp.asarray(shift, jnp.int32), (F,)), (0, n_f * Fb - F),
            constant_values=15).reshape(1, n_f * Fb)
        inputs.append(m_pad)
        in_specs.append(pl.BlockSpec((1, Fb), chan_idx()))
        inputs.append(s_pad)
        in_specs.append(pl.BlockSpec((1, Fb), chan_idx()))

    kernel = functools.partial(_trim_conv2d_kernel, K=K, TH=TH, TW=TW,
                               n_cin=n_ci, stride=g.S, ci_axis=ci_axis,
                               has_halo_h=g.has_halo,
                               has_halo_w=g.has_halo and g.tiled,
                               has_bias=bias is not None, relu=relu,
                               requant_shift=requant_shift,
                               has_requant=requant is not None)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, TH, TW, Fb), o_idx),
        out_shape=jax.ShapeDtypeStruct((N, n_ht * TH, n_wt * TW, n_f * Fb),
                                       out_dtype),
        scratch_shapes=[_scratch((TH, TW, Fb), acc_dtype)],
        interpret=interpret,
    )(*inputs)
    return out[:, :g.H_O, :g.W_O, :F]
