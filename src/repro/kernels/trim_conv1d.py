"""TrIM conv1d — the paper's dataflow specialized to 1-D causal depthwise
convolution (the Mamba/Mamba2 short-conv hot spot).

The triangular movement degenerates gracefully in 1-D:

- the K-tap weight vector per channel is **stationary** in VMEM;
- each input tile of TL sequence positions is fetched HBM->VMEM **once**
  with a (K-1)-element left halo (the shift-register buffer analogue) and
  reused K times via shifted VMEM slices;
- there is no reduction axis (depthwise), so the accumulator lives in
  registers within a single grid step and the output is written once.

x (B, L, D), w (K, D) -> (B, L, D), causal (left) padding.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _trim_conv1d_kernel(x_lo_ref, x_hi_ref, w_ref, o_ref, *, K: int, TL: int):
    # x_hi is the CURRENT tile; x_lo is the PREVIOUS tile supplying the
    # (K-1)-element causal halo (zero block for the first tile).
    x_prev = x_lo_ref[0]                        # (TL, Db)
    x_cur = x_hi_ref[0]                         # (TL, Db)
    if K > 1:
        x = jnp.concatenate([x_prev[TL - (K - 1):], x_cur], axis=0)
    else:
        x = x_cur
    w = w_ref[...]                              # (K, Db) — stationary
    acc = jnp.zeros(x_cur.shape, jnp.float32)
    for k in range(K):
        acc = acc + x[k:k + TL].astype(jnp.float32) * w[k].astype(jnp.float32)
    o_ref[0] = acc.astype(o_ref.dtype)


def trim_conv1d_pallas(x: jax.Array, w: jax.Array, *, tile_l: int = 512,
                       block_d: int = 128, interpret: bool = False,
                       ) -> jax.Array:
    """Causal depthwise conv. x (B, L, D), w (K, D) -> (B, L, D)."""
    B, L, D = x.shape
    K, Dw = w.shape
    assert Dw == D, (x.shape, w.shape)
    # tile must cover the (K-1)-element halo: floor TL at K
    TL = max(min(tile_l, L), K)
    n_lt = -(-L // TL)
    Db = min(block_d, D)
    n_d = -(-D // Db)

    # One extra leading zero tile supplies the causal halo of tile 0.
    x_pad = jnp.pad(x, ((0, 0), (TL, n_lt * TL - L), (0, n_d * Db - D)))
    w_pad = jnp.pad(w, ((0, 0), (0, n_d * Db - D)))

    grid = (B, n_lt, n_d)
    kernel = functools.partial(_trim_conv1d_kernel, K=K, TL=TL)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, TL, Db), lambda b, lt, d: (b, lt, d)),      # prev
            pl.BlockSpec((1, TL, Db), lambda b, lt, d: (b, lt + 1, d)),  # cur
            pl.BlockSpec((K, Db), lambda b, lt, d: (0, d)),
        ],
        out_specs=pl.BlockSpec((1, TL, Db), lambda b, lt, d: (b, lt, d)),
        out_shape=jax.ShapeDtypeStruct((B, n_lt * TL, n_d * Db), x.dtype),
        interpret=interpret,
    )(x_pad, x_pad, w_pad)
    return out[:, :L, :D]
