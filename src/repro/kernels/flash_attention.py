"""Flash attention as a Pallas TPU kernel — the §Perf answer to the
dominant memory term of the train/prefill cells.

The XLA-visible streaming attention (nn.attention.flash_attention)
necessarily materializes the (Sq, Sk) score tensor block-by-block in HBM
(two dots can't fuse in HLO), which makes attention bytes scale as
B*H*Sq*Sk*4 — the dominant roofline memory term at seq 4k-32k. This kernel
keeps the running (m, l, acc) statistics in VMEM scratch across the kv-block
grid axis, so HBM traffic drops to q+k+v+o (the flash-attention guarantee).

Grid: (B*H, n_q_blocks, n_kv_blocks), kv innermost. Causal blocks that are
fully masked are skipped with pl.when (their DMA is still scheduled by the
pipeline — on TPU the win comes from the revolving-buffer reuse, the skip
saves the MXU work).

Shapes: q (B, H, Sq, D), k/v (B, H, Sk, D) -> o (B, H, Sq, D). The block
layout wants D and the block sizes MXU-aligned (D multiple of 128 ideally;
interpret mode accepts anything).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, n_kv: int, causal: bool, scale: float,
                  kv_len: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)

    if causal:
        # skip blocks entirely above the diagonal
        run = (ki * bk) <= (qi * bq + bq - 1)
    else:
        run = ki >= 0

    @pl.when(run)
    def _step():
        q = q_ref[0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0].astype(jnp.float32)                  # (bk, D)
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < kv_len
        if causal:
            mask &= k_pos <= q_pos
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...][:, 0]                         # (bq,)
        l_prev = l_ref[...][:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * alpha[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32))
        m_ref[...] = m_new[:, None]
        l_ref[...] = l_new[:, None]

    @pl.when(ki == n_kv - 1)
    def _flush():
        l = l_ref[...][:, 0]
        o_ref[0] = (acc_ref[...]
                    / jnp.maximum(l, 1e-20)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True, block_q: int = 512,
                           block_k: int = 512,
                           kv_length: Optional[int] = None,
                           interpret: bool = False) -> jax.Array:
    """q (B, H, Sq, D), k/v (B, H, Sk, D) -> (B, H, Sq, D)."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    kv_len = Sk if kv_length is None else kv_length
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    nq = -(-Sq // bq)
    nk = -(-Sk // bk)
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, nq * bq - Sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, nk * bk - Sk), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, nk * bk - Sk), (0, 0)))
    qp = qp.reshape(B * H, nq * bq, D)
    kp = kp.reshape(B * H, nk * bk, D)
    vp = vp.reshape(B * H, nk * bk, D)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_kv=nk, causal=causal,
        scale=D ** -0.5, kv_len=kv_len)
    scratch = [
        _VMEM((bq, D), jnp.float32),
        _VMEM((bq, 1), jnp.float32),
        _VMEM((bq, 1), jnp.float32),
    ]
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk, D), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, D), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, nq * bq, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
    )(qp, kp, vp)
    return out.reshape(B, H, nq * bq, D)[:, :, :Sq]


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        kv_length: Optional[int] = None) -> jax.Array:
    """Naive oracle: full-softmax attention, f32."""
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * (D ** -0.5)
    k_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= k_pos[None, :] <= (jnp.arange(Sq)[:, None] + (Sk - Sq))
    if kv_length is not None:
        mask &= (k_pos < kv_length)[None, :]
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
