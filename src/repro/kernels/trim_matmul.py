"""TrIM matmul — the degenerate K=1 case of the paper's dataflow, i.e. a
weight-stationary blocked matmul with single-fetch input broadcast and a
VMEM psum accumulator over the contraction grid axis.

This is the building block the LM layers share with the conv engine: the
paper's TrIM Core (P_M-channel contraction on stationary kernels) IS a
blocked matmul when K=1, and its Engine (P_N cores on broadcast inputs) is
the N-block grid axis whose input index_map is N-independent.

a (M, K) @ b (K, N) -> (M, N); f32/bf16 (f32 accum) or int8 (int32 accum).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:
    from jax.experimental.pallas import tpu as pltpu
    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover
    _VMEM = None


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(ki == n_k - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def trim_matmul_pallas(a: jax.Array, b: jax.Array, *, block_m: int = 256,
                       block_n: int = 256, block_k: int = 512,
                       out_dtype=None, interpret: bool = False) -> jax.Array:
    M, K = a.shape
    K2, N = b.shape
    assert K == K2
    integer = jnp.issubdtype(a.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    if out_dtype is None:
        out_dtype = jnp.int32 if integer else a.dtype

    bm, bn, bk = min(block_m, M), min(block_n, N), min(block_k, K)
    gm, gn, gk = -(-M // bm), -(-N // bn), -(-K // bk)
    a_p = jnp.pad(a, ((0, gm * bm - M), (0, gk * bk - K)))
    b_p = jnp.pad(b, ((0, gk * bk - K), (0, gn * bn - N)))

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=gk),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda m, n, k: (m, k)),   # N-independent
            pl.BlockSpec((bk, bn), lambda m, n, k: (k, n)),   # M-stationary
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda m, n, k: (m, n)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), out_dtype),
        scratch_shapes=[_VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:M, :N]
