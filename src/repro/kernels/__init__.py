"""Pallas TPU kernels for the paper's compute hot-spots + the §Perf
attention kernel, each with a jit'd wrapper (ops.py) and a pure-jnp oracle
(ref.py) asserted against in tests:

- trim_conv2d — the paper's TrIM dataflow on the TPU memory hierarchy
  (single-fetch haloed input tiles, weight-stationary, VMEM psum accum),
  stride-aware with a fused bias/ReLU/requant epilogue (DESIGN.md §2) and
  a custom VJP (trim_conv2d_vjp — dilated-cotangent input-grad + per-tap
  weight-grad Pallas kernels, DESIGN.md §6) so training runs TrIM in both
  directions.
- trim_conv1d — TrIM-1D causal depthwise conv (the Mamba short-conv).
- trim_matmul — the K=1 degenerate TrIM (weight-stationary blocked GEMM).
- flash_attention — fused streaming-softmax attention (scores in VMEM),
  the answer to the dominant roofline memory term (§Perf).
- trim_ssd — the Mamba2 chunked SSD scan with the (CS, CS) quadratic block
  VMEM-resident and the inter-chunk state carried in scratch (the TrIM
  psum-buffer pattern; the mamba2 train cell's deep §Perf fix).
"""
from repro.kernels.trim_conv2d_vjp import (  # noqa: F401
    trim_conv2d_input_grad, trim_conv2d_wgrad_pallas)
from repro.kernels.flash_attention import (  # noqa: F401
    flash_attention_pallas, flash_attention_ref)
from repro.kernels.trim_ssd import trim_ssd_pallas  # noqa: F401

#: ops re-exports resolve lazily (PEP 562): ops.py sits *above* the engine
#: (it shims legacy kwargs onto repro.engine plans), and repro.engine
#: imports the kernel modules from this package — an eager import here
#: would close that cycle during package init.
_OPS_EXPORTS = ("trim_conv1d", "trim_conv2d", "trim_matmul")


def __getattr__(name):
    if name in _OPS_EXPORTS:
        from repro.kernels import ops

        return getattr(ops, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
