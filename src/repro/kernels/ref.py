"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests assert against
(``np.testing.assert_allclose`` over shape/dtype sweeps, plus hypothesis
property tests). They are also the CPU fallback used by the model layers
when the Pallas path is disabled.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1,
               padding: Optional[int] = None,
               acc_dtype: jnp.dtype = jnp.float32,
               groups: int = 1) -> jax.Array:
    """NHWC conv oracle. x (N,H,W,C), w (K,K,C/groups,F) -> (N,H_O,W_O,F).

    Integer inputs accumulate exactly in int32 (the TrIM precision contract);
    float inputs accumulate in f32. groups > 1 = grouped convolution
    (AlexNet's two-tower CL2/CL4/CL5 — the paper's Table II M values are
    per-group input channels).
    """
    K = w.shape[0]
    p = K // 2 if padding is None else padding
    if jnp.issubdtype(x.dtype, jnp.integer):
        acc_dtype = jnp.int32
        xc = x.astype(jnp.int32)
        wc = w.astype(jnp.int32)
    else:
        xc = x.astype(acc_dtype)
        wc = w.astype(acc_dtype)
    return lax.conv_general_dilated(
        xc, wc, window_strides=(stride, stride),
        padding=[(p, p), (p, p)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=acc_dtype)


def conv2d_exact_f32(x: jax.Array, w: jax.Array, stride: int = 1,
                     padding: Optional[int] = None,
                     groups: int = 1,
                     w_abs_max: Optional[int] = None) -> jax.Array:
    """Integer conv oracle evaluated on the f32 conv path — exactly.

    XLA's CPU integer convolution lowers to a scalar loop (two orders of
    magnitude slower than the Eigen f32 path the float conv takes).  For
    8-bit operands the same int32 result can be computed ON the fast f32
    path by splitting the channel contraction into chunks whose worst-case
    partial sums stay below 2**24: every intermediate value is then an
    integer that f32 represents exactly, each chunk rounds back to int32
    losslessly, and the int32 chunk sums recover the full contraction
    (integer addition is associative).  Bit-identical to ``conv2d_ref`` for
    8-bit inputs under the TrIM no-int32-overflow contract; float inputs
    and wider integer types (no exactness budget) delegate to
    ``conv2d_ref`` unchanged.

    This is the ``substrate="f32exact"`` arm of the execution engine — a
    per-layer schedule choice the autotuner (DESIGN.md §7) can measure
    against the plain oracle and the Pallas kernel.

    ``w_abs_max`` optionally tightens the weight-magnitude term of the
    exactness budget below the dtype bound.  The int5 MSR lane (DESIGN.md
    §9.3) stores its decompressed operands in int8 but guarantees
    ``|w| <= 31``, which widens the lossless channel chunks ~4x — the
    chunking loop shrinks accordingly.  The caller owns the bound: values
    exceeding it would silently break exactness.
    """
    if not (jnp.issubdtype(x.dtype, jnp.integer)
            and jnp.issubdtype(w.dtype, jnp.integer)):
        return conv2d_ref(x, w, stride=stride, padding=padding,
                          groups=groups)
    w_bound = max(abs(int(jnp.iinfo(w.dtype).min)),
                  int(jnp.iinfo(w.dtype).max))
    if w_abs_max is not None:
        w_bound = min(w_bound, int(w_abs_max))
    bound = (max(abs(int(jnp.iinfo(x.dtype).min)), int(jnp.iinfo(x.dtype).max))
             * w_bound)
    K = w.shape[0]
    chunk_c = ((1 << 24) // bound) // (K * K) if bound else 0
    if chunk_c < 1:
        return conv2d_ref(x, w, stride=stride, padding=padding,
                          groups=groups)
    if groups > 1:
        cg = x.shape[-1] // groups
        fg = w.shape[-1] // groups
        return jnp.concatenate(
            [conv2d_exact_f32(x[..., g * cg:(g + 1) * cg],
                              w[..., g * fg:(g + 1) * fg],
                              stride=stride, padding=padding,
                              w_abs_max=w_abs_max)
             for g in range(groups)], axis=-1)
    p = K // 2 if padding is None else padding
    xf = x.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    C = x.shape[-1]
    out = None
    for c0 in range(0, C, chunk_c):
        o = lax.conv_general_dilated(
            xf[..., c0:c0 + chunk_c], wf[:, :, c0:c0 + chunk_c, :],
            window_strides=(stride, stride), padding=[(p, p), (p, p)],
            dimension_numbers=("NHWC", "HWIO", "NHWC")).astype(jnp.int32)
        out = o if out is None else out + o
    return out


def conv1d_causal_ref(x: jax.Array, w: jax.Array,
                      acc_dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """Causal depthwise conv oracle (the Mamba short-conv).

    x (B, L, D), w (K, D) -> (B, L, D):
      out[b, l, d] = sum_k x[b, l - K + 1 + k, d] * w[k, d]
    with implicit left zero padding.
    """
    K = w.shape[0]
    xp = jnp.pad(x.astype(acc_dtype), ((0, 0), (K - 1, 0), (0, 0)))
    L = x.shape[1]
    out = jnp.zeros(x.shape, acc_dtype)
    for k in range(K):
        out = out + xp[:, k:k + L, :] * w[k].astype(acc_dtype)
    return out.astype(x.dtype if jnp.issubdtype(x.dtype, jnp.floating)
                      else acc_dtype)


def matmul_ref(a: jax.Array, b: jax.Array,
               acc_dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """Blocked-matmul oracle: (M,K) @ (K,N) with f32/int32 accumulation."""
    if jnp.issubdtype(a.dtype, jnp.integer):
        return jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                       preferred_element_type=jnp.int32)
    return jnp.dot(a, b, preferred_element_type=acc_dtype).astype(a.dtype)
