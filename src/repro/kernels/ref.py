"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth the kernel tests assert against
(``np.testing.assert_allclose`` over shape/dtype sweeps, plus hypothesis
property tests). They are also the CPU fallback used by the model layers
when the Pallas path is disabled.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax


def conv2d_ref(x: jax.Array, w: jax.Array, stride: int = 1,
               padding: Optional[int] = None,
               acc_dtype: jnp.dtype = jnp.float32,
               groups: int = 1) -> jax.Array:
    """NHWC conv oracle. x (N,H,W,C), w (K,K,C/groups,F) -> (N,H_O,W_O,F).

    Integer inputs accumulate exactly in int32 (the TrIM precision contract);
    float inputs accumulate in f32. groups > 1 = grouped convolution
    (AlexNet's two-tower CL2/CL4/CL5 — the paper's Table II M values are
    per-group input channels).
    """
    K = w.shape[0]
    p = K // 2 if padding is None else padding
    if jnp.issubdtype(x.dtype, jnp.integer):
        acc_dtype = jnp.int32
        xc = x.astype(jnp.int32)
        wc = w.astype(jnp.int32)
    else:
        xc = x.astype(acc_dtype)
        wc = w.astype(acc_dtype)
    return lax.conv_general_dilated(
        xc, wc, window_strides=(stride, stride),
        padding=[(p, p), (p, p)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups,
        preferred_element_type=acc_dtype)


def conv1d_causal_ref(x: jax.Array, w: jax.Array,
                      acc_dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """Causal depthwise conv oracle (the Mamba short-conv).

    x (B, L, D), w (K, D) -> (B, L, D):
      out[b, l, d] = sum_k x[b, l - K + 1 + k, d] * w[k, d]
    with implicit left zero padding.
    """
    K = w.shape[0]
    xp = jnp.pad(x.astype(acc_dtype), ((0, 0), (K - 1, 0), (0, 0)))
    L = x.shape[1]
    out = jnp.zeros(x.shape, acc_dtype)
    for k in range(K):
        out = out + xp[:, k:k + L, :] * w[k].astype(acc_dtype)
    return out.astype(x.dtype if jnp.issubdtype(x.dtype, jnp.floating)
                      else acc_dtype)


def matmul_ref(a: jax.Array, b: jax.Array,
               acc_dtype: jnp.dtype = jnp.float32) -> jax.Array:
    """Blocked-matmul oracle: (M,K) @ (K,N) with f32/int32 accumulation."""
    if jnp.issubdtype(a.dtype, jnp.integer):
        return jnp.dot(a.astype(jnp.int32), b.astype(jnp.int32),
                       preferred_element_type=jnp.int32)
    return jnp.dot(a, b, preferred_element_type=acc_dtype).astype(a.dtype)
