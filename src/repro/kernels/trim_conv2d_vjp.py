"""Backward-pass (VJP) Pallas kernels for the TrIM conv2d (DESIGN.md §6).

The forward kernel realizes the paper's triangular input movement; training
additionally needs dL/dx and dL/dw.  Both gradients are themselves
TrIM-shaped sweeps and reuse the forward machinery:

- **Input grad** — a transposed conv expressed as a TrIM *forward*: the
  cotangent is dilated by the stride (S-1 zeros between rows/columns),
  the weights are flipped spatially and transposed (K,K,C,F) -> (K,K,F,C),
  and ``trim_conv2d_pallas`` runs at stride 1 — same halo-row/halo-column
  block maps, same ``pick_tile_w`` VMEM sizing, zero new kernel code.
- **Weight grad** — a per-(K,K)-tap reduction: for every tap,
  ``dw[kh, kw] += <shifted input window, cotangent tile>`` — the (Cb, Fb)
  contraction over the output tile's spatial extent — accumulated in an
  fp32 (K, K, Cb, Fb) VMEM scratch across the batch/row/column grid axes.
  It is the forward kernel with the roles of weights and outputs
  exchanged: the dw block's index_map is constant along the spatial axes
  (stationary, like the forward's weights) and is written exactly once,
  on the last spatial step (the forward's psum pattern).

``make_trim_conv2d_vjp`` packages both under ``jax.custom_vjp`` around the
epilogue-fused forward (bias + ReLU in the flush): the ReLU mask is
*reconstructed* from the saved post-activation output (out > 0 <=>
pre-activation > 0, and relu'(0) = 0 either way), so no pre-activation
psums are stashed; dbias is the masked cotangent summed over N/H/W.
Float path only — the integer/requant datapath stays forward-only, as
does the ``ExecutionPolicy(emulate_hw=True)`` decimation replay (the
planner routes both around the VJP — ``repro.engine.execute``).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.trim_conv2d import (VMEM_BUDGET_BYTES, _scratch,
                                       assemble_halo_tile, conv2d_geom,
                                       halo_x_specs, pad_conv2d_x,
                                       trim_conv2d_pallas)


def trim_conv2d_input_grad(g_out: jax.Array, w: jax.Array, *,
                           x_hw, stride: int = 1,
                           padding: Optional[int] = None,
                           tile_h: int = 8, tile_w: Optional[int] = None,
                           block_c: int = 128, block_f: int = 128,
                           vmem_budget: int = VMEM_BUDGET_BYTES,
                           out_dtype=None,
                           interpret: bool = False) -> jax.Array:
    """dL/dx of the TrIM conv: g_out (N,H_O,W_O,F), w (K,K,C,F) -> (N,H,W,C).

    Dilate-by-stride + flipped-weight forward (DESIGN.md §6): the cotangent
    is zero-stuffed to the stride-1 extent, padded with K-1-p leading and
    K-1-p + (H+2p-K) mod S trailing rows/cols (the trailing remainder
    covers input pixels the strided sweep never touched — their gradient
    is zero), and pushed through the *forward* kernel at stride 1 with
    w[::-1, ::-1] transposed to (K,K,F,C).  ``block_c``/``block_f`` keep
    the forward-call meaning (C and F of the *forward* conv) and are
    swapped internally.
    """
    N, H_O, W_O, F = g_out.shape
    K = w.shape[0]
    H, W = x_hw
    S = int(stride)
    p = K // 2 if padding is None else padding
    if S > 1:
        Hd, Wd = (H_O - 1) * S + 1, (W_O - 1) * S + 1
        gd = jnp.zeros((N, Hd, Wd, F), g_out.dtype)
        gd = gd.at[:, ::S, ::S, :].set(g_out)
    else:
        Hd, Wd = H_O, W_O
        gd = g_out
    lo = K - 1 - p
    if lo < 0:                      # p > K-1: crop instead of (negative) pad
        gd = gd[:, -lo:, -lo:, :]
        Hd, Wd = Hd + lo, Wd + lo
    top = max(lo, 0)
    # Total rows must be H + K - 1 so the stride-1 valid sweep emits >= H.
    gd = jnp.pad(gd, ((0, 0), (top, max(H + K - 1 - top - Hd, 0)),
                      (top, max(W + K - 1 - top - Wd, 0)), (0, 0)))
    w_t = w[::-1, ::-1].transpose(0, 1, 3, 2)       # (K, K, F, C)
    dx = trim_conv2d_pallas(gd, w_t, stride=1, padding=0, tile_h=tile_h,
                            tile_w=tile_w, block_c=block_f, block_f=block_c,
                            vmem_budget=vmem_budget, out_dtype=out_dtype,
                            interpret=interpret)
    return dx[:, :H, :W, :]


def _trim_conv2d_wgrad_kernel(*refs, K: int, TH: int, TW: int, stride: int,
                              n_steps: int, n_wt: int, tiled: bool,
                              has_halo_h: bool, has_halo_w: bool):
    """One grid step: accumulate every (kh, kw) tap's (Cb, Fb) contribution
    from one (TH, TW) output tile into the stationary dw scratch."""
    it = iter(refs)
    x_ll_ref = next(it)
    x_lh_ref = next(it) if has_halo_w else None
    x_hl_ref = next(it) if has_halo_h else None
    x_hh_ref = next(it) if (has_halo_h and has_halo_w) else None
    g_ref = next(it)
    dw_ref = next(it)
    acc_ref = next(it)

    step = (pl.program_id(2) * n_wt + pl.program_id(3) if tiled
            else pl.program_id(2))

    @pl.when(step == 0)
    def _zero():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    halo = K - stride
    x = assemble_halo_tile(x_ll_ref, x_lh_ref, x_hl_ref, x_hh_ref, halo)
    gt = g_ref[0]                           # (TH, TW, Fb)
    cb = x.shape[-1]
    fb = gt.shape[-1]
    g2 = gt.reshape(TH * TW, fb)
    rows = (TH - 1) * stride + 1
    cols = (TW - 1) * stride + 1
    # The forward's K*K shifted views of the same resident tile, contracted
    # against the cotangent tile instead of the weights.
    for kh in range(K):
        for kw in range(K):
            patch = jax.lax.slice(x, (kh, kw, 0),
                                  (kh + rows, kw + cols, cb),
                                  (stride, stride, 1))  # (TH, TW, Cb)
            tap = jax.lax.dot_general(
                patch.reshape(TH * TW, cb), g2,
                (((0,), (0,)), ((), ())),
                preferred_element_type=jnp.float32)     # (Cb, Fb)
            acc_ref[kh, kw] = acc_ref[kh, kw] + tap

    @pl.when(step == n_steps - 1)
    def _flush():
        dw_ref[...] = acc_ref[...].astype(dw_ref.dtype)


def trim_conv2d_wgrad_pallas(x: jax.Array, g_out: jax.Array, *, K: int,
                             stride: int = 1,
                             padding: Optional[int] = None,
                             tile_h: int = 8, tile_w: Optional[int] = None,
                             block_c: int = 128, block_f: int = 128,
                             vmem_budget: int = VMEM_BUDGET_BYTES,
                             out_dtype=None,
                             interpret: bool = False) -> jax.Array:
    """dL/dw of the TrIM conv: x (N,H,W,C), g_out (N,H_O,W_O,F) ->
    (K,K,C,F).

    Reuses the forward geometry verbatim (``conv2d_geom`` — same TH/TW
    tiles, same haloed ll/lh/hl/hh input block maps); the grid is
    reordered to ``(n_ci, n_f, N*n_ht[, n_wt])`` so the spatial/batch
    reduction axes are innermost and the (K,K,Cb,Fb) fp32 scratch
    integrates across them, written back once on the last step.
    """
    N, H, W, C = x.shape
    _, H_O, W_O, F = g_out.shape
    geo = conv2d_geom(x.shape, (K, K, C, F), stride=stride, padding=padding,
                      tile_h=tile_h, tile_w=tile_w, block_c=block_c,
                      block_f=block_f, in_sz=x.dtype.itemsize,
                      w_sz=g_out.dtype.itemsize,
                      out_sz=jnp.dtype(x.dtype).itemsize,
                      vmem_budget=vmem_budget)
    assert (H_O, W_O) == (geo.H_O, geo.W_O), ((H_O, W_O), geo)
    if out_dtype is None:
        out_dtype = x.dtype
    TH, TW, n_ht, n_wt = geo.TH, geo.TW, geo.n_ht, geo.n_wt
    Cb, n_ci, Fb, n_f = geo.Cb, geo.n_ci, geo.Fb, geo.n_f

    x_pad = pad_conv2d_x(x, geo)
    # Cotangent padded to the output grid extent — the zero rows/cols/
    # channels contribute nothing to the dw sums.
    g_pad = jnp.pad(g_out, ((0, 0), (0, n_ht * TH - H_O),
                            (0, n_wt * TW - W_O), (0, n_f * Fb - F)))

    NB = N * n_ht
    if geo.tiled:
        grid = (n_ci, n_f, NB, n_wt)

        def x_idx(dh, dw):
            return lambda c, f, bt, wt: (bt // n_ht, bt % n_ht + dh,
                                         wt + dw, c)

        def g_idx(c, f, bt, wt):
            return (bt // n_ht, bt % n_ht, wt, f)

        def o_idx(c, f, bt, wt):
            return (0, 0, c, f)
    else:
        grid = (n_ci, n_f, NB)

        def x_idx(dh, dw):
            return lambda c, f, bt: (bt // n_ht, bt % n_ht + dh, 0, c)

        def g_idx(c, f, bt):
            return (bt // n_ht, bt % n_ht, 0, f)

        def o_idx(c, f, bt):
            return (0, 0, c, f)

    inputs, in_specs = halo_x_specs(x_pad, geo, x_idx)
    inputs.append(g_pad)
    in_specs.append(pl.BlockSpec((1, TH, TW, Fb), g_idx))

    kernel = functools.partial(
        _trim_conv2d_wgrad_kernel, K=K, TH=TH, TW=TW, stride=geo.S,
        n_steps=NB * n_wt, n_wt=n_wt, tiled=geo.tiled,
        has_halo_h=geo.has_halo, has_halo_w=geo.has_halo and geo.tiled)
    dw = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((K, K, Cb, Fb), o_idx),
        out_shape=jax.ShapeDtypeStruct((K, K, n_ci * Cb, n_f * Fb),
                                       out_dtype),
        scratch_shapes=[_scratch((K, K, Cb, Fb), jnp.float32)],
        interpret=interpret,
    )(*inputs)
    return dw[:, :, :C, :F]


@functools.lru_cache(maxsize=None)
def make_trim_conv2d_vjp(*, stride: int, padding: Optional[int], relu: bool,
                         has_bias: bool, tile_h: int, tile_w: Optional[int],
                         block_c: int, block_f: int, interpret: bool,
                         vmem_budget: int = VMEM_BUDGET_BYTES):
    """Build the ``jax.custom_vjp``-wrapped fused TrIM conv for one static
    configuration (cached so repeated traces reuse one primitive).

    Returns ``f(x, w, bias)`` when ``has_bias`` else ``f(x, w)``; the
    forward is the epilogue-fused Pallas kernel, the backward the
    input-grad/weight-grad Pallas pair above.  Cotangent dtypes follow the
    primals (dx: x.dtype, dw: w.dtype, dbias: bias.dtype).
    """
    kw = dict(stride=stride, padding=padding, tile_h=tile_h, tile_w=tile_w,
              block_c=block_c, block_f=block_f, vmem_budget=vmem_budget,
              interpret=interpret)

    def fwd_call(x, w, bias):
        return trim_conv2d_pallas(x, w, bias=bias, relu=relu, **kw)

    def bwd_core(x, w, out, g):
        if relu:
            # out = relu(pre): the mask is recoverable from the saved
            # activation — no pre-activation stash (DESIGN.md §6).
            g = g * (out > 0).astype(g.dtype)
        dx = trim_conv2d_input_grad(g, w, x_hw=x.shape[1:3],
                                    out_dtype=x.dtype, **kw)
        dw = trim_conv2d_wgrad_pallas(x, g, K=w.shape[0],
                                      out_dtype=w.dtype, **kw)
        return dx, dw, g

    if has_bias:
        @jax.custom_vjp
        def conv(x, w, b):
            return fwd_call(x, w, b)

        def conv_fwd(x, w, b):
            out = fwd_call(x, w, b)
            return out, (x, w, b, out)

        def conv_bwd(res, g):
            x, w, b, out = res
            dx, dw, gm = bwd_core(x, w, out, g)
            db = gm.astype(jnp.float32).sum(axis=(0, 1, 2)).astype(b.dtype)
            return dx, dw, db

        conv.defvjp(conv_fwd, conv_bwd)
        return conv

    @jax.custom_vjp
    def conv_nb(x, w):
        return fwd_call(x, w, None)

    def conv_nb_fwd(x, w):
        out = fwd_call(x, w, None)
        return out, (x, w, out)

    def conv_nb_bwd(res, g):
        x, w, out = res
        dx, dw, _ = bwd_core(x, w, out, g)
        return dx, dw

    conv_nb.defvjp(conv_nb_fwd, conv_nb_bwd)
    return conv_nb
