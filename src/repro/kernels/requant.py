"""Fixed-point multiplier + shift requantization (DESIGN.md §4).

The paper's engine requantizes int32 psums to B-bit activations with a
power-of-two right shift (``core/trim/quant.py``).  Arbitrary per-layer /
per-channel scales — the serial-accumulation accelerator's output stage
(Ahmadi et al., PAPERS.md) and standard int8 inference practice — need

    out = clip(round(acc * scale), 0, 255),   scale = m * 2**-s

with ``m`` a 15-bit integer multiplier and ``s`` an integer shift.  The
exact semantics implemented here (and mirrored bit-for-bit by the fused
Pallas epilogue, the jnp fallback epilogue, and the test oracles) is

    requant(acc, m, s) = clip((acc * m + 2**(s-1)) >> s, 0, 255)

i.e. round-half-up (round half toward +inf) of ``acc * m / 2**s``.

TPU Pallas has no int64 (and JAX's default x64-disabled mode silently
downcasts), so the 48-bit product ``acc * m`` is computed exactly with
int32-only arithmetic via a hi/lo split (see ``requant_mult_shift``).
Domain: ``1 <= m <= 32767`` and ``1 <= s <= 31`` — every scale in
(2**-31, 255] is representable with 15 bits of mantissa precision
(``scale_to_mult_shift``).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


def requant_mult_shift(acc: jax.Array, mult, shift) -> jax.Array:
    """``clip((acc * m + 2**(s-1)) >> s, 0, 255)`` — exact, int32-only.

    ``acc`` int32 (any value); ``mult``/``shift`` scalars or arrays that
    broadcast against ``acc`` (per-channel: shape (F,) against NHWF), with
    ``1 <= mult <= 32767`` and ``1 <= shift <= 31``.  Returns int32 in
    [0, 255] (caller casts to uint8).

    The 48-bit product is split as ``acc = hi*2**16 + lo`` (``lo`` the
    unsigned low half), so ``acc*m = (hi*m + (lo*m >> 16))*2**16 + c0``
    with every intermediate in int32 range.  The two shift regimes:

    - ``s >= 17``: the rounding constant is a multiple of 2**16, and the
      low 16 bits can never carry past the shift, so
      ``r = (h + 2**(s-17)) >> (s-16)`` is exact.
    - ``s <= 16``: ``r = (h << (16-s)) + ((c0 + 2**(s-1)) >> s)`` is exact;
      ``h`` is pre-clamped so the left shift saturates (clamped values are
      far outside [0, 255] in the true result, so the final clip agrees).
    """
    m = jnp.asarray(mult, jnp.int32)
    s = jnp.asarray(shift, jnp.int32)
    hi = jnp.right_shift(acc, 16)
    lo = jnp.bitwise_and(acc, 0xFFFF)
    b = lo * m                                   # <= 65535*32767 < 2**31
    h = hi * m + jnp.right_shift(b, 16)          # |h| < 2**30 + 2**15
    c0 = jnp.bitwise_and(b, 0xFFFF)
    # s >= 17 regime
    r_hi = jnp.right_shift(h + jnp.left_shift(1, jnp.clip(s - 17, 0, 30)),
                           jnp.clip(s - 16, 1, 31))
    # 1 <= s <= 16 regime (clamp h so h << (16-s) stays in int32)
    sl = jnp.clip(s, 1, 16)
    lim = jnp.left_shift(1, jnp.minimum(15 + sl, 30)) - 2
    hc = jnp.clip(h, -lim - 1, lim)
    r_lo = (jnp.left_shift(hc, 16 - sl)
            + jnp.right_shift(c0 + jnp.left_shift(1, sl - 1), sl))
    return jnp.clip(jnp.where(s >= 17, r_hi, r_lo), 0, 255)


def requant_ref_int64(acc: np.ndarray, mult, shift) -> np.ndarray:
    """Independent numpy int64 oracle for ``requant_mult_shift``."""
    a = acc.astype(np.int64)
    m = np.asarray(mult, np.int64)
    s = np.asarray(shift, np.int64)
    r = (a * m + (np.int64(1) << (s - 1))) >> s
    return np.clip(r, 0, 255).astype(np.int64)


def scale_to_mult_shift(scale) -> Tuple[np.ndarray, np.ndarray]:
    """Float scale(s) -> (mult int32, shift int32) with 15-bit mantissa.

    Picks ``s`` so ``m = round(scale * 2**s)`` lands in [2**14, 2**15)
    (full precision) and clamps to the valid domain ``m in [1, 32767]``,
    ``s in [1, 31]``.  Accepts scalars or arrays (per-channel scales).
    """
    sc = np.maximum(np.asarray(scale, np.float64), 2.0 ** -40)
    e = np.floor(np.log2(sc)).astype(np.int64)
    s = np.clip(14 - e, 1, 31)
    m = np.round(sc * np.exp2(s.astype(np.float64))).astype(np.int64)
    over = m >= 32768
    m = np.where(over, m >> 1, m)
    s = np.where(over, np.maximum(s - 1, 1), s)
    m = np.clip(m, 1, 32767).astype(np.int32)
    return m, s.astype(np.int32)
