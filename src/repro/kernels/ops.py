"""Public jit'd wrappers around the Pallas kernels, with CPU fallback.

On TPU these call the compiled Pallas kernels; on CPU they default to the
pure-jnp oracles (``ref.py``) for speed, or run the Pallas kernels in
interpret mode when ``force_pallas=True`` (that is what the kernel tests do
to validate the kernel bodies themselves).

Striding for the conv path is done here by decimation of the stride-1
result — exactly the hardware's behaviour for AlexNet CL1 (§V: full
stride-1 sweep, downstream decimation).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.trim_conv1d import trim_conv1d_pallas
from repro.kernels.trim_conv2d import trim_conv2d_pallas
from repro.kernels.trim_matmul import trim_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "force_pallas", "tile_h",
                                             "block_c", "block_f", "groups"))
def trim_conv2d(x: jax.Array, w: jax.Array, *, stride: int = 1,
                padding: Optional[int] = None, force_pallas: bool = False,
                tile_h: int = 8, block_c: int = 128, block_f: int = 128,
                groups: int = 1) -> jax.Array:
    """TrIM conv2d. x (N,H,W,C), w (K,K,C/groups,F) -> (N,H_O,W_O,F).

    groups > 1: grouped conv — each group maps onto its own set of TrIM
    cores (the hardware schedules groups as independent filter sets), here
    one kernel call per group."""
    use_pallas = _on_tpu() or force_pallas
    if use_pallas:
        if groups == 1:
            out = trim_conv2d_pallas(x, w, padding=padding, tile_h=tile_h,
                                     block_c=block_c, block_f=block_f,
                                     interpret=not _on_tpu())
        else:
            cg = x.shape[-1] // groups
            fg = w.shape[-1] // groups
            outs = [trim_conv2d_pallas(
                x[..., g * cg:(g + 1) * cg],
                w[..., g * fg:(g + 1) * fg],
                padding=padding, tile_h=tile_h, block_c=min(block_c, cg),
                block_f=min(block_f, fg), interpret=not _on_tpu())
                for g in range(groups)]
            out = jnp.concatenate(outs, axis=-1)
        if stride > 1:
            out = out[:, ::stride, ::stride, :]
        return out
    return ref.conv2d_ref(x, w, stride=stride, padding=padding,
                          groups=groups)


@functools.partial(jax.jit, static_argnames=("force_pallas", "tile_l",
                                             "block_d"))
def trim_conv1d(x: jax.Array, w: jax.Array, *, force_pallas: bool = False,
                tile_l: int = 512, block_d: int = 128) -> jax.Array:
    """Causal depthwise conv. x (B,L,D), w (K,D) -> (B,L,D)."""
    if _on_tpu() or force_pallas:
        return trim_conv1d_pallas(x, w, tile_l=tile_l, block_d=block_d,
                                  interpret=not _on_tpu())
    return ref.conv1d_causal_ref(x, w)


@functools.partial(jax.jit, static_argnames=("force_pallas", "block_m",
                                             "block_n", "block_k"))
def trim_matmul(a: jax.Array, b: jax.Array, *, force_pallas: bool = False,
                block_m: int = 256, block_n: int = 256, block_k: int = 512,
                ) -> jax.Array:
    """Weight-stationary blocked matmul (the K=1 TrIM case)."""
    if _on_tpu() or force_pallas:
        return trim_matmul_pallas(a, b, block_m=block_m, block_n=block_n,
                                  block_k=block_k, interpret=not _on_tpu())
    return ref.matmul_ref(a, b)
