"""Public jit'd wrappers around the Pallas kernels, with CPU fallback.

On TPU these call the compiled Pallas kernels; on CPU they default to the
pure-jnp oracles (``ref.py``) for speed, or run the Pallas kernels in
interpret mode when ``force_pallas=True`` (that is what the kernel tests do
to validate the kernel bodies themselves).

The conv path is stride-aware and width-tiled end to end: the kernel
computes only the strided H_O x W_O outputs, splits W_O into VMEM-sized
column tiles (``tile_w``; auto-picked by default) and can fuse the layer
epilogue (bias + ReLU + power-of-two or arbitrary-scale multiplier+shift
requantization) into its final-C_in flush.  ``emulate_hw=True``
opts back into the hardware's behaviour for strided layers (§V, AlexNet
CL1: full stride-1 sweep, downstream decimation) so model/benchmark
comparisons against Tables I-II stay honest — on every substrate, including
the CPU oracle.

The float conv path is differentiable on every substrate: the Pallas arm
carries a custom VJP (``trim_conv2d_vjp.py`` — dilated-cotangent forward
for dL/dx, per-tap reduction kernel for dL/dw, DESIGN.md §6), so
``jax.grad`` through ``trim_conv2d`` hits Pallas in both directions; the
CPU-oracle arm differentiates through ``lax.conv`` as before.  The
integer/requant datapath and ``emulate_hw`` stay forward-only.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.requant import requant_mult_shift
from repro.kernels.trim_conv1d import trim_conv1d_pallas
from repro.kernels.trim_conv2d import trim_conv2d_pallas
from repro.kernels.trim_conv2d_vjp import make_trim_conv2d_vjp
from repro.kernels.trim_matmul import trim_matmul_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _epilogue_jnp(out: jax.Array, bias: Optional[jax.Array], relu: bool,
                  requant_shift: Optional[int],
                  requant: Optional[Tuple[jax.Array, jax.Array]] = None,
                  ) -> jax.Array:
    """Unfused epilogue (CPU oracle + emulate_hw decimation paths).

    Bit-identical to the fused kernel flush: the power-of-two path shifts
    without rounding (the engine's output stage) and the multiplier+shift
    path reuses ``kernels.requant.requant_mult_shift``."""
    if bias is not None:
        out = out + bias.astype(out.dtype)
    if relu:
        out = jnp.maximum(out, 0)
    if requant_shift is not None:
        out = jnp.clip(jnp.right_shift(out, requant_shift),
                       0, 255).astype(jnp.uint8)
    if requant is not None:
        out = requant_mult_shift(out, requant[0],
                                 requant[1]).astype(jnp.uint8)
    return out


@functools.partial(jax.jit, static_argnames=("stride", "padding",
                                             "force_pallas", "tile_h",
                                             "tile_w", "block_c", "block_f",
                                             "groups", "relu",
                                             "requant_shift", "emulate_hw"))
def trim_conv2d(x: jax.Array, w: jax.Array,
                bias: Optional[jax.Array] = None,
                requant: Optional[Tuple[jax.Array, jax.Array]] = None, *,
                stride: int = 1,
                padding: Optional[int] = None, force_pallas: bool = False,
                tile_h: int = 8, tile_w: Optional[int] = None,
                block_c: int = 128, block_f: int = 128,
                groups: int = 1, relu: bool = False,
                requant_shift: Optional[int] = None,
                emulate_hw: bool = False) -> jax.Array:
    """TrIM conv2d. x (N,H,W,C), w (K,K,C/groups,F) -> (N,H_O,W_O,F).

    groups > 1: grouped conv — each group maps onto its own set of TrIM
    cores (the hardware schedules groups as independent filter sets), here
    one kernel call per group.

    bias (F,) / relu / requant_shift / requant: layer epilogue, fused into
    the kernel flush on the Pallas path.  requant_shift (integer path only)
    applies the engine's power-of-two requantization; requant=(mult, shift)
    (scalars or per-channel (F,) int32 arrays) the arbitrary-scale
    fixed-point requantization (``kernels/requant.py``) — both return uint8.

    tile_w: output-width tile for the Pallas path (None: auto-picked from
    the VMEM budget; wider-than-VGG maps tile instead of falling off the
    fast path — DESIGN.md §4).

    emulate_hw: replay the FPGA's strided-layer schedule — full stride-1
    sweep, decimate, *then* the epilogue (3 extra HBM round-trips and
    stride^2 wasted MACs, kept for Table I/II fidelity)."""
    if requant_shift is not None or requant is not None:
        assert jnp.issubdtype(x.dtype, jnp.integer), \
            "requantization needs the integer path"
        assert requant_shift is None or requant is None, \
            "requant_shift and requant are exclusive"
    decimate = emulate_hw and stride > 1
    use_pallas = _on_tpu() or force_pallas
    if not use_pallas:
        if decimate:
            out = ref.conv2d_ref(x, w, stride=1, padding=padding,
                                 groups=groups)[:, ::stride, ::stride, :]
        else:
            out = ref.conv2d_ref(x, w, stride=stride, padding=padding,
                                 groups=groups)
        return _epilogue_jnp(out, bias, relu, requant_shift, requant)

    def one(xg, wg, bg, rq, bc, bf):
        if decimate:
            # emulate_hw stays forward-only on the Pallas path (DESIGN.md
            # §6): the FPGA-faithful decimation schedule is an inference/
            # benchmark artifact, not a training datapath.
            o = trim_conv2d_pallas(xg, wg, padding=padding, tile_h=tile_h,
                                   tile_w=tile_w, block_c=bc, block_f=bf,
                                   interpret=not _on_tpu())
            return o[:, ::stride, ::stride, :]
        if jnp.issubdtype(xg.dtype, jnp.floating):
            # Float path: the custom-VJP-wrapped fused kernel, so jax.grad
            # runs the Pallas input-grad/weight-grad pair instead of
            # falling off to the oracle (DESIGN.md §6).
            f = make_trim_conv2d_vjp(stride=stride, padding=padding,
                                     relu=relu, has_bias=bg is not None,
                                     tile_h=tile_h, tile_w=tile_w,
                                     block_c=bc, block_f=bf,
                                     interpret=not _on_tpu())
            return f(xg, wg, bg) if bg is not None else f(xg, wg)
        return trim_conv2d_pallas(xg, wg, stride=stride, padding=padding,
                                  bias=bg, relu=relu,
                                  requant_shift=requant_shift,
                                  requant=rq,
                                  tile_h=tile_h, tile_w=tile_w,
                                  block_c=bc, block_f=bf,
                                  interpret=not _on_tpu())

    if groups == 1:
        out = one(x, w, bias, requant, block_c, block_f)
    else:
        cg = x.shape[-1] // groups
        fg = w.shape[-1] // groups

        def rq_slice(g):
            # Per-group requant slices (scalars broadcast to (F,) first so
            # per-channel and per-tensor calibrations both land per group).
            if requant is None:
                return None
            m, s = requant
            F = fg * groups
            m = jnp.broadcast_to(jnp.asarray(m, jnp.int32), (F,))
            s = jnp.broadcast_to(jnp.asarray(s, jnp.int32), (F,))
            return (m[g * fg:(g + 1) * fg], s[g * fg:(g + 1) * fg])

        outs = [one(x[..., g * cg:(g + 1) * cg],
                    w[..., g * fg:(g + 1) * fg],
                    None if bias is None else bias[g * fg:(g + 1) * fg],
                    rq_slice(g),
                    min(block_c, cg), min(block_f, fg))
                for g in range(groups)]
        out = jnp.concatenate(outs, axis=-1)
    if decimate:
        out = _epilogue_jnp(out, bias, relu, requant_shift, requant)
    return out


@functools.partial(jax.jit, static_argnames=("force_pallas", "tile_l",
                                             "block_d"))
def trim_conv1d(x: jax.Array, w: jax.Array, *, force_pallas: bool = False,
                tile_l: int = 512, block_d: int = 128) -> jax.Array:
    """Causal depthwise conv. x (B,L,D), w (K,D) -> (B,L,D)."""
    if _on_tpu() or force_pallas:
        return trim_conv1d_pallas(x, w, tile_l=tile_l, block_d=block_d,
                                  interpret=not _on_tpu())
    return ref.conv1d_causal_ref(x, w)


@functools.partial(jax.jit, static_argnames=("force_pallas", "block_m",
                                             "block_n", "block_k"))
def trim_matmul(a: jax.Array, b: jax.Array, *, force_pallas: bool = False,
                block_m: int = 256, block_n: int = 256, block_k: int = 512,
                ) -> jax.Array:
    """Weight-stationary blocked matmul (the K=1 TrIM case)."""
    if _on_tpu() or force_pallas:
        return trim_matmul_pallas(a, b, block_m=block_m, block_n=block_n,
                                  block_k=block_k, interpret=not _on_tpu())
    return ref.matmul_ref(a, b)
