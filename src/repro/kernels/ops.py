"""Public wrappers around the Pallas kernels, planned via ``repro.engine``.

``trim_conv2d`` keeps its historical signature but is now a thin shim: it
builds a single-layer :class:`~repro.engine.plan.ConvLayerPlan` from the
call shapes and an :class:`~repro.engine.policy.ExecutionPolicy`, then runs
it through :func:`repro.engine.execute.run_conv2d` — the one dispatch site
that decides pallas vs oracle vs interpret (the rule itself lives in
``ExecutionPolicy.resolved_substrate``).  ``trim_conv1d`` / ``trim_matmul``
accept the same policy.

Legacy kwargs (``force_pallas``, ``emulate_hw``) keep working but emit
``DeprecationWarning`` — pass ``policy=ExecutionPolicy(...)`` instead:

- ``ExecutionPolicy()``                      TPU -> compiled Pallas, else oracle
- ``ExecutionPolicy(substrate="pallas")``    Pallas everywhere (interpret
                                             mode off-TPU; old force_pallas)
- ``ExecutionPolicy(emulate_hw=True)``       FPGA decimation replay (§V)

The float conv path stays differentiable on every substrate: the Pallas
arm carries the custom VJP (``trim_conv2d_vjp.py``, DESIGN.md §6), the
oracle arm differentiates through ``lax.conv``.  The integer/requant
datapath and ``emulate_hw`` stay forward-only.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.engine.execute import run_conv2d
from repro.engine.plan import plan_conv_layer
from repro.engine.policy import ExecutionPolicy, policy_from_legacy
from repro.kernels import ref
from repro.kernels.trim_conv1d import trim_conv1d_pallas
from repro.kernels.trim_matmul import trim_matmul_pallas


def trim_conv2d(x: jax.Array, w: jax.Array,
                bias: Optional[jax.Array] = None,
                requant: Optional[Tuple[jax.Array, jax.Array]] = None, *,
                stride: int = 1, padding: Optional[int] = None,
                groups: int = 1, relu: bool = False,
                requant_shift: Optional[int] = None,
                policy: Optional[ExecutionPolicy] = None,
                tile_h: Optional[int] = None, tile_w: Optional[int] = None,
                block_c: Optional[int] = None,
                block_f: Optional[int] = None,
                force_pallas: Optional[bool] = None,
                emulate_hw: Optional[bool] = None) -> jax.Array:
    """TrIM conv2d. x (N,H,W,C), w (K,K,C/groups,F) -> (N,H_O,W_O,F).

    groups > 1: grouped conv — each group maps onto its own set of TrIM
    cores (the hardware schedules groups as independent filter sets).

    bias (F,) / relu / requant_shift / requant: layer epilogue, fused into
    the kernel flush on the Pallas path.  requant_shift (integer path only)
    applies the engine's power-of-two requantization; requant=(mult, shift)
    (scalars or per-channel (F,) int32 arrays) the arbitrary-scale
    fixed-point requantization (``kernels/requant.py``) — both return uint8.

    ``policy`` selects the substrate, ``emulate_hw`` replay, and kernel
    schedule in one hashable value (see ``repro.engine``); per-call
    ``tile_h``/``tile_w``/``block_c``/``block_f`` override its schedule
    fields.  ``force_pallas`` / ``emulate_hw`` kwargs are deprecated shims
    onto the policy.
    """
    if requant_shift is not None or requant is not None:
        assert jnp.issubdtype(x.dtype, jnp.integer), \
            "requantization needs the integer path"
        assert requant_shift is None or requant is None, \
            "requant_shift and requant are exclusive"
    pol = policy_from_legacy(policy, emulate_hw=emulate_hw,
                             force_pallas=force_pallas,
                             caller="trim_conv2d", tile_h=tile_h,
                             tile_w=tile_w, block_c=block_c,
                             block_f=block_f)
    rq_kind = ("shift" if requant_shift is not None
               else "mult_shift" if requant is not None else None)
    out_sz = 1 if rq_kind else (4 if jnp.issubdtype(x.dtype, jnp.integer)
                                else x.dtype.itemsize)
    plan = plan_conv_layer(
        (int(x.shape[1]), int(x.shape[2])), int(x.shape[3]),
        int(w.shape[0]), int(w.shape[3]),
        stride=stride, padding=padding, groups=groups, relu=relu,
        has_bias=bias is not None, requant_kind=rq_kind,
        in_sz=x.dtype.itemsize, w_sz=w.dtype.itemsize, out_sz=out_sz,
        policy=pol)
    return run_conv2d(plan, x, w, bias, requant,
                      requant_shift=requant_shift)


@functools.partial(jax.jit, static_argnames=("substrate", "tile_l",
                                             "block_d"))
def _conv1d_run(x, w, substrate: str, tile_l: int, block_d: int):
    if substrate == "oracle":
        return ref.conv1d_causal_ref(x, w)
    return trim_conv1d_pallas(x, w, tile_l=tile_l, block_d=block_d,
                              interpret=substrate == "interpret")


def trim_conv1d(x: jax.Array, w: jax.Array, *,
                policy: Optional[ExecutionPolicy] = None,
                tile_l: int = 512, block_d: int = 128,
                force_pallas: Optional[bool] = None) -> jax.Array:
    """Causal depthwise conv. x (B,L,D), w (K,D) -> (B,L,D)."""
    pol = policy_from_legacy(policy, force_pallas=force_pallas,
                             caller="trim_conv1d")
    return _conv1d_run(x, w, pol.resolved_substrate(), tile_l, block_d)


@functools.partial(jax.jit, static_argnames=("substrate", "block_m",
                                             "block_n", "block_k"))
def _matmul_run(a, b, substrate: str, block_m: int, block_n: int,
                block_k: int):
    if substrate == "oracle":
        return ref.matmul_ref(a, b)
    return trim_matmul_pallas(a, b, block_m=block_m, block_n=block_n,
                              block_k=block_k,
                              interpret=substrate == "interpret")


def trim_matmul(a: jax.Array, b: jax.Array, *,
                policy: Optional[ExecutionPolicy] = None,
                block_m: int = 256, block_n: int = 256, block_k: int = 512,
                force_pallas: Optional[bool] = None) -> jax.Array:
    """Weight-stationary blocked matmul (the K=1 TrIM case)."""
    pol = policy_from_legacy(policy, force_pallas=force_pallas,
                             caller="trim_matmul")
    return _matmul_run(a, b, pol.resolved_substrate(), block_m, block_n,
                       block_k)
