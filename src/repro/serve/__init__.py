"""The shared serving core (DESIGN.md §8).

``ServeEngine`` (compile-once executables per (ModelPlan, batch bucket) +
the backend/device-kind-stamped executable cache the LM launcher shares) +
``BucketBatcher``/``pad_batch`` (pad-and-bucket admission with deadline
flush) + ``ServeMetrics`` (per-bucket images/sec, p50/p99, queue depth,
pad waste) + ``serve_stream`` (the open-loop driver).  Both launchers —
``repro.launch.serve_cnn`` and ``repro.launch.serve`` — run on this.
"""

from repro.serve.batching import BucketBatcher, Request, pad_batch
from repro.serve.engine import ServeEngine, serve_stream
from repro.serve.metrics import ServeMetrics

__all__ = [
    "BucketBatcher",
    "Request",
    "ServeEngine",
    "ServeMetrics",
    "pad_batch",
    "serve_stream",
]
