"""The shared serving core (DESIGN.md §8).

``Server`` (the unified facade: threaded admission with backpressure and
per-request deadlines, a dedicated flush worker with double-buffered
host<->device staging, plus the deterministic inline open loop) built
from a frozen ``ServeConfig``, over ``ServeEngine`` (compile-once
executables per (ModelPlan, batch bucket) + the backend/device-kind-
stamped executable cache the LM launcher shares).  ``BucketBatcher`` /
``pad_batch`` do pad-and-bucket admission with deadline flush and
per-request expiry; ``ServeMetrics`` carries per-bucket images/sec,
p50/p99, queue depth, pad waste, and the admission counters
(submitted/shed/expired/overlapped).  Both launchers —
``repro.launch.serve_cnn`` and ``repro.launch.serve`` — run on this.

The fault-injection plane + self-healing machinery (DESIGN.md §11)
lives in ``repro.serve.faults``: a seeded frozen ``FaultPlan`` (armed
via ``ServeConfig.faults``), the degradation ``Lane`` ladder with its
``CircuitBreaker``, the bounded-backoff ``RetryPolicy``, and the
checksummed ``PackedWire`` int5 payload.

``serve_stream`` and ``ServeEngine.for_model_plan`` are deprecation
shims over the ``Server`` facade.
"""

from repro.serve.batching import BucketBatcher, Request, pad_batch
from repro.serve.config import OVERLOAD_POLICIES, ServeConfig
from repro.serve.engine import ServeEngine, serve_stream
from repro.serve.faults import (CircuitBreaker, FaultInjector, FaultPlan,
                                InjectedFault, Lane, NonFiniteOutput,
                                PackedWire, RetryPolicy, TransientFault,
                                WorkerCrash)
from repro.serve.metrics import SCHEMA_VERSION, ServeMetrics, stamp_payload
from repro.serve.server import Server

__all__ = [
    "BucketBatcher",
    "CircuitBreaker",
    "FaultInjector",
    "FaultPlan",
    "InjectedFault",
    "Lane",
    "NonFiniteOutput",
    "OVERLOAD_POLICIES",
    "PackedWire",
    "Request",
    "RetryPolicy",
    "SCHEMA_VERSION",
    "Server",
    "ServeConfig",
    "ServeEngine",
    "ServeMetrics",
    "TransientFault",
    "WorkerCrash",
    "pad_batch",
    "serve_stream",
    "stamp_payload",
]
