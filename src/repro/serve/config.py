"""The frozen serving configuration (DESIGN.md §8).

:class:`ServeConfig` is the serving-side analogue of the engine's
``ExecutionPolicy`` (§3): one frozen, hashable value object carrying every
admission knob — bucket shapes, the deadline-flush budget, the bounded
admission queue and its overload policy, the datapath, and the optional
per-request deadline — so the :class:`~repro.serve.server.Server` facade,
both launchers, and the benchmarks all construct their serving state from
one mapping instead of threading ad-hoc kwargs.

``ServeConfig.from_args`` is THE mapping from the shared launcher CLI
flags (``launch.cli.serving_parent``: ``--buckets`` / ``--max-delay-ms`` /
``--queue-capacity`` / ``--overload`` / ``--int8`` / ``--int5``) onto a
config, the same pattern ``ExecutionPolicy.from_args`` set for the
execution flags.
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass, field
from typing import Optional, Tuple

from repro.serve.faults import FaultPlan

#: Overload policies for a full admission queue (``queue_capacity``):
#: - "block":   producers wait for queue space (backpressure; the inline
#:   open loop relieves pressure by flushing, since the caller IS the
#:   flush worker there);
#: - "shed":    reject the request immediately (``Request.status ==
#:   "shed"``, counted — the caller sees the overload instead of
#:   unbounded queueing delay);
#: - "degrade": admit, but the flush worker ships eagerly into the
#:   smallest covering bucket while over capacity (degrade-to-smaller-
#:   bucket: latency-first draining instead of waiting to fill the
#:   largest bucket or age out the deadline).
OVERLOAD_POLICIES: Tuple[str, ...] = ("block", "shed", "degrade")


@dataclass(frozen=True)
class ServeConfig:
    """Frozen, hashable "how to serve": buckets + admission behavior.

    ``queue_capacity == 0`` means unbounded (no backpressure — the PR-6
    open-loop behavior).  ``request_timeout_ms`` is the default
    per-request deadline: a request still queued past it is *expired*
    (result never computed) rather than served stale; ``None`` disables.
    """

    buckets: Tuple[int, ...] = (1, 4, 16, 64)
    max_delay_ms: float = 5.0
    queue_capacity: int = 0
    overload: str = "block"
    datapath: str = "float"
    request_timeout_ms: Optional[float] = field(default=None)
    #: The seeded chaos schedule (DESIGN.md §11); ``None`` compiles the
    #: fault plane out of the serve path entirely (zero cost when off).
    faults: Optional[FaultPlan] = field(default=None)
    #: Bounded-retry budget per batch / stage / compile attempt chain.
    retry_attempts: int = 3
    retry_backoff_ms: float = 10.0
    #: Consecutive failures per (arch, lane, bucket) before the circuit
    #: breaker trips and the engine degrades to the next lane.
    breaker_threshold: int = 3

    def __post_init__(self):
        buckets = tuple(sorted(set(int(b) for b in self.buckets)))
        if not buckets or buckets[0] < 1:
            raise ValueError(
                f"buckets must be positive ints, got {self.buckets!r}")
        object.__setattr__(self, "buckets", buckets)
        if self.overload not in OVERLOAD_POLICIES:
            raise ValueError(
                f"overload {self.overload!r} not in {OVERLOAD_POLICIES}")
        if self.datapath not in ("float", "int8", "int5"):
            raise ValueError(
                f"datapath {self.datapath!r} not in ('float', 'int8', 'int5')")
        if int(self.queue_capacity) < 0:
            raise ValueError(
                f"queue_capacity must be >= 0, got {self.queue_capacity!r}")
        object.__setattr__(self, "queue_capacity", int(self.queue_capacity))
        if self.request_timeout_ms is not None and self.request_timeout_ms <= 0:
            raise ValueError(
                f"request_timeout_ms must be > 0, got {self.request_timeout_ms!r}")
        if self.faults is not None and not isinstance(self.faults, FaultPlan):
            raise ValueError(
                f"faults must be a FaultPlan or None, got {self.faults!r}")
        if int(self.retry_attempts) < 1:
            raise ValueError(
                f"retry_attempts must be >= 1, got {self.retry_attempts!r}")
        object.__setattr__(self, "retry_attempts", int(self.retry_attempts))
        if float(self.retry_backoff_ms) < 0:
            raise ValueError(
                f"retry_backoff_ms must be >= 0, got {self.retry_backoff_ms!r}")
        if int(self.breaker_threshold) < 1:
            raise ValueError(
                f"breaker_threshold must be >= 1, got {self.breaker_threshold!r}")
        object.__setattr__(
            self, "breaker_threshold", int(self.breaker_threshold))

    @property
    def max_delay_s(self) -> float:
        return float(self.max_delay_ms) / 1e3

    @property
    def request_timeout_s(self) -> Optional[float]:
        if self.request_timeout_ms is None:
            return None
        return float(self.request_timeout_ms) / 1e3

    @classmethod
    def from_args(cls, args: argparse.Namespace, **overrides) -> "ServeConfig":
        """One place mapping the shared serving CLI flags -> ServeConfig.

        Both launchers (``serve_cnn``, ``serve``) build their config here;
        ``overrides`` lets a launcher pin fields its CLI does not expose
        (the LM launcher pins ``buckets=(batch,)``).
        """
        kw = dict(
            buckets=tuple(int(b) for b in str(args.buckets).split(",")),
            max_delay_ms=float(args.max_delay_ms),
            queue_capacity=int(args.queue_capacity),
            overload=args.overload,
            datapath=("int5" if getattr(args, "int5", False)
                      else "int8" if getattr(args, "int8", False)
                      else "float"),
        )
        if getattr(args, "request_timeout_ms", None) is not None:
            kw["request_timeout_ms"] = float(args.request_timeout_ms)
        if getattr(args, "faults", None):
            kw["faults"] = FaultPlan.parse(args.faults)
        if getattr(args, "breaker_threshold", None) is not None:
            kw["breaker_threshold"] = int(args.breaker_threshold)
        kw.update(overrides)
        return cls(**kw)
