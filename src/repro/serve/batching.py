"""Pad-and-bucket admission for the serving core (DESIGN.md §8).

Incoming requests land in one FIFO queue; batches ship on a small STATIC
set of batch shapes (the buckets), so every flush hits an executable that
was compiled ahead of time — a request stream can never retrace.  A flush
happens when (a) the queue can fill the largest bucket, or (b) the oldest
request has waited ``max_delay_s`` — the deadline flush: a half-full
bucket ships into the smallest bucket that covers it, padding the rest.

:class:`BucketBatcher` is a pure state machine over an injectable clock
(``submit`` / ``poll`` / ``next_deadline``), so admission logic is tested
deterministically with a fake clock; the async driver around it lives in
``repro.serve.engine.serve_stream``.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Request:
    """One queued inference request; the serve loop fills ``result``.

    ``status`` walks pending -> served | shed | expired | failed exactly
    once (extended conservation, DESIGN.md §11: every submitted request
    ends in exactly one terminal state — served + shed + expired +
    failed == submitted); ``done`` is set at that transition, so
    producer threads can wait on their own handles.  ``deadline_s`` is
    the absolute clock time past which queued work is expired instead
    of served stale.  ``failed`` is the Server's recovery-exhausted
    terminal state: ``error`` then carries the last failure's summary
    (the request never receives a ``result``).
    """

    rid: int
    payload: Any
    t_submit: float
    result: Any = field(default=None, repr=False)
    deadline_s: Optional[float] = None
    status: str = "pending"
    error: Optional[str] = None
    done: threading.Event = field(
        default_factory=threading.Event, repr=False, compare=False)


class BucketBatcher:
    """FIFO admission queue that ships batches on static bucket shapes."""

    def __init__(
        self,
        buckets: Sequence[int] = (1, 4, 16, 64),
        max_delay_s: float = 0.005,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, got {buckets!r}")
        self.max_delay_s = float(max_delay_s)
        self._clock = clock
        self._q: Deque[Request] = deque()
        self._lock = threading.Lock()
        self._rid = itertools.count()
        # Monotone floor for caller-supplied submit timestamps: the last
        # admitted t_submit (init: the clock at construction).
        self._last_t = float(self._clock())
        # Queued requests carrying a per-request deadline (lets
        # purge_expired skip the queue scan on deadline-free streams).
        self._n_deadlined = 0

    @property
    def depth(self) -> int:
        with self._lock:
            return len(self._q)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket covering ``n`` requests (the pad target); ``n``
        beyond the largest bucket maps to the largest (callers split)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def take_rid(self) -> int:
        """Allocate one request id from the batcher's counter (so shed
        requests that never enter the queue still get unique rids)."""
        with self._lock:
            return next(self._rid)

    def submit(self, payload: Any, now: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Enqueue one request; returns its handle (``result`` lands on it
        when the serve loop flushes the bucket that carries it).

        A caller-supplied ``now`` is CLAMPED onto the monotone clock:
        into [previous submit's t_submit, clock()].  An unclamped
        timestamp behind the queue's monotone floor would make the
        deadline flush fire early (a backdated t_submit ages out
        instantly), and one ahead of the clock would make it fire late or
        never (next_deadline sits in the future forever) — both break the
        "oldest request ships within max_delay_s" contract.
        """
        t = self._clock() if now is None else float(now)
        with self._lock:
            t = min(max(t, self._last_t), max(self._clock(), self._last_t))
            self._last_t = t
            r = Request(next(self._rid), payload, t, deadline_s=deadline_s)
            self._q.append(r)
            if deadline_s is not None:
                self._n_deadlined += 1
        return r

    def purge_expired(self, now: Optional[float] = None) -> List[Request]:
        """Remove and return queued requests whose per-request deadline
        has passed — expired work is dropped, never served stale.  The
        caller owns the terminal transition (status/done/metrics); O(1)
        when no queued request carries a deadline."""
        with self._lock:
            if self._n_deadlined == 0:
                return []
            now = self._clock() if now is None else float(now)
            expired: List[Request] = []
            kept: Deque[Request] = deque()
            while self._q:
                r = self._q.popleft()
                if r.deadline_s is not None and now > r.deadline_s:
                    expired.append(r)
                    self._n_deadlined -= 1
                else:
                    kept.append(r)
            self._q = kept
        return expired

    def next_deadline(self) -> Optional[float]:
        """Absolute clock time the oldest request must ship by (None when
        the queue is empty) — what the serve loop sleeps against."""
        with self._lock:
            if not self._q:
                return None
            return self._q[0].t_submit + self.max_delay_s

    def poll(
        self, now: Optional[float] = None, force: bool = False
    ) -> Optional[Tuple[int, List[Request]]]:
        """Take one shippable batch: (bucket, requests) or None.

        Ships the largest bucket whenever the queue can fill it; ships
        whatever is pending (into the smallest covering bucket) when the
        oldest request's deadline passed or ``force`` (stream drain).
        """
        now = self._clock() if now is None else float(now)
        with self._lock:
            n = len(self._q)
            if n == 0:
                return None
            if n >= self.buckets[-1]:
                take = self.buckets[-1]
            elif force or now - self._q[0].t_submit >= self.max_delay_s:
                take = n
            else:
                return None
            reqs = [self._q.popleft() for _ in range(take)]
            self._n_deadlined -= sum(1 for r in reqs if r.deadline_s is not None)
        return self.bucket_for(len(reqs)), reqs


def pad_batch(images: Sequence[np.ndarray], bucket: int) -> np.ndarray:
    """Stack ``len(images) <= bucket`` HWC images into a (bucket, H, W, C)
    array, zero-padding the empty slots.  Zero padding is safe because the
    served executables are batch-independent per image (the float conv
    stack and the *calibrated* int8 datapath) — asserted bit-exactly by
    tests/test_serve.py."""
    n = len(images)
    if n == 0 or n > bucket:
        raise ValueError(f"cannot pad {n} images into bucket {bucket}")
    first = np.asarray(images[0])
    out = np.zeros((bucket,) + first.shape, first.dtype)
    for i, im in enumerate(images):
        out[i] = im
    return out
