"""Pad-and-bucket admission for the serving core (DESIGN.md §8).

Incoming requests land in one FIFO queue; batches ship on a small STATIC
set of batch shapes (the buckets), so every flush hits an executable that
was compiled ahead of time — a request stream can never retrace.  A flush
happens when (a) the queue can fill the largest bucket, or (b) the oldest
request has waited ``max_delay_s`` — the deadline flush: a half-full
bucket ships into the smallest bucket that covers it, padding the rest.

:class:`BucketBatcher` is a pure state machine over an injectable clock
(``submit`` / ``poll`` / ``next_deadline``), so admission logic is tested
deterministically with a fake clock; the async driver around it lives in
``repro.serve.engine.serve_stream``.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np


@dataclass
class Request:
    """One queued inference request; the serve loop fills ``result``."""

    rid: int
    payload: Any
    t_submit: float
    result: Any = field(default=None, repr=False)


class BucketBatcher:
    """FIFO admission queue that ships batches on static bucket shapes."""

    def __init__(
        self,
        buckets: Sequence[int] = (1, 4, 16, 64),
        max_delay_s: float = 0.005,
        clock: Callable[[], float] = time.monotonic,
    ):
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        if not self.buckets or self.buckets[0] < 1:
            raise ValueError(f"buckets must be positive ints, got {buckets!r}")
        self.max_delay_s = float(max_delay_s)
        self._clock = clock
        self._q: Deque[Request] = deque()
        self._lock = threading.Lock()
        self._rid = itertools.count()

    @property
    def depth(self) -> int:
        return len(self._q)

    def bucket_for(self, n: int) -> int:
        """Smallest bucket covering ``n`` requests (the pad target); ``n``
        beyond the largest bucket maps to the largest (callers split)."""
        for b in self.buckets:
            if n <= b:
                return b
        return self.buckets[-1]

    def submit(self, payload: Any, now: Optional[float] = None) -> Request:
        """Enqueue one request; returns its handle (``result`` lands on it
        when the serve loop flushes the bucket that carries it)."""
        r = Request(next(self._rid), payload,
                    self._clock() if now is None else float(now))
        with self._lock:
            self._q.append(r)
        return r

    def next_deadline(self) -> Optional[float]:
        """Absolute clock time the oldest request must ship by (None when
        the queue is empty) — what the serve loop sleeps against."""
        with self._lock:
            if not self._q:
                return None
            return self._q[0].t_submit + self.max_delay_s

    def poll(
        self, now: Optional[float] = None, force: bool = False
    ) -> Optional[Tuple[int, List[Request]]]:
        """Take one shippable batch: (bucket, requests) or None.

        Ships the largest bucket whenever the queue can fill it; ships
        whatever is pending (into the smallest covering bucket) when the
        oldest request's deadline passed or ``force`` (stream drain).
        """
        now = self._clock() if now is None else float(now)
        with self._lock:
            n = len(self._q)
            if n == 0:
                return None
            if n >= self.buckets[-1]:
                take = self.buckets[-1]
            elif force or now - self._q[0].t_submit >= self.max_delay_s:
                take = n
            else:
                return None
            reqs = [self._q.popleft() for _ in range(take)]
        return self.bucket_for(len(reqs)), reqs


def pad_batch(images: Sequence[np.ndarray], bucket: int) -> np.ndarray:
    """Stack ``len(images) <= bucket`` HWC images into a (bucket, H, W, C)
    array, zero-padding the empty slots.  Zero padding is safe because the
    served executables are batch-independent per image (the float conv
    stack and the *calibrated* int8 datapath) — asserted bit-exactly by
    tests/test_serve.py."""
    n = len(images)
    if n == 0 or n > bucket:
        raise ValueError(f"cannot pad {n} images into bucket {bucket}")
    first = np.asarray(images[0])
    out = np.zeros((bucket,) + first.shape, first.dtype)
    for i, im in enumerate(images):
        out[i] = im
    return out
