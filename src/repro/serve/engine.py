"""The serving core: compile-once executables + the open-loop serve driver.

:class:`ServeEngine` owns an executable cache keyed like ``tuned_plans/``
entries — every key is stamped with ``backend-device_kind`` (an executable
compiled for one hardware class is meaningless on another) plus the
workload coordinates.  For CNN serving it holds one ahead-of-time compiled
executable per (ModelPlan, batch bucket), built through the engine seam
(``plan_model`` → ``ModelPlan.executable_for`` →
``jax.jit(...).lower(...).compile()``), so a request stream structurally
cannot retrace under load; the LM launcher (``repro.launch.serve``) parks
its prefill/decode step executables in the same cache through the same
compile-once registry.

:func:`serve_stream` is the open-loop driver: it admits requests at their
stream arrival times (sleeping to honor them, so queueing delay is real),
flushes buckets on size or deadline through :class:`BucketBatcher`, and
records :class:`ServeMetrics`.  Clock and sleep are injectable — the tests
drive the whole loop on a fake clock.
"""

from __future__ import annotations

import re
import time
import warnings
from typing import Any, Callable, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.serve.batching import BucketBatcher, pad_batch
from repro.serve.config import ServeConfig
from repro.serve.metrics import ServeMetrics


class ServeEngine:
    """Compile-once executable cache + bucketed CNN inference."""

    def __init__(self, name: str = "serve", buckets: Sequence[int] = (1, 4, 16, 64)):
        self.name = name
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self._execs: Dict[str, Any] = {}
        #: key -> number of times its build ran (the no-retrace ledger:
        #: every value must stay 1 for the life of the engine).
        self.compile_counts: Dict[str, int] = {}
        self._plan = None
        self._params = None
        self._datapath = "float"
        self._requant = None

    # -- the executable cache -------------------------------------------

    @staticmethod
    def executable_key(*parts: object) -> str:
        """Cache key for one executable: ``{backend}-{device_kind}`` stamp
        (same slug rule as ``tuned_plans/`` file names) + the workload
        coordinates (model/arch, datapath, bucket, …)."""
        import jax

        slug = re.sub(r"[^A-Za-z0-9_.-]+", "-", jax.devices()[0].device_kind)
        stamp = f"{jax.default_backend()}-{slug}"
        return " ".join((stamp,) + tuple(str(p) for p in parts))

    def executable(self, key: str, build: Callable[[], Any]) -> Any:
        """Compile-once registry: ``build`` runs at most once per key; every
        later call returns the cached executable."""
        if key not in self._execs:
            self._execs[key] = build()
            self.compile_counts[key] = self.compile_counts.get(key, 0) + 1
        return self._execs[key]

    # -- CNN bucket serving ---------------------------------------------

    @classmethod
    def for_model_plan(
        cls,
        plan,
        params,
        *,
        buckets: Sequence[int] = (1, 4, 16, 64),
        datapath: str = "float",
        requant: Optional[Sequence[Tuple[Any, Any]]] = None,
        warm: bool = True,
    ) -> "ServeEngine":
        """Deprecated: use ``repro.serve.Server.from_plan(plan, params,
        ServeConfig(buckets=..., datapath=...))`` — the facade owns
        admission (threading, backpressure, deadlines) on top of this
        engine.  Delegates to :meth:`build_for_plan` unchanged."""
        warnings.warn(
            "ServeEngine.for_model_plan is deprecated; construct the "
            "serving facade via repro.serve.Server.from_plan(plan, "
            "params, ServeConfig(...))",
            DeprecationWarning,
            stacklevel=2,
        )
        return cls.build_for_plan(
            plan, params, buckets=buckets, datapath=datapath,
            requant=requant, warm=warm)

    @classmethod
    def build_for_plan(
        cls,
        plan,
        params,
        *,
        buckets: Sequence[int] = (1, 4, 16, 64),
        datapath: str = "float",
        requant: Optional[Sequence[Tuple[Any, Any]]] = None,
        warm: bool = True,
    ) -> "ServeEngine":
        """A serving engine for one :class:`~repro.engine.ModelPlan`.

        ``params`` are the float params ("float"), the quantized int8
        params ("int8"), or the MSR operand+exponent params from
        ``plan.quantize_int5`` ("int5" — DESIGN.md §9.3).  Both integer
        lanes *require* calibrated ``requant`` (per-layer (mult, shift)
        pairs from ``plan.calibrate_requant`` / ``calibrate_requant_int5``):
        the uncalibrated dynamic-shift path requantizes off the whole
        batch's ``psum.max()``, so a padded bucket would change per-image
        outputs — exactly what serving must never do.  ``warm=True``
        compiles every bucket's executable up front (production default:
        all compilation happens before the first request).
        """
        if datapath not in ("float", "int8", "int5"):
            raise ValueError(
                f"datapath {datapath!r} not in ('float', 'int8', 'int5')")
        if datapath in ("int8", "int5") and requant is None:
            raise ValueError(
                f"{datapath} serving requires calibrated requant pairs: the "
                "dynamic (uncalibrated) requant path depends on batch "
                "composition and cannot serve padded buckets bit-faithfully"
            )
        eng = cls(name=f"{plan.cfg.name}.{datapath}", buckets=buckets)
        eng._plan = plan
        eng._params = params
        eng._datapath = datapath
        eng._requant = None if requant is None else [tuple(p) for p in requant]
        if warm:
            eng.warmup()
        return eng

    @property
    def plan(self):
        """The base (N=1) ModelPlan this engine serves."""
        return self._plan

    def bucket_plan(self, bucket: int):
        """The ModelPlan for one bucket: same cfg + policy, planned at the
        bucket's batch size so batch-specific autotuner winners apply
        (tuned-plan cache keys carry the batch axis)."""
        from repro.engine import plan_model

        p = self._plan
        return plan_model(
            p.cfg, p.policy, c_in=p.layers[0].c_in, batch=int(bucket)
        )

    def _bucket_exec(self, bucket: int):
        plan = self.bucket_plan(bucket)
        key = self.executable_key(plan.cfg.name, self._datapath, f"n{bucket}")
        return self.executable(
            key, lambda: plan.executable_for(int(bucket), datapath=self._datapath)
        )

    def warmup(self) -> None:
        """Compile every bucket's executable (idempotent)."""
        for b in self.buckets:
            self._bucket_exec(b)

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"batch {n} exceeds the largest bucket {self.buckets[-1]}")

    def stage(self, images: np.ndarray):
        """Host->device staging for one padded batch: ``jax.device_put``
        dispatched now, so a caller that stages batch k+1 while batch k's
        executable runs overlaps the transfer with compute (the Server
        flush worker's double buffer).  The staged buffer is what the
        donated-input executables consume in place on backends that
        implement donation (``execute.executable_for``)."""
        import jax

        return jax.device_put(images)

    def run_bucket(self, bucket: int, images):
        """Run one already-padded (bucket, H, W, C) batch (host array or
        a ``stage``-d device array); returns the raw device output
        (async — caller materializes)."""
        ex = self._bucket_exec(bucket)
        if self._datapath == "float":
            return ex(self._params, images)
        return ex(self._params, images, self._requant)

    def infer(self, images: np.ndarray) -> np.ndarray:
        """Pad ``n <= max(buckets)`` images into their bucket, run, slice
        the padding back off — the synchronous single-shot entry point."""
        n = int(images.shape[0])
        b = self.bucket_for(n)
        out = self.run_bucket(b, pad_batch(list(images), b))
        return np.asarray(out)[:n]


def serve_stream(
    engine: ServeEngine,
    stream: Iterable,
    *,
    max_delay_s: float = 0.005,
    clock: Callable[[], float] = time.monotonic,
    sleep: Callable[[float], None] = time.sleep,
    batcher: Optional[BucketBatcher] = None,
    metrics: Optional[ServeMetrics] = None,
) -> ServeMetrics:
    """Deprecated: use ``repro.serve.Server(engine, ServeConfig(...))
    .run_stream(stream)``.

    The single-threaded open loop this function used to implement now
    lives (verbatim semantics) in ``Server.run_stream(stream,
    producers=0)``; this shim builds a Server around ``engine`` with the
    matching config and delegates, so metrics output is identical
    (asserted by tests/test_serve.py).
    """
    warnings.warn(
        "serve_stream is deprecated; use repro.serve.Server(engine, "
        "ServeConfig(...)).run_stream(stream)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.serve.server import Server

    cfg = ServeConfig(
        buckets=engine.buckets,
        max_delay_ms=max_delay_s * 1e3,
        datapath=engine._datapath,
    )
    srv = Server(engine, cfg, clock=clock, sleep=sleep, batcher=batcher,
                 metrics=metrics)
    return srv.run_stream(stream)
