"""The serving core: compile-once executables + the open-loop serve driver.

:class:`ServeEngine` owns an executable cache keyed like ``tuned_plans/``
entries — every key is stamped with ``backend-device_kind`` (an executable
compiled for one hardware class is meaningless on another) plus the
workload coordinates.  For CNN serving it holds one ahead-of-time compiled
executable per (ModelPlan, batch bucket), built through the engine seam
(``plan_model`` → ``ModelPlan.executable_for`` →
``jax.jit(...).lower(...).compile()``), so a request stream structurally
cannot retrace under load; the LM launcher (``repro.launch.serve``) parks
its prefill/decode step executables in the same cache through the same
compile-once registry.

:func:`serve_stream` is the open-loop driver: it admits requests at their
stream arrival times (sleeping to honor them, so queueing delay is real),
flushes buckets on size or deadline through :class:`BucketBatcher`, and
records :class:`ServeMetrics`.  Clock and sleep are injectable — the tests
drive the whole loop on a fake clock.
"""

from __future__ import annotations

import re
import time
from typing import Any, Callable, Dict, Iterable, Optional, Sequence, Tuple

import numpy as np

from repro.serve.batching import BucketBatcher, pad_batch
from repro.serve.metrics import ServeMetrics


class ServeEngine:
    """Compile-once executable cache + bucketed CNN inference."""

    def __init__(self, name: str = "serve", buckets: Sequence[int] = (1, 4, 16, 64)):
        self.name = name
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self._execs: Dict[str, Any] = {}
        #: key -> number of times its build ran (the no-retrace ledger:
        #: every value must stay 1 for the life of the engine).
        self.compile_counts: Dict[str, int] = {}
        self._plan = None
        self._params = None
        self._datapath = "float"
        self._requant = None

    # -- the executable cache -------------------------------------------

    @staticmethod
    def executable_key(*parts: object) -> str:
        """Cache key for one executable: ``{backend}-{device_kind}`` stamp
        (same slug rule as ``tuned_plans/`` file names) + the workload
        coordinates (model/arch, datapath, bucket, …)."""
        import jax

        slug = re.sub(r"[^A-Za-z0-9_.-]+", "-", jax.devices()[0].device_kind)
        stamp = f"{jax.default_backend()}-{slug}"
        return " ".join((stamp,) + tuple(str(p) for p in parts))

    def executable(self, key: str, build: Callable[[], Any]) -> Any:
        """Compile-once registry: ``build`` runs at most once per key; every
        later call returns the cached executable."""
        if key not in self._execs:
            self._execs[key] = build()
            self.compile_counts[key] = self.compile_counts.get(key, 0) + 1
        return self._execs[key]

    # -- CNN bucket serving ---------------------------------------------

    @classmethod
    def for_model_plan(
        cls,
        plan,
        params,
        *,
        buckets: Sequence[int] = (1, 4, 16, 64),
        datapath: str = "float",
        requant: Optional[Sequence[Tuple[Any, Any]]] = None,
        warm: bool = True,
    ) -> "ServeEngine":
        """A serving engine for one :class:`~repro.engine.ModelPlan`.

        ``params`` are the float params ("float") or the quantized int8
        params ("int8").  The int8 lane *requires* calibrated ``requant``
        (per-layer (mult, shift) pairs from ``plan.calibrate_requant``):
        the uncalibrated dynamic-shift path requantizes off the whole
        batch's ``psum.max()``, so a padded bucket would change per-image
        outputs — exactly what serving must never do.  ``warm=True``
        compiles every bucket's executable up front (production default:
        all compilation happens before the first request).
        """
        if datapath not in ("float", "int8"):
            raise ValueError(f"datapath {datapath!r} not in ('float', 'int8')")
        if datapath == "int8" and requant is None:
            raise ValueError(
                "int8 serving requires calibrated requant pairs: the dynamic "
                "(uncalibrated) requant path depends on batch composition and "
                "cannot serve padded buckets bit-faithfully"
            )
        eng = cls(name=f"{plan.cfg.name}.{datapath}", buckets=buckets)
        eng._plan = plan
        eng._params = params
        eng._datapath = datapath
        eng._requant = None if requant is None else [tuple(p) for p in requant]
        if warm:
            eng.warmup()
        return eng

    @property
    def plan(self):
        """The base (N=1) ModelPlan this engine serves."""
        return self._plan

    def bucket_plan(self, bucket: int):
        """The ModelPlan for one bucket: same cfg + policy, planned at the
        bucket's batch size so batch-specific autotuner winners apply
        (tuned-plan cache keys carry the batch axis)."""
        from repro.engine import plan_model

        p = self._plan
        return plan_model(
            p.cfg, p.policy, c_in=p.layers[0].c_in, batch=int(bucket)
        )

    def _bucket_exec(self, bucket: int):
        plan = self.bucket_plan(bucket)
        key = self.executable_key(plan.cfg.name, self._datapath, f"n{bucket}")
        return self.executable(
            key, lambda: plan.executable_for(int(bucket), datapath=self._datapath)
        )

    def warmup(self) -> None:
        """Compile every bucket's executable (idempotent)."""
        for b in self.buckets:
            self._bucket_exec(b)

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"batch {n} exceeds the largest bucket {self.buckets[-1]}")

    def run_bucket(self, bucket: int, images: np.ndarray):
        """Run one already-padded (bucket, H, W, C) batch; returns the raw
        device output (async — caller materializes)."""
        ex = self._bucket_exec(bucket)
        if self._datapath == "float":
            return ex(self._params, images)
        return ex(self._params, images, self._requant)

    def infer(self, images: np.ndarray) -> np.ndarray:
        """Pad ``n <= max(buckets)`` images into their bucket, run, slice
        the padding back off — the synchronous single-shot entry point."""
        n = int(images.shape[0])
        b = self.bucket_for(n)
        out = self.run_bucket(b, pad_batch(list(images), b))
        return np.asarray(out)[:n]


def serve_stream(
    engine: ServeEngine,
    stream: Iterable,
    *,
    max_delay_s: float = 0.005,
    clock: Callable[[], float] = time.monotonic,
    sleep: Callable[[float], None] = time.sleep,
    batcher: Optional[BucketBatcher] = None,
    metrics: Optional[ServeMetrics] = None,
) -> ServeMetrics:
    """Serve an arrival-timed request stream through ``engine``.

    ``stream`` yields ``(t_arrival_s, image, ...)`` with arrivals as
    offsets from loop start (``data.pipeline.SyntheticRequestStream``).
    The loop sleeps until each arrival (flushing deadline-expired buckets
    while it waits), submits, flushes any size-triggered batches, and
    drains the queue at stream end.  Results land on each
    :class:`~repro.serve.batching.Request` (``r.result``); returns the
    filled :class:`ServeMetrics` (``wall_s`` set).
    """
    batcher = batcher or BucketBatcher(
        engine.buckets, max_delay_s=max_delay_s, clock=clock
    )
    metrics = metrics or ServeMetrics(engine.buckets)
    t0 = clock()
    requests = []

    def flush(force: bool = False) -> None:
        while True:
            got = batcher.poll(force=force)
            if got is None:
                return
            bucket, reqs = got
            depth = batcher.depth
            t_a = clock()
            out = np.asarray(
                engine.run_bucket(bucket, pad_batch([r.payload for r in reqs],
                                                    bucket))
            )
            t_b = clock()
            for i, r in enumerate(reqs):
                r.result = out[i]
            metrics.record_flush(
                bucket,
                len(reqs),
                batch_s=t_b - t_a,
                latencies_s=[t_b - r.t_submit for r in reqs],
                queue_depth=depth,
            )

    for item in stream:
        t_arr, payload = float(item[0]), item[1]
        while clock() - t0 < t_arr:
            deadline = batcher.next_deadline()
            now = clock()
            if deadline is not None and deadline <= now:
                flush()
                continue
            wait = t0 + t_arr - now
            if deadline is not None:
                wait = min(wait, deadline - now)
            sleep(max(wait, 0.0))
        requests.append(batcher.submit(payload))
        flush()
    flush(force=True)
    metrics.wall_s = clock() - t0
    metrics.requests = requests
    return metrics
