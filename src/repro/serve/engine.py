"""The serving core: compile-once executables + the open-loop serve driver.

:class:`ServeEngine` owns an executable cache keyed like ``tuned_plans/``
entries — every key is stamped with ``backend-device_kind`` (an executable
compiled for one hardware class is meaningless on another) plus the
workload coordinates.  For CNN serving it holds one ahead-of-time compiled
executable per (ModelPlan, batch bucket), built through the engine seam
(``plan_model`` → ``ModelPlan.executable_for`` →
``jax.jit(...).lower(...).compile()``), so a request stream structurally
cannot retrace under load; the LM launcher (``repro.launch.serve``) parks
its prefill/decode step executables in the same cache through the same
compile-once registry.

:func:`serve_stream` is the open-loop driver: it admits requests at their
stream arrival times (sleeping to honor them, so queueing delay is real),
flushes buckets on size or deadline through :class:`BucketBatcher`, and
records :class:`ServeMetrics`.  Clock and sleep are injectable — the tests
drive the whole loop on a fake clock.
"""

from __future__ import annotations

import dataclasses
import re
import time
import warnings
from typing import (Any, Callable, Dict, Iterable, List, Optional, Sequence,
                    Tuple)

import numpy as np

from repro.serve.batching import BucketBatcher, pad_batch
from repro.serve.config import ServeConfig
from repro.serve.faults import (CircuitBreaker, FaultInjector, Lane,
                                PackedWire, RetryPolicy, with_retries)
from repro.serve.metrics import ServeMetrics


class ServeEngine:
    """Compile-once executable cache + bucketed CNN inference."""

    def __init__(self, name: str = "serve", buckets: Sequence[int] = (1, 4, 16, 64)):
        self.name = name
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self._execs: Dict[str, Any] = {}
        #: key -> number of times its build ran (the no-retrace ledger:
        #: every value must stay 1 for the life of the engine).
        self.compile_counts: Dict[str, int] = {}
        self._plan = None
        self._params = None
        self._datapath = "float"
        self._requant = None
        # -- resilience plane (DESIGN.md §11); inert until installed ----
        #: degradation order: lanes[0] is the primary datapath, later
        #: entries are what the circuit breaker falls back to.
        self.lanes: List[Lane] = []
        self._active: Dict[int, int] = {}  # bucket -> active lane index
        self.breaker = CircuitBreaker()
        self.injector: Optional[FaultInjector] = None
        self.wire: Optional[PackedWire] = None
        self.retry = RetryPolicy()
        self.on_retry: Optional[Callable[[], None]] = None
        self._retry_sleep: Callable[[float], None] = time.sleep
        #: degradation events, in order (stamped into serve JSON headers).
        self.degradations: List[dict] = []
        self._wire_params = None
        self._wire_version = -1

    # -- the executable cache -------------------------------------------

    @staticmethod
    def executable_key(*parts: object) -> str:
        """Cache key for one executable: ``{backend}-{device_kind}`` stamp
        (same slug rule as ``tuned_plans/`` file names) + the workload
        coordinates (model/arch, datapath, bucket, …)."""
        import jax

        slug = re.sub(r"[^A-Za-z0-9_.-]+", "-", jax.devices()[0].device_kind)
        stamp = f"{jax.default_backend()}-{slug}"
        return " ".join((stamp,) + tuple(str(p) for p in parts))

    def executable(self, key: str, build: Callable[[], Any]) -> Any:
        """Compile-once registry: ``build`` runs at most once per key; every
        later call returns the cached executable."""
        if key not in self._execs:
            self._execs[key] = build()
            self.compile_counts[key] = self.compile_counts.get(key, 0) + 1
        return self._execs[key]

    # -- CNN bucket serving ---------------------------------------------

    @classmethod
    def for_model_plan(
        cls,
        plan,
        params,
        *,
        buckets: Sequence[int] = (1, 4, 16, 64),
        datapath: str = "float",
        requant: Optional[Sequence[Tuple[Any, Any]]] = None,
        warm: bool = True,
    ) -> "ServeEngine":
        """Deprecated: use ``repro.serve.Server.from_plan(plan, params,
        ServeConfig(buckets=..., datapath=...))`` — the facade owns
        admission (threading, backpressure, deadlines) on top of this
        engine.  Delegates to :meth:`build_for_plan` unchanged."""
        warnings.warn(
            "ServeEngine.for_model_plan is deprecated; construct the "
            "serving facade via repro.serve.Server.from_plan(plan, "
            "params, ServeConfig(...))",
            DeprecationWarning,
            stacklevel=2,
        )
        return cls.build_for_plan(
            plan, params, buckets=buckets, datapath=datapath,
            requant=requant, warm=warm)

    @classmethod
    def build_for_plan(
        cls,
        plan,
        params,
        *,
        buckets: Sequence[int] = (1, 4, 16, 64),
        datapath: str = "float",
        requant: Optional[Sequence[Tuple[Any, Any]]] = None,
        warm: bool = True,
        fallbacks: Optional[Sequence[Lane]] = None,
        wire: Optional[PackedWire] = None,
    ) -> "ServeEngine":
        """A serving engine for one :class:`~repro.engine.ModelPlan`.

        ``params`` are the float params ("float"), the quantized int8
        params ("int8"), or the MSR operand+exponent params from
        ``plan.quantize_int5`` ("int5" — DESIGN.md §9.3).  Both integer
        lanes *require* calibrated ``requant`` (per-layer (mult, shift)
        pairs from ``plan.calibrate_requant`` / ``calibrate_requant_int5``):
        the uncalibrated dynamic-shift path requantizes off the whole
        batch's ``psum.max()``, so a padded bucket would change per-image
        outputs — exactly what serving must never do.  ``warm=True``
        compiles every bucket's executable up front (production default:
        all compilation happens before the first request).

        ``fallbacks`` registers the graceful-degradation ladder
        (DESIGN.md §11): extra :class:`~repro.serve.faults.Lane` entries,
        in degradation order, that the circuit breaker advances through
        after repeated executable failures or non-finite outputs.  Every
        lane is warmed alongside the primary, so degradation at serve
        time is a dictionary lookup, never a compile.  ``wire`` arms the
        packed int5 integrity check: the primary lane's weights are
        materialized from the checksummed 5-bit wire payload instead of
        the passed params (verified on every re-read).
        """
        if datapath not in ("float", "int8", "int5"):
            raise ValueError(
                f"datapath {datapath!r} not in ('float', 'int8', 'int5')")
        if datapath in ("int8", "int5") and requant is None:
            raise ValueError(
                f"{datapath} serving requires calibrated requant pairs: the "
                "dynamic (uncalibrated) requant path depends on batch "
                "composition and cannot serve padded buckets bit-faithfully"
            )
        eng = cls(name=f"{plan.cfg.name}.{datapath}", buckets=buckets)
        eng._plan = plan
        eng._params = params
        eng._datapath = datapath
        eng._requant = None if requant is None else [tuple(p) for p in requant]
        eng.lanes = [Lane(datapath, datapath, params, eng._requant)]
        for lane in (fallbacks or ()):
            if lane.name in {x.name for x in eng.lanes}:
                raise ValueError(f"duplicate lane name {lane.name!r}")
            eng.lanes.append(lane)
        if wire is not None:
            if datapath != "int5":
                raise ValueError(
                    "a PackedWire payload only backs the int5 datapath")
            eng.wire = wire
        if warm:
            eng.warmup()
        return eng

    @property
    def plan(self):
        """The base (N=1) ModelPlan this engine serves."""
        return self._plan

    def bucket_plan(self, bucket: int):
        """The ModelPlan for one bucket: same cfg + policy, planned at the
        bucket's batch size so batch-specific autotuner winners apply
        (tuned-plan cache keys carry the batch axis)."""
        from repro.engine import plan_model

        p = self._plan
        return plan_model(
            p.cfg, p.policy, c_in=p.layers[0].c_in, batch=int(bucket)
        )

    # -- lanes + the circuit breaker (DESIGN.md §11) --------------------

    def _ensure_lanes(self) -> List[Lane]:
        if not self.lanes and self._plan is not None:
            self.lanes = [
                Lane(self._datapath, self._datapath, self._params,
                     self._requant)
            ]
        return self.lanes

    def active_lane(self, bucket: int) -> int:
        """Index of the lane currently serving ``bucket`` (0 = primary;
        advanced only by circuit-breaker trips, never backwards)."""
        return self._active.get(int(bucket), 0)

    def lane_of(self, bucket: int) -> Lane:
        return self._ensure_lanes()[self.active_lane(bucket)]

    def _lane_plan(self, lane: Lane, bucket: int):
        from repro.engine import plan_model

        p = self._plan
        policy = p.policy
        if lane.substrate is not None:
            policy = dataclasses.replace(policy, substrate=lane.substrate)
        return plan_model(p.cfg, policy, c_in=p.layers[0].c_in,
                          batch=int(bucket))

    def _lane_exec(self, lane: Lane, bucket: int):
        plan = self._lane_plan(lane, bucket)
        key = self.executable_key(plan.cfg.name, lane.name, f"n{bucket}")

        def build():
            # bounded retry absorbs transiently rejected compiles (the
            # injected COMPILE_FAULT_HOOK fires inside executable_for,
            # which never caches an attempt that raised)
            return with_retries(
                lambda: plan.executable_for(int(bucket),
                                            datapath=lane.datapath),
                self.retry, sleep=self._retry_sleep, salt=key,
                on_retry=self._count_retry)

        return self.executable(key, build)

    def _count_retry(self, attempt: int, err: Exception) -> None:
        if self.on_retry is not None:
            self.on_retry()

    def _bucket_exec(self, bucket: int):
        return self._lane_exec(self.lane_of(bucket), bucket)

    def _lane_params(self, lane_idx: int, lane: Lane):
        """The lane's runtime params; the primary int5 lane re-reads them
        from the checksummed wire payload whenever its version moves (the
        integrity gate a bit-flip cannot get past)."""
        if lane_idx == 0 and self.wire is not None:
            if self._wire_params is None \
                    or self._wire_version != self.wire.version:
                self._wire_params = self.wire.qparams()
                self._wire_version = self.wire.version
            return self._wire_params
        return lane.params

    def breaker_key(self, bucket: int) -> str:
        """The circuit breaker's (arch, lane, bucket) coordinate."""
        lane = self.lane_of(bucket)
        arch = self._plan.cfg.name if self._plan is not None else self.name
        return f"{arch} {lane.name} n{int(bucket)}"

    def note_failure(self, bucket: int) -> Optional[dict]:
        """Feed one batch failure (executable exception, non-finite
        output, worker crash mid-batch) to the breaker.  On trip:
        re-verify the wire payload (restoring from the fp32 master if it
        was flipped) and degrade the bucket to the next lane.  Returns
        the degradation event dict, or None when nothing degraded."""
        bucket = int(bucket)
        key = self.breaker_key(bucket)
        if not self.breaker.failure(key):
            return None
        if self.wire is not None:
            self.wire.verify_or_restore()
        idx = self.active_lane(bucket)
        lanes = self._ensure_lanes()
        if idx + 1 >= len(lanes):
            return None  # tripped, but no lane left to degrade to
        self._active[bucket] = idx + 1
        ev = {"key": key, "bucket": bucket,
              "from": lanes[idx].name, "to": lanes[idx + 1].name}
        self.degradations.append(ev)
        return ev

    def note_success(self, bucket: int) -> None:
        self.breaker.success(self.breaker_key(int(bucket)))

    def install_resilience(
        self,
        *,
        injector: Optional[FaultInjector] = None,
        retry: Optional[RetryPolicy] = None,
        breaker_threshold: Optional[int] = None,
        sleep: Optional[Callable[[float], None]] = None,
        on_retry: Optional[Callable[[], None]] = None,
    ) -> None:
        """Arm the fault/recovery plane (called by ``Server.__init__``
        from its ServeConfig).  Binds the injector to the wire payload so
        planned bit-flips land on the live bytes, and routes retry
        sleeps through the server's (possibly fake) clock."""
        if injector is not None:
            self.injector = injector
            injector.wire = self.wire
        if retry is not None:
            self.retry = retry
        if breaker_threshold is not None:
            self.breaker.threshold = max(1, int(breaker_threshold))
        if sleep is not None:
            self._retry_sleep = sleep
        if on_retry is not None:
            self.on_retry = on_retry

    def warmup(self) -> None:
        """Compile every lane x bucket executable (idempotent), under the
        bounded-retry policy so a transiently rejected compile does not
        abort warmup; verify the wire payload's checksums if armed."""
        from repro.engine import execute

        if self.injector is not None:
            execute.COMPILE_FAULT_HOOK = self.injector.fire_compile
        try:
            for lane in self._ensure_lanes():
                for b in self.buckets:
                    self._lane_exec(lane, b)
        finally:
            execute.COMPILE_FAULT_HOOK = None
        if self.wire is not None:
            self.wire.verify_or_restore()

    def bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"batch {n} exceeds the largest bucket {self.buckets[-1]}")

    def stage(self, images: np.ndarray):
        """Host->device staging for one padded batch: ``jax.device_put``
        dispatched now, so a caller that stages batch k+1 while batch k's
        executable runs overlaps the transfer with compute (the Server
        flush worker's double buffer).  The staged buffer is what the
        donated-input executables consume in place on backends that
        implement donation (``execute.executable_for``)."""
        import jax

        if self.injector is not None:
            self.injector.fire_stage()
        return jax.device_put(images)

    def run_bucket(self, bucket: int, images):
        """Run one already-padded (bucket, H, W, C) batch (host array or
        a ``stage``-d device array) on the bucket's *active lane*;
        returns the raw device output (async — caller materializes)."""
        lane_idx = self.active_lane(bucket)
        lane = self._ensure_lanes()[lane_idx]
        if self.injector is not None:
            self.injector.fire_exec(lane_idx)
        ex = self._lane_exec(lane, bucket)
        params = self._lane_params(lane_idx, lane)
        if lane.datapath == "float":
            return ex(params, images)
        return ex(params, images, lane.requant)

    def infer(self, images: np.ndarray) -> np.ndarray:
        """Pad ``n <= max(buckets)`` images into their bucket, run, slice
        the padding back off — the synchronous single-shot entry point."""
        n = int(images.shape[0])
        b = self.bucket_for(n)
        out = self.run_bucket(b, pad_batch(list(images), b))
        return np.asarray(out)[:n]


def serve_stream(
    engine: ServeEngine,
    stream: Iterable,
    *,
    max_delay_s: float = 0.005,
    clock: Callable[[], float] = time.monotonic,
    sleep: Callable[[float], None] = time.sleep,
    batcher: Optional[BucketBatcher] = None,
    metrics: Optional[ServeMetrics] = None,
) -> ServeMetrics:
    """Deprecated: use ``repro.serve.Server(engine, ServeConfig(...))
    .run_stream(stream)``.

    The single-threaded open loop this function used to implement now
    lives (verbatim semantics) in ``Server.run_stream(stream,
    producers=0)``; this shim builds a Server around ``engine`` with the
    matching config and delegates, so metrics output is identical
    (asserted by tests/test_serve.py).
    """
    warnings.warn(
        "serve_stream is deprecated; use repro.serve.Server(engine, "
        "ServeConfig(...)).run_stream(stream)",
        DeprecationWarning,
        stacklevel=2,
    )
    from repro.serve.server import Server

    cfg = ServeConfig(
        buckets=engine.buckets,
        max_delay_ms=max_delay_s * 1e3,
        datapath=engine._datapath,
    )
    srv = Server(engine, cfg, clock=clock, sleep=sleep, batcher=batcher,
                 metrics=metrics)
    return srv.run_stream(stream)
