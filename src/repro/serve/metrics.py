"""Serving metrics: per-bucket throughput, latency percentiles, pad waste.

The TrIM paper's 453.6 GOPS peak (PAPER.md §V) is a sustained-load number,
and the companion dataflow paper frames throughput-per-access as the metric
that matters — both only measurable under load.  These are the software
counters that make the reproduction's serving claims concrete: per-bucket
images/sec (real images over engine wall-clock), request latency p50/p99
(submit → result materialized), queue depth at flush time, and the
pad-waste fraction the static buckets cost (padded slots / bucket slots).

Snapshots are plain dicts → JSON: ``BENCH_serve.json`` records and the CI
serve-smoke artifact both come from :meth:`ServeMetrics.snapshot`.
"""

from __future__ import annotations

import json
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

#: Schema version stamped on every serve-metrics / BENCH_serve* JSON
#: artifact (``stamp_payload``).  History:
#:   1 — implicit (PR 6): no version field; device stamp ad-hoc per writer.
#:   2 — ``schema_version`` + top-level ``backend``/``device_kind`` header
#:       (same fields the BENCH kernel artifacts carry), admission
#:       counters (submitted/shed/expired/overlapped) in totals.
SCHEMA_VERSION = 2


def device_stamp() -> dict:
    """The ``backend``/``device_kind`` pair every serve artifact carries
    (same stamp rule as the BENCH_kernels records and tuned_plans keys)."""
    import jax

    return {"backend": jax.default_backend(),
            "device_kind": jax.devices()[0].device_kind}


def stamp_payload(payload: Optional[dict] = None) -> dict:
    """THE one place serve JSON writers get their header: schema_version +
    backend/device_kind, then the caller's fields.  ``ServeMetrics.write``
    (launcher metrics artifacts) and ``benchmarks/run.py``'s
    BENCH_serve.json writer both build on this, so ``benchmarks/compare``
    can machine-scope serve metrics off the header without sniffing
    records."""
    out: dict = {"schema_version": SCHEMA_VERSION}
    out.update(device_stamp())
    out.update(payload or {})
    return out


@dataclass
class _BucketStats:
    flushes: int = 0
    images: int = 0
    padded: int = 0
    batch_s: List[float] = field(default_factory=list)
    latencies_s: List[float] = field(default_factory=list)
    queue_depths: List[int] = field(default_factory=list)


def _pctile(xs: Sequence[float], q: float) -> float:
    import numpy as np

    return float(np.percentile(np.asarray(xs, dtype=float), q)) if xs else 0.0


class ServeMetrics:
    """Accumulates per-bucket flush observations; snapshots to JSON."""

    def __init__(self, buckets: Sequence[int]):
        self.buckets = tuple(sorted(set(int(b) for b in buckets)))
        self._b: Dict[int, _BucketStats] = {b: _BucketStats() for b in self.buckets}
        self.wall_s: Optional[float] = None  # set by the serve loop
        # Admission counters (conservation: submitted == served + shed +
        # expired at drain).  Incremented from producer threads AND the
        # flush worker, so they take the lock — += is not atomic across
        # bytecodes.
        self._lock = threading.Lock()
        self.submitted = 0
        self.shed = 0
        self.expired = 0
        #: flushes whose host->device staging overlapped a prior
        #: in-flight bucket's compute (the double-buffering win).
        self.overlapped = 0
        # Resilience counters (DESIGN.md §11).  Extended conservation:
        # served + shed + expired + failed == submitted.  They surface in
        # snapshot() only when nonzero, so fault-off snapshots stay
        # byte-identical to the fault-plane-free schema.
        self.failed = 0
        self.retried = 0
        self.degraded = 0
        self.worker_restarts = 0
        self.integrity_restored = 0
        #: breaker key -> lane name it degraded to (insertion-ordered).
        self.degraded_lanes: Dict[str, str] = {}

    def record_failed(self, n: int = 1) -> None:
        with self._lock:
            self.failed += int(n)

    def record_retried(self, n: int = 1) -> None:
        with self._lock:
            self.retried += int(n)

    def record_degraded(self, key: str, to_lane: str) -> None:
        with self._lock:
            self.degraded += 1
            self.degraded_lanes[str(key)] = str(to_lane)

    def record_worker_restart(self) -> None:
        with self._lock:
            self.worker_restarts += 1

    def record_integrity_restored(self, n: int = 1) -> None:
        with self._lock:
            self.integrity_restored += int(n)

    def record_submit(self) -> None:
        with self._lock:
            self.submitted += 1

    def record_shed(self) -> None:
        with self._lock:
            self.shed += 1

    def record_expired(self, n: int = 1) -> None:
        with self._lock:
            self.expired += int(n)

    def record_overlap(self) -> None:
        with self._lock:
            self.overlapped += 1

    def record_flush(
        self,
        bucket: int,
        n_real: int,
        *,
        batch_s: float,
        latencies_s: Sequence[float],
        queue_depth: int = 0,
    ) -> None:
        """One shipped batch: ``n_real`` requests padded into ``bucket``
        slots, ``batch_s`` of engine wall-clock, per-request end-to-end
        latencies, and the queue depth left behind at flush time."""
        with self._lock:
            st = self._b.setdefault(int(bucket), _BucketStats())
            st.flushes += 1
            st.images += int(n_real)
            st.padded += int(bucket) - int(n_real)
            st.batch_s.append(float(batch_s))
            st.latencies_s.extend(float(x) for x in latencies_s)
            st.queue_depths.append(int(queue_depth))

    @property
    def total_images(self) -> int:
        return sum(st.images for st in self._b.values())

    def flushes(self, bucket: int) -> int:
        st = self._b.get(int(bucket))
        return st.flushes if st else 0

    def snapshot(self) -> dict:
        """The full metrics record (what the launchers/benchmarks emit)."""
        per_bucket = {}
        all_lat: List[float] = []
        total_slots = 0
        total_padded = 0
        busy_s = 0.0
        for b in sorted(self._b):
            st = self._b[b]
            busy = sum(st.batch_s)
            busy_s += busy
            total_slots += st.flushes * b
            total_padded += st.padded
            all_lat.extend(st.latencies_s)
            per_bucket[str(b)] = {
                "flushes": st.flushes,
                "images": st.images,
                "images_per_s": round(st.images / busy, 1) if busy else 0.0,
                "p50_ms": round(_pctile(st.latencies_s, 50) * 1e3, 3),
                "p99_ms": round(_pctile(st.latencies_s, 99) * 1e3, 3),
                "pad_waste": round(st.padded / (st.flushes * b), 4)
                if st.flushes
                else 0.0,
                "queue_depth_max": max(st.queue_depths, default=0),
            }
        totals = {
            "images": self.total_images,
            "flushes": sum(st.flushes for st in self._b.values()),
            "pad_waste": round(total_padded / total_slots, 4) if total_slots else 0.0,
            "p50_ms": round(_pctile(all_lat, 50) * 1e3, 3),
            "p99_ms": round(_pctile(all_lat, 99) * 1e3, 3),
            "busy_s": round(busy_s, 4),
            # admission accounting (served == images; conservation:
            # submitted == served + shed + expired once drained)
            "submitted": self.submitted,
            "shed": self.shed,
            "expired": self.expired,
            "overlapped": self.overlapped,
        }
        # Fault-plane ledger: keyed in only when engaged, so a fault-free
        # run's snapshot is byte-identical to the pre-§11 schema.
        for k in ("failed", "retried", "degraded", "worker_restarts",
                  "integrity_restored"):
            v = getattr(self, k)
            if v:
                totals[k] = v
        out_extra = {}
        if self.degraded_lanes:
            out_extra["degraded_lanes"] = dict(self.degraded_lanes)
        if self.wall_s:
            totals["wall_s"] = round(self.wall_s, 4)
            totals["images_per_s"] = round(self.total_images / self.wall_s, 1)
        out = {"buckets": list(self.buckets), "per_bucket": per_bucket,
               "totals": totals}
        out.update(out_extra)
        return out

    def write(self, path: str, extra: Optional[dict] = None) -> dict:
        """Write ``snapshot()`` (plus ``extra`` stamp fields) as JSON,
        under the serve schema header (``stamp_payload``: schema_version +
        backend/device_kind — callers no longer stamp those by hand)."""
        payload = stamp_payload(extra)
        payload["metrics"] = self.snapshot()
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        return payload
