"""The fault-injection plane + the self-healing primitives (DESIGN.md §11).

Serving hardware fails in ways a clean-room test stream never exercises:
a host thread dies mid-batch, a compile is rejected under memory
pressure, a BRAM soft error flips a bit of the packed int5 weight image
(exactly the dense wire format DESIGN.md §9.3 ships), a kernel returns
NaN.  This module makes every one of those failures *injectable,
deterministic and seeded*, so the recovery machinery is tested rather
than hoped for:

- :class:`FaultPlan` — a frozen, hashable description of which faults
  fire and how many times (carried on ``ServeConfig.faults``; parsed
  from the ``--faults`` CLI spec).  With ``faults=None`` the entire
  plane is compiled out of the serve path (zero cost when off).
- :class:`FaultInjector` — the armed runtime: thread-safe fire-budget
  counters consumed at the five injection sites (stage, compile,
  execute, worker, output) plus latency spikes and wire bit-flips.
- :class:`RetryPolicy` — bounded exponential backoff with deterministic
  jitter (seeded hash, not wall-clock randomness) used around staging
  and AOT compiles.
- :class:`CircuitBreaker` — per-(arch, datapath, bucket) failure
  counter; repeated executable failures or non-finite outputs trip it
  and the engine degrades to the next :class:`Lane`
  (int5 -> int8 -> float -> oracle substrate).
- :class:`PackedWire` — the int5 weight payload in its 5-bit wire form
  (``core.trim.quant.pack_int5``) with a CRC-32 checksum per layer and
  the fp32 master copy: a flipped payload is *detected* at
  re-materialization / warmup / breaker-trip and restored from the
  master instead of ever being served.

Everything here is driven by the injectable clock/sleep pair the serve
loop already carries, so chaos tests replay bit-for-bit on a fake clock.
"""

from __future__ import annotations

import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Fault taxonomy
# ---------------------------------------------------------------------------


class InjectedFault(RuntimeError):
    """Base class for every fault the plane raises (site in .site)."""

    site = "generic"


class TransientFault(InjectedFault):
    """A fault that goes away on retry (network blip, allocator race):
    the retry-with-backoff path must absorb it."""

    site = "transient"


class PersistentFault(InjectedFault):
    """A fault that keeps firing on the same lane: retries cannot fix
    it, the circuit breaker must degrade around it."""

    site = "persistent"


class WorkerCrash(InjectedFault):
    """Kills the flush worker thread mid-batch: the Server watchdog must
    fail the in-flight batch terminally and restart the worker."""

    site = "worker"


class NonFiniteOutput(RuntimeError):
    """A served batch came back with NaN/Inf — never delivered as valid;
    counts as an executable failure toward the circuit breaker."""


# ---------------------------------------------------------------------------
# FaultPlan: the frozen, seeded chaos schedule
# ---------------------------------------------------------------------------

#: ``--faults`` spec aliases -> FaultPlan field names.
_SPEC_ALIASES = {
    "seed": "seed",
    "stage": "stage_faults",
    "compile": "compile_faults",
    "exec": "exec_faults",
    "worker": "worker_crashes",
    "nonfinite": "nonfinite_batches",
    "bitflip": "bitflips",
    "latency": "latency_spikes",
    "latency-ms": "latency_spike_ms",
    "latency_ms": "latency_spike_ms",
}


@dataclass(frozen=True)
class FaultPlan:
    """Frozen, hashable "what breaks, how often" (DESIGN.md §11).

    Every count is a fire budget consumed deterministically in call
    order; ``seed`` drives the deterministic jitter and the bit-flip
    positions, so two runs with the same plan inject identically.
    """

    seed: int = 0
    #: transient exceptions at ``ServeEngine.stage`` (first N attempts).
    stage_faults: int = 0
    #: transient exceptions inside ``execute.executable_for`` (warmup).
    compile_faults: int = 0
    #: per-attempt exceptions in ``run_bucket`` on the PRIMARY lane only
    #: (a degraded lane is immune — what the breaker path recovers).
    exec_faults: int = 0
    #: flush-worker crashes (the watchdog/restart path).
    worker_crashes: int = 0
    #: NaN-corrupted batch outputs (the non-finite detection path).
    nonfinite_batches: int = 0
    #: bits flipped in the packed int5 wire payload (integrity path).
    bitflips: int = 0
    #: injected latency spikes before a flush is staged.
    latency_spikes: int = 0
    latency_spike_ms: float = 50.0

    def __post_init__(self):
        for f in ("stage_faults", "compile_faults", "exec_faults",
                  "worker_crashes", "nonfinite_batches", "bitflips",
                  "latency_spikes"):
            if int(getattr(self, f)) < 0:
                raise ValueError(f"{f} must be >= 0")
            object.__setattr__(self, f, int(getattr(self, f)))

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """``"seed=1,worker=1,stage=2,bitflip=1"`` -> FaultPlan.

        THE mapping behind the launchers' ``--faults`` flag: short site
        names (see ``--faults help`` text) with integer budgets;
        ``latency-ms`` is the one float knob.
        """
        kw: Dict[str, Any] = {}
        for part in str(spec).split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"--faults entry {part!r} is not name=value "
                    f"(names: {', '.join(sorted(_SPEC_ALIASES))})")
            name, _, val = part.partition("=")
            key = _SPEC_ALIASES.get(name.strip())
            if key is None:
                raise ValueError(
                    f"unknown --faults site {name.strip()!r} "
                    f"(names: {', '.join(sorted(_SPEC_ALIASES))})")
            kw[key] = float(val) if key == "latency_spike_ms" else int(val)
        return cls(**kw)

    @property
    def total_budget(self) -> int:
        return (self.stage_faults + self.compile_faults + self.exec_faults
                + self.worker_crashes + self.nonfinite_batches
                + self.bitflips + self.latency_spikes)

    def describe(self) -> Dict[str, Any]:
        return {k: v for k, v in self.__dict__.items()
                if v or k == "seed"}


def _hash01(*parts: object) -> float:
    """Deterministic [0, 1) from a seed tuple (crc32 — no wall clock,
    no global RNG: retry jitter must replay bit-for-bit)."""
    h = zlib.crc32(":".join(str(p) for p in parts).encode())
    return (h & 0xFFFFFFFF) / 2.0 ** 32


# ---------------------------------------------------------------------------
# RetryPolicy: bounded backoff + deterministic jitter
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff and seeded jitter.

    ``delay(attempt)`` for attempt 0, 1, ... is
    ``backoff_s * multiplier**attempt * (1 + jitter * u)`` with ``u``
    a deterministic hash of (seed, salt, attempt) — jittered enough to
    de-synchronize real deployments, reproducible enough for the fake
    clock.
    """

    max_attempts: int = 3
    backoff_s: float = 0.01
    multiplier: float = 2.0
    jitter: float = 0.5
    seed: int = 0

    def delay(self, attempt: int, salt: object = 0) -> float:
        base = self.backoff_s * (self.multiplier ** max(attempt, 0))
        return base * (1.0 + self.jitter * _hash01(self.seed, salt, attempt))


def with_retries(fn, policy: RetryPolicy, *, sleep=None, salt: object = 0,
                 on_retry=None):
    """Call ``fn()`` under ``policy``: re-raise only after the budget is
    spent; ``on_retry(attempt, err)`` fires before each backoff sleep."""
    import time as _time

    sleep = sleep or _time.sleep
    attempts = max(1, policy.max_attempts)
    for attempt in range(attempts):
        try:
            return fn()
        except WorkerCrash:
            raise  # a crash is not retryable work, it kills the thread
        except Exception as err:
            if attempt == attempts - 1:
                raise
            if on_retry is not None:
                on_retry(attempt, err)
            sleep(policy.delay(attempt, salt=salt))


# ---------------------------------------------------------------------------
# CircuitBreaker: per-(arch, datapath, bucket) failure accounting
# ---------------------------------------------------------------------------


class CircuitBreaker:
    """Counts consecutive failures per key; trips at ``threshold``.

    A tripped key stays tripped (the engine advances to the next lane,
    which carries a fresh key); ``success`` resets an un-tripped count,
    so only *repeated* failures degrade — one transient blip does not.
    """

    def __init__(self, threshold: int = 3):
        self.threshold = max(1, int(threshold))
        self._counts: Dict[str, int] = {}
        self._tripped: Dict[str, bool] = {}
        self._lock = threading.Lock()

    def failure(self, key: str) -> bool:
        """Record one failure; returns True exactly when this failure
        trips the breaker (count reaches threshold the first time)."""
        with self._lock:
            if self._tripped.get(key):
                return False
            n = self._counts.get(key, 0) + 1
            self._counts[key] = n
            if n >= self.threshold:
                self._tripped[key] = True
                return True
            return False

    def success(self, key: str) -> None:
        with self._lock:
            if not self._tripped.get(key):
                self._counts[key] = 0

    def tripped(self, key: str) -> bool:
        with self._lock:
            return bool(self._tripped.get(key))

    def state(self) -> Dict[str, Dict[str, int]]:
        with self._lock:
            return {k: {"failures": self._counts.get(k, 0),
                        "tripped": int(bool(self._tripped.get(k)))}
                    for k in set(self._counts) | set(self._tripped)}


# ---------------------------------------------------------------------------
# Lane: one (datapath, params, requant[, substrate]) the engine can serve
# ---------------------------------------------------------------------------


@dataclass
class Lane:
    """One servable datapath + its params, in degradation order.

    ``name`` keys executables/breakers (unique per lane);
    ``substrate=None`` keeps the plan policy's substrate, a string pins
    it (the pallas -> f32exact/oracle degradation arm).  ``requant`` is
    required for the integer datapaths, exactly as at the front door.
    """

    name: str
    datapath: str
    params: Any
    requant: Optional[Sequence[Tuple[Any, Any]]] = None
    substrate: Optional[str] = None

    def __post_init__(self):
        if self.datapath not in ("float", "int8", "int5"):
            raise ValueError(
                f"lane datapath {self.datapath!r} not in "
                f"('float', 'int8', 'int5')")
        if self.datapath in ("int8", "int5") and self.requant is None:
            raise ValueError(
                f"lane {self.name!r}: {self.datapath} requires calibrated "
                f"requant pairs (same contract as ServeEngine)")


# ---------------------------------------------------------------------------
# PackedWire: the int5 payload in wire form + integrity machinery
# ---------------------------------------------------------------------------


class PackedWire:
    """The int5 weight image as it would live in BRAM, plus its armor.

    Holds, per conv layer, the MSR codes packed to 5 bits/weight
    (``quant.pack_int5``), the per-channel shifts, and a CRC-32 over the
    packed bytes — alongside the fp32 master params everything was
    quantized from.  ``qparams()`` is the ONLY way weights leave this
    object, and it always verifies the checksums first: a flipped
    payload is re-quantized from the master (``restored`` counts) and
    can never be served.  ``flip_bit`` is the fault-injection hook.
    """

    def __init__(self, cfg, master_params, compensate: bool = True):
        self.cfg = cfg
        self.master = master_params
        self.compensate = bool(compensate)
        #: bumped on every mutation; consumers re-materialize on change.
        self.version = 0
        #: checksum-mismatch layers re-quantized from the master.
        self.restored = 0
        self.on_restore = None  # callback(n_layers) -> None
        self._lock = threading.Lock()
        self._cache: Optional[dict] = None
        self._cache_version = -1
        self._packed: List[Any] = []
        self._shifts: List[Any] = []
        self._shapes: List[Tuple[int, ...]] = []
        self._crcs: List[int] = []
        self._build_from_master()

    # -- construction / restore -----------------------------------------

    def _layer_codes(self):
        """(codes, shifts) per conv layer, quantized from the master."""
        import numpy as np

        from repro.core.trim.quant import msr_compress
        from repro.nn.conv import quantize_cnn

        qp8, _ = quantize_cnn(self.master, self.cfg)
        out = []
        for entry in qp8["conv"]:
            out.append(msr_compress(np.asarray(entry["kernel"])))
        return out

    def _build_from_master(self) -> None:
        from repro.core.trim.quant import pack_int5, wire_checksum

        packed, shifts, shapes, crcs = [], [], [], []
        for codes, sh in self._layer_codes():
            p = pack_int5(codes)
            packed.append(p)
            shifts.append(sh)
            shapes.append(codes.shape)
            crcs.append(wire_checksum(p))
        self._packed, self._shifts = packed, shifts
        self._shapes, self._crcs = shapes, crcs

    @property
    def n_layers(self) -> int:
        return len(self._packed)

    def nbytes(self) -> int:
        return int(sum(p.nbytes for p in self._packed))

    # -- fault-injection + verification ----------------------------------

    def flip_bit(self, layer: int, bit: int) -> None:
        """Flip one bit of one layer's packed payload (a BRAM soft
        error).  Bumps ``version`` so the next materialization re-reads
        — and therefore re-verifies — the wire bytes."""
        with self._lock:
            buf = self._packed[layer]
            buf[(bit // 8) % buf.size] ^= 1 << (bit % 8)
            self.version += 1

    def verify(self) -> List[int]:
        """Layers whose packed bytes no longer match their checksum."""
        from repro.core.trim.quant import wire_checksum

        with self._lock:
            return [i for i, (p, crc) in
                    enumerate(zip(self._packed, self._crcs))
                    if wire_checksum(p) != crc]

    def verify_or_restore(self) -> int:
        """Checksum every layer; re-quantize corrupt ones from the fp32
        master.  Returns how many layers were restored (0 = clean)."""
        bad = self.verify()
        if not bad:
            return 0
        self._build_from_master()
        with self._lock:
            self.restored += len(bad)
            self.version += 1
            self._cache = None
            self._cache_version = -1
        if self.on_restore is not None:
            self.on_restore(len(bad))
        return len(bad)

    # -- materialization --------------------------------------------------

    def qparams(self) -> dict:
        """The int5 runtime params (``{"kernel", "shift"}`` per layer),
        materialized from the verified wire bytes.

        Checksums are verified BEFORE decoding on every re-read (the
        wire is the source of truth a soft error mutates), so flipped
        weights are structurally unservable; the decoded operands are
        cached until ``version`` moves.
        """
        import jax.numpy as jnp
        import numpy as np

        from repro.core.trim.quant import msr_operand, unpack_int5

        with self._lock:
            if self._cache is not None and self._cache_version == self.version:
                return self._cache
        self.verify_or_restore()
        with self._lock:
            conv = []
            for p, sh, shape in zip(self._packed, self._shifts, self._shapes):
                codes = unpack_int5(p, int(np.prod(shape))).reshape(shape)
                w5, e = msr_operand(codes, sh, compensate=self.compensate)
                conv.append({"kernel": jnp.asarray(w5),
                             "shift": jnp.asarray(e, jnp.int32)})
            self._cache = {"conv": conv}
            self._cache_version = self.version
            return self._cache


# ---------------------------------------------------------------------------
# FaultInjector: the armed runtime
# ---------------------------------------------------------------------------


class FaultInjector:
    """Consumes a :class:`FaultPlan`'s budgets at the injection sites.

    Thread-safe: budgets decrement under one lock, so concurrent
    producers/workers fire each fault exactly the planned number of
    times.  ``fired`` is the post-hoc ledger (site -> times fired) the
    launchers stamp into their JSON header.
    """

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._lock = threading.Lock()
        self._budget = {
            "stage": plan.stage_faults,
            "compile": plan.compile_faults,
            "exec": plan.exec_faults,
            "worker": plan.worker_crashes,
            "nonfinite": plan.nonfinite_batches,
            "bitflip": plan.bitflips,
            "latency": plan.latency_spikes,
        }
        self.fired: Dict[str, int] = {k: 0 for k in self._budget}
        self.wire: Optional[PackedWire] = None

    def _take(self, site: str) -> bool:
        with self._lock:
            if self._budget.get(site, 0) <= 0:
                return False
            self._budget[site] -= 1
            self.fired[site] += 1
            return True

    # -- the injection sites ---------------------------------------------

    def fire_stage(self) -> None:
        if self._take("stage"):
            raise TransientFault(
                f"injected transient stage fault #{self.fired['stage']}")

    def fire_compile(self, *a, **kw) -> None:
        """Installed as ``execute.COMPILE_FAULT_HOOK`` during warmup."""
        if self._take("compile"):
            raise TransientFault(
                f"injected transient compile fault #{self.fired['compile']}")

    def fire_exec(self, lane_idx: int) -> None:
        """Persistent executable fault — primary lane only, so the
        degraded lane the breaker falls back to is immune."""
        if lane_idx == 0 and self._take("exec"):
            raise PersistentFault(
                f"injected executable fault #{self.fired['exec']}")

    def crash_worker(self) -> None:
        if self._take("worker"):
            raise WorkerCrash(
                f"injected worker crash #{self.fired['worker']}")

    def corrupt(self, arr):
        """NaN-corrupt one element of a float batch output (budget
        permitting); integer outputs pass through untouched."""
        import numpy as np

        if not np.issubdtype(np.asarray(arr).dtype, np.floating):
            return arr
        if not self._take("nonfinite"):
            return arr
        out = np.array(arr, copy=True)
        pos = int(_hash01(self.plan.seed, "nonfinite",
                          self.fired["nonfinite"]) * out.size)
        out.flat[min(pos, out.size - 1)] = np.nan
        return out

    def latency_s(self) -> float:
        if self._take("latency"):
            return float(self.plan.latency_spike_ms) / 1e3
        return 0.0

    def maybe_flip(self) -> bool:
        """Flip the next planned bit in the bound wire payload; returns
        whether a flip fired (no-op without a wire or budget)."""
        if self.wire is None or not self._take("bitflip"):
            return False
        k = self.fired["bitflip"]
        layer = int(_hash01(self.plan.seed, "flip-layer", k)
                    * self.wire.n_layers)
        nbits = max(self.wire.nbytes() * 8, 1)
        bit = int(_hash01(self.plan.seed, "flip-bit", k) * nbits)
        self.wire.flip_bit(min(layer, self.wire.n_layers - 1), bit)
        return True

    def exhausted(self) -> bool:
        with self._lock:
            return all(v <= 0 for v in self._budget.values())
