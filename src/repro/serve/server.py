"""The `Server` facade: threaded admission + flush worker (DESIGN.md §8).

One object owns the whole serving path.  Many producer threads call
``submit()``; a single dedicated flush worker owns the
:class:`~repro.serve.batching.BucketBatcher` (its lock is the only thing
producers and the worker contend on) and drains it on size or deadline.
A bounded admission queue (``ServeConfig.queue_capacity``) gives
backpressure with an explicit overload policy — ``block`` producers,
``shed`` the request, or ``degrade`` to eager smaller-bucket flushes —
and per-request deadlines expire queued work instead of serving stale
results.

The worker double-buffers host<->device staging: while bucket ``k``
computes on device, bucket ``k+1`` is padded and ``jax.device_put`` (and,
on backends that implement donation, its staged buffer is donated to the
executable — ``engine.execute.executable_for``).  ``np.asarray`` /
``jax.block_until_ready`` happens only at result hand-off, so transfer
and compute overlap across flushes (``ServeMetrics.overlapped`` counts
the flushes that actually pipelined).

``run_stream(stream, producers=0)`` keeps the PR-6 single-threaded open
loop — deterministic on an injected clock, and byte-for-byte the metrics
the deprecated ``serve_stream`` produced; ``producers >= 1`` partitions
the arrival-timed stream across that many real producer threads and
serves it through the worker.  Construct via ``Server.from_plan(plan,
params, ServeConfig(...))`` — the serving-side mirror of
``ExecutionPolicy -> plan_model`` (§3).

Self-healing (DESIGN.md §11): when ``ServeConfig.faults`` arms the
fault plane — or the engine carries fallback lanes — the flush path is
resilient: staging retries transient faults under bounded backoff, a
batch whose executable raises or whose output is non-finite is re-run
(each attempt re-consulting the bucket's active lane, so a circuit-
breaker trip lands the retry on the degraded lane), and a batch that
exhausts its budget reaches the terminal ``failed`` status instead of
orphaning its requests.  A watchdog (checked from ``submit`` and
``drain``) detects a dead flush worker, fails its in-flight work, and
restarts it so queued requests still drain.  Conservation extends to
served + shed + expired + failed == submitted.  With ``faults=None``
and a single lane, every one of these paths collapses to the PR-7
happy path: metrics snapshots are byte-identical.
"""

from __future__ import annotations

import threading
import time
from typing import Any, Callable, Iterable, List, Optional

import numpy as np

from repro.serve.batching import BucketBatcher, Request, pad_batch
from repro.serve.config import ServeConfig
from repro.serve.faults import (FaultInjector, NonFiniteOutput, RetryPolicy,
                                WorkerCrash)
from repro.serve.metrics import ServeMetrics


class Server:
    """Unified serving facade: ``submit`` / ``run_stream`` / ``drain`` /
    ``close`` over one compile-once engine + one frozen ServeConfig."""

    def __init__(
        self,
        engine,
        config: ServeConfig = ServeConfig(),
        *,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        batcher: Optional[BucketBatcher] = None,
        metrics: Optional[ServeMetrics] = None,
    ):
        if tuple(engine.buckets) != tuple(config.buckets):
            raise ValueError(
                f"engine buckets {engine.buckets} != config buckets "
                f"{config.buckets}: one ServeConfig must describe both")
        self.engine = engine
        self.config = config
        self._clock = clock
        self._sleep = sleep
        self._real_clock = clock is time.monotonic
        self.batcher = batcher or BucketBatcher(
            config.buckets, max_delay_s=config.max_delay_s, clock=clock)
        self.metrics = metrics or ServeMetrics(config.buckets)
        #: every admitted request handle, in admission order (what
        #: ``metrics.requests`` is set to at stream end)
        self.requests: List[Request] = []
        self._cv = threading.Condition()
        self._worker: Optional[threading.Thread] = None
        self._running = False
        self._draining = False
        self._closed = False
        #: (bucket, reqs) batches the worker took from the batcher but
        #: has not finished (cv-guarded): what a dead worker's watchdog
        #: cleanup fails terminally instead of orphaning.
        self._worker_work: List = []
        # -- fault/recovery plane (DESIGN.md §11) -----------------------
        self._injector: Optional[FaultInjector] = None
        if config.faults is not None:
            self._injector = FaultInjector(config.faults)
        self._retry = RetryPolicy(
            max_attempts=config.retry_attempts,
            backoff_s=config.retry_backoff_ms / 1e3,
            seed=config.faults.seed if config.faults is not None else 0)
        if hasattr(engine, "install_resilience"):
            engine.install_resilience(
                retry=self._retry,
                breaker_threshold=config.breaker_threshold,
                sleep=sleep, on_retry=self.metrics.record_retried)
            # assign (not install) the injector so a fault-free Server
            # around a previously chaos-armed engine disarms it
            engine.injector = self._injector
            if self._injector is not None:
                self._injector.wire = engine.wire
            if engine.wire is not None:
                engine.wire.on_restore = self.metrics.record_integrity_restored
        #: resilience bookkeeping (breaker success resets) is active only
        #: when something can actually fail or degrade — keeps the
        #: fault-off flush path identical to the PR-7 facade.
        self._resilient = (self._injector is not None
                           or len(getattr(engine, "lanes", ()) or ()) > 1)

    @classmethod
    def from_plan(
        cls,
        plan,
        params,
        config: ServeConfig = ServeConfig(),
        *,
        requant=None,
        warm: bool = True,
        clock: Callable[[], float] = time.monotonic,
        sleep: Callable[[float], None] = time.sleep,
        fallbacks=None,
        wire=None,
    ) -> "Server":
        """A server for one :class:`~repro.engine.ModelPlan`: builds the
        compile-once engine (one AOT executable per bucket, warmed before
        the first request) and wraps it in the facade.  The int8 datapath
        requires calibrated ``requant`` pairs, exactly as the engine
        does.  ``fallbacks``/``wire`` pass through to
        ``ServeEngine.build_for_plan`` (the degradation ladder and the
        checksummed int5 payload, DESIGN.md §11); warmup runs *after*
        the facade arms the fault plane, so injected compile faults and
        the bounded-retry policy cover warmup too."""
        from repro.serve.engine import ServeEngine

        engine = ServeEngine.build_for_plan(
            plan, params, buckets=config.buckets,
            datapath=config.datapath, requant=requant, warm=False,
            fallbacks=fallbacks, wire=wire)
        srv = cls(engine, config, clock=clock, sleep=sleep)
        if warm:
            engine.warmup()
        return srv

    # -- lifecycle ------------------------------------------------------

    def start(self) -> "Server":
        """Spawn the flush worker (idempotent; ``submit`` auto-starts)."""
        with self._cv:
            if self._closed:
                raise RuntimeError("start() on a closed Server")
            if self._running:
                return self
            self._running = True
            self._worker = threading.Thread(
                target=self._worker_run,
                name=f"serve-flush-{self.engine.name}", daemon=True)
            self._worker.start()
        return self

    def _watchdog(self) -> None:
        """A flush worker that died while the server is running is
        replaced (its un-finalized batches were already failed
        terminally by ``_record_worker_death``), so queued requests
        still drain after a crash.  Takes the cv itself — it is backed
        by an RLock, so callers already holding it re-enter safely."""
        with self._cv:
            if (self._running and self._worker is not None
                    and not self._worker.is_alive()):
                self.metrics.record_worker_restart()
                self._worker = threading.Thread(
                    target=self._worker_run,
                    name=f"serve-flush-{self.engine.name}", daemon=True)
                self._worker.start()

    def drain(self, timeout_s: float = 60.0) -> None:
        """Block until every admitted request reached a terminal state
        (served, expired, or failed) — queued work is force-flushed
        sub-bucket.  The wait loop doubles as the watchdog's second
        checkpoint: a worker that dies mid-drain is restarted so the
        remaining queue still ships."""
        with self._cv:
            worker = self._worker
            if worker is not None:
                self._draining = True
                pending = [r for r in self.requests if not r.done.is_set()]
                self._cv.notify_all()
        if worker is None:
            self._flush_ready(force=True)
            return
        end = time.monotonic() + timeout_s
        try:
            for r in pending:
                while not r.done.wait(0.05):
                    self._watchdog()
                    if time.monotonic() > end:
                        raise TimeoutError(
                            f"drain: request {r.rid} not completed within "
                            f"{timeout_s}s (flush worker stuck?)")
        finally:
            with self._cv:
                self._draining = False

    def close(self, timeout_s: float = 60.0) -> None:
        """Drain, stop the flush worker, and reject further submits.
        Producers must have stopped submitting (close is the shutdown
        hand-off, not a cancellation)."""
        with self._cv:
            if self._closed:
                return
            self._closed = True
        self.drain(timeout_s=timeout_s)
        with self._cv:
            worker = self._worker
            self._running = False
            self._cv.notify_all()
        if worker is not None:
            # join OUTSIDE the cv: the worker needs it to observe _running.
            worker.join(timeout=timeout_s)
            if worker.is_alive():
                raise TimeoutError("close: flush worker did not exit")
            with self._cv:
                self._worker = None

    def __enter__(self) -> "Server":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    # -- admission ------------------------------------------------------

    def _admit(self, payload: Any, now: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Request:
        """Shed-or-enqueue + counters (the non-blocking piece shared by
        ``submit`` and the inline open loop).  Caller holds no locks the
        batcher needs; ``requests`` append is atomic under the GIL."""
        t = self._clock() if now is None else float(now)
        if deadline_s is None and self.config.request_timeout_s is not None:
            deadline_s = t + self.config.request_timeout_s
        cap = self.config.queue_capacity
        if (cap and self.config.overload == "shed"
                and self.batcher.depth >= cap):
            r = Request(self.batcher.take_rid(), payload, t,
                        deadline_s=deadline_s)
            r.status = "shed"
            r.done.set()
            self.metrics.record_submit()
            self.metrics.record_shed()
        else:
            r = self.batcher.submit(payload, now=now, deadline_s=deadline_s)
            self.metrics.record_submit()
        # trimcheck: disable=lock-guarded-attr -- list.append is GIL-atomic;
        # threaded callers (submit) already hold the cv, inline mode is
        # single-threaded, and readers snapshot under the cv (drain).
        self.requests.append(r)
        return r

    def submit(self, payload: Any, *, deadline_s: Optional[float] = None,
               now: Optional[float] = None) -> Request:
        """Thread-safe admission: enqueue one request for the flush
        worker; returns its handle (wait on ``r.done``; ``r.status``
        lands on served / shed / expired).  Under the ``block`` overload
        policy a full queue makes this call wait for space — that is the
        backpressure."""
        self.start()
        cfg = self.config
        with self._cv:
            if self._closed:
                raise RuntimeError("submit() on a closed Server")
            self._watchdog()
            if cfg.queue_capacity and cfg.overload == "block":
                while (self.batcher.depth >= cfg.queue_capacity
                       and self._running):
                    self._watchdog()
                    self._cv.wait(0.05)
            r = self._admit(payload, now=now, deadline_s=deadline_s)
            self._cv.notify_all()
        return r

    # -- the flush path (worker-owned in threaded mode) -----------------

    def _finish_expired(self, r: Request) -> None:
        r.status = "expired"
        self.metrics.record_expired()
        r.done.set()

    def _finish_failed(self, reqs: List[Request], err=None) -> None:
        """Terminal ``failed``: the requests never get a result, but
        they ARE accounted — the conservation invariant is
        served + shed + expired + failed == submitted."""
        msg = f"{type(err).__name__}: {err}" if err is not None else "failed"
        for r in reqs:
            r.status = "failed"
            r.error = msg
            r.done.set()
        self.metrics.record_failed(len(reqs))
        self._done_with(reqs)

    def _done_with(self, reqs: List[Request]) -> None:
        """Drop a now-terminal batch from the worker's in-progress
        registry (no-op in inline mode, where nothing registers)."""
        with self._cv:
            if self._worker_work:
                self._worker_work[:] = [
                    w for w in self._worker_work if w[1] is not reqs]

    def _stage_retry(self, images):
        """``engine.stage`` under the bounded-retry policy: a transient
        staging fault (allocator race, injected TransientFault) is
        absorbed by backoff; the final attempt's error propagates to the
        batch-level recovery driver."""
        attempts = self.config.retry_attempts
        for attempt in range(attempts):
            try:
                return self.engine.stage(images)
            except Exception as err:
                if attempt == attempts - 1:
                    raise
                self.metrics.record_retried()
                self._sleep(self._retry.delay(attempt, salt="stage"))
        raise AssertionError("unreachable")  # pragma: no cover

    def _dispatch(self, bucket: int, reqs: List[Request]):
        """Stage one batch (pad + device_put) and launch its compute
        asynchronously.  Called back-to-back with a prior in-flight
        batch, the device_put here overlaps that batch's compute — the
        double-buffering."""
        t0 = self._clock()
        depth = self.batcher.depth
        if self._injector is not None:
            self._injector.maybe_flip()
            spike = self._injector.latency_s()
            if spike > 0.0:
                self._sleep(spike)
        staged = self._stage_retry(
            pad_batch([r.payload for r in reqs], bucket))
        out = self.engine.run_bucket(bucket, staged)
        return (bucket, reqs, out, t0, depth)

    def _finalize(self, dispatched) -> None:
        """Result hand-off: the ONLY place the flush path blocks on
        device work (np.asarray == block_until_ready).  A float batch
        with NaN/Inf is never delivered as valid — it raises
        :class:`NonFiniteOutput` into the recovery driver instead."""
        bucket, reqs, out, t0, depth = dispatched
        arr = np.asarray(out)
        if self._injector is not None:
            arr = self._injector.corrupt(arr)
        if arr.dtype.kind == "f" and not np.isfinite(arr).all():
            raise NonFiniteOutput(
                f"bucket {bucket}: non-finite values in served batch")
        t1 = self._clock()
        for i, r in enumerate(reqs):
            r.result = arr[i]
            r.status = "served"
            r.done.set()
        self.metrics.record_flush(
            bucket, len(reqs), batch_s=t1 - t0,
            latencies_s=[t1 - r.t_submit for r in reqs],
            queue_depth=depth)

    # -- recovery (DESIGN.md §11) ---------------------------------------

    def _record_batch_failure(self, bucket: int, err) -> None:
        """One failed batch attempt -> the engine's circuit breaker; a
        trip degrades the bucket's lane and is recorded in metrics."""
        ev = self.engine.note_failure(bucket) \
            if hasattr(self.engine, "note_failure") else None
        if ev is not None:
            self.metrics.record_degraded(ev["key"], ev["to"])

    def _run_batch(self, bucket: int, reqs: List[Request], err=None) -> bool:
        """Recovery driver: entered only after a failed attempt.

        Re-runs the batch synchronously under the remaining retry
        budget with backoff; every attempt re-consults the bucket's
        active lane, so a circuit-breaker trip mid-loop lands the next
        attempt on the degraded lane.  Exhausting the budget fails the
        batch terminally (never raises into the flush worker)."""
        for attempt in range(self.config.retry_attempts - 1):
            self.metrics.record_retried()
            self._sleep(self._retry.delay(attempt, salt=f"batch-{bucket}"))
            try:
                self._finalize(self._dispatch(bucket, reqs))
                if self._resilient:
                    self.engine.note_success(bucket)
                self._done_with(reqs)
                return True
            except WorkerCrash:
                raise
            except Exception as e:
                err = e
                self._record_batch_failure(bucket, e)
        self._finish_failed(reqs, err)
        return False

    def _dispatch_async(self, bucket: int, reqs: List[Request]):
        """One pipelined dispatch attempt for the flush path; on failure
        the batch drops into the synchronous recovery driver (losing
        only its staging overlap).  Returns the dispatched tuple, or
        None when the batch already reached a terminal state."""
        try:
            return self._dispatch(bucket, reqs)
        except WorkerCrash:
            raise
        except Exception as err:
            self._record_batch_failure(bucket, err)
            self._run_batch(bucket, reqs, err=err)
            return None

    def _complete(self, dispatched) -> None:
        """Finalize one dispatched batch, routing failures (executable
        exceptions surfacing at materialization, non-finite outputs)
        into the recovery driver."""
        bucket, reqs = dispatched[0], dispatched[1]
        try:
            self._finalize(dispatched)
        except WorkerCrash:
            raise
        except Exception as err:
            self._record_batch_failure(bucket, err)
            self._run_batch(bucket, reqs, err=err)
            return
        if self._resilient:
            self.engine.note_success(bucket)
        self._done_with(reqs)

    def _overloaded_degrade(self) -> bool:
        cap = self.config.queue_capacity
        return bool(cap and self.config.overload == "degrade"
                    and self.batcher.depth >= cap)

    def _flush_ready(self, force: bool = False) -> None:
        """Inline flush: expire + serve every currently-shippable batch
        synchronously (the single-threaded open loop's arm — no staging
        overlap; the threaded pipeline lives in ``_worker_loop``)."""
        while True:
            now = self._clock()
            for r in self.batcher.purge_expired(now):
                self._finish_expired(r)
            got = self.batcher.poll(now=now, force=force)
            if got is None:
                return
            d = self._dispatch_async(*got)
            if d is not None:
                self._complete(d)

    def _worker_run(self) -> None:
        """The flush worker's thread target: the detection seam the
        watchdog relies on.  ANY escape — an injected WorkerCrash or a
        real bug — is recorded (in-flight batches failed terminally,
        waiters woken) instead of silently orphaning requests; the
        watchdog then restarts the worker from ``submit``/``drain``."""
        try:
            self._worker_loop()
        except BaseException as err:
            self._record_worker_death(err)

    def _record_worker_death(self, err) -> None:
        """A dead worker's last act: every batch it had taken from the
        batcher but not finished is failed terminally (extended
        conservation stays intact) and counted against the circuit
        breaker — a crash mid-batch is evidence against that lane."""
        with self._cv:
            work = list(self._worker_work)
            self._worker_work.clear()
            self._cv.notify_all()
        for bucket, reqs in work:
            self._record_batch_failure(bucket, err)
            self._finish_failed(reqs, err)

    def _worker_loop(self) -> None:
        """The dedicated flush worker: the one consumer of the batcher.

        Keeps at most one batch in flight on device; when a second batch
        becomes shippable it is staged and launched BEFORE the in-flight
        one is finalized, so its transfer overlaps the running compute.
        Exits when the server stops and the queue is drained.
        """
        inflight = None
        while True:
            with self._cv:
                now = self._clock()
                expired = self.batcher.purge_expired(now)
                eager = (self._draining or not self._running
                         or self._overloaded_degrade())
                got = self.batcher.poll(now=now, force=eager)
                if got is not None:
                    # register BEFORE any fallible work: a crash between
                    # poll and finalize must not orphan the batch
                    self._worker_work.append(got)
                if expired or got:
                    # queue depth dropped: wake block-policy producers
                    self._cv.notify_all()
                if got is None and not expired and inflight is None:
                    if not self._running and self.batcher.depth == 0:
                        self._cv.notify_all()
                        return
                    dl = self.batcher.next_deadline()
                    # An injected clock may not advance with real time, so
                    # cap the real-time cv wait and re-read it frequently.
                    cap = None if self._real_clock else 0.05
                    timeout = cap if dl is None else max(dl - now, 0.0)
                    if cap is not None and timeout is not None:
                        timeout = min(timeout, cap)
                    self._cv.wait(timeout)
                    continue
            for r in expired:
                self._finish_expired(r)
            if got is not None:
                if self._injector is not None:
                    self._injector.crash_worker()
                # stage while inflight computes (the double buffer)
                nxt = self._dispatch_async(*got)
                if inflight is not None:
                    self.metrics.record_overlap()
                    self._complete(inflight)
                inflight = nxt
            elif inflight is not None:
                self._complete(inflight)
                inflight = None

    # -- stream drivers -------------------------------------------------

    def run_stream(self, stream: Iterable, *, producers: int = 0) -> ServeMetrics:
        """Serve an arrival-timed request stream; returns filled metrics.

        ``producers == 0``: the deterministic single-threaded open loop
        (admit at arrival times on the injected clock, flush size- and
        deadline-triggered batches inline) — the PR-6 ``serve_stream``
        semantics, still what the fake-clock tests and the concurrency
        benchmark's baseline arm drive.  ``producers >= 1``: partition
        the stream round-robin across that many real producer threads
        submitting through :meth:`submit` while the flush worker drains.
        """
        if producers and producers > 0:
            return self._run_stream_threaded(stream, int(producers))
        return self._run_stream_inline(stream)

    def _run_stream_inline(self, stream: Iterable) -> ServeMetrics:
        cfg = self.config
        t0 = self._clock()
        for item in stream:
            t_arr, payload = float(item[0]), item[1]
            while self._clock() - t0 < t_arr:
                deadline = self.batcher.next_deadline()
                now = self._clock()
                if deadline is not None and deadline <= now:
                    self._flush_ready()
                    continue
                wait = t0 + t_arr - now
                if deadline is not None:
                    wait = min(wait, deadline - now)
                self._sleep(max(wait, 0.0))
            if (cfg.queue_capacity and cfg.overload in ("block", "degrade")
                    and self.batcher.depth >= cfg.queue_capacity):
                # The inline loop IS the flush worker, so both waiting
                # for space (block) and eager draining (degrade) mean the
                # same thing here: ship what is queued, sub-bucket, now.
                self._flush_ready(force=True)
            self._admit(payload)
            self._flush_ready()
        self._flush_ready(force=True)
        self.metrics.wall_s = self._clock() - t0
        # trimcheck: disable=lock-guarded-attr -- inline loop: no flush
        # worker exists, the stream ran on this one thread.
        self.metrics.requests = self.requests
        return self.metrics

    def _run_stream_threaded(self, stream: Iterable,
                             producers: int) -> ServeMetrics:
        items = list(stream)
        self.start()
        t0 = self._clock()

        def producer(k: int) -> None:
            for item in items[k::producers]:
                t_arr = float(item[0])
                while True:
                    now = self._clock()
                    if now - t0 >= t_arr:
                        break
                    self._sleep(min(t_arr - (now - t0), 0.05))
                self.submit(item[1])

        threads = [
            threading.Thread(target=producer, args=(k,),
                             name=f"serve-producer-{k}", daemon=True)
            for k in range(producers)
        ]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        self.drain()
        self.metrics.wall_s = self._clock() - t0
        # trimcheck: disable=lock-guarded-attr -- producers joined and
        # drain() returned: the request list is quiescent here.
        self.metrics.requests = list(self.requests)
        return self.metrics
